"""Training step factory: sharded loss/grad/update with grad accumulation.

``make_train_step`` returns the jitted function the dry-run lowers for the
``train_4k`` cells.  Parameter PartitionSpecs come from per-name logical
axis rules + the policy's FSDP pass; optimizer states inherit the param
specs (ZeRO-style).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import model as M
from ..optim import adamw
from ..sharding import Policy

# logical axes for the *last* dims of each named parameter; leading stack
# dims are padded with None.  'heads'/'ff'/'experts'/'vocab' all map to the
# model axis under the default rules; FSDP then claims one leftover dim.
_PARAM_AXES: dict[str, tuple] = {
    "embed": ("vocab", "nofsdp"),
    "lm_head": ("nofsdp", "vocab"),
    "wq": (None, "heads"),
    "wk": (None, "kv_heads_p"),
    "wv": (None, "kv_heads_p"),
    "wo": ("ff", None),
    "wi": (None, "ff"),
    "w_up": ("experts", None, None),
    "w_down": ("experts", None, None),
    "router": (None, None),
    "wq_a": (None, None),
    "wq_b": (None, "heads"),
    "wkv_a": (None, None),
    "wkv_b": (None, "heads"),
    "in_proj": (None, "ff"),
    "out_proj": ("ff", None),
    "up": (None, "ff"),
    "down": ("ff", None),
    "w_in": (None, "ff"),
    "proj": (None, None),
}


def logical_axes_for(path, shape) -> tuple:
    name = None
    for p in reversed(path):
        if hasattr(p, "key"):
            name = str(p.key)
            break
    axes = _PARAM_AXES.get(name, ())
    ndim = len(shape)
    if len(axes) > ndim:
        axes = axes[-ndim:]
    return (None,) * (ndim - len(axes)) + tuple(axes)


def param_pspecs(policy: Policy, params_tree) -> Any:
    """Pytree of PartitionSpec matching params (works on ShapeDtypeStructs)."""
    def spec(path, leaf):
        axes = logical_axes_for(path, leaf.shape)
        return policy.param_spec(leaf.shape, axes)
    return jax.tree_util.tree_map_with_path(spec, params_tree)


def param_shardings(policy: Policy, params_tree) -> Any:
    mesh = policy.mesh
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        param_pspecs(policy, params_tree))


def batch_pspecs(policy: Policy, batch_tree) -> Any:
    def spec(path, leaf):
        # guarded: a batch dim the data axes don't divide (e.g. the
        # long_500k cell's global_batch=1) stays replicated
        return policy.guarded_spec(leaf.shape, "batch")
    return jax.tree_util.tree_map_with_path(spec, batch_tree)


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1           # gradient accumulation steps
    opt: adamw.AdamWConfig = dataclasses.field(default_factory=adamw.AdamWConfig)
    # top-k gradient compression with error feedback (optim.compress);
    # None = exact synchronization
    compress: "object" = None


def make_train_step(cfg, tc: TrainConfig, policy: Policy):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics).  With ``tc.microbatches > 1`` the batch's leading dim is split
    and gradients accumulate in fp32 through a scan (memory/compute knob
    used by the perf hillclimb)."""

    def loss(p, b):
        return M.loss_fn(cfg, p, b, policy)

    def grads_of(params, batch):
        if tc.microbatches <= 1:
            (l, met), g = jax.value_and_grad(loss, has_aux=True)(params, batch)
            return l, met, g
        n = tc.microbatches

        def split_mb(x):
            return x.reshape(n, x.shape[0] // n, *x.shape[1:])
        mbs = jax.tree.map(split_mb, batch)

        def one(carry, mb):
            acc, lsum = carry
            (l, met), g = jax.value_and_grad(loss, has_aux=True)(params, mb)
            acc = jax.tree.map(lambda a, x: a + x.astype(jnp.float32), acc, g)
            return (acc, lsum + l), met
        acc0 = jax.tree.map(lambda x: jnp.zeros(x.shape, jnp.float32), params)
        (g, lsum), mets = jax.lax.scan(one, (acc0, 0.0), mbs)
        g = jax.tree.map(lambda x: x / n, g)
        met = jax.tree.map(lambda x: x[-1], mets)
        return lsum / n, met, g

    if tc.compress is not None:
        from ..optim import compress as C

        def train_step(params, state, batch):
            opt_state, residual = state["opt"], state["residual"]
            l, met, g = grads_of(params, batch)
            g, residual = C.compress(tc.compress, g, residual)
            params, opt_state, om = adamw.apply_updates(tc.opt, params, g,
                                                        opt_state)
            met = dict(met)
            met.update(om)
            met["loss"] = l
            return params, {"opt": opt_state, "residual": residual}, met

        return train_step

    def train_step(params, opt_state, batch):
        l, met, g = grads_of(params, batch)
        params, opt_state, om = adamw.apply_updates(tc.opt, params, g, opt_state)
        met = dict(met)
        met.update(om)
        met["loss"] = l
        return params, opt_state, met

    return train_step


def jit_train_step(cfg, tc: TrainConfig, policy: Policy, params_shapes,
                   batch_shapes):
    """jit with explicit in/out shardings (what the dry-run lowers)."""
    step = make_train_step(cfg, tc, policy)
    mesh = policy.mesh
    pspec = param_shardings(policy, params_shapes)
    ospec = {"mu": pspec, "nu": pspec,
             "step": NamedSharding(mesh, P())}
    bspec = jax.tree.map(lambda s: NamedSharding(mesh, s),
                         batch_pspecs(policy, batch_shapes))
    mspec = NamedSharding(mesh, P())
    return jax.jit(
        step,
        in_shardings=(pspec, ospec, bspec),
        out_shardings=(pspec, ospec, mspec),
        donate_argnums=(0, 1),
    )
