"""xlstm-125m [ssm] — alternating mLSTM/sLSTM blocks [arXiv:2405.04517]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="xlstm-125m", family="ssm", block_pattern="xlstm",
    n_layers=12, d_model=768, n_heads=4, n_kv_heads=4, d_ff=0,
    vocab=50304, d_head=192, tie_embeddings=True,
    source="arXiv:2405.04517",
))
