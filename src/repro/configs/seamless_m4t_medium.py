"""seamless-m4t-medium [audio] — enc-dec backbone; modality frontend is a
STUB (input_specs() provides precomputed frame embeddings)
[arXiv:2308.11596; hf]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="seamless-m4t-medium", family="audio", block_pattern="encdec",
    n_layers=24, n_enc_layers=12, n_dec_layers=12,
    d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab=256206, d_head=64, modality_stub=True, rope_theta=1e4,
    source="arXiv:2308.11596",
))
