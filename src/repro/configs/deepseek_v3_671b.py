"""deepseek-v3-671b [moe] — MLA, 1 shared + 256 routed top-8, MTP
[arXiv:2412.19437; hf]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-v3-671b", family="moe", block_pattern="mla_moe",
    n_layers=61, d_model=7168, n_heads=128, n_kv_heads=128,
    d_ff=18432,           # dense-layer FFN width (first_k_dense layers)
    vocab=129280, attn_type="mla",
    q_lora_rank=1536, kv_lora_rank=512,
    qk_nope_head_dim=128, qk_rope_head_dim=64, v_head_dim=128,
    n_experts=256, moe_top_k=8, moe_d_ff=2048, n_shared_experts=1,
    first_k_dense=3, mtp=True, rope_theta=1e4,
    source="arXiv:2412.19437",
))
