"""stablelm-12b [dense] — [hf:stabilityai/stablelm-2-12b; hf]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="stablelm-12b", family="dense", block_pattern="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=13824,
    vocab=100352, d_head=160, rope_theta=1e4,
    source="hf:stabilityai/stablelm-2-12b",
))
