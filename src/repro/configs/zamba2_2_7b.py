"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block every 6
layers, ssm_state=64 [arXiv:2411.15242; hf]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="zamba2-2.7b", family="hybrid", block_pattern="zamba2",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32000, d_head=80, ssm_state=64, ssm_headdim=64,
    zamba_attn_every=6, rope_theta=1e4,
    source="arXiv:2411.15242",
))
