"""Model configuration dataclass + registry.

One ``<arch>.py`` per assigned architecture registers its exact published
config here; ``reduced()`` derives the CPU smoke-test variant of the same
family (small widths/layers/experts, identical code paths).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax.numpy as jnp

_REGISTRY: dict[str, "ModelConfig"] = {}


@dataclasses.dataclass
class ModelConfig:
    name: str
    family: str                      # dense | moe | audio | vlm | ssm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    block_pattern: str = "dense"     # dense|moe|mla_moe|xlstm|zamba2|encdec
    d_head: int | None = None
    qk_norm: bool = False
    causal: bool = True
    rope_theta: float = 5e5
    mrope: bool = False
    mrope_sections: tuple = (16, 24, 24)
    # --- MLA (deepseek) ---
    attn_type: str = "gqa"           # gqa | mla
    q_lora_rank: int = 1536
    kv_lora_rank: int = 512
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128
    # --- MoE ---
    n_experts: int = 0
    moe_top_k: int = 8
    moe_d_ff: int = 0
    n_shared_experts: int = 0
    first_k_dense: int = 0
    moe_capacity_factor: float = 1.25
    moe_renorm: bool = True
    moe_group_size: int = 512       # dispatch-group tokens (shards over data)
    aux_loss_coef: float = 0.01
    # --- SSM / Mamba2 (zamba2) ---
    ssm_state: int = 64
    ssm_expand: int = 2
    ssm_groups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256
    ssm_headdim: int = 64
    zamba_attn_every: int = 6
    # --- xLSTM ---
    xlstm_expand: int = 2
    slstm_every: int = 2             # every 2nd block is sLSTM
    # --- enc-dec (seamless) ---
    n_enc_layers: int = 0
    n_dec_layers: int = 0
    # --- frontends / heads ---
    modality_stub: bool = False      # inputs are precomputed embeddings
    mtp: bool = False                # deepseek multi-token prediction
    tie_embeddings: bool = False
    # --- numerics / chunking ---
    dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    q_chunk: int = 512
    kv_chunk: int = 1024
    remat: bool = True
    # dispatch inference paths (prefill/decode) to the Pallas kernels;
    # training keeps the jnp reference (pallas_call has no implicit VJP)
    use_kernels: bool = False
    # --- provenance ---
    source: str = ""

    def __post_init__(self):
        if self.d_head is None:
            self.d_head = self.d_model // self.n_heads

    # derived SSM dims
    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_headdim

    @property
    def xlstm_d_inner(self) -> int:
        return self.xlstm_expand * self.d_model

    @property
    def slstm_ff(self) -> int:
        return 2 * self.d_model

    @property
    def jdtype(self):
        return jnp.dtype(self.dtype)

    @property
    def sub_quadratic(self) -> bool:
        """Whether the arch supports long_500k (recurrent/hybrid state)."""
        return self.block_pattern in ("xlstm", "zamba2")

    def param_count(self) -> int:
        """Approximate parameter count (sanity checks + MODEL_FLOPS)."""
        d, dh = self.d_model, self.d_head
        def attn_params():
            if self.attn_type == "mla":
                return (d * self.q_lora_rank
                        + self.q_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.qk_rope_head_dim)
                        + d * (self.kv_lora_rank + self.qk_rope_head_dim)
                        + self.kv_lora_rank * self.n_heads * (self.qk_nope_head_dim + self.v_head_dim)
                        + self.n_heads * self.v_head_dim * d)
            return d * (self.n_heads + 2 * self.n_kv_heads) * dh + self.n_heads * dh * d

        def mlp_params(ff):
            return 3 * d * ff

        n = self.vocab * d  # embed
        if not self.tie_embeddings:
            n += self.vocab * d
        if self.block_pattern in ("dense", "moe", "mla_moe"):
            L = self.n_layers
            k_dense = self.first_k_dense if self.n_experts else L
            moe_layers = L - k_dense if self.n_experts else 0
            dense_layers = L - moe_layers
            n += dense_layers * (attn_params() + mlp_params(self.d_ff))
            if moe_layers:
                per_moe = (attn_params() + d * self.n_experts
                           + self.n_experts * mlp_params(self.moe_d_ff) / 1  # routed
                           + self.n_shared_experts * mlp_params(self.moe_d_ff))
                n += moe_layers * per_moe
        elif self.block_pattern == "encdec":
            per = attn_params() + mlp_params(self.d_ff)
            n += self.n_enc_layers * per
            n += self.n_dec_layers * (per + attn_params())  # + cross-attn
        elif self.block_pattern == "xlstm":
            di = self.xlstm_d_inner
            per_m = 2 * d * di + 3 * di * di + di * d
            per_s = 4 * d * d + d * (d // self.n_heads) * 4 + 3 * d * self.slstm_ff
            n += (self.n_layers // 2) * (per_m + per_s)
        elif self.block_pattern == "zamba2":
            di = self.ssm_d_inner
            conv_dim = di + 2 * self.ssm_state * self.ssm_groups
            per = (d * (2 * di + 2 * self.ssm_state * self.ssm_groups + self.ssm_heads)
                   + self.ssm_conv * conv_dim + di * d)
            n += self.n_layers * per
            n += attn_params()  # one shared attention block
        return int(n)

    def reduced(self) -> "ModelConfig":
        """Smoke-test config: same family/code paths, tiny sizes."""
        r = dataclasses.replace(
            self,
            name=self.name + "-reduced",
            n_layers=max(2, min(4, self.n_layers)),
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) or 2,
            d_head=16,
            d_ff=128 if self.d_ff else 0,
            vocab=256,
            n_experts=min(self.n_experts, 4),
            moe_d_ff=64 if self.n_experts else 0,
            moe_top_k=min(self.moe_top_k, 2),
            moe_capacity_factor=8.0,   # no token drops in smoke tests
            moe_group_size=64,
            first_k_dense=min(self.first_k_dense, 1),
            q_lora_rank=32, kv_lora_rank=16,
            qk_nope_head_dim=16, qk_rope_head_dim=8, v_head_dim=16,
            mrope_sections=(2, 3, 3) if self.mrope else self.mrope_sections,
            ssm_state=16, ssm_headdim=16, ssm_chunk=16,
            n_enc_layers=2 if self.n_enc_layers else 0,
            n_dec_layers=2 if self.n_dec_layers else 0,
            zamba_attn_every=2,
            q_chunk=32, kv_chunk=32,
            dtype="float32",
            remat=False,
        )
        if r.block_pattern == "zamba2":
            r = dataclasses.replace(r, n_layers=4)
        if r.block_pattern == "xlstm":
            r = dataclasses.replace(r, n_layers=4)
        return r


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if name not in _REGISTRY:
        # import all config modules lazily
        from . import ALL_ARCHS  # noqa: F401
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    from . import ALL_ARCHS  # noqa: F401
    return sorted(_REGISTRY)
