"""Assigned-architecture configs (one module per arch) + registry."""
from .base import ModelConfig, get_config, list_configs, register  # noqa

from . import (llama3_2_1b, mistral_large_123b, qwen3_8b, stablelm_12b,   # noqa
               deepseek_v3_671b, granite_moe_1b, seamless_m4t_medium,
               qwen2_vl_72b, xlstm_125m, zamba2_2_7b)

ALL_ARCHS = [
    "llama3.2-1b", "mistral-large-123b", "qwen3-8b", "stablelm-12b",
    "deepseek-v3-671b", "granite-moe-1b-a400m", "seamless-m4t-medium",
    "qwen2-vl-72b", "xlstm-125m", "zamba2-2.7b",
]
