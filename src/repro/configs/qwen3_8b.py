"""qwen3-8b [dense] — qk_norm + GQA [hf:Qwen/Qwen3-8B; hf]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-8b", family="dense", block_pattern="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8, d_ff=12288,
    vocab=151936, d_head=128, qk_norm=True, rope_theta=1e6,
    source="hf:Qwen/Qwen3-8B",
))
