"""qwen2-vl-72b [vlm] — M-RoPE backbone; patch frontend is a STUB
[arXiv:2409.12191; hf]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-72b", family="vlm", block_pattern="dense",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8, d_ff=29568,
    vocab=152064, d_head=128, mrope=True, mrope_sections=(16, 24, 24),
    modality_stub=True, rope_theta=1e6,
    source="arXiv:2409.12191",
))
