import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this driver:
  1. builds the production mesh ((16,16) single-pod / (2,16,16) multi-pod),
  2. builds ShapeDtypeStruct stand-ins for params/optimizer/batch/cache,
  3. jit-lowers the train/prefill/serve step with explicit in/out
     shardings and compiles it,
  4. records memory_analysis() (proves it fits), cost_analysis()
     (FLOPs/bytes), and the collective-byte totals parsed from the
     compiled HLO (all-gather/all-reduce/reduce-scatter/all-to-all/
     collective-permute) for the roofline (EXPERIMENTS.md §Roofline).

Results accumulate in a JSON file so the 40-cell sweep is resumable.

Usage:
  python -m repro.launch.dryrun --arch llama3.2-1b --shape train_4k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results.json]
"""
import argparse
import json
import re
import time
import traceback

import jax
import numpy as np

from ..configs import ALL_ARCHS, get_config
from ..models import model as M
from ..optim import adamw
from ..serving import engine as E
from ..sharding import Policy
from ..train import trainer as T
from .mesh import make_production_mesh
from .roofline import collective_bytes_hlo, count_jaxpr
from .specs import (HBM_BW, ICI_BW, PEAK_FLOPS, SHAPE_CELLS, ShapeCell,
                    cell_applicable, input_specs, model_flops)

def lower_cell(arch: str, shape: str, multi_pod: bool,
               fsdp: bool = True, microbatches: int = 1,
               overrides: dict | None = None,
               sp: bool = False, serve_layout: str | None = None,
               train_layout: str | None = None):
    """Lower + compile one cell; returns the result record.

    ``sp`` / ``serve_layout`` select the §Perf hillclimb layouts
    (sharding.make_rules); the defaults are the paper-faithful baseline.
    """
    cfg = get_config(arch)
    cell = SHAPE_CELLS[shape]
    ok, why = cell_applicable(cfg, cell)
    rec = {"arch": arch, "shape": shape,
           "mesh": "2x16x16" if multi_pod else "16x16",
           "sp": sp, "serve_layout": serve_layout,
           "train_layout": train_layout}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec

    from ..sharding import make_rules
    if serve_layout in ("1d", "2d"):
        fsdp = False        # params stationary; no per-step FSDP gathers
    mesh = make_production_mesh(multi_pod=multi_pod)
    policy = Policy(mesh=mesh, fsdp=fsdp, overrides=overrides or {},
                    rules=make_rules(sp=sp, serve_layout=serve_layout,
                                     train_layout=train_layout))
    n_chips = int(np.prod(list(mesh.shape.values())))
    params_shapes = M.param_shapes(cfg)
    specs = input_specs(cfg, cell)
    t0 = time.time()

    if cell.kind == "train":
        # bf16 optimizer state for the giant configs (DESIGN.md §6)
        state_dtype = ("bfloat16" if cfg.param_count() > 5e10 else "float32")
        tc = T.TrainConfig(microbatches=microbatches,
                           opt=adamw.AdamWConfig(state_dtype=state_dtype))
        opt_shapes = jax.eval_shape(
            lambda p: adamw.init_state(tc.opt, p), params_shapes)
        step = T.jit_train_step(cfg, tc, policy, params_shapes,
                                specs["batch"])
        raw = T.make_train_step(cfg, tc, policy)
        with mesh:
            jxp = jax.make_jaxpr(raw)(params_shapes, opt_shapes, specs["batch"])
            lowered = step.lower(params_shapes, opt_shapes, specs["batch"])
    elif cell.kind == "prefill":
        step = E.jit_prefill(cfg, policy, params_shapes, specs["batch"],
                             max_len=specs["max_len"])
        with mesh:
            jxp = jax.make_jaxpr(
                lambda p, b: M.prefill(cfg, p, b, max_len=specs["max_len"],
                                       shd=policy))(params_shapes, specs["batch"])
            lowered = step.lower(params_shapes, specs["batch"])
    else:  # decode
        step = E.jit_decode_step(cfg, policy, params_shapes, specs["cache"],
                                 specs["batch"])
        with mesh:
            jxp = jax.make_jaxpr(
                lambda p, c, b: M.decode_step(cfg, p, c, b, policy))(
                params_shapes, specs["cache"], specs["batch"])
            lowered = step.lower(params_shapes, specs["cache"],
                                 specs["batch"])
    jcost = count_jaxpr(jxp)

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes_hlo(hlo)

    # XLA cost_analysis counts while bodies ONCE (see roofline.py), so the
    # authoritative FLOP/byte totals come from the jaxpr counter (global,
    # scan-multiplied); per-chip = /n_chips under even sharding.  The HLO
    # numbers are kept as diagnostics.
    flops = jcost["flops"] / n_chips
    bytes_acc = jcost["bytes"] / n_chips
    hlo_flops_once = float(cost.get("flops", 0.0))
    coll_total = sum(coll.values())
    t_compute = flops / PEAK_FLOPS
    t_memory = bytes_acc / HBM_BW
    t_coll = coll_total / ICI_BW
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    mf = model_flops(cfg, cell)
    mf_per_chip = mf / n_chips

    rec.update(
        status="ok",
        n_chips=n_chips,
        lower_s=round(t_lower, 1),
        compile_s=round(t_compile, 1),
        bytes_per_device=int(getattr(mem, "temp_size_in_bytes", 0)
                             + getattr(mem, "argument_size_in_bytes", 0)
                             + getattr(mem, "output_size_in_bytes", 0)),
        temp_bytes=int(getattr(mem, "temp_size_in_bytes", 0)),
        arg_bytes=int(getattr(mem, "argument_size_in_bytes", 0)),
        out_bytes=int(getattr(mem, "output_size_in_bytes", 0)),
        gen_code_bytes=int(getattr(mem, "generated_code_size_in_bytes", 0)),
        flops_per_chip=flops,
        bytes_per_chip=bytes_acc,
        hlo_flops_body_once=hlo_flops_once,
        collective_bytes_per_chip=coll_total,
        collectives=coll,
        roofline={
            "compute_s": t_compute,
            "memory_s": t_memory,
            "collective_s": t_coll,
            "dominant": dominant,
        },
        model_flops_total=mf,
        model_flops_per_chip=mf_per_chip,
        useful_flop_ratio=(mf_per_chip / flops) if flops else None,
    )
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--fsdp", type=int, default=1)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--sp", action="store_true",
                    help="sequence-parallel residual activations (train)")
    ap.add_argument("--serve-layout", default=None,
                    choices=["legacy", "1d", "2d"],
                    help="decode-path layout (perf hillclimb)")
    ap.add_argument("--train-layout", default=None, choices=["tp", "dp"],
                    help="train-path layout (perf hillclimb)")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--force", action="store_true",
                    help="recompute cells already in the results file")
    args = ap.parse_args()

    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    archs = ALL_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPE_CELLS) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                key = f"{arch}|{shape}|{'2x16x16' if mp else '16x16'}"
                if key in results and results[key].get("status") in ("ok", "skipped") \
                        and not args.force:
                    print(f"[cached] {key}")
                    continue
                print(f"[lower+compile] {key} ...", flush=True)
                try:
                    rec = lower_cell(arch, shape, mp, fsdp=bool(args.fsdp),
                                     microbatches=args.microbatches,
                                     sp=args.sp,
                                     serve_layout=args.serve_layout,
                                     train_layout=args.train_layout)
                except Exception as e:
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": "error", "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                results[key] = rec
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
                if rec["status"] == "ok":
                    r = rec["roofline"]
                    print(f"  ok: {rec['compile_s']}s compile, "
                          f"{rec['bytes_per_device']/2**30:.2f} GiB/dev, "
                          f"dominant={r['dominant']} "
                          f"(c={r['compute_s']*1e3:.2f}ms m={r['memory_s']*1e3:.2f}ms "
                          f"coll={r['collective_s']*1e3:.2f}ms)", flush=True)
                else:
                    print(f"  {rec['status']}: {rec.get('reason', rec.get('error'))}",
                          flush=True)

    n_ok = sum(1 for r in results.values() if r["status"] == "ok")
    n_skip = sum(1 for r in results.values() if r["status"] == "skipped")
    n_err = sum(1 for r in results.values() if r["status"] == "error")
    print(f"\n== dry-run summary: {n_ok} ok, {n_skip} skipped, {n_err} errors "
          f"(of {len(results)} cells) ==")


if __name__ == "__main__":
    main()
