"""Training driver: ``python -m repro.launch.train --arch llama3.2-1b``.

Production path in miniature: config registry -> mesh over available
devices -> sharded params/optimizer -> deterministic data pipeline ->
jitted train step -> fault-managed loop with atomic checkpoints and exact
resume (params, optimizer, and data cursor all round-trip).

On this CPU container the default ``--reduced`` flag trains the smoke
config of the same family; on a pod the full config + production mesh
apply unchanged (see launch/dryrun.py for the 512-chip lowering proof).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..checkpoint import ckpt
from ..configs import ALL_ARCHS, get_config
from ..data.pipeline import DataConfig, SyntheticTokenSource
from ..fault.manager import FaultConfig, StragglerDetector, run_with_recovery
from ..models import model as M
from ..optim import adamw
from ..sharding import Policy
from ..train import trainer as T
from .mesh import make_host_mesh


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=ALL_ARCHS)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--reduced", type=int, default=1,
                    help="train the reduced smoke config (CPU container)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    mesh = make_host_mesh(model_axis=args.model_axis)
    policy = Policy(mesh=mesh, fsdp=True)
    print(f"arch={cfg.name} params~{cfg.param_count()/1e6:.1f}M "
          f"mesh={dict(mesh.shape)}")

    dc = DataConfig(global_batch=args.batch, seq_len=args.seq,
                    vocab=cfg.vocab, seed=args.seed,
                    embed_dim=cfg.d_model if cfg.modality_stub else 0,
                    encdec=cfg.block_pattern == "encdec")
    source = SyntheticTokenSource(dc)

    tc = T.TrainConfig(
        microbatches=args.microbatches,
        opt=adamw.AdamWConfig(lr=args.lr, warmup_steps=10,
                              total_steps=args.steps))
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt_state = adamw.init_state(tc.opt, params)
    step_fn = T.jit_train_step(cfg, tc, policy,
                               jax.eval_shape(lambda: params),
                               jax.eval_shape(lambda: source(0)))

    state = {"params": params, "opt": opt_state}
    start = 0
    last = ckpt.latest_step(args.ckpt_dir)
    if last is not None:
        state, extra = ckpt.restore(args.ckpt_dir, state)
        start = SyntheticTokenSource.resume_step(extra["data"])
        print(f"resumed from checkpoint step {start}")

    losses: list[float] = []
    det = StragglerDetector(FaultConfig(), n_hosts=1)

    def one_step(i: int) -> None:
        batch = jax.tree.map(jnp.asarray, source(i))
        with mesh:
            p, o, met = step_fn(state["params"], state["opt"], batch)
        state["params"], state["opt"] = p, o
        losses.append(float(met["loss"]))
        if i % args.log_every == 0 or i == args.steps - 1:
            print(f"step {i:5d} loss {losses[-1]:.4f} "
                  f"lr {float(met.get('lr', 0)):.2e}")

    def save_fn(i: int) -> None:
        ckpt.save(args.ckpt_dir, i, state,
                  extra={"data": source.checkpoint_state(i)})

    def restore_fn() -> int:
        nonlocal state
        state, extra = ckpt.restore(args.ckpt_dir, state)
        return SyntheticTokenSource.resume_step(extra["data"])

    t0 = time.time()
    stats = run_with_recovery(
        one_step, start_step=start, total_steps=args.steps,
        cfg=FaultConfig(checkpoint_every=args.ckpt_every),
        save_fn=save_fn, restore_fn=restore_fn, detector=det)
    dt = time.time() - t0

    first = float(np.mean(losses[:5])) if len(losses) >= 5 else losses[0]
    final = float(np.mean(losses[-5:]))
    print(f"done: {len(losses)} steps in {dt:.1f}s "
          f"({dt/max(len(losses),1)*1e3:.0f} ms/step); "
          f"loss {first:.3f} -> {final:.3f}; restarts={stats.restarts}")
    return {"losses": losses, "stats": stats, "first": first, "final": final}


if __name__ == "__main__":
    main()
