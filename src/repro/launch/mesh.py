"""Production meshes.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Single pod: (16, 16) = 256 chips, axes (data, model).
Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model) — the pod axis
composes with data parallelism (hierarchical gradient reduction:
reduce-scatter in-pod over ICI, all-reduce across pods over DCN).
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1) -> Mesh:
    """Mesh over whatever devices exist (smoke tests / elastic restarts)."""
    n = len(jax.devices())
    data = n // model_axis
    return jax.make_mesh((data, model_axis), ("data", "model"))
