"""Roofline term extraction that survives scan-over-layers.

XLA's ``compiled.cost_analysis()`` counts a while-loop body ONCE (verified
in tests), so any scanned-layers model under-reports FLOPs/bytes by ~L and
collective bytes likewise.  Two fixes:

* ``count_jaxpr``   — walks the step function's jaxpr, multiplying scan
  bodies by their trip counts.  FLOPs are exact (dot_general/conv algebra);
  bytes use a fusion model: anchor ops (dot/conv/gather/scatter/reduce/
  carried state) count input+output traffic, elementwise/layout ops count
  as fused (0) — a deliberate approximation documented in EXPERIMENTS.md.
  Totals are GLOBAL (pre-partitioning); per-chip = /n_chips assuming even
  sharding.

* ``collective_bytes_hlo`` — parses the compiled HLO *per computation*,
  multiplies collectives inside while bodies by the trip count recovered
  from the loop condition's comparison constant.
"""
from __future__ import annotations

import re
from typing import Any

import numpy as np

# ---------------------------------------------------------------------------
# jaxpr flop/byte counter
# ---------------------------------------------------------------------------

_ELTWISE_FLOPS_ONLY = True


def _aval_bytes(aval) -> float:
    shape = getattr(aval, "shape", ())
    dt = getattr(aval, "dtype", None)
    if dt is None:
        return 0.0
    return float(int(np.prod(shape)) if shape else 1) * np.dtype(dt).itemsize


def _aval_size(aval) -> float:
    shape = getattr(aval, "shape", ())
    return float(int(np.prod(shape))) if shape else 1.0


def _dot_flops(eqn) -> float:
    # 2 * prod(out_shape) * contraction size
    out = eqn.outvars[0].aval
    dims = eqn.params["dimension_numbers"]
    (lc, rc), _ = dims
    lhs = eqn.invars[0].aval
    k = 1
    for d in lc:
        k *= lhs.shape[d]
    return 2.0 * _aval_size(out) * k


def _conv_flops(eqn) -> float:
    out = eqn.outvars[0].aval
    rhs = eqn.invars[1].aval
    # per output element: 2 * (kernel spatial x in-channels/groups)
    per = 2.0 * float(np.prod(rhs.shape[:-1])) if rhs.shape else 2.0
    # rhs layout varies; use total kernel size / out_channels
    per = 2.0 * float(np.prod(rhs.shape)) / max(out.shape[-1], 1)
    return _aval_size(out) * per


ANCHORS = {"dot_general", "conv_general_dilated", "gather", "scatter",
           "scatter-add", "scatter_add", "dynamic_slice",
           "dynamic_update_slice", "reduce_sum", "reduce_max", "reduce_min",
           "sort", "top_k", "fft", "cumsum", "cumlogsumexp", "argmax",
           "argmin", "iota"}


def count_jaxpr(jaxpr) -> dict[str, float]:
    """Returns {'flops': ..., 'bytes': ...} with scan trip multiplication."""
    return _count(jaxpr.jaxpr if hasattr(jaxpr, "jaxpr") else jaxpr)


def _count(jx) -> dict[str, float]:
    flops = 0.0
    byts = 0.0
    for eqn in jx.eqns:
        name = eqn.primitive.name
        sub = None
        mult = 1.0
        if name == "scan":
            sub = eqn.params["jaxpr"]
            mult = float(eqn.params.get("length", 1))
        elif name == "while":
            sub = eqn.params["body_jaxpr"]
            mult = 1.0  # unknown statically; models use scan
        elif name == "cond":
            branches = eqn.params["branches"]
            costs = [_count(b.jaxpr) for b in branches]
            flops += max(c["flops"] for c in costs)
            byts += max(c["bytes"] for c in costs)
            continue
        elif name in ("pjit", "closed_call", "remat", "remat2", "checkpoint",
                      "custom_jvp_call", "custom_vjp_call",
                      "custom_vjp_call_jaxpr", "custom_jvp_call_jaxpr"):
            sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr") \
                or eqn.params.get("fun_jaxpr")
            mult = 1.0
        if sub is not None:
            c = _count(sub.jaxpr if hasattr(sub, "jaxpr") else sub)
            flops += mult * c["flops"]
            byts += mult * c["bytes"]
            continue
        out_avals = [v.aval for v in eqn.outvars if hasattr(v, "aval")]
        in_avals = [v.aval for v in eqn.invars if hasattr(v, "aval")]
        if name == "dot_general":
            flops += _dot_flops(eqn)
            byts += sum(map(_aval_bytes, in_avals)) + sum(map(_aval_bytes, out_avals))
        elif name == "conv_general_dilated":
            flops += _conv_flops(eqn)
            byts += sum(map(_aval_bytes, in_avals)) + sum(map(_aval_bytes, out_avals))
        elif name in ANCHORS:
            flops += sum(map(_aval_size, out_avals))
            byts += sum(map(_aval_bytes, in_avals)) + sum(map(_aval_bytes, out_avals))
        else:
            # elementwise / layout: fused — FLOPs counted, bytes fused away
            flops += sum(map(_aval_size, out_avals))
    return {"flops": flops, "bytes": byts}


# ---------------------------------------------------------------------------
# while-aware collective parser over compiled HLO text
# ---------------------------------------------------------------------------

COLLECTIVE_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                  "collective-permute")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}
_SHAPE_RE = re.compile(
    r"(bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64|pred|c64|c128|f8e4m3fn|f8e5m2)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?(%[\w\.\-]+)\s*\(.*\{\s*$")
_WHILE_RE = re.compile(r"while\(.*?\).*?condition=(%?[\w\.\-_]+).*?body=(%?[\w\.\-_]+)")
_CONST_CMP = re.compile(r"constant\((\d+)\)")


def _split_computations(hlo: str) -> dict[str, list[str]]:
    comps: dict[str, list[str]] = {}
    cur = None
    depth = 0
    for line in hlo.splitlines():
        s = line.rstrip()
        if cur is None:
            m = _COMP_HDR.match(s.strip())
            if m:
                cur = m.group(1).lstrip("%")
                comps[cur] = []
                depth = 1
            continue
        depth += s.count("{") - s.count("}")
        if depth <= 0:
            cur = None
            continue
        comps[cur].append(s)
    return comps


def _line_shape_bytes(line: str) -> float:
    shapes = _SHAPE_RE.findall(line)
    if not shapes:
        return 0.0
    sizes = []
    for dt, dims in shapes:
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        sizes.append(n * _DTYPE_BYTES.get(dt, 4))
    return float(max(sizes))


_GROUPS_IOTA = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return 2


def _wire_factor(op: str, line: str) -> float:
    """Per-chip wire bytes as a multiple of the op's printed (output)
    shape, for a ring implementation over a group of size n:

      all-reduce      2(n-1)/n x tensor     (reduce-scatter + all-gather)
      all-gather      (n-1)/n  x output     (output printed full)
      reduce-scatter  (n-1)    x output     (output printed as the shard)
      all-to-all      (n-1)/n  x tensor
      collective-permute  1    x tensor
    """
    n = _group_size(line)
    if op == "all-reduce":
        return 2.0 * (n - 1) / n
    if op == "all-gather":
        return (n - 1) / n
    if op == "reduce-scatter":
        return float(n - 1)
    if op == "all-to-all":
        return (n - 1) / n
    return 1.0


def collective_bytes_hlo(hlo: str) -> dict[str, float]:
    comps = _split_computations(hlo)

    # trip count of a while: the comparison constant in its condition
    def trip_count(cond_name: str) -> float:
        lines = comps.get(cond_name, [])
        for ln in lines:
            if "compare" in ln:
                m = _CONST_CMP.search(ln)
                if m:
                    return float(m.group(1))
        # fall back: largest constant in the condition computation
        best = 1.0
        for ln in lines:
            for m in _CONST_CMP.finditer(ln):
                best = max(best, float(m.group(1)))
        return best

    memo: dict[str, dict[str, float]] = {}

    def comp_cost(name: str) -> dict[str, float]:
        if name in memo:
            return memo[name]
        memo[name] = {k: 0.0 for k in COLLECTIVE_OPS}  # cycle guard
        total = {k: 0.0 for k in COLLECTIVE_OPS}
        for ln in comps.get(name, []):
            s = ln.strip()
            m = _WHILE_RE.search(s)
            if m and " while(" in s.replace("= while(", " while("):
                cond, body = m.group(1).lstrip("%"), m.group(2).lstrip("%")
                t = trip_count(cond)
                sub = comp_cost(body)
                for k in COLLECTIVE_OPS:
                    total[k] += t * sub[k]
                continue
            mm = re.search(r"=\s*(?:\([^)]*\)|\S+)\s+(\S+)\(", s)
            if not mm:
                continue
            op = mm.group(1)
            for c in COLLECTIVE_OPS:
                if op == c or op.startswith(c + "-start") or op.startswith(c + "."):
                    total[c] += _line_shape_bytes(s) * _wire_factor(c, s)
                    break
            else:
                # fusions/calls into other computations: calls=%name
                cm = re.search(r"(?:calls|to_apply)=(%?[\w\.\-_]+)", s)
                if cm:
                    sub = comp_cost(cm.group(1).lstrip("%"))
                    for k in COLLECTIVE_OPS:
                        total[k] += sub[k]
        memo[name] = total
        return total

    # entry computation: the one named like ENTRY or main
    entry = None
    for ln in hlo.splitlines():
        if ln.startswith("ENTRY"):
            m = re.match(r"ENTRY\s+(%?[\w\.\-_]+)", ln)
            if m:
                entry = m.group(1).lstrip("%")
            break
    if entry is None or entry not in comps:
        # aggregate everything once as fallback
        total = {k: 0.0 for k in COLLECTIVE_OPS}
        for name in comps:
            c = comp_cost(name)
            for k in COLLECTIVE_OPS:
                total[k] += c[k]
        return total
    return comp_cost(entry)
