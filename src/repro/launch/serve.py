"""Serving driver: ``python -m repro.launch.serve --arch qwen3-8b``.

Batched prefill + decode against sharded KV/state caches.  With
``--concurrent arch2`` it co-schedules two models' request streams using
BIDENT's joint (i, j) search over their fused-operator graphs — the
paper's multi-model regime driving a real execution engine.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import ALL_ARCHS, get_config
from ..core import (ContentionModel, EDGE_PUS, EdgeSoCCostModel,
                    solve_concurrent_joint)
from ..core.modelgraph import model_op_graph
from ..models import model as M
from ..serving.engine import Engine
from ..sharding import Policy


def main(argv=None) -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b", choices=ALL_ARCHS)
    ap.add_argument("--concurrent", default=None, choices=ALL_ARCHS,
                    help="co-schedule a second model's stream")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch).reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(args.seed))
    engine = Engine(cfg=cfg, params=params, policy=Policy())

    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(rng.integers(
        0, cfg.vocab, (args.batch, args.prompt_len), dtype=np.int32))
    t0 = time.time()
    out = engine.generate(prompts, max_new=args.max_new)
    dt = time.time() - t0
    tps = args.batch * args.max_new / dt
    print(f"{cfg.name}: generated {out.shape} in {dt:.2f}s "
          f"({tps:.1f} tok/s, greedy, batched)")

    result = {"tokens": out, "tok_per_s": tps}
    if args.concurrent:
        # BIDENT joint co-schedule of the two models' operator graphs
        cfg2 = get_config(args.concurrent)
        g1 = model_op_graph(get_config(args.arch), kind="decode",
                            batch=args.batch, seq=2048)
        g2 = model_op_graph(cfg2, kind="decode", batch=args.batch, seq=2048)
        m = EdgeSoCCostModel()
        t1, t2 = m.build_table(g1), m.build_table(g2)
        sched = solve_concurrent_joint(
            list(range(len(g1))), t1, list(range(len(g2))), t2,
            EDGE_PUS, ContentionModel())
        print(f"concurrent co-schedule {args.arch} + {args.concurrent}: "
              f"{len(sched.steps)} steps, predicted makespan "
              f"{sched.latency*1e3:.2f} ms")
        result["concurrent_schedule"] = sched
    return result


if __name__ == "__main__":
    main()
