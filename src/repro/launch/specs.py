"""Shape cells + ShapeDtypeStruct input specs per (arch x shape).

The four assigned shape cells (LM shapes are seq_len x global_batch):

* train_4k    — seq 4096,   batch 256  -> lowers ``train_step``
* prefill_32k — seq 32768,  batch 32   -> lowers ``prefill``
* decode_32k  — seq 32768,  batch 128  -> lowers ``serve_step`` (1 token)
* long_500k   — seq 524288, batch 1    -> serve_step; **runs only for the
  sub-quadratic archs** (xlstm, zamba2) — full-attention archs skip it per
  the assignment (noted in DESIGN.md).

``[audio]``/``[vlm]`` archs get stub modality inputs: ``input_specs``
provides precomputed frame/patch embeddings.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from ..models import model as M

ShapeDtype = jax.ShapeDtypeStruct


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    name: str
    kind: str          # train | prefill | decode
    seq: int
    batch: int


SHAPE_CELLS: dict[str, ShapeCell] = {
    "train_4k": ShapeCell("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCell("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCell("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCell("long_500k", "decode", 524288, 1),
}


def cell_applicable(cfg, cell: ShapeCell) -> tuple[bool, str]:
    if cell.name == "long_500k" and not cfg.sub_quadratic:
        return False, "full-attention arch: 500k dense KV is the quadratic regime the assignment excludes"
    return True, ""


def input_specs(cfg, cell: ShapeCell) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    B, S = cell.batch, cell.seq
    i32 = jnp.int32
    dt = cfg.jdtype
    if cell.kind == "train":
        batch = {}
        if cfg.block_pattern == "encdec":
            batch["embeds"] = ShapeDtype((B, S, cfg.d_model), dt)
            batch["tokens"] = ShapeDtype((B, S), i32)
        elif cfg.modality_stub:
            batch["embeds"] = ShapeDtype((B, S, cfg.d_model), dt)
        else:
            batch["tokens"] = ShapeDtype((B, S), i32)
        batch["labels"] = ShapeDtype((B, S), i32)
        return {"batch": batch}
    if cell.kind == "prefill":
        batch = {}
        if cfg.block_pattern == "encdec":
            batch["embeds"] = ShapeDtype((B, S, cfg.d_model), dt)
            batch["tokens"] = ShapeDtype((B, S), i32)
        elif cfg.modality_stub:
            batch["embeds"] = ShapeDtype((B, S, cfg.d_model), dt)
        else:
            batch["tokens"] = ShapeDtype((B, S), i32)
        return {"batch": batch, "max_len": S}
    if cell.kind == "decode":
        cache = jax.eval_shape(lambda: M.init_cache(cfg, B, S))
        batch = {"tokens": ShapeDtype((B, 1), i32)}
        if cfg.modality_stub and cfg.block_pattern != "encdec":
            # VLM backbone decodes text tokens; embed table exists
            batch = {"tokens": ShapeDtype((B, 1), i32)}
        return {"batch": batch, "cache": cache}
    raise ValueError(cell.kind)


# hardware constants: TPU v5e (the TARGET platform of this build)
PEAK_FLOPS = 197e12        # bf16 FLOP/s per chip
HBM_BW = 819e9             # B/s per chip
ICI_BW = 50e9              # B/s per link (per chip, per direction)
CHIP_POWER_COMPUTE = 170.0  # W active MXU (energy model, DESIGN.md §2.3)
CHIP_POWER_MEMORY = 120.0
CHIP_POWER_IDLE = 60.0


def model_flops(cfg, cell: ShapeCell) -> float:
    """MODEL_FLOPS = 6*N*D (dense) or 6*N_active*D (MoE) for the step.

    For train cells D = processed tokens and the 6x covers fwd+bwd; for
    prefill 2*N*D (fwd only); for decode D = new tokens (=batch)."""
    n_params = cfg.param_count()
    if cfg.n_experts:
        # subtract inactive routed-expert params
        d = cfg.d_model
        moe_layers = cfg.n_layers - cfg.first_k_dense
        routed = moe_layers * cfg.n_experts * 3 * d * cfg.moe_d_ff
        active = moe_layers * cfg.moe_top_k * 3 * d * cfg.moe_d_ff
        n_active = n_params - routed + active
    else:
        n_active = n_params
    if cell.kind == "train":
        tokens = cell.batch * cell.seq
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.batch * cell.seq
        return 2.0 * n_active * tokens
    # decode: one token per sequence; embedding params don't matmul
    return 2.0 * n_active * cell.batch
