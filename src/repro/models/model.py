"""Composable LM: block-spec patterns -> init / forward / prefill / decode.

Every assigned architecture maps to one of five block patterns:

* ``dense``    — GQA attention + SwiGLU (llama3.2 / mistral-large /
                 qwen3 (qk-norm) / stablelm / qwen2-vl (M-RoPE, stub
                 patch embeddings))
* ``moe``      — GQA attention + top-k MoE (granite)
* ``mla_moe``  — MLA attention, first-k dense then MoE + shared expert,
                 optional MTP head (deepseek-v3)
* ``encdec``   — encoder + decoder with cross-attention (seamless, stub
                 frame embeddings)
* ``xlstm``    — alternating mLSTM / sLSTM pairs
* ``zamba2``   — Mamba2 backbone + one *shared* GQA attention block applied
                 every ``zamba_attn_every`` layers

All patterns scan over stacked layer parameters so HLO size (and CPU
compile time for the 512-device dry-run) is depth-independent.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from ..sharding import Policy, NO_POLICY
from . import layers as L


def _stack_init(init_fn, key, n: int):
    return jax.vmap(init_fn)(jax.random.split(key, n))


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(cfg, key) -> dict:
    dt = cfg.jdtype
    k_embed, k_blocks, k_head, k_extra = jax.random.split(key, 4)
    params: dict[str, Any] = {
        "embed": (jax.random.normal(k_embed, (cfg.vocab, cfg.d_model),
                                    jnp.float32) * 0.02).astype(dt),
        "final_norm": jnp.ones((cfg.d_model,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.dense_init(k_head, cfg.d_model, cfg.vocab, dt)

    def dense_block(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": jnp.ones((cfg.d_model,), dt),
                "attn": L.gqa_init(k1, cfg, dt),
                "ln2": jnp.ones((cfg.d_model,), dt),
                "mlp": L.swiglu_init(k2, cfg.d_model, cfg.d_ff, dt)}

    def moe_block(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": jnp.ones((cfg.d_model,), dt),
                "attn": L.gqa_init(k1, cfg, dt),
                "ln2": jnp.ones((cfg.d_model,), dt),
                "moe": L.moe_init(k2, cfg, dt)}

    def mla_dense_block(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": jnp.ones((cfg.d_model,), dt),
                "attn": L.mla_init(k1, cfg, dt),
                "ln2": jnp.ones((cfg.d_model,), dt),
                "mlp": L.swiglu_init(k2, cfg.d_model, cfg.d_ff, dt)}

    def mla_moe_block(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": jnp.ones((cfg.d_model,), dt),
                "attn": L.mla_init(k1, cfg, dt),
                "ln2": jnp.ones((cfg.d_model,), dt),
                "moe": L.moe_init(k2, cfg, dt)}

    def enc_block(k):
        k1, k2 = jax.random.split(k)
        return {"ln1": jnp.ones((cfg.d_model,), dt),
                "attn": L.gqa_init(k1, cfg, dt),
                "ln2": jnp.ones((cfg.d_model,), dt),
                "mlp": L.swiglu_init(k2, cfg.d_model, cfg.d_ff, dt)}

    def dec_block(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {"ln1": jnp.ones((cfg.d_model,), dt),
                "attn": L.gqa_init(k1, cfg, dt),
                "lnx": jnp.ones((cfg.d_model,), dt),
                "xattn": L.cross_attn_init(k2, cfg, dt),
                "ln2": jnp.ones((cfg.d_model,), dt),
                "mlp": L.swiglu_init(k3, cfg.d_model, cfg.d_ff, dt)}

    def mamba_block(k):
        return {"ln1": jnp.ones((cfg.d_model,), dt),
                "mamba": L.mamba2_init(k, cfg, dt)}

    def xlstm_pair(k):
        k1, k2 = jax.random.split(k)
        return {"ln_m": jnp.ones((cfg.d_model,), dt),
                "mlstm": L.mlstm_init(k1, cfg, dt),
                "ln_s": jnp.ones((cfg.d_model,), dt),
                "slstm": L.slstm_init(k2, cfg, dt)}

    bp = cfg.block_pattern
    if bp == "dense":
        params["blocks"] = _stack_init(dense_block, k_blocks, cfg.n_layers)
    elif bp == "moe":
        params["blocks"] = _stack_init(moe_block, k_blocks, cfg.n_layers)
    elif bp == "mla_moe":
        kd, km, kt = jax.random.split(k_blocks, 3)
        params["dense_blocks"] = _stack_init(mla_dense_block, kd, cfg.first_k_dense)
        params["moe_blocks"] = _stack_init(
            mla_moe_block, km, cfg.n_layers - cfg.first_k_dense)
        if cfg.mtp:
            k1, k2 = jax.random.split(kt)
            params["mtp"] = {
                "proj": L.dense_init(k1, 2 * cfg.d_model, cfg.d_model, dt),
                "block": mla_dense_block(k2),
                "norm": jnp.ones((cfg.d_model,), dt),
            }
    elif bp == "encdec":
        ke, kd = jax.random.split(k_blocks)
        params["enc_blocks"] = _stack_init(enc_block, ke, cfg.n_enc_layers)
        params["dec_blocks"] = _stack_init(dec_block, kd, cfg.n_dec_layers)
        params["enc_norm"] = jnp.ones((cfg.d_model,), dt)
    elif bp == "xlstm":
        params["blocks"] = _stack_init(xlstm_pair, k_blocks, cfg.n_layers // 2)
    elif bp == "zamba2":
        params["blocks"] = _stack_init(mamba_block, k_blocks, cfg.n_layers)
        params["shared_attn"] = {"ln": jnp.ones((cfg.d_model,), dt),
                                 "attn": L.gqa_init(k_extra, cfg, dt)}
    else:
        raise ValueError(f"unknown block pattern {bp!r}")
    return params


def param_shapes(cfg) -> Any:
    """ShapeDtypeStruct pytree (no allocation) — dry-run input."""
    return jax.eval_shape(lambda k: init_params(cfg, k),
                          jax.random.PRNGKey(0))


# ---------------------------------------------------------------------------
# forward (training / full-sequence)
# ---------------------------------------------------------------------------

def _maybe_remat(fn, cfg):
    return jax.checkpoint(fn) if cfg.remat else fn


def _embed_in(cfg, params, batch, shd: Policy):
    """tokens (B,T) int32 -> embeddings, or pass through stub embeddings."""
    if "embeds" in batch:
        h = batch["embeds"].astype(cfg.jdtype)
    else:
        h = jnp.take(params["embed"], batch["tokens"], axis=0)
    return shd.constrain(h, "batch", "seq_act", "embed", name="embed_out")


def _positions(cfg, batch, T: int):
    B = (batch["tokens"].shape[0] if "tokens" in batch
         else batch["embeds"].shape[0])
    if cfg.mrope:
        if "positions" in batch:
            return batch["positions"]
        p = jnp.broadcast_to(jnp.arange(T)[None], (B, T))
        return jnp.stack([p, p, p])           # text-only: t=h=w stream
    return jnp.broadcast_to(jnp.arange(T)[None], (B, T))


def _logits(cfg, params, h, shd: Policy):
    h = L.rms_norm(h, params["final_norm"], cfg.norm_eps)
    w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    logits = h @ w
    return shd.constrain(logits, "batch", "seq", "vocab", name="logits")


def forward(cfg, params, batch, shd: Policy = NO_POLICY,
            return_hidden: bool = False):
    """Full-sequence forward -> (logits, aux_loss[, hidden])."""
    h = _embed_in(cfg, params, batch, shd)
    T = h.shape[1]
    pos = _positions(cfg, batch, T)
    bp = cfg.block_pattern
    aux = jnp.zeros((), jnp.float32)

    if bp in ("dense", "moe"):
        def body(carry, lp):
            h, aux = carry
            a, _ = L.gqa_attention(lp["attn"], L.rms_norm(h, lp["ln1"], cfg.norm_eps),
                                   cfg, shd, positions=pos)
            h = h + a
            if bp == "moe":
                m, a_l = L.moe_block(lp["moe"], L.rms_norm(h, lp["ln2"], cfg.norm_eps),
                                     cfg, shd)
                aux = aux + a_l
            else:
                m = L.swiglu_mlp(lp["mlp"], L.rms_norm(h, lp["ln2"], cfg.norm_eps), shd)
            return (h + m, aux), None
        (h, aux), _ = jax.lax.scan(_maybe_remat(body, cfg), (h, aux),
                                   params["blocks"])

    elif bp == "mla_moe":
        def dense_body(carry, lp):
            h, aux = carry
            a, _ = L.mla_attention(lp["attn"], L.rms_norm(h, lp["ln1"], cfg.norm_eps),
                                   cfg, shd, positions=pos)
            h = h + a
            m = L.swiglu_mlp(lp["mlp"], L.rms_norm(h, lp["ln2"], cfg.norm_eps), shd)
            return (h + m, aux), None

        def moe_body(carry, lp):
            h, aux = carry
            a, _ = L.mla_attention(lp["attn"], L.rms_norm(h, lp["ln1"], cfg.norm_eps),
                                   cfg, shd, positions=pos)
            h = h + a
            m, a_l = L.moe_block(lp["moe"], L.rms_norm(h, lp["ln2"], cfg.norm_eps),
                                 cfg, shd)
            return (h + m, aux + a_l), None

        (h, aux), _ = jax.lax.scan(_maybe_remat(dense_body, cfg), (h, aux),
                                   params["dense_blocks"])
        (h, aux), _ = jax.lax.scan(_maybe_remat(moe_body, cfg), (h, aux),
                                   params["moe_blocks"])

    elif bp == "encdec":
        # batch: embeds (encoder input, stub frontend) + tokens (decoder)
        enc_cfg = dataclasses.replace(cfg, causal=False)
        e = batch["embeds"].astype(cfg.jdtype)
        e = shd.constrain(e, "batch", "seq_act", "embed", name="enc_in")
        epos = jnp.broadcast_to(jnp.arange(e.shape[1])[None], e.shape[:2])

        def enc_body(h, lp):
            a, _ = L.gqa_attention(lp["attn"], L.rms_norm(h, lp["ln1"], cfg.norm_eps),
                                   enc_cfg, shd, positions=epos)
            h = h + a
            m = L.swiglu_mlp(lp["mlp"], L.rms_norm(h, lp["ln2"], cfg.norm_eps), shd)
            return h + m, None
        e, _ = jax.lax.scan(_maybe_remat(enc_body, cfg), e, params["enc_blocks"])
        memory = L.rms_norm(e, params["enc_norm"], cfg.norm_eps)

        h = jnp.take(params["embed"], batch["tokens"], axis=0)
        h = shd.constrain(h, "batch", "seq_act", "embed", name="dec_in")
        T = h.shape[1]
        dpos = jnp.broadcast_to(jnp.arange(T)[None], (h.shape[0], T))

        def dec_body(h, lp):
            a, _ = L.gqa_attention(lp["attn"], L.rms_norm(h, lp["ln1"], cfg.norm_eps),
                                   cfg, shd, positions=dpos)
            h = h + a
            x = L.cross_attention(lp["xattn"], L.rms_norm(h, lp["lnx"], cfg.norm_eps),
                                  memory, cfg, shd)
            h = h + x
            m = L.swiglu_mlp(lp["mlp"], L.rms_norm(h, lp["ln2"], cfg.norm_eps), shd)
            return h + m, None
        h, _ = jax.lax.scan(_maybe_remat(dec_body, cfg), h, params["dec_blocks"])

    elif bp == "xlstm":
        def body(h, lp):
            a, _ = L.mlstm_block(lp["mlstm"], L.rms_norm(h, lp["ln_m"], cfg.norm_eps),
                                 cfg, shd)
            h = h + a
            s, _ = L.slstm_block(lp["slstm"], L.rms_norm(h, lp["ln_s"], cfg.norm_eps),
                                 cfg, shd)
            return h + s, None
        h, _ = jax.lax.scan(_maybe_remat(body, cfg), h, params["blocks"])

    elif bp == "zamba2":
        every = cfg.zamba_attn_every
        G = cfg.n_layers // every
        grouped = jax.tree.map(
            lambda x: x.reshape(G, every, *x.shape[1:]), params["blocks"])
        sa = params["shared_attn"]

        def group_body(h, glp):
            def inner(h, lp):
                m, _ = L.mamba2_block(lp["mamba"],
                                      L.rms_norm(h, lp["ln1"], cfg.norm_eps),
                                      cfg, shd)
                return h + m, None
            h, _ = jax.lax.scan(inner, h, glp)
            a, _ = L.gqa_attention(sa["attn"], L.rms_norm(h, sa["ln"], cfg.norm_eps),
                                   cfg, shd, positions=pos)
            return h + a, None
        h, _ = jax.lax.scan(_maybe_remat(group_body, cfg), h, grouped)
    else:
        raise ValueError(bp)

    logits = _logits(cfg, params, h, shd)
    if return_hidden:
        return logits, aux, h
    return logits, aux


# ---------------------------------------------------------------------------
# loss
# ---------------------------------------------------------------------------

def _ce(logits, labels):
    lg = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lg, axis=-1)
    gold = jnp.take_along_axis(lg, labels[..., None], axis=-1)[..., 0]
    mask = (labels >= 0).astype(jnp.float32)
    nll = ((lse - gold) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    zloss = ((lse ** 2) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return nll, zloss, mask.sum()


def loss_fn(cfg, params, batch, shd: Policy = NO_POLICY):
    """Next-token cross-entropy (+ MoE aux + z-loss + MTP for deepseek)."""
    use_mtp = cfg.mtp and "mtp" in params and "tokens" in batch
    if use_mtp:
        logits, aux, h = forward(cfg, params, batch, shd, return_hidden=True)
    else:
        logits, aux = forward(cfg, params, batch, shd)
    labels = batch["labels"]
    nll, zloss, ntok = _ce(logits, labels)
    total = nll + 1e-4 * zloss + cfg.aux_loss_coef * aux
    metrics = {"nll": nll, "zloss": zloss, "aux": aux, "tokens": ntok}

    if use_mtp:
        # DeepSeek-V3 multi-token prediction (depth 1): predict token t+2
        # from h_t combined with the embedding of token t+1.
        mtp = params["mtp"]
        tok_next = batch["tokens"][:, 1:]
        e_next = jnp.take(params["embed"], tok_next, axis=0)
        hin = jnp.concatenate([h[:, :-1], e_next], axis=-1) @ mtp["proj"]
        T1 = hin.shape[1]
        pos = jnp.broadcast_to(jnp.arange(T1)[None], hin.shape[:2])
        lp = mtp["block"]
        a, _ = L.mla_attention(lp["attn"], L.rms_norm(hin, lp["ln1"], cfg.norm_eps),
                               cfg, shd, positions=pos)
        hin = hin + a
        hin = hin + L.swiglu_mlp(lp["mlp"], L.rms_norm(hin, lp["ln2"], cfg.norm_eps),
                                 shd)
        hin = L.rms_norm(hin, mtp["norm"], cfg.norm_eps)
        w = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
        mtp_logits = hin @ w
        mtp_labels = jnp.concatenate(
            [labels[:, 2:], jnp.full_like(labels[:, :1], -1)], axis=1)
        mtp_nll, _, _ = _ce(mtp_logits, mtp_labels)
        total = total + 0.3 * mtp_nll
        metrics["mtp_nll"] = mtp_nll
    return total, metrics


# ---------------------------------------------------------------------------
# KV / state caches
# ---------------------------------------------------------------------------

def init_cache(cfg, batch: int, max_len: int) -> dict:
    dt = cfg.jdtype
    bp = cfg.block_pattern
    Lc = cfg.n_layers

    def attn_cache(n, length):
        return {"k": jnp.zeros((n, batch, length, cfg.n_kv_heads, cfg.d_head), dt),
                "v": jnp.zeros((n, batch, length, cfg.n_kv_heads, cfg.d_head), dt)}

    if bp == "dense" or bp == "moe":
        return {"attn": attn_cache(Lc, max_len),
                "len": jnp.zeros((), jnp.int32)}
    if bp == "mla_moe":
        def mla_cache(n):
            return {"c_kv": jnp.zeros((n, batch, max_len, cfg.kv_lora_rank), dt),
                    "k_pe": jnp.zeros((n, batch, max_len, cfg.qk_rope_head_dim), dt)}
        return {"dense": mla_cache(cfg.first_k_dense),
                "moe": mla_cache(Lc - cfg.first_k_dense),
                "len": jnp.zeros((), jnp.int32)}
    if bp == "encdec":
        n = cfg.n_dec_layers
        return {"attn": attn_cache(n, max_len),
                # cross-attention K/V computed once from encoder memory
                "xk": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, cfg.d_head), dt),
                "xv": jnp.zeros((n, batch, max_len, cfg.n_kv_heads, cfg.d_head), dt),
                "len": jnp.zeros((), jnp.int32)}
    if bp == "xlstm":
        P2 = Lc // 2
        H = cfg.n_heads
        dh = cfg.xlstm_d_inner // H
        dhs = cfg.d_model // H
        return {
            "mlstm": jnp.zeros((P2, batch, H, dh, dh + 1), jnp.float32),
            "slstm": tuple(jnp.zeros((P2, batch, H, dhs), jnp.float32)
                           for _ in range(4)),
            "len": jnp.zeros((), jnp.int32)}
    if bp == "zamba2":
        G = cfg.n_layers // cfg.zamba_attn_every
        conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_state * cfg.ssm_groups
        P = cfg.ssm_d_inner // cfg.ssm_heads
        return {
            "ssm": jnp.zeros((Lc, batch, cfg.ssm_heads, cfg.ssm_state, P),
                             jnp.float32),
            "conv": jnp.zeros((Lc, batch, cfg.ssm_conv - 1, conv_dim), dt),
            "attn": attn_cache(G, max_len),
            "len": jnp.zeros((), jnp.int32)}
    raise ValueError(bp)


# ---------------------------------------------------------------------------
# decode step (one token; the ``serve_step`` the dry-run lowers)
# ---------------------------------------------------------------------------

def decode_step(cfg, params, cache, batch, shd: Policy = NO_POLICY):
    """One decode step.  batch: tokens (B, 1) (+ embeds for stubs).
    Returns (logits (B, 1, V), new_cache)."""
    h = _embed_in(cfg, params, batch, shd)
    B, T = h.shape[:2]
    idx = cache["len"]
    if cfg.mrope:
        p = jnp.broadcast_to(idx[None, None], (B, T))
        pos = jnp.stack([p, p, p])
    else:
        pos = jnp.broadcast_to(idx[None, None], (B, T))
    bp = cfg.block_pattern

    if bp in ("dense", "moe"):
        def body(h, xs):
            lp, ck, cv = xs
            a, nc = L.gqa_attention(
                lp["attn"], L.rms_norm(h, lp["ln1"], cfg.norm_eps), cfg, shd,
                positions=pos, cache={"k": ck, "v": cv, "len": idx})
            h = h + a
            if bp == "moe":
                m, _ = L.moe_block(lp["moe"], L.rms_norm(h, lp["ln2"], cfg.norm_eps),
                                   cfg, shd)
            else:
                m = L.swiglu_mlp(lp["mlp"], L.rms_norm(h, lp["ln2"], cfg.norm_eps),
                                 shd)
            return h + m, (nc["k"], nc["v"])
        h, (nk, nv) = jax.lax.scan(
            body, h, (params["blocks"], cache["attn"]["k"], cache["attn"]["v"]))
        new_cache = {"attn": {"k": nk, "v": nv}, "len": idx + T}

    elif bp == "mla_moe":
        def mk_body(is_moe):
            def body(h, xs):
                lp, cc, cp = xs
                a, nc = L.mla_attention(
                    lp["attn"], L.rms_norm(h, lp["ln1"], cfg.norm_eps), cfg, shd,
                    positions=pos, cache={"c_kv": cc, "k_pe": cp, "len": idx})
                h = h + a
                if is_moe:
                    m, _ = L.moe_block(lp["moe"],
                                       L.rms_norm(h, lp["ln2"], cfg.norm_eps),
                                       cfg, shd)
                else:
                    m = L.swiglu_mlp(lp["mlp"],
                                     L.rms_norm(h, lp["ln2"], cfg.norm_eps), shd)
                return h + m, (nc["c_kv"], nc["k_pe"])
            return body
        h, (dc, dp) = jax.lax.scan(
            mk_body(False), h,
            (params["dense_blocks"], cache["dense"]["c_kv"], cache["dense"]["k_pe"]))
        h, (mc, mp) = jax.lax.scan(
            mk_body(True), h,
            (params["moe_blocks"], cache["moe"]["c_kv"], cache["moe"]["k_pe"]))
        new_cache = {"dense": {"c_kv": dc, "k_pe": dp},
                     "moe": {"c_kv": mc, "k_pe": mp}, "len": idx + T}

    elif bp == "encdec":
        def body(h, xs):
            lp, ck, cv, xk, xv = xs
            a, nc = L.gqa_attention(
                lp["attn"], L.rms_norm(h, lp["ln1"], cfg.norm_eps), cfg, shd,
                positions=pos, cache={"k": ck, "v": cv, "len": idx})
            h = h + a
            # cross-attention against cached encoder K/V
            xq = (L.rms_norm(h, lp["lnx"], cfg.norm_eps) @ lp["xattn"]["wq"])
            xq = xq.reshape(B, T, cfg.n_heads, cfg.d_head)
            valid = jnp.ones((xk.shape[1],), bool)
            xo = L._decode_attention(xq, xk, xv, valid, q_offset=xk.shape[1])
            h = h + xo.reshape(B, T, -1) @ lp["xattn"]["wo"]
            m = L.swiglu_mlp(lp["mlp"], L.rms_norm(h, lp["ln2"], cfg.norm_eps), shd)
            return h + m, (nc["k"], nc["v"])
        h, (nk, nv) = jax.lax.scan(
            body, h, (params["dec_blocks"], cache["attn"]["k"],
                      cache["attn"]["v"], cache["xk"], cache["xv"]))
        new_cache = {"attn": {"k": nk, "v": nv}, "xk": cache["xk"],
                     "xv": cache["xv"], "len": idx + T}

    elif bp == "xlstm":
        def body(h, xs):
            lp, ms, ss = xs
            a, nm = L.mlstm_block(lp["mlstm"],
                                  L.rms_norm(h, lp["ln_m"], cfg.norm_eps),
                                  cfg, shd, state={"ssm": ms})
            h = h + a
            s, ns = L.slstm_block(lp["slstm"],
                                  L.rms_norm(h, lp["ln_s"], cfg.norm_eps),
                                  cfg, shd, state={"slstm": ss})
            return h + s, (nm["ssm"], ns["slstm"])
        h, (nms, nss) = jax.lax.scan(body, h,
                                     (params["blocks"], cache["mlstm"],
                                      cache["slstm"]))
        new_cache = {"mlstm": nms, "slstm": nss, "len": idx + T}

    elif bp == "zamba2":
        every = cfg.zamba_attn_every
        G = cfg.n_layers // every
        grouped = jax.tree.map(
            lambda x: x.reshape(G, every, *x.shape[1:]), params["blocks"])
        gssm = cache["ssm"].reshape(G, every, *cache["ssm"].shape[1:])
        gconv = cache["conv"].reshape(G, every, *cache["conv"].shape[1:])
        sa = params["shared_attn"]

        def group_body(h, xs):
            glp, ssm_g, conv_g, ck, cv = xs
            def inner(h, ixs):
                lp, s, c = ixs
                m, ns = L.mamba2_block(lp["mamba"],
                                       L.rms_norm(h, lp["ln1"], cfg.norm_eps),
                                       cfg, shd, state={"ssm": s, "conv": c})
                return h + m, (ns["ssm"], ns["conv"])
            h, (nssm, nconv) = jax.lax.scan(inner, h, (glp, ssm_g, conv_g))
            a, nc = L.gqa_attention(sa["attn"],
                                    L.rms_norm(h, sa["ln"], cfg.norm_eps), cfg,
                                    shd, positions=pos,
                                    cache={"k": ck, "v": cv, "len": idx})
            return h + a, (nssm, nconv, nc["k"], nc["v"])
        h, (nssm, nconv, nk, nv) = jax.lax.scan(
            group_body, h, (grouped, gssm, gconv,
                            cache["attn"]["k"], cache["attn"]["v"]))
        new_cache = {
            "ssm": nssm.reshape(cfg.n_layers, *nssm.shape[2:]),
            "conv": nconv.reshape(cfg.n_layers, *nconv.shape[2:]),
            "attn": {"k": nk, "v": nv}, "len": idx + T}
    else:
        raise ValueError(bp)

    return _logits(cfg, params, h, shd), new_cache


# ---------------------------------------------------------------------------
# prefill (the ``prefill_step`` the dry-run lowers for prefill shapes)
# ---------------------------------------------------------------------------

def prefill(cfg, params, batch, max_len: int, shd: Policy = NO_POLICY):
    """Run the full prompt, returning (last-position logits, filled cache).

    For recurrent patterns the cache is the final recurrent state; for
    attention patterns the K/V cache is written back chunk-free via a
    second pass of the per-layer K/V projections (cheap relative to
    attention itself) — a deliberate simplification that keeps prefill a
    single scan-over-layers program.
    """
    h = _embed_in(cfg, params, batch, shd)
    B, T = h.shape[:2]
    pos = _positions(cfg, batch, T)
    bp = cfg.block_pattern
    cache = init_cache(cfg, B, max_len)

    if bp in ("dense", "moe"):
        def body(h, xs):
            lp, ck, cv = xs
            x = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
            # write K/V into the cache at [0, T)
            k = (x @ lp["attn"]["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.d_head)
            v = (x @ lp["attn"]["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.d_head)
            if cfg.qk_norm:
                k = L.rms_norm(k, lp["attn"]["k_norm"])
            cs, sn = L.rope_cos_sin(pos[0] if pos.ndim == 3 else pos,
                                    cfg.d_head, cfg.rope_theta)
            if cfg.mrope:
                cs, sn = L.mrope_cos_sin(pos, cfg.d_head, cfg.rope_theta,
                                         cfg.mrope_sections)
            k = L.apply_rope(k, cs, sn)
            ck = jax.lax.dynamic_update_slice_in_dim(ck, k, 0, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, v, 0, axis=1)
            a, _ = L.gqa_attention(
                lp["attn"], x, cfg, shd, positions=pos,
                use_flash="pallas" if cfg.use_kernels else None)
            h = h + a
            if bp == "moe":
                m, _ = L.moe_block(lp["moe"], L.rms_norm(h, lp["ln2"], cfg.norm_eps),
                                   cfg, shd)
            else:
                m = L.swiglu_mlp(lp["mlp"], L.rms_norm(h, lp["ln2"], cfg.norm_eps),
                                 shd)
            return h + m, (ck, cv)
        h, (nk, nv) = jax.lax.scan(
            _maybe_remat(body, cfg), h,
            (params["blocks"], cache["attn"]["k"], cache["attn"]["v"]))
        cache = {"attn": {"k": nk, "v": nv},
                 "len": jnp.asarray(T, jnp.int32)}

    elif bp == "mla_moe":
        def mk_body(is_moe):
            def body(h, xs):
                lp, cc, cp = xs
                x = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
                kv_a = x @ lp["attn"]["wkv_a"]
                c_kv = L.rms_norm(kv_a[..., :cfg.kv_lora_rank],
                                  lp["attn"]["kv_a_norm"])
                k_pe = kv_a[..., cfg.kv_lora_rank:]
                cs, sn = L.rope_cos_sin(pos, cfg.qk_rope_head_dim, cfg.rope_theta)
                k_pe = L.apply_rope(k_pe[:, :, None, :], cs, sn)[:, :, 0]
                cc = jax.lax.dynamic_update_slice_in_dim(cc, c_kv, 0, axis=1)
                cp = jax.lax.dynamic_update_slice_in_dim(cp, k_pe, 0, axis=1)
                a, _ = L.mla_attention(lp["attn"], x, cfg, shd, positions=pos)
                h = h + a
                if is_moe:
                    m, _ = L.moe_block(lp["moe"],
                                       L.rms_norm(h, lp["ln2"], cfg.norm_eps),
                                       cfg, shd)
                else:
                    m = L.swiglu_mlp(lp["mlp"],
                                     L.rms_norm(h, lp["ln2"], cfg.norm_eps), shd)
                return h + m, (cc, cp)
            return body
        h, (dc, dp) = jax.lax.scan(
            _maybe_remat(mk_body(False), cfg), h,
            (params["dense_blocks"], cache["dense"]["c_kv"], cache["dense"]["k_pe"]))
        h, (mc, mp) = jax.lax.scan(
            _maybe_remat(mk_body(True), cfg), h,
            (params["moe_blocks"], cache["moe"]["c_kv"], cache["moe"]["k_pe"]))
        cache = {"dense": {"c_kv": dc, "k_pe": dp},
                 "moe": {"c_kv": mc, "k_pe": mp},
                 "len": jnp.asarray(T, jnp.int32)}

    elif bp == "encdec":
        # encode, then prefill the decoder prompt + cross K/V
        enc_cfg = dataclasses.replace(cfg, causal=False)
        e = batch["embeds"].astype(cfg.jdtype)
        epos = jnp.broadcast_to(jnp.arange(e.shape[1])[None], e.shape[:2])

        def enc_body(h, lp):
            a, _ = L.gqa_attention(lp["attn"], L.rms_norm(h, lp["ln1"], cfg.norm_eps),
                                   enc_cfg, shd, positions=epos)
            h = h + a
            m = L.swiglu_mlp(lp["mlp"], L.rms_norm(h, lp["ln2"], cfg.norm_eps), shd)
            return h + m, None
        e, _ = jax.lax.scan(_maybe_remat(enc_body, cfg), e, params["enc_blocks"])
        memory = L.rms_norm(e, params["enc_norm"], cfg.norm_eps)
        S = memory.shape[1]

        h = jnp.take(params["embed"], batch["tokens"], axis=0)
        T2 = h.shape[1]
        dpos = jnp.broadcast_to(jnp.arange(T2)[None], (B, T2))

        def dec_body(h, xs):
            lp, ck, cv = xs
            x = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
            k = (x @ lp["attn"]["wk"]).reshape(B, T2, cfg.n_kv_heads, cfg.d_head)
            v = (x @ lp["attn"]["wv"]).reshape(B, T2, cfg.n_kv_heads, cfg.d_head)
            cs, sn = L.rope_cos_sin(dpos, cfg.d_head, cfg.rope_theta)
            k = L.apply_rope(k, cs, sn)
            ck = jax.lax.dynamic_update_slice_in_dim(ck, k, 0, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, v, 0, axis=1)
            a, _ = L.gqa_attention(lp["attn"], x, cfg, shd, positions=dpos)
            h = h + a
            xh = L.rms_norm(h, lp["lnx"], cfg.norm_eps)
            xo = L.cross_attention(lp["xattn"], xh, memory, cfg, shd)
            h = h + xo
            xk = (memory @ lp["xattn"]["wk"]).reshape(B, S, cfg.n_kv_heads, cfg.d_head)
            xv = (memory @ lp["xattn"]["wv"]).reshape(B, S, cfg.n_kv_heads, cfg.d_head)
            m = L.swiglu_mlp(lp["mlp"], L.rms_norm(h, lp["ln2"], cfg.norm_eps), shd)
            return h + m, (ck, cv, xk, xv)
        h, (nk, nv, xk, xv) = jax.lax.scan(
            _maybe_remat(dec_body, cfg), h,
            (params["dec_blocks"], cache["attn"]["k"], cache["attn"]["v"]))
        cache = {"attn": {"k": nk, "v": nv}, "xk": xk, "xv": xv,
                 "len": jnp.asarray(T2, jnp.int32)}

    elif bp == "xlstm":
        def body(h, lp):
            a, nm = L.mlstm_block(lp["mlstm"], L.rms_norm(h, lp["ln_m"], cfg.norm_eps),
                                  cfg, shd, use_kernel=cfg.use_kernels)
            h = h + a
            s, ns = L.slstm_block(lp["slstm"], L.rms_norm(h, lp["ln_s"], cfg.norm_eps),
                                  cfg, shd)
            return h + s, (nm["ssm"], ns["slstm"])
        h, (nms, nss) = jax.lax.scan(_maybe_remat(body, cfg), h, params["blocks"])
        cache = {"mlstm": nms, "slstm": nss, "len": jnp.asarray(T, jnp.int32)}

    elif bp == "zamba2":
        every = cfg.zamba_attn_every
        G = cfg.n_layers // every
        grouped = jax.tree.map(
            lambda x: x.reshape(G, every, *x.shape[1:]), params["blocks"])
        sa = params["shared_attn"]
        conv_dim = cfg.ssm_d_inner + 2 * cfg.ssm_state * cfg.ssm_groups

        def group_body(h, xs):
            glp, ck, cv = xs
            def inner(h, lp):
                x = L.rms_norm(h, lp["ln1"], cfg.norm_eps)
                m, ns = L.mamba2_block(lp["mamba"], x, cfg, shd,
                                       use_kernel=cfg.use_kernels)
                # conv tail state for decode continuation
                zxbcdt = x @ lp["mamba"]["in_proj"]
                xbc = zxbcdt[..., cfg.ssm_d_inner:cfg.ssm_d_inner + conv_dim]
                conv_tail = xbc[:, -(cfg.ssm_conv - 1):, :]
                return h + m, (ns["ssm"], conv_tail)
            h, (ssm_g, conv_g) = jax.lax.scan(inner, h, glp)
            x = L.rms_norm(h, sa["ln"], cfg.norm_eps)
            k = (x @ sa["attn"]["wk"]).reshape(B, T, cfg.n_kv_heads, cfg.d_head)
            v = (x @ sa["attn"]["wv"]).reshape(B, T, cfg.n_kv_heads, cfg.d_head)
            cs, sn = L.rope_cos_sin(pos, cfg.d_head, cfg.rope_theta)
            k = L.apply_rope(k, cs, sn)
            ck = jax.lax.dynamic_update_slice_in_dim(ck, k, 0, axis=1)
            cv = jax.lax.dynamic_update_slice_in_dim(cv, v, 0, axis=1)
            a, _ = L.gqa_attention(sa["attn"], x, cfg, shd, positions=pos)
            return h + a, (ssm_g, conv_g, ck, cv)
        h, (nssm, nconv, nk, nv) = jax.lax.scan(
            _maybe_remat(group_body, cfg), h,
            (grouped, cache["attn"]["k"], cache["attn"]["v"]))
        cache = {
            "ssm": nssm.reshape(cfg.n_layers, *nssm.shape[2:]),
            "conv": nconv.reshape(cfg.n_layers, *nconv.shape[2:]),
            "attn": {"k": nk, "v": nv}, "len": jnp.asarray(T, jnp.int32)}
    else:
        raise ValueError(bp)

    return _logits(cfg, params, h[:, -1:], shd), cache
