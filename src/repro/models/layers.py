"""Model-zoo primitive layers (pure JAX, functional, shard-annotated).

Conventions:
* activations are (batch, seq, ...) laid out as ``B T H D`` for attention;
* every layer is ``fn(params, x, cfg, shd, ...)`` with ``shd`` a
  ``repro.sharding.Policy`` (no-op without a mesh);
* params are plain dict pytrees; init functions live next to apply
  functions; stacked-layer variants are produced by ``jax.vmap`` of init.

Numerics note: layers compute in ``cfg.dtype`` (bf16 for the big configs)
with fp32 softmax/normalizer accumulations — matching what the Pallas
kernels do on TPU.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from ..sharding import Policy


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype) -> jax.Array:
    scale = 1.0 / math.sqrt(d_in)
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def split(key, n: int):
    return jax.random.split(key, n)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps: float = 1e-6):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * w).astype(dt)


def layer_norm(x, w, b, eps: float = 1e-5):
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return ((x - mu) * jax.lax.rsqrt(var + eps) * w + b).astype(dt)


# ---------------------------------------------------------------------------
# rotary position embeddings (+ M-RoPE for qwen2-vl)
# ---------------------------------------------------------------------------

def rope_cos_sin(positions, d_head: int, theta: float):
    """positions (..., T) -> cos/sin (..., T, d_head//2), fp32."""
    inv = 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float32) / d_head))
    ang = positions[..., None].astype(jnp.float32) * inv
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x (B, T, H, D); cos/sin (B, T, D/2) or (B, T, H, D/2)."""
    if cos.ndim == x.ndim - 1:
        cos, sin = cos[..., None, :], sin[..., None, :]
    x1, x2 = jnp.split(x, 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def mrope_cos_sin(positions3, d_head: int, theta: float, sections=(16, 24, 24)):
    """M-RoPE (Qwen2-VL): three position streams (t, h, w) each driving a
    section of the rotary dims.  positions3: (3, B, T)."""
    assert sum(sections) == d_head // 2
    cos_p, sin_p = [], []
    inv = 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float32) / d_head))
    start = 0
    for s, sec in enumerate(sections):
        ang = positions3[s][..., None].astype(jnp.float32) * inv[start:start + sec]
        cos_p.append(jnp.cos(ang))
        sin_p.append(jnp.sin(ang))
        start += sec
    return jnp.concatenate(cos_p, -1), jnp.concatenate(sin_p, -1)


# ---------------------------------------------------------------------------
# chunked (flash) attention — the jnp oracle shared with the Pallas kernel
# ---------------------------------------------------------------------------

def flash_attention_ref(q, k, v, *, causal: bool = True,
                        q_chunk: int = 512, kv_chunk: int = 512,
                        q_offset: int = 0):
    """Online-softmax blockwise attention (pure jnp + lax.scan).

    q: (B, Tq, Hq, D), k/v: (B, Tk, Hk, D) with Hq % Hk == 0.  Never
    materialises the (Tq, Tk) score matrix; memory is O(q_chunk x kv_chunk).
    ``q_offset`` positions q tokens at kv index ``q_offset + i`` for causal
    masking (prefill continuation / decode).
    """
    B, Tq, Hq, D = q.shape
    _, Tk, Hk, _ = k.shape
    Dv = v.shape[-1]
    G = Hq // Hk
    scale = 1.0 / math.sqrt(D)
    q_chunk = min(q_chunk, Tq)
    kv_chunk = min(kv_chunk, Tk)
    nq = -(-Tq // q_chunk)
    nk = -(-Tk // kv_chunk)
    # pad to multiples
    qp = jnp.pad(q, ((0, 0), (0, nq * q_chunk - Tq), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, nk * kv_chunk - Tk), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, nk * kv_chunk - Tk), (0, 0), (0, 0)))
    # (nq, B, C, Hk, G, D)
    qs = qp.reshape(B, nq, q_chunk, Hk, G, D).transpose(1, 0, 2, 3, 4, 5)
    ks = kp.reshape(B, nk, kv_chunk, Hk, D).transpose(1, 0, 2, 3, 4)
    vs = vp.reshape(B, nk, kv_chunk, Hk, Dv).transpose(1, 0, 2, 3, 4)
    kv_valid = (jnp.arange(nk * kv_chunk) < Tk).reshape(nk, kv_chunk)

    def q_block(qi, q_blk):
        q_blk = q_blk.astype(jnp.float32) * scale
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, inp):
            acc, m, l = carry
            ki, k_blk, v_blk, valid = inp
            k_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            # scores: (B, C, Hk, G, Ck)
            s = jnp.einsum("bchgd,bkhd->bchgk", q_blk,
                           k_blk.astype(jnp.float32))
            mask = valid[None, None, None, None, :]
            if causal:
                cm = q_pos[:, None] >= k_pos[None, :]
                mask = jnp.logical_and(mask, cm[None, :, None, None, :])
            s = jnp.where(mask, s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + p.sum(-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bchgk,bkhd->bchgd", p, v_blk.astype(jnp.float32))
            return (acc, m_new, l_new), None

        acc0 = jnp.zeros((B, q_chunk, Hk, G, Dv), jnp.float32)
        m0 = jnp.full((B, q_chunk, Hk, G), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, q_chunk, Hk, G), jnp.float32)
        # remat the kv step: backward recomputes each score block instead of
        # saving nk of them (flash-attention backward's memory contract)
        (acc, m, l), _ = jax.lax.scan(
            jax.checkpoint(kv_step), (acc0, m0, l0),
            (jnp.arange(nk), ks, vs, kv_valid))
        return acc / jnp.maximum(l[..., None], 1e-30)

    out = jax.lax.map(lambda args: q_block(*args), (jnp.arange(nq), qs))
    out = out.transpose(1, 0, 2, 3, 4, 5).reshape(B, nq * q_chunk, Hq, Dv)
    return out[:, :Tq].astype(q.dtype)


def plain_attention(q, k, v, *, causal: bool, q_offset: int = 0):
    """Reference dense attention (small shapes / decode).  v's head dim may
    differ from q/k's (MLA)."""
    B, Tq, Hq, D = q.shape
    _, Tk, Hk, _ = k.shape
    Dv = v.shape[-1]
    G = Hq // Hk
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Tq, Hk, G, D).astype(jnp.float32) * scale
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    if causal:
        q_pos = q_offset + jnp.arange(Tq)
        mask = q_pos[:, None] >= jnp.arange(Tk)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Tq, Hq, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# GQA attention block (llama/qwen/stablelm/mistral/qwen2-vl/zamba2-shared)
# ---------------------------------------------------------------------------

def gqa_init(key, cfg, dtype) -> dict:
    d, dh = cfg.d_model, cfg.d_head
    ks = split(key, 6)
    p = {
        "wq": dense_init(ks[0], d, cfg.n_heads * dh, dtype),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * dh, dtype),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * dh, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * dh, d, dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((dh,), dtype)
        p["k_norm"] = jnp.ones((dh,), dtype)
    return p


def gqa_attention(p, x, cfg, shd: Policy, *, positions, cache=None,
                  use_flash: bool | None = None):
    """Returns (out, new_cache).  cache = dict(k, v, len) for decode."""
    B, T, d = x.shape
    dh = cfg.d_head
    q = (x @ p["wq"]).reshape(B, T, cfg.n_heads, dh)
    k = (x @ p["wk"]).reshape(B, T, cfg.n_kv_heads, dh)
    v = (x @ p["wv"]).reshape(B, T, cfg.n_kv_heads, dh)
    q = shd.constrain(q, "batch", "seq", "heads", None, name="attn_q")
    k = shd.constrain(k, "batch", "seq", "kv_heads", None, name="attn_k")
    v = shd.constrain(v, "batch", "seq", "kv_heads", None, name="attn_v")
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if cfg.mrope:
        cos, sin = mrope_cos_sin(positions, dh, cfg.rope_theta,
                                 cfg.mrope_sections)
    else:
        cos, sin = rope_cos_sin(positions[0] if positions.ndim == 3
                                else positions, dh, cfg.rope_theta)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    new_cache = None
    if cache is not None:
        # decode: insert k/v at cache['len'], attend over the full cache
        idx = cache["len"]
        ck = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, idx, axis=1)
        cv = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, idx, axis=1)
        new_cache = {"k": ck, "v": cv, "len": idx + T}
        mask_len = ck.shape[1]
        kv_pos = jnp.arange(mask_len)
        valid = kv_pos < (idx + T)
        # under seq-sharded serving layouts q must stay cheap to move:
        # scores/output then contract against the sharded cache locally
        q = shd.constrain(q, "batch", None, "decode_q_heads", None,
                          name="decode_q")
        o = _decode_attention(q, ck, cv, valid, q_offset=idx)
    else:
        q_off = 0
        if use_flash is None:
            use_flash = T > 1024
        if use_flash == "pallas":
            from ..kernels import ops as K
            o = K.flash_attention(q, k, v, causal=cfg.causal,
                                  block_q=min(cfg.q_chunk, 128),
                                  block_k=min(cfg.kv_chunk, 128))
        elif use_flash:
            o = flash_attention_ref(q, k, v, causal=cfg.causal,
                                    q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk,
                                    q_offset=q_off)
        else:
            o = plain_attention(q, k, v, causal=cfg.causal, q_offset=q_off)
    o = shd.constrain(o, "batch", "seq", "heads", None, name="attn_o")
    of = o.reshape(B, T, cfg.n_heads * dh)
    of = shd.constrain(of, "batch", "seq", "attn_o_feat", name="attn_o_flat")
    out = of @ p["wo"]
    return shd.constrain(out, "batch", "seq_act", "embed", name="attn_out"), new_cache


def _decode_attention(q, k, v, valid, q_offset):
    """Attention of T=1..few query tokens over a padded cache."""
    B, Tq, Hq, D = q.shape
    _, Tk, Hk, _ = k.shape
    Dv = v.shape[-1]
    G = Hq // Hk
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Tq, Hk, G, D).astype(jnp.float32) * scale
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    q_pos = q_offset + jnp.arange(Tq)
    causal = q_pos[:, None] >= jnp.arange(Tk)[None, :]
    mask = jnp.logical_and(valid[None, :], causal)
    s = jnp.where(mask[None, None, None], s, -1e30)
    # fp32 softmax, then probs cast to the cache dtype before the PV
    # contraction: halves the partial-sum bytes the seq-sharded serving
    # layouts all-reduce (standard practice; f32 path kept for f32 caches)
    p = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v)
    return o.reshape(B, Tq, Hq, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLA attention (deepseek-v3): latent-compressed KV + decoupled RoPE
# ---------------------------------------------------------------------------

def mla_init(key, cfg, dtype) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    ks = split(key, 8)
    qr, kvr = cfg.q_lora_rank, cfg.kv_lora_rank
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    return {
        "wq_a": dense_init(ks[0], d, qr, dtype),
        "q_a_norm": jnp.ones((qr,), dtype),
        "wq_b": dense_init(ks[1], qr, H * (dn + dr), dtype),
        "wkv_a": dense_init(ks[2], d, kvr + dr, dtype),
        "kv_a_norm": jnp.ones((kvr,), dtype),
        "wkv_b": dense_init(ks[3], kvr, H * (dn + dv), dtype),
        "wo": dense_init(ks[4], H * dv, d, dtype),
    }


def mla_attention(p, x, cfg, shd: Policy, *, positions, cache=None):
    """DeepSeek-V3 Multi-head Latent Attention.

    Prefill/train: expanded form.  Decode: *weight-absorbed* form scoring
    directly against the latent cache (the MLA serving optimisation) —
    cache holds only (c_kv[kvr], k_pe[dr]) per position.
    """
    B, T, d = x.shape
    H = cfg.n_heads
    dn, dr, dv = cfg.qk_nope_head_dim, cfg.qk_rope_head_dim, cfg.v_head_dim
    kvr = cfg.kv_lora_rank
    q = rms_norm(x @ p["wq_a"], p["q_a_norm"]) @ p["wq_b"]
    q = q.reshape(B, T, H, dn + dr)
    q_nope, q_pe = q[..., :dn], q[..., dn:]
    kv_a = x @ p["wkv_a"]
    c_kv, k_pe = kv_a[..., :kvr], kv_a[..., kvr:]
    c_kv = rms_norm(c_kv, p["kv_a_norm"])
    pos = positions[0] if positions.ndim == 3 else positions
    cos, sin = rope_cos_sin(pos, dr, cfg.rope_theta)
    q_pe = apply_rope(q_pe, cos, sin)
    k_pe = apply_rope(k_pe[:, :, None, :], cos, sin)[:, :, 0]  # shared across heads
    scale = 1.0 / math.sqrt(dn + dr)

    w_kv_b = p["wkv_b"].reshape(kvr, H, dn + dv)
    w_uk, w_uv = w_kv_b[..., :dn], w_kv_b[..., dn:]

    if cache is not None:
        idx = cache["len"]
        cc = jax.lax.dynamic_update_slice_in_dim(cache["c_kv"], c_kv, idx, axis=1)
        cp = jax.lax.dynamic_update_slice_in_dim(cache["k_pe"], k_pe, idx, axis=1)
        new_cache = {"c_kv": cc, "k_pe": cp, "len": idx + T}
        # absorbed scoring: q_abs (B,T,H,kvr) = q_nope . W_uk
        q_nope = shd.constrain(q_nope, "batch", None, "decode_q_heads", None,
                               name="mla_decode_q")
        q_pe = shd.constrain(q_pe, "batch", None, "decode_q_heads", None,
                             name="mla_decode_qpe")
        q_abs = jnp.einsum("bthn,rhn->bthr", q_nope.astype(jnp.float32),
                           w_uk.astype(jnp.float32))
        s = jnp.einsum("bthr,bsr->bhts", q_abs, cc.astype(jnp.float32))
        s = s + jnp.einsum("bthr,bsr->bhts", q_pe.astype(jnp.float32),
                           cp.astype(jnp.float32))
        s = s * scale
        kv_pos = jnp.arange(cc.shape[1])
        q_pos = idx + jnp.arange(T)
        mask = jnp.logical_and(kv_pos[None, :] < idx + T,
                               q_pos[:, None] >= kv_pos[None, :])
        s = jnp.where(mask[None, None], s, -1e30)
        pr = jax.nn.softmax(s, axis=-1)
        o_lat = jnp.einsum("bhts,bsr->bthr", pr, cc.astype(jnp.float32))
        o = jnp.einsum("bthr,rhv->bthv", o_lat, w_uv.astype(jnp.float32))
        o = o.astype(x.dtype)
    else:
        new_cache = None
        kv = jnp.einsum("btr,rhe->bthe", c_kv, w_kv_b.astype(c_kv.dtype))
        k_nope, v = kv[..., :dn], kv[..., dn:]
        k = jnp.concatenate(
            [k_nope, jnp.broadcast_to(k_pe[:, :, None, :], (B, T, H, dr))], -1)
        qf = jnp.concatenate([q_nope, q_pe], -1)
        qf = shd.constrain(qf, "batch", "seq", "heads", None, name="mla_q")
        k = shd.constrain(k, "batch", "seq", "heads", None, name="mla_k")
        if T > 1024:
            o = flash_attention_ref(qf, k, v, causal=True,
                                    q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
        else:
            o = plain_attention(qf, k, v, causal=True)
    of = o.reshape(B, T, H * dv)
    of = shd.constrain(of, "batch", "seq", "attn_o_feat", name="mla_o_flat")
    out = of @ p["wo"]
    return shd.constrain(out, "batch", "seq_act", "embed", name="mla_out"), new_cache


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_init(key, d: int, d_ff: int, dtype) -> dict:
    ks = split(key, 2)
    return {"wi": dense_init(ks[0], d, 2 * d_ff, dtype),
            "wo": dense_init(ks[1], d_ff, d, dtype)}


def swiglu_mlp(p, x, shd: Policy):
    h = x @ p["wi"]
    h = shd.constrain(h, "batch", "seq", "ff", name="mlp_h")
    gate, up = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(gate) * up
    out = h @ p["wo"]
    return shd.constrain(out, "batch", "seq_act", "embed", name="mlp_out")


# ---------------------------------------------------------------------------
# MoE (granite / deepseek-v3): top-k routing, capacity, shared expert
# ---------------------------------------------------------------------------

def moe_init(key, cfg, dtype) -> dict:
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    ks = split(key, 4)
    scale_i = 1.0 / math.sqrt(d)
    scale_o = 1.0 / math.sqrt(ff)
    p = {
        "router": dense_init(ks[0], d, e, jnp.float32),
        "w_up": (jax.random.normal(ks[1], (e, d, 2 * ff), jnp.float32)
                 * scale_i).astype(dtype),
        "w_down": (jax.random.normal(ks[2], (e, ff, d), jnp.float32)
                   * scale_o).astype(dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = swiglu_init(ks[3], d, cfg.moe_d_ff * cfg.n_shared_experts, dtype)
    return p


def moe_block(p, x, cfg, shd: Policy):
    """Grouped dispatch-einsum MoE (Switch/MaxText style), static capacity.

    Tokens are partitioned into contiguous *groups* (the group dim shards
    over the data axis), routing capacity is per (group, expert), and the
    dispatch one-hot is (G, Ng, E, cap) — per-device memory is
    tokens_per_device x E x cap, independent of global batch.  Tokens
    beyond capacity are dropped (residual path carries them).  The expert
    dim of the weights shards over the model axis (EP).
    """
    B, T, d = x.shape
    E, K = cfg.n_experts, cfg.moe_top_k
    N = B * T
    gs = min(getattr(cfg, "moe_group_size", 512), N)
    if N % gs:
        gs = N
    G = N // gs
    xg = x.reshape(G, gs, d)
    xg = shd.constrain(xg, "batch", None, None, name="moe_groups")
    logits = (xg.astype(jnp.float32) @ p["router"])          # (G, Ng, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)            # (G, Ng, K)
    if cfg.moe_renorm:
        gate_vals = gate_vals / jnp.clip(gate_vals.sum(-1, keepdims=True), 1e-9)
    cap = max(int(cfg.moe_capacity_factor * gs * K / E), 1)
    # position of each (token, k) within its (group, expert) queue
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)    # (G, Ng, K, E)
    flat = onehot.reshape(G, gs * K, E)
    pos_in_e = jnp.cumsum(flat, axis=1) - flat               # (G, Ng*K, E)
    pos = (pos_in_e * flat).sum(-1).reshape(G, gs, K)
    keep = pos < cap
    # dispatch (G, Ng, E, cap) one-hot
    disp = (jax.nn.one_hot(gate_idx, E, dtype=x.dtype)[..., None]
            * jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                             dtype=x.dtype)[..., :cap][:, :, :, None, :])
    disp = disp.sum(2)                                       # (G, Ng, E, cap)
    disp = shd.constrain(disp, "batch", None, "experts", None, name="moe_disp")
    xe = jnp.einsum("gnec,gnd->gecd", disp, xg)              # (G, E, cap, d)
    xe = shd.constrain(xe, "batch", "experts", None, None, name="moe_xe")
    h = jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    g, u = jnp.split(h, 2, axis=-1)
    h = jax.nn.silu(g) * u
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    ye = shd.constrain(ye, "batch", "experts", None, None, name="moe_ye")
    # combine: weight each token's expert outputs by its gate value
    gate_full = (jax.nn.one_hot(gate_idx, E, dtype=x.dtype)
                 * gate_vals.astype(x.dtype)[..., None]).sum(2)  # (G, Ng, E)
    y = jnp.einsum("gnec,gecd,gne->gnd", disp, ye, gate_full)
    out = y.reshape(B, T, d)
    if "shared" in p:
        out = out + swiglu_mlp(p["shared"], x, shd)
    # aux losses for training: load-balance (Switch) in fp32
    me = probs.mean((0, 1))                                  # mean router prob
    ce = (disp.sum((0, 1, 3)) / jnp.maximum(disp.sum(), 1.0))  # fraction routed
    aux = E * jnp.sum(me * ce)
    return shd.constrain(out, "batch", "seq_act", "embed", name="moe_out"), aux


# ---------------------------------------------------------------------------
# chunked gated linear recurrence — shared by Mamba2 (SSD) and mLSTM
# ---------------------------------------------------------------------------

def chunked_linear_recurrence(c, b, v, log_a, *, chunk: int,
                              initial_state=None):
    """y_t = c_t^T S_t,  S_t = exp(log_a_t) * S_{t-1} + b_t v_t^T.

    c, b: (B, T, H, N); v: (B, T, H, P); log_a: (B, T, H) (<= 0).
    Returns (y: (B, T, H, P), final_state: (B, H, N, P)).

    This is the Mamba-2 SSD chunked algorithm: intra-chunk work is dense
    matmuls (MXU-friendly), inter-chunk state is a short scan — the
    TPU-native restructuring of the paper's "CumSum favours CPU"
    sequential-recurrence operator.
    """
    B, T, H, N = b.shape
    P = v.shape[-1]
    nc = -(-T // chunk)
    pad = nc * chunk - T
    if pad:
        c = jnp.pad(c, ((0, 0), (0, pad), (0, 0), (0, 0)))
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
    f32 = jnp.float32
    cc = c.reshape(B, nc, chunk, H, N).astype(f32)
    bb = b.reshape(B, nc, chunk, H, N).astype(f32)
    vv = v.reshape(B, nc, chunk, H, P).astype(f32)
    la = log_a.reshape(B, nc, chunk, H).astype(f32)
    cum = jnp.cumsum(la, axis=2)                    # (B, nc, C, H)
    tot = cum[:, :, -1]                             # (B, nc, H)

    # intra-chunk: L[i,j] = exp(cum_i - cum_j) for i >= j.  Mask *before*
    # exp: masked entries have diff > 0 and exp would overflow to inf,
    # poisoning gradients through the where (0 * inf = NaN in the vjp).
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]   # (B,nc,C,C,H)
    ii = jnp.arange(chunk)
    lmask = ii[:, None] >= ii[None, :]
    diff = jnp.where(lmask[None, None, :, :, None], diff, -1e9)
    L = jnp.exp(diff)
    s_intra = jnp.einsum("bgihn,bgjhn->bgijh", cc, bb) * L
    y_intra = jnp.einsum("bgijh,bgjhp->bgihp", s_intra, vv)

    # per-chunk state contribution: sum_j exp(tot - cum_j) b_j v_j^T
    w = jnp.exp(tot[:, :, None, :] - cum)                   # (B,nc,C,H)
    chunk_state = jnp.einsum("bgjh,bgjhn,bgjhp->bghnp", w, bb, vv)

    # inter-chunk scan over nc
    def step(S, inp):
        cs, dec = inp                                       # (B,H,N,P), (B,H)
        S_new = S * jnp.exp(dec)[..., None, None] + cs
        return S_new, S                                     # emit state *before* chunk

    S0 = (jnp.zeros((B, H, N, P), f32) if initial_state is None
          else initial_state.astype(f32))
    S_final, states_in = jax.lax.scan(
        step, S0, (chunk_state.transpose(1, 0, 2, 3, 4),
                   tot.transpose(1, 0, 2)))
    states_in = states_in.transpose(1, 0, 2, 3, 4)          # (B,nc,H,N,P)
    y_inter = jnp.einsum("bgihn,bghnp,bgih->bgihp", cc, states_in,
                         jnp.exp(cum))
    y = (y_intra + y_inter).reshape(B, nc * chunk, H, P)[:, :T]
    return y.astype(v.dtype), S_final


def linear_recurrence_step(S, c_t, b_t, v_t, log_a_t):
    """Single decode step: S' = a*S + b v^T; y = c^T S'."""
    f32 = jnp.float32
    S = S.astype(f32)
    a = jnp.exp(log_a_t.astype(f32))[..., None, None]
    S_new = S * a + jnp.einsum("bhn,bhp->bhnp", b_t.astype(f32), v_t.astype(f32))
    y = jnp.einsum("bhn,bhnp->bhp", c_t.astype(f32), S_new)
    return y.astype(v_t.dtype), S_new


# ---------------------------------------------------------------------------
# Mamba2 block (zamba2 backbone)
# ---------------------------------------------------------------------------

def mamba2_init(key, cfg, dtype) -> dict:
    d = cfg.d_model
    di = cfg.ssm_d_inner
    H = cfg.ssm_heads
    N = cfg.ssm_state
    ks = split(key, 6)
    conv_dim = di + 2 * N * cfg.ssm_groups
    return {
        "in_proj": dense_init(ks[0], d, 2 * di + 2 * N * cfg.ssm_groups + H, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, conv_dim), jnp.float32)
                   * 0.2).astype(dtype),
        "conv_b": jnp.zeros((conv_dim,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "D": jnp.ones((H,), jnp.float32),
        "dt_bias": jnp.zeros((H,), jnp.float32),
        "norm_w": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[2], di, d, dtype),
    }


def mamba2_block(p, x, cfg, shd: Policy, *, state=None,
                 use_kernel: bool = False):
    """Mamba-2 (SSD).  state = dict(ssm (B,H,N,P), conv (B, k-1, convdim))
    for single-step decode; None for full-sequence training."""
    B, T, d = x.shape
    di, H, N, G = cfg.ssm_d_inner, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_groups
    P = di // H
    conv_dim = di + 2 * N * G
    zxbcdt = x @ p["in_proj"]
    z, xbc, dt = jnp.split(zxbcdt, [di, di + conv_dim], axis=-1)
    z = shd.constrain(z, "batch", "seq", "ff", name="ssm_z")
    # depthwise causal conv over (x, B, C)
    if state is not None:
        conv_in = jnp.concatenate([state["conv"], xbc], axis=1)
        new_conv = conv_in[:, -(cfg.ssm_conv - 1):]
        xbc = jnp.einsum("bkc,kc->bc", conv_in[:, -cfg.ssm_conv:],
                         p["conv_w"])[:, None, :] + p["conv_b"]
    else:
        new_conv = None
        pad = jnp.zeros((B, cfg.ssm_conv - 1, conv_dim), xbc.dtype)
        xin = jnp.concatenate([pad, xbc], axis=1)
        xbc = sum(xin[:, i:i + T] * p["conv_w"][i] for i in range(cfg.ssm_conv))
        xbc = xbc + p["conv_b"]
    xbc = jax.nn.silu(xbc)
    xs, Bc, Cc = jnp.split(xbc, [di, di + N * G], axis=-1)
    Tx = xs.shape[1]
    xs = xs.reshape(B, Tx, H, P)
    Bc = Bc.reshape(B, Tx, G, N)
    Cc = Cc.reshape(B, Tx, G, N)
    rep = H // G
    Bh = jnp.repeat(Bc, rep, axis=2)
    Ch = jnp.repeat(Cc, rep, axis=2)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,T,H)
    A = -jnp.exp(p["A_log"])
    log_a = dt * A                                                 # (B,T,H)
    xdt = xs * dt[..., None].astype(xs.dtype)
    if state is not None:
        y, S = linear_recurrence_step(state["ssm"], Ch[:, 0], Bh[:, 0],
                                      xdt[:, 0], log_a[:, 0])
        y = y[:, None]
        new_state = {"ssm": S, "conv": new_conv}
    elif use_kernel:
        from ..kernels import ops as K
        y, S = K.ssd_scan(Ch, Bh, xdt, log_a, chunk=cfg.ssm_chunk)
        new_state = {"ssm": S, "conv": None}
    else:
        y, S = chunked_linear_recurrence(Ch, Bh, xdt, log_a,
                                         chunk=cfg.ssm_chunk)
        new_state = {"ssm": S, "conv": None}
    y = y + xs * p["D"][None, None, :, None].astype(xs.dtype)
    y = y.reshape(B, y.shape[1], di)
    y = rms_norm(y * jax.nn.silu(z[:, :y.shape[1]]), p["norm_w"])
    out = y @ p["out_proj"]
    return shd.constrain(out, "batch", "seq_act", "embed", name="ssm_out"), new_state


# ---------------------------------------------------------------------------
# xLSTM blocks (mLSTM: matrix memory; sLSTM: scalar memory + state mixing)
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg, dtype) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    di = cfg.xlstm_d_inner
    dh = di // H
    ks = split(key, 8)
    return {
        "up": dense_init(ks[0], d, 2 * di, dtype),
        "wq": dense_init(ks[1], di, di, dtype),
        "wk": dense_init(ks[2], di, di, dtype),
        "wv": dense_init(ks[3], di, di, dtype),
        "wif": dense_init(ks[4], di, 2 * H, dtype),  # input+forget gates
        "norm_w": jnp.ones((di,), dtype),
        "down": dense_init(ks[5], di, d, dtype),
    }


def mlstm_block(p, x, cfg, shd: Policy, *, state=None,
                use_kernel: bool = False):
    """mLSTM: exponentially-gated matrix memory == gated linear attention.
    Uses the same chunked recurrence as Mamba2 (TPU adaptation)."""
    B, T, d = x.shape
    H = cfg.n_heads
    di = cfg.xlstm_d_inner
    dh = di // H
    h = x @ p["up"]
    hx, hg = jnp.split(h, 2, axis=-1)
    q = (hx @ p["wq"]).reshape(B, T, H, dh)
    k = (hx @ p["wk"]).reshape(B, T, H, dh) / math.sqrt(dh)
    v = (hx @ p["wv"]).reshape(B, T, H, dh)
    gates = (hx @ p["wif"]).astype(jnp.float32)
    i_g, f_g = jnp.split(gates, 2, axis=-1)                   # (B,T,H)
    log_f = -jax.nn.softplus(-f_g)                            # log sigmoid
    # stabilised exponential input gate: fold exp(i) into k
    k = k * jnp.exp(jnp.minimum(i_g, 8.0))[..., None].astype(k.dtype)
    # normaliser: append ones column to v
    v_aug = jnp.concatenate([v, jnp.ones_like(v[..., :1])], axis=-1)
    if state is not None:
        y_aug, S = linear_recurrence_step(state["ssm"], q[:, 0], k[:, 0],
                                          v_aug[:, 0], log_f[:, 0])
        y_aug = y_aug[:, None]
        new_state = {"ssm": S}
    elif use_kernel:
        from ..kernels import ops as K
        y_aug, S = K.ssd_scan(q, k, v_aug, log_f, chunk=cfg.ssm_chunk)
        new_state = {"ssm": S}
    else:
        y_aug, S = chunked_linear_recurrence(q, k, v_aug, log_f,
                                             chunk=cfg.ssm_chunk)
        new_state = {"ssm": S}
    y, nrm = y_aug[..., :dh], y_aug[..., dh:]
    y = y / jnp.maximum(jnp.abs(nrm), 1.0).astype(y.dtype)
    y = y.reshape(B, y.shape[1], di)
    y = rms_norm(y, p["norm_w"]) * jax.nn.silu(hg[:, :y.shape[1]])
    out = y @ p["down"]
    return shd.constrain(out, "batch", "seq_act", "embed", name="mlstm_out"), new_state


def slstm_init(key, cfg, dtype) -> dict:
    d = cfg.d_model
    H = cfg.n_heads
    dh = d // H
    ks = split(key, 4)
    return {
        "w_in": dense_init(ks[0], d, 4 * d, dtype),     # z i f o pre-acts
        "r": (jax.random.normal(ks[1], (H, dh, 4 * dh), jnp.float32)
              / math.sqrt(dh)).astype(dtype),           # block-diag recurrent
        "bias": jnp.zeros((4 * d,), dtype),
        "norm_w": jnp.ones((d,), dtype),
        "ff": swiglu_init(ks[2], d, cfg.slstm_ff, dtype),
    }


def slstm_block(p, x, cfg, shd: Policy, *, state=None):
    """sLSTM: scalar memories, exponential gating, per-head state mixing.
    Truly sequential -> lax.scan over time (the CPU-affine recurrence of
    the paper, kept as a scan on TPU)."""
    B, T, d = x.shape
    H = cfg.n_heads
    dh = d // H
    pre_all = x @ p["w_in"] + p["bias"]                      # (B,T,4d)

    def cell(carry, pre_t):
        c, n, hprev, m = carry                               # (B,H,dh) each, m (B,H,dh)
        rec = jnp.einsum("bhe,hef->bhf", hprev, p["r"].astype(jnp.float32))
        pre = pre_t.reshape(B, H, 4 * dh).astype(jnp.float32) + rec
        z, i, f, o = jnp.split(pre, 4, axis=-1)
        z = jnp.tanh(z)
        o = jax.nn.sigmoid(o)
        log_f = -jax.nn.softplus(-f)
        m_new = jnp.maximum(log_f + m, i)
        i_p = jnp.exp(i - m_new)
        f_p = jnp.exp(log_f + m - m_new)
        c_new = f_p * c + i_p * z
        n_new = f_p * n + i_p
        h_new = o * c_new / jnp.maximum(jnp.abs(n_new), 1.0)
        return (c_new, n_new, h_new, m_new), h_new

    if state is None:
        zeros = jnp.zeros((B, H, dh), jnp.float32)
        carry0 = (zeros, zeros, zeros, zeros)
    else:
        carry0 = state["slstm"]
    carry, hs = jax.lax.scan(cell, carry0,
                             pre_all.transpose(1, 0, 2))     # scan over T
    h = hs.transpose(1, 0, 2, 3).reshape(B, T, d).astype(x.dtype)
    h = rms_norm(h, p["norm_w"])
    out = h + swiglu_mlp(p["ff"], h, shd)
    return shd.constrain(out, "batch", "seq_act", "embed", name="slstm_out"), \
        {"slstm": carry}


# ---------------------------------------------------------------------------
# cross-attention (seamless enc-dec)
# ---------------------------------------------------------------------------

def cross_attn_init(key, cfg, dtype) -> dict:
    d, dh = cfg.d_model, cfg.d_head
    ks = split(key, 4)
    return {
        "wq": dense_init(ks[0], d, cfg.n_heads * dh, dtype),
        "wk": dense_init(ks[1], d, cfg.n_kv_heads * dh, dtype),
        "wv": dense_init(ks[2], d, cfg.n_kv_heads * dh, dtype),
        "wo": dense_init(ks[3], cfg.n_heads * dh, d, dtype),
    }


def cross_attention(p, x, memory, cfg, shd: Policy):
    B, T, d = x.shape
    S = memory.shape[1]
    dh = cfg.d_head
    q = (x @ p["wq"]).reshape(B, T, cfg.n_heads, dh)
    k = (memory @ p["wk"]).reshape(B, S, cfg.n_kv_heads, dh)
    v = (memory @ p["wv"]).reshape(B, S, cfg.n_kv_heads, dh)
    q = shd.constrain(q, "batch", "seq", "heads", None, name="xattn_q")
    if S > 2048:
        o = flash_attention_ref(q, k, v, causal=False,
                                q_chunk=cfg.q_chunk, kv_chunk=cfg.kv_chunk)
    else:
        o = plain_attention(q, k, v, causal=False)
    of = o.reshape(B, T, cfg.n_heads * dh)
    of = shd.constrain(of, "batch", "seq", "attn_o_feat", name="xattn_o_flat")
    out = of @ p["wo"]
    return shd.constrain(out, "batch", "seq_act", "embed", name="xattn_out")
