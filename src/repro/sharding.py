"""Logical-axis sharding policy.

Model code annotates tensors with *logical* axis names; the policy maps
them to mesh axes.  ``Policy.constrain`` is a no-op without a mesh, so the
same model code runs single-device smoke tests and 512-chip dry-runs.

The default rules implement DP(+pod) x TP with optional FSDP (ZeRO-3-style
parameter sharding over the data axis) and EP (experts over the model
axis).  The BIDENT autoshard pass (``repro.core.autoshard``) emits
*overrides* to these rules — that is how the paper's per-operator PU
assignment becomes a per-operator sharding assignment on TPU.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# logical axis -> mesh axis (None = replicated). A tuple value shards one
# logical axis over several mesh axes.
DEFAULT_RULES: dict[str, object] = {
    "batch": ("pod", "data"),     # pure DP composes pod x data
    "seq": None,                  # sequence replicated by default (SP opts in)
    "seq_shard": ("pod", "data"), # sequence-parallel alternative for act.s
    "seq_act": None,              # residual-stream seq axis: "model" = Megatron-SP
    "embed": None,
    "heads": "model",
    "kv_heads": None,             # kv heads replicated (GQA kv < TP degree)
    "head_dim": None,
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "expert_cap": None,
    "kv_len": None,               # KV-cache seq axis (serving layouts shard it)
    "decode_q_heads": "model",    # q heads in the decode attention region
    "attn_o_feat": "model",       # flattened attn output features (pre-wo)
    "mla_o_heads": "model",       # MLA latent attn output heads (pre-w_uv)
    "kv_heads_p": None,           # wk/wv output features (serve layouts shard)
    "state": None,
    # parameter FSDP axis: weights' non-TP dim sharded over data
    "fsdp": "data",
}


def make_rules(*, sp: bool = False, serve_layout: str | None = None,
               train_layout: str | None = None) -> dict[str, object]:
    """Rule presets found by the §Perf hillclimb (EXPERIMENTS.md).

    sp: Megatron-style sequence parallelism — residual-stream activations
        (the ``seq_act`` sites between attention/MLP regions) shard their
        seq dim over the model axis, turning TP activation all-reduces
        into reduce-scatter/all-gather pairs and cutting normalization /
        elementwise memory traffic by the TP degree.

    train_layout: "dp" folds the model axis into batch (pure DP+FSDP) —
        the right call for <~8B models where TP only buys activation
        all-reduces (§Perf iteration T2).

    serve_layout: decode-path layouts:
      * "1d"  — small models (fit TP-replicated): batch over data, KV-cache
        seq over model; params TP over model, replicated over data (no
        per-step FSDP gathers).
      * "2d"  — big models (>=~70B): batch replicated, KV-cache seq over
        (data x model) = full 256-way, weights stationary 2D-sharded
        (d_in over data via FSDP + d_out over model).  Per-step collective
        traffic is O(activations), never O(params) or O(cache).
    """
    rules = dict(DEFAULT_RULES)
    if sp:
        rules["seq_act"] = "model"
    if train_layout == "dp":
        # pure data parallelism for small models (<~8B on 256 chips): the
        # model axis folds into batch; no TP -> no per-layer activation
        # all-reduces; gradient sync (O(params)) is the only collective.
        # batch folds over (data x model); the pod axis joins through
        # FSDP + the hierarchical gradient all-reduce (global batch =
        # n_chips/pod per pod keeps divisibility on the 2-pod mesh)
        rules["batch"] = ("data", "model")
        rules["heads"] = None
        rules["ff"] = None
        rules["vocab"] = None
        rules["attn_o_feat"] = None
        rules["kv_heads_p"] = None
        rules["fsdp"] = ("pod", "data", "model")   # ZeRO-3 over all chips
    elif train_layout not in (None, "tp"):
        raise ValueError(train_layout)
    if serve_layout == "1d":
        rules["kv_len"] = "model"
        rules["kv_heads_p"] = "model"
    elif serve_layout == "2d":
        # weight-stationary 2D: params shard statically over BOTH mesh
        # axes through their logical dims (never re-gathered per step);
        # KV cache seq shards 256-way; batch replicates (decode
        # activations are tiny).  Per-step collective traffic becomes
        # O(activations) instead of O(params + cache).
        rules["batch"] = None
        rules["kv_len"] = ("data", "model")
        rules["ff"] = ("data", "model")
        rules["vocab"] = ("data", "model")
        rules["experts"] = ("data", "model")
        rules["kv_heads_p"] = ("data", "model")
        # q is tiny at decode: replicate it so GSPMD contracts against the
        # seq-sharded cache locally instead of gathering the cache
        rules["decode_q_heads"] = None
        # flattened attn output shards 2D to match wo's stationary 2D
        # layout (otherwise GSPMD re-gathers wo every layer)
        rules["attn_o_feat"] = ("data", "model")
    elif serve_layout not in (None, "legacy"):
        raise ValueError(serve_layout)
    return rules




def _fit_axis(mesh, dim: int, ax):
    """Largest suffix of the axis tuple whose size divides ``dim``.

    ("data","model") degrades to ("model",) then to None instead of
    jumping straight to replicated — e.g. qwen2-vl's d_ff=29568 divides
    the 16-way model axis but not the 256-way (data x model) product.
    """
    if ax is None:
        return None
    axes = ax if isinstance(ax, tuple) else (ax,)
    for i in range(len(axes)):
        cand = axes[i:]
        size = 1
        for m in cand:
            size *= mesh.shape[m] if mesh else 1
        if size > 1 and dim % size == 0:
            return cand if len(cand) > 1 else cand[0]
    return None

def _dedup_axes(axes: list) -> list:
    """A mesh axis may appear at most once per PartitionSpec: later dims
    that re-request an already-claimed axis fall back to replicated (the
    first claim wins).  Layout presets can therefore map several logical
    axes to the same mesh axis and let per-tensor structure decide."""
    used: set = set()
    out = []
    for ax in axes:
        keys = ax if isinstance(ax, tuple) else (ax,)
        if ax is None or not (used & set(keys)):
            out.append(ax)
            used.update(k for k in keys if k is not None)
        else:
            out.append(None)
    return out

@dataclasses.dataclass
class Policy:
    """Maps logical axis names to mesh axes and applies constraints."""

    mesh: Mesh | None = None
    rules: Mapping[str, object] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_RULES))
    fsdp: bool = False
    # per-tensor-name overrides emitted by the autoshard pass:
    # name -> tuple of logical axes (replaces the annotation at that site)
    overrides: Mapping[str, tuple] = dataclasses.field(default_factory=dict)

    def _axis(self, logical: str | None):
        if logical is None:
            return None
        ax = self.rules.get(logical, None)
        if ax is None:
            return None
        if isinstance(ax, tuple):
            # drop mesh axes that don't exist (e.g. "pod" on single-pod mesh)
            if self.mesh is not None:
                ax = tuple(a for a in ax if a in self.mesh.axis_names)
                if not ax:
                    return None
                return ax if len(ax) > 1 else ax[0]
            return ax
        if self.mesh is not None and ax not in self.mesh.axis_names:
            return None
        return ax

    def spec(self, *logical_axes: str | None) -> P:
        return P(*(self._axis(a) for a in logical_axes))

    def named(self, *logical_axes: str | None) -> NamedSharding | None:
        if self.mesh is None:
            return None
        return NamedSharding(self.mesh, self.spec(*logical_axes))

    def constrain(self, x, *logical_axes: str | None, name: str | None = None):
        """with_sharding_constraint under the policy; no-op without a mesh.

        ``name`` keys into autoshard overrides: when the BIDENT search has
        assigned this site a different sharding "PU", the override wins.
        """
        if self.mesh is None:
            return x
        if name is not None and name in self.overrides:
            logical_axes = self.overrides[name]
        # pad/trim to rank
        axes = list(logical_axes)
        if len(axes) < x.ndim:
            axes += [None] * (x.ndim - len(axes))
        axes = axes[: x.ndim]
        # never request a sharding that doesn't divide the dim; tuple
        # axes degrade to their largest dividing suffix
        fixed = [_fit_axis(self.mesh, dim, self._axis(a))
                 for dim, a in zip(x.shape, axes)]
        return jax.lax.with_sharding_constraint(
            x, NamedSharding(self.mesh, P(*_dedup_axes(fixed))))

    def guarded_spec(self, shape: Sequence[int], *logical_axes: str | None) -> P:
        """PartitionSpec with the divisibility guard (no FSDP pass):
        a dim whose size the mapped mesh axes don't divide stays
        replicated instead of erroring at jit boundary."""
        axes = list(logical_axes)
        if len(axes) < len(shape):
            axes += [None] * (len(shape) - len(axes))
        fixed = [_fit_axis(self.mesh, dim, self._axis(a))
                 for dim, a in zip(shape, axes)]
        return P(*_dedup_axes(fixed))

    # -- parameter specs -----------------------------------------------------
    def param_spec(self, shape: Sequence[int], logical_axes: Sequence[str | None]) -> P:
        """PartitionSpec for a parameter; applies FSDP to the first
        unsharded (and divisible) dim when ``fsdp`` is on.  The sentinel
        logical axis ``"nofsdp"`` keeps a dim replicated AND opts it out of
        the FSDP pass (e.g. the embedding's d_model dim: FSDP there would
        turn the logits matmul into a partial-sum all-reduce of the full
        (batch, seq, vocab) tensor across the data axis)."""
        axes = [self._axis(a) for a in logical_axes]
        if self.fsdp and self.mesh is not None:
            data_ax = self._axis("fsdp")
            # flatten tuple entries: ('pod','data') uses the data axis too
            used: set = set()
            for a in axes:
                used.update(a if isinstance(a, tuple) else (a,))
            if data_ax is not None and data_ax not in used and not (
                    isinstance(data_ax, tuple) and used & set(data_ax)):
                dsize = 1
                for m in (data_ax if isinstance(data_ax, tuple)
                          else (data_ax,)):
                    dsize *= self.mesh.shape[m]
                for i, (dim, a) in enumerate(zip(shape, axes)):
                    if (a is None and dim % dsize == 0
                            and logical_axes[i] != "nofsdp"):
                        axes[i] = data_ax
                        break
        # divisibility guard; tuple axes degrade to a dividing suffix
        fixed = [_fit_axis(self.mesh, dim, ax)
                 for dim, ax in zip(shape, axes)]
        return P(*_dedup_axes(fixed))


NO_POLICY = Policy(mesh=None)
