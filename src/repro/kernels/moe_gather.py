"""Fused MoE expert GLU apply — Pallas TPU kernel.

TPU adaptation of the paper's Gather-affinity operator class (KAN spline
eval / MoE dispatch): on the edge SoC the gather favours the CPU because
it falls outside the NPU MAC datapath; on TPU the fix is to restructure
dispatch into *dense, capacity-padded* form (XLA one-hot dispatch is
MXU-friendly) and fuse the expert FFN so the (E, cap, 2F) GLU hidden
tensor never round-trips HBM.

The kernel computes, per expert e and token tile m:

    y[e, m] = (silu(x[e,m] @ Wg[e]) * (x[e,m] @ Wu[e])) @ Wd[e]

with the ff dimension tiled sequentially and a fp32 (bm x d) accumulator
in VMEM scratch.  Eliminated HBM traffic vs the unfused path: the
2 x (E x cap x F) hidden write+read (the dominant activation traffic of
the MoE block at decode batch sizes).

Grid: (E, cap/bm, F/bf); the f axis is innermost/sequential.
VMEM per step (bm=128, bf=256, d=4096, bf16): x 1MB + wg,wu 2x2MB +
wd 2MB + acc(f32) 2MB ~= 9MB — under the ~16MB budget; shrink bf for
d=7168 (deepseek) to stay inside.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _expert_glu_kernel(x_ref, wg_ref, wu_ref, wd_ref, y_ref, acc_ref, *,
                       num_f: int):
    fi = pl.program_id(2)

    @pl.when(fi == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]                                    # (bm, d)
    wg = wg_ref[0]                                  # (d, bf)
    wu = wu_ref[0]                                  # (d, bf)
    wd = wd_ref[0]                                  # (bf, d)
    g = jax.lax.dot_general(x, wg, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    u = jax.lax.dot_general(x, wu, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    a = (jax.nn.silu(g) * u).astype(x.dtype)
    acc_ref[...] += jax.lax.dot_general(a, wd, (((1,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32)

    @pl.when(fi == num_f - 1)
    def _finish():
        y_ref[0] = acc_ref[...].astype(y_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_f", "interpret"))
def expert_glu(x, w_up, w_down, *, block_m: int = 128, block_f: int = 256,
               interpret: bool = False):
    """x: (E, cap, d) capacity-padded per-expert tokens; w_up: (E, d, 2F)
    ([..., :F] gate, [..., F:] up); w_down: (E, F, d).
    Returns (E, cap, d) expert outputs in x.dtype.
    """
    E, cap, d = x.shape
    F = w_down.shape[1]
    assert w_up.shape == (E, d, 2 * F), (w_up.shape, (E, d, 2 * F))
    block_m = min(block_m, max(cap, 1))
    block_f = min(block_f, F)
    nm = -(-cap // block_m)
    nf = -(-F // block_f)
    assert F % block_f == 0, "pick block_f dividing d_ff"
    pm = nm * block_m - cap
    if pm:
        x = jnp.pad(x, ((0, 0), (0, pm), (0, 0)))

    kernel = functools.partial(_expert_glu_kernel, num_f=nf)
    y = pl.pallas_call(
        kernel,
        grid=(E, nm, nf),
        in_specs=[
            pl.BlockSpec((1, block_m, d), lambda e, mi, fi: (e, mi, 0)),
            pl.BlockSpec((1, d, block_f), lambda e, mi, fi: (e, 0, fi)),
            pl.BlockSpec((1, d, block_f),
                         lambda e, mi, fi, nf=nf: (e, 0, nf + fi)),
            pl.BlockSpec((1, block_f, d), lambda e, mi, fi: (e, fi, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_m, d), lambda e, mi, fi: (e, mi, 0)),
        out_shape=jax.ShapeDtypeStruct((E, nm * block_m, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_m, d), jnp.float32)],
        interpret=interpret,
    )(x, w_up, w_up, w_down)
    return y[:, :cap]


def dispatch_indices(gate_idx, capacity: int, n_experts: int):
    """Capacity-padded dispatch bookkeeping (XLA side; cheap vs matmuls).

    gate_idx: (T, K) int32.  Returns (token_of (E, cap) int32 with -1 pads,
    keep (T, K) bool, pos (T, K) int32) where pos is each (t, k) slot's
    queue position within its expert.
    """
    T, K = gate_idx.shape
    onehot = jax.nn.one_hot(gate_idx, n_experts, dtype=jnp.int32)  # (T,K,E)
    flat = onehot.reshape(T * K, n_experts)
    pos_flat = jnp.cumsum(flat, axis=0) - flat
    pos = (pos_flat.reshape(T, K, n_experts) * onehot).sum(-1)      # (T,K)
    keep = pos < capacity
    # scatter token ids into the (E, cap) table
    tok_ids = jnp.broadcast_to(jnp.arange(T)[:, None], (T, K))
    e_flat = gate_idx.reshape(-1)
    p_flat = jnp.where(keep, pos, capacity).reshape(-1)
    token_of = jnp.full((n_experts, capacity + 1), -1, jnp.int32)
    token_of = token_of.at[e_flat, p_flat].set(tok_ids.reshape(-1),
                                               mode="drop")
    return token_of[:, :capacity], keep, pos


def moe_dispatch_combine(x, gate_idx, gate_vals, w_up, w_down, *,
                         capacity: int, block_m: int = 128,
                         block_f: int = 256, interpret: bool = False):
    """End-to-end fused MoE: dispatch (XLA gather) -> expert_glu (Pallas)
    -> combine (XLA weighted scatter-add).  Matches
    ``ref.moe_dispatch_combine_ref``.
    """
    T, d = x.shape
    E = w_up.shape[0]
    K = gate_idx.shape[1]
    token_of, keep, pos = dispatch_indices(gate_idx, capacity, E)
    valid = token_of >= 0
    xe = jnp.where(valid[..., None],
                   x[jnp.where(valid, token_of, 0)], 0.0)           # (E,cap,d)
    ye = expert_glu(xe, w_up, w_down, block_m=block_m, block_f=block_f,
                    interpret=interpret)                            # (E,cap,d)
    # combine: each kept (t, k) adds gate_vals[t,k] * ye[e, pos]
    ye_flat = ye.reshape(E * capacity, d)
    slot = gate_idx * capacity + jnp.minimum(pos, capacity - 1)     # (T,K)
    contrib = ye_flat[slot] * (gate_vals * keep)[..., None].astype(x.dtype)
    return contrib.sum(axis=1).astype(x.dtype)
