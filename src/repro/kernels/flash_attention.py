"""Blockwise causal GQA flash attention — Pallas TPU kernel.

TPU mapping of the attention hot-spot (the paper's GEMM-affinity operator
class): online-softmax over MXU-aligned (block_q x block_k) score tiles,
fp32 accumulators in VMEM scratch, q/k/v streamed HBM->VMEM by BlockSpec.

Grid: (B, Hq, num_q_blocks, num_kv_blocks).  The kv axis is the innermost,
sequential ("arbitrary") dimension; acc/m/l scratch carries across it.  GQA
is handled in the k/v index maps (query head h reads kv head h // group).
Causal skipping: kv blocks strictly above the diagonal are not processed
(@pl.when), which halves compute for causal masks.

The VMEM working set per grid step is
  q (bq x D) + k,v (bk x D each) + acc (bq x Dv, f32) + 2 x (bq x 1)
= 128x128 tiles at bf16 -> well under the ~16 MiB VMEM budget.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                 scale: float, causal: bool, q_offset: int,
                 block_q: int, block_k: int, kv_len: int, num_kv: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q_pos = q_offset + qi * block_q + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 0)
    k_pos = ki * block_k + jax.lax.broadcasted_iota(
        jnp.int32, (block_q, block_k), 1)

    def _process():
        q = q_ref[0, :, 0, :].astype(jnp.float32) * scale       # (bq, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)               # (bk, D)
        v = v_ref[0, :, 0, :].astype(jnp.float32)               # (bk, Dv)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = k_pos < kv_len                                    # kv padding
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]                                      # (bq, 1)
        l_prev = l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)
        l_ref[...] = l_prev * alpha + p.sum(axis=-1, keepdims=True)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    if causal:
        # skip kv blocks strictly above the causal diagonal
        first_q = q_offset + qi * block_q
        needed = ki * block_k <= first_q + block_q - 1

        @pl.when(needed)
        def _():
            _process()
    else:
        _process()

    @pl.when(ki == num_kv - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "q_offset", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = False):
    """q (B,Tq,Hq,D); k/v (B,Tk,Hk,Dk/Dv) with Hq % Hk == 0.

    Returns (B,Tq,Hq,Dv) in q.dtype.  Tq/Tk are padded to the block sizes
    internally; padded kv positions are masked, padded q rows dropped.
    """
    B, Tq, Hq, D = q.shape
    _, Tk, Hk, _ = k.shape
    Dv = v.shape[-1]
    assert Hq % Hk == 0, (Hq, Hk)
    group = Hq // Hk
    scale = 1.0 / math.sqrt(D)

    block_q = min(block_q, max(Tq, 1))
    block_k = min(block_k, max(Tk, 1))
    nq = -(-Tq // block_q)
    nk = -(-Tk // block_k)
    pq, pk = nq * block_q - Tq, nk * block_k - Tk
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pk:
        k = jnp.pad(k, ((0, 0), (0, pk), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pk), (0, 0), (0, 0)))

    kernel = functools.partial(
        _attn_kernel, scale=scale, causal=causal, q_offset=q_offset,
        block_q=block_q, block_k=block_k, kv_len=Tk, num_kv=nk)

    out = pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, qi, ki: (b, qi, h, 0)),
            pl.BlockSpec((1, block_k, 1, D),
                         lambda b, h, qi, ki, g=group: (b, ki, h // g, 0)),
            pl.BlockSpec((1, block_k, 1, Dv),
                         lambda b, h, qi, ki, g=group: (b, ki, h // g, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, Dv),
                               lambda b, h, qi, ki: (b, qi, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nq * block_q, Hq, Dv), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, Dv), jnp.float32),   # acc
            pltpu.VMEM((block_q, 1), jnp.float32),    # running max m
            pltpu.VMEM((block_q, 1), jnp.float32),    # running denom l
        ],
        interpret=interpret,
    )(q, k, v)
    return out[:, :Tq]
