"""Kernel payload variant tables: the Pallas kernels as first-class
per-target op payloads.

Each factory returns a ``{dialect: callable}`` table for one fused-op
payload, with weights/side operands closed over so a single activation
flows through a chain graph (the layer convention: weights are module
state, activations are the dataflow).  Dialects:

* ``"ref"``    — the pure-jnp oracle from :mod:`repro.kernels.ref`
  (bind it as ``op.fn``: the interpreter path and every probe verify
  against it);
* ``"pallas"`` — the Pallas kernel via :mod:`repro.kernels.ops`
  (``interpret=None`` → interpret-mode off-TPU, compiled on TPU);
* ``"numpy"``  — host NumPy, for the host-affine ops the paper maps to
  CPU (eltwise glue, sort) — eager, never jitted.

``bind_variants(op, table)`` installs a table on a
:class:`~repro.core.op.FusedOp` (``fn`` ← ``"ref"``, the rest into
``op.variants``) and records example inputs for measured profiling.
The compiled executor serves ``op.payload_for(target.dialect)`` only
after the cold-run probe against the reference composition — see
:mod:`repro.core.laneprogram`.
"""
from __future__ import annotations

from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp
import numpy as np

from . import ops, ref

PayloadTable = Mapping[str, Callable[..., Any]]


def bind_variants(op, table: PayloadTable,
                  example_inputs: tuple | None = None):
    """Install a payload table on a ``FusedOp``: ``table["ref"]`` becomes
    the reference ``op.fn``, every other dialect goes into
    ``op.variants``; ``example_inputs`` (if given) lands in
    ``op.meta["example_inputs"]`` for the measured profiler."""
    if "ref" not in table:
        raise ValueError("payload table needs a 'ref' entry (the oracle)")
    op.fn = table["ref"]
    op.variants = {k: fn for k, fn in table.items() if k != "ref"}
    if example_inputs is not None:
        op.meta["example_inputs"] = example_inputs
    return op


# ---------------------------------------------------------------------------
# kernel payloads (activation in, activation out; weights closed over)
# ---------------------------------------------------------------------------


def attention_payloads(k, v, *, causal: bool = True, q_offset: int = 0,
                       block_q: int = 64, block_k: int = 64,
                       interpret: bool | None = None) -> dict:
    """Fused attention: activation is the query ``(B, Tq, Hq, D)``; the
    key/value streams (e.g. a decode KV cache) are closed over."""
    def ref_fn(q):
        return ref.attention_ref(q, k, v, causal=causal, q_offset=q_offset)

    def pallas_fn(q):
        return ops.flash_attention(q, k, v, causal=causal, q_offset=q_offset,
                                   block_q=block_q, block_k=block_k,
                                   interpret=interpret)
    return {"ref": ref_fn, "pallas": pallas_fn}


def ssd_payloads(c, b, log_a, *, initial_state=None, chunk: int = 32,
                 interpret: bool | None = None) -> dict:
    """SSD recurrence: activation is the value stream ``(B, T, H, P)``;
    the state/input projections and decay gates are closed over.  Only
    the sequence output flows (the carried state is layer-internal)."""
    def ref_fn(x):
        y, _ = ref.ssd_scan_ref(c, b, x, log_a, initial_state=initial_state)
        return y

    def pallas_fn(x):
        y, _ = ops.ssd_scan(c, b, x, log_a, initial_state=initial_state,
                            chunk=chunk, interpret=interpret)
        return y
    return {"ref": ref_fn, "pallas": pallas_fn}


def moe_payloads(w_gate, w_up, w_down, *, capacity: int, top_k: int = 2,
                 block_m: int = 16, block_f: int = 16,
                 interpret: bool | None = None) -> dict:
    """Routed MoE layer: activation ``(T, d)`` tokens; router + expert
    weights closed over.  Gating (softmax top-k, renormalized) is shared
    jnp code so the dialects differ only in dispatch/combine."""
    def gates(x):
        logits = x.astype(jnp.float32) @ w_gate.astype(jnp.float32)
        gv, gi = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), top_k)
        gv = (gv / gv.sum(-1, keepdims=True)).astype(x.dtype)
        return gi, gv

    def ref_fn(x):
        gi, gv = gates(x)
        return ref.moe_dispatch_combine_ref(x, gi, gv, w_up, w_down,
                                            capacity=capacity)

    def pallas_fn(x):
        gi, gv = gates(x)
        return ops.moe_dispatch_combine(x, gi, gv, w_up, w_down,
                                        capacity=capacity, block_m=block_m,
                                        block_f=block_f, interpret=interpret)
    return {"ref": ref_fn, "pallas": pallas_fn}


# ---------------------------------------------------------------------------
# host-affine payloads (the CPU-mapped glue the paper's Fig. 2 CPU class)
# ---------------------------------------------------------------------------


def eltwise_payloads(scale: float = 1.0) -> dict:
    """Elementwise gate/activation with a NumPy host variant."""
    s32 = np.float32(scale)

    def ref_fn(x):
        return jnp.tanh(x * jnp.asarray(s32))

    def numpy_fn(x):
        return np.tanh(np.asarray(x) * s32)
    return {"ref": ref_fn, "numpy": numpy_fn}


def sort_payloads() -> dict:
    """Shape-preserving full sort of the flattened activation — the
    classic host-affine op (XLA:CPU's variadic sort trails ``np.sort``
    by a wide, stable margin at large N)."""
    def ref_fn(x):
        return jnp.sort(x.reshape(-1)).reshape(x.shape)

    def numpy_fn(x):
        a = np.asarray(x)
        return np.sort(a.reshape(-1)).reshape(a.shape)
    return {"ref": ref_fn, "numpy": numpy_fn}
