"""Mamba-2 chunked SSD scan — Pallas TPU kernel.

This is the TPU adaptation of the paper's CumSum/selective-scan operator
class (Fig. 2: sequential recurrences favour the CPU on the edge SoC
because GPU/NPU MAC datapaths can't express them).  On TPU the same
insight becomes: restructure the recurrence into *chunked* form so the
intra-chunk work is dense (chunk x chunk) / (chunk x N) matmuls on the
MXU and only the inter-chunk state carry is sequential.

Recurrence: S_t = exp(log_a_t) * S_{t-1} + b_t v_t^T;  y_t = c_t^T S_t.

Grid: (B, H, num_chunks); the chunk axis is sequential — the (N x P) state
lives in fp32 VMEM scratch across chunk iterations.  Per chunk:

  intra:  y_intra = ((c b^T) .* L) v     with L[i,j] = exp(cum_i - cum_j), i>=j
  inter:  y_inter = (c .* exp(cum)) S_prev
  carry:  S = exp(tot) * S_prev + (b .* exp(tot - cum))^T v

VMEM working set (chunk=256, N=P=64, f32): c,b,v 3x64KB + L 256KB +
state 16KB — far under budget; chunk up to 512 remains safe.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(c_ref, b_ref, v_ref, la_ref, s0_ref, y_ref, sfin_ref,
                state_ref, *, chunk: int, num_chunks: int, seq_len: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = s0_ref[0, 0].astype(jnp.float32)

    c = c_ref[0, :, 0, :].astype(jnp.float32)            # (C, N)
    b = b_ref[0, :, 0, :].astype(jnp.float32)            # (C, N)
    v = v_ref[0, :, 0, :].astype(jnp.float32)            # (C, P)
    la = la_ref[0, :, 0:1].astype(jnp.float32)           # (C, 1)

    # padded tail positions (t >= seq_len) must not touch the state: force
    # their decay to 0 (identity carry) and their b/v contribution to zero.
    t_pos = ci * chunk + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1), 0)
    valid = t_pos < seq_len
    la = jnp.where(valid, la, 0.0)
    b = jnp.where(valid, b, 0.0)

    cum = jnp.cumsum(la, axis=0)                          # (C, 1)
    tot = cum[chunk - 1:chunk, :]                         # (1, 1)

    # intra-chunk: decay matrix L (C, C), lower-triangular in exp space
    diff = cum - cum.reshape(1, chunk)                    # cum_i - cum_j
    ii = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    diff = jnp.where(ii >= jj, diff, -1e30)
    L = jnp.exp(diff)
    s_intra = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                                  preferred_element_type=jnp.float32) * L
    y = jax.lax.dot_general(s_intra, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: contribution of the carried state
    S_prev = state_ref[...]                               # (N, P) f32
    y += jax.lax.dot_general(c * jnp.exp(cum), S_prev,
                             (((1,), (0,)), ((), ())),
                             preferred_element_type=jnp.float32)

    # state update
    w = jnp.exp(tot - cum)                                # (C, 1)
    chunk_state = jax.lax.dot_general(b * w, v, (((0,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
    state_ref[...] = S_prev * jnp.exp(tot[0, 0]) + chunk_state

    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == num_chunks - 1)
    def _finish():
        sfin_ref[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(c, b, v, log_a, *, initial_state=None, chunk: int = 256,
             interpret: bool = False):
    """c, b: (B,T,H,N); v: (B,T,H,P); log_a: (B,T,H) (<= 0).

    Returns (y (B,T,H,P) in v.dtype, S_final (B,H,N,P) f32).
    T is padded to a chunk multiple internally (pad positions carry the
    state through unchanged).
    """
    B, T, H, N = b.shape
    P = v.shape[-1]
    chunk = min(chunk, max(T, 1))
    nc = -(-T // chunk)
    pad = nc * chunk - T
    if pad:
        zc = ((0, 0), (0, pad), (0, 0), (0, 0))
        c = jnp.pad(c, zc)
        b = jnp.pad(b, zc)
        v = jnp.pad(v, zc)
        log_a = jnp.pad(log_a, ((0, 0), (0, pad), (0, 0)))
    if initial_state is None:
        s0 = jnp.zeros((B, H, N, P), jnp.float32)
    else:
        s0 = initial_state.astype(jnp.float32)

    kernel = functools.partial(_ssd_kernel, chunk=chunk, num_chunks=nc,
                               seq_len=T)
    y, s_final = pl.pallas_call(
        kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, 1, N), lambda bb, h, ci: (bb, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda bb, h, ci: (bb, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1, P), lambda bb, h, ci: (bb, ci, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda bb, h, ci: (bb, ci, h)),
            pl.BlockSpec((1, 1, N, P), lambda bb, h, ci: (bb, h, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda bb, h, ci: (bb, ci, h, 0)),
            pl.BlockSpec((1, 1, N, P), lambda bb, h, ci: (bb, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, nc * chunk, H, P), v.dtype),
            jax.ShapeDtypeStruct((B, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        interpret=interpret,
    )(c, b, v, log_a, s0)
    return y[:, :T], s_final
