"""Public jit'd wrappers for the Pallas kernels.

On the TPU target these dispatch to the compiled kernels; on this CPU
container they run in ``interpret=True`` mode (the kernel body executed
in Python), which is how the sweep tests validate them against ``ref.py``.
``default_interpret()`` picks automatically from the backend.
"""
from __future__ import annotations

import jax

from .flash_attention import flash_attention as _flash_attention
from .moe_gather import (dispatch_indices, expert_glu as _expert_glu,
                         moe_dispatch_combine as _moe_dispatch_combine)
from .ssd_scan import ssd_scan as _ssd_scan


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def flash_attention(q, k, v, *, causal: bool = True, q_offset: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    return _flash_attention(q, k, v, causal=causal, q_offset=q_offset,
                            block_q=block_q, block_k=block_k,
                            interpret=interpret)


def ssd_scan(c, b, v, log_a, *, initial_state=None, chunk: int = 256,
             interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    return _ssd_scan(c, b, v, log_a, initial_state=initial_state,
                     chunk=chunk, interpret=interpret)


def expert_glu(x, w_up, w_down, *, block_m: int = 128, block_f: int = 256,
               interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    return _expert_glu(x, w_up, w_down, block_m=block_m, block_f=block_f,
                       interpret=interpret)


def moe_dispatch_combine(x, gate_idx, gate_vals, w_up, w_down, *,
                         capacity: int, block_m: int = 128,
                         block_f: int = 256, interpret: bool | None = None):
    if interpret is None:
        interpret = default_interpret()
    return _moe_dispatch_combine(x, gate_idx, gate_vals, w_up, w_down,
                                 capacity=capacity, block_m=block_m,
                                 block_f=block_f, interpret=interpret)


__all__ = ["flash_attention", "ssd_scan", "expert_glu",
           "moe_dispatch_combine", "dispatch_indices", "default_interpret"]
