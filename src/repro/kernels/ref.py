"""Pure-jnp oracles for every Pallas kernel in this package.

These are the single source of truth for kernel semantics: the interpret-
mode sweep tests assert each ``pallas_call`` against the matching function
here.  The model zoo (``repro.models.layers``) calls the same math, so a
kernel validated against ref.py is validated against the models too.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# flash attention (causal/full GQA) — mirrors layers.flash_attention_ref
# but in the simplest dense form (the oracle must be obviously correct).
# ---------------------------------------------------------------------------


def attention_ref(q, k, v, *, causal: bool = True, q_offset: int = 0):
    """Dense softmax attention.  q (B,Tq,Hq,D); k/v (B,Tk,Hk,D), Hq%Hk==0.

    fp32 scores/normalizer, output cast back to q.dtype — the numerics
    contract the Pallas kernel implements on the MXU.
    """
    B, Tq, Hq, D = q.shape
    _, Tk, Hk, _ = k.shape
    Dv = v.shape[-1]
    G = Hq // Hk
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, Tq, Hk, G, D).astype(jnp.float32) * scale
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    if causal:
        q_pos = q_offset + jnp.arange(Tq)
        mask = q_pos[:, None] >= jnp.arange(Tk)[None, :]
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, Tq, Hq, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# SSD / chunked gated linear recurrence — sequential-scan oracle
# ---------------------------------------------------------------------------


def ssd_scan_ref(c, b, v, log_a, *, initial_state=None):
    """Sequential oracle: S_t = exp(log_a_t)*S_{t-1} + b_t v_t^T; y_t = c_t^T S_t.

    c, b: (B,T,H,N); v: (B,T,H,P); log_a: (B,T,H).
    Returns (y (B,T,H,P), S_final (B,H,N,P)).  O(T) steps — slow but
    unambiguous; the kernel's chunked algebra must reproduce it.
    """
    B, T, H, N = b.shape
    P = v.shape[-1]
    f32 = jnp.float32

    def step(S, inp):
        c_t, b_t, v_t, la_t = inp
        S = S * jnp.exp(la_t.astype(f32))[..., None, None]
        S = S + jnp.einsum("bhn,bhp->bhnp", b_t.astype(f32), v_t.astype(f32))
        y = jnp.einsum("bhn,bhnp->bhp", c_t.astype(f32), S)
        return S, y

    S0 = (jnp.zeros((B, H, N, P), f32) if initial_state is None
          else initial_state.astype(f32))
    S_final, ys = jax.lax.scan(
        step, S0,
        (c.transpose(1, 0, 2, 3), b.transpose(1, 0, 2, 3),
         v.transpose(1, 0, 2, 3), log_a.transpose(1, 0, 2)))
    return ys.transpose(1, 0, 2, 3).astype(v.dtype), S_final


# ---------------------------------------------------------------------------
# MoE top-k dispatch/combine — dense-loop oracle
# ---------------------------------------------------------------------------


def moe_dispatch_combine_ref(x, gate_idx, gate_vals, w_up, w_down, *,
                             capacity: int):
    """Oracle for the fused MoE expert-apply with capacity dropping.

    x: (T, d) tokens; gate_idx/gate_vals: (T, K); w_up: (E, d, 2F);
    w_down: (E, F, d).  A (token, k) assignment beyond the expert's
    ``capacity`` (in first-come order over the flattened (t, k) stream)
    is dropped.  Returns (T, d) combined expert outputs.
    """
    T, d = x.shape
    K = gate_idx.shape[1]
    E = w_up.shape[0]
    onehot = jax.nn.one_hot(gate_idx, E, dtype=jnp.int32)       # (T,K,E)
    flat = onehot.reshape(T * K, E)
    pos = (jnp.cumsum(flat, axis=0) - flat).reshape(T, K, E)
    pos_tk = (pos * onehot).sum(-1)                              # (T,K)
    keep = pos_tk < capacity

    xf = x.astype(jnp.float32)
    out = jnp.zeros((T, d), jnp.float32)
    for e in range(E):
        h = xf @ w_up[e].astype(jnp.float32)                     # (T, 2F)
        g, u = jnp.split(h, 2, axis=-1)
        y_e = (jax.nn.silu(g) * u) @ w_down[e].astype(jnp.float32)
        w_e = ((gate_idx == e) * keep * gate_vals).sum(-1)       # (T,)
        out = out + y_e * w_e[:, None]
    return out.astype(x.dtype)
