"""Pallas TPU kernels for the compute hot-spots of the model zoo.

The paper (BIDENT) optimizes *scheduling*, not kernels, but its three
CPU-affine operator classes (Fig. 2) map to TPU compute hot-spots that we
restructure MXU-natively (DESIGN.md §5):

* ``flash_attention`` — blockwise causal GQA attention (GEMM class);
* ``ssd_scan``        — chunked Mamba-2/mLSTM recurrence (CumSum class);
* ``moe_gather``      — capacity-padded fused expert GLU (Gather class).

Each kernel is ``pl.pallas_call`` + explicit BlockSpec VMEM tiling with a
jit wrapper in ``ops.py`` and a pure-jnp oracle in ``ref.py``; interpret-
mode sweep tests in ``tests/test_kernels.py`` assert kernel == oracle.
"""
from . import ops, payloads, ref  # noqa: F401
from .ops import (expert_glu, flash_attention, moe_dispatch_combine,  # noqa
                  ssd_scan)
from .payloads import (attention_payloads, bind_variants,  # noqa: F401
                       eltwise_payloads, moe_payloads, sort_payloads,
                       ssd_payloads)
