"""AdamW with sharded states, global-norm clipping, LR schedule, and
optional state-dtype downcast (bf16 m/v for the 100B+ configs — the memory
table in DESIGN.md §6 drives this choice).

No optax in this container; the implementation is ~80 lines of pytree ops
and is what the dry-run lowers, so it must shard cleanly: optimizer states
inherit the parameter PartitionSpecs (ZeRO-style when FSDP is on).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    state_dtype: str = "float32"   # "bfloat16" for the giant configs


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = jnp.minimum(step / max(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    scale = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos
    return cfg.lr * warm * scale


def init_state(cfg: AdamWConfig, params) -> dict:
    dt = jnp.dtype(cfg.state_dtype)
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def apply_updates(cfg: AdamWConfig, params, grads, state) -> tuple[Any, dict, dict]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9)) \
        if cfg.clip_norm else 1.0
    lr = lr_at(cfg, step)
    b1, b2 = cfg.b1, cfg.b2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)
    sdt = jnp.dtype(cfg.state_dtype)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = mu.astype(jnp.float32) * b1 + (1 - b1) * g
        nu32 = nu.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mhat = mu32 / bc1
        nhat = nu32 / bc2
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:   # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return ((p.astype(jnp.float32) - lr * delta).astype(p.dtype),
                mu32.astype(sdt), nu32.astype(sdt))

    out = jax.tree.map(upd, params, grads, state["mu"], state["nu"])
    # unzip the 3-tuples
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda t: isinstance(t, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda t: isinstance(t, tuple))
    new_state = {"mu": new_mu, "nu": new_nu, "step": step}
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
