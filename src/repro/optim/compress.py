"""Top-k gradient compression with error feedback (DESIGN.md §6).

At 1000+-node scale, gradient synchronization over DCN between pods is
the cross-pod bottleneck; magnitude top-k sparsification with an error-
feedback accumulator (Stich et al., "Sparsified SGD with Memory") cuts
the synchronized bytes by 1/k_frac while provably preserving
convergence:

    e_t   <- e_{t-1} + g_t          (accumulate into the residual)
    s_t   <- topk_mask(e_t)         (what gets synchronized)
    e_t   <- e_t - s_t              (what stays local)

The compressed tensor here is materialised densely (mask * values) —
the wire format on a real pod is (indices, values); the *math* (what
the optimizer sees, what the residual carries) is exactly the deployed
algorithm, which is what the correctness tests pin down.

Off by default; enable via ``TrainConfig(compress=CompressionConfig(...))``.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CompressionConfig:
    k_frac: float = 0.1          # fraction of entries synchronized
    min_size: int = 4096         # leaves smaller than this pass through


def init_residual(params) -> Any:
    """Error-feedback accumulators, one per parameter leaf (fp32)."""
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _topk_mask(x: jax.Array, k_frac: float) -> jax.Array:
    """Boolean mask keeping the k largest-magnitude entries of ``x``."""
    n = x.size
    k = max(int(n * k_frac), 1)
    flat = jnp.abs(x.reshape(-1))
    # threshold = k-th largest magnitude; ties keep >= threshold (may pass
    # marginally more than k entries — harmless for error feedback)
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(x) >= thresh) & (thresh > 0)


def compress(cfg: CompressionConfig, grads, residual):
    """(synchronized_grads, new_residual).

    Leaves below ``min_size`` are synchronized exactly (their bytes are
    negligible and biasing tiny norm/bias vectors hurts).
    """
    def one(g, e):
        g32 = g.astype(jnp.float32)
        if g.size < cfg.min_size or cfg.k_frac >= 1.0:
            return g32, jnp.zeros_like(e)
        acc = e + g32
        mask = _topk_mask(acc, cfg.k_frac)
        sent = jnp.where(mask, acc, 0.0)
        return sent, acc - sent

    out = jax.tree.map(one, grads, residual)
    sent = jax.tree.map(lambda t: t[0], out,
                        is_leaf=lambda t: isinstance(t, tuple))
    new_res = jax.tree.map(lambda t: t[1], out,
                           is_leaf=lambda t: isinstance(t, tuple))
    return sent, new_res


def compression_ratio(cfg: CompressionConfig, params) -> float:
    """Fraction of gradient bytes actually synchronized."""
    total = kept = 0
    for p in jax.tree.leaves(params):
        total += p.size
        kept += p.size if p.size < cfg.min_size else int(p.size * cfg.k_frac)
    return kept / max(total, 1)
