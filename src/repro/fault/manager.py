"""Fault-tolerance runtime: heartbeats, straggler mitigation, restart policy.

At 1000+ nodes, node failure is a when, not an if.  The manager wraps the
train loop with three mechanisms:

* **Heartbeats + failure detection** — each host registers a heartbeat per
  step; a host silent for ``failure_timeout`` is declared dead.  On a real
  pod the signal comes from the coordination service (jax.distributed /
  the GKE controller); here the interface is injectable so tests drive it.

* **Straggler mitigation** — per-step wall-clock is tracked in a rolling
  window; a host whose step time exceeds ``straggler_factor`` x the
  cluster median is flagged.  Policy hooks: ``on_straggler`` can trigger
  backup-task dispatch (speculative re-execution of that host's shard) or
  demotion of the host at the next elastic boundary.  Detection is
  always-on; mitigation is pluggable because it is deployment-specific.

* **Checkpoint/restart + elastic rescale** — ``run_with_recovery`` retries
  the step function through ``RecoverableError``; restart reloads the
  latest atomic checkpoint (see ``repro.checkpoint``).  Because
  checkpoints are stored mesh-agnostic, the restarted job may come back
  with a different device count (lost pod) — the trainer rebuilds the mesh
  from ``len(jax.devices())`` and re-shards on restore.

``RecoverableError`` is also the transient-fault vocabulary of the
*inference* execution runtime (:mod:`repro.core.faults` — segment
watchdogs, bounded retry, PU-loss recovery): both runtimes retry through
the same exception type, so a payload/step only needs one way to say
"this failure is transient, re-execute me".
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable

__all__ = ["RecoverableError", "FaultConfig", "HeartbeatTracker",
           "StragglerDetector", "RecoveryStats", "run_with_recovery"]


class RecoverableError(RuntimeError):
    """Raised when a transient/hardware fault should trigger retry or
    checkpoint-restart instead of job death.  Shared vocabulary of the
    train-loop fault manager (this module) and the inference execution
    runtime (:mod:`repro.core.faults`, whose injected
    ``TransientFault`` subclasses this)."""


@dataclasses.dataclass
class FaultConfig:
    failure_timeout: float = 60.0     # s without heartbeat -> dead
    straggler_factor: float = 1.5     # x median step time -> straggler
    straggler_window: int = 20        # rolling window (steps)
    max_restarts: int = 5
    checkpoint_every: int = 100       # steps


class HeartbeatTracker:
    def __init__(self, cfg: FaultConfig, n_hosts: int, clock=time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.last: dict[int, float] = {h: clock() for h in range(n_hosts)}

    def beat(self, host: int, t: float | None = None) -> None:
        self.last[host] = self.clock() if t is None else t

    def dead_hosts(self, now: float | None = None) -> list[int]:
        now = self.clock() if now is None else now
        return [h for h, t in self.last.items()
                if now - t > self.cfg.failure_timeout]


class StragglerDetector:
    def __init__(self, cfg: FaultConfig, n_hosts: int):
        self.cfg = cfg
        self.times: dict[int, collections.deque] = {
            h: collections.deque(maxlen=cfg.straggler_window)
            for h in range(n_hosts)}

    def record(self, host: int, step_time: float) -> None:
        self.times[host].append(step_time)

    def medians(self) -> dict[int, float]:
        out = {}
        for h, dq in self.times.items():
            if dq:
                s = sorted(dq)
                out[h] = s[len(s) // 2]
        return out

    def stragglers(self) -> list[int]:
        med = self.medians()
        if len(med) < 2:
            return []
        cluster = sorted(med.values())[len(med) // 2]
        return [h for h, m in med.items()
                if m > self.cfg.straggler_factor * cluster]


@dataclasses.dataclass
class RecoveryStats:
    restarts: int = 0
    stragglers_flagged: int = 0
    failures_detected: int = 0


def run_with_recovery(step_fn: Callable[[int], None], *,
                      start_step: int,
                      total_steps: int,
                      cfg: FaultConfig,
                      save_fn: Callable[[int], None],
                      restore_fn: Callable[[], int],
                      on_straggler: Callable[[list[int]], None] | None = None,
                      detector: StragglerDetector | None = None,
                      host: int = 0) -> RecoveryStats:
    """Drive ``step_fn`` from start to total with checkpoint/restart.

    ``restore_fn`` reloads the latest checkpoint and returns its step —
    the loop resumes there (exactness is the checkpoint module's
    contract: optimizer state, rng, and the data cursor all round-trip).
    """
    stats = RecoveryStats()
    step = start_step
    while step < total_steps:
        try:
            t0 = time.monotonic()
            step_fn(step)
            if detector is not None:
                detector.record(host, time.monotonic() - t0)
                bad = detector.stragglers()
                if bad:
                    stats.stragglers_flagged += len(bad)
                    if on_straggler is not None:
                        on_straggler(bad)
            step += 1
            if step % cfg.checkpoint_every == 0 or step == total_steps:
                save_fn(step)
        except RecoverableError:
            stats.failures_detected += 1
            stats.restarts += 1
            if stats.restarts > cfg.max_restarts:
                raise
            step = restore_fn()
    return stats
