"""Sharded pytree checkpointing: atomic, manifest-driven, mesh-agnostic.

Design (no orbax in this container; the layout mirrors what orbax does):

* each leaf is saved as one ``.npy`` file keyed by its pytree path;
* a JSON manifest records tree structure, dtypes, shapes, and step —
  written last and atomically (tmp + rename), so a crash mid-save never
  corrupts the latest checkpoint;
* checkpoints are stored *logically unsharded*.  On restore, leaves are
  re-sharded to whatever mesh the new job runs on — this is what makes
  **elastic rescale** work: save on 512 chips, restore on 256 or 1024.
* ``keep_last`` old checkpoints are garbage-collected after a successful
  save (never before).

On a real multi-host pod each host writes only the shards it owns
(``jax.experimental.multihost_utils``); in this single-process container
the gather is a no-op but the code path is the same.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree) -> tuple[list[tuple[str, Any]], Any]:
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in leaves:
        key = "/".join(_path_str(p) for p in path) or "_root"
        out.append((key, leaf))
    return out, treedef


def _path_str(p) -> str:
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save(ckpt_dir: str, step: int, tree, *, keep_last: int = 3,
         extra: dict | None = None) -> str:
    """Atomic checkpoint save; returns the checkpoint path."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp)
    leaves, _ = _flatten(tree)
    manifest = {"step": step, "time": time.time(), "extra": extra or {},
                "leaves": []}
    for key, leaf in leaves:
        arr = np.asarray(jax.device_get(leaf))
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp, fname), arr)
        manifest["leaves"].append({
            "key": key, "file": fname, "shape": list(arr.shape),
            "dtype": str(arr.dtype)})
    # manifest last + atomic rename = crash-safe
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep_last)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for d in os.listdir(ckpt_dir):
        if d.startswith("step_") and not d.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")):
                steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, target_tree, *, step: int | None = None,
            shardings=None) -> tuple[Any, dict]:
    """Restore into the structure of ``target_tree``.

    ``shardings`` (optional pytree of NamedSharding matching target) puts
    each leaf directly on the new mesh — the elastic-rescale path.
    Returns (tree, extra).
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    by_key = {l["key"]: l for l in manifest["leaves"]}

    leaves, treedef = _flatten(target_tree)
    shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                    else [None] * len(leaves))
    out = []
    for (key, ref), shd in zip(leaves, shard_leaves):
        meta = by_key.get(key)
        if meta is None:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = np.load(os.path.join(path, meta["file"]))
        want_shape = tuple(ref.shape) if hasattr(ref, "shape") else arr.shape
        if tuple(arr.shape) != want_shape:
            raise ValueError(f"{key}: shape {arr.shape} != target {want_shape}")
        if shd is not None:
            out.append(jax.device_put(arr, shd))
        else:
            out.append(jnp.asarray(arr, dtype=getattr(ref, "dtype", None)))
    tree = jax.tree_util.tree_unflatten(treedef, out)
    return tree, manifest.get("extra", {})


def _gc(ckpt_dir: str, keep_last: int) -> None:
    steps = sorted(
        int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(ckpt_dir, d, "manifest.json")))
    for s in steps[:-keep_last] if keep_last else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)
