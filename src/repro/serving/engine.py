"""Batched serving engine: prefill + decode with sharded KV/state caches.

``jit_decode_step`` / ``jit_prefill`` are what the dry-run lowers for the
``decode_*`` / ``prefill_*`` shape cells.  The engine's ``generate`` drives
real batched requests for the examples.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from ..models import model as M
from ..sharding import Policy
from ..train.trainer import batch_pspecs, param_shardings

# cache leaf name -> logical axes for its *last* dims (leading stack dims
# padded with None).  kv-head and state-head dims shard over the model
# axis (guarded by divisibility), batch over data(+pod).
_CACHE_AXES: dict[str, tuple] = {
    "k": ("batch", "kv_len", "heads", None),
    "v": ("batch", "kv_len", "heads", None),
    "xk": ("batch", "kv_len", "heads", None),
    "xv": ("batch", "kv_len", "heads", None),
    "c_kv": ("batch", "kv_len", None),
    "k_pe": ("batch", "kv_len", None),
    "ssm": ("batch", "heads", None, None),
    "conv": ("batch", None, "ff"),
    "mlstm": ("batch", "heads", None, None),
    "slstm": ("batch", "heads", None),
    "len": (),
}


def cache_pspecs(policy: Policy, cache_tree) -> Any:
    def spec(path, leaf):
        name = None
        for p in reversed(path):
            if hasattr(p, "key"):
                name = str(p.key)
                break
        axes = _CACHE_AXES.get(name, ())
        ndim = len(leaf.shape)
        ax = axes[-ndim:] if len(axes) > ndim else axes
        ax = (None,) * (ndim - len(ax)) + tuple(ax)
        return policy.param_spec(leaf.shape, ax)
    return jax.tree_util.tree_map_with_path(spec, cache_tree)


def cache_shardings(policy: Policy, cache_tree) -> Any:
    mesh = policy.mesh
    return jax.tree.map(lambda s: NamedSharding(mesh, s),
                        cache_pspecs(policy, cache_tree))


def jit_decode_step(cfg, policy: Policy, params_shapes, cache_shapes,
                    batch_shapes):
    """serve_step: one new token against an existing cache."""
    mesh = policy.mesh
    pshard = param_shardings(policy, params_shapes)
    cshard = cache_shardings(policy, cache_shapes)
    bshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          batch_pspecs(policy, batch_shapes))
    B = _batch_of(batch_shapes)
    lshard = NamedSharding(
        mesh, policy.guarded_spec((B, 1, cfg.vocab), "batch", None, "vocab"))

    def step(params, cache, batch):
        return M.decode_step(cfg, params, cache, batch, policy)

    return jax.jit(step, in_shardings=(pshard, cshard, bshard),
                   out_shardings=(lshard, cshard), donate_argnums=(1,))


def jit_prefill(cfg, policy: Policy, params_shapes, batch_shapes,
                max_len: int):
    mesh = policy.mesh
    pshard = param_shardings(policy, params_shapes)
    bshard = jax.tree.map(lambda s: NamedSharding(mesh, s),
                          batch_pspecs(policy, batch_shapes))
    cache_shapes = jax.eval_shape(
        lambda: M.init_cache(cfg, _batch_of(batch_shapes), max_len))
    cshard = cache_shardings(policy, cache_shapes)
    B = _batch_of(batch_shapes)
    lshard = NamedSharding(
        mesh, policy.guarded_spec((B, 1, cfg.vocab), "batch", None, "vocab"))

    def pre(params, batch):
        return M.prefill(cfg, params, batch, max_len=max_len, shd=policy)

    return jax.jit(pre, in_shardings=(pshard, bshard),
                   out_shardings=(lshard, cshard))


def _batch_of(batch_shapes) -> int:
    leaf = jax.tree.leaves(batch_shapes)[0]
    return leaf.shape[0]


# ---------------------------------------------------------------------------
# simple engine for the examples (greedy decode, CPU-friendly)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Engine:
    cfg: Any
    params: Any
    policy: Policy = dataclasses.field(default_factory=Policy)
    # one trace per distinct (batch, cache) shape signature — the decode
    # step used to be re-wrapped in a fresh ``jax.jit`` on every
    # ``generate`` call, which re-traced and re-compiled the whole step
    # each time; ``decode_trace_counts`` makes the reuse observable
    # (regression-tested: two same-shape generates == one trace)
    decode_trace_counts: dict = dataclasses.field(
        default_factory=dict, repr=False, compare=False)
    _jit_decode: Any = dataclasses.field(
        default=None, repr=False, compare=False)

    def decode_step_fn(self):
        """The engine's single jitted decode step.

        ``jax.jit``'s own cache keys on argument shapes/dtypes, so one
        jitted callable per engine covers every (batch, cache-length)
        combination — new shapes trace once, repeats hit the compile
        cache.
        """
        if self._jit_decode is None:
            def step(params, cache, batch):
                key = (tuple(batch["tokens"].shape),
                       tuple(tuple(getattr(l, "shape", ()))
                             for l in jax.tree.leaves(cache)))
                self.decode_trace_counts[key] = \
                    self.decode_trace_counts.get(key, 0) + 1
                return M.decode_step(self.cfg, params, cache, batch,
                                     self.policy)
            self._jit_decode = jax.jit(step)
        return self._jit_decode

    def generate(self, prompt_tokens, max_new: int = 16,
                 max_len: int | None = None):
        """Greedy batched generation.  prompt_tokens: (B, T) int32."""
        B, T = prompt_tokens.shape
        max_len = max_len or (T + max_new)
        logits, cache = M.prefill(self.cfg, self.params,
                                  {"tokens": prompt_tokens},
                                  max_len=max_len, shd=self.policy)
        outs = []
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        step = self.decode_step_fn()
        for _ in range(max_new):
            outs.append(tok)
            logits, cache = step(self.params, cache, {"tokens": tok})
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
        return jnp.concatenate(outs, axis=1)
