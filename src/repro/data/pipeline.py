"""Deterministic synthetic token pipeline with per-host sharding and an
exactly-resumable cursor.

Real deployments swap ``SyntheticTokenSource`` for a tokenized corpus
reader; everything downstream (sharding, cursor, checkpointing of the data
position) is production behaviour:

* determinism: batch ``i`` is a pure function of (seed, i) — restart-safe
  and independent of worker count;
* per-host sharding: each host materialises only its slice of the global
  batch (``jax.process_index()`` striding), the standard multi-pod input
  path;
* resume: the cursor (= step index) lives in the checkpoint, so restart
  continues the exact token stream.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np


@dataclasses.dataclass
class DataConfig:
    global_batch: int
    seq_len: int
    vocab: int
    seed: int = 0
    # stub-modality inputs (audio/vlm backbones): emit embeddings instead
    embed_dim: int = 0
    encdec: bool = False


class SyntheticTokenSource:
    """Batch i is fully determined by (seed, i)."""

    def __init__(self, cfg: DataConfig, process_index: int | None = None,
                 process_count: int | None = None):
        self.cfg = cfg
        self.pi = jax.process_index() if process_index is None else process_index
        self.pc = jax.process_count() if process_count is None else process_count
        if cfg.global_batch % self.pc:
            raise ValueError("global batch must divide process count")
        self.local_batch = cfg.global_batch // self.pc

    def __call__(self, step: int) -> dict:
        """Local shard of global batch ``step``."""
        c = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([c.seed, step, self.pi]))
        out: dict = {}
        # a Markov-ish stream so the loss actually decreases in examples
        toks = rng.integers(0, c.vocab, (self.local_batch, c.seq_len + 1),
                            dtype=np.int32)
        toks[:, 1::2] = (toks[:, 0:-1:2] * 31 + 7) % c.vocab  # learnable pairs
        if c.embed_dim:
            out["embeds"] = rng.standard_normal(
                (self.local_batch, c.seq_len, c.embed_dim)).astype(np.float32) * 0.1
        if c.encdec or not c.embed_dim:
            out["tokens"] = toks[:, :-1]
        out["labels"] = toks[:, 1:]
        return out

    def checkpoint_state(self, step: int) -> dict:
        return {"step": step, "seed": self.cfg.seed}

    @staticmethod
    def resume_step(state: dict) -> int:
        return int(state["step"])
