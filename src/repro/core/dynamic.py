"""Dynamic operator-level scheduling + intra-PU tile mapping.

The paper's §6 Future Work, implemented:

1. **Dynamic scheduling** — BIDENT's static schedule is optimal for the
   profiled costs, but "thermal throttling reduces PU throughput,
   concurrent system processes compete for memory bandwidth" (§6).
   ``DynamicScheduler`` keeps the offline cost table, folds in a
   lightweight runtime *condition* (per-PU throughput multipliers from
   monitoring), and re-runs the shortest-path search from the next
   unexecuted operator when conditions drift beyond a hysteresis
   threshold.  Re-planning is the same O(N K^2) search — sub-millisecond
   (§3.4) — so remapping never outweighs its own benefit for the
   schedule sizes the paper targets.

2. **Tile-level mapping** — the Intel NPU exposes 6 compute tiles; the
   paper proposes assigning tiles by compute- vs memory-boundedness
   (ops below the roofline ridge get fewer tiles, freeing the rest for
   concurrent ops).  ``tile_split`` implements exactly that allocator
   for a pair of co-scheduled operators on one tiled PU.
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Mapping, Sequence

import numpy as np

from .costmodel import CostEntry, CostTable, PUSpec
from .errors import InfeasibleScheduleError
from .op import FusedOp
from .schedule import SeqSchedule
from .search import solve_sequential
from .workload import Workload


# ---------------------------------------------------------------------------
# runtime conditions
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RuntimeCondition:
    """Per-PU throughput multipliers from runtime monitoring.

    slowdown[pu] = 1.0 means nominal; 2.0 means ops on that PU currently
    take twice their profiled time (thermal throttling, a co-resident
    process, bandwidth pressure).  ``unavailable`` PUs are dropped from
    the table entirely (the paper's compile-failure semantics applied at
    runtime — e.g. a PU claimed by another tenant).
    """

    slowdown: Mapping[str, float] = dataclasses.field(default_factory=dict)
    unavailable: frozenset[str] = frozenset()

    def factor(self, pu: str) -> float:
        return float(self.slowdown.get(pu, 1.0))

    def key(self, pus: Iterable[str]) -> tuple[tuple[str, float | None], ...]:
        """Canonical per-PU scaling tuple over ``pus``: ``(name, factor)``
        with ``None`` marking an unavailable PU.  Two conditions with
        equal keys price every workload identically, which is what the
        orchestrator keys its plan cache on (and diffs to decide which
        PUs' cached plans to invalidate)."""
        return tuple((p, None if p in self.unavailable else self.factor(p))
                     for p in sorted(pus))

    @property
    def nominal(self) -> bool:
        return not self.unavailable and all(
            float(f) == 1.0 for f in self.slowdown.values())

    def lose(self, *pus: str) -> "RuntimeCondition":
        """This condition with ``pus`` additionally unavailable — how a
        permanent mid-run PU loss folds into the session condition
        (``Orchestrator`` recovery: re-plan the remaining ops on the
        surviving PUs)."""
        return RuntimeCondition(
            slowdown=dict(self.slowdown),
            unavailable=frozenset(self.unavailable) | set(pus))

    def restore(self, *pus: str) -> "RuntimeCondition":
        """This condition with ``pus`` available again (and any slowdown
        override on them dropped) — the inverse of :meth:`lose`, how a
        half-open circuit-breaker probe re-admits a quarantined PU into
        the planning table (:mod:`repro.core.health`)."""
        back = set(pus)
        return RuntimeCondition(
            slowdown={p: f for p, f in self.slowdown.items()
                      if p not in back},
            unavailable=frozenset(self.unavailable) - back)


# InfeasibleScheduleError historically lived here; it now sits in
# ``repro.core.errors`` so the concurrent solvers can raise it too
# (``dynamic`` imports ``search``, so ``search`` cannot import us).
# Re-exported for backward compatibility.
__all__ = ["DynamicScheduler", "RuntimeCondition", "InfeasibleScheduleError",
           "RemapEvent", "adjusted_table"]


def adjusted_table(table: CostTable, cond: RuntimeCondition) -> CostTable:
    """Scalar cost table under a runtime condition.

    Oracle/compat helper only: the ``DynamicScheduler`` hot path applies
    conditions as per-PU column scalings on the dense ``Workload`` view
    (``Workload.under_condition``) and never rebuilds a dict table."""
    out = CostTable(list(table.pus))
    for (oi, pu), e in table.items():
        if pu in cond.unavailable:
            continue
        f = cond.factor(pu)
        out.set(oi, pu, CostEntry(kernel=e.kernel * f, dispatch=e.dispatch,
                                  h2d=e.h2d, d2h=e.d2h, power=e.power))
    return out


# ---------------------------------------------------------------------------
# dynamic scheduler
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RemapEvent:
    at_op: int                    # chain position where remapping happened
    reason: str
    old_tail_cost: float          # predicted cost of keeping the old plan
    new_tail_cost: float          # predicted cost of the re-planned tail


class DynamicScheduler:
    """Executes a chain op-by-op, re-planning the *tail* when runtime
    conditions drift.

    Hysteresis: re-plan only when the predicted tail improvement exceeds
    ``replan_threshold`` (relative), so monitoring noise doesn't thrash
    the schedule — the paper's requirement that remapping overhead "not
    negate the latency benefit".

    Runs entirely on the dense ``Workload`` layer: a runtime condition is
    applied as per-PU column scalings on the ``(N, K)`` views
    (``Workload.under_condition``) — O(K) column rescales instead of the
    old per-``on_condition`` dict-table rebuild — and tail evaluation /
    re-planning consume row-sliced views of the same arrays.
    """

    def __init__(self, chain: Sequence[int], ops: Sequence[FusedOp],
                 table: CostTable | None, pus: Mapping[str, PUSpec],
                 objective: str = "latency",
                 replan_threshold: float = 0.05,
                 workload: Workload | None = None):
        if table is None and workload is None:
            raise ValueError(
                "DynamicScheduler needs a CostTable or a prebuilt Workload")
        self.chain = list(chain)
        self.ops = ops
        self.base_table = table
        self.pus = pus
        self.objective = objective
        self.threshold = replan_threshold
        self.workload = workload if workload is not None else Workload.build(
            chain, table, pus, ops=ops)
        self.plan = solve_sequential(self.chain, ops, table, pus, objective,
                                     workload=self.workload)
        self.events: list[RemapEvent] = []

    def _adjusted(self, cond: RuntimeCondition) -> Workload:
        return self.workload.under_condition(cond.slowdown, cond.unavailable)

    def tail_cost(self, pos: int, assignment: Sequence[str],
                  wl: Workload) -> float:
        """Cost of executing chain[pos:] under ``assignment`` and the
        (condition-adjusted) workload ``wl``; +inf when the kept
        assignment is infeasible (e.g. an unavailable PU)."""
        if pos >= len(self.chain):
            return 0.0
        lat, eng = wl.tail(pos).evaluate(list(assignment[pos:]),
                                         allow_infeasible=True)
        return lat if self.objective == "latency" else eng

    def on_condition(self, pos: int, cond: RuntimeCondition,
                     wl_adj: Workload | None = None) -> SeqSchedule:
        """Called between ops: re-plan chain[pos:] if conditions warrant.

        A re-planned schedule carries *real* latency/energy: the stitched
        assignment is re-evaluated on a spliced workload — the
        already-executed prefix priced at the nominal profile, the new
        tail under the current condition — so downstream consumers never
        see NaN placeholders.  Pass ``wl_adj`` to reuse an
        already-adjusted workload for ``cond``.
        """
        if wl_adj is None:
            wl_adj = self._adjusted(cond)
        keep = self.tail_cost(pos, self.plan.assignment, wl_adj)
        tail = self.chain[pos:]
        if not tail:
            return self.plan
        tail_wl = wl_adj.tail(pos)
        try:
            replanned = solve_sequential(tail, self.ops, None, self.pus,
                                         self.objective, workload=tail_wl)
        except ValueError as err:
            raise InfeasibleScheduleError(
                f"re-planning chain[{pos}:] is infeasible under the active "
                f"runtime condition (slowdown={dict(cond.slowdown)}, "
                f"unavailable={sorted(cond.unavailable)}): {err}") from err
        new_cost = (replanned.latency if self.objective == "latency"
                    else replanned.energy)
        if keep == float("inf") or new_cost < keep * (1 - self.threshold):
            self.events.append(RemapEvent(
                at_op=pos,
                reason="unavailable PU" if keep == float("inf")
                else "condition drift",
                old_tail_cost=keep, new_tail_cost=new_cost))
            stitched = (list(self.plan.assignment[:pos])
                        + list(replanned.assignment))
            lat, eng = self.workload.spliced(wl_adj, pos).evaluate(stitched)
            self.plan = SeqSchedule(
                chain=self.chain, assignment=stitched,
                latency=lat, energy=eng, objective=self.objective)
        return self.plan

    def simulate(self, conditions: Mapping[int, RuntimeCondition]) -> float:
        """Execute the whole chain, applying ``conditions[pos]`` when
        reached; returns realised latency (ops run under the condition
        active at their position).

        Raises :class:`InfeasibleScheduleError` (not a bare
        ``IndexError``) when an op has no supported PU under the active
        condition.
        """
        cond = RuntimeCondition()
        wl = self.workload
        d = wl.dense
        total = 0.0
        for pos in range(len(self.chain)):
            if pos in conditions:
                cond = conditions[pos]
                wl = self._adjusted(cond)
                self.on_condition(pos, cond, wl_adj=wl)
                d = wl.dense
            pu = self.plan.assignment[pos]
            j = wl.col(pu)
            if not d.mask[pos, j]:
                raise InfeasibleScheduleError(
                    f"{wl.op_name(pos)} at position {pos} cannot run on "
                    f"{pu} under the active runtime condition "
                    f"(slowdown={dict(cond.slowdown)}, "
                    f"unavailable={sorted(cond.unavailable)})")
            total += float(d.w[pos, j])
            if pos + 1 < len(self.chain):
                jn = wl.col(self.plan.assignment[pos + 1])
                if not d.mask[pos + 1, jn]:
                    sup = np.flatnonzero(d.mask[pos + 1])
                    if len(sup) == 0:
                        raise InfeasibleScheduleError(
                            f"{wl.op_name(pos + 1)} at position {pos + 1} "
                            f"has no supported PU under the active runtime "
                            f"condition (slowdown={dict(cond.slowdown)}, "
                            f"unavailable={sorted(cond.unavailable)}) — "
                            "the schedule cannot make progress")
                    jn = int(sup[0])
                # transition: accelerator-gated H2D of next + D2H of prev
                if jn != j:
                    total += ((float(d.h2d[pos + 1, jn]) if d.acc[jn] else 0.0)
                              + (float(d.d2h[pos, j]) if d.acc[j] else 0.0))
        return total


# ---------------------------------------------------------------------------
# intra-PU tile-level mapping (paper §6, second item)
# ---------------------------------------------------------------------------


def ridge_intensity(pu: PUSpec, dtype_bytes: int = 2) -> float:
    """Roofline ridge point of a PU: FLOPs/byte where compute == memory."""
    return pu.peak_gemm.get(dtype_bytes, pu.peak_gemm[2]) / pu.mem_bw


def tile_split(op_a: FusedOp, op_b: FusedOp, pu: PUSpec,
               n_tiles: int = 6) -> tuple[int, int, float]:
    """Split a tiled PU between two data-independent operators.

    Ops *below* the ridge point (memory-bound) gain little from extra
    tiles (bandwidth is shared); compute-bound ops scale with tiles.
    Returns (tiles_a, tiles_b, makespan) minimizing the pair makespan
    over all integer splits, with:

      t(op, k) = max(flops/(peak * k/n_tiles), bytes/mem_bw)

    i.e. compute scales with the tile share, the shared memory system
    does not — exactly the paper's proposed allocation rule.
    """
    def t(op: FusedOp, k: int) -> float:
        if k == 0:
            return float("inf")
        eff = pu.kind_eff.get(op.kind, pu.kind_eff["other"])
        peak = pu.peak_gemm.get(op.dtype_bytes, pu.peak_gemm[2]) * eff
        t_compute = op.flops / (peak * k / n_tiles)
        t_memory = op.bytes_moved / pu.mem_bw
        return max(t_compute, t_memory)

    best = None
    for ka in range(1, n_tiles):
        mk = max(t(op_a, ka), t(op_b, n_tiles - ka))
        if best is None or mk < best[2]:
            best = (ka, n_tiles - ka, mk)
    return best
