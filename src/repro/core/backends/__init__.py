"""Builtin execution backends, declared as :class:`~repro.core.targets.Target` data.

``default_registry()`` is the front door: the three host backends every
container has (`numpy-eager`, `xla-cpu`, `pallas-interpret`) plus one
auto-discovered target per real JAX device.  Adding a backend is
registering one more ``Target`` value — see ``builtin.py`` for the
factories and :mod:`repro.core.targets` for the contract.
"""
from .builtin import (default_registry, device_target, discover_devices,
                      numpy_eager, pallas_interpret, xla_cpu)

__all__ = [
    "default_registry", "device_target", "discover_devices",
    "numpy_eager", "pallas_interpret", "xla_cpu",
]
