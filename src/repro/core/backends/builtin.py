"""Factories for the builtin targets.

Every factory returns a plain :class:`~repro.core.targets.Target` value;
keyword overrides pass straight through, so a caller can re-declare any
pricing field without subclassing anything:

    reg = default_registry()
    reg.register(xla_cpu(name="xla-cpu-lowlat", dispatch_s=5e-6), replace=False)

The three host backends exist in every container:

* ``numpy-eager``     — eager host execution, never jitted; serves the
  ``"numpy"`` dialect of an op's variant table (falling back to the
  reference ``fn``).  Models the paper's plain-CPU lane: minimal
  dispatch, no device handoff cost on its own side.
* ``xla-cpu``         — the reference payloads under ``jax.jit`` (the
  compiled path's bitwise-gated fast lane).
* ``pallas-interpret``— serves the ``"pallas"`` dialect (the Pallas
  kernels in interpret mode), tolerance-gated against the reference
  oracle per the blockwise-accumulation buckets in ``targets.VARIANT_TOL``.

``discover_devices()`` adds one jitted ``ref``-dialect target per real
``jax.devices()`` entry (``cpu:0``, ``tpu:0``, ...), device-pinned via
``Target.device``; non-CPU platforms are priced as accelerators.
"""
from __future__ import annotations

import logging
from typing import Any

from ..targets import Target, TargetRegistry

_log = logging.getLogger(__name__)


def numpy_eager(**overrides: Any) -> Target:
    kw: dict[str, Any] = dict(
        name="numpy-eager", kind="host", dialect="numpy", jit=False,
        is_accelerator=False, dispatch_s=3e-6, handoff_s=0.0,
        power_compute=15.0, power_memory=11.0)
    kw.update(overrides)
    return Target(**kw)


def xla_cpu(**overrides: Any) -> Target:
    # atol/rtol declare the jit-probe tolerance: XLA fusion reorders f32
    # accumulation, so eager-vs-jit is rarely bitwise for softmax/einsum
    # compositions — without a declared tolerance the probe would reject
    # the jit and serve the ~100x slower eager composition, which is not
    # what "the jitted reference lane" means.  handoff_s is deliberately
    # conservative (1 ms): leaving a fused XLA segment forfeits fusion
    # that the per-op cost cells cannot see, so a lane switch must earn
    # a wide measured margin before the planner takes it.
    kw: dict[str, Any] = dict(
        name="xla-cpu", kind="cpu", dialect="ref", jit=True,
        is_accelerator=True, dispatch_s=2e-5, handoff_s=1e-3,
        power_compute=17.0, power_memory=12.0, atol=1e-5, rtol=1e-5)
    kw.update(overrides)
    return Target(**kw)


def pallas_interpret(**overrides: Any) -> Target:
    kw: dict[str, Any] = dict(
        name="pallas-interpret", kind="interpret", dialect="pallas",
        jit=True, interpret=True, is_accelerator=True, dispatch_s=5e-5,
        handoff_s=1e-3, power_compute=20.0, power_memory=12.0)
    kw.update(overrides)
    return Target(**kw)


def device_target(dev: Any, **overrides: Any) -> Target:
    """A jitted reference-dialect target pinned to one JAX device."""
    platform = getattr(dev, "platform", "cpu")
    kw: dict[str, Any] = dict(
        name=f"{platform}:{getattr(dev, 'id', 0)}", kind=platform,
        dialect="ref", jit=True, device=dev,
        is_accelerator=platform != "cpu",
        dispatch_s=2e-5, handoff_s=1e-3 if platform != "cpu" else 5e-4,
        atol=1e-5, rtol=1e-5,
        meta={"device_kind": getattr(dev, "device_kind", platform)})
    kw.update(overrides)
    return Target(**kw)


def discover_devices() -> list[Target]:
    """One target per real ``jax.devices()`` entry (empty when jax or the
    runtime backend is unavailable — discovery must never fail import)."""
    try:
        import jax
        devices = jax.devices()
    except Exception as e:  # pragma: no cover - jax is baked in here
        _log.warning("device discovery failed: %s", e)
        return []
    return [device_target(d) for d in devices]


def default_registry(*, devices: bool = True) -> TargetRegistry:
    """The builtin target set: `numpy-eager` + `xla-cpu` +
    `pallas-interpret`, plus (``devices=True``) every real JAX device."""
    reg = TargetRegistry([numpy_eager(), xla_cpu(), pallas_interpret()])
    if devices:
        for t in discover_devices():
            if t.name not in reg:
                reg.register(t)
    return reg
