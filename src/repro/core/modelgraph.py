"""Analytic fused-operator graphs for the assigned architectures.

``model_op_graph(cfg, ...)`` expands a model config into the fused-operator
DAG at the granularity the paper profiles (Table 1 "fused ops"): one op per
GEMM / attention / recurrence / router / norm-act cluster, with exact
operand shapes.  This feeds both execution modes:

* EdgeSoC mode — cost the ops on CPU/GPU/NPU (paper reproduction on the
  model zoo's own architectures);
* TPU autoshard mode — cost the ops under sharding strategies
  (``core.autoshard``), per (arch x shape) cell.

MoE layers emit a fork/join phase: the shared-expert branch and the routed
branch are data-independent (paper §3.2.2 branches); the enc-dec archs emit
encoder and decoder towers that the multi-model concurrent scheduler can
co-schedule.
"""
from __future__ import annotations

from typing import Sequence

from .op import FusedOp, OpGraph


def _mm(name: str, batch_tokens: int, d_in: int, d_out: int, dtb: int) -> FusedOp:
    return FusedOp(name=name, kind="matmul",
                   in_shapes=((batch_tokens, d_in), (d_in, d_out)),
                   out_shape=(batch_tokens, d_out), dtype_bytes=dtb)


def _norm(name: str, batch_tokens: int, d: int, dtb: int) -> FusedOp:
    return FusedOp(name=name, kind="norm", in_shapes=((batch_tokens, d),),
                   out_shape=(batch_tokens, d), dtype_bytes=dtb)


def _act(name: str, batch_tokens: int, d: int, dtb: int) -> FusedOp:
    return FusedOp(name=name, kind="act", in_shapes=((batch_tokens, d),),
                   out_shape=(batch_tokens, d), dtype_bytes=dtb)


def _attn(name: str, B: int, H: int, Tq: int, Tk: int, dh: int, dtb: int) -> FusedOp:
    op = FusedOp(name=name, kind="attention",
                 in_shapes=((B, H, Tq, dh), (B, H, Tk, dh)),
                 out_shape=(B, H, Tq, dh), dtype_bytes=dtb)
    # q read + K AND V read (the KV-cache stream that dominates decode) + out
    op.bytes_moved = float(dtb * B * H * (Tq * dh + 2 * Tk * dh + Tq * dh))
    return op


def _scan(name: str, B: int, T: int, H: int, N: int, P: int, dtb: int) -> FusedOp:
    # recurrent state update: flops ~ T x H x N x P MACs (x2) + gating
    op = FusedOp(name=name, kind="scan",
                 in_shapes=((B, T, H, N), (B, T, H, P)),
                 out_shape=(B, T, H, P), dtype_bytes=dtb)
    op.flops = 4.0 * B * T * H * N * P
    return op


def model_op_graph(cfg, *, kind: str = "train", batch: int = 8,
                   seq: int = 2048) -> OpGraph:
    """Fused-op DAG for one forward pass of ``cfg`` at (batch, seq).

    kind: "train"/"prefill" = full-sequence forward; "decode" = one token
    against a cache of ``seq`` (Tk = seq, Tq = 1).
    """
    dtb = 2 if cfg.dtype == "bfloat16" else 4
    B = batch
    Tq = 1 if kind == "decode" else seq
    Tk = seq
    NT = B * Tq                       # tokens processed this step
    d = cfg.d_model

    ops: list[FusedOp] = []
    edges: list[tuple[int, int]] = []
    tail: int | None = None           # index of the op new ops chain onto

    def add(op: FusedOp, after: int | Sequence[int] | None = "tail") -> int:
        nonlocal tail
        idx = len(ops)
        ops.append(op)
        if after == "tail":
            if tail is not None:
                edges.append((tail, idx))
        elif after is None:
            pass
        else:
            for a in (after if isinstance(after, (list, tuple)) else [after]):
                edges.append((a, idx))
        tail = idx
        return idx

    # embedding lookup
    add(FusedOp(name="embed", kind="embed",
                in_shapes=((cfg.vocab, d), (NT,)), out_shape=(NT, d),
                dtype_bytes=dtb))

    def gqa_layer(i: int, prefix: str = "") -> None:
        nonlocal tail
        add(_norm(f"{prefix}L{i}.ln1", NT, d, dtb))
        qkv = cfg.n_heads * cfg.d_head + 2 * cfg.n_kv_heads * cfg.d_head
        add(_mm(f"{prefix}L{i}.qkv", NT, d, qkv, dtb))
        add(_attn(f"{prefix}L{i}.attn", B, cfg.n_heads, Tq, Tk, cfg.d_head, dtb))
        add(_mm(f"{prefix}L{i}.o", NT, cfg.n_heads * cfg.d_head, d, dtb))

    def mla_layer(i: int) -> None:
        add(_norm(f"L{i}.ln1", NT, d, dtb))
        add(_mm(f"L{i}.q_a", NT, d, cfg.q_lora_rank, dtb))
        add(_mm(f"L{i}.q_b", NT, cfg.q_lora_rank,
                cfg.n_heads * (cfg.qk_nope_head_dim + cfg.qk_rope_head_dim), dtb))
        add(_mm(f"L{i}.kv_a", NT, d, cfg.kv_lora_rank + cfg.qk_rope_head_dim, dtb))
        add(_mm(f"L{i}.kv_b", NT, cfg.kv_lora_rank,
                cfg.n_heads * (cfg.qk_nope_head_dim + cfg.v_head_dim), dtb))
        add(_attn(f"L{i}.attn", B, cfg.n_heads, Tq, Tk,
                  cfg.qk_nope_head_dim + cfg.qk_rope_head_dim, dtb))
        add(_mm(f"L{i}.o", NT, cfg.n_heads * cfg.v_head_dim, d, dtb))

    def dense_mlp(i: int, prefix: str = "") -> None:
        add(_norm(f"{prefix}L{i}.ln2", NT, d, dtb))
        add(_mm(f"{prefix}L{i}.mlp_up", NT, d, 2 * cfg.d_ff, dtb))
        add(_act(f"{prefix}L{i}.mlp_act", NT, cfg.d_ff, dtb))
        add(_mm(f"{prefix}L{i}.mlp_down", NT, cfg.d_ff, d, dtb))

    def moe_mlp(i: int) -> None:
        """Router -> fork(routed branch || shared branch) -> join."""
        nonlocal tail
        add(_norm(f"L{i}.ln2", NT, d, dtb))
        fork = add(_mm(f"L{i}.router", NT, d, cfg.n_experts, 4))
        # routed branch: dispatch gather, expert GEMMs (active experts
        # only: top-k of tokens), combine scatter
        ff = cfg.moe_d_ff
        tok_k = NT * cfg.moe_top_k
        disp = add(FusedOp(name=f"L{i}.dispatch", kind="gather",
                           in_shapes=((NT, d), (tok_k,)),
                           out_shape=(tok_k, d), dtype_bytes=dtb), after=fork)
        add(_mm(f"L{i}.exp_up", tok_k, d, 2 * ff, dtb))
        add(_act(f"L{i}.exp_act", tok_k, ff, dtb))
        add(_mm(f"L{i}.exp_down", tok_k, ff, d, dtb))
        comb = add(FusedOp(name=f"L{i}.combine", kind="scatter",
                           in_shapes=((tok_k, d), (tok_k,)),
                           out_shape=(NT, d), dtype_bytes=dtb))
        join_srcs = [comb]
        if cfg.n_shared_experts:
            sh_up = add(_mm(f"L{i}.shared_up", NT, d,
                            2 * ff * cfg.n_shared_experts, dtb), after=fork)
            add(_act(f"L{i}.shared_act", NT, ff * cfg.n_shared_experts, dtb))
            sh_dn = add(_mm(f"L{i}.shared_down", NT,
                            ff * cfg.n_shared_experts, d, dtb))
            join_srcs.append(sh_dn)
        add(FusedOp(name=f"L{i}.moe_add", kind="add",
                    in_shapes=((NT, d),) * 2, out_shape=(NT, d),
                    dtype_bytes=dtb), after=join_srcs)

    def mamba_layer(i: int) -> None:
        di, H, N = cfg.ssm_d_inner, cfg.ssm_heads, cfg.ssm_state
        P = di // H
        conv_dim = di + 2 * N * cfg.ssm_groups
        add(_norm(f"L{i}.ln1", NT, d, dtb))
        add(_mm(f"L{i}.in_proj", NT, d, 2 * di + 2 * N * cfg.ssm_groups
                + cfg.ssm_heads, dtb))
        add(FusedOp(name=f"L{i}.conv", kind="dwconv",
                    in_shapes=((B, Tq, conv_dim), (conv_dim, 1, cfg.ssm_conv, 1)),
                    out_shape=(B, Tq, conv_dim), dtype_bytes=dtb))
        add(_scan(f"L{i}.ssd", B, Tq, H, N, P, dtb))
        add(_norm(f"L{i}.gate_norm", NT, di, dtb))
        add(_mm(f"L{i}.out_proj", NT, di, d, dtb))

    def xlstm_pair(i: int) -> None:
        di = cfg.xlstm_d_inner
        H = cfg.n_heads
        dh = di // H
        add(_norm(f"L{i}.ln_m", NT, d, dtb))
        add(_mm(f"L{i}.m_up", NT, d, 2 * di, dtb))
        add(_mm(f"L{i}.m_qkv", NT, di, 3 * di, dtb))
        add(_scan(f"L{i}.mlstm", B, Tq, H, dh, dh + 1, dtb))
        add(_mm(f"L{i}.m_down", NT, di, d, dtb))
        add(_norm(f"L{i}.ln_s", NT, d, dtb))
        add(_mm(f"L{i}.s_in", NT, d, 4 * d, dtb))
        add(_scan(f"L{i}.slstm", B, Tq, H, d // H, d // H, dtb))
        add(_mm(f"L{i}.s_ff_up", NT, d, 2 * cfg.slstm_ff, dtb))
        add(_mm(f"L{i}.s_ff_down", NT, cfg.slstm_ff, d, dtb))

    bp = cfg.block_pattern
    if bp in ("dense", "moe"):
        for i in range(cfg.n_layers):
            gqa_layer(i)
            if bp == "moe":
                moe_mlp(i)
            else:
                dense_mlp(i)
    elif bp == "mla_moe":
        for i in range(cfg.n_layers):
            mla_layer(i)
            if i < cfg.first_k_dense:
                dense_mlp(i)
            else:
                moe_mlp(i)
    elif bp == "encdec":
        # encoder tower feeds decoder cross-attention; decoder self-attn
        # and encoder run as two towers joined at cross-attn (fork at embed)
        enc_T = seq
        enc_NT = B * enc_T
        root = tail
        enc_tail = root
        for i in range(cfg.n_enc_layers):
            tail_save = tail
            # encoder ops chain from enc_tail
            if i == 0:
                pass
            gqa_layer(i, prefix="enc.")
            dense_mlp(i, prefix="enc.")
        enc_end = tail
        for i in range(cfg.n_dec_layers):
            gqa_layer(i, prefix="dec.")
            add(_mm(f"dec.L{i}.xq", NT, d, cfg.n_heads * cfg.d_head, dtb))
            add(_attn(f"dec.L{i}.xattn", B, cfg.n_heads, Tq, enc_T,
                      cfg.d_head, dtb))
            add(_mm(f"dec.L{i}.xo", NT, cfg.n_heads * cfg.d_head, d, dtb))
            dense_mlp(i, prefix="dec.")
    elif bp == "xlstm":
        for i in range(cfg.n_layers // 2):
            xlstm_pair(i)
    elif bp == "zamba2":
        for i in range(cfg.n_layers):
            mamba_layer(i)
            if (i + 1) % cfg.zamba_attn_every == 0:
                gqa_layer(i, prefix="shared.")
    else:
        raise ValueError(bp)

    add(_norm("final_norm", NT, d, dtb))
    # prefill emits last-position logits only (cf. models.model.prefill)
    head_tokens = B if kind == "prefill" else NT
    add(_mm("lm_head", head_tokens, d, cfg.vocab, dtb))
    # terminal fused reduction: the CE loss (train) / argmax sample (decode)
    # fuses with the head matmul in XLA, so the inter-op tensor leaving the
    # head is (tokens, 1) — per-token NLL or sampled ids — NOT the full
    # logits.  Modeling it as a separate op with the fused-away input keeps
    # the exit D2H physical (gathering 260 GB of logits is not a thing any
    # real system does).
    add(FusedOp(name="loss" if kind == "train" else "sample", kind="add",
                in_shapes=((head_tokens, 1),), out_shape=(head_tokens, 1),
                dtype_bytes=4))
    return OpGraph(ops, edges=edges)


def kernel_chain(*, blocks: int = 1, batch: int = 1, seq: int = 64,
                 heads: int = 2, head_dim: int = 16, state: int = 8,
                 experts: int = 4, moe_ff: int = 16, top_k: int = 2,
                 chunk: int = 32, block_q: int = 32, block_k: int = 32,
                 block_m: int = 16, block_f: int = 16, seed: int = 0,
                 interpret: bool | None = None):
    """Kernel-backed zoo chain: a runnable OpGraph whose ops carry real
    payload variant tables (``op.fn`` = jnp oracle, ``op.variants`` =
    {"pallas": ..., "numpy": ...}) so lanes bound to different targets
    execute genuinely different code for the same op.

    Each block is attention -> act -> SSD scan -> sort -> MoE -> act on a
    ``(batch, seq, heads, head_dim)`` float32 activation: the three Pallas
    hot-spots interleaved with the host-affine glue the paper maps to CPU
    (Fig. 2 classes).  Returns ``(graph, external_inputs)`` ready for
    ``ScheduleExecutor`` / per-target ``MeasuredProfiler``
    (``meta["example_inputs"]`` is set on every op).

    Lazy-imports jax so plain analytic use of this module stays
    numpy-only.
    """
    import jax
    import jax.numpy as jnp

    from ..kernels import payloads as kp

    B, T, H, D = batch, seq, heads, head_dim
    d_model = H * D
    tokens = B * T
    act_shape = (B, T, H, D)
    cap = -((-tokens * top_k) // experts)         # ceil
    capacity = max(block_m, -(-cap // 8) * 8)     # >= block_m, mult of 8

    keys = iter(jax.random.split(jax.random.PRNGKey(seed), 8 * blocks + 1))

    def rnd(shape, scale=1.0):
        return (scale * jax.random.normal(next(keys), shape)
                ).astype(jnp.float32)

    x0 = rnd(act_shape)
    ops: list[FusedOp] = []
    example = {}

    def add(name, kind, table, wrap=None):
        op = FusedOp(name=name, kind=kind, in_shapes=(act_shape,),
                     out_shape=act_shape, dtype_bytes=4)
        if wrap is not None:
            table = {k: wrap(fn) for k, fn in table.items()}
        kp.bind_variants(op, table, example_inputs=(x0,))
        ops.append(op)
        return op

    for j in range(blocks):
        kv_k = rnd((B, T, H, D), 0.5)
        kv_v = rnd((B, T, H, D), 0.5)
        add(f"b{j}.attn", "attention",
            kp.attention_payloads(kv_k, kv_v, causal=True,
                                  block_q=min(block_q, T),
                                  block_k=min(block_k, T),
                                  interpret=interpret))
        add(f"b{j}.gate", "act", kp.eltwise_payloads(1.0 + 0.25 * j))
        ssd_c = rnd((B, T, H, state), 0.5)
        ssd_b = rnd((B, T, H, state), 0.5)
        log_a = -0.05 * jnp.abs(rnd((B, T, H)))
        add(f"b{j}.ssd", "scan",
            kp.ssd_payloads(ssd_c, ssd_b, log_a, chunk=min(chunk, T),
                            interpret=interpret))
        add(f"b{j}.sort", "gather", kp.sort_payloads())
        w_gate = rnd((d_model, experts), 0.5)
        w_up = rnd((experts, d_model, 2 * moe_ff), 0.5)
        w_down = rnd((experts, moe_ff, d_model), 0.5)

        def tokenized(fn):
            def run(x):
                y = fn(x.reshape(tokens, d_model))
                return y.reshape(act_shape)
            return run

        add(f"b{j}.moe", "gather",
            kp.moe_payloads(w_gate, w_up, w_down, capacity=capacity,
                            top_k=top_k, block_m=block_m, block_f=block_f,
                            interpret=interpret),
            wrap=tokenized)
        add(f"b{j}.out", "act", kp.eltwise_payloads(0.5))

    example[0] = (x0,)
    return OpGraph(ops), example
