"""BIDENT core: operator-level orchestration as shortest-path search.

The paper's primary contribution, mode-agnostic over two cost providers:
the EdgeSoC CPU/GPU/NPU models (faithful reproduction) and the TPU
sharding-strategy roofline (``repro.core.autoshard``, the beyond-paper
system).

The documented front door is ``Orchestrator`` (register → plan →
execute, with plan caching and online admission); the per-regime
``solve_*`` free functions remain the stable low-level layer it routes
to.
"""
from .contention import (ContentionModel, DEFAULT_MM_SF, GroupCostCache,
                         PairCostCache, uses_default_coexec,
                         uses_default_group)
from .errors import InfeasibleScheduleError
from .costmodel import (CPU, GPU, NPU, EDGE_PUS, DEFAULT_SF, CostEntry,
                        CostTable, DenseCostTable, EdgeSoCCostModel, PUSpec,
                        transition_cost)
from .dynamic import DynamicScheduler, RuntimeCondition
from .errors import (ExecutionError, ExecutionTimeoutError,
                     FaultRetryExceededError, PULostError)
from .executor import ScheduleExecutor
from .faults import (CHAOS_KINDS, ChaosEvent, ChaosTrace, DEFAULT_POLICY,
                     ExecutionPolicy, FaultPlan, FaultSpec, TransientFault)
from .health import (BreakerTransition, HealthMonitor, HealthPolicy,
                     TargetHealth)
from .laneprogram import LaneProgram, compile_lane_program, results_bitwise_equal
from .graph import (DenseChain, ExecGraph, build_dense_chain,
                    build_sequential_graph)
from .op import Branch, FusedOp, OpGraph, Phase, chain_graph
from .orchestrator import Orchestrator, Plan
from .profiler import (AnalyticProfiler, MeasuredProfiler, Measurement,
                       measure_callable, measure_callable_stats,
                       trace_fused_ops)
from .schedule import (ConcurrentSchedule, ConcurrentStep, DagSchedule,
                       DagStep, ParallelSchedule,
                       SeqSchedule, evaluate_sequential,
                       evaluate_sequential_reference, schedule_from_dict,
                       schedule_to_dict, single_pu_cost)
from .search import (ConcurrentCaches, DAG_ALGORITHMS,
                     DEFAULT_HORIZON_STATES,
                     DEFAULT_MAX_STATES, IncrementalConcurrentSolver,
                     dijkstra, sequential_dp, sequential_dp_reference,
                     solve_concurrent, solve_concurrent_aligned,
                     solve_concurrent_aligned_reference,
                     solve_concurrent_horizon,
                     solve_concurrent_joint, solve_concurrent_joint_reference,
                     solve_dag, solve_parallel, solve_sequential)
from .serve import (Arrival, ArrivalTrace, RequestRecord, SHED_REASONS,
                    ServeReport, ServingEngine)
from .targets import (Target, TargetRegistry, pu_specs_for_targets,
                      resolve_targets, variant_tolerance)
from .workload import Workload
from . import autoshard, backends, modelgraph, paperzoo  # noqa: F401

__all__ = [
    "ContentionModel", "DEFAULT_MM_SF", "GroupCostCache", "PairCostCache",
    "uses_default_coexec", "uses_default_group", "CPU", "GPU", "NPU",
    "EDGE_PUS", "DEFAULT_SF", "CostEntry", "CostTable", "DenseCostTable",
    "DynamicScheduler", "EdgeSoCCostModel", "InfeasibleScheduleError",
    "ExecutionError", "ExecutionTimeoutError", "FaultRetryExceededError",
    "PULostError", "DEFAULT_POLICY", "ExecutionPolicy", "FaultPlan",
    "FaultSpec", "TransientFault", "CHAOS_KINDS", "ChaosEvent", "ChaosTrace",
    "BreakerTransition", "HealthMonitor", "HealthPolicy", "TargetHealth",
    "Orchestrator", "PUSpec",
    "Plan", "RuntimeCondition", "Workload", "DEFAULT_MAX_STATES",
    "transition_cost", "ScheduleExecutor", "LaneProgram",
    "compile_lane_program", "results_bitwise_equal",
    "DenseChain", "ExecGraph",
    "build_dense_chain", "build_sequential_graph", "Branch", "FusedOp",
    "OpGraph", "Phase",
    "chain_graph", "AnalyticProfiler", "MeasuredProfiler", "Measurement",
    "measure_callable", "measure_callable_stats",
    "Target", "TargetRegistry", "pu_specs_for_targets", "resolve_targets",
    "variant_tolerance",
    "trace_fused_ops", "ConcurrentSchedule",
    "ConcurrentStep", "DagSchedule", "DagStep", "DAG_ALGORITHMS",
    "solve_dag", "ParallelSchedule", "SeqSchedule",
    "evaluate_sequential", "evaluate_sequential_reference",
    "schedule_from_dict", "schedule_to_dict",
    "single_pu_cost", "dijkstra", "sequential_dp", "sequential_dp_reference",
    "ConcurrentCaches", "DEFAULT_HORIZON_STATES",
    "IncrementalConcurrentSolver",
    "solve_concurrent", "solve_concurrent_aligned",
    "solve_concurrent_aligned_reference", "solve_concurrent_horizon",
    "solve_concurrent_joint", "solve_concurrent_joint_reference",
    "solve_parallel", "solve_sequential",
    "Arrival", "ArrivalTrace", "RequestRecord", "SHED_REASONS",
    "ServeReport", "ServingEngine",
]
