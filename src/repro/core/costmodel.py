"""Per-PU cost models and the (operator, PU) cost table.

Two cost providers share one ``CostTable`` interface:

* ``EdgeSoCCostModel`` — analytic models of the paper's three PUs (CPU /
  GPU / NPU on an Intel Core Ultra-class SoC), calibrated so that the
  paper's motivating measurements hold:

    - Fig. 2 operator affinity: GPU fastest for MatMul (2.8x vs CPU, 1.6x
      vs NPU) and Conv2D (2.2x / 1.1x); CPU fastest for DWConv, Add, RDFT,
      CumSum, Gather with NPU penalties of 4.7x / 8.7x / 4.1x on the
      non-GEMM trio.
    - Fig. 3 MatMul size sweep: FP16 CPU fastest through N=64, GPU
      crosses at N=128 and widens to ~4.8x at N=2048; INT8 CPU leads
      through N=128, GPU crosses at N=256, NPU overtakes GPU only at
      N=2048 (MAC-array utilisation saturation).
    - Power ordering under GEMM load: GPU > CPU > NPU (paper §4.2).

* ``repro.core.autoshard.ShardingCostModel`` — TPU mode: "PUs" are sharding
  strategies; node costs come from the v5e roofline. (separate module)

The measured-profiling path (``repro.core.profiler``) fills the same
``CostTable`` from wall-clock timings instead.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterable, Mapping, Sequence

import numpy as np

from .op import FusedOp, OpGraph

# ---------------------------------------------------------------------------
# Cost table
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CostEntry:
    """Profiled cost of one fused operator on one PU (paper §3.1)."""

    kernel: float      # kernel execution time (s)
    dispatch: float    # kernel dispatch / submit time (s)
    h2d: float         # host-to-device availability cost (s)
    d2h: float         # device-to-host availability cost (s)
    power: float       # sustained power during execution (W)

    @property
    def w(self) -> float:
        """Node weight: dispatch + execution (paper §3.2.1)."""
        return self.dispatch + self.kernel

    @property
    def energy(self) -> float:
        return self.w * self.power


class CostTable:
    """(op index, pu name) -> CostEntry; missing entry == unsupported."""

    def __init__(self, pus: Sequence[str]):
        self.pus: list[str] = list(pus)
        self._t: dict[tuple[int, str], CostEntry] = {}
        # free-form provenance metadata attached by the producer, e.g.
        # MeasuredProfiler records per-op measurement failures under
        # ``meta["profile_failures"]`` instead of swallowing them
        self.meta: dict = {}

    def set(self, op_idx: int, pu: str, entry: CostEntry) -> None:
        if pu not in self.pus:
            raise KeyError(f"unknown PU {pu!r}")
        self._t[(op_idx, pu)] = entry

    def get(self, op_idx: int, pu: str) -> CostEntry | None:
        return self._t.get((op_idx, pu))

    def supported(self, op_idx: int, pu: str) -> bool:
        return (op_idx, pu) in self._t

    def supported_pus(self, op_idx: int) -> list[str]:
        return [p for p in self.pus if (op_idx, p) in self._t]

    def items(self):
        """Iterate ((op_idx, pu), entry) over all populated cells."""
        return self._t.items()

    def require(self, op_idx: int, pu: str) -> CostEntry:
        e = self.get(op_idx, pu)
        if e is None:
            raise KeyError(f"op {op_idx} unsupported on {pu}")
        return e


# ---------------------------------------------------------------------------
# Dense (vectorized) cost-table view
# ---------------------------------------------------------------------------


class DenseCostTable:
    """Vectorized ``(N, K)`` view of a ``CostTable`` along an op chain.

    Built once per chain and shared by the vectorized DP / A* solvers.
    Row ``i`` is chain position ``i`` (op index ``chain[i]``); column ``k``
    is ``table.pus[k]``.  Unsupported (op, PU) slots hold ``inf`` in the
    cost arrays (``w``, ``energy``) so that NumPy ``min``/``argmin`` route
    around them exactly like the sparse search routes around missing
    entries, and ``0`` in the auxiliary arrays (``power``, ``h2d``,
    ``d2h``) so no ``inf * 0`` NaNs can arise in transition algebra.

    ``sig`` assigns every row a signature id: rows with identical
    (w, power, support) vectors share an id, which is what lets the
    concurrent solvers memoize the ``(K0, K1)`` pair-cost matrices per
    op-kind/PU signature instead of per chain position.
    """

    def __init__(self, pus: Sequence[str], chain: Sequence[int],
                 mask: np.ndarray, w: np.ndarray, power: np.ndarray,
                 h2d: np.ndarray, d2h: np.ndarray, acc: np.ndarray,
                 dispatch: np.ndarray | None = None):
        self.pus = list(pus)
        self.chain = list(chain)
        self.mask = mask            # (N, K) bool
        self.w = w                  # (N, K); inf where unsupported
        self.power = power          # (N, K); 0 where unsupported
        self.h2d = h2d              # (N, K); 0 where unsupported
        self.d2h = d2h              # (N, K); 0 where unsupported
        self.acc = acc              # (K,) bool: PU is an accelerator
        # (N, K) dispatch share of w; 0 where unsupported.  Kept separate
        # so runtime conditions can scale the *kernel* share (w - dispatch)
        # without rebuilding the table (see workload.Workload.under_condition).
        self.dispatch = (dispatch if dispatch is not None
                         else np.zeros_like(power))
        with np.errstate(invalid="ignore"):  # inf * 0 at unsupported slots
            self.energy = w * power          # (N, K)
        self.energy[~mask] = np.inf
        self._sig: np.ndarray | None = None
        self._sig_row: np.ndarray | None = None

    def _build_sigs(self) -> None:
        # pair-cost matrices depend only on (w, power, support); one
        # vectorized unique over the stacked rows (id order is opaque)
        stacked = np.concatenate(
            [self.w, self.power, self.mask.astype(np.float64)], axis=1)
        _, first, inv = np.unique(stacked, axis=0, return_index=True,
                                  return_inverse=True)
        self._sig = inv.reshape(-1).astype(np.int64)
        self._sig_row = first.astype(np.int64)

    @property
    def sig(self) -> np.ndarray:
        """(N,) signature id per row; equal-id rows have identical
        (w, power, support) vectors.  Computed lazily — the sequential
        solvers never need it."""
        if self._sig is None:
            self._build_sigs()
        return self._sig

    @property
    def sig_row(self) -> np.ndarray:
        """(n_sig,) a representative row index per signature id."""
        if self._sig_row is None:
            self._build_sigs()
        return self._sig_row

    @property
    def n_sig(self) -> int:
        return len(self.sig_row)

    @property
    def n(self) -> int:
        return len(self.chain)

    @property
    def k(self) -> int:
        return len(self.pus)

    @classmethod
    def from_chain(cls, chain: Sequence[int], table: CostTable,
                   pus: Mapping[str, "PUSpec"]) -> "DenseCostTable":
        n, k = len(chain), len(table.pus)
        mask = np.zeros((n, k), dtype=bool)
        w = np.full((n, k), np.inf)
        power = np.zeros((n, k))
        h2d = np.zeros((n, k))
        d2h = np.zeros((n, k))
        disp = np.zeros((n, k))
        pos_of: dict[int, list[int]] = {}
        for i, oi in enumerate(chain):
            pos_of.setdefault(oi, []).append(i)
        col = {pu: j for j, pu in enumerate(table.pus)}
        # single pass over populated cells (vs N*K speculative lookups)
        for (oi, pu), e in table.items():
            rows = pos_of.get(oi)
            if not rows:
                continue
            j = col[pu]
            ww, pw, hh, dd = e.dispatch + e.kernel, e.power, e.h2d, e.d2h
            for i in rows:
                mask[i, j] = True
                w[i, j] = ww
                power[i, j] = pw
                h2d[i, j] = hh
                d2h[i, j] = dd
                disp[i, j] = e.dispatch
        acc = np.array([pus[p].is_accelerator for p in table.pus], dtype=bool)
        return cls(table.pus, chain, mask, w, power, h2d, d2h, acc,
                   dispatch=disp)

    def require_row(self, pos: int, what: str = "op") -> None:
        if not self.mask[pos].any():
            raise ValueError(
                f"{what} {self.chain[pos]} unsupported on all PUs")


# ---------------------------------------------------------------------------
# Edge SoC PU models
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class PUSpec:
    """Analytic model of one processing unit."""

    name: str
    is_accelerator: bool
    dispatch_s: float                  # fixed per-kernel dispatch latency
    mem_bw: float                      # effective streaming bandwidth (B/s)
    # peak compute (FLOP/s) per (kind-class, dtype): see _eff_flops
    peak_gemm: Mapping[int, float]     # dtype_bytes -> peak FLOP/s
    # MAC-array / SIMT pipeline-fill constant per dtype (FLOPs).  Applies to
    # GEMM-datapath kinds only: t_compute = (flops + sat) / (peak * eff).
    # This is what makes the NPU win INT8 GEMM only at N=2048 (Fig. 3b).
    sat_flops: Mapping[int, float]
    kind_eff: Mapping[str, float]      # relative efficiency per op kind
    kind_bw_eff: Mapping[str, float]   # bandwidth efficiency per op kind
    h2d_base: float                    # fixed H2D cost (cache/IOMMU/DMA setup)
    h2d_bw: float                      # H2D per-byte bandwidth (B/s)
    power_compute: float               # package W when compute-bound
    power_memory: float                # package W when memory-bound
    # cache-spill knee (FLOPs) for GEMM kinds: effective peak degrades as
    # peak / (1 + flops/knee).  Models the CPU's LLC falling out of reuse
    # at large GEMMs — the paper's Fig. 3a CPU gap widening from 2.8x at
    # N=1024 to 4.8x at N=2048.  Empty = no spill (accelerators).
    spill_flops: Mapping[int, float] = dataclasses.field(default_factory=dict)

    def h2d(self, nbytes: float) -> float:
        if not self.is_accelerator:
            return 0.0
        return self.h2d_base + nbytes / self.h2d_bw

    d2h = h2d  # symmetric (paper §3.1)


def _mk(name, **kw) -> PUSpec:
    return PUSpec(name=name, **kw)


# Op kinds that run on the MAC/MXU datapath (pipeline-fill ramp applies).
GEMM_KINDS = ("matmul", "conv2d", "attention")

# Calibrated PU set (see module docstring for the calibration targets).
CPU = _mk(
    "CPU", is_accelerator=False, dispatch_s=3e-6, mem_bw=55e9,
    # AMX/VNNI-class GEMM throughput with an LLC spill knee: Fig. 3a's CPU
    # gap widens 2.8x (N=1024) -> 4.8x (N=2048) as reuse falls out of cache
    peak_gemm={2: 0.675e12, 1: 0.95e12}, sat_flops={2: 0.0, 1: 0.0},
    spill_flops={2: 19.7e9, 1: 39e9},
    kind_eff={
        "matmul": 1.0, "conv2d": 1.16, "dwconv": 0.80, "attention": 0.9,
        "rdft": 0.55, "cumsum": 0.35, "gather": 0.30, "scatter": 0.30,
        "scan": 0.35, "embed": 0.35, "norm": 0.6, "softmax": 0.6,
        "act": 0.7, "add": 0.7, "mul": 0.7, "other": 0.5, "transfer": 1.0,
    },
    kind_bw_eff={
        "gather": 0.75, "scatter": 0.70, "embed": 0.75, "cumsum": 0.85,
        "scan": 0.85, "rdft": 0.8, "dwconv": 0.85, "add": 0.95, "mul": 0.95,
        "norm": 0.9, "softmax": 0.9, "act": 0.95,
    },
    h2d_base=0.0, h2d_bw=60e9, power_compute=17.0, power_memory=12.0,
)

GPU = _mk(
    "GPU", is_accelerator=True, dispatch_s=5e-6, mem_bw=95e9,
    peak_gemm={2: 1.75e12, 1: 2.30e12}, sat_flops={2: 2.0e6, 1: 2.0e6},
    kind_eff={
        "matmul": 1.0, "conv2d": 0.95, "dwconv": 0.35, "attention": 0.95,
        "rdft": 0.10, "cumsum": 0.02, "gather": 0.10, "scatter": 0.10,
        "scan": 0.02, "embed": 0.10, "norm": 0.5, "softmax": 0.55,
        "act": 0.6, "add": 0.6, "mul": 0.6, "other": 0.3, "transfer": 1.0,
    },
    kind_bw_eff={
        "gather": 0.30, "scatter": 0.28, "embed": 0.30, "cumsum": 0.05,
        "scan": 0.05, "rdft": 0.35, "dwconv": 0.5, "add": 0.6, "mul": 0.6,
        "norm": 0.6, "softmax": 0.6, "act": 0.6,
    },
    # unified memory: H2D = cache flush + IOMMU walk, not a PCIe copy
    h2d_base=5e-6, h2d_bw=120e9, power_compute=28.0, power_memory=18.0,
)

NPU = _mk(
    "NPU", is_accelerator=True, dispatch_s=45e-6, mem_bw=68e9,
    peak_gemm={2: 1.10e12, 1: 4.0e12}, sat_flops={2: 0.8e8, 1: 8.0e9},
    kind_eff={
        "matmul": 1.0, "conv2d": 1.49, "dwconv": 0.50, "attention": 0.85,
        "rdft": 0.075, "cumsum": 0.008, "gather": 0.04, "scatter": 0.04,
        "scan": 0.008, "embed": 0.04, "norm": 0.35, "softmax": 0.35,
        "act": 0.45, "add": 0.5, "mul": 0.5, "other": 0.1, "transfer": 1.0,
    },
    kind_bw_eff={
        "gather": 0.15, "scatter": 0.14, "embed": 0.15, "cumsum": 0.080,
        "scan": 0.080, "rdft": 0.10, "dwconv": 0.6, "add": 0.75, "mul": 0.75,
        "norm": 0.6, "softmax": 0.6, "act": 0.7,
    },
    h2d_base=10e-6, h2d_bw=80e9, power_compute=9.0, power_memory=7.5,
)

EDGE_PUS: dict[str, PUSpec] = {p.name: p for p in (CPU, GPU, NPU)}

# Paper §3.2.2: measured cross-PU slowdown factors SF(P_run, P_interfere).
# NPU is most sensitive (1.17x with CPU active, 1.09x with GPU active);
# CPU and GPU show negligible cross-PU interference with each other, and
# slightly more when the NPU's DMA bursts hit the shared DRAM — this
# ordering is what makes GPU||CPU the consistently-best pair assignment
# in Fig. 4.
DEFAULT_SF: dict[tuple[str, str], float] = {
    ("NPU", "CPU"): 1.17, ("NPU", "GPU"): 1.09,
    ("CPU", "NPU"): 1.03, ("CPU", "GPU"): 1.01,
    ("GPU", "NPU"): 1.03, ("GPU", "CPU"): 1.01,
    ("CPU", "CPU"): 1.0, ("GPU", "GPU"): 1.0, ("NPU", "NPU"): 1.0,
}

# Package static/uncore power (W): drawn for the whole execution window
# regardless of which PUs are active.  This is what makes *shorter
# makespans* save energy in concurrent scheduling (paper Fig. 8's 48.2%
# average concurrent energy reduction) — the SoC's base power integrates
# over wall-clock time.
STATIC_POWER_W = 6.0


class EdgeSoCCostModel:
    """Analytic cost provider for the paper's CPU/GPU/NPU SoC."""

    def __init__(self, pus: Mapping[str, PUSpec] | None = None):
        self.pus: dict[str, PUSpec] = dict(pus or EDGE_PUS)

    # -- per-op costing ------------------------------------------------------
    def _t_compute(self, op: FusedOp, pu: PUSpec) -> float:
        peak = pu.peak_gemm.get(op.dtype_bytes, pu.peak_gemm[2])
        eff = pu.kind_eff.get(op.kind, pu.kind_eff["other"])
        sat = 0.0
        if op.kind in GEMM_KINDS:
            sat = pu.sat_flops.get(op.dtype_bytes, 0.0)
            knee = pu.spill_flops.get(op.dtype_bytes, 0.0)
            if knee:
                peak = peak / (1.0 + op.flops / knee)
        return (op.flops + sat) / max(peak * eff, 1.0)

    def kernel_time(self, op: FusedOp, pu: PUSpec) -> float:
        """Roofline time: max(compute term, memory term)."""
        t_compute = self._t_compute(op, pu)
        bw_eff = pu.kind_bw_eff.get(op.kind, 1.0)
        t_memory = op.bytes_moved / (pu.mem_bw * bw_eff)
        return max(t_compute, t_memory)

    def entry(self, op: FusedOp, pu: PUSpec) -> CostEntry | None:
        unsupported = op.meta.get("unsupported_on", ())
        if pu.name in unsupported:
            return None  # compile failure -> omitted from table (paper §3.1)
        k = self.kernel_time(op, pu)
        # Power depends on boundedness: compute-bound draws more.
        t_compute = self._t_compute(op, pu)
        frac_compute = min(t_compute / k, 1.0) if k > 0 else 0.0
        power = pu.power_memory + (pu.power_compute - pu.power_memory) * frac_compute
        return CostEntry(
            kernel=k,
            dispatch=pu.dispatch_s,
            h2d=pu.h2d(op.in_bytes),
            d2h=pu.d2h(op.out_bytes),
            power=power,
        )

    def build_table(self, graph: OpGraph) -> CostTable:
        table = CostTable(list(self.pus))
        for i, op in enumerate(graph.ops):
            for name, pu in self.pus.items():
                e = self.entry(op, pu)
                if e is not None:
                    table.set(i, name, e)
        return table

    # -- transition costs (paper §3.2.1 edge rule) --------------------------
    def transition(self, table: CostTable, prev_op: int, prev_pu: str,
                   next_op: int, next_pu: str) -> float:
        return transition_cost(self.pus, table, prev_op, prev_pu, next_op, next_pu)


def transition_cost(pus: Mapping[str, PUSpec], table: CostTable,
                    prev_op: int, prev_pu: str, next_op: int, next_pu: str) -> float:
    """Paper §3.2.1: zero if same PU; else H2D(O_next, P_next) when P_next is
    an accelerator, plus D2H(O_prev, P_prev) for accelerator->accelerator or
    accelerator->CPU transitions."""
    if prev_pu == next_pu:
        return 0.0
    cost = 0.0
    if pus[next_pu].is_accelerator:
        cost += table.require(next_op, next_pu).h2d
    if pus[prev_pu].is_accelerator:
        cost += table.require(prev_op, prev_pu).d2h
    return cost


# ---------------------------------------------------------------------------
# Helpers to build representative operators (used by Fig. 2/3/4 benchmarks)
# ---------------------------------------------------------------------------


def make_matmul(n: int, dtype_bytes: int = 2, batch: int = 1, name: str | None = None) -> FusedOp:
    return FusedOp(
        name=name or f"matmul{n}", kind="matmul",
        in_shapes=((batch, n, n), (n, n)), out_shape=(batch, n, n),
        dtype_bytes=dtype_bytes,
    )


def make_conv2d(c_in: int = 64, c_out: int = 64, hw: int = 56, k: int = 3,
                dtype_bytes: int = 2, name: str | None = None) -> FusedOp:
    return FusedOp(
        name=name or "conv2d", kind="conv2d",
        in_shapes=((1, c_in, hw, hw), (c_out, c_in, k, k)),
        out_shape=(1, c_out, hw, hw), dtype_bytes=dtype_bytes,
    )


def make_dwconv(c: int = 128, hw: int = 56, k: int = 3, dtype_bytes: int = 2) -> FusedOp:
    return FusedOp(
        name="dwconv", kind="dwconv",
        in_shapes=((1, c, hw, hw), (c, 1, k, k)),
        out_shape=(1, c, hw, hw), dtype_bytes=dtype_bytes,
    )


def make_eltwise(kind: str, numel: int, dtype_bytes: int = 2) -> FusedOp:
    return FusedOp(name=kind, kind=kind, in_shapes=((numel,), (numel,)) if kind in ("add", "mul") else ((numel,),),
                   out_shape=(numel,), dtype_bytes=dtype_bytes)


def make_rdft(n: int = 1024, ch: int = 512, dtype_bytes: int = 2) -> FusedOp:
    return FusedOp(name="rdft", kind="rdft", in_shapes=((1, ch, n),),
                   out_shape=(1, ch, n // 2 + 1, 2), dtype_bytes=dtype_bytes)


def make_cumsum(n: int = 4096, ch: int = 256, dtype_bytes: int = 2) -> FusedOp:
    return FusedOp(name="cumsum", kind="cumsum", in_shapes=((1, ch, n),),
                   out_shape=(1, ch, n), dtype_bytes=dtype_bytes)


def make_gather(rows: int = 65536, dim: int = 64, idx: int = 8192, dtype_bytes: int = 2) -> FusedOp:
    return FusedOp(name="gather", kind="gather", in_shapes=((rows, dim), (idx,)),
                   out_shape=(idx, dim), dtype_bytes=dtype_bytes)


FIG2_OPS: dict[str, FusedOp] = {
    "MatMul": make_matmul(1024),
    "Conv2D": make_conv2d(128, 128, 56, 3),
    "DWConv": make_dwconv(64, 28, 3),
    "Add": make_eltwise("add", 1 * 64 * 28 * 28),
    "RDFT": make_rdft(1024, 512),
    "CumSum": make_cumsum(4096, 256),
    "Gather": make_gather(65536, 64, 8192),
}
