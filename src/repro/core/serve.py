"""Streaming serving engine: an async front end over the Orchestrator.

The orchestrator exposes the online-admission API (``admit`` /
``advance`` / ``retire`` / ``replan_active``); this module is the
traffic loop that drives it at load — the difference between a paper
artifact and a scheduler that serves requests (ROADMAP item 1).

* :class:`ArrivalTrace` — reproducible request streams: ``poisson``
  (memoryless arrivals at a target rate) and ``bursty`` (Poisson
  background plus clustered bursts, the hard case for admission).
* :class:`ServingEngine` — an asyncio event loop feeding the
  orchestrator: continuous admission into a bounded concurrent set,
  **bounded re-plan latency** via windowed warm re-plans
  (``horizon_states``; every admit/advance/retire event costs one
  O(budget) incremental solve, never a full-grid re-solve), per-request
  SLO deadlines with optimistic-bound shedding, and graceful shedding of
  requests a re-plan proves infeasible
  (:class:`~repro.core.errors.InfeasibleScheduleError`) instead of
  taking the serving loop down.
* :class:`ServeReport` — sustained throughput, p50/p99 *plan* latency
  (wall-clock re-plan cost, the scheduler's own overhead) and p50/p99
  *request* latency (virtual queueing + execution time), plus the
  warm/cold re-plan split from ``orchestrator.stats``.

Two execution modes share the loop:

* ``execution="virtual"`` (default) — a planned :class:`ConcurrentStep`
  "runs" by advancing the virtual clock by its cost-model latency and
  recording progress via ``advance`` — the same discrete-event
  convention as the cost-model benchmarks, so the loop exercises the
  full planning path at thousands of requests without burning hours of
  wall clock.  Re-plan latencies are the real wall-clock cost of the
  plan calls.

* ``execution="real"`` — advance events come from *completed execution*:
  at every boundary the loop carves the next window of planned steps
  (up to the arrival horizon or the first request completion), executes
  it through the fault runtime (``ScheduleExecutor.run_concurrent`` on
  the interpreter oracle, or compiled :class:`LaneProgram` segments
  with ``compile_exec=True``), and only then advances the orchestrator
  and the virtual clock by what actually finished.  The virtual clock
  still sequences arrivals/SLOs — it is the serving timeline chaos
  scripts (:class:`~repro.core.faults.ChaosTrace`) and breaker
  cooldowns run on.  A per-target :class:`~repro.core.health.
  HealthMonitor` watches every window: transient faults retry in-loop,
  a degrading PU trips its circuit breaker and is quarantined via
  ``Orchestrator.on_condition`` (warm-re-planning the entire active set
  on the survivors), a half-open probe re-admits it on observed
  success, and unrecoverable requests are shed with a typed reason
  (:data:`SHED_REASONS`) — never a hang, and never a silent wrong
  answer: every completed request's outputs are checked bitwise against
  a fault-free solo run (``RequestRecord.bitwise_ok``).
"""
from __future__ import annotations

import asyncio
import dataclasses
import json
import time
from typing import Mapping, Sequence

import numpy as np

from .errors import (ExecutionTimeoutError, FaultRetryExceededError,
                     InfeasibleScheduleError, PULostError)
from .faults import ChaosTrace, ExecutionPolicy, FaultPlan
from .health import HealthMonitor, HealthPolicy
from .laneprogram import results_bitwise_equal
from .op import FusedOp, OpGraph, chain_graph
from .orchestrator import Orchestrator, Plan
from .schedule import ConcurrentSchedule
from .search import DEFAULT_HORIZON_STATES

# the typed shed vocabulary: every shed request carries exactly one
#   slo        — the optimistic remaining-work bound misses the deadline
#   infeasible — no available PU supports some remaining op
#   timeout    — a window kept exceeding the watchdog budget past the
#                in-loop retry allowance
#   fault      — a fault persisted through every retry and could be
#                pinned on this request
SHED_REASONS = ("slo", "infeasible", "timeout", "fault")


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One request arrival: which model, when (virtual seconds), and an
    optional absolute SLO budget in virtual seconds (``None`` defers to
    the engine's ``slo_factor`` policy, if any)."""
    rid: int
    model: str
    time: float
    slo: float | None = None


@dataclasses.dataclass
class ArrivalTrace:
    """A reproducible arrival stream (sorted by time)."""
    arrivals: list[Arrival]
    kind: str = "custom"

    def __post_init__(self) -> None:
        self.arrivals = sorted(self.arrivals, key=lambda a: a.time)

    def __len__(self) -> int:
        return len(self.arrivals)

    def to_json(self) -> str:
        """Serialize the exact stream (floats round-trip via repr): a
        failing serving run ships as a replayable artifact, not a
        seed + generator-version pair."""
        return json.dumps({
            "kind": self.kind,
            "arrivals": [dataclasses.asdict(a) for a in self.arrivals]})

    @classmethod
    def from_json(cls, s: str) -> "ArrivalTrace":
        d = json.loads(s)
        return cls(arrivals=[Arrival(**a) for a in d["arrivals"]],
                   kind=d.get("kind", "custom"))

    @classmethod
    def poisson(cls, models: Sequence[str], rate: float, n: int,
                seed: int = 0, slo: float | None = None) -> "ArrivalTrace":
        """``n`` arrivals with Exp(``rate``) inter-arrival gaps, models
        drawn uniformly — the classic open-loop load model."""
        if rate <= 0 or n < 0:
            raise ValueError(f"poisson: need rate > 0 and n >= 0, got "
                             f"rate={rate}, n={n}")
        rng = np.random.default_rng(seed)
        ts = np.cumsum(rng.exponential(1.0 / rate, size=n))
        picks = rng.integers(0, len(models), size=n)
        return cls([Arrival(i, models[int(picks[i])], float(ts[i]), slo)
                    for i in range(n)], kind="poisson")

    @classmethod
    def bursty(cls, models: Sequence[str], rate: float, n: int,
               burst_every: int = 5, burst_size: int = 3,
               burst_span: float = 1e-3, seed: int = 0,
               slo: float | None = None) -> "ArrivalTrace":
        """Poisson background where every ``burst_every``-th arrival
        brings ``burst_size - 1`` near-simultaneous companions (within
        ``burst_span`` virtual seconds) — clustered admissions that
        stress bounded re-plan latency."""
        base = cls.poisson(models, rate, n, seed=seed, slo=slo)
        rng = np.random.default_rng(seed + 1)
        out = list(base.arrivals)
        rid = n
        for k, a in enumerate(base.arrivals):
            if burst_every and k % burst_every == 0:
                for j in range(burst_size - 1):
                    out.append(Arrival(
                        rid, models[int(rng.integers(0, len(models)))],
                        a.time + float(rng.uniform(0, burst_span)), slo))
                    rid += 1
        return cls(out, kind="bursty")


@dataclasses.dataclass
class RequestRecord:
    """Lifecycle record of one served (or shed) request."""
    rid: int
    model: str
    arrival: float
    deadline: float | None
    ops_total: int
    ops_done: int = 0
    handle: int | None = None
    admitted_at: float | None = None
    finished_at: float | None = None
    shed: bool = False
    shed_reason: str = ""          # one of SHED_REASONS when shed
    # real-execution bookkeeping
    retries: int = 0               # window re-executions touching this req
    recovered: bool = False        # survived at least one fault recovery
    bitwise_ok: bool | None = None  # outputs == fault-free solo run
    results: dict = dataclasses.field(default_factory=dict, repr=False)

    @property
    def latency(self) -> float | None:
        """Virtual arrival→completion latency (queueing + execution)."""
        if self.finished_at is None:
            return None
        return self.finished_at - self.arrival


def _pct(xs: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


@dataclasses.dataclass
class ServeReport:
    """What a serving run sustained, and what it cost to plan it.

    The availability block (``recovered`` … ``breaker``) is populated by
    real-execution runs: recovery latency is the wall-clock cost from
    catching a fault to a successful warm re-plan of the active set, and
    ``breaker`` carries the :class:`~repro.core.health.HealthMonitor`
    stats including the full breaker-transition log.  ``cache`` is the
    over-the-run delta of ``Orchestrator.cache_stats()`` (LRU evictions
    + ``ConcurrentCaches`` trims), so cache-pressure-induced slowdowns
    show up in serving output."""
    n_requests: int
    completed: int
    shed: int
    makespan: float               # virtual seconds, first arrival -> drain
    throughput: float             # completed requests / virtual second
    latency_p50: float            # virtual request latency percentiles
    latency_p99: float
    plan_ms_p50: float            # wall-clock re-plan latency percentiles
    plan_ms_p99: float
    plan_events: int
    replans_warm: int
    replans_cold: int
    occupancy_mean: float         # time-weighted mean concurrent set size
    # availability accounting (real-execution runs)
    recovered: int = 0            # completed despite >= 1 fault recovery
    retried: int = 0              # window re-executions
    recoveries: int = 0           # fault -> re-plan recovery cycles
    recovery_ms_p50: float = 0.0  # wall-clock fault -> re-planned
    recovery_ms_p99: float = 0.0
    shed_reasons: dict = dataclasses.field(default_factory=dict)
    bitwise_checked: int = 0      # completions verified vs solo reference
    bitwise_failures: int = 0     # MUST stay 0: silent-wrong-answer count
    exec_wall_s: float = 0.0      # wall clock spent really executing
    breaker: dict = dataclasses.field(default_factory=dict)
    cache: dict = dataclasses.field(default_factory=dict)
    requests: list[RequestRecord] = dataclasses.field(
        default_factory=list, repr=False)

    def to_dict(self) -> dict:
        # not dataclasses.asdict: that would deep-copy every request's
        # results payloads just to drop them
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self) if f.name != "requests"}


class ServingEngine:
    """Continuous-admission serving loop over one :class:`Orchestrator`.

    ``models`` maps model names to their inference graphs (or bare op
    sequences); each is registered once and cloned per concurrent
    in-flight request through handle aliasing (``register(graph,
    table=...)`` always issues a fresh handle, so two in-flight requests
    of the same model hold distinct admission slots; finished handles
    return to a per-model free pool, keeping the registration count
    bounded by peak concurrency).

    The loop is an asyncio pipeline — a producer task feeding arrivals
    into a queue, the scheduler task draining it — with virtual-time
    execution (see module docstring).  Every membership or progress
    boundary costs exactly one windowed warm re-plan of at most
    ``horizon_states`` grid states, so admission latency stays bounded
    no matter how much work is in flight.  ``max_concurrent`` bounds the
    co-scheduled set (grid width); excess arrivals queue FIFO.

    Shedding keeps the loop alive instead of failing a whole run:

    * **SLO**: a request whose optimistic remaining-work bound (suffix
      sum of per-op best-PU costs) can no longer meet its deadline is
      shed at admission or at the next re-plan boundary.
    * **Infeasibility**: when a re-plan raises
      :class:`InfeasibleScheduleError` (e.g. a condition change left an
      op with no supporting PU), the offending requests are shed and the
      survivors re-planned.
    * **Degradation** (``execution="real"``): a window that keeps timing
      out is shed ``"timeout"``; a fault that survives every retry and
      names a request sheds exactly that request ``"fault"``; a PU whose
      breaker opens is quarantined and the active set warm-re-planned on
      the survivors (see module docstring).

    Real-execution knobs: ``inputs`` maps model name → ``{op index:
    args tuple}`` external inputs (shared by every request of the
    model); ``exec_policy`` is the per-window watchdog/retry policy;
    ``health_policy`` tunes the breaker; ``max_window_retries`` bounds
    in-loop re-execution of a failed window before shedding;
    ``compile_exec=True`` executes windows as compiled
    :class:`~repro.core.laneprogram.LaneProgram` segments instead of the
    per-op interpreter (same bitwise guarantee — jit is probe-verified).
    """

    def __init__(self, orch: Orchestrator,
                 models: Mapping[str, OpGraph | Sequence[FusedOp]],
                 objective: str = "latency",
                 horizon_states: int | None = DEFAULT_HORIZON_STATES,
                 max_concurrent: int = 3,
                 slo_factor: float | None = None,
                 execution: str = "virtual",
                 inputs: Mapping[str, Mapping[int, tuple]] | None = None,
                 exec_policy: ExecutionPolicy | None = None,
                 health_policy: HealthPolicy | None = None,
                 max_window_retries: int = 2,
                 compile_exec: bool = False):
        if not models:
            raise ValueError("ServingEngine needs at least one model")
        if max_concurrent < 1:
            raise ValueError(
                f"max_concurrent must be >= 1, got {max_concurrent}")
        if execution not in ("virtual", "real"):
            raise ValueError(
                f"execution must be 'virtual' or 'real', got {execution!r}")
        self.orch = orch
        self.objective = objective
        self.horizon_states = horizon_states
        self.max_concurrent = max_concurrent
        self.slo_factor = slo_factor
        self.execution = execution
        self.exec_policy = exec_policy
        self.health_policy = health_policy
        self.max_window_retries = max_window_retries
        self.compile_exec = compile_exec
        self.health: HealthMonitor | None = None   # set per serve() run
        self._inputs: dict[str, dict] = {
            m: dict(v) for m, v in (inputs or {}).items()}
        self._refs: dict[str, dict] = {}  # model -> fault-free solo results
        self._graphs: dict[str, OpGraph] = {}
        self._base: dict[str, int] = {}       # model -> provider handle
        self._tables: dict[str, object] = {}  # model -> profiled CostTable
        self._free: dict[str, list[int]] = {}  # model -> reusable handles
        self._bound: dict[str, np.ndarray] = {}  # optimistic suffix bound
        for name, g in models.items():
            if not isinstance(g, OpGraph):
                g = chain_graph(list(g))
            self._graphs[name] = g
            h = orch.register(g)
            self._base[name] = h
            self._tables[name] = orch._reg(h).table
            self._free[name] = [h]
            wl = orch.workload(h)
            d = wl.dense
            best = np.where(d.mask, d.w, np.inf).min(axis=1)
            best = np.where(np.isfinite(best), best, 0.0)  # infeasible ops
            self._bound[name] = np.concatenate(
                (np.cumsum(best[::-1])[::-1], [0.0]))

    # -- handle aliasing -----------------------------------------------------
    def _acquire(self, model: str) -> int:
        free = self._free[model]
        if free:
            return free.pop()
        # an explicit-table registration always gets a fresh handle: the
        # same model can hold several concurrent admission slots
        return self.orch.register(self._graphs[model],
                                  table=self._tables[model])

    def _release(self, model: str, h: int) -> None:
        self._free[model].append(h)

    def _ref(self, model: str) -> dict:
        """Fault-free solo reference outputs of ``model`` (memoized):
        the oracle every real-mode completion is checked bitwise
        against."""
        ref = self._refs.get(model)
        if ref is None:
            ref = self.orch.executor.run_monolithic(
                self._graphs[model], self._inputs.get(model))
            self._refs[model] = ref
        return ref

    # -- serving loop --------------------------------------------------------
    def serve(self, trace: ArrivalTrace,
              chaos: ChaosTrace | None = None) -> ServeReport:
        """Run a trace to drain (synchronous wrapper over the async
        loop).  ``chaos`` scripts seeded faults across the run on the
        serving clock (real execution only)."""
        return asyncio.run(self.serve_async(trace, chaos))

    async def serve_async(self, trace: ArrivalTrace,
                          chaos: ChaosTrace | None = None) -> ServeReport:
        if chaos is not None and self.execution != "real":
            raise ValueError(
                "a ChaosTrace needs execution='real' — virtual serving "
                "never dispatches, so there is nothing to inject into")
        queue: asyncio.Queue = asyncio.Queue()

        async def produce() -> None:
            for a in trace.arrivals:
                await queue.put(a)
            await queue.put(None)          # end of stream

        producer = asyncio.create_task(produce())
        try:
            report = await self._schedule(queue, len(trace.arrivals), chaos)
        finally:
            producer.cancel()
        return report

    async def _schedule(self, queue: asyncio.Queue, n_expected: int,
                        chaos: ChaosTrace | None = None) -> ServeReport:
        orch = self.orch
        now = 0.0
        t0 = None                      # virtual time of first arrival
        plan_ms: list[float] = []
        records: list[RequestRecord] = []
        inflight: dict[int, RequestRecord] = {}   # handle -> record
        waiting: list[RequestRecord] = []         # admitted=no, FIFO
        pending: Arrival | None = None            # next undelivered arrival
        stream_done = False
        busy_time = 0.0                # integral of |active| over time
        warm0 = orch.stats["replans_warm"]
        cold0 = orch.stats["replans_cold"]
        cache0 = orch.cache_stats()
        plan: Plan | None = None
        cursor = 0                     # next step of `plan` to run

        # -- real-execution state -------------------------------------------
        real = self.execution == "real"
        health = HealthMonitor(self.health_policy) if real else None
        self.health = health
        base_cond = orch.condition     # externally-imposed condition
        faults = FaultPlan([], seed=chaos.seed if chaos else 0)
        chaos_events = list(chaos.events) if chaos is not None else []
        chaos_idx = 0
        rid_specs: list = []           # (ChaosEvent, armed FaultSpec) pairs
        recovery_ms: list[float] = []
        recoveries = 0
        retried = 0
        exec_wall = 0.0

        def record_of(a: Arrival) -> RequestRecord:
            wl = orch.workload(self._base[a.model])
            slo = a.slo
            if slo is None and self.slo_factor is not None:
                slo = self.slo_factor * float(self._bound[a.model][0])
            return RequestRecord(
                rid=a.rid, model=a.model, arrival=a.time,
                deadline=None if slo is None else a.time + slo,
                ops_total=wl.n)

        def bound(rec: RequestRecord) -> float:
            return float(self._bound[rec.model][rec.ops_done])

        def shed(rec: RequestRecord, reason: str) -> None:
            rec.shed, rec.shed_reason = True, reason
            if rec.handle is not None:
                rec_h = rec.handle
                rec.handle = None
                self._release(rec.model, rec_h)

        def timed(fn, *args, **kw):
            t = time.perf_counter()
            out = fn(*args, **kw)
            plan_ms.append((time.perf_counter() - t) * 1e3)
            return out

        def admit_due() -> bool:
            """Admit waiting requests while capacity allows; returns
            whether membership changed (plan invalidated)."""
            nonlocal plan
            changed = False
            while waiting and len(inflight) < self.max_concurrent:
                rec = waiting.pop(0)
                if rec.deadline is not None and \
                        now + bound(rec) > rec.deadline:
                    shed(rec, "slo")           # cannot make it: shed now
                    continue
                h = self._acquire(rec.model)
                rec.handle = h
                rec.admitted_at = now
                inflight[h] = rec
                plan = timed(orch.admit, h, self.objective,
                             self.horizon_states)
                changed = True
            return changed

        def replan() -> None:
            """Windowed warm re-plan with graceful shedding."""
            nonlocal plan, cursor
            while True:
                try:
                    if plan is None and inflight:
                        plan = timed(orch.replan_active, self.objective,
                                     self.horizon_states)
                    cursor = 0
                    return
                except InfeasibleScheduleError:
                    bad = [h for h, rec in inflight.items()
                           if self._infeasible(rec)]
                    if not bad:
                        raise          # not a per-request infeasibility
                    for h in bad:
                        rec = inflight.pop(h)
                        orch.retire(h, self.objective,
                                    self.horizon_states)
                        shed(rec, "infeasible")
                    plan = None

        # -- real-execution helpers -----------------------------------------
        def arm_chaos() -> None:
            """Fold chaos events whose scripted time has arrived into the
            live fault plan (the executor only ever sees armed specs)."""
            nonlocal chaos_idx
            while chaos_idx < len(chaos_events) \
                    and chaos_events[chaos_idx].time <= now:
                ev = chaos_events[chaos_idx]
                chaos_idx += 1
                if ev.kind == "pu_restored":
                    faults.revive(ev.lane)
                    continue
                spec = ev.spec()
                if ev.rid is not None:
                    spec.request = -1      # bound per window (slots shift)
                    rid_specs.append((ev, spec))
                faults.add(spec)

        def bind_rid_specs(handles) -> None:
            """Re-translate rid-targeted specs to this window's execution
            slots (slot = position in the plan's handle tuple)."""
            slot_of = {inflight[h].rid: s for s, h in enumerate(handles)
                       if h in inflight}
            for ev, spec in rid_specs:
                spec.request = slot_of.get(ev.rid, -1)

        def apply_health() -> None:
            """Fold the health-derived condition into the orchestrator
            and warm re-plan the entire active set on the survivors
            (requests with no surviving PU shed typed)."""
            nonlocal plan
            orch.on_condition(health.condition(base_cond))
            plan = None
            replan()

        def check_bitwise(rec: RequestRecord) -> None:
            rec.bitwise_ok = results_bitwise_equal(
                rec.results, self._ref(rec.model))

        def finish(h: int) -> None:
            nonlocal plan, cursor
            rec = inflight.pop(h)
            rec.finished_at = now
            rec.handle = None
            if real:
                check_bitwise(rec)
            plan = timed(orch.retire, h, self.objective,
                         self.horizon_states)
            cursor = 0
            self._release(rec.model, h)

        def shed_inflight(h: int, reason: str) -> None:
            rec = inflight.pop(h)
            orch.retire(h, self.objective, self.horizon_states)
            shed(rec, reason)

        def recover(t_fail: float) -> None:
            """One fault -> re-plan recovery cycle, timed wall-clock from
            the catch to the re-planned active set."""
            nonlocal recoveries
            recoveries += 1
            for rec in inflight.values():
                rec.recovered = True
            apply_health()
            recovery_ms.append((time.perf_counter() - t_fail) * 1e3)

        def commit(handles, results, steps) -> None:
            """Fold executed results into the request frontiers, advance
            the orchestrator by what newly completed, and move the
            serving clock past the fully-completed step prefix."""
            nonlocal now, busy_time, cursor
            for slot, h in enumerate(handles):
                rec = inflight.get(h)
                if rec is None:
                    continue
                fresh = [op for op in results[slot]
                         if op not in rec.results]
                rec.results.update(results[slot])
                if fresh:
                    orch.advance(h, len(fresh))
                    rec.ops_done += len(fresh)
            for st in steps:
                if not all(op is None
                           or op in inflight[handles[slot]].results
                           for slot, op in enumerate(st.ops)
                           if handles[slot] in inflight):
                    break
                cursor += 1
                busy_time += len(inflight) * st.cost
                now += st.cost
            for h in [h for h, rec in inflight.items()
                      if rec.ops_done >= rec.ops_total]:
                finish(h)

        def select_window() -> int:
            """End index (exclusive) of the step window to execute this
            boundary: stop at the arrival horizon or after a step that
            completes a request — the same boundaries the virtual loop
            observes, so both modes re-plan at identical membership
            events."""
            steps = plan.schedule.steps
            horizon = pending.time if pending is not None else None
            t = now
            done = {h: inflight[h].ops_done for h in plan.handles}
            end = cursor
            while end < len(steps):
                if horizon is not None and t >= horizon:
                    break
                st = steps[end]
                end += 1
                t += st.cost
                fin = False
                for slot, op in enumerate(st.ops):
                    if op is None:
                        continue
                    h = plan.handles[slot]
                    done[h] += 1
                    if done[h] >= inflight[h].ops_total:
                        fin = True
                if fin:
                    break
            return end

        def exec_window(end: int) -> None:
            """Really execute plan steps [cursor:end) through the fault
            runtime, with in-loop retries, breaker-driven quarantine +
            fleet-wide re-plan, and typed shedding."""
            nonlocal plan, retried, exec_wall
            handles = plan.handles
            steps = list(plan.schedule.steps[cursor:end])
            graphs = [orch._reg(h).graph for h in handles]
            ext = [self._inputs.get(inflight[h].model) for h in handles]
            est = sum(st.cost for st in steps)
            sub = ConcurrentSchedule(steps=steps, latency=est, energy=0.0,
                                     objective=self.objective,
                                     mode="window")
            window_pus = sorted({pu for st in steps for pu in st.pus
                                 if pu is not None})
            attempts = 0
            while True:
                arm_chaos()
                bind_rid_specs(handles)
                frontiers = [dict(inflight[h].results) if h in inflight
                             else {} for h in handles]
                timings: list = []
                tw = time.perf_counter()
                try:
                    if self.compile_exec:
                        seg_t: list = []
                        prog = orch.executor.compile_concurrent(
                            graphs, sub, completed=frontiers, partial=True)
                        results = prog.run(
                            ext, policy=self.exec_policy, faults=faults,
                            estimate=est, completed=frontiers,
                            segment_timings=seg_t)
                        timings = [(lane, r, i, dt / max(len(items), 1))
                                   for lane, items, dt in seg_t
                                   for (r, i) in items]
                    else:
                        results = orch.executor.run_concurrent(
                            graphs, sub, ext, completed=frontiers,
                            policy=self.exec_policy, faults=faults,
                            estimate=est, partial=True,
                            op_timings=timings)
                except PULostError as err:
                    exec_wall += time.perf_counter() - tw
                    t_fail = time.perf_counter()
                    commit(handles, err.partial or frontiers, steps)
                    health.record_loss(err.pu, now)
                    recover(t_fail)
                    return
                except ExecutionTimeoutError as err:
                    exec_wall += time.perf_counter() - tw
                    t_fail = time.perf_counter()
                    lanes = sorted(err.inflight) or window_pus
                    opened = False
                    for lane in lanes:
                        opened |= health.record_failure(
                            lane, now, "timeout")
                    attempts += 1
                    retried += 1
                    for h in handles:
                        if h in inflight:
                            inflight[h].retries += 1
                    if opened:
                        recover(t_fail)
                        return
                    if attempts <= self.max_window_retries:
                        continue       # discard + re-execute the window
                    for h in handles:
                        if h in inflight:
                            shed_inflight(h, "timeout")
                    plan = None
                    return
                except FaultRetryExceededError as err:
                    exec_wall += time.perf_counter() - tw
                    t_fail = time.perf_counter()
                    opened = err.lane is not None and health.record_failure(
                        err.lane, now, "retry_exceeded")
                    attempts += 1
                    retried += 1
                    for h in handles:
                        if h in inflight:
                            inflight[h].retries += 1
                    if opened:
                        recover(t_fail)
                        return
                    if attempts <= self.max_window_retries:
                        continue
                    if err.request is not None \
                            and 0 <= err.request < len(handles) \
                            and handles[err.request] in inflight:
                        shed_inflight(handles[err.request], "fault")
                    else:
                        for h in handles:
                            if h in inflight:
                                shed_inflight(h, "fault")
                    plan = None
                    return
                # -- success ------------------------------------------------
                exec_wall += time.perf_counter() - tw
                slot_model = [inflight[h].model if h in inflight else None
                              for h in handles]
                commit(handles, results, steps)
                for pu, r, i, dt in timings:
                    if slot_model[r] is None:
                        continue
                    pred = self._predicted(slot_model[r], i, pu)
                    if pred is not None:
                        health.observe(pu, pred, dt, now)
                executed = {pu for pu, _r, _i, _dt in timings} \
                    if timings else set(window_pus)
                for pu in executed & health.half_open():
                    health.probe_result(pu, ok=True, now=now)
                if health.dirty():
                    apply_health()     # e.g. a drift rescale folded in
                return

        while True:
            # -- drain the arrival stream up to the virtual clock ------------
            while not stream_done:
                if pending is None:
                    if queue.empty() and (inflight or waiting):
                        break          # nothing delivered yet; keep serving
                    item = await queue.get()
                    if item is None:
                        stream_done = True
                        break
                    pending = item
                if pending.time > now and (inflight or waiting):
                    break              # future arrival; serve current work
                now = max(now, pending.time)
                if t0 is None:
                    t0 = pending.time
                rec = record_of(pending)
                records.append(rec)
                if rec.ops_total and not self._model_feasible(rec.model):
                    shed(rec, "infeasible")
                else:
                    waiting.append(rec)
                pending = None
            if not inflight and not waiting:
                if stream_done and pending is None:
                    break              # drained
                continue

            # -- membership / progress boundary: admit + (re)plan ------------
            if real:
                arm_chaos()            # the serving clock reached new events
                if health.due_probes(now):
                    apply_health()     # half-open: re-admit for probing
            if admit_due():
                cursor = 0
            if plan is None:
                replan()
            if plan is None:           # everything fully advanced
                for h, rec in list(inflight.items()):
                    rec.finished_at = now
                    rec.handle = None
                    if real:
                        check_bitwise(rec)
                    inflight.pop(h)
                    orch.retire(h, self.objective, self.horizon_states)
                    self._release(rec.model, h)
                continue

            if real:
                # -- really execute the next step window ---------------------
                end = select_window()
                if end <= cursor:
                    plan = None        # window exhausted: warm re-plan
                else:
                    exec_window(end)
                    if plan is not None and cursor >= \
                            len(plan.schedule.steps):
                        plan = None
            else:
                # -- run planned steps in virtual time -----------------------
                steps = plan.schedule.steps
                handles = plan.handles
                horizon = pending.time if pending is not None else None
                finished: list[int] = []
                while cursor < len(steps):
                    if horizon is not None and now >= horizon:
                        break          # an arrival is due: admit first
                    step = steps[cursor]
                    cursor += 1
                    busy_time += len(inflight) * step.cost
                    now += step.cost
                    for slot, op in enumerate(step.ops):
                        if op is None:
                            continue
                        h = handles[slot]
                        rec = inflight[h]
                        orch.advance(h, 1)
                        rec.ops_done += 1
                        if rec.ops_done >= rec.ops_total:
                            finished.append(h)
                    if finished:
                        break          # membership change: re-plan
                for h in finished:
                    rec = inflight.pop(h)
                    rec.finished_at = now
                    rec.handle = None
                    plan = timed(orch.retire, h, self.objective,
                                 self.horizon_states)
                    cursor = 0
                    self._release(rec.model, h)
                if not finished and cursor >= len(steps):
                    plan = None        # window exhausted: warm re-plan
            # mid-flight SLO check at the boundary
            for h, rec in list(inflight.items()):
                if rec.deadline is not None and \
                        now + bound(rec) > rec.deadline:
                    inflight.pop(h)
                    orch.retire(h, self.objective, self.horizon_states)
                    shed(rec, "slo")
                    plan = None
            await asyncio.sleep(0)     # cooperative yield per boundary

        lats = [r.latency for r in records if r.latency is not None]
        completed = len(lats)
        makespan = max(now - (t0 or 0.0), 0.0)
        shed_reasons: dict[str, int] = {}
        for r in records:
            if r.shed:
                shed_reasons[r.shed_reason] = \
                    shed_reasons.get(r.shed_reason, 0) + 1
        cache1 = orch.cache_stats()
        cache_delta = {k: v - cache0.get(k, 0)
                       for k, v in cache1.items() if isinstance(v, int)}
        cache_delta["sizes"] = cache1.get("sizes", {})
        checked = [r for r in records if r.bitwise_ok is not None]
        return ServeReport(
            n_requests=len(records),
            completed=completed,
            shed=sum(r.shed for r in records),
            makespan=makespan,
            throughput=completed / makespan if makespan > 0 else 0.0,
            latency_p50=_pct(lats, 50), latency_p99=_pct(lats, 99),
            plan_ms_p50=_pct(plan_ms, 50), plan_ms_p99=_pct(plan_ms, 99),
            plan_events=len(plan_ms),
            replans_warm=orch.stats["replans_warm"] - warm0,
            replans_cold=orch.stats["replans_cold"] - cold0,
            occupancy_mean=busy_time / makespan if makespan > 0 else 0.0,
            recovered=sum(1 for r in records
                          if r.recovered and r.latency is not None),
            retried=retried,
            recoveries=recoveries,
            recovery_ms_p50=_pct(recovery_ms, 50),
            recovery_ms_p99=_pct(recovery_ms, 99),
            shed_reasons=shed_reasons,
            bitwise_checked=len(checked),
            bitwise_failures=sum(1 for r in checked if not r.bitwise_ok),
            exec_wall_s=exec_wall,
            breaker=health.stats() if health is not None else {},
            cache=cache_delta,
            requests=records)

    def _predicted(self, model: str, op: int, pu: str) -> float | None:
        """Cost-model latency for ``op`` of ``model`` on ``pu`` (drift ref)."""
        wl = self.orch.workload(self._base[model])
        d = wl.dense
        try:
            pos = list(wl.chain).index(op)
            j = list(d.pus).index(pu)
        except ValueError:
            return None
        if not d.mask[pos, j]:
            return None
        return float(d.w[pos, j])

    # -- feasibility probes --------------------------------------------------
    def _avail_cols(self, model: str) -> list[int]:
        d = self.orch.workload(self._base[model]).dense
        gone = self.orch.condition.unavailable
        return [i for i, pu in enumerate(d.pus) if pu not in gone]

    def _model_feasible(self, model: str) -> bool:
        d = self.orch.workload(self._base[model]).dense
        cols = self._avail_cols(model)
        if not cols:
            return False
        return bool(d.mask[:, cols].any(axis=1).all())

    def _infeasible(self, rec: RequestRecord) -> bool:
        d = self.orch.workload(self._base[rec.model]).dense
        cols = self._avail_cols(rec.model)
        if not cols:
            return True
        return not bool(d.mask[rec.ops_done:, cols].any(axis=1).all())
