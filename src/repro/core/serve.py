"""Streaming serving engine: an async front end over the Orchestrator.

The orchestrator exposes the online-admission API (``admit`` /
``advance`` / ``retire`` / ``replan_active``); this module is the
traffic loop that drives it at load — the difference between a paper
artifact and a scheduler that serves requests (ROADMAP item 1).

* :class:`ArrivalTrace` — reproducible request streams: ``poisson``
  (memoryless arrivals at a target rate) and ``bursty`` (Poisson
  background plus clustered bursts, the hard case for admission).
* :class:`ServingEngine` — an asyncio event loop feeding the
  orchestrator: continuous admission into a bounded concurrent set,
  **bounded re-plan latency** via windowed warm re-plans
  (``horizon_states``; every admit/advance/retire event costs one
  O(budget) incremental solve, never a full-grid re-solve), per-request
  SLO deadlines with optimistic-bound shedding, and graceful shedding of
  requests a re-plan proves infeasible
  (:class:`~repro.core.errors.InfeasibleScheduleError`) instead of
  taking the serving loop down.
* :class:`ServeReport` — sustained throughput, p50/p99 *plan* latency
  (wall-clock re-plan cost, the scheduler's own overhead) and p50/p99
  *request* latency (virtual queueing + execution time), plus the
  warm/cold re-plan split from ``orchestrator.stats``.

Execution is virtual-time: a planned :class:`ConcurrentStep` "runs" by
advancing the virtual clock by its cost-model latency and recording
progress via ``advance`` — the same discrete-event convention as the
cost-model benchmarks, so the loop exercises the full planning path at
thousands of requests without burning hours of wall clock.  Re-plan
latencies are the real wall-clock cost of the plan calls.
"""
from __future__ import annotations

import asyncio
import dataclasses
import time
from typing import Mapping, Sequence

import numpy as np

from .errors import InfeasibleScheduleError
from .op import FusedOp, OpGraph, chain_graph
from .orchestrator import Orchestrator, Plan
from .search import DEFAULT_HORIZON_STATES


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One request arrival: which model, when (virtual seconds), and an
    optional absolute SLO budget in virtual seconds (``None`` defers to
    the engine's ``slo_factor`` policy, if any)."""
    rid: int
    model: str
    time: float
    slo: float | None = None


@dataclasses.dataclass
class ArrivalTrace:
    """A reproducible arrival stream (sorted by time)."""
    arrivals: list[Arrival]
    kind: str = "custom"

    def __post_init__(self) -> None:
        self.arrivals = sorted(self.arrivals, key=lambda a: a.time)

    def __len__(self) -> int:
        return len(self.arrivals)

    @classmethod
    def poisson(cls, models: Sequence[str], rate: float, n: int,
                seed: int = 0, slo: float | None = None) -> "ArrivalTrace":
        """``n`` arrivals with Exp(``rate``) inter-arrival gaps, models
        drawn uniformly — the classic open-loop load model."""
        if rate <= 0 or n < 0:
            raise ValueError(f"poisson: need rate > 0 and n >= 0, got "
                             f"rate={rate}, n={n}")
        rng = np.random.default_rng(seed)
        ts = np.cumsum(rng.exponential(1.0 / rate, size=n))
        picks = rng.integers(0, len(models), size=n)
        return cls([Arrival(i, models[int(picks[i])], float(ts[i]), slo)
                    for i in range(n)], kind="poisson")

    @classmethod
    def bursty(cls, models: Sequence[str], rate: float, n: int,
               burst_every: int = 5, burst_size: int = 3,
               burst_span: float = 1e-3, seed: int = 0,
               slo: float | None = None) -> "ArrivalTrace":
        """Poisson background where every ``burst_every``-th arrival
        brings ``burst_size - 1`` near-simultaneous companions (within
        ``burst_span`` virtual seconds) — clustered admissions that
        stress bounded re-plan latency."""
        base = cls.poisson(models, rate, n, seed=seed, slo=slo)
        rng = np.random.default_rng(seed + 1)
        out = list(base.arrivals)
        rid = n
        for k, a in enumerate(base.arrivals):
            if burst_every and k % burst_every == 0:
                for j in range(burst_size - 1):
                    out.append(Arrival(
                        rid, models[int(rng.integers(0, len(models)))],
                        a.time + float(rng.uniform(0, burst_span)), slo))
                    rid += 1
        return cls(out, kind="bursty")


@dataclasses.dataclass
class RequestRecord:
    """Lifecycle record of one served (or shed) request."""
    rid: int
    model: str
    arrival: float
    deadline: float | None
    ops_total: int
    ops_done: int = 0
    handle: int | None = None
    admitted_at: float | None = None
    finished_at: float | None = None
    shed: bool = False
    shed_reason: str = ""

    @property
    def latency(self) -> float | None:
        """Virtual arrival→completion latency (queueing + execution)."""
        if self.finished_at is None:
            return None
        return self.finished_at - self.arrival


def _pct(xs: Sequence[float], q: float) -> float:
    return float(np.percentile(np.asarray(xs), q)) if len(xs) else 0.0


@dataclasses.dataclass
class ServeReport:
    """What a serving run sustained, and what it cost to plan it."""
    n_requests: int
    completed: int
    shed: int
    makespan: float               # virtual seconds, first arrival -> drain
    throughput: float             # completed requests / virtual second
    latency_p50: float            # virtual request latency percentiles
    latency_p99: float
    plan_ms_p50: float            # wall-clock re-plan latency percentiles
    plan_ms_p99: float
    plan_events: int
    replans_warm: int
    replans_cold: int
    occupancy_mean: float         # time-weighted mean concurrent set size
    requests: list[RequestRecord] = dataclasses.field(
        default_factory=list, repr=False)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d.pop("requests")
        return d


class ServingEngine:
    """Continuous-admission serving loop over one :class:`Orchestrator`.

    ``models`` maps model names to their inference graphs (or bare op
    sequences); each is registered once and cloned per concurrent
    in-flight request through handle aliasing (``register(graph,
    table=...)`` always issues a fresh handle, so two in-flight requests
    of the same model hold distinct admission slots; finished handles
    return to a per-model free pool, keeping the registration count
    bounded by peak concurrency).

    The loop is an asyncio pipeline — a producer task feeding arrivals
    into a queue, the scheduler task draining it — with virtual-time
    execution (see module docstring).  Every membership or progress
    boundary costs exactly one windowed warm re-plan of at most
    ``horizon_states`` grid states, so admission latency stays bounded
    no matter how much work is in flight.  ``max_concurrent`` bounds the
    co-scheduled set (grid width); excess arrivals queue FIFO.

    Shedding keeps the loop alive instead of failing a whole run:

    * **SLO**: a request whose optimistic remaining-work bound (suffix
      sum of per-op best-PU costs) can no longer meet its deadline is
      shed at admission or at the next re-plan boundary.
    * **Infeasibility**: when a re-plan raises
      :class:`InfeasibleScheduleError` (e.g. a condition change left an
      op with no supporting PU), the offending requests are shed and the
      survivors re-planned.
    """

    def __init__(self, orch: Orchestrator,
                 models: Mapping[str, OpGraph | Sequence[FusedOp]],
                 objective: str = "latency",
                 horizon_states: int | None = DEFAULT_HORIZON_STATES,
                 max_concurrent: int = 3,
                 slo_factor: float | None = None):
        if not models:
            raise ValueError("ServingEngine needs at least one model")
        if max_concurrent < 1:
            raise ValueError(
                f"max_concurrent must be >= 1, got {max_concurrent}")
        self.orch = orch
        self.objective = objective
        self.horizon_states = horizon_states
        self.max_concurrent = max_concurrent
        self.slo_factor = slo_factor
        self._graphs: dict[str, OpGraph] = {}
        self._base: dict[str, int] = {}       # model -> provider handle
        self._tables: dict[str, object] = {}  # model -> profiled CostTable
        self._free: dict[str, list[int]] = {}  # model -> reusable handles
        self._bound: dict[str, np.ndarray] = {}  # optimistic suffix bound
        for name, g in models.items():
            if not isinstance(g, OpGraph):
                g = chain_graph(list(g))
            self._graphs[name] = g
            h = orch.register(g)
            self._base[name] = h
            self._tables[name] = orch._reg(h).table
            self._free[name] = [h]
            wl = orch.workload(h)
            d = wl.dense
            best = np.where(d.mask, d.w, np.inf).min(axis=1)
            best = np.where(np.isfinite(best), best, 0.0)  # infeasible ops
            self._bound[name] = np.concatenate(
                (np.cumsum(best[::-1])[::-1], [0.0]))

    # -- handle aliasing -----------------------------------------------------
    def _acquire(self, model: str) -> int:
        free = self._free[model]
        if free:
            return free.pop()
        # an explicit-table registration always gets a fresh handle: the
        # same model can hold several concurrent admission slots
        return self.orch.register(self._graphs[model],
                                  table=self._tables[model])

    def _release(self, model: str, h: int) -> None:
        self._free[model].append(h)

    # -- serving loop --------------------------------------------------------
    def serve(self, trace: ArrivalTrace) -> ServeReport:
        """Run a trace to drain (synchronous wrapper over the async
        loop)."""
        return asyncio.run(self.serve_async(trace))

    async def serve_async(self, trace: ArrivalTrace) -> ServeReport:
        queue: asyncio.Queue = asyncio.Queue()

        async def produce() -> None:
            for a in trace.arrivals:
                await queue.put(a)
            await queue.put(None)          # end of stream

        producer = asyncio.create_task(produce())
        try:
            report = await self._schedule(queue, len(trace.arrivals))
        finally:
            producer.cancel()
        return report

    async def _schedule(self, queue: asyncio.Queue,
                        n_expected: int) -> ServeReport:
        orch = self.orch
        now = 0.0
        t0 = None                      # virtual time of first arrival
        plan_ms: list[float] = []
        records: list[RequestRecord] = []
        inflight: dict[int, RequestRecord] = {}   # handle -> record
        waiting: list[RequestRecord] = []         # admitted=no, FIFO
        pending: Arrival | None = None            # next undelivered arrival
        stream_done = False
        busy_time = 0.0                # integral of |active| over time
        warm0 = orch.stats["replans_warm"]
        cold0 = orch.stats["replans_cold"]
        plan: Plan | None = None
        cursor = 0                     # next step of `plan` to run

        def record_of(a: Arrival) -> RequestRecord:
            wl = orch.workload(self._base[a.model])
            slo = a.slo
            if slo is None and self.slo_factor is not None:
                slo = self.slo_factor * float(self._bound[a.model][0])
            return RequestRecord(
                rid=a.rid, model=a.model, arrival=a.time,
                deadline=None if slo is None else a.time + slo,
                ops_total=wl.n)

        def bound(rec: RequestRecord) -> float:
            return float(self._bound[rec.model][rec.ops_done])

        def shed(rec: RequestRecord, reason: str) -> None:
            rec.shed, rec.shed_reason = True, reason
            if rec.handle is not None:
                rec_h = rec.handle
                rec.handle = None
                self._release(rec.model, rec_h)

        def timed(fn, *args, **kw):
            t = time.perf_counter()
            out = fn(*args, **kw)
            plan_ms.append((time.perf_counter() - t) * 1e3)
            return out

        def admit_due() -> bool:
            """Admit waiting requests while capacity allows; returns
            whether membership changed (plan invalidated)."""
            nonlocal plan
            changed = False
            while waiting and len(inflight) < self.max_concurrent:
                rec = waiting.pop(0)
                if rec.deadline is not None and \
                        now + bound(rec) > rec.deadline:
                    shed(rec, "slo")           # cannot make it: shed now
                    continue
                h = self._acquire(rec.model)
                rec.handle = h
                rec.admitted_at = now
                inflight[h] = rec
                plan = timed(orch.admit, h, self.objective,
                             self.horizon_states)
                changed = True
            return changed

        def replan() -> None:
            """Windowed warm re-plan with graceful shedding."""
            nonlocal plan, cursor
            while True:
                try:
                    if plan is None and inflight:
                        plan = timed(orch.replan_active, self.objective,
                                     self.horizon_states)
                    cursor = 0
                    return
                except InfeasibleScheduleError:
                    bad = [h for h, rec in inflight.items()
                           if self._infeasible(rec)]
                    if not bad:
                        raise          # not a per-request infeasibility
                    for h in bad:
                        rec = inflight.pop(h)
                        orch.retire(h, self.objective,
                                    self.horizon_states)
                        shed(rec, "infeasible")
                    plan = None

        while True:
            # -- drain the arrival stream up to the virtual clock ------------
            while not stream_done:
                if pending is None:
                    if queue.empty() and (inflight or waiting):
                        break          # nothing delivered yet; keep serving
                    item = await queue.get()
                    if item is None:
                        stream_done = True
                        break
                    pending = item
                if pending.time > now and (inflight or waiting):
                    break              # future arrival; serve current work
                now = max(now, pending.time)
                if t0 is None:
                    t0 = pending.time
                rec = record_of(pending)
                records.append(rec)
                if rec.ops_total and not self._model_feasible(rec.model):
                    shed(rec, "infeasible")
                else:
                    waiting.append(rec)
                pending = None
            if not inflight and not waiting:
                if stream_done and pending is None:
                    break              # drained
                continue

            # -- membership / progress boundary: admit + (re)plan ------------
            if admit_due():
                cursor = 0
            if plan is None:
                replan()
            if plan is None:           # everything fully advanced
                for h, rec in list(inflight.items()):
                    rec.finished_at = now
                    inflight.pop(h)
                    orch.retire(h, self.objective, self.horizon_states)
                    self._release(rec.model, h)
                continue

            # -- run planned steps in virtual time ---------------------------
            steps = plan.schedule.steps
            handles = plan.handles
            horizon = pending.time if pending is not None else None
            finished: list[int] = []
            while cursor < len(steps):
                if horizon is not None and now >= horizon:
                    break              # an arrival is due: admit first
                step = steps[cursor]
                cursor += 1
                busy_time += len(inflight) * step.cost
                now += step.cost
                for slot, op in enumerate(step.ops):
                    if op is None:
                        continue
                    h = handles[slot]
                    rec = inflight[h]
                    orch.advance(h, 1)
                    rec.ops_done += 1
                    if rec.ops_done >= rec.ops_total:
                        finished.append(h)
                if finished:
                    break              # membership change: re-plan
            for h in finished:
                rec = inflight.pop(h)
                rec.finished_at = now
                plan = timed(orch.retire, h, self.objective,
                             self.horizon_states)
                cursor = 0
                self._release(rec.model, h)
            if not finished and cursor >= len(steps):
                plan = None            # window exhausted: warm re-plan
            # mid-flight SLO check at the boundary
            for h, rec in list(inflight.items()):
                if rec.deadline is not None and \
                        now + bound(rec) > rec.deadline:
                    inflight.pop(h)
                    orch.retire(h, self.objective, self.horizon_states)
                    shed(rec, "slo")
                    plan = None
            await asyncio.sleep(0)     # cooperative yield per boundary

        lats = [r.latency for r in records if r.latency is not None]
        completed = len(lats)
        makespan = max(now - (t0 or 0.0), 0.0)
        return ServeReport(
            n_requests=len(records),
            completed=completed,
            shed=sum(r.shed for r in records),
            makespan=makespan,
            throughput=completed / makespan if makespan > 0 else 0.0,
            latency_p50=_pct(lats, 50), latency_p99=_pct(lats, 99),
            plan_ms_p50=_pct(plan_ms, 50), plan_ms_p99=_pct(plan_ms, 99),
            plan_events=len(plan_ms),
            replans_warm=orch.stats["replans_warm"] - warm0,
            replans_cold=orch.stats["replans_cold"] - cold0,
            occupancy_mean=busy_time / makespan if makespan > 0 else 0.0,
            requests=records)

    # -- feasibility probes --------------------------------------------------
    def _avail_cols(self, model: str) -> list[int]:
        d = self.orch.workload(self._base[model]).dense
        gone = self.orch.condition.unavailable
        return [i for i, pu in enumerate(d.pus) if pu not in gone]

    def _model_feasible(self, model: str) -> bool:
        d = self.orch.workload(self._base[model]).dense
        cols = self._avail_cols(model)
        if not cols:
            return False
        return bool(d.mask[:, cols].any(axis=1).all())

    def _infeasible(self, rec: RequestRecord) -> bool:
        d = self.orch.workload(self._base[rec.model]).dense
        cols = self._avail_cols(rec.model)
        if not cols:
            return True
        return not bool(d.mask[rec.ops_done:, cols].any(axis=1).all())
