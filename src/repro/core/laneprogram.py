"""Compiled lane programs: the segment-fused execution path.

The interpreter in :mod:`repro.core.executor` dispatches every op as a
Python closure call plus a ``threading.Event`` wait/set — faithful to the
command-queue model, but after the planning side went ms-scale that per-op
overhead *is* the runtime cost the paper says the orchestrator avoids
("the output schedule ... is applied directly by the execution
orchestrator").  A :class:`LaneProgram` removes it in two moves:

* **Segment partitioning.**  Each PU lane's FIFO queue is cut into
  *maximal contiguous same-lane segments*: a new segment starts only at a
  cross-lane boundary (an op whose predecessor ran on another lane — the
  D2H/H2D handoff points), at a request switch on a shared lane, or at a
  co-scheduled concurrent step (co-scheduled ops stay individually
  dispatched so the granularity the contention laws priced is preserved —
  they become single-op *barrier* segments).  The boundary test reads the
  op graph's true predecessor sets, so for DAG schedules (lane queues
  from ``ScheduleExecutor.compile_dag``) cuts land exactly at cross-lane
  dependency *edges*: two independent subgraphs mapped to different
  lanes fuse into segments that overlap with no synchronisation at all.  Synchronisation collapses
  from one event per op to one event per segment, waited on only across
  the boundary cuts.

* **Segment fusion.**  Each segment's op payloads compose into one
  callable.  On the first run the segment executes composed-but-eager
  (the *probe*), then attempts ``jax.jit`` of the composition and keeps
  the jitted version **only if its outputs are bitwise identical** to
  eager execution — checked on the probe inputs and on a perturbed
  same-shape input set, so a value coincidence cannot certify it —
  payloads that are not JAX-traceable (NumPy closures, ``None``
  payloads) or whose dtypes a jit round-trip would alter fall back to the
  composed-Python form automatically.  Either way the per-op event churn
  is gone; the jitted form additionally collapses a whole segment into a
  single XLA dispatch.

Programs are built once per (plan, input-signature) by
``ScheduleExecutor.compile_scheduled`` / ``compile_dag`` /
``compile_concurrent`` and cached
by ``Orchestrator.execute`` (see the ``program_for`` hook), mirroring the
plan cache: a repeat ``execute`` call skips partitioning and compilation
entirely.  The per-op interpreter remains the bitwise-equivalence oracle
(``Orchestrator.execute(..., compile=False)``).

A program's first ``run`` mutates segment state (probe → jit/python mode
settling), so a single program must not be run from two threads
concurrently until warm; the orchestrator's cache serialises this in
practice (one program per plan/input key).

Op payloads must be **pure** on this path: compile verification executes
each payload a few extra times (the jit probe, plus an eager + jitted
pass over perturbed same-shape inputs), and warm runs replay the fused
callable — a payload with internal state (counters, cache mutation,
appended buffers) would advance differently than under the per-op
interpreter.  Stateful or side-effecting payloads belong on the
interpreter oracle (``Orchestrator.execute(..., compile=False)``).
Purity is also what makes the fault runtime's *segment-granularity
retry* safe (see :mod:`repro.core.faults`): a transiently-failed
segment writes no results and simply re-executes; every cross-lane wait
in ``run`` is bounded by the watchdog budget; and a permanent PU loss
surfaces as :class:`~repro.core.errors.PULostError` carrying the
frontier of completed segments for orchestrator-level re-plan + resume.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
import time
from typing import Any, Callable, Mapping, Sequence

import numpy as np

from repro.fault.manager import RecoverableError

from .errors import ExecutionError, PULostError
from .faults import (_JOIN_GRACE, ExecutionPolicy, FaultPlan, RunContext,
                     _Aborted, run_with_retries)
from .op import OpGraph
from .targets import variant_tolerance

try:  # the compiled path degrades to composed-Python without jax
    import jax
except Exception:  # pragma: no cover - jax is baked into this container
    jax = None

# segment execution modes
COLD = "cold"        # not yet run: next run probes eagerly, then compiles
JIT = "jit"          # fused callable is jitted (bitwise-verified vs probe)
PYTHON = "python"    # composed-Python fallback (non-traceable payloads)


def _bitwise_equal(a, b) -> bool:
    """True iff two payload outputs are bitwise identical (dtype, shape,
    and raw bytes — ``allclose`` is deliberately not used here)."""
    if a is None or b is None:
        return a is None and b is None
    xa, xb = np.asarray(a), np.asarray(b)
    return (xa.dtype == xb.dtype and xa.shape == xb.shape
            and xa.tobytes() == xb.tobytes())


def _perturb(x):
    """A same-shape/dtype input with different float values, for the
    second leg of compile verification (non-floats pass through)."""
    a = np.asarray(x)
    if np.issubdtype(a.dtype, np.floating):
        return ((a * np.asarray(0.7371, a.dtype)
                 + np.asarray(0.1113, a.dtype)).astype(a.dtype, copy=False))
    return x


def results_bitwise_equal(a: Mapping[int, Any], b: Mapping[int, Any]) -> bool:
    """Bitwise comparison of two executor results dicts (the strict form
    of ``ScheduleExecutor.outputs_close``: dtypes and bytes must match)."""
    if set(a) != set(b):
        return False
    return all(_bitwise_equal(a[k], b[k]) for k in a)


def _within_tolerance(ref, got, target) -> bool:
    """Variant-vs-reference closeness at the target's per-dtype tolerance
    bucket (non-float outputs must be bitwise; shape/dtype must match)."""
    if ref is None or got is None:
        return ref is None and got is None
    a, b = np.asarray(ref), np.asarray(got)
    if a.shape != b.shape or a.dtype != b.dtype:
        return False
    if a.dtype.kind not in "fc":
        return a.tobytes() == b.tobytes()
    atol, rtol = (target.tolerance(a.dtype) if target is not None
                  else variant_tolerance(a.dtype))
    if atol == 0.0 and rtol == 0.0:
        return a.tobytes() == b.tobytes()
    return bool(np.allclose(a.astype(np.float64), b.astype(np.float64),
                            atol=atol, rtol=rtol))


@dataclasses.dataclass
class Segment:
    """A maximal run of same-lane ops fused into one callable.

    ``items`` are ``(request, op)`` pairs in lane-queue order; ``deps``
    are indices of segments on *other* lanes whose outputs this segment
    reads (same-lane predecessors are implicit in FIFO order).  A
    ``barrier`` segment holds exactly one co-scheduled concurrent-step op
    and is never fused with its neighbours.

    When the lane is bound to a :class:`~repro.core.targets.Target`,
    ``fns`` still holds the reference payloads (the probe oracle) and
    ``var_fns`` the target-dialect variants; the cold run verifies the
    variant composition against the reference outputs (bitwise, else the
    target's per-dtype tolerance) before it is ever served, and the
    target's ``jit``/``device`` policy governs compilation and input
    placement.  ``verified`` records the outcome (``"bitwise"`` /
    ``"tolerance"`` / ``"rejected"`` / ``"error: ..."``).
    """

    index: int
    lane: str
    barrier: bool = False
    target: Any = None
    items: list[tuple[int, int]] = dataclasses.field(default_factory=list)
    fns: list[Callable | None] = dataclasses.field(default_factory=list)
    var_fns: list[Callable | None] | None = None
    use_variant: bool = False
    verified: str | None = None
    jit_verified: str | None = None
    deps: list[int] = dataclasses.field(default_factory=list)
    # results of other segments this segment reads, in flat order
    flat_refs: list[tuple[int, int]] = dataclasses.field(default_factory=list)
    # per item: arg sources after the op's external inputs — ("f", j) is
    # flat input j (another segment's output), ("o", t) is item t's output
    argspecs: list[list[tuple[str, int]]] = dataclasses.field(
        default_factory=list)
    # one descriptive wait label per entry of ``deps``, precomputed at
    # compile time so the watchdog can name both sides of a hung handoff
    # without per-run string formatting
    dep_whats: list[str] = dataclasses.field(default_factory=list)
    mode: str = COLD
    _jfn: Any = dataclasses.field(default=None, repr=False)

    # -- composition --------------------------------------------------------
    def _compose(self, fns: Sequence[Callable | None], flat: tuple,
                 ext_lists: tuple) -> tuple:
        """Run every op of the segment over a payload list.

        ``flat`` holds the cross-segment input values (in ``flat_refs``
        order), ``ext_lists`` the per-item external-input tuples.  Arg
        order per op matches the interpreter exactly: external inputs
        first, then predecessor outputs in ``graph.pred`` order.
        """
        outs: list[Any] = []
        for t, spec in enumerate(self.argspecs):
            fn = fns[t]
            if fn is None:
                outs.append(None)
                continue
            deps = tuple(flat[j] if kind == "f" else outs[j]
                         for kind, j in spec)
            outs.append(fn(*(tuple(ext_lists[t]) + deps)))
        return tuple(outs)

    def _composed(self, flat: tuple, ext_lists: tuple) -> tuple:
        """The reference composition (``op.fn`` payloads)."""
        return self._compose(self.fns, flat, ext_lists)

    def _composed_var(self, flat: tuple, ext_lists: tuple) -> tuple:
        """The target-dialect variant composition."""
        return self._compose(self.var_fns, flat, ext_lists)

    def _place(self, flat: tuple, ext_lists: tuple) -> tuple[tuple, tuple]:
        """Pin segment inputs to the bound target's device (identity when
        no target/device is bound)."""
        tgt = self.target
        if tgt is None or tgt.device is None or jax is None:
            return flat, ext_lists
        def put(v):
            return jax.device_put(v, tgt.device)
        return (tuple(put(v) for v in flat),
                tuple(tuple(put(v) for v in e) for e in ext_lists))

    def _gather(self, results: Sequence[dict], ext: Sequence[dict]):
        flat = tuple(results[r][p] for r, p in self.flat_refs)
        ext_lists = tuple(tuple(ext[r].get(i, ())) for r, i in self.items)
        return flat, ext_lists

    def execute(self, results: Sequence[dict], ext: Sequence[dict]) -> None:
        flat, ext_lists = self._gather(results, ext)
        if self.mode == JIT:
            outs = self._jfn(flat, ext_lists)
        elif self.mode == PYTHON and self.use_variant:
            outs = self._composed_var(*self._place(flat, ext_lists))
        else:
            outs = self._composed(flat, ext_lists)
            if self.mode == COLD:
                self._settle(flat, ext_lists, outs)
        for (r, i), o in zip(self.items, outs):
            results[r][i] = o

    def _settle(self, flat, ext_lists, outs) -> None:
        """Cold-run settling.  ``outs`` are the eager *reference* outputs
        (they are what this cold run serves — a variant is never served
        unverified).  Order of business: probe-verify the target variant
        against them, then attempt jit compilation of whichever
        composition survived, honouring the target's jit policy."""
        self.mode = PYTHON
        tgt = self.target
        if self.var_fns is not None:
            probe = self._verify_variant(flat, ext_lists, outs)
            if probe is not None:          # variant accepted: serve it
                if tgt is None or tgt.jit:
                    self._jit_verify(self._composed_var, *probe)
                return
        if tgt is not None and not tgt.jit:
            return                          # eager-by-policy target
        self._maybe_compile(flat, ext_lists, outs)

    def _verify_variant(self, flat, ext_lists, ref_outs):
        """Probe the variant composition against the reference outputs.
        Accepts on bitwise equality, else on the target's per-dtype
        tolerance; rejection (or any execution error) drops ``var_fns``
        so the segment permanently serves the reference payloads.
        Returns ``(placed_flat, placed_ext, variant_outs)`` when the
        variant is accepted, else ``None``."""
        try:
            pflat, pext = self._place(flat, ext_lists)
            got = self._composed_var(pflat, pext)
        except Exception as e:
            self.verified = f"error: {type(e).__name__}"
            self.var_fns = None
            return None
        if len(got) == len(ref_outs) and all(
                _bitwise_equal(a, b) for a, b in zip(ref_outs, got)):
            self.verified = "bitwise"
        elif len(got) == len(ref_outs) and all(
                _within_tolerance(a, b, self.target)
                for a, b in zip(ref_outs, got)):
            self.verified = "tolerance"
        else:
            self.verified = "rejected"
            self.var_fns = None
            return None
        self.use_variant = True
        return pflat, pext, got

    def _maybe_compile(self, flat, ext_lists, outs) -> None:
        """Probe-and-verify compilation of the *reference* composition:
        jit it and keep the jitted form only if its outputs match the
        eager probe bitwise — on the probe inputs AND on an independently
        perturbed same-shape input set, so a value coincidence on the
        probe (e.g. an FMA contraction that happens to round identically
        there) cannot certify a jit that diverges on later inputs.
        Anything else (trace failures on NumPy payloads, f64→f32 dtype
        drift under a jit round-trip, non-array outputs) keeps the
        Python form."""
        self.mode = PYTHON
        if any(fn is None for fn in self.fns):
            return
        self._jit_verify(self._composed, flat, ext_lists, outs)

    def _jit_verify(self, composed, flat, ext_lists, outs) -> None:
        """Shared jit probe for the reference and variant compositions:
        bitwise on the probe inputs and on a perturbed second leg, exactly
        the PR 5 rule.  A target that *declares* a tolerance
        (``Target.atol``/``rtol``) additionally accepts a jit whose
        outputs stay within that tolerance on both legs — XLA fusion
        reorders float accumulation, so an eager-vs-jit probe of e.g. a
        softmax composition is rarely bitwise; a declared-tolerance
        target says so in data rather than silently eating the ~100x
        eager fallback.  Targetless segments (the PR 5 analytic path)
        remain strictly bitwise.  On success ``_jfn`` wraps the jitted
        callable with the target's device placement and ``mode`` flips
        to JIT; ``jit_verified`` records which rule admitted it."""
        if jax is None:
            return
        if not all(isinstance(o, jax.Array) for o in outs):
            return
        tgt = self.target
        declared = tgt is not None and (tgt.atol or tgt.rtol)

        def admit(ref_o, got_o):
            if len(got_o) != len(ref_o):
                return None
            if all(_bitwise_equal(a, b) for a, b in zip(ref_o, got_o)):
                return "bitwise"
            if declared and all(_within_tolerance(a, b, tgt)
                                for a, b in zip(ref_o, got_o)):
                return "tolerance"
            return None

        try:
            jfn = jax.jit(composed)
            how = admit(outs, tuple(jfn(flat, ext_lists)))
            if how is not None:
                flat2 = tuple(_perturb(v) for v in flat)
                ext2 = tuple(tuple(_perturb(v) for v in e)
                             for e in ext_lists)
                ref2 = tuple(composed(flat2, ext2))
                how2 = admit(ref2, tuple(jfn(flat2, ext2)))
                how = (None if how2 is None
                       else ("bitwise" if how == how2 == "bitwise"
                             else "tolerance"))
        except Exception:
            return
        if how is not None:
            if self.target is not None and self.target.device is not None:
                self._jfn = lambda f, e: tuple(jfn(*self._place(f, e)))
            else:
                self._jfn = jfn
            self.jit_verified = how
            self.mode = JIT


class LanePool:
    """Persistent lane workers: one daemon thread + FIFO task queue per
    lane (the command-queue model, kept warm across runs so thread spawn
    cost never lands on the dispatch path).

    Threads are **daemon** deliberately: a payload that hangs in native
    code past the watchdog budget wedges its worker, and a non-daemon
    thread would then block interpreter exit forever (the
    ``ThreadPoolExecutor`` atexit-join behaviour this replaces).  The
    watchdog backstop drops the whole pool (``shutdown``) and the next
    run builds a fresh one; wedged daemon workers leak harmlessly.
    """

    def __init__(self, lanes: Sequence[str]):
        self._queues: dict[str, queue.SimpleQueue] = {}
        for pu in lanes:
            q: queue.SimpleQueue = queue.SimpleQueue()
            self._queues[pu] = q
            threading.Thread(target=self._worker, args=(q,),
                             name=f"lane-{pu}", daemon=True).start()

    @staticmethod
    def _worker(q: "queue.SimpleQueue") -> None:
        while True:
            task = q.get()
            if task is None:
                return
            fn, done = task
            try:
                fn()
            except BaseException:   # submitted fns do their own reporting
                pass
            finally:
                done.set()

    def submit(self, lane: str, fn: Callable[[], None]) -> threading.Event:
        """Enqueue ``fn`` on ``lane``; the returned event is set when it
        finishes (success or not — errors are the fn's job to record)."""
        done = threading.Event()
        self._queues[lane].put((fn, done))
        return done

    def shutdown(self, wait: bool = False) -> None:
        for q in self._queues.values():
            q.put(None)


class LaneProgram:
    """A compiled plan: per-lane segment lists + cross-lane handoff deps.

    Build with :func:`compile_lane_program` (or the ``ScheduleExecutor``
    ``compile_*`` wrappers); ``run(external_inputs)`` executes with one
    worker thread per lane and returns the same results shape as the
    interpreter (``run_scheduled`` for single-graph programs,
    ``run_concurrent`` for M-request programs).
    """

    def __init__(self, graphs: Sequence[OpGraph],
                 segments: list[Segment],
                 lane_segments: dict[str, list[Segment]],
                 single: bool):
        self.graphs = list(graphs)
        self.segments = segments
        self.lane_segments = lane_segments
        self.lanes = [pu for pu, segs in lane_segments.items() if segs]
        self.single = single
        self.n_requests = len(self.graphs)
        self.runs = 0
        # a program whose segment DAG (handoff deps + per-lane FIFO
        # order) admits exactly ONE topological order is inherently
        # serial: no two segments can ever overlap, so run() executes it
        # inline — no worker threads, no events at all.  Sequential
        # chains always qualify; programs with real co-execution
        # (parallel branches, concurrent requests) never do and keep the
        # lane workers (pooled persistently: thread spawn per run would
        # dwarf the dispatch overhead this path removes).
        self.serial_order = self._serial_order()
        self._pool: LanePool | None = None
        # identity snapshot of every covered op's fn + variant table,
        # taken at compile time (see payloads_current)
        self._payload_tokens: dict[tuple[int, int], tuple] = {
            (r, i): self.graphs[r].ops[i].payload_token()
            for seg in segments for (r, i) in seg.items}

    def payloads_current(self) -> bool:
        """True while every op's payload *and variant table* are still
        the ones baked in at compile time.  A caller that rebinds
        ``graph.ops[i].fn`` — or any entry of ``graph.ops[i].variants``
        — after compilation invalidates the program: the orchestrator
        checks this on every program-cache hit and recompiles on
        mismatch, so a stale fused callable (or a stale variant
        selection) is never served."""
        for (r, i), (fn0, var0) in self._payload_tokens.items():
            op = self.graphs[r].ops[i]
            if op.fn is not fn0:
                return False
            variants = op.variants
            if len(variants) != len(var0):
                return False
            for key, f in var0:
                if variants.get(key) is not f:
                    return False
        return True

    def close(self) -> None:
        """Release the persistent lane-worker pool (idempotent; a later
        ``run`` lazily recreates it).  Called on cache eviction so idle
        worker threads don't outlive the program's cache entry."""
        if self._pool is not None:
            self._pool.shutdown(wait=False)
            self._pool = None

    def _serial_order(self) -> list[Segment] | None:
        n = len(self.segments)
        indeg = [0] * n
        succ: list[list[int]] = [[] for _ in range(n)]
        for s in self.segments:
            for d in s.deps:
                succ[d].append(s.index)
                indeg[s.index] += 1
        for segs in self.lane_segments.values():
            for a, b in zip(segs, segs[1:]):
                succ[a.index].append(b.index)
                indeg[b.index] += 1
        ready = [i for i in range(n) if indeg[i] == 0]
        order: list[int] = []
        while ready:
            if len(ready) > 1:
                return None            # two segments could co-execute
            u = ready.pop()
            order.append(u)
            for v in succ[u]:
                indeg[v] -= 1
                if indeg[v] == 0:
                    ready.append(v)
        return [self.segments[i] for i in order] if len(order) == n else None

    @property
    def stats(self) -> dict:
        """Structure + compilation summary (jit counts settle after the
        first ``run``; before it every segment reports ``cold``)."""
        modes = [s.mode for s in self.segments]
        return {
            "n_ops": sum(len(s.items) for s in self.segments),
            "n_segments": len(self.segments),
            "n_jitted": modes.count(JIT),
            "n_python": modes.count(PYTHON),
            "n_cold": modes.count(COLD),
            "n_barrier": sum(1 for s in self.segments if s.barrier),
            "n_variant": sum(1 for s in self.segments if s.use_variant),
            "variant_verified": {s.index: s.verified for s in self.segments
                                 if s.verified is not None},
            "jit_verified": {s.index: s.jit_verified for s in self.segments
                             if s.jit_verified is not None},
            "lane_targets": {s.lane: s.target.name for s in self.segments
                             if s.target is not None},
            "max_segment_ops": max((len(s.items) for s in self.segments),
                                   default=0),
            "serial": self.serial_order is not None,
            "runs": self.runs,
        }

    def _exec_segment(self, seg: Segment, results, ext,
                      run: RunContext | None) -> None:
        """Execute one segment under the fault runtime: injected faults
        fire per (request, op) item, transient failures retry the whole
        segment with backoff (payloads are pure on this path, and a
        failed ``execute`` writes no results, so re-execution is clean),
        and a jitted segment failing with a non-transient error falls
        back to its composed-eager form once — mirroring the
        compile-time probe fallback — before giving up.  ``run=None`` is
        the fault-free serial fast path (no injection, default retry
        policy)."""
        what = (f"segment {seg.index} on lane {seg.lane!r} "
                f"(ops {seg.items[0]}..{seg.items[-1]})")

        def attempt():
            if run is not None and run.faults is not None:
                for (r, i) in seg.items:
                    run.faults.fire(seg.lane, r, i, run)
            seg.execute(results, ext)

        r0, i0 = seg.items[0]
        if run is not None:
            run.current[seg.lane] = what
        try:
            run_with_retries(run, attempt, what,
                             lane=seg.lane, request=r0, op=i0)
        except (ExecutionError, RecoverableError):
            raise
        except Exception:
            if seg.mode != JIT:
                raise
            # jitted form failed eagerly-unseen (e.g. a donated-buffer or
            # tracing edge on later inputs): demote to composed-Python
            # and retry once, mirroring the probe's fallback rule
            seg.mode = PYTHON
            seg._jfn = None
            run_with_retries(run, attempt, what,
                             lane=seg.lane, request=r0, op=i0)
        finally:
            if run is not None:
                run.current.pop(seg.lane, None)

    def run(self, external_inputs=None, *,
            policy: ExecutionPolicy | None = None,
            faults: FaultPlan | None = None,
            estimate: float | None = None,
            completed=None,
            segment_timings: list | None = None):
        """Execute the program; same results shape as the interpreter.

        ``policy`` tunes the watchdog/retry runtime (``estimate`` — e.g.
        the plan's cost-model latency — scales the watchdog budget) and
        ``faults`` injects a scripted
        :class:`~repro.core.faults.FaultPlan`.  Every cross-lane wait is
        deadline-bounded; on a permanent PU loss the raised
        :class:`~repro.core.errors.PULostError` carries the execution
        frontier (results of every segment completed before the loss).

        ``completed`` seeds the results with an execution frontier (one
        ``{op: value}`` dict for single-graph programs, a sequence of
        them for M-request programs): a program compiled over a *window*
        of remaining ops (``compile_concurrent(..., completed=...)``)
        reads its cross-window inputs from the frontier instead of
        recomputing them.  ``segment_timings``, when a list, receives one
        ``(lane, items, wall_seconds)`` tuple per completed segment — the
        compiled path's advance-event / drift-measurement feed, mirroring
        the interpreter's ``op_timings``.
        """
        if self.single:
            ext = [dict(external_inputs or {})]
            seeds = [dict(completed or {})]
        else:
            ext_seq = list(external_inputs or [None] * self.n_requests)
            if len(ext_seq) != self.n_requests:
                raise ValueError(
                    f"program covers {self.n_requests} requests, got "
                    f"{len(ext_seq)} input mapping(s)")
            ext = [dict(e or {}) for e in ext_seq]
            seeds = [dict(c or {}) for c in
                     (completed or [None] * self.n_requests)]
        results: list[dict[int, Any]] = seeds

        def exec_seg(seg: Segment, run: RunContext | None) -> None:
            t0 = time.monotonic() if segment_timings is not None else 0.0
            self._exec_segment(seg, results, ext, run)
            if segment_timings is not None:
                segment_timings.append(
                    (seg.lane, tuple(seg.items), time.monotonic() - t0))

        if self.serial_order is not None:
            # inherently serial: no cross-lane waits exist, so the
            # watchdog has nothing to bound — fault-free runs skip the
            # RunContext entirely (this is the warm fast path)
            run = (RunContext(policy, faults, estimate)
                   if faults is not None else None)
            try:
                for seg in self.serial_order:
                    exec_seg(seg, run)
            except PULostError as e:
                if e.partial is None:
                    e.partial = [dict(res) for res in results]
                raise
            self.runs += 1
            return results[0] if self.single else results

        run = RunContext(policy, faults, estimate)
        done = [threading.Event() for _ in self.segments]

        def release_all() -> None:
            for ev in done:
                ev.set()

        run.release = release_all

        def lane_worker(pu: str) -> None:
            try:
                for seg in self.lane_segments[pu]:
                    for d, dwhat in zip(seg.deps, seg.dep_whats):
                        if not done[d].is_set():
                            run.wait(done[d], dwhat)
                    run.check_abort()
                    exec_seg(seg, run)
                    done[seg.index].set()
            except _Aborted:
                pass  # a peer already failed; unwind silently
            except BaseException as e:
                run.fail(e)

        if self._pool is None:
            self._pool = LanePool(self.lanes)
        tasks = [(pu, self._pool.submit(pu, lambda pu=pu: lane_worker(pu)))
                 for pu in self.lanes]
        for pu, task_done in tasks:
            if run.deadline is None:
                task_done.wait()
            elif not task_done.wait(
                    max(run.deadline - time.monotonic(), 0.0) + _JOIN_GRACE):
                # backstop: a payload the watchdog cannot interrupt wedged
                # this worker — drop the whole pool (daemon threads; the
                # next run builds a fresh one) and surface a typed timeout
                run.abort.set()
                release_all()
                self.close()
                raise run._timeout(f"lane worker {pu!r}")
        if run.errors:
            err = run.first_error()
            if isinstance(err, PULostError) and err.partial is None:
                err.partial = [dict(res) for res in results]
            raise err
        self.runs += 1
        return results[0] if self.single else results


def compile_lane_program(graphs: Sequence[OpGraph],
                         lane_items: Mapping[str, Sequence[tuple[int, int]]],
                         barriers: frozenset[tuple[int, int]] | set = frozenset(),
                         single: bool = False,
                         targets: Mapping[str, Any] | None = None
                         ) -> LaneProgram:
    """Partition per-lane op queues into segments and build the program.

    ``lane_items`` maps each PU lane to its FIFO queue of ``(request,
    op)`` pairs (already validated/ordered by the executor); ``barriers``
    are co-scheduled concurrent-step ops that must stay single-op
    segments.  Cut rules, applied walking each queue in order — a new
    segment starts when:

    * the op (or the previous op) is a barrier op,
    * the request changes (segments never span requests), or
    * any predecessor ran on a *different* lane (the handoff cut: waits
      happen only at segment starts, so a cross-lane input is only legal
      for a segment's first op).

    Same-lane predecessors never cut (earlier queue position ⇒ an earlier
    segment on the same FIFO lane ⇒ already complete).

    A predecessor absent from every lane queue is a *frontier* op (window
    programs over a partially-executed plan): it cuts like a cross-lane
    handoff and resolves as a flat input read from the ``completed``
    seeds at run time, with no segment dependency.

    ``targets`` optionally binds lane names to
    :class:`~repro.core.targets.Target`\\ s: a bound segment keeps the
    reference payloads as its probe oracle and additionally resolves the
    target dialect's variant payloads at compile time (served only after
    the cold-run verification — see :class:`Segment`).
    """
    lane_of: dict[tuple[int, int], str] = {}
    for pu, items in lane_items.items():
        for it in items:
            lane_of[it] = pu

    tmap = dict(targets or {})
    segments: list[Segment] = []
    lane_segments: dict[str, list[Segment]] = {pu: [] for pu in lane_items}
    seg_of: dict[tuple[int, int], Segment] = {}
    for pu, items in lane_items.items():
        cur: Segment | None = None
        for (r, i) in items:
            barrier = (r, i) in barriers
            cross = any(lane_of.get((r, p)) != pu
                        for p in graphs[r].pred[i])
            if (cur is None or barrier or cur.barrier
                    or cur.items[-1][0] != r or cross):
                cur = Segment(index=len(segments), lane=pu, barrier=barrier,
                              target=tmap.get(pu))
                segments.append(cur)
                lane_segments[pu].append(cur)
            cur.items.append((r, i))
            cur.fns.append(graphs[r].ops[i].fn)
            seg_of[(r, i)] = cur

    # compile-time variant selection: a segment on a non-"ref"-dialect
    # target gets the resolved variant payload list iff any op actually
    # carries a variant for that dialect (otherwise the reference path
    # is the variant path and nothing needs verifying)
    for seg in segments:
        tgt = seg.target
        if tgt is None or tgt.dialect in (None, "ref"):
            continue
        vf = [graphs[r].ops[i].payload_for(tgt.dialect)
              for (r, i) in seg.items]
        if any(v is not f for v, f in zip(vf, seg.fns)):
            seg.var_fns = vf

    for seg in segments:
        internal = {it: t for t, it in enumerate(seg.items)}
        flat_index: dict[tuple[int, int], int] = {}
        deps: set[int] = set()
        for (r, i) in seg.items:
            spec: list[tuple[str, int]] = []
            for p in graphs[r].pred[i]:
                src = (r, p)
                t2 = internal.get(src)
                if t2 is not None:
                    spec.append(("o", t2))
                    continue
                j = flat_index.setdefault(src, len(flat_index))
                spec.append(("f", j))
                producer = seg_of.get(src)
                if producer is not None and producer.lane != seg.lane:
                    deps.add(producer.index)
            seg.argspecs.append(spec)
        seg.flat_refs = sorted(flat_index, key=flat_index.get)
        seg.deps = sorted(deps)
        seg.dep_whats = [
            f"segment {seg.index} on lane {seg.lane!r} (first op "
            f"{seg.items[0]}) waiting for segment {d} on lane "
            f"{segments[d].lane!r} (ops {segments[d].items[0]}.."
            f"{segments[d].items[-1]})"
            for d in seg.deps]
    return LaneProgram(graphs, segments, lane_segments, single=single)
