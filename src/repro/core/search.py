"""Search engine (Algorithm 1, Stage 3).

* ``dijkstra`` — textbook Dijkstra over the explicit execution graph
  (node-weighted; node weights folded into incoming edges).
* ``sequential_dp`` — the O(N K^2) topological-order recurrence (Eq. 1).
  Tests assert both give identical costs.
* ``solve_parallel`` — phase/branch partitioning + per-branch Dijkstra +
  contention-adjusted makespans (§3.3.2).
* ``solve_concurrent_aligned`` / ``solve_concurrent_joint`` — the two
  multi-model modes (§3.2.2 / §3.3.3).
"""
from __future__ import annotations

import heapq
from typing import Mapping, Sequence

from .contention import ContentionModel
from .costmodel import CostTable, PUSpec, transition_cost
from .graph import ExecGraph, build_sequential_graph, node_weight
from .op import FusedOp, OpGraph
from .schedule import (BranchSchedule, ConcurrentSchedule, ConcurrentStep,
                       ParallelSchedule, PhaseSchedule, SeqSchedule,
                       evaluate_sequential)

# ---------------------------------------------------------------------------
# Shortest path on the explicit graph
# ---------------------------------------------------------------------------


def dijkstra(g: ExecGraph) -> tuple[float, list[str]]:
    """Shortest s->t path; returns (cost, PU assignment per chain position)."""
    INF = float("inf")
    dist: dict[int, float] = {g.S: 0.0}
    prev: dict[int, int] = {}
    heap: list[tuple[float, int]] = [(0.0, g.S)]
    done: set[int] = set()
    while heap:
        d, u = heapq.heappop(heap)
        if u in done:
            continue
        done.add(u)
        if u == g.T:
            break
        for v, ew in g.adj.get(u, ()):  # edge weight + node weight of v
            nd = d + ew + g.node_w.get(v, 0.0)
            if nd < dist.get(v, INF):
                dist[v] = nd
                prev[v] = u
                heapq.heappush(heap, (nd, v))
    if g.T not in dist:
        raise ValueError("no feasible path (some op unsupported everywhere?)")
    # reconstruct
    rev_ids = {v: k for k, v in g.node_ids.items()}
    path: list[str] = []
    cur = g.T
    while cur != g.S:
        cur = prev[cur]
        if cur in rev_ids:
            path.append(rev_ids[cur][1])
    path.reverse()
    return dist[g.T], path


def sequential_dp(
    chain: Sequence[int],
    ops: Sequence[FusedOp],
    table: CostTable,
    pus: Mapping[str, PUSpec],
    objective: str = "latency",
) -> tuple[float, list[str]]:
    """Eq. (1) dynamic program; identical optimum to ``dijkstra``."""
    INF = float("inf")

    def escale(pu: str) -> float:
        return pus[pu].power_memory if objective == "energy" else 1.0

    sup = [table.supported_pus(oi) for oi in chain]
    # base case: cost(1, j) = H2D(O_1, P_j) + w(v_1j)
    cost = {p: table.require(chain[0], p).h2d * escale(p)
            + node_weight(table.require(chain[0], p), objective)
            for p in sup[0]}
    back: list[dict[str, str]] = []
    for pos in range(1, len(chain)):
        oi_prev, oi = chain[pos - 1], chain[pos]
        ncost: dict[str, float] = {}
        nback: dict[str, str] = {}
        for pj in sup[pos]:
            w = node_weight(table.require(oi, pj), objective)
            best, barg = INF, None
            for pk in sup[pos - 1]:
                tc = transition_cost(pus, table, oi_prev, pk, oi, pj) * escale(pj)
                c = cost[pk] + tc
                if c < best:
                    best, barg = c, pk
            ncost[pj] = w + best
            nback[pj] = barg
        cost = ncost
        back.append(nback)
    # final D2H
    lastpos = len(chain) - 1
    best, bp = INF, None
    for p in sup[lastpos]:
        c = cost[p] + table.require(chain[lastpos], p).d2h * escale(p)
        if c < best:
            best, bp = c, p
    # backtrack
    assign = [bp]
    for pos in range(len(chain) - 1, 0, -1):
        bp = back[pos - 1][bp]
        assign.append(bp)
    assign.reverse()
    return best, assign


def solve_sequential(
    chain: Sequence[int],
    ops: Sequence[FusedOp],
    table: CostTable,
    pus: Mapping[str, PUSpec],
    objective: str = "latency",
    algorithm: str = "dijkstra",
) -> SeqSchedule:
    if algorithm == "dijkstra":
        g = build_sequential_graph(chain, ops, table, pus, objective)
        _, assign = dijkstra(g)
    elif algorithm == "dp":
        _, assign = sequential_dp(chain, ops, table, pus, objective)
    else:
        raise ValueError(algorithm)
    lat, eng = evaluate_sequential(chain, assign, ops, table, pus)
    return SeqSchedule(chain=list(chain), assignment=assign, latency=lat,
                       energy=eng, objective=objective)


# ---------------------------------------------------------------------------
# Intra-model parallel search (§3.3.2)
# ---------------------------------------------------------------------------


def solve_parallel(
    graph: OpGraph,
    table: CostTable,
    pus: Mapping[str, PUSpec],
    contention: ContentionModel | None = None,
    objective: str = "latency",
) -> ParallelSchedule:
    """Phase partition -> per-branch Dijkstra -> contention-adjusted makespan.

    Per phase we also evaluate serialising all branches on the per-branch
    optimal assignments and keep whichever is cheaper, so parallel
    orchestration never regresses below the sequential schedule (paper
    Table 3 reports parallel speedup >= sequential speedup everywhere).
    """
    contention = contention or ContentionModel()
    phases_out: list[PhaseSchedule] = []
    total_lat = 0.0
    total_eng = 0.0
    for phase in graph.phases():
        brs: list[BranchSchedule] = []
        for br in phase.branches:
            s = solve_sequential(br.ops, graph.ops, table, pus, objective)
            brs.append(BranchSchedule(
                branch_ops=list(br.ops), assignment=s.assignment,
                solo_latency=s.latency, adj_latency=s.latency, energy=s.energy))
        if len(brs) > 1:
            # contention adjustment: every op cost scaled by the max SF vs
            # the PU set used by the *other* branches.
            pu_sets = [set(b.assignment) for b in brs]
            for bi, b in enumerate(brs):
                others: set[str] = set().union(
                    *(pu_sets[j] for j in range(len(brs)) if j != bi)) if len(brs) > 1 else set()
                lat_adj = 0.0
                eng_adj = 0.0
                # re-walk the branch applying per-op SF; transitions unscaled
                chain, assign = b.branch_ops, b.assignment
                e0 = table.require(chain[0], assign[0])
                lat_adj += e0.h2d
                eng_adj += e0.h2d * pus[assign[0]].power_memory
                for pos, (oi, p) in enumerate(zip(chain, assign)):
                    e = table.require(oi, p)
                    sf = contention.branch_factor(p, others)
                    lat_adj += e.w * sf
                    eng_adj += e.w * sf * e.power
                    if pos + 1 < len(chain):
                        tc = transition_cost(pus, table, oi, p,
                                             chain[pos + 1], assign[pos + 1])
                        lat_adj += tc
                        eng_adj += tc * pus[assign[pos + 1]].power_memory
                eN = table.require(chain[-1], assign[-1])
                lat_adj += eN.d2h
                eng_adj += eN.d2h * pus[assign[-1]].power_memory
                b.adj_latency = lat_adj
                b.energy = eng_adj
            par_makespan = max(b.adj_latency for b in brs)
            par_energy = sum(b.energy for b in brs)
            seq_makespan = sum(b.solo_latency for b in brs)
            # serialised energy: recompute without SF (solo energies)
            seq_energy = 0.0
            for b in brs:
                _, e = evaluate_sequential(b.branch_ops, b.assignment,
                                           graph.ops, table, pus)
                seq_energy += e
            key_par = par_makespan if objective == "latency" else par_energy
            key_seq = seq_makespan if objective == "latency" else seq_energy
            if key_par <= key_seq:
                phases_out.append(PhaseSchedule(
                    index=phase.index, parallel=True, branches=brs,
                    makespan=par_makespan, energy=par_energy))
                total_lat += par_makespan
                total_eng += par_energy
            else:
                for b in brs:  # revert adjustment bookkeeping
                    b.adj_latency = b.solo_latency
                phases_out.append(PhaseSchedule(
                    index=phase.index, parallel=False, branches=brs,
                    makespan=seq_makespan, energy=seq_energy))
                total_lat += seq_makespan
                total_eng += seq_energy
        else:
            b = brs[0]
            phases_out.append(PhaseSchedule(
                index=phase.index, parallel=False, branches=brs,
                makespan=b.solo_latency, energy=b.energy))
            total_lat += b.solo_latency
            total_eng += b.energy
    return ParallelSchedule(phases=phases_out, latency=total_lat,
                            energy=total_eng, objective=objective)


# ---------------------------------------------------------------------------
# Multi-model concurrent search (§3.2.2 / §3.3.3)
# ---------------------------------------------------------------------------


def _solo_w(table: CostTable, oi: int, pu: str) -> float:
    return table.require(oi, pu).w


def solve_concurrent_aligned(
    chain0: Sequence[int], table0: CostTable,
    chain1: Sequence[int], table1: CostTable,
    pus: Mapping[str, PUSpec],
    contention: ContentionModel | None = None,
    objective: str = "latency",
) -> ConcurrentSchedule:
    """Aligned Dijkstra: both requests advance in lockstep (same-model pairs).

    At each step the search selects a PU pair (d0, d1).  Same-PU step cost =
    average of measured concurrent execution times; cross-PU = max of
    (contention-adjusted) solo times.  Tails (unequal lengths) advance solo.
    """
    contention = contention or ContentionModel()
    n = min(len(chain0), len(chain1))
    steps: list[ConcurrentStep] = []
    total = 0.0
    energy = 0.0
    for i in range(n):
        o0, o1 = chain0[i], chain1[i]
        best = None
        for d0 in table0.supported_pus(o0):
            t0 = _solo_w(table0, o0, d0)
            p0 = table0.require(o0, d0).power
            for d1 in table1.supported_pus(o1):
                t1 = _solo_w(table1, o1, d1)
                p1 = table1.require(o1, d1).power
                step = contention.pair_step_cost(t0, d0, t1, d1)
                cc0, cc1 = contention.co_exec(t0, d0, t1, d1)
                # energy: each op runs for its concurrent duration at its
                # PU's power (time-shared same-PU execution draws the PU's
                # power once -> charge each op its solo share).
                if d0 == d1:
                    e = t0 * p0 + t1 * p1
                else:
                    e = cc0 * p0 + cc1 * p1
                key = step if objective == "latency" else e
                if best is None or key < best[0]:
                    best = (key, step, e, d0, d1)
        _, step_cost, step_energy, d0, d1 = best
        steps.append(ConcurrentStep(ops=(o0, o1), pus=(d0, d1), cost=step_cost))
        total += step_cost
        energy += step_energy
    # solo tail for the longer request
    longer, table_l, idx = ((chain0, table0, 0) if len(chain0) > n
                            else (chain1, table1, 1))
    for i in range(n, len(longer)):
        oi = longer[i]
        cands = [(node_weight(table_l.require(oi, p), "latency"),
                  table_l.require(oi, p).energy, p)
                 for p in table_l.supported_pus(oi)]
        key_i = 0 if objective == "latency" else 1
        w, e, p = min(cands, key=lambda c: c[key_i])
        ops = (oi, None) if idx == 0 else (None, oi)
        pus_ = (p, None) if idx == 0 else (None, p)
        steps.append(ConcurrentStep(ops=ops, pus=pus_, cost=w))
        total += w
        energy += e
    return ConcurrentSchedule(steps=steps, latency=total, energy=energy,
                              objective=objective, mode="aligned")


def solve_concurrent_joint(
    chain0: Sequence[int], table0: CostTable,
    chain1: Sequence[int], table1: CostTable,
    pus: Mapping[str, PUSpec],
    contention: ContentionModel | None = None,
    objective: str = "latency",
) -> ConcurrentSchedule:
    """Joint (i, j) Dijkstra: each request's progress tracked independently.

    State (i, j) = completed op counts.  Transitions: advance both
    (i+1, j+1), advance request 0 solo (i+1, j), or advance request 1 solo
    (i, j+1) — allowing asymmetric completion with solo tails (paper
    §3.2.2).
    """
    contention = contention or ContentionModel()
    n0, n1 = len(chain0), len(chain1)
    INF = float("inf")
    dist: dict[tuple[int, int], float] = {(0, 0): 0.0}
    prev: dict[tuple[int, int], tuple[tuple[int, int], ConcurrentStep, float]] = {}
    heap: list[tuple[float, tuple[int, int]]] = [(0.0, (0, 0))]
    done: set[tuple[int, int]] = set()

    def step_options(i: int, j: int):
        # (next_state, step, objective_key, energy)
        if i < n0 and j < n1:
            o0, o1 = chain0[i], chain1[j]
            for d0 in table0.supported_pus(o0):
                t0 = _solo_w(table0, o0, d0)
                p0 = table0.require(o0, d0).power
                for d1 in table1.supported_pus(o1):
                    t1 = _solo_w(table1, o1, d1)
                    p1 = table1.require(o1, d1).power
                    step = contention.pair_step_cost(t0, d0, t1, d1)
                    cc0, cc1 = contention.co_exec(t0, d0, t1, d1)
                    e = (t0 * p0 + t1 * p1) if d0 == d1 else (cc0 * p0 + cc1 * p1)
                    yield ((i + 1, j + 1),
                           ConcurrentStep(ops=(o0, o1), pus=(d0, d1), cost=step),
                           step if objective == "latency" else e, e)
        if i < n0:
            o0 = chain0[i]
            for d0 in table0.supported_pus(o0):
                ent = table0.require(o0, d0)
                yield ((i + 1, j),
                       ConcurrentStep(ops=(o0, None), pus=(d0, None), cost=ent.w),
                       ent.w if objective == "latency" else ent.energy, ent.energy)
        if j < n1:
            o1 = chain1[j]
            for d1 in table1.supported_pus(o1):
                ent = table1.require(o1, d1)
                yield ((i, j + 1),
                       ConcurrentStep(ops=(None, o1), pus=(None, d1), cost=ent.w),
                       ent.w if objective == "latency" else ent.energy, ent.energy)

    target = (n0, n1)
    while heap:
        d, st = heapq.heappop(heap)
        if st in done:
            continue
        done.add(st)
        if st == target:
            break
        for nxt, step, key, e in step_options(*st):
            nd = d + key
            if nd < dist.get(nxt, INF):
                dist[nxt] = nd
                prev[nxt] = (st, step, e)
                heapq.heappush(heap, (nd, nxt))
    if target not in dist:
        raise ValueError("joint search failed to reach target state")
    # reconstruct
    steps: list[ConcurrentStep] = []
    energy = 0.0
    cur = target
    while cur != (0, 0):
        st, step, e = prev[cur]
        steps.append(step)
        energy += e
        cur = st
    steps.reverse()
    latency = sum(s.cost for s in steps)
    return ConcurrentSchedule(steps=steps, latency=latency, energy=energy,
                              objective=objective, mode="joint")
