"""Search engine (Algorithm 1, Stage 3).

* ``dijkstra`` — textbook Dijkstra over the explicit execution graph
  (node-weighted; node weights folded into incoming edges).
* ``sequential_dp`` — the O(N K^2) topological-order recurrence (Eq. 1),
  vectorized to one NumPy matrix op per chain position over the dense
  ``(K, K)`` transition matrix (``graph.DenseChain``).  The scalar
  reference (``sequential_dp_reference``) is kept; tests assert both give
  bit-identical costs and assignments, and both equal ``dijkstra``.
* ``solve_parallel`` — phase/branch partitioning + per-branch search +
  contention-adjusted makespans (§3.3.2); the contention re-walk is a
  gathered-array computation instead of a per-op Python loop.
* ``solve_dag`` — the unified front door over op DAGs: antichain-frontier
  scheduling whose state is an order ideal of DAG nodes.  Linear chains
  dispatch to the chain DP, disjoint unions of chains to the exact grid
  sweep, and fork/join shapes to ``solve_parallel`` — each **bit-for-bit**
  (the retained solvers are the shape-restricted oracles) — while
  ``algorithm="frontier"`` runs the genuine generalization
  (``_solve_dag_frontier``): exact DP over order ideals with co-scheduled
  antichain steps priced by the same solo edges / group-law tables as the
  grid sweep, finding cross-phase overlaps the branch route cannot.
* ``solve_concurrent_aligned`` / ``solve_concurrent_joint`` — the two
  pair modes (§3.2.2 / §3.3.3).  The joint solver is A* over the
  (i, j) progress grid: edge costs come from memoized ``(K0, K1)``
  pair-cost matrices (``contention.PairCostCache``) reduced to one
  min-edge per transition, and the admissible heuristic is the exact
  cost-to-go computed by a vectorized backward DP over the grid
  (``_cost_to_go``; the loose suffix-sum bound ``_suffix_heuristic`` is
  kept for validation).  Priorities are quantized and f-ties break
  toward deeper states, so exact-cost tie plateaus (ubiquitous in energy
  mode) are traversed in O(path) instead of flooding the grid.  Scalar
  reference implementations (``*_reference``) are retained and used
  automatically for ``ContentionModel`` subclasses that override the
  co-execution cost laws.
* ``solve_concurrent`` — the M-request generalization over ``Workload``
  views: M = 2 dispatches to the pair A* bit-for-bit; M-dimensional
  progress grids up to ``max_states`` are searched exactly by a
  **vectorized anti-diagonal sweep** (``_solve_concurrent_grid``): all
  states with equal total progress are relaxed together, one batched
  NumPy relaxation per advance subset, singleton advances priced from
  the dense solo-edge arrays and group advances gathered from
  per-(subset, signature-tuple) edge tables built once per solve
  (``contention.GroupCostCache``, the M-ary ``PairCostCache``).  The
  pre-vectorization heap A* is retained as ``algorithm="grid_astar"``
  (equivalence oracle).  Grids beyond ``max_states`` stitch a
  **rolling-horizon merge** (``_solve_concurrent_rolling``): exact sweep
  over a bounded window of next ops across ALL M requests, window after
  window.  The old pairwise-merge schedule
  (``_solve_concurrent_pairwise``) survives only as the
  custom-contention fallback.

All solvers consume the dense ``Workload`` layer; the scalar dict
``CostTable`` is ingested once at the boundary (``Workload.build``) and
only the ``*_reference`` oracles walk it.
"""
from __future__ import annotations

import heapq
import itertools
import math
from typing import Mapping, Sequence

import numpy as np

from .contention import (ContentionModel, GroupCostCache, PairCostCache,
                         uses_default_coexec, uses_default_group)
from .costmodel import CostTable, DenseCostTable, PUSpec, transition_cost
from .errors import InfeasibleScheduleError
from .graph import (DenseChain, ExecGraph, build_dense_chain,
                    build_sequential_graph, node_weight)
from .op import FusedOp, OpGraph
from .schedule import (BranchSchedule, ConcurrentSchedule, ConcurrentStep,
                       DagSchedule, DagStep, ParallelSchedule, PhaseSchedule,
                       SeqSchedule)
from .workload import Workload

# ---------------------------------------------------------------------------
# Shortest path on the explicit graph
# ---------------------------------------------------------------------------


def dijkstra(g: ExecGraph) -> tuple[float, list[str]]:
    """Shortest s->t path; returns (cost, PU assignment per chain position)."""
    INF = float("inf")
    dist: dict[int, float] = {g.S: 0.0}
    prev: dict[int, int] = {}
    heap: list[tuple[float, int]] = [(0.0, g.S)]
    done: set[int] = set()
    while heap:
        d, u = heapq.heappop(heap)
        if u in done:
            continue
        done.add(u)
        if u == g.T:
            break
        for v, ew in g.adj.get(u, ()):  # edge weight + node weight of v
            nd = d + ew + g.node_w.get(v, 0.0)
            if nd < dist.get(v, INF):
                dist[v] = nd
                prev[v] = u
                heapq.heappush(heap, (nd, v))
    if g.T not in dist:
        raise ValueError("no feasible path (some op unsupported everywhere?)")
    # reconstruct
    rev_ids = {v: k for k, v in g.node_ids.items()}
    path: list[str] = []
    cur = g.T
    while cur != g.S:
        cur = prev[cur]
        if cur in rev_ids:
            path.append(rev_ids[cur][1])
    path.reverse()
    return dist[g.T], path


# ---------------------------------------------------------------------------
# Sequential DP (Eq. 1) — vectorized + scalar reference
# ---------------------------------------------------------------------------


def sequential_dp(
    chain: Sequence[int],
    ops: Sequence[FusedOp],
    table: CostTable,
    pus: Mapping[str, PUSpec],
    objective: str = "latency",
    dense: DenseCostTable | None = None,
) -> tuple[float, list[str]]:
    """Eq. (1) dynamic program over the dense chain's batched transition
    tensor: all ``(K, K)`` transition matrices and node weights are built
    in one vectorized shot, then the recurrence runs one matrix op per
    chain position (for small K — the edge SoC's 3 PUs — the per-position
    minimisation runs as a tight loop over the precomputed arrays
    instead, since NumPy's per-call overhead exceeds the K^2 arithmetic).

    Bit-identical to ``sequential_dp_reference`` (same additions in the
    same order, same first-minimum tie-break) and the same optimum as
    ``dijkstra``.
    """
    dc = build_dense_chain(chain, ops, table, pus, objective, dense=dense)
    n = len(chain)
    k = dc.dense.k
    pu_names = dc.dense.pus
    if k >= 8:
        cost = dc.entry_w + dc.node_w[0]             # (K,)
        trans = dc.transitions()
        back = np.empty((n - 1, k), dtype=np.int64) if n > 1 else None
        for pos in range(1, n):
            m = cost[:, None] + trans[pos - 1]       # (K, K): prev k -> next j
            back[pos - 1] = np.argmin(m, axis=0)     # first minimum, PU order
            cost = dc.node_w[pos] + np.min(m, axis=0)
        total = cost + dc.exit_w
        bp = int(np.argmin(total))
        best = float(total[bp])
        if not np.isfinite(best):
            raise ValueError(
                "no feasible path (some op unsupported everywhere?)")
        idxs = [bp]
        for pos in range(n - 1, 0, -1):
            bp = int(back[pos - 1][bp])
            idxs.append(bp)
        idxs.reverse()
        return best, [pu_names[i] for i in idxs]
    # small-K path: same recurrence over the same batched arrays
    INF = float("inf")
    trans = dc.transitions().tolist()
    nws = dc.node_w.tolist()
    cost = (dc.entry_w + dc.node_w[0]).tolist()
    rng = range(k)
    back: list[list[int]] = []
    for pos in range(1, n):
        t = trans[pos - 1]
        nw = nws[pos]
        ncost = [0.0] * k
        nback = [0] * k
        for j in rng:
            best, barg = INF, 0
            for kk in rng:
                c = cost[kk] + t[kk][j]
                if c < best:
                    best, barg = c, kk
            ncost[j] = nw[j] + best
            nback[j] = barg
        cost = ncost
        back.append(nback)
    exit_w = dc.exit_w.tolist()
    best, bp = INF, 0
    for j in rng:
        c = cost[j] + exit_w[j]
        if c < best:
            best, bp = c, j
    if best == INF:
        raise ValueError("no feasible path (some op unsupported everywhere?)")
    idxs = [bp]
    for pos in range(n - 1, 0, -1):
        bp = back[pos - 1][bp]
        idxs.append(bp)
    idxs.reverse()
    return best, [pu_names[i] for i in idxs]


def sequential_dp_reference(
    chain: Sequence[int],
    ops: Sequence[FusedOp],
    table: CostTable,
    pus: Mapping[str, PUSpec],
    objective: str = "latency",
) -> tuple[float, list[str]]:
    """Scalar Eq. (1) recurrence (pre-vectorization reference)."""
    INF = float("inf")

    def escale(pu: str) -> float:
        return pus[pu].power_memory if objective == "energy" else 1.0

    sup = [table.supported_pus(oi) for oi in chain]
    # base case: cost(1, j) = H2D(O_1, P_j) + w(v_1j)
    cost = {p: table.require(chain[0], p).h2d * escale(p)
            + node_weight(table.require(chain[0], p), objective)
            for p in sup[0]}
    back: list[dict[str, str]] = []
    for pos in range(1, len(chain)):
        oi_prev, oi = chain[pos - 1], chain[pos]
        ncost: dict[str, float] = {}
        nback: dict[str, str] = {}
        for pj in sup[pos]:
            w = node_weight(table.require(oi, pj), objective)
            best, barg = INF, None
            for pk in sup[pos - 1]:
                tc = transition_cost(pus, table, oi_prev, pk, oi, pj) * escale(pj)
                c = cost[pk] + tc
                if c < best:
                    best, barg = c, pk
            ncost[pj] = w + best
            nback[pj] = barg
        cost = ncost
        back.append(nback)
    # final D2H
    lastpos = len(chain) - 1
    best, bp = INF, None
    for p in sup[lastpos]:
        c = cost[p] + table.require(chain[lastpos], p).d2h * escale(p)
        if c < best:
            best, bp = c, p
    # backtrack
    assign = [bp]
    for pos in range(len(chain) - 1, 0, -1):
        bp = back[pos - 1][bp]
        assign.append(bp)
    assign.reverse()
    return best, assign


def solve_sequential(
    chain: Sequence[int],
    ops: Sequence[FusedOp],
    table: CostTable | None,
    pus: Mapping[str, PUSpec],
    objective: str = "latency",
    algorithm: str = "dp",
    workload: Workload | None = None,
) -> SeqSchedule:
    """Sequential solve on the dense ``Workload`` layer.

    Pass ``workload`` to reuse a prebuilt dense view (``table`` may then
    be ``None``); otherwise the scalar table is ingested once here.  The
    ``dijkstra`` / ``dp_reference`` algorithms are the explicit-graph /
    scalar oracles and still walk the dict table.
    """
    wl = workload if workload is not None else Workload.build(
        chain, table, pus, ops=ops)
    oracle_table = table if table is not None else wl.table
    if algorithm in ("dijkstra", "dp_reference") and oracle_table is None:
        raise ValueError(
            f"algorithm={algorithm!r} walks the scalar oracle table, but "
            "none is available (the workload is a derived dense view); "
            "pass the table or use algorithm='dp'")
    if algorithm == "dijkstra":
        g = build_sequential_graph(chain, ops, oracle_table, pus, objective)
        _, assign = dijkstra(g)
    elif algorithm == "dp":
        _, assign = sequential_dp(chain, ops, table, pus, objective,
                                  dense=wl.dense)
    elif algorithm == "dp_reference":
        _, assign = sequential_dp_reference(chain, ops, oracle_table, pus,
                                            objective)
    else:
        raise ValueError(algorithm)
    lat, eng = wl.evaluate(assign)
    return SeqSchedule(chain=list(chain), assignment=assign, latency=lat,
                       energy=eng, objective=objective)


# ---------------------------------------------------------------------------
# Intra-model parallel search (§3.3.2)
# ---------------------------------------------------------------------------


def _rewalk_branch(
    wl: Workload, assign: Sequence[str], contention: ContentionModel,
    others: set[str],
) -> tuple[float, float]:
    """Contention-adjusted (latency, energy) of a fixed branch assignment:
    every op cost scaled by the max SF vs the PU set used by the *other*
    branches; transitions unscaled.  One gather over the branch
    workload's dense rows — O(branch length), not O(model size)."""
    d = wl.dense
    c = wl.cols(assign)
    rows = np.arange(d.n)
    wv = d.w[rows, c]
    pv = d.power[rows, c]
    h2dv = d.h2d[rows, c]
    d2hv = d.d2h[rows, c]
    accv = d.acc[c]
    sf_of = {p: contention.branch_factor(p, others) for p in set(assign)}
    sfv = np.array([sf_of[p] for p in assign])
    pmv = wl.power_memory[c]
    # inter-op transitions (same PU -> 0; accelerator-gated H2D/D2H)
    same = c[1:] == c[:-1]
    tcv = np.where(same, 0.0,
                   np.where(accv[1:], h2dv[1:], 0.0)
                   + np.where(accv[:-1], d2hv[:-1], 0.0))
    lat = float(h2dv[0] + np.sum(wv * sfv) + np.sum(tcv) + d2hv[-1])
    eng = float(h2dv[0] * pmv[0] + np.sum(wv * sfv * pv)
                + np.sum(tcv * pmv[1:]) + d2hv[-1] * pmv[-1])
    return lat, eng


def solve_parallel(
    graph: OpGraph,
    table: CostTable | None,
    pus: Mapping[str, PUSpec],
    contention: ContentionModel | None = None,
    objective: str = "latency",
    workload: Workload | None = None,
) -> ParallelSchedule:
    """Phase partition -> per-branch search -> contention-adjusted makespan.

    Per phase we also evaluate serialising all branches on the per-branch
    optimal assignments and keep whichever is cheaper, so parallel
    orchestration never regresses below the sequential schedule (paper
    Table 3 reports parallel speedup >= sequential speedup everywhere).

    The whole graph is ingested into one ``Workload``; per-branch views
    are row-selections of it (no dict walks per branch).
    """
    contention = contention or ContentionModel()
    wl_full = workload if workload is not None else Workload.build(
        list(range(len(graph.ops))), table, pus, ops=graph.ops)
    phases_out: list[PhaseSchedule] = []
    total_lat = 0.0
    total_eng = 0.0
    for phase in graph.phases():
        brs: list[BranchSchedule] = []
        br_wls: list[Workload] = []
        for br in phase.branches:
            bwl = wl_full.select(br.ops)
            s = solve_sequential(br.ops, graph.ops, table, pus, objective,
                                 workload=bwl)
            br_wls.append(bwl)
            brs.append(BranchSchedule(
                branch_ops=list(br.ops), assignment=s.assignment,
                solo_latency=s.latency, adj_latency=s.latency, energy=s.energy))
        if len(brs) > 1:
            pu_sets = [set(b.assignment) for b in brs]
            for bi, b in enumerate(brs):
                others: set[str] = set().union(
                    *(pu_sets[j] for j in range(len(brs)) if j != bi))
                b.adj_latency, b.energy = _rewalk_branch(
                    br_wls[bi], b.assignment, contention, others)
            par_makespan = max(b.adj_latency for b in brs)
            par_energy = sum(b.energy for b in brs)
            seq_makespan = sum(b.solo_latency for b in brs)
            # serialised energy: recompute without SF (solo energies)
            seq_energy = 0.0
            for bwl, b in zip(br_wls, brs):
                _, e = bwl.evaluate(b.assignment)
                seq_energy += e
            key_par = par_makespan if objective == "latency" else par_energy
            key_seq = seq_makespan if objective == "latency" else seq_energy
            if key_par <= key_seq:
                phases_out.append(PhaseSchedule(
                    index=phase.index, parallel=True, branches=brs,
                    makespan=par_makespan, energy=par_energy))
                total_lat += par_makespan
                total_eng += par_energy
            else:
                for b in brs:  # revert adjustment bookkeeping
                    b.adj_latency = b.solo_latency
                phases_out.append(PhaseSchedule(
                    index=phase.index, parallel=False, branches=brs,
                    makespan=seq_makespan, energy=seq_energy))
                total_lat += seq_makespan
                total_eng += seq_energy
        else:
            b = brs[0]
            phases_out.append(PhaseSchedule(
                index=phase.index, parallel=False, branches=brs,
                makespan=b.solo_latency, energy=b.energy))
            total_lat += b.solo_latency
            total_eng += b.energy
    return ParallelSchedule(phases=phases_out, latency=total_lat,
                            energy=total_eng, objective=objective)


# ---------------------------------------------------------------------------
# DAG (antichain-frontier) search — chains and branches unified
# ---------------------------------------------------------------------------


DAG_ALGORITHMS = ("auto", "chain", "union-grid", "phase", "frontier")

# A frontier advance co-schedules at most this many ready ops per step:
# one op per PU of the paper's edge SoC.  Larger antichains still
# execute (across consecutive steps); the cap bounds the per-ideal
# subset fan-out and the group-edge table size (``n_sig ** k`` cells).
_DAG_GROUP_CAP = 3


def _seq_to_dag(wl: Workload, s: SeqSchedule) -> DagSchedule:
    """Chain-route conversion: one singleton step per position.

    Step costs carry the exact sequential decomposition (boundary H2D on
    the first step, incoming transition per step, boundary D2H on the
    last), but ``latency``/``energy`` are the authoritative
    ``SeqSchedule`` values (bitwise the chain DP's)."""
    d = wl.dense
    c = wl.cols(s.assignment)
    rows = np.arange(d.n)
    cost = d.w[rows, c]            # fancy indexing: already a fresh array
    if cost.dtype != np.float64:
        cost = cost.astype(float)
    h2d = d.h2d[rows, c]
    d2h = d.d2h[rows, c]
    accv = d.acc[c]
    cost[0] += h2d[0]
    cost[-1] += d2h[-1]
    if d.n > 1:
        same = c[1:] == c[:-1]
        cost[1:] += np.where(same, 0.0,
                             np.where(accv[1:], h2d[1:], 0.0)
                             + np.where(accv[:-1], d2h[:-1], 0.0))
    pu_t = {p: (p,) for p in set(s.assignment)}   # few PUs, many steps
    steps = list(map(DagStep, zip(s.chain),      # zip -> the (op,) tuples
                     map(pu_t.__getitem__, s.assignment), cost.tolist()))
    return DagSchedule(steps=steps, latency=s.latency, energy=s.energy,
                       objective=s.objective, mode="chain")


def _concurrent_to_dag(cs: ConcurrentSchedule, mode: str) -> DagSchedule:
    """Union-of-chains conversion: drop the per-request ``None`` padding
    (each non-idle (op, pu) pair carries over in request order)."""
    steps = [DagStep(
        ops=tuple(o for o in st.ops if o is not None),
        pus=tuple(p for p in st.pus if p is not None),
        cost=st.cost) for st in cs.steps]
    return DagSchedule(steps=steps, latency=cs.latency, energy=cs.energy,
                       objective=cs.objective, mode=mode)


def _parallel_to_dag(par: ParallelSchedule) -> DagSchedule:
    """Phase-route conversion: one step per fork/join phase (a
    precedence-closed unit — ops listed branch-by-branch in branch
    order, *not* an antichain), cost = the phase makespan.  Latency,
    energy, and the per-op assignment are bitwise ``solve_parallel``'s.
    """
    steps = []
    for ph in par.phases:
        ops = tuple(o for b in ph.branches for o in b.branch_ops)
        pus_ = tuple(p for b in ph.branches for p in b.assignment)
        steps.append(DagStep(ops=ops, pus=pus_, cost=float(ph.makespan)))
    return DagSchedule(steps=steps, latency=par.latency, energy=par.energy,
                       objective=par.objective, mode="phase")


def solve_dag(
    graph: OpGraph,
    table: CostTable | None,
    pus: Mapping[str, PUSpec],
    contention: ContentionModel | None = None,
    objective: str = "latency",
    algorithm: str = "auto",
    workload: Workload | None = None,
    caches: ConcurrentCaches | None = None,
    max_states: int | None = None,
    group_cap: int = _DAG_GROUP_CAP,
) -> DagSchedule:
    """Schedule an op DAG as antichain-frontier advances — the front door
    that unifies the chain, branch, and general-DAG shapes.

    Routes (``algorithm="auto"`` picks the first match; each named route
    can be forced):

    * ``"chain"`` — a single linear chain: dispatches to the sequential
      chain DP **bit-for-bit** (full sequential cost semantics: boundary
      H2D/D2H and inter-op transitions included).
    * ``"union-grid"`` — a disjoint union of linear chains: dispatches
      each component to one axis of the exact anti-diagonal grid sweep
      **bit-for-bit** (the concurrent formulation: node weights only,
      group advances priced by the contention model's group laws).
    * ``"phase"`` — anything else: dispatches to the retained
      fork/join branch route (``solve_parallel``) **bit-for-bit** (the
      old branch re-walk, demoted to oracle duty).
    * ``"frontier"`` — the generalization (never auto-selected, so the
      oracle-reproducing routes above stay bitwise): exact DP over the
      DAG's order ideals, each step advancing an antichain of ready
      nodes, priced exactly like the grid sweep (solo edges for
      singletons, :class:`~repro.core.contention.GroupCostCache` group
      laws for co-scheduled sets).  On a union of chains the ideal
      lattice *is* the progress grid, so this reduces to today's sweep;
      on a general DAG it finds step-level co-schedules the phase route
      cannot (ops of different fork/join phases overlapping), which is
      the paper's intra-model-parallelism win.

    Pass ``workload`` (a DAG workload from :meth:`Workload.from_graph`,
    possibly ``under_condition``-adjusted) to reuse a prebuilt dense
    view; ``table`` may then be ``None``.  ``max_states`` bounds the
    frontier route's discovered order ideals (and the union route's
    grid) — a memory bound, as for ``solve_concurrent``.
    """
    contention = contention or ContentionModel()
    if algorithm not in DAG_ALGORITHMS:
        raise ValueError(algorithm)
    n_ops = len(graph.ops)
    if workload is not None and (
            len(workload.chain) != n_ops
            or sorted(workload.chain) != list(range(n_ops))):
        raise ValueError(
            f"solve_dag: the workload's rows ({len(workload.chain)} ops) "
            f"do not cover the graph's {n_ops} ops exactly — build it "
            "with Workload.from_graph(graph, table, pus)")

    def need_wl(preds: bool) -> Workload:
        # the chain/union/phase oracles never read predecessor sets, so
        # only the frontier route pays for ``from_graph`` — this keeps
        # the dispatch overhead on linear DAGs at the plain-build cost
        if workload is not None:
            return workload
        if preds:
            return Workload.from_graph(graph, table, pus)
        return Workload.build(graph.topo_order(), table, pus, ops=graph.ops)

    all_chains = graph.is_chain()   # degrees <= 1: chain(s), possibly many
    # for a degree-<=1 graph every edge merges two components, so the
    # component count is n - #edges — no union-find needed to route
    n_comps = n_ops - graph.n_edges if all_chains else None
    comps: list[list[int]] | None = None
    if all_chains and n_comps > 1:
        comps = graph.components()
    if algorithm == "auto":
        if all_chains and n_comps == 1:
            algorithm = "chain"
        elif (all_chains and uses_default_group(contention)
              and math.prod(len(c) + 1 for c in comps)
              <= (max_states if max_states is not None
                  else DEFAULT_MAX_STATES)):
            algorithm = "union-grid"
        else:
            algorithm = "phase"
    if algorithm == "chain":
        if not (all_chains and n_comps == 1):
            raise ValueError(
                "algorithm='chain' requires a single linear chain; this "
                f"graph has {len(graph.components())} component(s) and "
                f"{'only chain' if all_chains else 'fork/join'} structure "
                "— use 'auto', 'phase', or 'frontier'")
        wl = need_wl(preds=False)
        s = solve_sequential(wl.chain, graph.ops, table, pus, objective,
                             workload=wl)
        return _seq_to_dag(wl, s)
    if algorithm == "union-grid":
        if not all_chains:
            raise ValueError(
                "algorithm='union-grid' requires a disjoint union of "
                "linear chains (no forks/joins) — use 'auto', 'phase', "
                "or 'frontier'")
        if not uses_default_group(contention):
            raise ValueError(
                "algorithm='union-grid' dispatches to the exact grid "
                "sweep, which requires the default group co-execution "
                f"laws; {type(contention).__name__} overrides them — use "
                "'auto' or 'phase'")
        if comps is None:
            comps = graph.components()
        wl = need_wl(preds=False)
        comp_wls = [wl.select(c) for c in comps]
        cs = _solve_concurrent_grid(comp_wls, contention, objective, caches)
        return _concurrent_to_dag(cs, "union-grid")
    if algorithm == "phase":
        par = solve_parallel(graph, table, pus, contention, objective,
                             workload=need_wl(preds=False))
        return _parallel_to_dag(par)
    wl = need_wl(preds=True)
    if wl.preds is None and not (all_chains and n_comps == 1):
        raise ValueError(
            "algorithm='frontier' on a non-chain graph needs a DAG "
            "workload carrying predecessor sets — build it with "
            "Workload.from_graph(graph, table, pus) (a preds-free "
            "workload would be scheduled under linear-chain precedence)")
    return _solve_dag_frontier(wl, contention, objective,
                               caches=caches, max_states=max_states,
                               group_cap=group_cap)


def _dag_infeasible(wl: Workload, pos: int) -> InfeasibleScheduleError:
    """DAG-route infeasibility: name the node and its predecessor
    context (a request-index/chain-position message is meaningless for
    DAG nodes)."""
    preds = wl.pred_positions(pos)
    pstr = (", ".join(wl.op_name(q) for q in preds) if preds
            else "none (a source node)")
    return InfeasibleScheduleError(
        f"DAG node {wl.op_name(pos)} (topological position {pos}; "
        f"predecessors: {pstr}) is unsupported on every PU — no frontier "
        "advance can ever schedule it, so the DAG cannot complete")


def _solve_dag_frontier(
    wl: Workload, cm: ContentionModel, objective: str,
    caches: ConcurrentCaches | None = None,
    max_states: int | None = None,
    group_cap: int = _DAG_GROUP_CAP,
) -> DagSchedule:
    """Exact DP over the DAG's order ideals (downward-closed node sets).

    State = the completed ideal as a bitmask over topological positions;
    the *frontier* of an ideal is its antichain of ready positions (all
    predecessors inside).  A transition advances any non-empty ready
    subset of size ``<= group_cap``: singletons are priced from the
    dense solo edges, larger sets from the contention model's group law
    via a :class:`~repro.core.contention.GroupCostCache` over ``k``
    copies of this workload's dense table (memoized per ``k`` — and per
    content signature when a :class:`ConcurrentCaches` pool is passed,
    where it is shared with any grid solve over content-identical
    workloads).  Every transition strictly grows the ideal, so ideals
    are relaxed exactly, grouped by popcount (the anti-diagonal order);
    ties resolve to the first strict improvement in (ideal, subset-size,
    position-lexicographic) order — deterministic.  On a union of
    chains, ideals are exactly the progress-grid states and the
    transitions the grid's advance subsets, so this reduces to today's
    sweep.
    """
    if not uses_default_group(cm):
        raise ValueError(
            "the frontier route prices co-scheduled antichains with the "
            "default group co-execution laws via GroupCostCache; "
            f"{type(cm).__name__} overrides them — use algorithm='phase'")
    n = wl.n
    if n > 63:
        raise ValueError(
            f"the frontier route's ideal bitmasks cover at most 63 nodes "
            f"(graph has {n}) — use algorithm='phase'")
    if max_states is None:
        max_states = DEFAULT_MAX_STATES
    d = wl.dense
    skey, sarg, sw, se = _solo_edges(d, objective)
    bad = ~np.isfinite(np.asarray(skey))
    if bad.any():
        raise _dag_infeasible(wl, int(np.argmax(bad)))
    pred_mask = [0] * n
    for i in range(n):
        for q in wl.pred_positions(i):
            pred_mask[i] |= 1 << q
    sig = d.sig
    # adaptive group cap: a near-unique-signature profile would make the
    # k-ary edge table (n_sig ** k cells) dwarf the search — shrink k
    # until the table fits the rolling-route cap
    cap = max(1, group_cap)
    while cap > 1 and d.n_sig ** cap > _ROLLING_TABLE_CAP:
        cap -= 1

    group_tabs: dict[int, tuple] = {}

    def tables(k: int) -> tuple:
        tabs = group_tabs.get(k)
        if tabs is None:
            if caches is not None:
                key = (wl.signature(),) * k
                gc = caches.group_tables.get(key)
                created = gc is None
                if created:
                    gc = GroupCostCache(cm, [d] * k)
                    caches.group_tables[key] = gc
                else:
                    caches.group_tables[key] = caches.group_tables.pop(key)
                tabs = gc.edge_tables(objective)
                if created:
                    caches.trim()
            else:
                tabs = GroupCostCache(cm, [d] * k).edge_tables(objective)
            group_tabs[k] = tabs
        return tabs

    full = (1 << n) - 1
    INF = float("inf")
    dist: dict[int, float] = {0: 0.0}
    # act[ideal] = (prev ideal, ops positions, pus, step cost, step energy)
    act: dict[int, tuple] = {}
    levels: list[list[int]] = [[] for _ in range(n + 1)]
    levels[0].append(0)

    for t in range(n):
        lvl = sorted(levels[t])
        for ideal in lvl:
            base = dist[ideal]
            rest = ~ideal
            ready = [i for i in range(n)
                     if (rest >> i) & 1 and (pred_mask[i] & rest) == 0]
            kmax = min(cap, len(ready))
            for k in range(1, kmax + 1):
                if k == 1:
                    combos = ((i,) for i in ready)
                else:
                    combos = itertools.combinations(ready, k)
                    ktab, stab, etab, atab = tables(k)
                for S in combos:
                    if k == 1:
                        i = S[0]
                        key = float(skey[i])
                        cost = float(sw[i])
                        energy = float(se[i])
                        pus_ = (d.pus[int(sarg[i])],)
                    else:
                        idx = tuple(int(sig[i]) for i in S)
                        key = float(ktab[idx])
                        if not math.isfinite(key):
                            continue   # pragma: no cover - gated above
                        cost = float(stab[idx])
                        energy = float(etab[idx])
                        ci = int(atab[idx])
                        js = []
                        for _ in range(k):
                            ci, j = divmod(ci, d.k)
                            js.append(j)
                        js.reverse()
                        pus_ = tuple(d.pus[j] for j in js)
                    nmask = ideal
                    for i in S:
                        nmask |= 1 << i
                    nd = base + key
                    old = dist.get(nmask)
                    if old is None:
                        if len(dist) >= max_states:
                            raise ValueError(
                                f"frontier sweep exceeded max_states="
                                f"{max_states} order ideals (a memory "
                                "bound) — raise max_states or use "
                                "algorithm='phase'")
                        dist[nmask] = nd
                        act[nmask] = (ideal, S, pus_, cost, energy)
                        levels[t + k].append(nmask)
                    elif nd < old:
                        dist[nmask] = nd
                        act[nmask] = (ideal, S, pus_, cost, energy)

    if not math.isfinite(dist.get(full, INF)):  # pragma: no cover
        raise InfeasibleScheduleError(
            "frontier sweep exhausted without completing the DAG (every "
            "node passed the per-PU support gate, so this indicates an "
            "internal inconsistency)")

    steps: list[DagStep] = []
    total_energy = 0.0
    s = full
    while s != 0:
        prev, S, pus_, cost, energy = act[s]
        steps.append(DagStep(ops=tuple(wl.chain[i] for i in S), pus=pus_,
                             cost=cost))
        total_energy += energy
        s = prev
    steps.reverse()
    latency = sum(st.cost for st in steps)
    return DagSchedule(steps=steps, latency=latency, energy=total_energy,
                       objective=objective, mode="frontier")


# ---------------------------------------------------------------------------
# Multi-model concurrent search (§3.2.2 / §3.3.3)
# ---------------------------------------------------------------------------


def _solo_w(table: CostTable, oi: int, pu: str) -> float:
    return table.require(oi, pu).w


def _require_pair_tables(table0: CostTable | None, table1: CostTable | None,
                         cm: ContentionModel) -> None:
    """The scalar reference routes walk the dict tables; derived dense
    views (``Workload.tail``/``under_condition``/...) carry none, so fail
    with a descriptive error instead of an ``AttributeError`` mid-walk."""
    if table0 is None or table1 is None:
        raise ValueError(
            "this solve routes to the scalar reference solver (custom "
            f"contention laws on {type(cm).__name__}, or an explicit "
            "reference algorithm), which walks the scalar CostTables — "
            "but at least one chain has none (a derived dense view); "
            "solve from Workload.build(...) of an adjusted table instead")


def _solo_edges(d: DenseCostTable, objective: str
                ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
    """Per-position solo-advance edges: (key, chosen PU idx, w, energy)."""
    key = d.w if objective == "latency" else d.energy
    arg = np.argmin(key, axis=1)                 # first minimum, PU order
    rows = np.arange(d.n)
    return key[rows, arg], arg, d.w[rows, arg], d.energy[rows, arg]


def _suffix_heuristic(d: DenseCostTable, objective: str, scale: float
                      ) -> np.ndarray:
    """Admissible remaining-cost bound per progress index: suffix sums of
    each op's best-PU solo cost, scaled by the contention model's minimum
    co-execution factor.  (The loose-but-free bound; ``_cost_to_go``
    tightens it to the exact relaxed optimum.)"""
    m = np.min(d.w if objective == "latency" else d.energy, axis=1) * scale
    suf = np.zeros(d.n + 1)
    suf[:-1] = np.cumsum(m[::-1])[::-1]
    return suf


def _cost_to_go(pk: np.ndarray, sk0: np.ndarray, sk1: np.ndarray,
                sig0: list[int], sig1_idx: np.ndarray) -> np.ndarray:
    """Exact optimal cost-to-go over the (i, j) progress grid.

    Backward DP, one vectorized row per chain-0 position: the within-row
    dependency (solo chain-1 advances) is a suffix running-min after
    rebasing by chain-1 solo prefix sums, so each row is O(n1) NumPy work.
    This is the A* heuristic — exact up to accumulated FP rounding
    (<= (n0 + n1) ulps), so A* expands only the optimal corridor instead
    of flooding the grid.
    """
    n0, n1 = len(sig0), len(sig1_idx)
    q1 = np.zeros(n1 + 1)
    q1[:-1] = np.cumsum(sk1[::-1])[::-1]
    ctg = np.empty((n0 + 1, n1 + 1))
    ctg[n0] = q1
    c2 = np.empty(n1 + 1)
    for i in range(n0 - 1, -1, -1):
        nxt = ctg[i + 1]
        prow = pk[sig0[i]].take(sig1_idx)
        np.minimum(prow + nxt[1:], sk0[i] + nxt[:-1], out=c2[:-1])
        c2[-1] = sk0[i] + nxt[-1]
        t = c2 - q1
        rev = t[::-1]
        np.minimum.accumulate(rev, out=rev)
        np.add(q1, t, out=ctg[i])
    return ctg


def solve_concurrent_aligned(
    chain0: Sequence[int], table0: CostTable,
    chain1: Sequence[int], table1: CostTable,
    pus: Mapping[str, PUSpec],
    contention: ContentionModel | None = None,
    objective: str = "latency",
    dense0: DenseCostTable | None = None,
    dense1: DenseCostTable | None = None,
    cache: PairCostCache | None = None,
) -> ConcurrentSchedule:
    """Aligned Dijkstra: both requests advance in lockstep (same-model pairs).

    At each step the search selects a PU pair (d0, d1).  Same-PU step cost =
    average of measured concurrent execution times; cross-PU = max of
    (contention-adjusted) solo times.  Tails (unequal lengths) advance solo.
    Per-step PU-pair minimisation runs on the memoized dense pair-cost
    matrices; pass ``cache`` to share one ``PairCostCache`` across this
    pair's latency- and energy-objective solves.  A custom contention
    model falls back to the scalar reference.
    """
    contention = contention or ContentionModel()
    if not uses_default_coexec(contention):
        _require_pair_tables(table0, table1, contention)
        return solve_concurrent_aligned_reference(
            chain0, table0, chain1, table1, pus, contention, objective)
    if cache is not None:
        d0, d1 = cache.d0, cache.d1
    else:
        d0 = dense0 if dense0 is not None else DenseCostTable.from_chain(
            chain0, table0, pus)
        d1 = dense1 if dense1 is not None else DenseCostTable.from_chain(
            chain1, table1, pus)
        cache = PairCostCache(contention, d0, d1)
    k1 = d1.k
    n = min(d0.n, d1.n)
    steps: list[ConcurrentStep] = []
    total = 0.0
    energy = 0.0
    sig0, sig1 = d0.sig.tolist(), d1.sig.tolist()
    pk, ps, pe, pa = cache.edge_tables(objective)
    pkl, psl, pel, pal = pk.tolist(), ps.tolist(), pe.tolist(), pa.tolist()
    for i in range(n):
        s0, s1 = sig0[i], sig1[i]
        if pkl[s0][s1] == float("inf"):
            d0.require_row(i)
            d1.require_row(i)
        p0i, p1i = divmod(pal[s0][s1], k1)
        step_cost = psl[s0][s1]
        steps.append(ConcurrentStep(ops=(chain0[i], chain1[i]),
                                    pus=(d0.pus[p0i], d1.pus[p1i]),
                                    cost=step_cost))
        total += step_cost
        energy += pel[s0][s1]
    # solo tail for the longer request
    dl, idx = (d0, 0) if d0.n > n else (d1, 1)
    longer = chain0 if idx == 0 else chain1
    _, sarg, sw, se = _solo_edges(dl, objective)
    for i in range(n, dl.n):
        dl.require_row(i)
        p = dl.pus[int(sarg[i])]
        w, e = float(sw[i]), float(se[i])
        ops = (longer[i], None) if idx == 0 else (None, longer[i])
        pus_ = (p, None) if idx == 0 else (None, p)
        steps.append(ConcurrentStep(ops=ops, pus=pus_, cost=w))
        total += w
        energy += e
    return ConcurrentSchedule(steps=steps, latency=total, energy=energy,
                              objective=objective, mode="aligned")


def solve_concurrent_aligned_reference(
    chain0: Sequence[int], table0: CostTable,
    chain1: Sequence[int], table1: CostTable,
    pus: Mapping[str, PUSpec],
    contention: ContentionModel | None = None,
    objective: str = "latency",
) -> ConcurrentSchedule:
    """Scalar aligned-mode solver (pre-vectorization reference)."""
    contention = contention or ContentionModel()
    n = min(len(chain0), len(chain1))
    steps: list[ConcurrentStep] = []
    total = 0.0
    energy = 0.0
    for i in range(n):
        o0, o1 = chain0[i], chain1[i]
        best = None
        for d0 in table0.supported_pus(o0):
            t0 = _solo_w(table0, o0, d0)
            p0 = table0.require(o0, d0).power
            for d1 in table1.supported_pus(o1):
                t1 = _solo_w(table1, o1, d1)
                p1 = table1.require(o1, d1).power
                step = contention.pair_step_cost(t0, d0, t1, d1)
                cc0, cc1 = contention.co_exec(t0, d0, t1, d1)
                # energy: each op runs for its concurrent duration at its
                # PU's power (time-shared same-PU execution draws the PU's
                # power once -> charge each op its solo share).
                if d0 == d1:
                    e = t0 * p0 + t1 * p1
                else:
                    e = cc0 * p0 + cc1 * p1
                key = step if objective == "latency" else e
                if best is None or key < best[0]:
                    best = (key, step, e, d0, d1)
        _, step_cost, step_energy, d0, d1 = best
        steps.append(ConcurrentStep(ops=(o0, o1), pus=(d0, d1), cost=step_cost))
        total += step_cost
        energy += step_energy
    # solo tail for the longer request
    longer, table_l, idx = ((chain0, table0, 0) if len(chain0) > n
                            else (chain1, table1, 1))
    for i in range(n, len(longer)):
        oi = longer[i]
        cands = [(node_weight(table_l.require(oi, p), "latency"),
                  table_l.require(oi, p).energy, p)
                 for p in table_l.supported_pus(oi)]
        key_i = 0 if objective == "latency" else 1
        w, e, p = min(cands, key=lambda c: c[key_i])
        ops = (oi, None) if idx == 0 else (None, oi)
        pus_ = (p, None) if idx == 0 else (None, p)
        steps.append(ConcurrentStep(ops=ops, pus=pus_, cost=w))
        total += w
        energy += e
    return ConcurrentSchedule(steps=steps, latency=total, energy=energy,
                              objective=objective, mode="aligned")


def solve_concurrent_joint(
    chain0: Sequence[int], table0: CostTable,
    chain1: Sequence[int], table1: CostTable,
    pus: Mapping[str, PUSpec],
    contention: ContentionModel | None = None,
    objective: str = "latency",
    algorithm: str = "auto",
    dense0: DenseCostTable | None = None,
    dense1: DenseCostTable | None = None,
    cache: PairCostCache | None = None,
) -> ConcurrentSchedule:
    """Joint (i, j) search: each request's progress tracked independently.

    State (i, j) = completed op counts.  Transitions: advance both
    (i+1, j+1), advance request 0 solo (i+1, j), or advance request 1 solo
    (i, j+1) — allowing asymmetric completion with solo tails (paper
    §3.2.2).

    Runs as A* on the dense progress grid: all PU options for a transition
    share a successor, so each state has at most three precomputed
    min-edges, and the consistent suffix-sum heuristic steers expansion
    down the optimal corridor instead of flooding the grid like the
    reference Dijkstra.  Identical cost/assignment semantics to
    ``solve_concurrent_joint_reference``.
    """
    contention = contention or ContentionModel()
    if algorithm == "auto":
        algorithm = "astar" if uses_default_coexec(contention) else "dijkstra"
    if algorithm == "dijkstra":
        _require_pair_tables(table0, table1, contention)
        return solve_concurrent_joint_reference(
            chain0, table0, chain1, table1, pus, contention, objective)
    if algorithm != "astar":
        raise ValueError(algorithm)
    if not uses_default_coexec(contention):
        raise ValueError(
            "algorithm='astar' requires the default co-execution cost laws; "
            f"{type(contention).__name__} overrides them — use "
            "algorithm='auto' or 'dijkstra'")

    if cache is not None:
        d0, d1 = cache.d0, cache.d1
    else:
        d0 = dense0 if dense0 is not None else DenseCostTable.from_chain(
            chain0, table0, pus)
        d1 = dense1 if dense1 is not None else DenseCostTable.from_chain(
            chain1, table1, pus)
        cache = PairCostCache(contention, d0, d1)
    n0, n1 = d0.n, d1.n
    k1 = d1.k
    pk, ps, pe, pa = cache.edge_tables(objective)
    sk0, sa0, sw0, se0 = _solo_edges(d0, objective)
    sk1, sa1, sw1, se1 = _solo_edges(d1, objective)
    if not (np.isfinite(sk0).all() and np.isfinite(sk1).all()):
        # some op unsupported on every PU: no transition can advance it
        raise ValueError("joint search failed to reach target state")

    sig0, sig1 = d0.sig.tolist(), d1.sig.tolist()
    sk0l, sk1l = sk0.tolist(), sk1.tolist()
    pkl = pk.tolist()    # nested Python lists: cheaper hot-loop indexing
    hs = _cost_to_go(pk, sk0, sk1, sig0, d1.sig).ravel()

    # f is quantized before entering the heap and ties break toward
    # *larger* g (deeper states).  Schedules whose true costs coincide
    # (e.g. energy mode, where pairing two ops on their shared best PU
    # costs exactly their solo sum) reach f values that differ only by
    # accumulated FP rounding; without quantization that noise orders the
    # plateau breadth-first and the search floods the whole grid.  The
    # quantum sits ~100x above worst-case accumulated rounding and ~100x
    # below any physically meaningful cost gap, and bounds the returned
    # path's suboptimality by 2 quanta (~1e-11 relative) — tie-free
    # instances still return the bitwise-exact reference optimum.
    c00 = hs[0]
    quantum = (c00 if c00 > 0 else 1.0) * (n0 + n1 + 64) * 1e-15
    inv_q = 1.0 / quantum

    n1p = n1 + 1
    n_states = (n0 + 1) * n1p
    dist = np.full(n_states, np.inf)
    act = np.zeros(n_states, dtype=np.int8)  # 1 = pair, 2 = solo0, 3 = solo1
    target = n_states - 1
    dist[0] = 0.0
    heap: list[tuple[int, float, int]] = [(int(c00 * inv_q), 0.0, 0)]
    found = False
    while heap:
        fq, ng, s = heapq.heappop(heap)
        g = -ng
        if g > dist[s]:
            continue
        if s == target:
            found = True
            break
        i, j = divmod(s, n1p)
        if i < n0 and j < n1:
            nd = g + pkl[sig0[i]][sig1[j]]
            ns = s + n1p + 1
            if nd < dist[ns]:
                dist[ns] = nd
                act[ns] = 1
                heapq.heappush(heap, (int((nd + hs[ns]) * inv_q), -nd, ns))
        if i < n0:
            nd = g + sk0l[i]
            ns = s + n1p
            if nd < dist[ns]:
                dist[ns] = nd
                act[ns] = 2
                heapq.heappush(heap, (int((nd + hs[ns]) * inv_q), -nd, ns))
        if j < n1:
            nd = g + sk1l[j]
            ns = s + 1
            if nd < dist[ns]:
                dist[ns] = nd
                act[ns] = 3
                heapq.heappush(heap, (int((nd + hs[ns]) * inv_q), -nd, ns))
    if not found:
        raise ValueError("joint search failed to reach target state")
    # reconstruct (energy accumulated target -> start, like the reference)
    steps: list[ConcurrentStep] = []
    energy = 0.0
    i, j = n0, n1
    while (i, j) != (0, 0):
        a = int(act[i * n1p + j])
        if a == 1:
            i -= 1
            j -= 1
            p0i, p1i = divmod(int(pa[sig0[i], sig1[j]]), k1)
            steps.append(ConcurrentStep(
                ops=(chain0[i], chain1[j]),
                pus=(d0.pus[p0i], d1.pus[p1i]),
                cost=float(ps[sig0[i], sig1[j]])))
            energy += float(pe[sig0[i], sig1[j]])
        elif a == 2:
            i -= 1
            steps.append(ConcurrentStep(
                ops=(chain0[i], None), pus=(d0.pus[int(sa0[i])], None),
                cost=float(sw0[i])))
            energy += float(se0[i])
        elif a == 3:
            j -= 1
            steps.append(ConcurrentStep(
                ops=(None, chain1[j]), pus=(None, d1.pus[int(sa1[j])]),
                cost=float(sw1[j])))
            energy += float(se1[j])
        else:  # pragma: no cover - would mean a corrupt predecessor chain
            raise RuntimeError(f"joint A*: no action recorded at ({i}, {j})")
    steps.reverse()
    latency = sum(s.cost for s in steps)
    return ConcurrentSchedule(steps=steps, latency=latency, energy=energy,
                              objective=objective, mode="joint")


def solve_concurrent_joint_reference(
    chain0: Sequence[int], table0: CostTable,
    chain1: Sequence[int], table1: CostTable,
    pus: Mapping[str, PUSpec],
    contention: ContentionModel | None = None,
    objective: str = "latency",
) -> ConcurrentSchedule:
    """Joint (i, j) Dijkstra over dict states (pre-A* reference)."""
    contention = contention or ContentionModel()
    n0, n1 = len(chain0), len(chain1)
    INF = float("inf")
    dist: dict[tuple[int, int], float] = {(0, 0): 0.0}
    prev: dict[tuple[int, int], tuple[tuple[int, int], ConcurrentStep, float]] = {}
    heap: list[tuple[float, tuple[int, int]]] = [(0.0, (0, 0))]
    done: set[tuple[int, int]] = set()

    def step_options(i: int, j: int):
        # (next_state, step, objective_key, energy)
        if i < n0 and j < n1:
            o0, o1 = chain0[i], chain1[j]
            for d0 in table0.supported_pus(o0):
                t0 = _solo_w(table0, o0, d0)
                p0 = table0.require(o0, d0).power
                for d1 in table1.supported_pus(o1):
                    t1 = _solo_w(table1, o1, d1)
                    p1 = table1.require(o1, d1).power
                    step = contention.pair_step_cost(t0, d0, t1, d1)
                    cc0, cc1 = contention.co_exec(t0, d0, t1, d1)
                    e = (t0 * p0 + t1 * p1) if d0 == d1 else (cc0 * p0 + cc1 * p1)
                    yield ((i + 1, j + 1),
                           ConcurrentStep(ops=(o0, o1), pus=(d0, d1), cost=step),
                           step if objective == "latency" else e, e)
        if i < n0:
            o0 = chain0[i]
            for d0 in table0.supported_pus(o0):
                ent = table0.require(o0, d0)
                yield ((i + 1, j),
                       ConcurrentStep(ops=(o0, None), pus=(d0, None), cost=ent.w),
                       ent.w if objective == "latency" else ent.energy, ent.energy)
        if j < n1:
            o1 = chain1[j]
            for d1 in table1.supported_pus(o1):
                ent = table1.require(o1, d1)
                yield ((i, j + 1),
                       ConcurrentStep(ops=(None, o1), pus=(None, d1), cost=ent.w),
                       ent.w if objective == "latency" else ent.energy, ent.energy)

    target = (n0, n1)
    while heap:
        d, st = heapq.heappop(heap)
        if st in done:
            continue
        done.add(st)
        if st == target:
            break
        for nxt, step, key, e in step_options(*st):
            nd = d + key
            if nd < dist.get(nxt, INF):
                dist[nxt] = nd
                prev[nxt] = (st, step, e)
                heapq.heappush(heap, (nd, nxt))
    if target not in dist:
        raise ValueError("joint search failed to reach target state")
    # reconstruct
    steps: list[ConcurrentStep] = []
    energy = 0.0
    cur = target
    while cur != (0, 0):
        st, step, e = prev[cur]
        steps.append(step)
        energy += e
        cur = st
    steps.reverse()
    latency = sum(s.cost for s in steps)
    return ConcurrentSchedule(steps=steps, latency=latency, energy=energy,
                              objective=objective, mode="joint")


# ---------------------------------------------------------------------------
# M-request concurrent search over Workloads (generalizes the pair solvers)
# ---------------------------------------------------------------------------


class ConcurrentCaches:
    """Objective-independent setup shared across repeated
    ``solve_concurrent`` calls under one contention model and runtime
    condition.

    ``pair`` memoizes ``PairCostCache`` instances and ``group_tables``
    the vectorized grid sweep's per-subset
    :class:`~repro.core.contention.GroupCostCache` tables (both
    objectives' bests per entry, shared by the full-grid and every
    rolling-horizon window solve).  Both are keyed by the participating
    workloads' **content signatures** (``Workload.signature()``), so a
    single pool safely serves *different* workload tuples: overlapping
    handle sets, tail re-plans at any progress, and re-admitted models
    all hit the same tables — the backbone of warm-start incremental
    re-planning (equal signatures ⇒ identical dense views ⇒ identical
    table contents).  ``group`` memoizes the retained heap A*'s scalar
    per-(subset, signature-tuple) edges; its inner ids are only
    meaningful per workload tuple, so entries are scoped under the
    tuple's signature key.

    A pool must not be shared across contention models or runtime
    conditions — both change table contents without changing the keys
    (the orchestrator keys its pools by condition for exactly this
    reason).

    Because one pool now serves a whole serving session, it is bounded:
    ``pair`` and ``group_tables`` are insertion-ordered LRUs trimmed to
    ``max_table_bytes`` (half each; the newest entry always survives),
    and ``group`` keeps the most recent ``max_group_scopes`` tuple
    memos.  Eviction only costs a rebuild on the next miss — values are
    content-derived, so correctness is unaffected.
    """

    def __init__(self, max_table_bytes: int = 512 * 2**20,
                 max_group_scopes: int = 64) -> None:
        self.pair: dict[tuple[str, str], PairCostCache] = {}
        self.group: dict[tuple[str, ...], dict] = {}
        self.group_tables: dict[tuple, GroupCostCache] = {}
        self.max_table_bytes = max_table_bytes
        self.max_group_scopes = max_group_scopes
        # monotonic trim counters, surfaced by Orchestrator.cache_stats()
        # (and from there ServeReport): sustained growth during a serving
        # run is the cache-pressure signal behind re-plan slowdowns
        self.stats = {"pair_trims": 0, "group_table_trims": 0,
                      "group_scope_trims": 0}

    def trim(self) -> None:
        """Evict oldest ``pair``/``group_tables`` entries past the byte
        budget (lazily built tables are accounted as they fill) and
        oldest ``group`` scopes past the scope cap.  Entries still
        referenced by an in-flight solve stay alive until it finishes.
        Every eviction bumps the matching ``stats`` counter."""
        half = self.max_table_bytes // 2
        for d, key in ((self.pair, "pair_trims"),
                       (self.group_tables, "group_table_trims")):
            while len(d) > 1 and \
                    sum(v.nbytes() for v in d.values()) > half:
                d.pop(next(iter(d)))
                self.stats[key] += 1
        while len(self.group) > self.max_group_scopes:
            self.group.pop(next(iter(self.group)))
            self.stats["group_scope_trims"] += 1


def _require_oracle_tables(wls: Sequence[Workload],
                           cm: ContentionModel) -> None:
    """Custom co-execution laws route to the scalar reference solvers,
    which need each workload's oracle ``CostTable``.  Derived dense views
    (``under_condition``/``tail``/``select``/``spliced``) carry none —
    their rows no longer correspond to the source dict — so reject them
    loudly instead of silently pricing the wrong costs."""
    if uses_default_coexec(cm):
        return
    for r, wl in enumerate(wls):
        if wl.table is None:
            raise ValueError(
                f"{type(cm).__name__} overrides the co-execution laws, "
                "which requires the scalar reference solvers — but "
                f"workload {r} has no oracle CostTable (it is a derived "
                "dense view); solve from a Workload.build(...) of the "
                "adjusted table instead")


def _solo_step_walk(wl: Workload, req: int, m: int, objective: str,
                    lo: int = 0, hi: int | None = None,
                    solo: tuple | None = None,
                    ) -> tuple[list[ConcurrentStep], float, float]:
    """Solo-advance steps for one request inside an M-request schedule:
    each op on its best PU by ``objective`` (node weights only — the
    concurrent formulation prices no inter-op transitions).  ``lo``/
    ``hi`` bound the walked span (warm tail / bounded-horizon re-plans);
    ``solo`` passes precomputed ``_solo_edges`` arrays."""
    d = wl.dense
    _, sarg, sw, se = solo if solo is not None else _solo_edges(d, objective)
    steps: list[ConcurrentStep] = []
    lat = 0.0
    eng = 0.0
    for i in range(lo, d.n if hi is None else hi):
        d.require_row(i)
        ops = [None] * m
        pus_: list[str | None] = [None] * m
        ops[req] = wl.chain[i]
        pus_[req] = d.pus[int(sarg[i])]
        w, e = float(sw[i]), float(se[i])
        steps.append(ConcurrentStep(ops=tuple(ops), pus=tuple(pus_), cost=w))
        lat += w
        eng += e
    return steps, lat, eng


DEFAULT_MAX_STATES = 2_000_000     # exact-grid ceiling: a MEMORY bound
DEFAULT_WINDOW_STATES = 65_536     # rolling-horizon per-window grid budget
DEFAULT_HORIZON_STATES = 1_024     # bounded-lookahead serving re-plan budget

# Boxes up to this many states take the sweep's hoisted relaxation path
# (per-subset sources/keys/successors precomputed in diagonal-major
# order, ~170 B/state peak); larger boxes stream per diagonal.  Both
# paths are bitwise-identical — the cap trades peak memory against the
# per-NumPy-call overhead that dominates small warm re-plan boxes.
_SWEEP_HOIST_CAP = 131_072

# Boxes up to this many states take the destination-major merged
# relaxation: all subsets' edges are concatenated, sorted once by
# (dst diagonal, dst, cold write order), and each diagonal resolves in
# one batched group-min — ~9 NumPy calls per diagonal instead of ~8 per
# (diagonal, subset).  This is the serving re-plan hot path (horizon
# windows are <= ~2k states).  The edge sort is O(E log E) over
# E ~ 2^m * states edges, so large boxes fall back to the hoisted path.
_SWEEP_MERGE_CAP = 8_192


def solve_concurrent(
    workloads: Sequence[Workload],
    contention: ContentionModel | None = None,
    objective: str = "latency",
    algorithm: str = "auto",
    max_states: int | None = None,
    caches: ConcurrentCaches | None = None,
    window_states: int = DEFAULT_WINDOW_STATES,
) -> ConcurrentSchedule:
    """Joint co-scheduling of M >= 1 concurrent requests.

    The single formulation of the paper's §3.2.2, generalized: state =
    per-request completed-op counts; a transition advances any non-empty
    subset of requests one op each, priced by the contention model's
    group co-execution laws.

    * **M = 1** — a solo walk (each op on its best PU by objective).
    * **M = 2** — dispatched to ``solve_concurrent_joint``: the dense
      pair A* fast path, bit-for-bit (the retained pair solvers ARE the
      M = 2 case).
    * **M >= 3, grids up to ``max_states``** — exact vectorized
      anti-diagonal sweep of the M-dimensional progress grid
      (``algorithm="grid"`` forces it; ``"grid_astar"`` forces the
      retained heap A* oracle; both raise if the grid exceeds
      ``max_states`` or the contention model overrides the group laws).
      ``max_states`` (``None`` = ``DEFAULT_MAX_STATES``) is a *memory*
      bound (~100 bytes/state for the sweep's dense per-state arrays),
      not a time bound; it governs the M >= 3 routes and the explicitly
      grid-forced M = 2 solves — passing it alongside the M = 2 pair
      fast path (which is corridor-exact and not state-bounded) raises
      rather than silently ignoring it.
    * **M >= 3, larger grids** — the rolling-horizon merge
      (``algorithm="rolling"`` forces it): the next window of ops across
      ALL M requests is co-scheduled with an exact grid sweep
      (``<= window_states`` states per window, window lengths
      proportional to remaining chain lengths) and windows are stitched
      back-to-back.  Upper-bounds the exact grid optimum and recovers
      cross-request concurrency the old pairwise merge serialized away.
    * **custom contention laws** — the documented pairwise-merge
      fallback (``algorithm="pairwise"`` forces it): requests sorted by
      descending solo-best cost, adjacent pairs co-scheduled with the
      exact pair A* (whose scalar reference honours overridden pair
      laws), pairs executed back-to-back, an odd cheapest request
      running solo.

    ``algorithm="auto"`` picks the exact sweep when it fits
    ``max_states``, the rolling-horizon merge when it does not, and
    pairwise only under custom contention laws (or for the degenerate
    near-unique-signature profiles whose shared group tables would dwarf
    the rolling windows; forcing ``"rolling"`` there raises instead of
    silently downgrading).  Pass ``caches`` (a
    :class:`ConcurrentCaches` dedicated to this workload tuple) to share
    the objective-independent setup across a latency + energy solve
    pair.
    """
    contention = contention or ContentionModel()
    wls = list(workloads)
    m = len(wls)
    if m == 0:
        raise ValueError("solve_concurrent needs at least one workload")
    if algorithm not in ("auto", "astar", "dijkstra", "grid", "grid_astar",
                         "rolling", "pairwise"):
        raise ValueError(algorithm)
    if m == 1:
        if algorithm != "auto" or max_states is not None:
            raise ValueError(
                "algorithm=/max_states= were forced, but a single request "
                "has no concurrent search to route — the M = 1 solve is a "
                "solo best-PU walk; drop the arguments")
        steps, lat, eng = _solo_step_walk(wls[0], 0, 1, objective)
        return ConcurrentSchedule(steps=steps, latency=lat, energy=eng,
                                  objective=objective, mode="joint")
    _require_oracle_tables(wls, contention)
    if m == 2 and algorithm in ("auto", "astar", "dijkstra"):
        if max_states is not None:
            raise ValueError(
                "max_states bounds the grid/rolling routes, but this M = 2 "
                "solve dispatches to the pair A* fast path (corridor-exact, "
                "not state-bounded) — drop max_states, or force "
                "algorithm='grid'/'grid_astar'/'rolling'/'pairwise' to "
                "apply a state-bounded route")
        pair_algo = "auto" if algorithm == "auto" else algorithm
        cache = _pair_cache(caches, contention, wls, 0, 1)
        return solve_concurrent_joint(
            wls[0].chain, wls[0].table, wls[1].chain, wls[1].table,
            wls[0].pus, contention, objective, algorithm=pair_algo,
            dense0=wls[0].dense, dense1=wls[1].dense, cache=cache)
    if max_states is None:
        max_states = DEFAULT_MAX_STATES
    n_states = math.prod(wl.n + 1 for wl in wls)
    default_laws = uses_default_group(contention)
    if algorithm in ("grid", "grid_astar"):
        if not default_laws:
            raise ValueError(
                f"algorithm={algorithm!r} requires the default group "
                f"co-execution laws; {type(contention).__name__} overrides "
                "them — use algorithm='auto' or 'pairwise'")
        if n_states > max_states:
            raise ValueError(
                f"algorithm={algorithm!r} on {n_states} states exceeds "
                f"max_states={max_states}; raise max_states (a memory "
                "bound of ~100 bytes/state) or use algorithm='rolling' "
                "or 'pairwise'")
        if algorithm == "grid":
            return _solve_concurrent_grid(wls, contention, objective, caches)
        group_memo = None
        if caches is not None:
            # the heap A* memo's (subset, signature-id) keys are only
            # meaningful for one workload tuple — scope them under the
            # tuple's content signatures so a shared pool stays safe
            scope = tuple(wl.signature() for wl in wls)
            group_memo = caches.group.setdefault(scope, {})
            caches.group[scope] = caches.group.pop(scope)  # LRU refresh
            caches.trim()
        return _solve_concurrent_grid_astar(wls, contention, objective,
                                            group_memo)
    if algorithm == "rolling":
        if not default_laws:
            raise ValueError(
                "algorithm='rolling' co-schedules each window with the "
                "exact grid sweep, which requires the default group "
                f"co-execution laws; {type(contention).__name__} overrides "
                "them — use algorithm='auto' or 'pairwise'")
        sig_states = _group_table_states(wls)
        if sig_states > _ROLLING_TABLE_CAP:
            raise ValueError(
                "algorithm='rolling' shares group-edge tables over the "
                f"requests' full signature alphabets, and {sig_states} "
                f"signature tuples exceed the {_ROLLING_TABLE_CAP} table "
                "cap (near-unique per-op signatures, e.g. a measured "
                "profile) — use algorithm='auto' or 'pairwise'")
        return _solve_concurrent_rolling(wls, contention, objective, caches,
                                         min(window_states, max_states))
    if algorithm == "pairwise":
        return _solve_concurrent_pairwise(wls, contention, objective, caches)
    if algorithm != "auto":   # "astar"/"dijkstra": pair-only spellings
        raise ValueError(
            f"algorithm={algorithm!r} names the two-request pair solvers "
            f"and does not generalize to M = {m} requests — use "
            "'auto', 'grid', 'grid_astar', 'rolling', or 'pairwise'")
    if not default_laws:
        return _solve_concurrent_pairwise(wls, contention, objective, caches)
    if n_states <= max_states:
        return _solve_concurrent_grid(wls, contention, objective, caches)
    if _group_table_states(wls) <= _ROLLING_TABLE_CAP:
        return _solve_concurrent_rolling(wls, contention, objective, caches,
                                         min(window_states, max_states))
    return _solve_concurrent_pairwise(wls, contention, objective, caches)


def _pair_cache(caches: ConcurrentCaches | None, cm: ContentionModel,
                wls: Sequence[Workload], a: int, b: int
                ) -> PairCostCache | None:
    """Memoized PairCostCache for requests (a, b), keyed by the pair's
    content signatures so any workload tuple containing an identically
    priced pair reuses it; None when the pair solver should build its
    own (no pool, or custom laws where the dense cache is unused)."""
    if caches is None or not uses_default_coexec(cm):
        return None
    key = (wls[a].signature(), wls[b].signature())
    cache = caches.pair.get(key)
    if cache is None:
        cache = PairCostCache(cm, wls[a].dense, wls[b].dense)
        caches.pair[key] = cache
        caches.trim()
    else:
        caches.pair[key] = caches.pair.pop(key)       # LRU refresh
    return cache


def _require_all_advanceable(wls: Sequence[Workload],
                             solo_keys: Sequence[np.ndarray]) -> None:
    """Descriptive infeasibility gate for the M-request solvers: an op
    with no supported PU can never be advanced by any transition, so
    every route fails identically — report which request, which op, and
    where, instead of an opaque search-exhaustion error later."""
    for r, (wl, key) in enumerate(zip(wls, solo_keys)):
        bad = ~np.isfinite(np.asarray(key))
        if bad.any():
            pos = int(np.argmax(bad))
            raise InfeasibleScheduleError(
                f"request {r}: {wl.op_name(pos)} at chain position {pos} "
                "is unsupported on every PU — no concurrent transition "
                "can advance it")


class _GridContext:
    """Per-solve vectorized inputs shared by the full-grid sweep and the
    rolling-horizon windows: per-request dense solo edges, signature-id
    arrays, and lazily built per-subset group-edge tables
    (:class:`~repro.core.contention.GroupCostCache`).  When backed by a
    shared :class:`ConcurrentCaches` pool the tables are keyed by the
    requests' *content signatures* (``Workload.signature()``), so every
    window of a rolling solve, the companion solve under the other
    objective, AND any later solve over content-identical workloads —
    a tail re-plan, an overlapping handle set, a re-admitted model —
    reuses them; an unpooled context falls back to request-index keys.
    """

    def __init__(self, wls: Sequence[Workload], cm: ContentionModel,
                 objective: str, caches: ConcurrentCaches | None = None,
                 check_advanceable: bool = True):
        self.wls = list(wls)
        self.m = len(self.wls)
        self.cm = cm
        self.objective = objective
        self.denses = [wl.dense for wl in self.wls]
        self.pu_lists = [d.pus for d in self.denses]
        self.solo = [_solo_edges(d, objective) for d in self.denses]
        if check_advanceable:
            _require_all_advanceable(self.wls, [s[0] for s in self.solo])
        self.sigs = [d.sig for d in self.denses]
        self._caches = caches
        self._pooled = caches is not None
        self._keys: list[str] | None = None   # content signatures, lazy
        self._tables = caches.group_tables if caches is not None else {}

    def tables(self, reqs: tuple[int, ...]
               ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        if self._pooled:
            if self._keys is None:
                self._keys = [wl.signature() for wl in self.wls]
            key: tuple = tuple(self._keys[r] for r in reqs)
        else:
            key = reqs
        gc = self._tables.get(key)
        created = gc is None
        if created:
            gc = GroupCostCache(self.cm, [self.denses[r] for r in reqs])
            self._tables[key] = gc
        elif self._pooled:
            self._tables[key] = self._tables.pop(key)   # LRU refresh
        tabs = gc.edge_tables(self.objective)
        if created and self._pooled:
            # trim after the build so the new entry's size is accounted
            self._caches.trim()
        return tabs

    def sweep(self, lo: Sequence[int], hi: Sequence[int]
              ) -> tuple[list[ConcurrentStep], float]:
        """Exact anti-diagonal DP over the progress sub-box
        ``prod([lo_r, hi_r])``; returns ``(steps, energy)``.

        All states with equal total progress form an anti-diagonal; every
        transition strictly increases total progress, so diagonals are a
        topological order and each one is relaxed in a handful of batched
        NumPy operations per advance subset.  Within one (diagonal,
        subset) relaxation distinct sources map to distinct successors
        (``s + delta`` is injective), so the scatter needs no conflict
        resolution; ties between subsets resolve to the first strict
        improvement in (source-diagonal, subset-bitmask) order — a fixed,
        deterministic policy.  Unlike the retained heap A*
        (quantized-priority tie plateaus, suboptimality <= 2 quanta),
        the sweep returns the exact FP-minimal objective.

        Three relaxation paths, all bitwise-identical (same candidate
        values, same tie policy): boxes up to ``_SWEEP_MERGE_CAP``
        states run destination-major — every subset's edges are
        concatenated, sorted once by (dst diagonal, dst, cold write
        order) and each diagonal resolves as one batched first-achiever
        group-min, collapsing the per-(diagonal, subset) NumPy overhead
        that dominates the small warm re-plan boxes of the serving hot
        path.  Boxes up to ``_SWEEP_HOIST_CAP`` run the hoisted path:
        per-subset valid-source lists, gathered edge keys and successor
        indices precomputed over the whole box in diagonal-major order,
        leaving a gather/add/compare/scatter per (diagonal, subset).
        Larger boxes stream per diagonal to keep peak memory at a few
        arrays per state.
        """
        m = self.m
        sizes = [hi[r] - lo[r] for r in range(m)]
        shape = [s + 1 for s in sizes]
        strides = [0] * m
        strides[m - 1] = 1
        for r in range(m - 2, -1, -1):
            strides[r] = strides[r + 1] * shape[r + 1]
        n_states = strides[0] * shape[0]
        target = n_states - 1
        if target == 0:
            return [], 0.0
        flat = np.arange(n_states)
        pos = [(flat // strides[r]) % shape[r] for r in range(m)]
        apos = [pos[r] + lo[r] for r in range(m)]   # absolute chain position
        tsum = pos[0].copy()
        for r in range(1, m):
            tsum += pos[r]
        if n_states > _SWEEP_MERGE_CAP:   # diagonal-major source order —
            # only the hoisted/streaming paths consume it
            order = np.argsort(tsum, kind="stable")
            offs = np.concatenate(
                ([0], np.cumsum(np.bincount(tsum,
                                            minlength=sum(sizes) + 1))))
        can = [pos[r] < sizes[r] for r in range(m)]
        sk = [self.solo[r][0] for r in range(m)]
        subsets = []    # (bits, reqs, delta, key_table_flat, table_shape)
        for bits in range(1, 1 << m):
            reqs = tuple(r for r in range(m) if bits & (1 << r))
            if any(sizes[r] == 0 for r in reqs):
                continue        # a finished request can never advance
            delta = sum(strides[r] for r in reqs)
            if len(reqs) == 1:
                subsets.append((bits, reqs, delta, None, None))
            else:
                tab = self.tables(reqs)[0]
                subsets.append((bits, reqs, delta, tab.ravel(), tab.shape))

        dist = np.full(n_states, np.inf)
        act = np.zeros(n_states, dtype=np.int32)    # subset bitmask taken
        dist[0] = 0.0
        if n_states <= _SWEEP_MERGE_CAP:
            # destination-major merged relaxation: dist[src] is final
            # before any edge out of src is relaxed (every transition
            # strictly deepens the diagonal), so dist[dst] is the plain
            # min over incoming candidates and act[dst] the FIRST
            # candidate attaining it in the cold write order
            # (source-diagonal asc == popcount desc, then subset order)
            # — strict-`<` sequential relaxation keeps exactly that
            # first achiever, so values AND actions are bitwise-equal.
            S_, K_, D_, B_, R_ = [], [], [], [], []
            for bits, reqs, delta, kflat, tshape in subsets:
                valid = can[reqs[0]]
                for r in reqs[1:]:
                    valid = valid & can[r]
                srcs = np.flatnonzero(valid)
                if kflat is None:
                    r0 = reqs[0]
                    keys = sk[r0][apos[r0][srcs]]
                else:
                    idx = self.sigs[reqs[0]][apos[reqs[0]][srcs]]
                    for r, sdim in zip(reqs[1:], tshape[1:]):
                        idx = idx * sdim + self.sigs[r][apos[r][srcs]]
                    keys = kflat[idx]
                S_.append(srcs)
                K_.append(keys)
                D_.append(srcs + delta)
                B_.append(np.full(srcs.size, bits, dtype=np.int32))
                R_.append(np.full(srcs.size, m - len(reqs),
                                  dtype=np.int64))
            S = np.concatenate(S_)
            K = np.concatenate(K_)
            D = np.concatenate(D_)
            B = np.concatenate(B_)
            R = np.concatenate(R_)
            skey = (tsum[D] * n_states + D) * (m + 1) + R
            perm = np.argsort(skey, kind="stable")
            S, K, D, B = S[perm], K[perm], D[perm], B[perm]
            E = D.size
            gs = np.flatnonzero(
                np.concatenate(([True], D[1:] != D[:-1])))
            uD = D[gs]
            gcnt = np.diff(np.append(gs, E))
            tmax = int(tsum[target])
            eoffs = np.concatenate(
                ([0], np.cumsum(np.bincount(tsum[D],
                                            minlength=tmax + 1))))
            goffs = np.concatenate(
                ([0], np.cumsum(np.bincount(tsum[uD],
                                            minlength=tmax + 1))))
            lidx = np.arange(E)
            for t in range(1, tmax + 1):
                a, z = eoffs[t], eoffs[t + 1]
                if a == z:
                    continue
                ga, gz = goffs[t], goffs[t + 1]
                starts = gs[ga:gz] - a
                nd = dist[S[a:z]] + K[a:z]
                mins = np.minimum.reduceat(nd, starts)
                cand = np.where(nd == np.repeat(mins, gcnt[ga:gz]),
                                lidx[a:z], E)
                first = np.minimum.reduceat(cand, starts)
                ud = uD[ga:gz]
                dist[ud] = mins
                act[ud] = B[first]
        elif n_states <= _SWEEP_HOIST_CAP:
            # hoisted path: per-subset valid sources / keys / successors
            # precomputed over the whole box in diagonal-major order
            plans = []      # (bits, srcs, keys, dsts, per-diagonal offsets)
            for bits, reqs, delta, kflat, tshape in subsets:
                valid = can[reqs[0]]
                for r in reqs[1:]:
                    valid = valid & can[r]
                vo = valid[order]
                srcs = order[vo]
                voffs = np.concatenate(([0], np.cumsum(vo)))[offs]
                if kflat is None:
                    r0 = reqs[0]
                    keys = sk[r0][apos[r0][srcs]]
                else:
                    idx = self.sigs[reqs[0]][apos[reqs[0]][srcs]]
                    for r, sdim in zip(reqs[1:], tshape[1:]):
                        idx = idx * sdim + self.sigs[r][apos[r][srcs]]
                    keys = kflat[idx]
                plans.append((bits, srcs, keys, srcs + delta, voffs))
            for t in range(len(offs) - 2):  # last diagonal is the target
                for bits, srcs, keys, dsts, voffs in plans:
                    a, z = voffs[t], voffs[t + 1]
                    if a == z:
                        continue
                    nd = dist[srcs[a:z]] + keys[a:z]
                    nst = dsts[a:z]
                    better = nd < dist[nst]
                    if better.any():
                        b = nst[better]
                        dist[b] = nd[better]
                        act[b] = bits
        else:
            for t in range(len(offs) - 2):  # last diagonal is the target
                seg = order[offs[t]:offs[t + 1]]
                dseg = dist[seg]
                for bits, reqs, delta, kflat, tshape in subsets:
                    valid = can[reqs[0]][seg]
                    for r in reqs[1:]:
                        valid = valid & can[r][seg]
                    sv = seg[valid]
                    if not sv.size:
                        continue
                    gv = dseg[valid]
                    if kflat is None:
                        r0 = reqs[0]
                        key = sk[r0][apos[r0][sv]]
                    else:
                        idx = self.sigs[reqs[0]][apos[reqs[0]][sv]]
                        for r, sdim in zip(reqs[1:], tshape[1:]):
                            idx = idx * sdim + self.sigs[r][apos[r][sv]]
                        key = kflat[idx]
                    nd = gv + key
                    nst = sv + delta
                    better = nd < dist[nst]
                    if better.any():
                        b = nst[better]
                        dist[b] = nd[better]
                        act[b] = bits
        if not np.isfinite(dist[target]):  # pragma: no cover - gated above
            raise InfeasibleScheduleError(
                "grid sweep exhausted without reaching the all-requests-"
                "complete state (every op passed the per-PU support gate, "
                "so this indicates an internal inconsistency)")

        # reconstruct target -> start (energy accumulated in that order,
        # like the pair A* and the retained heap grid A*)
        by_bits = {bits: (reqs, delta) for bits, reqs, delta, _, _ in subsets}
        steps: list[ConcurrentStep] = []
        energy = 0.0
        posv = list(sizes)
        s = target
        while s != 0:
            bits = int(act[s])
            if bits == 0:  # pragma: no cover - corrupt predecessor chain
                raise RuntimeError(f"grid sweep: no action recorded at {posv}")
            reqs, delta = by_bits[bits]
            for r in reqs:
                posv[r] -= 1
            s -= delta
            ops: list[int | None] = [None] * m
            pus_: list[str | None] = [None] * m
            if len(reqs) == 1:
                r = reqs[0]
                ap = lo[r] + posv[r]
                _, sarg, sw, se = self.solo[r]
                ops[r] = self.wls[r].chain[ap]
                pus_[r] = self.pu_lists[r][int(sarg[ap])]
                cost = float(sw[ap])
                energy += float(se[ap])
            else:
                _, ps, pe, pa = self.tables(reqs)
                key = tuple(int(self.sigs[r][lo[r] + posv[r]]) for r in reqs)
                cost = float(ps[key])
                energy += float(pe[key])
                ci = int(pa[key])
                combo: list[int] = []
                for r in reversed(reqs):
                    ci, j = divmod(ci, self.denses[r].k)
                    combo.append(j)
                combo.reverse()
                for r, j in zip(reqs, combo):
                    ops[r] = self.wls[r].chain[lo[r] + posv[r]]
                    pus_[r] = self.pu_lists[r][j]
            steps.append(ConcurrentStep(ops=tuple(ops), pus=tuple(pus_),
                                        cost=cost))
        steps.reverse()
        return steps, energy


def _solve_concurrent_grid(
    wls: Sequence[Workload], cm: ContentionModel, objective: str,
    caches: ConcurrentCaches | None = None,
) -> ConcurrentSchedule:
    """Exact vectorized anti-diagonal sweep of the M-dimensional progress
    grid (see :meth:`_GridContext.sweep`).  Singleton advances are priced
    from the dense solo-edge arrays; group advances gather from the
    per-(subset, signature-tuple) edge tables built once per solve."""
    ctx = _GridContext(wls, cm, objective, caches)
    steps, energy = ctx.sweep([0] * len(wls), [wl.n for wl in wls])
    latency = sum(st.cost for st in steps)
    return ConcurrentSchedule(steps=steps, latency=latency, energy=energy,
                              objective=objective, mode="joint-grid")


def _window_lengths(rem: Sequence[int], budget: int) -> list[int]:
    """Rolling-horizon window lengths: the largest proportional scaling
    of the remaining chain lengths whose window sub-grid fits ``budget``
    states.  Every unfinished request advances at least one op per
    window (the progress guarantee; with many requests and a tiny budget
    that floor may overshoot the budget slightly)."""
    if math.prod(r + 1 for r in rem) <= budget:
        return list(rem)                   # final window: exact to the end

    def scaled(a: float) -> list[int]:
        return [min(r, max(1, int(a * r))) if r else 0 for r in rem]

    lo_a, hi_a = 0.0, 1.0
    for _ in range(40):
        mid = 0.5 * (lo_a + hi_a)
        if math.prod(x + 1 for x in scaled(mid)) <= budget:
            lo_a = mid
        else:
            hi_a = mid
    return scaled(lo_a)


# the rolling route's shared group tables cover the requests' full
# signature alphabets; a near-unique-signature profile (e.g. measured
# tables where every op times differently) could make them larger than
# the windows they serve — ``solve_concurrent`` routes such instances to
# the pairwise merge under "auto" and rejects a forced "rolling" loudly.
# Each signature tuple retains 2 objectives x 4 float64/int64 cells
# (64 B) in the dominant all-requests table, so the cap bounds the
# memoized footprint to ~64 MB — the same order as a max_states-sized
# sweep's per-state arrays (zoo alphabets are orders of magnitude below)
_ROLLING_TABLE_CAP = 1_000_000


def _group_table_states(wls: Sequence[Workload]) -> int:
    """Signature tuples of the largest (all-requests) group-edge table —
    the dominant term of the rolling route's shared-table footprint."""
    return math.prod(wl.dense.n_sig for wl in wls)


def _solve_concurrent_rolling(
    wls: Sequence[Workload], cm: ContentionModel, objective: str,
    caches: ConcurrentCaches | None = None,
    window_states: int = DEFAULT_WINDOW_STATES,
) -> ConcurrentSchedule:
    """Rolling-horizon merge for grids beyond the exact-solve ceiling.

    The next window of ops across ALL M requests — window lengths
    proportional to each request's remaining chain, bounded to
    ``window_states`` grid states — is co-scheduled with the exact
    vectorized sweep, and windows are stitched back-to-back.  Each
    stitched schedule is a feasible path of the full progress grid, so
    its cost upper-bounds the exact grid optimum; unlike the pairwise
    merge it keeps ops of *every* request available for co-execution at
    all times instead of serializing disjoint pairs.
    """
    m = len(wls)
    ctx = _GridContext(wls, cm, objective, caches)
    ns = [wl.n for wl in wls]
    done = [0] * m
    steps: list[ConcurrentStep] = []
    energy = 0.0
    while any(done[r] < ns[r] for r in range(m)):
        rem = [ns[r] - done[r] for r in range(m)]
        w = _window_lengths(rem, window_states)
        hi = [done[r] + w[r] for r in range(m)]
        wsteps, weng = ctx.sweep(done, hi)
        steps.extend(wsteps)
        energy += weng
        done = hi
    latency = sum(st.cost for st in steps)
    return ConcurrentSchedule(steps=steps, latency=latency, energy=energy,
                              objective=objective, mode="rolling")


def _solve_concurrent_grid_astar(
    wls: Sequence[Workload], cm: ContentionModel, objective: str,
    group_memo: dict | None = None,
) -> ConcurrentSchedule:
    """Retained heap A* on the M-dimensional progress grid (the
    pre-vectorization implementation, kept as the equivalence oracle for
    the anti-diagonal sweep — ``algorithm="grid_astar"``).

    Same structure as the pair A*: singleton advances use the per-request
    solo edges; subset advances of size >= 2 are priced by the group
    co-execution laws, minimized over all supported PU combinations and
    memoized per (subset, signature-tuple) — the model zoo's repeated
    layer shapes make the memo hit rate high.  The admissible heuristic
    is the per-request scaled suffix bound (max across requests for
    latency — a makespan dominates every request's remaining floor — and
    the sum for energy, which is additive per op).
    """
    m = len(wls)
    denses = [wl.dense for wl in wls]
    ns = [d.n for d in denses]
    solo = [_solo_edges(d, objective) for d in denses]
    _require_all_advanceable(wls, [s[0] for s in solo])
    sigs = [d.sig.tolist() for d in denses]
    sk = [s[0].tolist() for s in solo]
    scale = cm.min_factor()
    sufs = [_suffix_heuristic(d, objective, scale) for d in denses]

    # dense heuristic over the whole grid (<= max_states floats)
    shape = tuple(n + 1 for n in ns)
    if objective == "latency":
        h = np.zeros(shape)
        for r, suf in enumerate(sufs):
            np.maximum(h, suf.reshape([-1 if i == r else 1
                                       for i in range(m)]), out=h)
    else:
        h = sum(suf.reshape([-1 if i == r else 1 for i in range(m)])
                for r, suf in enumerate(sufs))
        h = np.ascontiguousarray(h)
    hs = h.ravel()

    strides = [0] * m
    strides[m - 1] = 1
    for r in range(m - 2, -1, -1):
        strides[r] = strides[r + 1] * shape[r + 1]
    n_states = strides[0] * shape[0]
    target = n_states - 1

    # subset masks, their advancing-request tuples and state deltas
    masks = []
    for bits in range(1, 1 << m):
        reqs = tuple(r for r in range(m) if bits & (1 << r))
        masks.append((bits, reqs, sum(strides[r] for r in reqs)))

    pu_lists = [d.pus for d in denses]
    if group_memo is None:
        group_memo = {}
    obj_idx = 0 if objective == "latency" else 1

    def group_edge(reqs: tuple[int, ...], sig_key: tuple[int, ...]) -> tuple:
        """(key, step_cost, energy, pu-index tuple) minimized over all
        supported PU combos; first minimum in lexicographic PU-index
        order (the M-ary analog of the pair cache's row-major argmin).
        One enumeration computes BOTH objectives' bests — the memo is
        objective-independent, so a shared pool serves a latency solve
        and an energy solve of the same workload tuple."""
        res = group_memo.get((reqs, sig_key))
        if res is not None:
            return res[obj_idx]
        rows = [denses[r].sig_row[s] for r, s in zip(reqs, sig_key)]
        wrows = [denses[r].w[row] for r, row in zip(reqs, rows)]
        prows = [denses[r].power[row] for r, row in zip(reqs, rows)]
        sup = [np.flatnonzero(denses[r].mask[row])
               for r, row in zip(reqs, rows)]
        inf = float("inf")
        best_l = best_e = (inf, inf, inf, None)
        for combo in itertools.product(*sup):
            ts = [float(wr[j]) for wr, j in zip(wrows, combo)]
            pws = [float(pr[j]) for pr, j in zip(prows, combo)]
            pnames = [pu_lists[r][j] for r, j in zip(reqs, combo)]
            step = cm.group_step_cost(ts, pnames)
            e = cm.group_energy(ts, pws, pnames)
            if step < best_l[0]:
                best_l = (step, step, e, combo)
            if e < best_e[0]:
                best_e = (e, step, e, combo)
        group_memo[(reqs, sig_key)] = (best_l, best_e)
        return best_l if obj_idx == 0 else best_e

    # tie plateaus: same quantization + deeper-g tie-break as the pair A*
    c00 = float(hs[0])
    quantum = (c00 if c00 > 0 else 1.0) * (sum(ns) + 64) * 1e-15
    inv_q = 1.0 / quantum

    dist = np.full(n_states, np.inf)
    act = np.zeros(n_states, dtype=np.int32)   # subset bitmask taken
    dist[0] = 0.0
    heap: list[tuple[int, float, int]] = [(int(c00 * inv_q), 0.0, 0)]
    found = False
    while heap:
        fq, ng, s = heapq.heappop(heap)
        g = -ng
        if g > dist[s]:
            continue
        if s == target:
            found = True
            break
        pos = []
        rem = s
        for st in strides:
            q, rem = divmod(rem, st)
            pos.append(q)
        for bits, reqs, delta in masks:
            ok = True
            for r in reqs:
                if pos[r] >= ns[r]:
                    ok = False
                    break
            if not ok:
                continue
            if len(reqs) == 1:
                r = reqs[0]
                key = sk[r][pos[r]]
            else:
                key = group_edge(
                    reqs, tuple(sigs[r][pos[r]] for r in reqs))[0]
                if key == float("inf"):
                    continue
            nd = g + key
            nst = s + delta
            if nd < dist[nst]:
                dist[nst] = nd
                act[nst] = bits
                heapq.heappush(
                    heap, (int((nd + hs[nst]) * inv_q), -nd, nst))
    if not found:  # pragma: no cover - gated by _require_all_advanceable
        raise InfeasibleScheduleError(
            "grid A* exhausted without reaching the all-requests-complete "
            "state (every op passed the per-PU support gate, so this "
            "indicates an internal inconsistency)")

    # reconstruct target -> start
    steps: list[ConcurrentStep] = []
    energy = 0.0
    pos = list(ns)
    s = target
    while s != 0:
        bits = int(act[s])
        if bits == 0:  # pragma: no cover - corrupt predecessor chain
            raise RuntimeError(f"grid A*: no action recorded at {pos}")
        reqs = tuple(r for r in range(m) if bits & (1 << r))
        for r in reqs:
            pos[r] -= 1
        s -= sum(strides[r] for r in reqs)
        ops: list[int | None] = [None] * m
        pus_: list[str | None] = [None] * m
        if len(reqs) == 1:
            r = reqs[0]
            _, sarg, sw, se = solo[r]
            ops[r] = wls[r].chain[pos[r]]
            pus_[r] = pu_lists[r][int(sarg[pos[r]])]
            cost = float(sw[pos[r]])
            energy += float(se[pos[r]])
        else:
            _, cost, e, combo = group_edge(
                reqs, tuple(sigs[r][pos[r]] for r in reqs))
            for r, j in zip(reqs, combo):
                ops[r] = wls[r].chain[pos[r]]
                pus_[r] = pu_lists[r][j]
            energy += e
        steps.append(ConcurrentStep(ops=tuple(ops), pus=tuple(pus_),
                                    cost=cost))
    steps.reverse()
    latency = sum(st.cost for st in steps)
    return ConcurrentSchedule(steps=steps, latency=latency, energy=energy,
                              objective=objective, mode="joint-grid")


def _solve_concurrent_pairwise(
    wls: Sequence[Workload], cm: ContentionModel, objective: str,
    caches: ConcurrentCaches | None = None,
) -> ConcurrentSchedule:
    """Pairwise-merge fallback for M-request co-scheduling.

    Requests are sorted by descending solo-best cost (suffix total of
    each op's best-PU solo cost) and *adjacent* requests pair up — the
    two longest together, then the next two, and so on — because a
    well-overlapped pair's makespan approaches the longer member's solo
    time, so pairing long with long minimizes the serialized total.
    Each pair is co-scheduled with the exact pair A* (or its scalar
    reference under custom contention laws); pairs run back-to-back;
    an odd cheapest request runs solo at the end.  The result is a
    feasible M-ary ``ConcurrentSchedule`` (only ops within a pair
    co-execute) whose cost upper-bounds the exact grid optimum.
    """
    m = len(wls)
    solo_keys = [_solo_edges(wl.dense, objective)[0] for wl in wls]
    # an unadvanceable op would otherwise sort its request first (inf
    # total) and surface later as the pair solver's opaque error
    _require_all_advanceable(wls, solo_keys)
    totals = [float(np.sum(skr)) for skr in solo_keys]
    order = sorted(range(m), key=lambda r: (-totals[r], r))
    steps: list[ConcurrentStep] = []
    latency = 0.0
    energy = 0.0
    for a, b in zip(order[::2], order[1::2]):
        pair = solve_concurrent_joint(
            wls[a].chain, wls[a].table, wls[b].chain, wls[b].table,
            wls[a].pus, cm, objective,
            dense0=wls[a].dense, dense1=wls[b].dense,
            cache=_pair_cache(caches, cm, wls, a, b))
        for st in pair.steps:
            ops: list[int | None] = [None] * m
            pus_: list[str | None] = [None] * m
            ops[a], ops[b] = st.ops
            pus_[a], pus_[b] = st.pus
            steps.append(ConcurrentStep(ops=tuple(ops), pus=tuple(pus_),
                                        cost=st.cost))
        latency += pair.latency
        energy += pair.energy
    if m % 2:
        r = order[-1]
        solo_steps, lat, eng = _solo_step_walk(wls[r], r, m, objective)
        steps.extend(solo_steps)
        latency += lat
        energy += eng
    return ConcurrentSchedule(steps=steps, latency=latency, energy=energy,
                              objective=objective, mode="pairwise")


# ---------------------------------------------------------------------------
# Warm-start incremental re-planning (the serving hot path)
# ---------------------------------------------------------------------------


def solve_concurrent_horizon(
    workloads: Sequence[Workload],
    contention: ContentionModel | None = None,
    objective: str = "latency",
    caches: ConcurrentCaches | None = None,
    horizon_states: int = DEFAULT_HORIZON_STATES,
) -> ConcurrentSchedule:
    """Exact bounded-lookahead *prefix* of a concurrent schedule.

    Co-schedules only the next window of ops across all M requests —
    window lengths proportional to each request's remaining chain,
    bounded to ``horizon_states`` grid states — with the exact
    vectorized sweep, and returns that window (``mode="horizon"``).
    This is the serving engine's bounded-latency re-plan primitive: the
    cost of a re-plan is O(``horizon_states``) regardless of how much
    work remains, so admission never stalls behind a full-grid solve.
    The window is a feasible prefix of a full schedule (every unfinished
    request advances ≥ 1 op); callers execute it and re-plan at the
    window frontier.  Requires the default group co-execution laws
    (custom laws have no windowed exact route — use
    ``solve_concurrent(algorithm="pairwise")``).
    """
    contention = contention or ContentionModel()
    wls = list(workloads)
    m = len(wls)
    if m == 0:
        raise ValueError("solve_concurrent_horizon needs at least one "
                         "workload")
    if horizon_states < 2:
        raise ValueError(
            f"horizon_states must be >= 2 (one advanced op needs a "
            f"2-state axis), got {horizon_states}")
    if m == 1:
        w = _window_lengths([wls[0].n], horizon_states)[0]
        steps, lat, eng = _solo_step_walk(wls[0], 0, 1, objective, 0, w)
        return ConcurrentSchedule(steps=steps, latency=lat, energy=eng,
                                  objective=objective, mode="horizon")
    if not uses_default_group(contention):
        raise ValueError(
            "solve_concurrent_horizon windows the exact grid sweep, which "
            "requires the default group co-execution laws; "
            f"{type(contention).__name__} overrides them — use "
            "solve_concurrent(algorithm='pairwise') for a full solve")
    ctx = _GridContext(wls, contention, objective, caches)
    w = _window_lengths([wl.n for wl in wls], horizon_states)
    steps, energy = ctx.sweep([0] * m, w)
    return ConcurrentSchedule(steps=steps,
                              latency=sum(st.cost for st in steps),
                              energy=energy, objective=objective,
                              mode="horizon")


class _PairCacheView:
    """A parent :class:`~repro.core.contention.PairCostCache` re-exposed
    over tail dense views that carry the *parent's* signature ids
    (``_tail_sig_view``): table lookups by those ids return values
    bitwise-identical to a tail-built cache's, because each entry
    depends only on the signature's row content.  Internal to the warm
    M = 2 re-plan path — the views must never be used to *build* a new
    cache (their ``sig_row`` still indexes parent rows)."""

    def __init__(self, cache: PairCostCache, d0: DenseCostTable,
                 d1: DenseCostTable):
        self._cache = cache
        self.d0 = d0
        self.d1 = d1

    def edge_tables(self, objective: str):
        return self._cache.edge_tables(objective)


def _tail_sig_view(wl: Workload, pos: int) -> Workload:
    """``wl.tail(pos)`` whose dense view keeps the parent's signature
    ids (instead of lazily re-deriving a tail-local alphabet), so the
    parent's signature-indexed edge tables stay directly addressable.
    ``sig_row`` is inherited verbatim and indexes *parent* rows — valid
    for table lookups only, never for building caches from the view."""
    if pos == 0:
        return wl
    tl = wl.tail(pos)
    d, pd = tl.dense, wl.dense
    d._sig = pd.sig[pos:]
    d._sig_row = pd.sig_row
    return tl


class IncrementalConcurrentSolver:
    """Warm-start re-planner for a fixed concurrent workload tuple.

    Built once per (workload tuple, contention model, condition) — the
    orchestrator keeps one per active handle set — it persists the
    per-objective grid contexts (solo edges, signature arrays) and
    shares the content-keyed pair/group edge tables of a
    :class:`ConcurrentCaches` pool, so that every re-plan event of the
    serving lifecycle prices only what changed:

    * **advance** — the remaining sub-box is re-swept on the persistent
      context; no tail views, no ``np.unique`` signature derivation, no
      edge-table builds.
    * **retire** (a member finishes) — the surviving subset's context is
      assembled from the same memoized per-request pieces, and every
      group table over surviving members is a pool hit.
    * **admit** (a new member) — the orchestrator builds a solver for
      the widened tuple; tables over previously-seen members (and over
      re-admitted models, keyed by content) are pool hits, so only
      subsets involving genuinely new content are priced.
    * **condition fold-in** — condition-scaled workloads have new
      content signatures, so their tables re-price exactly once into
      the new condition's pool and every subsequent re-plan under that
      condition is warm again.

    ``solve(progress, objective)`` returns a schedule **bitwise
    identical** to ``solve_concurrent([wl.tail(p) for unfinished], ...)``
    on the same state — same auto routing (solo walk / pair A* /
    grid sweep / rolling merge), same relaxation order, same tie
    policy, same FP accumulation — the cold solver remains the oracle
    (``tests/test_incremental_replan.py`` replays random traces against
    it).  Routes the warm layer cannot reproduce bit-for-bit (custom
    contention laws, the pairwise fallback) return ``None`` so callers
    fall back to the cold solver.  ``horizon_states`` bounds a re-plan
    to the next window, mirroring :func:`solve_concurrent_horizon`.
    """

    def __init__(self, workloads: Sequence[Workload],
                 contention: ContentionModel | None = None,
                 caches: ConcurrentCaches | None = None,
                 max_states: int | None = None,
                 window_states: int = DEFAULT_WINDOW_STATES):
        self.wls = list(workloads)
        self.m = len(self.wls)
        if self.m == 0:
            raise ValueError("IncrementalConcurrentSolver needs at least "
                             "one workload")
        self.cm = contention or ContentionModel()
        self.caches = caches if caches is not None else ConcurrentCaches()
        self.max_states = (DEFAULT_MAX_STATES if max_states is None
                           else max_states)
        self.window_states = window_states
        self.ns = [wl.n for wl in self.wls]
        self.stats = {"solves": 0, "delegated": 0}
        self._ctx: dict[tuple, _GridContext] = {}
        self._solo: dict[tuple[int, str], tuple] = {}
        self._last_bad: dict[tuple[int, str], int] = {}

    # -- memoized per-request pieces ----------------------------------------
    def _solo_for(self, r: int, objective: str) -> tuple:
        key = (r, objective)
        solo = self._solo.get(key)
        if solo is None:
            solo = _solo_edges(self.wls[r].dense, objective)
            self._solo[key] = solo
        return solo

    def _context(self, active: tuple[int, ...], objective: str
                 ) -> _GridContext:
        key = (active, objective)
        ctx = self._ctx.get(key)
        if ctx is None:
            # feasibility is progress-dependent, so it is checked per
            # solve over the remaining tail (mirroring the cold error),
            # not once over the full chains here
            ctx = _GridContext([self.wls[r] for r in active], self.cm,
                               objective, self.caches,
                               check_advanceable=False)
            self._ctx[key] = ctx
        return ctx

    def _check_tails(self, active: tuple[int, ...], progress: Sequence[int],
                     objective: str) -> None:
        """Per-solve advanceability gate over the remaining tails —
        message-identical to ``_require_all_advanceable`` on the cold
        path's tail workloads (request indices are positions in the
        active tuple; chain positions are tail-relative)."""
        for idx, r in enumerate(active):
            key = (r, objective)
            last = self._last_bad.get(key)
            if last is None:
                bad = ~np.isfinite(np.asarray(self._solo_for(r, objective)[0]))
                last = int(bad.nonzero()[0][-1]) if bad.any() else -1
                self._last_bad[key] = last
            p = progress[r]
            if last >= p:
                skey = np.asarray(self._solo_for(r, objective)[0])
                pos = int(np.argmax(~np.isfinite(skey[p:])))
                raise InfeasibleScheduleError(
                    f"request {idx}: {self.wls[r].op_name(p + pos)} at "
                    f"chain position {pos} is unsupported on every PU — "
                    "no concurrent transition can advance it")

    def _tail_n_sig(self, r: int, p: int) -> int:
        return int(np.unique(self.wls[r].dense.sig[p:]).size)

    # -- solve routes --------------------------------------------------------
    def _solo_tail(self, r: int, lo: int, hi: int | None, objective: str,
                   mode: str) -> ConcurrentSchedule:
        steps, lat, eng = _solo_step_walk(self.wls[r], 0, 1, objective,
                                          lo, hi,
                                          solo=self._solo_for(r, objective))
        return ConcurrentSchedule(steps=steps, latency=lat, energy=eng,
                                  objective=objective, mode=mode)

    def _solve_pair(self, active: tuple[int, ...], progress: Sequence[int],
                    objective: str) -> ConcurrentSchedule:
        a, b = active
        wa, wb = self.wls[a], self.wls[b]
        pa, pb = progress[a], progress[b]
        base = _pair_cache(self.caches, self.cm, self.wls, a, b)
        ta, tb = _tail_sig_view(wa, pa), _tail_sig_view(wb, pb)
        cache = (base if pa == 0 and pb == 0
                 else _PairCacheView(base, ta.dense, tb.dense))
        return solve_concurrent_joint(
            ta.chain, ta.table, tb.chain, tb.table, wa.pus, self.cm,
            objective, algorithm="astar", cache=cache)

    def _sweep_box(self, active: tuple[int, ...], progress: Sequence[int],
                   hi: Sequence[int], objective: str, mode: str
                   ) -> ConcurrentSchedule:
        ctx = self._context(active, objective)
        steps, energy = ctx.sweep([progress[r] for r in active], hi)
        return ConcurrentSchedule(steps=steps,
                                  latency=sum(st.cost for st in steps),
                                  energy=energy, objective=objective,
                                  mode=mode)

    def _solve_rolling(self, active: tuple[int, ...],
                       progress: Sequence[int], objective: str
                       ) -> ConcurrentSchedule:
        ctx = self._context(active, objective)
        budget = min(self.window_states, self.max_states)
        ns = [self.ns[r] for r in active]
        done = [progress[r] for r in active]
        steps: list[ConcurrentStep] = []
        energy = 0.0
        while any(d < n for d, n in zip(done, ns)):
            rem = [n - d for d, n in zip(done, ns)]
            w = _window_lengths(rem, budget)
            hi = [d + wi for d, wi in zip(done, w)]
            wsteps, weng = ctx.sweep(done, hi)
            steps.extend(wsteps)
            energy += weng
            done = hi
        return ConcurrentSchedule(steps=steps,
                                  latency=sum(st.cost for st in steps),
                                  energy=energy, objective=objective,
                                  mode="rolling")

    def solve(self, progress: Sequence[int], objective: str = "latency",
              horizon_states: int | None = None) -> ConcurrentSchedule | None:
        """Warm re-plan from ``progress`` (completed-op count per
        request; fully-advanced requests drop out of the schedule, whose
        step tuples cover only the unfinished ones, exactly like the
        cold path's active-set filtering).  Returns ``None`` when the
        state routes to a path the warm layer cannot reproduce bitwise
        (custom contention laws / pairwise) — fall back to
        :func:`solve_concurrent`."""
        progress = list(progress)
        if len(progress) != self.m:
            raise ValueError(
                f"progress has {len(progress)} entries for {self.m} "
                "workloads")
        for r, (p, n) in enumerate(zip(progress, self.ns)):
            if not 0 <= p <= n:
                raise ValueError(
                    f"request {r}: progress {p} outside [0, {n}]")
        active = tuple(r for r in range(self.m) if progress[r] < self.ns[r])
        if not active:
            raise ValueError("solve: every request is fully advanced — "
                             "nothing left to schedule")
        if horizon_states is not None:
            return self._solve_horizon(active, progress, objective,
                                       horizon_states)
        if len(active) == 1:
            self.stats["solves"] += 1
            return self._solo_tail(active[0], progress[active[0]], None,
                                   objective, "joint")
        if not uses_default_coexec(self.cm):
            self.stats["delegated"] += 1
            return None
        if len(active) == 2:
            self.stats["solves"] += 1
            return self._solve_pair(active, progress, objective)
        if not uses_default_group(self.cm):
            self.stats["delegated"] += 1
            return None
        rem = [self.ns[r] - progress[r] for r in active]
        n_states = math.prod(x + 1 for x in rem)
        if n_states <= self.max_states:
            self._check_tails(active, progress, objective)
            self.stats["solves"] += 1
            return self._sweep_box(active, progress,
                                   [self.ns[r] for r in active],
                                   objective, "joint-grid")
        sig_states = math.prod(self._tail_n_sig(r, progress[r])
                               for r in active)
        if sig_states <= _ROLLING_TABLE_CAP:
            self._check_tails(active, progress, objective)
            self.stats["solves"] += 1
            return self._solve_rolling(active, progress, objective)
        self.stats["delegated"] += 1
        return None

    def _solve_horizon(self, active: tuple[int, ...],
                       progress: Sequence[int], objective: str,
                       horizon_states: int) -> ConcurrentSchedule | None:
        if horizon_states < 2:
            raise ValueError(
                f"horizon_states must be >= 2 (one advanced op needs a "
                f"2-state axis), got {horizon_states}")
        if len(active) == 1:
            r = active[0]
            p = progress[r]
            w = _window_lengths([self.ns[r] - p], horizon_states)[0]
            self.stats["solves"] += 1
            return self._solo_tail(r, p, p + w, objective, "horizon")
        if not uses_default_group(self.cm):
            self.stats["delegated"] += 1
            return None      # cold solve_concurrent_horizon raises for this
        self._check_tails(active, progress, objective)
        rem = [self.ns[r] - progress[r] for r in active]
        w = _window_lengths(rem, horizon_states)
        hi = [progress[r] + wi for r, wi in zip(active, w)]
        self.stats["solves"] += 1
        return self._sweep_box(active, progress, hi, objective, "horizon")
