"""Shared scheduler error types.

Lives in its own leaf module so both the search engine
(:mod:`repro.core.search`) and the dynamic scheduler
(:mod:`repro.core.dynamic`, which imports the search engine) can raise
the same exception without a circular import.
"""
from __future__ import annotations


class InfeasibleScheduleError(ValueError):
    """No PU can run some op (profiling gap, compile failure on every PU,
    or a runtime condition that masked the last capable PU).

    Raised with context — which request, which op, which chain position —
    instead of a bare ``ValueError`` from deep inside a solver loop.
    """
