"""Shared scheduler + execution-runtime error types.

Lives in its own leaf module so the search engine
(:mod:`repro.core.search`), the dynamic scheduler
(:mod:`repro.core.dynamic`, which imports the search engine), and the
execution runtime (:mod:`repro.core.executor` /
:mod:`repro.core.laneprogram` / :mod:`repro.core.faults`) can raise the
same exceptions without circular imports.
"""
from __future__ import annotations

from typing import Any


class InfeasibleScheduleError(ValueError):
    """No PU can run some op (profiling gap, compile failure on every PU,
    or a runtime condition that masked the last capable PU).

    Raised with context — which request, which op, which chain position —
    instead of a bare ``ValueError`` from deep inside a solver loop.
    """


class ExecutionError(RuntimeError):
    """Base class for failures of the execution runtime (as opposed to
    planning failures, which are :class:`InfeasibleScheduleError`)."""


class ExecutionTimeoutError(ExecutionError):
    """A cross-lane wait (or a whole run) exceeded its watchdog budget.

    Every ``threading.Event`` wait in the executor and the compiled
    :class:`~repro.core.laneprogram.LaneProgram` is bounded by a deadline
    derived from the plan's cost-model estimate times a configurable
    factor (see :class:`~repro.core.faults.ExecutionPolicy`); a lane that
    hangs raises this — naming the lane, op/segment, and elapsed vs
    budget — instead of deadlocking the run forever.

    ``inflight`` is a structured snapshot of ``RunContext.current`` at
    the deadline (``{lane: in-flight work description}``): the lanes
    that were still executing when the watchdog fired.  Health tracking
    (:mod:`repro.core.health`) uses it to attribute the timeout to the
    stalled lane(s) instead of blaming the whole PU set.
    """

    def __init__(self, message: str,
                 inflight: dict[str, str] | None = None):
        super().__init__(message)
        self.inflight: dict[str, str] = dict(inflight or {})


class PULostError(ExecutionError):
    """A PU lane died permanently mid-run (injected via
    :class:`~repro.core.faults.FaultPlan` kind ``"pu_lost"``, or raised
    by a payload that detects its device is gone).

    Carries the loss point and — attached by the executor before the
    error propagates — the execution *frontier*: ``partial`` is the list
    of per-request results dicts completed before the loss, which
    ``Orchestrator.execute`` uses to re-plan the remaining ops on the
    surviving PUs and resume without recomputing finished work.
    """

    def __init__(self, message: str, pu: str | None = None,
                 request: int | None = None, op: int | None = None):
        super().__init__(message)
        self.pu = pu
        self.request = request
        self.op = op
        # per-request {op: result} dicts completed before the loss;
        # attached by the raising executor path
        self.partial: list[dict[int, Any]] | None = None


class FaultRetryExceededError(ExecutionError):
    """A transient (``RecoverableError``) failure persisted through every
    bounded retry attempt; raised ``from`` the final transient error with
    the failing point and attempt count in the message.

    Carries the failing point structurally (``lane``/``request``/``op``,
    any of which may be ``None`` when the caller had no point context) so
    the serving layer can attribute the exhaustion to a lane's health
    record and shed exactly the affected request."""

    def __init__(self, message: str, lane: str | None = None,
                 request: int | None = None, op: int | None = None):
        super().__init__(message)
        self.lane = lane
        self.request = request
        self.op = op
