"""TPU autoshard mode: sharding strategies as BIDENT "PUs".

The beyond-paper system (DESIGN.md §2.2).  On a TPU pod the heterogeneity
that matters is not CPU/GPU/NPU but *which sharding a given operator runs
under*.  This module maps BIDENT's abstraction 1:1 onto that problem:

  PU P_j                   -> sharding strategy S_j (REP/DP/SP/TP/DP_TP/EP)
  kernel cost w(O_i, P_j)  -> v5e roofline time of the per-shard work
  H2D/D2H transition cost  -> resharding collective bytes / ICI bandwidth
  unsupported (op, PU)     -> infeasible (op, strategy): no node in graph
  energy w x p             -> pod power model (compute vs memory bound)

The *same* CostTable / graph / Dijkstra machinery from ``core`` then finds
the optimal per-operator sharding path — the paper's Algorithm 1 applied
to distributed-sharding search (an exact, shortest-path variant of the
Alpa-style intra-op pass).

Faithful-to-paper approximation (documented, and revisited in the §Perf
hillclimb): a strategy transition is modeled as D2H (all-gather the
producer's output out of its sharding) + H2D (local slice into the
consumer's sharding), exactly mirroring the paper's accelerator H2D/D2H
edge rule.  A direct all-to-all reshard can be cheaper; see
``direct_reshard`` below, which the optimized mode enables.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

import numpy as np

from .contention import ContentionModel
from .costmodel import CostEntry, CostTable, PUSpec
from .op import FusedOp, OpGraph
from .schedule import ParallelSchedule, SeqSchedule, evaluate_sequential, single_pu_cost
from .search import solve_parallel, solve_sequential

# ---------------------------------------------------------------------------
# TPU v5e chip constants (the TARGET platform; see launch/specs.py)
# ---------------------------------------------------------------------------

PEAK_FLOPS = 197e12     # bf16 FLOP/s per chip
HBM_BW = 819e9          # B/s per chip
ICI_BW = 50e9           # B/s per link per chip
DISPATCH_S = 1.5e-6     # per-XLA-op launch overhead
HOP_LAT = 1e-6          # per collective phase latency
POWER_COMPUTE = 170.0   # W per chip, MXU busy
POWER_MEMORY = 120.0    # W per chip, HBM bound

# MXU vs VPU efficiency per fused-op kind (fraction of peak FLOP/s).
KIND_EFF = {
    "matmul": 0.85, "conv2d": 0.80, "attention": 0.75, "rdft": 0.30,
    "cumsum": 0.05, "scan": 0.05, "gather": 0.20, "scatter": 0.20,
    "embed": 0.20, "norm": 0.10, "softmax": 0.10, "act": 0.10,
    "add": 0.10, "mul": 0.10, "other": 0.10, "dwconv": 0.40,
    "transfer": 1.0,
}
KIND_BW_EFF = {
    "gather": 0.5, "scatter": 0.5, "embed": 0.5, "cumsum": 0.7, "scan": 0.7,
}

# kinds whose recurrence/statefulness forbids sharding the time dim
_SEQ_FORBIDDEN = ("attention", "scan", "cumsum")


@dataclasses.dataclass(frozen=True)
class Strategy:
    """One sharding strategy = one BIDENT "PU"."""

    name: str
    # parallel degree over which this strategy divides the op's work,
    # given (data_axis, model_axis) mesh sizes
    data_frac: bool      # shards over the data axis
    model_frac: bool     # shards over the model axis
    # which tensor dim the strategy splits (for feasibility checks):
    # "batch" (dim 0), "seq" (dim 1), "feature" (last dim), "table"
    # (first dim of operand 0 — the EP/gather case), or None (replicated)
    split: str | None

    def degree(self, d_data: int, d_model: int) -> int:
        deg = 1
        if self.data_frac:
            deg *= d_data
        if self.model_frac:
            deg *= d_model
        return deg


STRATEGIES: dict[str, Strategy] = {
    "REP":   Strategy("REP", False, False, None),
    "DP":    Strategy("DP", True, False, "batch"),
    "SP":    Strategy("SP", True, False, "seq"),
    "TP":    Strategy("TP", False, True, "feature"),
    "DP_TP": Strategy("DP_TP", True, True, "batch+feature"),
    "EP":    Strategy("EP", False, True, "table"),
}


def strategy_pus(d_data: int, d_model: int,
                 names: Sequence[str] | None = None) -> dict[str, PUSpec]:
    """PUSpec adapters so the core search/graph code works unchanged.

    Every strategy is an "accelerator" (the paper's transition rule then
    charges D2H out of the source + H2D into the destination, which is our
    all-gather + local-slice reshard model).  Power fields carry the *pod*
    power (chips x per-chip W) used to scale transition-edge energy.
    """
    n = d_data * d_model
    out: dict[str, PUSpec] = {}
    for nm in (names or STRATEGIES):
        out[nm] = PUSpec(
            name=nm, is_accelerator=True, dispatch_s=DISPATCH_S,
            mem_bw=HBM_BW, peak_gemm={2: PEAK_FLOPS, 1: 2 * PEAK_FLOPS},
            sat_flops={2: 0.0, 1: 0.0}, kind_eff=KIND_EFF,
            kind_bw_eff=KIND_BW_EFF, h2d_base=0.0, h2d_bw=ICI_BW,
            power_compute=POWER_COMPUTE * n, power_memory=POWER_MEMORY * n,
        )
    return out


# ---------------------------------------------------------------------------
# cost model
# ---------------------------------------------------------------------------


class ShardingCostModel:
    """Fill a CostTable whose "PUs" are sharding strategies."""

    def __init__(self, d_data: int = 16, d_model: int = 16,
                 strategies: Sequence[str] | None = None,
                 direct_reshard: bool = False):
        self.d_data = d_data
        self.d_model = d_model
        self.names = list(strategies or STRATEGIES)
        self.pus = strategy_pus(d_data, d_model, self.names)
        # beyond-paper refinement: transitions bounded by a direct
        # all-to-all instead of gather+slice (see transition docstring)
        self.direct_reshard = direct_reshard

    # -- feasibility ---------------------------------------------------------
    def feasible(self, op: FusedOp, s: Strategy) -> bool:
        if s.split is None:
            return True
        shape = op.out_shape or (op.in_shapes[0] if op.in_shapes else ())
        if not shape:
            return False
        if s.split == "batch":
            return shape[0] % self.d_data == 0 and shape[0] >= self.d_data
        if s.split == "seq":
            if op.kind in _SEQ_FORBIDDEN:
                return False
            return (len(shape) >= 3 and shape[1] % self.d_data == 0
                    and shape[1] >= self.d_data)
        if s.split == "feature":
            return shape[-1] % self.d_model == 0 and shape[-1] >= self.d_model
        if s.split == "batch+feature":
            return (shape[0] % self.d_data == 0 and shape[0] >= self.d_data
                    and shape[-1] % self.d_model == 0
                    and shape[-1] >= self.d_model)
        if s.split == "table":
            # EP: shard the lookup table / expert dim (gather/scatter class)
            if op.kind not in ("gather", "scatter", "embed"):
                return False
            t = op.in_shapes[0] if op.in_shapes else ()
            return bool(t) and t[0] % self.d_model == 0 and t[0] >= self.d_model
        return False

    # -- per-shard bytes (the DP/TP asymmetry) -------------------------------
    def _shard_bytes(self, op: FusedOp, s: Strategy, deg: int) -> float:
        """HBM bytes per chip under strategy ``s``.

        The asymmetry that makes the search non-trivial: token-sharding
        (DP/SP) replicates *weights* (every chip streams the full weight),
        while weight-sharding (TP/EP) replicates *activations*.  For
        decode-shape GEMMs (tiny token count, weight-dominated) TP wins by
        ~d_model x; for train-shape GEMMs (activation-dominated) DP wins.
        This is the TPU analog of the paper's operand-size-dependent PU
        affinity (Observation 2 / Fig. 3).
        """
        dtb = op.dtype_bytes
        if op.kind in ("matmul", "conv2d", "dwconv") and len(op.in_shapes) >= 2:
            act = float(np.prod(op.in_shapes[0])) * dtb
            w = float(np.prod(op.in_shapes[1])) * dtb
            out = op.out_bytes
            if s.split in ("batch", "seq"):            # DP / SP
                return act / deg + w + out / deg
            if s.split == "feature":                    # TP (column parallel)
                return act + w / deg + out / deg
            if s.split == "batch+feature":              # DP_TP
                return (act / self.d_data + w / self.d_model
                        + out / deg)
            return act + w + out                        # REP
        if op.kind in ("gather", "scatter", "embed") and op.in_shapes:
            table = float(np.prod(op.in_shapes[0])) * dtb
            rest = (sum(float(np.prod(sh)) for sh in op.in_shapes[1:]) * dtb
                    + op.out_bytes)
            if s.split == "table":                      # EP
                return table / deg + rest
            if s.split is None:
                return table + rest
            return table + rest / deg                   # token sharding
        # weight-free ops (attention over cache, norms, eltwise, scans):
        # all strategies divide traffic evenly over their degree
        return op.bytes_moved / deg

    # -- per-op costing ------------------------------------------------------
    def entry(self, op: FusedOp, name: str) -> CostEntry | None:
        """Cost of ``op`` under strategy ``name``.

        Infeasibility is *soft* by default: when the strategy's split dim
        doesn't exist / divide, the op degrades to replicated execution
        under that strategy (exactly what XLA's sharding propagation does
        for non-divisible dims — cf. Policy's divisibility guard).  Hard
        omission (no table entry — the paper's compile-failure case) only
        happens via ``op.meta['unsupported_on']``.
        """
        if name in op.meta.get("unsupported_on", ()):
            return None
        s = STRATEGIES[name]
        if not self.feasible(op, s):
            s = STRATEGIES["REP"]
        deg = s.degree(self.d_data, self.d_model)
        eff = KIND_EFF.get(op.kind, KIND_EFF["other"])
        bw_eff = KIND_BW_EFF.get(op.kind, 0.8)
        t_compute = (op.flops / deg) / (PEAK_FLOPS * eff)
        t_memory = self._shard_bytes(op, s, deg) / (HBM_BW * bw_eff)
        kernel = max(t_compute, t_memory)
        frac_compute = min(t_compute / kernel, 1.0) if kernel > 0 else 0.0
        n = self.d_data * self.d_model
        power = (POWER_MEMORY + (POWER_COMPUTE - POWER_MEMORY) * frac_compute) * n
        # d2h: all-gather this op's output out of the strategy's activation
        # sharding (bytes x (deg-1)/deg over ICI, + per-phase hop latency).
        if deg > 1:
            gather = (op.out_bytes * (deg - 1) / deg) / ICI_BW \
                + HOP_LAT * math.log2(deg)
            if self.direct_reshard:
                # a direct reshard moves only each chip's resident slice to
                # its new owners: at most bytes/deg per chip pairwise
                gather = min(gather,
                             (op.out_bytes / deg) / ICI_BW
                             + HOP_LAT * math.log2(deg))
        else:
            gather = 0.0
        return CostEntry(kernel=kernel, dispatch=DISPATCH_S, h2d=0.0,
                         d2h=gather, power=power)

    def build_table(self, graph: OpGraph) -> CostTable:
        table = CostTable(self.names)
        for i, op in enumerate(graph.ops):
            for nm in self.names:
                e = self.entry(op, nm)
                if e is not None:
                    table.set(i, nm, e)
        return table


# ---------------------------------------------------------------------------
# autoshard pass
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class AutoshardResult:
    schedule: SeqSchedule
    single: dict[str, float | None]      # strategy -> monolithic latency
    best_single: str
    speedup: float                       # vs best single strategy
    table: CostTable
    model: ShardingCostModel

    def summary(self) -> str:
        lines = [f"autoshard: {len(self.schedule.chain)} fused ops, "
                 f"objective={self.schedule.objective}"]
        for nm, v in sorted(self.single.items()):
            mark = " <- best single" if nm == self.best_single else ""
            lines.append(f"  {nm:6s}: "
                         + (f"{v*1e3:9.3f} ms{mark}" if v is not None
                            else "   infeasible"))
        lines.append(f"  BIDENT: {self.schedule.latency*1e3:9.3f} ms "
                     f"({self.speedup:.2f}x vs best single)")
        counts: dict[str, int] = {}
        for a in self.schedule.assignment:
            counts[a] = counts.get(a, 0) + 1
        lines.append("  assignment: " + ", ".join(
            f"{k}={v}" for k, v in sorted(counts.items())))
        return "\n".join(lines)


def autoshard(graph: OpGraph, *, d_data: int = 16, d_model: int = 16,
              objective: str = "latency",
              direct_reshard: bool = False) -> AutoshardResult:
    """Run the BIDENT search with sharding strategies as PUs."""
    model = ShardingCostModel(d_data, d_model, direct_reshard=direct_reshard)
    table = model.build_table(graph)
    chain = list(range(len(graph)))
    sched = solve_sequential(chain, graph.ops, table, model.pus, objective)
    single: dict[str, float | None] = {}
    for nm in model.names:
        c = single_pu_cost(chain, nm, graph.ops, table, model.pus)
        single[nm] = None if c is None else (c[0] if objective == "latency"
                                             else c[1])
    feas = {k: v for k, v in single.items() if v is not None}
    best_single = min(feas, key=feas.get)
    opt = sched.latency if objective == "latency" else sched.energy
    return AutoshardResult(schedule=sched, single=single,
                           best_single=best_single,
                           speedup=feas[best_single] / max(opt, 1e-30),
                           table=table, model=model)


# ---------------------------------------------------------------------------
# override emission: strategy -> Policy logical axes per constrain site
# ---------------------------------------------------------------------------

# logical-axes template per strategy for rank-3 (B, T, F) activation sites;
# Policy.constrain pads/trims to the tensor rank and applies divisibility
# guards, so these templates are safe for any site.
_STRATEGY_AXES: dict[str, tuple] = {
    "REP":   (None, None, None),
    "DP":    ("batch", None, None),
    "SP":    ("batch", "seq_shard", None),
    "TP":    (None, None, "ff"),
    "DP_TP": ("batch", None, "ff"),
    "EP":    (None, None, "experts"),
}


def emit_overrides(site_assignment: Mapping[str, str]) -> dict[str, tuple]:
    """Map {constrain-site name -> strategy} to Policy.overrides.

    The returned dict plugs into ``sharding.Policy(overrides=...)``: model
    code tags its ``with_sharding_constraint`` sites with ``name=...`` and
    the override replaces the default logical axes at that site — this is
    how a BIDENT schedule becomes real NamedShardings in the lowered HLO.
    """
    out: dict[str, tuple] = {}
    for site, strat in site_assignment.items():
        if strat not in _STRATEGY_AXES:
            raise KeyError(f"unknown strategy {strat!r}")
        out[site] = _STRATEGY_AXES[strat]
    return out


# ---------------------------------------------------------------------------
# intra-model parallel regime on TPU (paper §3.3.2 mapped to mesh slices)
# ---------------------------------------------------------------------------

def _ici_contention(names) -> ContentionModel:
    """Branches that co-execute under different strategies contend for ICI
    and HBM bandwidth; a flat measured-style 1.10x factor stands in for
    the paper's per-PU-pair SF table (strategies sharing a mesh axis
    contend; REP never does)."""
    sf = {}
    for a in names:
        for b in names:
            sf[(a, b)] = 1.0 if (a == b or "REP" in (a, b)) else 1.10
    return ContentionModel(sf=sf, mm_sf=sf)


def autoshard_parallel(graph: OpGraph, *, d_data: int = 16,
                       d_model: int = 16, objective: str = "latency",
                       direct_reshard: bool = False) -> ParallelSchedule:
    """Phase/branch-parallel BIDENT search with strategies as PUs.

    MoE layers' routed/shared branches (and enc/dec towers) become the
    paper's concurrent phases: each branch gets its own per-operator
    strategy path and the phase makespan is the contention-adjusted max —
    i.e. independent subgraphs co-execute on disjoint mesh capacity.
    """
    model = ShardingCostModel(d_data, d_model, direct_reshard=direct_reshard)
    table = model.build_table(graph)
    return solve_parallel(graph, table, model.pus,
                          _ici_contention(model.names), objective)
