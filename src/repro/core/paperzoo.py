"""Fused-operator graphs for the paper's own evaluated models (Table 1).

BIDENT evaluates ten model families on the Intel Core Ultra SoC.  To
reproduce Tables 2/3 and Figures 6/8 we rebuild each model's fused-operator
DAG at the paper's granularity (Table 1 "fused ops"), with operand shapes
from the published input shapes.  The *kind mix* is what drives every
result: conv-heavy (ResNet/SNN), GEMM-heavy (ViT/LLaMA/BitNet), FFT
(Hyena), sequential-scan (Mamba), spline-gather (KAN), dual-tower
(LAVISH), and the 4-stage VLA pipeline (pi05).

Each builder returns an ``OpGraph`` (fork/join edges where the paper
exploits intra-model parallelism) and takes ``dtb`` (2 = FP16, 1 = INT8,
the paper's two precision columns).  KAN ops carry
``unsupported_on=("NPU",)`` — the paper's compile-failure case (BitwiseAnd
on float inputs); pi05's prefix/denoise stages carry
``unsupported_on=("GPU",)`` (exceeds GPU memory).
"""
from __future__ import annotations

from typing import Sequence

from .op import FusedOp, OpGraph


class _G:
    """Tiny DAG-builder helper (chain by default, explicit forks)."""

    def __init__(self):
        self.ops: list[FusedOp] = []
        self.edges: list[tuple[int, int]] = []
        self.tail: int | None = None

    def add(self, op: FusedOp, after="tail") -> int:
        idx = len(self.ops)
        self.ops.append(op)
        if after == "tail":
            if self.tail is not None:
                self.edges.append((self.tail, idx))
        elif after is None:
            pass
        else:
            for a in (after if isinstance(after, (list, tuple)) else [after]):
                self.edges.append((a, idx))
        self.tail = idx
        return idx

    def graph(self) -> OpGraph:
        return OpGraph(self.ops, edges=self.edges)


def _conv(name, cin, cout, hw, k, dtb, stride=1, unsupported=()):
    out_hw = hw // stride
    return FusedOp(name=name, kind="conv2d",
                   in_shapes=((1, cin, hw, hw), (cout, cin, k, k)),
                   out_shape=(1, cout, out_hw, out_hw), dtype_bytes=dtb,
                   meta={"unsupported_on": unsupported})


def _mm(name, m, k, n, dtb, unsupported=()):
    return FusedOp(name=name, kind="matmul",
                   in_shapes=((1, m, k), (k, n)), out_shape=(1, m, n),
                   dtype_bytes=dtb, meta={"unsupported_on": unsupported})


def _elt(name, kind, numel, dtb, unsupported=()):
    return FusedOp(name=name, kind=kind, in_shapes=((numel,),),
                   out_shape=(numel,), dtype_bytes=dtb,
                   meta={"unsupported_on": unsupported})


# ---------------------------------------------------------------------------
# CNNs / Transformers
# ---------------------------------------------------------------------------


def resnet50(dtb: int = 2) -> OpGraph:
    """1x3x224x224; ~73 fused Conv-BN-ReLU ops + residual adds."""
    g = _G()
    g.add(_conv("stem", 3, 64, 224, 7, dtb, stride=2))
    cfgs = [(64, 256, 56, 3), (128, 512, 28, 4), (256, 1024, 14, 6),
            (512, 2048, 7, 3)]
    cin = 64
    for bi, (mid, cout, hw, reps) in enumerate(cfgs):
        for r in range(reps):
            g.add(_conv(f"b{bi}.{r}.c1", cin, mid, hw, 1, dtb))
            g.add(_conv(f"b{bi}.{r}.c2", mid, mid, hw, 3, dtb))
            g.add(_conv(f"b{bi}.{r}.c3", mid, cout, hw, 1, dtb))
            g.add(_elt(f"b{bi}.{r}.add", "add", cout * hw * hw, dtb))
            cin = cout
    g.add(FusedOp(name="pool", kind="norm", in_shapes=((1, 2048, 7, 7),),
                  out_shape=(1, 2048), dtype_bytes=dtb))
    g.add(_mm("fc", 1, 2048, 1000, dtb))
    return g.graph()


def vit_b16(dtb: int = 2, head_branches: int = 4) -> OpGraph:
    """1x3x224x224 -> 197 tokens x 768; 12 layers.  Attention splits into
    ``head_branches`` independent head-group branches per layer (the
    paper's "independent attention heads execute on different PUs",
    Table 3: ViT has the most concurrent phases)."""
    g = _G()
    T, d, ff = 197, 768, 3072
    g.add(_conv("patch", 3, d, 224, 16, dtb, stride=16))
    for i in range(12):
        g.add(_elt(f"L{i}.ln1", "norm", T * d, dtb))
        fork = g.add(_mm(f"L{i}.qkv", T, d, 3 * d, dtb))
        heads = []
        dh = d // head_branches
        for h in range(head_branches):
            a = g.add(FusedOp(name=f"L{i}.attn{h}", kind="attention",
                              in_shapes=((1, head_branches, T, dh),
                                         (1, head_branches, T, dh)),
                              out_shape=(1, head_branches, T, dh),
                              dtype_bytes=dtb), after=fork)
            heads.append(a)
        g.add(_mm(f"L{i}.o", T, d, d, dtb), after=heads)
        g.add(_elt(f"L{i}.ln2", "norm", T * d, dtb))
        g.add(_mm(f"L{i}.mlp1", T, d, ff, dtb))
        g.add(_elt(f"L{i}.gelu", "act", T * ff, dtb))
        g.add(_mm(f"L{i}.mlp2", T, ff, d, dtb))
    g.add(_mm("head", 1, d, 1000, dtb))
    return g.graph()


def llama_1l(dtb: int = 2) -> OpGraph:
    """One LLaMA-7B decoder layer at 1x128 (13 fused ops, Fig. 5)."""
    g = _G()
    T, d, ff = 128, 4096, 11008
    g.add(_elt("ln1", "norm", T * d, dtb))
    g.add(_mm("q", T, d, d, dtb))
    g.add(_mm("k", T, d, d, dtb))
    g.add(_mm("v", T, d, d, dtb))
    g.add(FusedOp(name="attn", kind="attention",
                  in_shapes=((1, 32, T, 128), (1, 32, T, 128)),
                  out_shape=(1, 32, T, 128), dtype_bytes=dtb))
    g.add(_mm("o", T, d, d, dtb))
    g.add(_elt("ln2", "norm", T * d, dtb))
    f = g.add(_mm("gate_proj", T, d, ff, dtb))
    g.add(_mm("up_proj", T, d, ff, dtb), after=f - 1)  # parallel with gate
    g.add(_elt("silu", "act", T * ff, dtb), after=f)
    g.add(_elt("mul", "mul", T * ff, dtb), after=[f + 1, f + 2])
    g.add(_mm("down_proj", T, ff, d, dtb))
    g.add(_elt("residual", "add", T * d, dtb))
    return g.graph()


def bitnet(dtb: int = 2) -> OpGraph:
    """Ternary transformer, 36 fused ops, single sequential chain
    (0 concurrent phases, Table 3)."""
    g = _G()
    T, d, ff = 128, 2048, 5460
    for i in range(3):
        g.add(_elt(f"L{i}.ln1", "norm", T * d, dtb))
        g.add(_mm(f"L{i}.qkv", T, d, 3 * d, 1))      # ternary weights
        g.add(FusedOp(name=f"L{i}.attn", kind="attention",
                      in_shapes=((1, 16, T, 128), (1, 16, T, 128)),
                      out_shape=(1, 16, T, 128), dtype_bytes=dtb))
        g.add(_mm(f"L{i}.o", T, d, d, 1))
        g.add(_elt(f"L{i}.add1", "add", T * d, dtb))
        g.add(_elt(f"L{i}.ln2", "norm", T * d, dtb))
        g.add(_mm(f"L{i}.up", T, d, ff, 1))
        g.add(_elt(f"L{i}.act", "act", T * ff, dtb))
        g.add(_mm(f"L{i}.gate", T, ff, ff, 1))
        g.add(_elt(f"L{i}.mul", "mul", T * ff, dtb))
        g.add(_mm(f"L{i}.down", T, ff, d, 1))
        g.add(_elt(f"L{i}.add2", "add", T * d, dtb))
    return g.graph()


# ---------------------------------------------------------------------------
# Emerging architectures
# ---------------------------------------------------------------------------


def mamba_370m(dtb: int = 2) -> OpGraph:
    """Selective SSM at 1x128 (~52 fused ops).  The selective-scan
    recurrences are the paper's CumSum-affinity case (CPU-favoured).
    Parallel SSM branches give Table 3's 25 concurrent phases."""
    g = _G()
    T, d, di, N = 128, 1024, 2048, 16
    for i in range(8):
        fork = g.add(_mm(f"L{i}.in_proj", T, d, 2 * di, dtb))
        # x-branch: conv + scan;   z-branch: gate activation (independent)
        c = g.add(FusedOp(name=f"L{i}.conv", kind="dwconv",
                          in_shapes=((1, di, T, 1), (di, 1, 4, 1)),
                          out_shape=(1, di, T, 1), dtype_bytes=dtb),
                  after=fork)
        s = g.add(FusedOp(name=f"L{i}.scan", kind="cumsum",
                          in_shapes=((1, di, T),), out_shape=(1, di, T),
                          dtype_bytes=dtb))
        z = g.add(_elt(f"L{i}.zgate", "act", T * di, dtb), after=fork)
        g.add(_elt(f"L{i}.mul", "mul", T * di, dtb), after=[s, z])
        g.add(_mm(f"L{i}.out_proj", T, di, d, dtb))
    g.add(_mm("head", 1, d, 50280, dtb))
    return g.graph()


def hyena(dtb: int = 2) -> OpGraph:
    """FFT long-convolution operator mix at 1x1x1024x512.  RDFT/IRDFT +
    elementwise gating are CPU-affine (Fig. 2); the dense projections are
    GEMMs.  448 fused ops at FP16 (order-2 filters over many blocks)."""
    g = _G()
    T, d = 1024, 512
    n_blocks = 56 if dtb == 2 else 11
    for i in range(n_blocks):
        fork = g.add(_mm(f"B{i}.proj", T, d, 3 * d, dtb))
        # two independent filter branches (x1, x2) + gate path
        outs = []
        for br in range(2):
            r = g.add(FusedOp(name=f"B{i}.rdft{br}", kind="rdft",
                              in_shapes=((1, d, T),),
                              out_shape=(1, d, T // 2 + 1, 2),
                              dtype_bytes=dtb), after=fork)
            g.add(_elt(f"B{i}.fmul{br}", "mul", d * (T // 2 + 1) * 2, dtb))
            irf = g.add(FusedOp(name=f"B{i}.irdft{br}", kind="rdft",
                                in_shapes=((1, d, T // 2 + 1, 2),),
                                out_shape=(1, d, T), dtype_bytes=dtb))
            outs.append(irf)
        g.add(_elt(f"B{i}.gate", "mul", d * T, dtb), after=outs)
        g.add(_mm(f"B{i}.out", T, d, d, dtb))
    return g.graph()


def kan(dtb: int = 2) -> OpGraph:
    """Kolmogorov-Arnold network at 1x784 (27 fused ops).  Spline
    evaluation = gather + control-heavy elementwise; CANNOT compile on the
    NPU (BitwiseAnd on float inputs) -> every op omitted from the NPU
    column, the paper's §3.1 fallback-elimination case."""
    g = _G()
    uns = ("NPU",)
    dims = [(784, 128), (128, 128), (128, 64), (64, 10)]
    for i, (din, dout) in enumerate(dims):
        # grid lookup (gather), basis eval (elementwise), spline matmul,
        # base matmul, combine
        g.add(FusedOp(name=f"L{i}.grid_gather", kind="gather",
                      in_shapes=((din * 16, 8), (din,)),
                      out_shape=(din, 8), dtype_bytes=dtb,
                      meta={"unsupported_on": uns}))
        g.add(_elt(f"L{i}.basis", "act", din * 8, dtb, unsupported=uns))
        g.add(_mm(f"L{i}.spline_mm", 1, din * 8, dout, dtb, unsupported=uns))
        f = len(g.ops) - 3
        g.add(_mm(f"L{i}.base_mm", 1, din, dout, dtb, unsupported=uns),
              after=f - 1 if i else None)
        g.add(_elt(f"L{i}.combine", "add", dout, dtb, unsupported=uns),
              after=[len(g.ops) - 2, len(g.ops) - 1])
    # fix chain roots: first layer's base_mm has no predecessor op
    return OpGraph(g.ops, edges=[e for e in g.edges if e[0] >= 0])


def snn_vgg9(dtb: int = 2) -> OpGraph:
    """Spiking VGG9 at 1x1x32x32, 25 timesteps (93 fused ops).

    The op mix behind the paper's largest sequential gain (1.58x): ~50
    membrane-potential convs (grouped over timestep windows, MAC-friendly)
    interleaved with ~40 spiking accumulate/threshold/reset ops.  The
    spiking ops are *control-heavy* — comparisons, conditional resets,
    stateful membrane updates on the DSP/scalar path — the paper's
    KAN-spline affinity class, so they carry the gather-kind cost profile
    (CPU-favoured; order-of-magnitude NPU penalty)."""
    g = _G()
    T = 25
    groups = 5           # convs fuse over 5-timestep windows -> 5 per layer
    Tg = T // groups
    cfgs = [(1, 64, 32), (64, 64, 32), (64, 128, 16), (128, 128, 16),
            (128, 256, 8), (256, 256, 8), (256, 256, 8), (256, 512, 4),
            (512, 512, 4)]
    for i, (cin, cout, hw) in enumerate(cfgs):
        for w in range(groups):
            g.add(FusedOp(name=f"c{i}.w{w}", kind="conv2d",
                          in_shapes=((Tg, cin, hw, hw), (cout, cin, 3, 3)),
                          out_shape=(Tg, cout, hw, hw), dtype_bytes=dtb))
        # spiking neuron dynamics over the full window: the membrane
        # accumulation is a *temporal recurrence* across the 25 steps
        # (cumsum class — the paper's Mamba-scan affinity); threshold
        # compare + conditional reset are control-heavy (gather class);
        # spike trains are binary (1-byte)
        numel = T * cout * hw * hw
        for nm, kd, db in (("acc", "cumsum", 4), ("thresh", "gather", 1),
                           ("reset", "gather", 1), ("enc", "act", 1)):
            g.add(FusedOp(name=f"s{i}.{nm}", kind=kd,
                          in_shapes=((numel,),), out_shape=(numel,),
                          dtype_bytes=db))
    g.add(_mm("fc1", T, 512 * 4 * 4, 1024, dtb))
    g.add(FusedOp(name="fc1.spike", kind="gather",
                  in_shapes=((T * 1024,),), out_shape=(T * 1024,),
                  dtype_bytes=4))
    g.add(_mm("fc2", T, 1024, 10, dtb))
    g.add(_elt("readout", "add", T * 10, dtb))
    return g.graph()


def lavish(dtb: int = 2) -> OpGraph:
    """Audio-visual transformer (dual 224^2 + 128^2 towers -> fusion).
    The dual encoder is the fork the parallel scheduler exploits
    (Table 3: +9%)."""
    g = _G()
    root = g.add(_elt("input", "add", 3 * 224 * 224, dtb))
    # visual tower
    v = g.add(_conv("v.patch", 3, 768, 224, 16, dtb, stride=16), after=root)
    for i in range(2):
        g.add(_mm(f"v.L{i}.qkv", 196, 768, 3 * 768, dtb))
        g.add(FusedOp(name=f"v.L{i}.attn", kind="attention",
                      in_shapes=((1, 12, 196, 64), (1, 12, 196, 64)),
                      out_shape=(1, 12, 196, 64), dtype_bytes=dtb))
        g.add(_mm(f"v.L{i}.mlp", 196, 768, 3072, dtb))
    v_end = g.tail
    # audio tower (smaller)
    a = g.add(_conv("a.patch", 1, 768, 128, 16, dtb, stride=16), after=root)
    for i in range(2):
        g.add(_mm(f"a.L{i}.qkv", 64, 768, 3 * 768, dtb))
        g.add(_mm(f"a.L{i}.mlp", 64, 768, 3072, dtb))
    a_end = g.tail
    g.add(_mm("fusion", 260, 768, 768, dtb), after=[v_end, a_end])
    g.add(_mm("head", 1, 768, 309, dtb))
    return g.graph()


def pi05() -> OpGraph:
    """pi0.5 VLA pipeline: text embedder || INT8 vision encoder ->
    prefix-cache decoder -> 10 iterative denoising steps (~4,600 fused
    ops, single mixed-precision configuration).  The prefix/denoise
    stages exceed GPU memory -> unsupported_on GPU (paper Table 2 N/A)."""
    g = _G()
    root = g.add(_elt("inputs", "add", 1024, 2))
    no_gpu = ("GPU",)
    # text embedder (small CPU-ish ops)
    t = root
    for i in range(120):
        t = g.add(_mm(f"txt.{i}.mm", 64, 512, 512, 2), after=t)
        t = g.add(_elt(f"txt.{i}.act", "act", 64 * 512, 2), after=t)
    txt_end = t
    # vision encoder (INT8 conv/mm tower), parallel with text
    v = root
    for i in range(27):
        v = g.add(_conv(f"vis.{i}.conv", 64 if i else 3, 64, 56, 3, 1),
                  after=v)
        v = g.add(_elt(f"vis.{i}.act", "act", 64 * 56 * 56, 1), after=v)
        v = g.add(_mm(f"vis.{i}.mm", 196, 768, 768, 1), after=v)
    vis_end = v
    # prefix-cache decoder (GEMM-heavy, no GPU)
    p = g.add(_mm("prefix.in", 256, 2048, 2048, 2, unsupported=no_gpu),
              after=[txt_end, vis_end])
    for i in range(400):
        p = g.add(_mm(f"pre.{i}.mm", 256, 2048, 2048, 2,
                      unsupported=no_gpu), after=p)
        p = g.add(_elt(f"pre.{i}.act", "act", 256 * 2048, 2,
                       unsupported=no_gpu), after=p)
    # 10 denoising iterations, each with two parallel branches
    for it in range(10):
        fork = p
        b1 = fork
        for i in range(80):
            b1 = g.add(_mm(f"dn{it}.a{i}", 128, 1024, 1024, 2,
                           unsupported=no_gpu), after=b1)
            b1 = g.add(_elt(f"dn{it}.a{i}.act", "act", 128 * 1024, 2,
                            unsupported=no_gpu), after=b1)
        b2 = fork
        for i in range(80):
            b2 = g.add(_mm(f"dn{it}.b{i}", 128, 1024, 1024, 2,
                           unsupported=no_gpu), after=b2)
            b2 = g.add(_elt(f"dn{it}.b{i}.act", "act", 128 * 1024, 2,
                            unsupported=no_gpu), after=b2)
        p = g.add(_elt(f"dn{it}.join", "add", 128 * 1024, 2,
                       unsupported=no_gpu), after=[b1, b2])
    g.add(_mm("action_head", 1, 1024, 32, 2), after=p)
    return g.graph()


def vla_pipeline(dtb: int = 2, depth: int = 5) -> OpGraph:
    """Compact multi-stage VLA pipeline: vision encoder || language
    encoder -> fusion -> action head, as an explicit op DAG.

    The paper's intra-model-parallelism scenario at DAG-solver scale
    (``pi05`` is the same pipeline at full ~4,600-op profile scale; this
    builder keeps it under the frontier DP's 63-node bitmask so the
    antichain-frontier route can co-schedule the towers step by step).
    The towers are deliberately affinity-split — a conv tower (NPU-fast)
    against a GEMM/attention tower (GPU-fast) — so co-executing them on
    different PUs beats any serialized single-sequence route: paired
    advances cost ``max(w_v, w_l) * SF`` with the cross-PU SF factors
    well under 2x.
    """
    g = _G()
    root = g.add(_elt("inputs", "add", 3 * 224 * 224, dtb))
    # vision encoder: conv tower (NPU-affine)
    v = g.add(_conv("vis.patch", 3, 64, 224, 8, dtb, stride=4), after=root)
    for i in range(depth):
        v = g.add(_conv(f"vis.{i}.conv", 64, 64, 56, 3, dtb), after=v)
        v = g.add(_elt(f"vis.{i}.act", "act", 64 * 56 * 56, dtb), after=v)
    v_end = g.add(_mm("vis.proj", 196, 768, 768, dtb), after=v)
    # language encoder: GEMM/attention tower (GPU-affine), parallel
    t = g.add(_mm("lang.embed", 128, 768, 768, dtb), after=root)
    for i in range(depth):
        t = g.add(_mm(f"lang.{i}.qkv", 128, 768, 3 * 768, dtb), after=t)
        t = g.add(FusedOp(name=f"lang.{i}.attn", kind="attention",
                          in_shapes=((1, 12, 128, 64), (1, 12, 128, 64)),
                          out_shape=(1, 12, 128, 64), dtype_bytes=dtb),
                  after=t)
    t_end = g.add(_mm("lang.proj", 128, 768, 768, dtb), after=t)
    # fusion + action head (sequential epilogue)
    f = g.add(_mm("fusion", 324, 768, 768, dtb), after=[v_end, t_end])
    g.add(_mm("action.fc", 1, 768, 256, dtb), after=f)
    g.add(_mm("action_head", 1, 256, 32, dtb))
    return g.graph()


# ---------------------------------------------------------------------------
# registry: the paper's 19 model-precision configurations
# ---------------------------------------------------------------------------

def zoo() -> dict[str, OpGraph]:
    """All 19 configurations of Table 1/2 (9 models x FP16+INT8, + pi05)."""
    out: dict[str, OpGraph] = {}
    builders = {
        "ResNet-50": resnet50, "ViT-B/16": vit_b16, "LLaMA-7B(1L)": llama_1l,
        "BitNet": bitnet, "Mamba-370M": mamba_370m, "Hyena": hyena,
        "KAN": kan, "SNN-VGG9": snn_vgg9, "LAVISH": lavish,
    }
    for name, fn in builders.items():
        out[f"{name} FP16"] = fn(2)
        out[f"{name} INT8"] = fn(1)
    out["pi0.5"] = pi05()
    return out


ZOO_NAMES: Sequence[str] = tuple(
    [f"{m} {p}" for m in ("ResNet-50", "ViT-B/16", "LLaMA-7B(1L)", "BitNet",
                          "Mamba-370M", "Hyena", "KAN", "SNN-VGG9", "LAVISH")
     for p in ("FP16", "INT8")] + ["pi0.5"])
