"""Execution-graph builder (Algorithm 1, Stage 2).

Encodes the operator->PU mapping problem as a weighted directed graph:

* node ``v_{i,j}`` = execute fused op ``O_i`` on PU ``P_j``; weight =
  dispatch + kernel time of ``O_i`` on ``P_j`` (energy mode: ``w x p``).
* edge ``v_{i,j} -> v_{i+1,k}``: 0 if ``j == k``; otherwise the profiled
  PU-transition (H2D/D2H) cost.
* virtual ``s`` / ``t`` nodes carry the initial H2D and final D2H costs.

The graph is an explicit object (not just the DP recurrence) so that the
shortest-path reduction in the paper is directly visible and testable:
``search.dijkstra`` on this graph must equal ``search.sequential_dp``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

from .costmodel import CostTable, PUSpec, transition_cost
from .op import FusedOp, OpGraph

Objective = str  # "latency" | "energy"


def node_weight(entry, objective: Objective) -> float:
    if objective == "latency":
        return entry.w
    if objective == "energy":
        return entry.w * entry.power
    raise ValueError(f"unknown objective {objective!r}")


@dataclasses.dataclass
class ExecGraph:
    """Explicit weighted digraph over (op, PU) states, plus s/t."""

    # node ids: 0 = s, 1 = t, then 2 + i*K + j for (op i, pu j) among
    # *supported* pairs (unsupported pairs get no node — paper §3.1).
    n_ops: int
    pus: list[str]
    node_ids: dict[tuple[int, str], int]
    node_w: dict[int, float]
    adj: dict[int, list[tuple[int, float]]]  # u -> [(v, edge_weight)]
    S: int = 0
    T: int = 1

    def nodes(self) -> int:
        return 2 + len(self.node_ids)


def build_sequential_graph(
    chain: Sequence[int],
    ops: Sequence[FusedOp],
    table: CostTable,
    pus: Mapping[str, PUSpec],
    objective: Objective = "latency",
) -> ExecGraph:
    """Build the sequential execution graph for a chain of op indices.

    ``chain`` lists op indices (into ``ops``) forming a linear dependency
    chain O_1 -> ... -> O_N.
    """
    pu_names = list(table.pus)
    node_ids: dict[tuple[int, str], int] = {}
    node_w: dict[int, float] = {}
    adj: dict[int, list[tuple[int, float]]] = {0: [], 1: []}

    nid = 2
    for pos, oi in enumerate(chain):
        sup = table.supported_pus(oi)
        if not sup:
            raise ValueError(f"op {oi} ({ops[oi].name}) unsupported on all PUs")
        for p in sup:
            node_ids[(pos, p)] = nid
            e = table.require(oi, p)
            node_w[nid] = node_weight(e, objective)
            adj[nid] = []
            nid += 1

    def energy_scale(pu: str) -> float:
        # transition edges consume time on the interconnect/host; in energy
        # mode we charge them at the destination PU's memory-bound power.
        return pus[pu].power_memory if objective == "energy" else 1.0

    # s -> first op nodes: H2D cost of O_1 on P_j (zero for CPU/host).
    first = chain[0]
    for p in table.supported_pus(first):
        w = table.require(first, p).h2d * energy_scale(p)
        adj[0].append((node_ids[(0, p)], w))

    # consecutive ops, all PU pairs
    for pos in range(len(chain) - 1):
        oi, oj = chain[pos], chain[pos + 1]
        for pj in table.supported_pus(oi):
            u = node_ids[(pos, pj)]
            for pk in table.supported_pus(oj):
                v = node_ids[(pos + 1, pk)]
                tc = transition_cost(pus, table, oi, pj, oj, pk)
                adj[u].append((v, tc * energy_scale(pk)))

    # last op nodes -> t: D2H cost of O_N on P_j
    lastpos = len(chain) - 1
    last = chain[lastpos]
    for p in table.supported_pus(last):
        u = node_ids[(lastpos, p)]
        w = table.require(last, p).d2h * energy_scale(p)
        adj[u].append((1, w))

    return ExecGraph(n_ops=len(chain), pus=pu_names, node_ids=node_ids,
                     node_w=node_w, adj=adj)
