"""Execution-graph builder (Algorithm 1, Stage 2).

Encodes the operator->PU mapping problem as a weighted directed graph:

* node ``v_{i,j}`` = execute fused op ``O_i`` on PU ``P_j``; weight =
  dispatch + kernel time of ``O_i`` on ``P_j`` (energy mode: ``w x p``).
* edge ``v_{i,j} -> v_{i+1,k}``: 0 if ``j == k``; otherwise the profiled
  PU-transition (H2D/D2H) cost.
* virtual ``s`` / ``t`` nodes carry the initial H2D and final D2H costs.

The graph is an explicit object (not just the DP recurrence) so that the
shortest-path reduction in the paper is directly visible and testable:
``search.dijkstra`` on this graph must equal ``search.sequential_dp``.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Mapping, Sequence

import numpy as np

from .costmodel import CostTable, DenseCostTable, PUSpec, transition_cost
from .op import FusedOp, OpGraph

Objective = str  # "latency" | "energy"


def node_weight(entry, objective: Objective) -> float:
    if objective == "latency":
        return entry.w
    if objective == "energy":
        return entry.w * entry.power
    raise ValueError(f"unknown objective {objective!r}")


@dataclasses.dataclass
class ExecGraph:
    """Explicit weighted digraph over (op, PU) states, plus s/t."""

    # node ids: 0 = s, 1 = t, then 2 + i*K + j for (op i, pu j) among
    # *supported* pairs (unsupported pairs get no node — paper §3.1).
    n_ops: int
    pus: list[str]
    node_ids: dict[tuple[int, str], int]
    node_w: dict[int, float]
    adj: dict[int, list[tuple[int, float]]]  # u -> [(v, edge_weight)]
    S: int = 0
    T: int = 1

    def nodes(self) -> int:
        return 2 + len(self.node_ids)


def build_sequential_graph(
    chain: Sequence[int],
    ops: Sequence[FusedOp],
    table: CostTable,
    pus: Mapping[str, PUSpec],
    objective: Objective = "latency",
) -> ExecGraph:
    """Build the sequential execution graph for a chain of op indices.

    ``chain`` lists op indices (into ``ops``) forming a linear dependency
    chain O_1 -> ... -> O_N.
    """
    pu_names = list(table.pus)
    node_ids: dict[tuple[int, str], int] = {}
    node_w: dict[int, float] = {}
    adj: dict[int, list[tuple[int, float]]] = {0: [], 1: []}

    nid = 2
    for pos, oi in enumerate(chain):
        sup = table.supported_pus(oi)
        if not sup:
            raise ValueError(f"op {oi} ({ops[oi].name}) unsupported on all PUs")
        for p in sup:
            node_ids[(pos, p)] = nid
            e = table.require(oi, p)
            node_w[nid] = node_weight(e, objective)
            adj[nid] = []
            nid += 1

    def energy_scale(pu: str) -> float:
        # transition edges consume time on the interconnect/host; in energy
        # mode we charge them at the destination PU's memory-bound power.
        return pus[pu].power_memory if objective == "energy" else 1.0

    # s -> first op nodes: H2D cost of O_1 on P_j (zero for CPU/host).
    first = chain[0]
    for p in table.supported_pus(first):
        w = table.require(first, p).h2d * energy_scale(p)
        adj[0].append((node_ids[(0, p)], w))

    # consecutive ops, all PU pairs
    for pos in range(len(chain) - 1):
        oi, oj = chain[pos], chain[pos + 1]
        for pj in table.supported_pus(oi):
            u = node_ids[(pos, pj)]
            for pk in table.supported_pus(oj):
                v = node_ids[(pos + 1, pk)]
                tc = transition_cost(pus, table, oi, pj, oj, pk)
                adj[u].append((v, tc * energy_scale(pk)))

    # last op nodes -> t: D2H cost of O_N on P_j
    lastpos = len(chain) - 1
    last = chain[lastpos]
    for p in table.supported_pus(last):
        u = node_ids[(lastpos, p)]
        w = table.require(last, p).d2h * energy_scale(p)
        adj[u].append((1, w))

    return ExecGraph(n_ops=len(chain), pus=pu_names, node_ids=node_ids,
                     node_w=node_w, adj=adj)


# ---------------------------------------------------------------------------
# Dense (implicit) execution graph
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class DenseChain:
    """Array view of the sequential execution graph (no explicit nodes).

    Same semantics as ``build_sequential_graph`` — node weights, the
    s->first H2D edges, the last->t D2H edges, and the per-position
    ``(K, K)`` transition matrices — but held as NumPy arrays so the DP
    recurrence is one matrix op per chain position.  ``transition(pos)``
    returns ``T[k, j]`` = cost of moving from (op ``pos-1``, PU ``k``) to
    (op ``pos``, PU ``j``), energy-scaled exactly like the explicit graph's
    edges.
    """

    dense: DenseCostTable
    objective: Objective
    esc: np.ndarray        # (K,) transition energy scale (1.0 in latency mode)
    node_w: np.ndarray     # (N, K) node weights; inf where unsupported
    entry_w: np.ndarray    # (K,) s -> (op 0, PU j) edge weights
    exit_w: np.ndarray     # (K,) (op N-1, PU j) -> t edge weights
    _trans: np.ndarray | None = None

    def transitions(self) -> np.ndarray:
        """All ``(N-1, K, K)`` transition matrices, built in one batched op.

        ``transitions()[p][k][j]`` = cost of moving from (op ``p``, PU
        ``k``) to (op ``p+1``, PU ``j``): same PU -> 0; otherwise the
        accelerator-gated H2D of the next op plus D2H of the previous op,
        energy-scaled by the destination PU exactly like the explicit
        graph's edges.
        """
        if self._trans is None:
            d = self.dense
            h2d_next = np.where(d.acc, d.h2d, 0.0)[1:]       # (N-1, K)
            d2h_prev = np.where(d.acc, d.d2h, 0.0)[:-1]      # (N-1, K)
            t = ((h2d_next[:, None, :] + d2h_prev[:, :, None])
                 * self.esc[None, None, :])
            k = d.k
            t[:, np.arange(k), np.arange(k)] = 0.0
            self._trans = t
        return self._trans

    def transition(self, pos: int) -> np.ndarray:
        """(K, K) transition-cost matrix into chain position ``pos``."""
        return self.transitions()[pos - 1]


def build_dense_chain(
    chain: Sequence[int],
    ops: Sequence[FusedOp],
    table: CostTable,
    pus: Mapping[str, PUSpec],
    objective: Objective = "latency",
    dense: DenseCostTable | None = None,
) -> DenseChain:
    """Dense equivalent of ``build_sequential_graph``."""
    d = dense if dense is not None else DenseCostTable.from_chain(chain, table, pus)
    for pos, oi in enumerate(chain):
        if not d.mask[pos].any():
            raise ValueError(f"op {oi} ({ops[oi].name}) unsupported on all PUs")
    if objective == "latency":
        esc = np.ones(d.k)
        node_w = d.w
    elif objective == "energy":
        esc = np.array([pus[p].power_memory for p in d.pus])
        node_w = d.energy
    else:
        raise ValueError(f"unknown objective {objective!r}")
    # boundary edges are NOT accelerator-gated (matches the explicit graph)
    entry_w = d.h2d[0] * esc
    exit_w = d.d2h[-1] * esc
    return DenseChain(dense=d, objective=objective, esc=esc, node_w=node_w,
                      entry_w=entry_w, exit_w=exit_w)
