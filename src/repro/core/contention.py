"""Cross-PU contention models (paper §3.2.2, "Memory contention modeling").

Two empirically grounded models:

* **Intra-model parallel** — when branches co-execute on different PUs, each
  operator's cost is scaled by a measured slowdown factor
  ``SF(P_run, P_interfere)``.  The paper's measurements: the NPU is most
  sensitive (1.17x with CPU active, 1.09x with GPU active); CPU and GPU show
  negligible interference.

* **Multi-model concurrent** — co-scheduled operators from different models
  on the *same* PU are profiled under barrier-synchronised simultaneous
  execution.  The default derived model serialises same-PU co-execution
  (each op's measured concurrent latency ~= sum of solo latencies, which is
  what time-sharing a single command queue yields) and applies a
  memory-bandwidth contention factor across PUs.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Mapping, Sequence

import numpy as np

from .costmodel import DEFAULT_SF, DenseCostTable

# Multi-model cross-PU memory-bandwidth contention (two active PUs hammering
# the shared DRAM).  Slightly stronger than the intra-model SF because whole
# models (not single branches) co-execute.
DEFAULT_MM_SF: dict[tuple[str, str], float] = {
    ("NPU", "CPU"): 1.22, ("NPU", "GPU"): 1.15,
    ("CPU", "NPU"): 1.04, ("CPU", "GPU"): 1.08,
    ("GPU", "NPU"): 1.04, ("GPU", "CPU"): 1.08,
    ("CPU", "CPU"): 1.0, ("GPU", "GPU"): 1.0, ("NPU", "NPU"): 1.0,
}


@dataclasses.dataclass
class ContentionModel:
    """SF tables + derived co-execution costs."""

    sf: Mapping[tuple[str, str], float] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_SF))
    mm_sf: Mapping[tuple[str, str], float] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_MM_SF))

    def slowdown(self, run: str, interfere: str) -> float:
        return self.sf.get((run, interfere), 1.0)

    def branch_factor(self, run_pu: str, other_pus: set[str]) -> float:
        """Paper §3.3.2: max over PUs used by other concurrent branches."""
        if not other_pus:
            return 1.0
        return max(self.slowdown(run_pu, p) for p in other_pus)

    # -- multi-model co-execution -------------------------------------------
    def co_exec(self, t_a: float, pu_a: str, t_b: float, pu_b: str
                ) -> tuple[float, float]:
        """Concurrent latencies of two ops from different models.

        Same PU: the command queue serialises them -> each op's measured
        wall-clock concurrent latency is the pair's makespan.  Different
        PUs: each solo latency inflated by memory-bandwidth contention.
        """
        if pu_a == pu_b:
            s = t_a + t_b
            return s, s
        return (t_a * self.mm_sf.get((pu_a, pu_b), 1.0),
                t_b * self.mm_sf.get((pu_b, pu_a), 1.0))

    def pair_step_cost(self, t_a: float, pu_a: str, t_b: float, pu_b: str) -> float:
        """Aligned-mode step cost (paper §3.2.2): same-PU uses the average of
        measured concurrent times; cross-PU uses the max of (contention-
        adjusted) solo times."""
        cc_a, cc_b = self.co_exec(t_a, pu_a, t_b, pu_b)
        if pu_a == pu_b:
            return 0.5 * (cc_a + cc_b)
        return max(cc_a, cc_b)

    # -- M-ary co-execution (generalizes the pair laws above) ---------------
    def _group_factors(self, pus_: Sequence[str]) -> dict[str, float]:
        """Per-active-PU bandwidth-contention factor: max SF against the
        *other* distinct PUs active in the step (1.0 when alone)."""
        active = set(pus_)
        return {q: max((self.mm_sf.get((q, p), 1.0)
                        for p in active if p != q), default=1.0)
                for q in active}

    def group_step_cost(self, ts: Sequence[float],
                        pus_: Sequence[str]) -> float:
        """Makespan of M co-scheduled ops (one per request).

        Ops sharing a PU serialise on its command queue (queue time = sum
        of solo times); each queue is inflated by the memory-bandwidth
        contention factor against the other active PUs; the step cost is
        the slowest queue.  For M = 2 this reduces exactly to
        ``pair_step_cost``: same-PU -> ``t_a + t_b``, cross-PU ->
        ``max(t_a*SF(a,b), t_b*SF(b,a))``.
        """
        f = self._group_factors(pus_)
        cost = 0.0
        for q, fq in f.items():
            tq = sum(t for t, p in zip(ts, pus_) if p == q)
            cost = max(cost, tq * fq)
        return cost

    def group_energy(self, ts: Sequence[float], powers: Sequence[float],
                     pus_: Sequence[str]) -> float:
        """Energy of M co-scheduled ops: each op runs for its concurrent
        duration at its PU's power.  Time-shared same-PU execution draws
        the PU's power once, so each op is charged its solo share scaled
        only by the cross-PU contention factor — for M = 2 this is the
        pair energy law bit-for-bit (same-PU ``t_a*p_a + t_b*p_b``,
        cross-PU ``cc_a*p_a + cc_b*p_b``)."""
        f = self._group_factors(pus_)
        return sum(t * f[p] * pw for t, p, pw in zip(ts, pus_, powers))

    # -- batched M-ary laws (one fixed PU combo, many op tuples) ------------
    def group_step_cost_batch(self, ts: np.ndarray,
                              pus_: Sequence[str]) -> np.ndarray:
        """Vectorized :meth:`group_step_cost`: ``ts`` is ``(..., M)`` solo
        times of M co-scheduled ops and ``pus_`` their (single, shared
        across the batch) PU assignment.  Returns the ``(...,)`` makespans,
        bit-for-bit equal to the scalar law applied per tuple: per-PU
        queue sums accumulate in op-position order and the per-queue
        factor/max algebra is order-exact."""
        f = self._group_factors(pus_)
        cost: np.ndarray | None = None
        for q in dict.fromkeys(pus_):           # distinct PUs, first-seen order
            tq: np.ndarray | None = None
            for i, p in enumerate(pus_):
                if p == q:
                    tq = ts[..., i] if tq is None else tq + ts[..., i]
            vq = tq * f[q]
            cost = vq if cost is None else np.maximum(cost, vq)
        return cost

    def group_energy_batch(self, ts: np.ndarray, powers: np.ndarray,
                           pus_: Sequence[str]) -> np.ndarray:
        """Vectorized :meth:`group_energy` over ``(..., M)`` solo times and
        powers for one fixed PU combo — same term grouping and summation
        order as the scalar law, so results match element-for-element."""
        f = self._group_factors(pus_)
        out: np.ndarray | None = None
        for i, p in enumerate(pus_):
            term = (ts[..., i] * f[p]) * powers[..., i]
            out = term if out is None else out + term
        return out

    def min_factor(self) -> float:
        """Smallest factor any co-executed op's solo time can be scaled by.

        Used to keep the A* lower-bound heuristic admissible even for
        custom ``mm_sf`` tables with entries < 1 (same-PU co-execution
        always costs at least each op's solo time, cross-PU costs at
        least ``solo * mm_sf``)."""
        return min(1.0, *self.mm_sf.values()) if self.mm_sf else 1.0


def uses_default_coexec(cm: ContentionModel) -> bool:
    """True iff ``cm`` inherits the base co-execution cost laws, so the
    vectorized pair-cost matrices below reproduce its behaviour exactly.
    Subclasses overriding ``co_exec``/``pair_step_cost`` fall back to the
    scalar reference solvers."""
    return (type(cm).co_exec is ContentionModel.co_exec
            and type(cm).pair_step_cost is ContentionModel.pair_step_cost)


def uses_default_group(cm: ContentionModel) -> bool:
    """True iff ``cm`` inherits the base M-ary group laws AND the pair
    laws they generalize.  The M-dimensional grid search prices group
    advances with ``group_step_cost``/``group_energy`` (the vectorized
    sweep through their ``*_batch`` forms); a model that overrides any of
    the family would be priced inconsistently, so such models route to
    the pairwise-merge fallback (which honours custom pair laws through
    the reference solvers)."""
    return (uses_default_coexec(cm)
            and type(cm).group_step_cost is ContentionModel.group_step_cost
            and type(cm).group_energy is ContentionModel.group_energy
            and type(cm).group_step_cost_batch
            is ContentionModel.group_step_cost_batch
            and type(cm).group_energy_batch
            is ContentionModel.group_energy_batch
            and type(cm)._group_factors is ContentionModel._group_factors)


class GroupCostCache:
    """Batched group-edge tables per *signature tuple* for one ordered
    subset of >= 2 co-advancing requests — the M-ary generalization of
    :class:`PairCostCache`.

    A group co-advance's cost/energy over all PU combos depends only on
    the advancing ops' per-PU (w, power, support) signatures
    (``DenseCostTable.sig``), so one batched reduction per signature
    tuple serves every grid state that advances this subset.  For each of
    the ``prod(n_sig_r)`` signature tuples the cache stores the best PU
    combo under BOTH objectives (one enumeration pass, memoized — a
    shared cache serves a latency solve and an energy solve of the same
    workload tuple, like ``PairCostCache.edge_tables``).

    Semantics replicate the scalar per-state enumeration of the heap grid
    A* bit-for-bit: PU combos are scanned in the same row-major
    (``itertools.product``) order with strict first-minimum updates, the
    costs come from :meth:`ContentionModel.group_step_cost_batch` /
    :meth:`~ContentionModel.group_energy_batch` (order-exact vectorized
    forms of the scalar laws), and unsupported slots are ``inf`` in both
    keys so they can never win the argmin.
    """

    def __init__(self, cm: ContentionModel, denses: Sequence[DenseCostTable]):
        if len(denses) < 2:
            raise ValueError(
                f"GroupCostCache is for group advances of >= 2 requests, "
                f"got {len(denses)}; singleton advances price from the "
                "dense solo-edge arrays")
        self.cm = cm
        self.denses = list(denses)
        self.ks = [d.k for d in self.denses]
        self.shape = tuple(d.n_sig for d in self.denses)
        self._memo: dict[str, tuple[np.ndarray, np.ndarray, np.ndarray,
                                    np.ndarray]] = {}

    def nbytes(self) -> int:
        """Bytes held by the built edge tables (0 until ``edge_tables``
        first runs — ``ConcurrentCaches.trim`` budgets on this)."""
        return sum(a.nbytes for arrs in self._memo.values() for a in arrs)

    def edge_tables(self, objective: str
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray,
                               np.ndarray]:
        """``(key, step_cost, energy, flat PU-combo argmin)`` per signature
        tuple, each of shape ``(n_sig_1, ..., n_sig_g)``.  The argmin is
        row-major over ``(K_1, ..., K_g)`` (decode with divmod), matching
        the scalar enumeration's first-minimum tie-break."""
        if objective not in self._memo:
            self._build()
        return self._memo[objective]

    # tuples per build chunk: each chunk gathers g per-request (C, K)
    # w/power/mask blocks once and then serves every PU combo from cheap
    # column views, bounding the gather scratch to a few tens of MB even
    # at the rolling route's signature-alphabet cap
    _CHUNK_TUPLES = 262_144

    def _build(self) -> None:
        g = len(self.denses)
        rows = [d.sig_row for d in self.denses]
        tsig = [d.w[r] for d, r in zip(self.denses, rows)]       # (S_r, K_r)
        psig = [d.power[r] for d, r in zip(self.denses, rows)]
        msig = [d.mask[r] for d, r in zip(self.denses, rows)]
        grid = np.indices(self.shape).reshape(g, -1)             # (g, n_tup)
        n_tup = grid.shape[1]
        pu_lists = [d.pus for d in self.denses]
        combos = list(itertools.product(*[range(k) for k in self.ks]))
        out = {obj: (np.full(n_tup, np.inf), np.empty(n_tup),
                     np.empty(n_tup), np.zeros(n_tup, dtype=np.int64))
               for obj in ("latency", "energy")}
        for lo in range(0, n_tup, self._CHUNK_TUPLES):
            hi = min(lo + self._CHUNK_TUPLES, n_tup)
            # one gather per (request, kind) per chunk — combo-independent
            gat = [(tsig[i][grid[i, lo:hi]], psig[i][grid[i, lo:hi]],
                    msig[i][grid[i, lo:hi]]) for i in range(g)]
            ts = np.empty((hi - lo, g))
            pws = np.empty((hi - lo, g))
            for ci, combo in enumerate(combos):
                pnames = [pu_lists[i][j] for i, j in enumerate(combo)]
                valid: np.ndarray | None = None
                for i, j in enumerate(combo):
                    ts[:, i] = gat[i][0][:, j]
                    pws[:, i] = gat[i][1][:, j]
                    vi = gat[i][2][:, j]
                    valid = vi if valid is None else valid & vi
                with np.errstate(invalid="ignore"):  # inf*0 at unsupported
                    cost = self.cm.group_step_cost_batch(ts, pnames)
                    eng = self.cm.group_energy_batch(ts, pws, pnames)
                cost = np.where(valid, cost, np.inf)
                eng = np.where(valid, eng, np.inf)
                for obj, key in (("latency", cost), ("energy", eng)):
                    pk, ps, pe, pa = out[obj]
                    pkc = pk[lo:hi]
                    imp = key < pkc
                    if imp.any():
                        pkc[imp] = key[imp]
                        ps[lo:hi][imp] = cost[imp]
                        pe[lo:hi][imp] = eng[imp]
                        pa[lo:hi][imp] = ci
        self._memo.update(
            {obj: tuple(a.reshape(self.shape) for a in arrs)
             for obj, arrs in out.items()})


class PairCostCache:
    """Batched ``(K0, K1)`` pair-cost / pair-energy matrices per signature.

    For two co-scheduled ops (one per model) the step cost and energy over
    all PU pairs depend only on the ops' per-PU (w, power, support)
    vectors — their *signatures* (``DenseCostTable.sig``).  The model zoo
    repeats layer shapes heavily, so reducing once per signature pair
    turns the per-state K0*K1 Python loop of the reference solvers into a
    single batched NumPy evaluation shared across thousands of (i, j)
    states.

    Matrix semantics replicate ``ContentionModel`` bit-for-bit:

    * cost:   same PU -> ``t0 + t1`` (serialised queue); cross-PU ->
      ``max(t0*SF(a,b), t1*SF(b,a))``.
    * energy: same PU -> ``t0*p0 + t1*p1``; cross-PU ->
      ``cc0*p0 + cc1*p1``.

    Unsupported slots are ``inf`` in both, so flat ``argmin`` picks the
    same first-minimum the scalar ``for d0 ... for d1`` loops pick.
    """

    # peak elements per 4-D temporary in edge_tables (~16 MB of float64):
    # measured/profiled tables can have near-unique per-op signatures, so
    # the (S0, S1, K0, K1) block is built in row chunks to bound memory.
    _CHUNK_ELEMS = 2_000_000

    def __init__(self, cm: ContentionModel, dense0: DenseCostTable,
                 dense1: DenseCostTable):
        self.cm = cm
        self.d0 = dense0
        self.d1 = dense1
        p0, p1 = dense0.pus, dense1.pus
        self.sf_a = np.array([[cm.mm_sf.get((a, b), 1.0) for b in p1]
                              for a in p0])
        self.sf_b = np.array([[cm.mm_sf.get((b, a), 1.0) for b in p1]
                              for a in p0])
        self.same = np.array([[a == b for b in p1] for a in p0])
        self._memo: dict[str, tuple[np.ndarray, np.ndarray, np.ndarray,
                                    np.ndarray]] = {}

    def nbytes(self) -> int:
        """Bytes held by the built signature-pair matrices (0 until
        ``edge_tables`` first runs — ``ConcurrentCaches.trim`` budgets
        on this)."""
        return sum(a.nbytes for arrs in self._memo.values() for a in arrs)

    def edge_tables(self, objective: str
                    ) -> tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray]:
        """Co-advance edges for *all* signature pairs, reduced in batches.

        Every PU pair of a co-advance leads to the same successor state,
        so the search only needs the minimum-key pair per signature pair;
        its latency / energy / identity are kept for reconstruction.
        Returns ``(key, step_cost, energy, flat_argmin)``, each
        ``(n_sig0, n_sig1)``.  The flat row-major argmin reproduces the
        scalar solvers' first-minimum ``for d0 ... for d1`` tie-break.

        The 4-D cost/energy reductions are objective-independent, so the
        first call builds **both** objectives' tables in one chunked pass
        and memoizes them — a shared cache threaded through a pair's
        latency- and energy-objective solves pays the 4-D setup once.
        """
        if objective not in self._memo:
            self._build()
        return self._memo[objective]

    def _build(self) -> None:
        r0, r1 = self.d0.sig_row, self.d1.sig_row
        t0s, p0s, m0s = self.d0.w[r0], self.d0.power[r0], self.d0.mask[r0]
        t1, p1, m1 = self.d1.w[r1], self.d1.power[r1], self.d1.mask[r1]
        s0, s1 = len(r0), len(r1)
        k0, k1 = t0s.shape[1], t1.shape[1]
        out = {obj: tuple(np.empty((s0, s1)) for _ in range(3))
               + (np.empty((s0, s1), dtype=np.int64),)
               for obj in ("latency", "energy")}
        a1 = t1[None, :, None, :]        # (1, S1, 1, K1)
        with np.errstate(invalid="ignore"):  # inf * 0 at unsupported slots
            e1 = a1 * p1[None, :, None, :]
        bad1 = ~m1[None, :, None, :]
        same = self.same[None, None, :, :]
        chunk = max(1, self._CHUNK_ELEMS // max(1, s1 * k0 * k1))
        for lo in range(0, s0, chunk):
            hi = min(lo + chunk, s0)
            a0 = t0s[lo:hi, None, :, None]       # (C, 1, K0, 1)
            with np.errstate(invalid="ignore"):  # inf * 0 at unsupported
                cc0 = a0 * self.sf_a[None, None, :, :]
                cc1 = a1 * self.sf_b[None, None, :, :]
                cost = np.maximum(cc0, cc1)
                energy = (cc0 * p0s[lo:hi, None, :, None]
                          + cc1 * p1[None, :, None, :])
                cost = np.where(same, a0 + a1, cost)
                energy = np.where(
                    same, a0 * p0s[lo:hi, None, :, None] + e1, energy)
            bad = ~m0s[lo:hi, None, :, None] | bad1
            cost[bad] = np.inf
            energy[bad] = np.inf
            cost = cost.reshape(hi - lo, s1, k0 * k1)
            energy = energy.reshape(hi - lo, s1, k0 * k1)
            for obj in ("latency", "energy"):
                key = cost if obj == "latency" else energy
                pk, ps, pe, pa = out[obj]
                arg = key.argmin(axis=2)
                sel = arg[:, :, None]
                pa[lo:hi] = arg
                pk[lo:hi] = np.take_along_axis(key, sel, axis=2)[:, :, 0]
                ps[lo:hi] = np.take_along_axis(cost, sel, axis=2)[:, :, 0]
                pe[lo:hi] = np.take_along_axis(energy, sel, axis=2)[:, :, 0]
        self._memo.update(out)
