"""Cross-PU contention models (paper §3.2.2, "Memory contention modeling").

Two empirically grounded models:

* **Intra-model parallel** — when branches co-execute on different PUs, each
  operator's cost is scaled by a measured slowdown factor
  ``SF(P_run, P_interfere)``.  The paper's measurements: the NPU is most
  sensitive (1.17x with CPU active, 1.09x with GPU active); CPU and GPU show
  negligible interference.

* **Multi-model concurrent** — co-scheduled operators from different models
  on the *same* PU are profiled under barrier-synchronised simultaneous
  execution.  The default derived model serialises same-PU co-execution
  (each op's measured concurrent latency ~= sum of solo latencies, which is
  what time-sharing a single command queue yields) and applies a
  memory-bandwidth contention factor across PUs.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping

from .costmodel import DEFAULT_SF

# Multi-model cross-PU memory-bandwidth contention (two active PUs hammering
# the shared DRAM).  Slightly stronger than the intra-model SF because whole
# models (not single branches) co-execute.
DEFAULT_MM_SF: dict[tuple[str, str], float] = {
    ("NPU", "CPU"): 1.22, ("NPU", "GPU"): 1.15,
    ("CPU", "NPU"): 1.04, ("CPU", "GPU"): 1.08,
    ("GPU", "NPU"): 1.04, ("GPU", "CPU"): 1.08,
    ("CPU", "CPU"): 1.0, ("GPU", "GPU"): 1.0, ("NPU", "NPU"): 1.0,
}


@dataclasses.dataclass
class ContentionModel:
    """SF tables + derived co-execution costs."""

    sf: Mapping[tuple[str, str], float] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_SF))
    mm_sf: Mapping[tuple[str, str], float] = dataclasses.field(
        default_factory=lambda: dict(DEFAULT_MM_SF))

    def slowdown(self, run: str, interfere: str) -> float:
        return self.sf.get((run, interfere), 1.0)

    def branch_factor(self, run_pu: str, other_pus: set[str]) -> float:
        """Paper §3.3.2: max over PUs used by other concurrent branches."""
        if not other_pus:
            return 1.0
        return max(self.slowdown(run_pu, p) for p in other_pus)

    # -- multi-model co-execution -------------------------------------------
    def co_exec(self, t_a: float, pu_a: str, t_b: float, pu_b: str
                ) -> tuple[float, float]:
        """Concurrent latencies of two ops from different models.

        Same PU: the command queue serialises them -> each op's measured
        wall-clock concurrent latency is the pair's makespan.  Different
        PUs: each solo latency inflated by memory-bandwidth contention.
        """
        if pu_a == pu_b:
            s = t_a + t_b
            return s, s
        return (t_a * self.mm_sf.get((pu_a, pu_b), 1.0),
                t_b * self.mm_sf.get((pu_b, pu_a), 1.0))

    def pair_step_cost(self, t_a: float, pu_a: str, t_b: float, pu_b: str) -> float:
        """Aligned-mode step cost (paper §3.2.2): same-PU uses the average of
        measured concurrent times; cross-PU uses the max of (contention-
        adjusted) solo times."""
        cc_a, cc_b = self.co_exec(t_a, pu_a, t_b, pu_b)
        if pu_a == pu_b:
            return 0.5 * (cc_a + cc_b)
        return max(cc_a, cc_b)
