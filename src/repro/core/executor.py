"""Execution orchestrator: applies a static schedule and really runs it.

The paper's output schedule is "applied directly by the execution
orchestrator" with zero runtime overhead.  This executor models each PU as
an execution *lane* (a worker thread with a FIFO command queue — the
command-queue semantics of a real PU).  Two execution paths share the lane
model:

* the **per-op interpreter** (``run_scheduled`` / ``run_concurrent``):
  ops are enqueued onto their assigned lane in dependency order and
  cross-lane dependencies synchronise via one event per op.  This is the
  bitwise-equivalence oracle — for every model in the zoo, orchestrated
  execution must produce outputs identical to monolithic single-lane
  execution (``run_monolithic``);

* the **compiled path** (``compile_scheduled`` / ``compile_concurrent``
  → :class:`~repro.core.laneprogram.LaneProgram`): each lane's queue is
  partitioned into maximal contiguous same-lane segments, each segment's
  payloads fuse into one callable (jitted when bitwise-safe), and events
  exist only at the cross-lane boundary cuts.  Same results, a fraction
  of the dispatch/synchronisation overhead — see ``laneprogram``.

Both paths run under the fault runtime of :mod:`repro.core.faults`: every
cross-lane wait is bounded by the watchdog budget, a failure on one lane
releases every event so sibling lanes unwind instead of parking on a dead
producer, transient (``RecoverableError``) payload failures retry with
backoff, and a permanent PU loss surfaces as
:class:`~repro.core.errors.PULostError` carrying the execution frontier
(``partial``) so the orchestrator can re-plan and resume.  Lane workers
are daemon threads: even a payload the watchdog cannot interrupt (a
genuine native hang) cannot block interpreter shutdown.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Mapping, Sequence

import numpy as np

from .errors import InfeasibleScheduleError, PULostError
from .faults import (_JOIN_GRACE, ExecutionPolicy, FaultPlan, RunContext,
                     _Aborted, run_with_retries)
from .laneprogram import LaneProgram, compile_lane_program
from .op import OpGraph


class ScheduleExecutor:
    """Runs an OpGraph whose ops carry ``fn`` payloads under an assignment.

    ``targets`` optionally binds lane names to registered
    :class:`~repro.core.targets.Target`\\ s (see
    :mod:`repro.core.backends`): the **compiled** path then selects and
    device-places each lane's payload variants per its bound target at
    compile time.  The per-op interpreter deliberately ignores the
    binding — it always executes ``op.fn`` and remains the
    single-variant bitwise oracle.
    """

    def __init__(self, pus: Sequence[str], targets=None):
        from .targets import resolve_targets
        self.pus = list(pus)
        self.targets = resolve_targets(targets)
        if self.targets:
            unknown = sorted(set(self.targets) - set(self.pus))
            if unknown:
                raise ValueError(
                    f"target binding names lane(s) {unknown} not in the "
                    f"executor's PU set {self.pus}")

    def run_monolithic(self, graph: OpGraph,
                       external_inputs: Mapping[int, tuple] | None = None) -> dict[int, Any]:
        """Reference: run everything on one lane in topological order."""
        ext = dict(external_inputs or {})
        results: dict[int, Any] = {}
        for i in graph.topo_order():
            op = graph.ops[i]
            if op.fn is None:
                results[i] = None
            else:
                e = ext.get(i, ())
                dep_vals = tuple(results[p] for p in graph.pred[i])
                results[i] = op.fn(*(tuple(e) + dep_vals))
        return results

    # ------------------------------------------------------------------
    # assignment / schedule normalization (shared by both paths)
    # ------------------------------------------------------------------
    def _normalize_assignment(self, graph: OpGraph, assignment,
                              completed: Mapping[int, Any] | None = None
                              ) -> dict[int, str]:
        """``{op index: PU name}`` from a mapping or any schedule object
        exposing one (``SeqSchedule`` — via its chain — or
        ``ParallelSchedule.assignment``), with coverage validation.
        Ops already present in ``completed`` (a resume frontier) need no
        assignment."""
        if hasattr(assignment, "chain") and hasattr(assignment, "assignment"):
            assignment = dict(zip(assignment.chain, assignment.assignment))
        elif hasattr(assignment, "assignment"):
            assignment = assignment.assignment
        have = set(assignment) | set(completed or ())
        missing = [i for i in range(len(graph.ops)) if i not in have]
        if missing:
            raise ValueError(
                f"assignment does not cover the graph: {len(missing)} op(s) "
                f"unassigned (e.g. {missing[:5]}) — partial (tail/admission) "
                "plans cannot be executed on the full graph")
        return dict(assignment)

    def _scheduled_lane_queues(self, graph: OpGraph,
                               assignment: Mapping[int, str],
                               completed: Mapping[int, Any] | None = None
                               ) -> dict[str, list[int]]:
        """One FIFO lane per PU; ops enqueue in topological order.
        Completed (frontier) ops are not re-enqueued."""
        lane_queues: dict[str, list[int]] = {p: [] for p in self.pus}
        done = completed or ()
        for i in graph.topo_order():
            if i in done:
                continue
            lane_queues[assignment[i]].append(i)
        return lane_queues

    def _concurrent_lane_queues(self, graphs: Sequence[OpGraph], schedule,
                                completed: Sequence[Mapping[int, Any]] | None
                                = None, partial: bool = False
                                ) -> tuple[dict[str, list[tuple[int, int]]],
                                           set[tuple[int, int]]]:
        """Lane queues in schedule-step order + the co-scheduled op set.

        Validates coverage AND dependency order (a mis-ordered schedule
        would otherwise deadlock the lane workers instead of raising).
        Ops of a step where >= 2 requests advance together are returned
        as *barrier* ops: the compiled path keeps them individually
        dispatched so the co-execution granularity the contention laws
        priced is preserved.  ``completed`` (a resume frontier) seeds the
        per-request done sets: frontier ops need no schedule step and
        satisfy dependency/coverage checks.  ``partial=True`` skips the
        final full-coverage check — a *window* of a longer plan (the
        real-execution serving loop runs plans chunk by chunk) is a valid
        unit of execution as long as precedence holds; dependency
        validation is never skipped.
        """
        m = len(graphs)
        if schedule.n_requests != m:
            raise ValueError(
                f"schedule covers {schedule.n_requests} requests, "
                f"got {m} graphs")
        lane_queues: dict[str, list[tuple[int, int]]] = {p: [] for p in self.pus}
        barriers: set[tuple[int, int]] = set()
        seen: list[set[int]] = [set(completed[r]) if completed else set()
                                for r in range(m)]
        for st in schedule.steps:
            active = [(r, oi, pu) for r, (oi, pu)
                      in enumerate(zip(st.ops, st.pus)) if oi is not None]
            for r, oi, pu in active:
                if completed and oi in seen[r] and oi in completed[r]:
                    continue  # frontier op re-listed by a stale schedule
                missing_pred = [p for p in graphs[r].pred[oi]
                                if p not in seen[r]]
                if missing_pred:
                    raise ValueError(
                        f"schedule lists op {oi} of request {r} before its "
                        f"predecessor(s) {missing_pred} — executing it "
                        "would deadlock the lanes")
                lane_queues[pu].append((r, oi))
                seen[r].add(oi)
                if len(active) > 1:
                    barriers.add((r, oi))
        if not partial:
            for r, g in enumerate(graphs):
                if seen[r] != set(range(len(g.ops))):
                    missing = sorted(set(range(len(g.ops))) - seen[r])
                    raise ValueError(
                        f"schedule does not cover request {r}: missing ops "
                        f"{missing[:5]}{'...' if len(missing) > 5 else ''}")
        return lane_queues, barriers

    def _dag_lane_queues(self, graph: OpGraph, schedule,
                         completed: Mapping[int, Any] | None = None
                         ) -> dict[str, list[tuple[int, int]]]:
        """Lane queues in DAG-schedule step order.

        Ops enqueue onto their assigned lane in the order the
        ``DagSchedule`` lists them; synchronization at runtime comes from
        the graph's *true dependency edges* only (per-op events in the
        interpreter, segment cuts in the compiled path) — no step
        barriers, so independent subgraphs on different lanes overlap
        (the paper's intra-model-parallelism win).  Coverage and
        precedence are validated here: a step op whose predecessors have
        not all been listed earlier (same step counts, in listed order)
        raises :class:`InfeasibleScheduleError` naming the node and its
        unmet predecessors instead of deadlocking the lane workers.
        """
        lane_queues: dict[str, list[tuple[int, int]]] = {
            p: [] for p in self.pus}
        seen: set[int] = set(completed or ())

        def _nm(i: int) -> str:
            return f"op {i} ({graph.ops[i].name})"

        for st in schedule.steps:
            for oi, pu in zip(st.ops, st.pus):
                if completed and oi in seen and oi in completed:
                    continue  # frontier op re-listed by a stale schedule
                unmet = [p for p in graph.pred[oi] if p not in seen]
                if unmet:
                    raise InfeasibleScheduleError(
                        f"DAG schedule lists node {_nm(oi)} before its "
                        f"unmet predecessor(s) "
                        f"{[_nm(p) for p in unmet]} — executing it would "
                        "deadlock the lanes")
                if pu not in lane_queues:
                    raise ValueError(
                        f"DAG schedule assigns {_nm(oi)} to unknown lane "
                        f"{pu!r} (executor lanes: {self.pus})")
                lane_queues[pu].append((0, oi))
                seen.add(oi)
        if seen != set(range(len(graph.ops))):
            missing = sorted(set(range(len(graph.ops))) - seen)
            raise ValueError(
                f"DAG schedule does not cover the graph: missing ops "
                f"{missing[:5]}{'...' if len(missing) > 5 else ''}")
        return lane_queues

    # ------------------------------------------------------------------
    # per-op interpreter (the bitwise-equivalence oracle)
    # ------------------------------------------------------------------
    def run_scheduled(self, graph: OpGraph, assignment,
                      external_inputs: Mapping[int, tuple] | None = None, *,
                      policy: ExecutionPolicy | None = None,
                      faults: FaultPlan | None = None,
                      completed: Mapping[int, Any] | None = None,
                      estimate: float | None = None) -> dict[int, Any]:
        """Run under the schedule: one worker lane per PU, event-synced.

        ``assignment`` is an ``{op index: PU name}`` mapping, or any
        schedule object exposing one (``SeqSchedule`` — via its chain —
        or ``ParallelSchedule.assignment``), so orchestrator plans can be
        executed without hand-building the mapping.

        ``policy`` tunes the watchdog/retry runtime (see
        :class:`~repro.core.faults.ExecutionPolicy`; ``estimate`` — e.g.
        the plan's cost-model latency — scales the watchdog budget),
        ``faults`` injects a scripted :class:`FaultPlan`, and
        ``completed`` resumes from an execution frontier: ops with a
        recorded result are not re-run (their values seed the results
        dict), which is how post-PU-loss recovery preserves bitwise
        equality with the fault-free run.
        """
        assignment = self._normalize_assignment(graph, assignment, completed)
        lane_queues = self._scheduled_lane_queues(graph, assignment, completed)
        lane_items = {pu: [(0, i) for i in q] for pu, q in lane_queues.items()}
        out = self._run_lanes(
            [graph], lane_items, [external_inputs],
            policy=policy, faults=faults,
            completed=[completed] if completed else None, estimate=estimate)
        return out[0]

    def run_dag(self, graph: OpGraph, schedule,
                external_inputs: Mapping[int, tuple] | None = None, *,
                policy: ExecutionPolicy | None = None,
                faults: FaultPlan | None = None,
                completed: Mapping[int, Any] | None = None,
                estimate: float | None = None) -> dict[int, Any]:
        """Run a ``DagSchedule``: ops enqueue per-lane in step order and
        cross-lane synchronization happens only at true dependency edges,
        so a multi-op (antichain) step's ops really overlap across lanes.

        ``policy`` / ``faults`` / ``completed`` / ``estimate`` behave as
        in :meth:`run_scheduled`.
        """
        lane_queues = self._dag_lane_queues(graph, schedule, completed)
        out = self._run_lanes(
            [graph], lane_queues, [external_inputs],
            policy=policy, faults=faults,
            completed=[completed] if completed else None, estimate=estimate)
        return out[0]

    def run_concurrent(self, graphs: Sequence[OpGraph], schedule,
                       external_inputs: Sequence[Mapping[int, tuple] | None]
                       | None = None, *,
                       policy: ExecutionPolicy | None = None,
                       faults: FaultPlan | None = None,
                       completed: Sequence[Mapping[int, Any]] | None = None,
                       estimate: float | None = None,
                       partial: bool = False,
                       op_timings: list | None = None
                       ) -> list[dict[int, Any]]:
        """Run an M-model ``ConcurrentSchedule`` across the PU lanes.

        All M models' ops are multiplexed onto the *shared* lanes (one
        FIFO worker per PU — the command-queue semantics the concurrent
        cost laws assume): ops enqueue in schedule-step order, so two
        co-scheduled ops land on their assigned lanes side by side and
        same-PU co-scheduled ops serialise on one queue.  Dependencies
        are per-model (requests are independent); each model's results
        dict is returned in request order, for bitwise verification
        against isolated ``run_monolithic`` runs.

        ``policy`` / ``faults`` / ``completed`` / ``estimate`` behave as
        in :meth:`run_scheduled` (``completed`` is one frontier dict per
        request).  ``partial=True`` accepts a schedule that covers only a
        *window* of each request's remaining ops (precedence is still
        validated against the frontier) — the unit the real-execution
        serving loop advances by.  ``op_timings``, when a list, receives
        one ``(pu, request, op, wall_seconds)`` tuple per completed op —
        the measurement feed for EWMA latency-drift health tracking.
        """
        m = len(graphs)
        lane_queues, _ = self._concurrent_lane_queues(graphs, schedule,
                                                      completed, partial)
        ext = list(external_inputs or [None] * m)
        return self._run_lanes(list(graphs), lane_queues, ext,
                               policy=policy, faults=faults,
                               completed=completed, estimate=estimate,
                               op_timings=op_timings)

    # ------------------------------------------------------------------
    def _run_lanes(self, graphs: Sequence[OpGraph],
                   lane_queues: Mapping[str, Sequence[tuple[int, int]]],
                   ext: Sequence[Mapping[int, tuple] | None], *,
                   policy: ExecutionPolicy | None,
                   faults: FaultPlan | None,
                   completed: Sequence[Mapping[int, Any]] | None,
                   estimate: float | None,
                   op_timings: list | None = None) -> list[dict[int, Any]]:
        """Shared lane runtime of both interpreter entry points.

        One daemon worker thread per non-empty lane; per-op events bound
        by the run's watchdog budget; the first failure aborts the run
        and releases every event so no lane stays parked on a dead
        producer.  Frontier (``completed``) results seed the results
        dicts with their events pre-set.
        """
        m = len(graphs)
        results: list[dict[int, Any]] = [
            dict(completed[r]) if completed and completed[r] else {}
            for r in range(m)]
        done_ev: dict[tuple[int, int], threading.Event] = {
            (r, i): threading.Event()
            for r, g in enumerate(graphs) for i in range(len(g.ops))}
        for r in range(m):
            for i in results[r]:
                done_ev[(r, i)].set()

        run = RunContext(policy, faults, estimate)

        def release_all() -> None:
            for ev in done_ev.values():
                ev.set()

        run.release = release_all

        def exec_op(pu: str, r: int, i: int) -> None:
            g = graphs[r]
            for p in g.pred[i]:
                if not done_ev[(r, p)].is_set():
                    run.wait(done_ev[(r, p)],
                             f"op {i} of request {r} on lane {pu!r} "
                             f"(waiting for op {p})")
            run.check_abort()
            op = g.ops[i]
            what = f"op {i} of request {r} on lane {pu!r}"
            run.current[pu] = what

            def attempt():
                if run.faults is not None:
                    run.faults.fire(pu, r, i, run)
                if op.fn is None:
                    return None
                e = (ext[r] or {}).get(i, ())
                dep_vals = tuple(results[r][p] for p in g.pred[i])
                return op.fn(*(tuple(e) + dep_vals))

            t0 = time.monotonic() if op_timings is not None else 0.0
            results[r][i] = run_with_retries(run, attempt, what,
                                             lane=pu, request=r, op=i)
            if op_timings is not None:
                op_timings.append((pu, r, i, time.monotonic() - t0))
            run.current.pop(pu, None)
            done_ev[(r, i)].set()

        def lane_worker(pu: str) -> None:
            try:
                for r, i in lane_queues[pu]:
                    exec_op(pu, r, i)
            except _Aborted:
                pass  # a peer already failed; unwind silently
            except BaseException as e:
                run.fail(e)

        threads = [threading.Thread(target=lane_worker, args=(pu,),
                                    name=f"lane-{pu}", daemon=True)
                   for pu in lane_queues if lane_queues[pu]]
        for t in threads:
            t.start()
        for t in threads:
            if run.deadline is None:
                t.join()
            else:
                t.join(max(run.deadline - time.monotonic(), 0.0) + _JOIN_GRACE)
                if t.is_alive():
                    # backstop: a payload the watchdog cannot interrupt
                    # (daemon thread — it cannot block process exit)
                    run.abort.set()
                    release_all()
                    raise run._timeout(f"lane worker {t.name!r}")
        if run.errors:
            err = run.first_error()
            if isinstance(err, PULostError) and err.partial is None:
                err.partial = [dict(res) for res in results]
            raise err
        return results

    # ------------------------------------------------------------------
    # compiled path (laneprogram)
    # ------------------------------------------------------------------
    def compile_scheduled(self, graph: OpGraph, assignment) -> LaneProgram:
        """Compile a sequential/parallel plan into a :class:`LaneProgram`.

        Accepts the same ``assignment`` forms as ``run_scheduled``;
        ``program.run(external_inputs)`` then returns the same results
        dict, with per-op dispatch/event overhead collapsed to one fused
        call + one event per segment.
        """
        assignment = self._normalize_assignment(graph, assignment)
        queues = self._scheduled_lane_queues(graph, assignment)
        lane_items = {pu: [(0, i) for i in q] for pu, q in queues.items()}
        return compile_lane_program([graph], lane_items, single=True,
                                    targets=self.targets)

    def compile_dag(self, graph: OpGraph, schedule) -> LaneProgram:
        """Compile a ``DagSchedule`` into a :class:`LaneProgram`: each
        lane's queue (in step order) partitions into fused segments with
        events only at cross-lane dependency cuts, so independent
        subgraphs on different lanes overlap exactly as in :meth:`run_dag`;
        ``program.run(external_inputs)`` matches it bitwise."""
        lane_queues = self._dag_lane_queues(graph, schedule)
        return compile_lane_program([graph], lane_queues, single=True,
                                    targets=self.targets)

    def compile_concurrent(self, graphs: Sequence[OpGraph], schedule,
                           completed: Sequence[Mapping[int, Any]] | None
                           = None, partial: bool = False) -> LaneProgram:
        """Compile an M-model ``ConcurrentSchedule`` into a
        :class:`LaneProgram` (co-scheduled steps become single-op barrier
        segments); ``program.run(inputs)`` matches ``run_concurrent``.

        ``completed``/``partial`` compile a *window* program over the
        remaining ops of a partially-executed plan; run it with the same
        frontier (``program.run(..., completed=...)``) so cross-window
        inputs resolve from already-computed values."""
        lane_queues, barriers = self._concurrent_lane_queues(
            graphs, schedule, completed, partial)
        return compile_lane_program(list(graphs), lane_queues,
                                    barriers=barriers, single=False,
                                    targets=self.targets)

    # ------------------------------------------------------------------
    @staticmethod
    def outputs_close(a: Mapping[int, Any], b: Mapping[int, Any],
                      rtol: float = 0.0, atol: float = 0.0) -> bool:
        """Orchestrated vs monolithic outputs must match (bitwise by
        default: the schedule must not change numerics)."""
        if set(a) != set(b):
            return False
        for k in a:
            x, y = a[k], b[k]
            if x is None and y is None:
                continue
            if not np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol):
                return False
        return True
