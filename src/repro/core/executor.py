"""Execution orchestrator: applies a static schedule and really runs it.

The paper's output schedule is "applied directly by the execution
orchestrator" with zero runtime overhead.  This executor models each PU as
an execution *lane* (a worker thread with a FIFO command queue — the
command-queue semantics of a real PU).  Two execution paths share the lane
model:

* the **per-op interpreter** (``run_scheduled`` / ``run_concurrent``):
  ops are enqueued onto their assigned lane in dependency order and
  cross-lane dependencies synchronise via one event per op.  This is the
  bitwise-equivalence oracle — for every model in the zoo, orchestrated
  execution must produce outputs identical to monolithic single-lane
  execution (``run_monolithic``);

* the **compiled path** (``compile_scheduled`` / ``compile_concurrent``
  → :class:`~repro.core.laneprogram.LaneProgram`): each lane's queue is
  partitioned into maximal contiguous same-lane segments, each segment's
  payloads fuse into one callable (jitted when bitwise-safe), and events
  exist only at the cross-lane boundary cuts.  Same results, a fraction
  of the dispatch/synchronisation overhead — see ``laneprogram``.
"""
from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Mapping, Sequence

import numpy as np

from .laneprogram import LaneProgram, compile_lane_program
from .op import OpGraph


class ScheduleExecutor:
    """Runs an OpGraph whose ops carry ``fn`` payloads under an assignment."""

    def __init__(self, pus: Sequence[str]):
        self.pus = list(pus)

    def run_monolithic(self, graph: OpGraph,
                       external_inputs: Mapping[int, tuple] | None = None) -> dict[int, Any]:
        """Reference: run everything on one lane in topological order."""
        return self._run(graph, external_inputs, lanes=1, assignment=None)

    # ------------------------------------------------------------------
    # assignment / schedule normalization (shared by both paths)
    # ------------------------------------------------------------------
    def _normalize_assignment(self, graph: OpGraph, assignment
                              ) -> dict[int, str]:
        """``{op index: PU name}`` from a mapping or any schedule object
        exposing one (``SeqSchedule`` — via its chain — or
        ``ParallelSchedule.assignment``), with coverage validation."""
        if hasattr(assignment, "chain") and hasattr(assignment, "assignment"):
            assignment = dict(zip(assignment.chain, assignment.assignment))
        elif hasattr(assignment, "assignment"):
            assignment = assignment.assignment
        missing = [i for i in range(len(graph.ops)) if i not in assignment]
        if missing:
            raise ValueError(
                f"assignment does not cover the graph: {len(missing)} op(s) "
                f"unassigned (e.g. {missing[:5]}) — partial (tail/admission) "
                "plans cannot be executed on the full graph")
        return dict(assignment)

    def _scheduled_lane_queues(self, graph: OpGraph,
                               assignment: Mapping[int, str]
                               ) -> dict[str, list[int]]:
        """One FIFO lane per PU; ops enqueue in topological order."""
        lane_queues: dict[str, list[int]] = {p: [] for p in self.pus}
        for i in graph.topo_order():
            lane_queues[assignment[i]].append(i)
        return lane_queues

    def _concurrent_lane_queues(self, graphs: Sequence[OpGraph], schedule
                                ) -> tuple[dict[str, list[tuple[int, int]]],
                                           set[tuple[int, int]]]:
        """Lane queues in schedule-step order + the co-scheduled op set.

        Validates coverage AND dependency order (a mis-ordered schedule
        would otherwise deadlock the lane workers instead of raising).
        Ops of a step where >= 2 requests advance together are returned
        as *barrier* ops: the compiled path keeps them individually
        dispatched so the co-execution granularity the contention laws
        priced is preserved.
        """
        m = len(graphs)
        if schedule.n_requests != m:
            raise ValueError(
                f"schedule covers {schedule.n_requests} requests, "
                f"got {m} graphs")
        lane_queues: dict[str, list[tuple[int, int]]] = {p: [] for p in self.pus}
        barriers: set[tuple[int, int]] = set()
        seen: list[set[int]] = [set() for _ in range(m)]
        for st in schedule.steps:
            active = [(r, oi, pu) for r, (oi, pu)
                      in enumerate(zip(st.ops, st.pus)) if oi is not None]
            for r, oi, pu in active:
                missing_pred = [p for p in graphs[r].pred[oi]
                                if p not in seen[r]]
                if missing_pred:
                    raise ValueError(
                        f"schedule lists op {oi} of request {r} before its "
                        f"predecessor(s) {missing_pred} — executing it "
                        "would deadlock the lanes")
                lane_queues[pu].append((r, oi))
                seen[r].add(oi)
                if len(active) > 1:
                    barriers.add((r, oi))
        for r, g in enumerate(graphs):
            if seen[r] != set(range(len(g.ops))):
                missing = sorted(set(range(len(g.ops))) - seen[r])
                raise ValueError(
                    f"schedule does not cover request {r}: missing ops "
                    f"{missing[:5]}{'...' if len(missing) > 5 else ''}")
        return lane_queues, barriers

    # ------------------------------------------------------------------
    # per-op interpreter (the bitwise-equivalence oracle)
    # ------------------------------------------------------------------
    def run_scheduled(self, graph: OpGraph, assignment,
                      external_inputs: Mapping[int, tuple] | None = None) -> dict[int, Any]:
        """Run under the schedule: one worker lane per PU, event-synced.

        ``assignment`` is an ``{op index: PU name}`` mapping, or any
        schedule object exposing one (``SeqSchedule`` — via its chain —
        or ``ParallelSchedule.assignment``), so orchestrator plans can be
        executed without hand-building the mapping.
        """
        assignment = self._normalize_assignment(graph, assignment)
        return self._run(graph, external_inputs, lanes=len(self.pus),
                         assignment=assignment)

    # ------------------------------------------------------------------
    def _run(self, graph: OpGraph, external_inputs, lanes: int,
             assignment: Mapping[int, str] | None) -> dict[int, Any]:
        external_inputs = dict(external_inputs or {})
        n = len(graph.ops)
        results: dict[int, Any] = {}
        done_ev: dict[int, threading.Event] = {i: threading.Event() for i in range(n)}
        errors: list[BaseException] = []

        def gather_inputs(i: int) -> tuple:
            ext = external_inputs.get(i, ())
            dep_vals = tuple(results[p] for p in graph.pred[i])
            return tuple(ext) + dep_vals

        def exec_op(i: int) -> None:
            for p in graph.pred[i]:
                done_ev[p].wait()  # cross-lane dependency (D2H/H2D handoff)
            op = graph.ops[i]
            if op.fn is None:
                results[i] = None
            else:
                results[i] = op.fn(*gather_inputs(i))
            done_ev[i].set()

        if assignment is None:
            for i in graph.topo_order():
                exec_op(i)
            return results

        lane_queues = self._scheduled_lane_queues(graph, assignment)

        def lane_worker(pu: str) -> None:
            try:
                for i in lane_queues[pu]:
                    exec_op(i)
            except BaseException as e:
                # record the original failure FIRST, then release every
                # event so no other lane can deadlock waiting on this one
                errors.append(e)
                for ev in done_ev.values():
                    ev.set()

        with ThreadPoolExecutor(max_workers=len(self.pus)) as pool:
            futs = [pool.submit(lane_worker, p) for p in self.pus]
            for f in futs:
                f.result()
        if errors:
            raise errors[0]
        return results

    # ------------------------------------------------------------------
    def run_concurrent(self, graphs: Sequence[OpGraph], schedule,
                       external_inputs: Sequence[Mapping[int, tuple] | None]
                       | None = None) -> list[dict[int, Any]]:
        """Run an M-model ``ConcurrentSchedule`` across the PU lanes.

        All M models' ops are multiplexed onto the *shared* lanes (one
        FIFO worker per PU — the command-queue semantics the concurrent
        cost laws assume): ops enqueue in schedule-step order, so two
        co-scheduled ops land on their assigned lanes side by side and
        same-PU co-scheduled ops serialise on one queue.  Dependencies
        are per-model (requests are independent); each model's results
        dict is returned in request order, for bitwise verification
        against isolated ``run_monolithic`` runs.
        """
        m = len(graphs)
        lane_queues, _ = self._concurrent_lane_queues(graphs, schedule)
        ext = list(external_inputs or [None] * m)

        results: list[dict[int, Any]] = [{} for _ in range(m)]
        done_ev: dict[tuple[int, int], threading.Event] = {
            (r, i): threading.Event()
            for r, g in enumerate(graphs) for i in range(len(g.ops))}
        errors: list[BaseException] = []

        def exec_op(r: int, i: int) -> None:
            g = graphs[r]
            for p in g.pred[i]:
                done_ev[(r, p)].wait()
            op = g.ops[i]
            if op.fn is None:
                results[r][i] = None
            else:
                e = (ext[r] or {}).get(i, ())
                dep_vals = tuple(results[r][p] for p in g.pred[i])
                results[r][i] = op.fn(*(tuple(e) + dep_vals))
            done_ev[(r, i)].set()

        def lane_worker(pu: str) -> None:
            try:
                for r, i in lane_queues[pu]:
                    exec_op(r, i)
            except BaseException as e:
                errors.append(e)
                for ev in done_ev.values():
                    ev.set()

        with ThreadPoolExecutor(max_workers=len(self.pus)) as pool:
            futs = [pool.submit(lane_worker, p) for p in self.pus]
            for f in futs:
                f.result()
        if errors:
            raise errors[0]
        return results

    # ------------------------------------------------------------------
    # compiled path (laneprogram)
    # ------------------------------------------------------------------
    def compile_scheduled(self, graph: OpGraph, assignment) -> LaneProgram:
        """Compile a sequential/parallel plan into a :class:`LaneProgram`.

        Accepts the same ``assignment`` forms as ``run_scheduled``;
        ``program.run(external_inputs)`` then returns the same results
        dict, with per-op dispatch/event overhead collapsed to one fused
        call + one event per segment.
        """
        assignment = self._normalize_assignment(graph, assignment)
        queues = self._scheduled_lane_queues(graph, assignment)
        lane_items = {pu: [(0, i) for i in q] for pu, q in queues.items()}
        return compile_lane_program([graph], lane_items, single=True)

    def compile_concurrent(self, graphs: Sequence[OpGraph],
                           schedule) -> LaneProgram:
        """Compile an M-model ``ConcurrentSchedule`` into a
        :class:`LaneProgram` (co-scheduled steps become single-op barrier
        segments); ``program.run(inputs)`` matches ``run_concurrent``."""
        lane_queues, barriers = self._concurrent_lane_queues(graphs, schedule)
        return compile_lane_program(list(graphs), lane_queues,
                                    barriers=barriers, single=False)

    # ------------------------------------------------------------------
    @staticmethod
    def outputs_close(a: Mapping[int, Any], b: Mapping[int, Any],
                      rtol: float = 0.0, atol: float = 0.0) -> bool:
        """Orchestrated vs monolithic outputs must match (bitwise by
        default: the schedule must not change numerics)."""
        if set(a) != set(b):
            return False
        for k in a:
            x, y = a[k], b[k]
            if x is None and y is None:
                continue
            if not np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol):
                return False
        return True
