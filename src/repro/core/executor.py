"""Execution orchestrator: applies a static schedule and really runs it.

The paper's output schedule is "applied directly by the execution
orchestrator" with zero runtime overhead.  This executor models each PU as
an execution *lane* (a worker thread with a FIFO command queue — the
command-queue semantics of a real PU).  Ops are enqueued onto their
assigned lane in dependency order; cross-lane dependencies synchronise via
events (the H2D/D2H handoff points of the unified-memory system model).

Its purpose in this reproduction is **correctness validation**: for every
model in the zoo, orchestrated execution must produce outputs identical to
monolithic single-lane execution.
"""
from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Mapping, Sequence

import numpy as np

from .op import OpGraph


class ScheduleExecutor:
    """Runs an OpGraph whose ops carry ``fn`` payloads under an assignment."""

    def __init__(self, pus: Sequence[str]):
        self.pus = list(pus)

    def run_monolithic(self, graph: OpGraph,
                       external_inputs: Mapping[int, tuple] | None = None) -> dict[int, Any]:
        """Reference: run everything on one lane in topological order."""
        return self._run(graph, external_inputs, lanes=1, assignment=None)

    def run_scheduled(self, graph: OpGraph, assignment,
                      external_inputs: Mapping[int, tuple] | None = None) -> dict[int, Any]:
        """Run under the schedule: one worker lane per PU, event-synced.

        ``assignment`` is an ``{op index: PU name}`` mapping, or any
        schedule object exposing one (``SeqSchedule`` — via its chain —
        or ``ParallelSchedule.assignment``), so orchestrator plans can be
        executed without hand-building the mapping.
        """
        if hasattr(assignment, "chain") and hasattr(assignment, "assignment"):
            assignment = dict(zip(assignment.chain, assignment.assignment))
        elif hasattr(assignment, "assignment"):
            assignment = assignment.assignment
        missing = [i for i in range(len(graph.ops)) if i not in assignment]
        if missing:
            raise ValueError(
                f"assignment does not cover the graph: {len(missing)} op(s) "
                f"unassigned (e.g. {missing[:5]}) — partial (tail/admission) "
                "plans cannot be executed on the full graph")
        return self._run(graph, external_inputs, lanes=len(self.pus),
                         assignment=dict(assignment))

    # ------------------------------------------------------------------
    def _run(self, graph: OpGraph, external_inputs, lanes: int,
             assignment: Mapping[int, str] | None) -> dict[int, Any]:
        external_inputs = dict(external_inputs or {})
        n = len(graph.ops)
        results: dict[int, Any] = {}
        done_ev: dict[int, threading.Event] = {i: threading.Event() for i in range(n)}
        errors: list[BaseException] = []

        def gather_inputs(i: int) -> tuple:
            ext = external_inputs.get(i, ())
            dep_vals = tuple(results[p] for p in graph.pred[i])
            return tuple(ext) + dep_vals

        def exec_op(i: int) -> None:
            for p in graph.pred[i]:
                done_ev[p].wait()  # cross-lane dependency (D2H/H2D handoff)
            op = graph.ops[i]
            if op.fn is None:
                results[i] = None
            else:
                results[i] = op.fn(*gather_inputs(i))
            done_ev[i].set()

        order = graph.topo_order()
        if assignment is None:
            for i in order:
                exec_op(i)
            return results

        # one FIFO lane per PU; ops enqueue in topological order
        lane_queues: dict[str, list[int]] = {p: [] for p in self.pus}
        for i in order:
            lane_queues[assignment[i]].append(i)

        def lane_worker(pu: str) -> None:
            try:
                for i in lane_queues[pu]:
                    exec_op(i)
            except BaseException as e:  # pragma: no cover
                errors.append(e)
                for ev in done_ev.values():
                    ev.set()

        with ThreadPoolExecutor(max_workers=len(self.pus)) as pool:
            futs = [pool.submit(lane_worker, p) for p in self.pus]
            for f in futs:
                f.result()
        if errors:
            raise errors[0]
        return results

    # ------------------------------------------------------------------
    def run_concurrent(self, graphs: Sequence[OpGraph], schedule,
                       external_inputs: Sequence[Mapping[int, tuple] | None]
                       | None = None) -> list[dict[int, Any]]:
        """Run an M-model ``ConcurrentSchedule`` across the PU lanes.

        All M models' ops are multiplexed onto the *shared* lanes (one
        FIFO worker per PU — the command-queue semantics the concurrent
        cost laws assume): ops enqueue in schedule-step order, so two
        co-scheduled ops land on their assigned lanes side by side and
        same-PU co-scheduled ops serialise on one queue.  Dependencies
        are per-model (requests are independent); each model's results
        dict is returned in request order, for bitwise verification
        against isolated ``run_monolithic`` runs.
        """
        m = len(graphs)
        if schedule.n_requests != m:
            raise ValueError(
                f"schedule covers {schedule.n_requests} requests, "
                f"got {m} graphs")
        ext = list(external_inputs or [None] * m)
        # lane queues in schedule-step order; validate coverage AND
        # dependency order (a mis-ordered schedule would otherwise
        # deadlock the lane workers instead of raising)
        lane_queues: dict[str, list[tuple[int, int]]] = {p: [] for p in self.pus}
        seen: list[set[int]] = [set() for _ in range(m)]
        for st in schedule.steps:
            for r, (oi, pu) in enumerate(zip(st.ops, st.pus)):
                if oi is None:
                    continue
                missing_pred = [p for p in graphs[r].pred[oi]
                                if p not in seen[r]]
                if missing_pred:
                    raise ValueError(
                        f"schedule lists op {oi} of request {r} before its "
                        f"predecessor(s) {missing_pred} — executing it "
                        "would deadlock the lanes")
                lane_queues[pu].append((r, oi))
                seen[r].add(oi)
        for r, g in enumerate(graphs):
            if seen[r] != set(range(len(g.ops))):
                missing = sorted(set(range(len(g.ops))) - seen[r])
                raise ValueError(
                    f"schedule does not cover request {r}: missing ops "
                    f"{missing[:5]}{'...' if len(missing) > 5 else ''}")

        results: list[dict[int, Any]] = [{} for _ in range(m)]
        done_ev: dict[tuple[int, int], threading.Event] = {
            (r, i): threading.Event()
            for r, g in enumerate(graphs) for i in range(len(g.ops))}
        errors: list[BaseException] = []

        def exec_op(r: int, i: int) -> None:
            g = graphs[r]
            for p in g.pred[i]:
                done_ev[(r, p)].wait()
            op = g.ops[i]
            if op.fn is None:
                results[r][i] = None
            else:
                e = (ext[r] or {}).get(i, ())
                dep_vals = tuple(results[r][p] for p in g.pred[i])
                results[r][i] = op.fn(*(tuple(e) + dep_vals))
            done_ev[(r, i)].set()

        def lane_worker(pu: str) -> None:
            try:
                for r, i in lane_queues[pu]:
                    exec_op(r, i)
            except BaseException as e:  # pragma: no cover
                errors.append(e)
                for ev in done_ev.values():
                    ev.set()

        with ThreadPoolExecutor(max_workers=len(self.pus)) as pool:
            futs = [pool.submit(lane_worker, p) for p in self.pus]
            for f in futs:
                f.result()
        if errors:
            raise errors[0]
        return results

    # ------------------------------------------------------------------
    @staticmethod
    def outputs_close(a: Mapping[int, Any], b: Mapping[int, Any],
                      rtol: float = 0.0, atol: float = 0.0) -> bool:
        """Orchestrated vs monolithic outputs must match (bitwise by
        default: the schedule must not change numerics)."""
        if set(a) != set(b):
            return False
        for k in a:
            x, y = a[k], b[k]
            if x is None and y is None:
                continue
            if not np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol):
                return False
        return True
