"""Execution orchestrator: applies a static schedule and really runs it.

The paper's output schedule is "applied directly by the execution
orchestrator" with zero runtime overhead.  This executor models each PU as
an execution *lane* (a worker thread with a FIFO command queue — the
command-queue semantics of a real PU).  Ops are enqueued onto their
assigned lane in dependency order; cross-lane dependencies synchronise via
events (the H2D/D2H handoff points of the unified-memory system model).

Its purpose in this reproduction is **correctness validation**: for every
model in the zoo, orchestrated execution must produce outputs identical to
monolithic single-lane execution.
"""
from __future__ import annotations

import threading
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Mapping, Sequence

import numpy as np

from .op import OpGraph


class ScheduleExecutor:
    """Runs an OpGraph whose ops carry ``fn`` payloads under an assignment."""

    def __init__(self, pus: Sequence[str]):
        self.pus = list(pus)

    def run_monolithic(self, graph: OpGraph,
                       external_inputs: Mapping[int, tuple] | None = None) -> dict[int, Any]:
        """Reference: run everything on one lane in topological order."""
        return self._run(graph, external_inputs, lanes=1, assignment=None)

    def run_scheduled(self, graph: OpGraph, assignment: Mapping[int, str],
                      external_inputs: Mapping[int, tuple] | None = None) -> dict[int, Any]:
        """Run under the schedule: one worker lane per PU, event-synced."""
        return self._run(graph, external_inputs, lanes=len(self.pus),
                         assignment=dict(assignment))

    # ------------------------------------------------------------------
    def _run(self, graph: OpGraph, external_inputs, lanes: int,
             assignment: Mapping[int, str] | None) -> dict[int, Any]:
        external_inputs = dict(external_inputs or {})
        n = len(graph.ops)
        results: dict[int, Any] = {}
        done_ev: dict[int, threading.Event] = {i: threading.Event() for i in range(n)}
        errors: list[BaseException] = []

        def gather_inputs(i: int) -> tuple:
            ext = external_inputs.get(i, ())
            dep_vals = tuple(results[p] for p in graph.pred[i])
            return tuple(ext) + dep_vals

        def exec_op(i: int) -> None:
            for p in graph.pred[i]:
                done_ev[p].wait()  # cross-lane dependency (D2H/H2D handoff)
            op = graph.ops[i]
            if op.fn is None:
                results[i] = None
            else:
                results[i] = op.fn(*gather_inputs(i))
            done_ev[i].set()

        order = graph.topo_order()
        if assignment is None:
            for i in order:
                exec_op(i)
            return results

        # one FIFO lane per PU; ops enqueue in topological order
        lane_queues: dict[str, list[int]] = {p: [] for p in self.pus}
        for i in order:
            lane_queues[assignment[i]].append(i)

        def lane_worker(pu: str) -> None:
            try:
                for i in lane_queues[pu]:
                    exec_op(i)
            except BaseException as e:  # pragma: no cover
                errors.append(e)
                for ev in done_ev.values():
                    ev.set()

        with ThreadPoolExecutor(max_workers=len(self.pus)) as pool:
            futs = [pool.submit(lane_worker, p) for p in self.pus]
            for f in futs:
                f.result()
        if errors:
            raise errors[0]
        return results

    # ------------------------------------------------------------------
    @staticmethod
    def outputs_close(a: Mapping[int, Any], b: Mapping[int, Any],
                      rtol: float = 0.0, atol: float = 0.0) -> bool:
        """Orchestrated vs monolithic outputs must match (bitwise by
        default: the schedule must not change numerics)."""
        if set(a) != set(b):
            return False
        for k in a:
            x, y = a[k], b[k]
            if x is None and y is None:
                continue
            if not np.allclose(np.asarray(x), np.asarray(y), rtol=rtol, atol=atol):
                return False
        return True
