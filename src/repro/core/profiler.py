"""Profiler (Algorithm 1, Stage 1).

Two complementary paths fill the same ``CostTable``:

* ``AnalyticProfiler`` — per-PU analytic cost models (``EdgeSoCCostModel``),
  used when the target PUs don't physically exist in this container.
* ``MeasuredProfiler`` — wall-clock measurement of each fused operator as a
  standalone jitted sub-model on the host backend (the paper's
  extract-and-measure flow: 20 warm-up + 200 measurement iterations,
  here reduced for CI budgets).  Host measurements anchor the CPU column;
  accelerator columns are derived by the analytic PU ratios, mirroring how
  the paper's offline profiling would populate the table on real silicon.

``trace_fused_ops`` extracts a fused-operator graph from an arbitrary JAX
callable via its jaxpr, applying a backend-compiler-like fusion rule
(elementwise/reduction ops fuse into the preceding anchor op, the paper's
"Conv-BN-ReLU" granularity).
"""
from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Mapping, Sequence

import jax
import numpy as np

_log = logging.getLogger(__name__)

from .costmodel import CostEntry, CostTable, EdgeSoCCostModel, PUSpec
from .op import FusedOp, OpGraph

# jaxpr primitive -> op kind classification
_ANCHOR_KINDS: dict[str, str] = {
    "dot_general": "matmul",
    "conv_general_dilated": "conv2d",
    "cumsum": "cumsum",
    "cumlogsumexp": "cumsum",
    "scan": "scan",
    "while": "scan",
    "gather": "gather",
    "scatter": "scatter",
    "scatter-add": "scatter",
    "scatter_add": "scatter",
    "fft": "rdft",
    "sort": "gather",
    "argmax": "gather",
    "top_k": "gather",
    "dynamic_slice": "gather",
    "dynamic_update_slice": "scatter",
}
_ELTWISE = {
    "add", "sub", "mul", "div", "max", "min", "exp", "log", "tanh",
    "logistic", "rsqrt", "sqrt", "pow", "integer_pow", "neg", "sign",
    "abs", "erf", "select_n", "clamp", "convert_element_type", "and",
    "or", "xor", "not", "lt", "le", "gt", "ge", "eq", "ne", "squeeze",
    "expand_dims", "cos", "sin", "floor", "ceil", "round", "stop_gradient",
    "copy", "real", "imag", "complex", "conj",
}
_REDUCE = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
           "argmin", "reduce_and", "reduce_or", "softmax"}
_LAYOUT = {"reshape", "transpose", "broadcast_in_dim", "concatenate",
           "slice", "rev", "pad", "iota", "split"}


def _classify(prim_name: str) -> str | None:
    if prim_name in _ANCHOR_KINDS:
        return _ANCHOR_KINDS[prim_name]
    if prim_name in _ELTWISE:
        return "eltwise"
    if prim_name in _REDUCE:
        return "reduce"
    if prim_name in _LAYOUT:
        return "layout"
    return None


def trace_fused_ops(fn: Callable, *example_args, name: str = "model") -> OpGraph:
    """Extract a fused-operator chain from a JAX callable.

    Fusion rule: anchor ops (GEMM/conv/scan/gather/fft/...) start a new
    fused operator; elementwise / reduction / layout ops fuse into the
    current one.  The result is a sequential chain in program order — the
    granularity the paper's NPU PERF_COUNT decomposition yields.
    """
    jaxpr = jax.make_jaxpr(fn)(*example_args)
    fused: list[FusedOp] = []
    cur_extra_flops = 0.0
    cur_extra_bytes = 0.0

    def shape_of(v) -> tuple[int, ...]:
        aval = v.aval
        return tuple(int(d) for d in getattr(aval, "shape", ()) or ())

    def dtype_bytes_of(v) -> int:
        aval = v.aval
        dt = getattr(aval, "dtype", None)
        return int(np.dtype(dt).itemsize) if dt is not None else 2

    def walk(jp) -> None:
        nonlocal cur_extra_flops, cur_extra_bytes
        for eqn in jp.eqns:
            pname = eqn.primitive.name
            # recurse into pjit/closed calls (control flow like scan/while
            # stays a single anchor op — it IS the fused recurrence kernel)
            if pname in ("pjit", "closed_call", "custom_jvp_call",
                         "custom_vjp_call", "custom_vjp_call_jaxpr",
                         "remat", "checkpoint"):
                inner = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
                if inner is not None:
                    walk(inner.jaxpr if hasattr(inner, "jaxpr") else inner)
                    continue
            kind = _classify(pname)
            outv = eqn.outvars[0] if eqn.outvars else None
            out_shape = shape_of(outv) if outv is not None else ()
            dtb = dtype_bytes_of(outv) if outv is not None else 2
            in_shapes = tuple(shape_of(v) for v in eqn.invars
                              if hasattr(v, "aval"))
            if kind in ("eltwise", "reduce", "layout", None):
                # fuse into current op
                n_out = float(np.prod(out_shape)) if out_shape else 0.0
                cur_extra_flops += n_out
                cur_extra_bytes += n_out * dtb
                continue
            op = FusedOp(
                name=f"{name}.{len(fused)}.{pname}", kind=kind,
                in_shapes=in_shapes, out_shape=out_shape, dtype_bytes=dtb,
            )
            if fused and (cur_extra_flops or cur_extra_bytes):
                fused[-1].flops += cur_extra_flops
                fused[-1].bytes_moved += cur_extra_bytes
            cur_extra_flops = cur_extra_bytes = 0.0
            fused.append(op)
    walk(jaxpr.jaxpr)
    if fused and (cur_extra_flops or cur_extra_bytes):
        fused[-1].flops += cur_extra_flops
        fused[-1].bytes_moved += cur_extra_bytes
    if not fused:
        fused = [FusedOp(name=f"{name}.all", kind="other", out_shape=(1,))]
    return OpGraph(fused, edges=None)


# ---------------------------------------------------------------------------
# Wall-clock measurement
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Measurement:
    """One payload's timing distribution: ``median`` (the robust number
    the cost table consumes), ``best`` (the min — what a noiseless
    machine would report), and the raw ``times`` so jitter is never
    hidden by a single scalar."""

    median: float
    best: float
    times: tuple[float, ...]

    @property
    def spread(self) -> float:
        """max/best - 1: the visible jitter of this measurement."""
        return (max(self.times) / self.best - 1.0) if self.best > 0 else 0.0

    def __float__(self) -> float:
        return self.median


def measure_callable_stats(fn: Callable, args: Sequence[Any], *,
                           warmup: int = 3, iters: int = 10,
                           jit: bool = True,
                           device: Any = None) -> Measurement:
    """Wall-clock :class:`Measurement` of ``fn(*args)``.

    JAX dispatch is **asynchronous**: a call returns future-backed arrays
    long before the computation finishes, so every timed iteration (and
    every warmup) is fenced with ``jax.block_until_ready`` on the actual
    output pytree — without the fence a jitted payload times as ~0 (the
    dispatch cost alone).  ``jit=False`` measures the payload eagerly
    (still fenced — eager JAX is async too), which is what non-jitting
    targets (NumPy/eager backends) execute; ``device`` pins the inputs
    with ``jax.device_put`` first so transfers are not billed to the
    kernel.
    """
    if device is not None:
        args = tuple(jax.device_put(a, device) for a in args)
    run = jax.jit(fn) if jit else fn
    for _ in range(max(warmup, 1)):   # at least once: trigger compilation
        jax.block_until_ready(run(*args))
    ts = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        out = run(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return Measurement(median=float(np.median(ts)), best=float(min(ts)),
                       times=tuple(ts))


def measure_callable(fn: Callable, args: Sequence[Any], *, warmup: int = 3,
                     iters: int = 10, jit: bool = True,
                     device: Any = None) -> float:
    """Median wall-clock seconds of ``fn(*args)`` (blocked until ready).
    Scalar form of :func:`measure_callable_stats`."""
    return measure_callable_stats(fn, args, warmup=warmup, iters=iters,
                                  jit=jit, device=device).median


class AnalyticProfiler:
    """Fill a CostTable from analytic PU models (no hardware needed)."""

    def __init__(self, model: EdgeSoCCostModel | None = None):
        self.model = model or EdgeSoCCostModel()

    def profile(self, graph: OpGraph) -> CostTable:
        return self.model.build_table(graph)


class MeasuredProfiler:
    """Fill the cost table from real wall-clock measurements.

    Two modes share the constructor:

    * **CPU-anchored (default, ``targets=None``).**  The paper's
      offline-profiling stand-in when the PUs don't physically exist:
      measure each payload once on the host, anchor the CPU column, and
      derive the accelerator columns via the analytic per-PU ratios.
    * **Per-target (``targets={lane: Target}``).**  The real loop: each
      op's resolved payload variant (``op.payload_for(target.dialect)``)
      is measured *on every bound backend* under that target's jit
      policy and device placement, and each measurement lands directly
      in that lane's column (``kernel`` = median; ``dispatch``/
      ``h2d``/``d2h``/``power`` from the target's declared pricing).
      Full distributions go to ``table.meta["measurements"]``
      (``{(op, lane): {"median", "best", "spread"}}``).  Payload-less
      ops fall back to the analytic CPU estimate on every lane (noted
      in ``table.meta["analytic_fallback"]``); an op a target declares
      in ``meta["unsupported_on"]`` gets no cell on that lane.

    For ops that carry an ``fn`` payload and example inputs in
    ``op.meta['example_inputs']`` we measure; otherwise we fall back to the
    analytic CPU estimate.  A measurement that *fails* (payload raises,
    un-jittable closure, ...) is never silently swallowed: each failure is
    logged, collected into the returned table's
    ``meta["profile_failures"]`` (``{op index: "ExcType: message"}`` in
    CPU-anchored mode, ``{(op index, lane): ...}`` per-target — where a
    failed cell is *omitted*, i.e. the op is unsupported on that
    backend), and under ``strict=True`` re-raised with the op named
    instead of falling back.
    """

    def __init__(self, model: EdgeSoCCostModel | None = None,
                 warmup: int = 2, iters: int = 5, strict: bool = False,
                 targets=None):
        from .targets import resolve_targets
        self.model = model or EdgeSoCCostModel()
        self.warmup = warmup
        self.iters = iters
        self.strict = strict
        self.targets = resolve_targets(targets)

    def profile(self, graph: OpGraph,
                strict: bool | None = None) -> CostTable:
        strict = self.strict if strict is None else strict
        if self.targets is not None:
            return self._profile_targets(graph, strict)
        failures: dict[int, str] = {}
        table = CostTable(list(self.model.pus))
        table.meta["profile_failures"] = failures
        for i, op in enumerate(graph.ops):
            analytic = {name: self.model.entry(op, pu)
                        for name, pu in self.model.pus.items()}
            cpu_est = analytic.get("CPU")
            measured = None
            if op.fn is not None and "example_inputs" in op.meta:
                try:
                    measured = measure_callable(
                        op.fn, op.meta["example_inputs"],
                        warmup=self.warmup, iters=self.iters)
                except Exception as e:
                    if strict:
                        raise RuntimeError(
                            f"MeasuredProfiler: measuring op {i} "
                            f"({op.name!r}, kind {op.kind!r}) failed"
                        ) from e
                    failures[i] = f"{type(e).__name__}: {e}"
                    _log.warning(
                        "MeasuredProfiler: op %d (%s) measurement failed "
                        "(%s); falling back to the analytic CPU estimate",
                        i, op.name, failures[i])
                    measured = None
            scale = (measured / cpu_est.kernel
                     if (measured and cpu_est and cpu_est.kernel > 0) else 1.0)
            for name, e in analytic.items():
                if e is None:
                    continue
                table.set(i, name, CostEntry(
                    kernel=e.kernel * scale, dispatch=e.dispatch,
                    h2d=e.h2d, d2h=e.d2h, power=e.power))
        return table

    # -- per-target mode ----------------------------------------------------
    def _analytic_anchor(self, op: FusedOp) -> CostEntry | None:
        """Analytic estimate for payload-less ops: the model's CPU spec
        (any host spec if "CPU" is absent)."""
        pu = self.model.pus.get("CPU")
        if pu is None:
            pu = next(iter(self.model.pus.values()))
        return self.model.entry(op, pu)

    def _profile_targets(self, graph: OpGraph, strict: bool) -> CostTable:
        """Measure every op on every bound backend; see the class docs."""
        targets = self.targets
        failures: dict[tuple[int, str], str] = {}
        stats: dict[tuple[int, str], dict] = {}
        fallback: list[tuple[int, str]] = []
        table = CostTable(list(targets))
        table.meta["profile_failures"] = failures
        table.meta["measurements"] = stats
        table.meta["analytic_fallback"] = fallback
        table.meta["targets"] = {lane: t.name for lane, t in targets.items()}
        for i, op in enumerate(graph.ops):
            unsupported = op.meta.get("unsupported_on", ())
            for lane, tgt in targets.items():
                if lane in unsupported or tgt.name in unsupported:
                    continue
                fn = op.payload_for(tgt.dialect)
                if fn is None or "example_inputs" not in op.meta:
                    est = self._analytic_anchor(op)
                    if est is None:
                        continue
                    fallback.append((i, lane))
                    table.set(i, lane, CostEntry(
                        kernel=est.kernel, dispatch=tgt.dispatch_s,
                        h2d=tgt.handoff_s, d2h=tgt.handoff_s,
                        power=tgt.power_compute))
                    continue
                try:
                    m = measure_callable_stats(
                        fn, op.meta["example_inputs"],
                        warmup=self.warmup, iters=self.iters,
                        jit=tgt.jit, device=tgt.device)
                except Exception as e:
                    if strict:
                        raise RuntimeError(
                            f"MeasuredProfiler: measuring op {i} "
                            f"({op.name!r}, kind {op.kind!r}) on target "
                            f"{tgt.name!r} (lane {lane!r}) failed") from e
                    failures[(i, lane)] = f"{type(e).__name__}: {e}"
                    _log.warning(
                        "MeasuredProfiler: op %d (%s) failed on target %s "
                        "(%s); cell omitted — op unsupported on this lane",
                        i, op.name, tgt.name, failures[(i, lane)])
                    continue
                stats[(i, lane)] = {"median": m.median, "best": m.best,
                                    "spread": m.spread}
                table.set(i, lane, CostEntry(
                    kernel=m.median, dispatch=tgt.dispatch_s,
                    h2d=tgt.handoff_s, d2h=tgt.handoff_s,
                    power=tgt.power_compute))
        return table
