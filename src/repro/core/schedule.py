"""Schedule objects + cost accounting.

A ``Schedule`` is the static output of the search engine (paper §3.4: "The
output schedule is a static mapping that is applied directly by the
execution orchestrator").  ``evaluate_*`` re-derives latency and energy for
a *fixed* assignment, so that e.g. the energy of a latency-optimised
schedule can be compared against the energy-optimised one (paper Fig. 6).

Evaluation runs on the dense ``Workload`` layer (one gather over the
``(N, K)`` arrays); the scalar dict walk is retained as
``evaluate_sequential_reference`` for the equivalence suite.

``schedule_to_dict`` / ``schedule_from_dict`` give every schedule kind a
lossless JSON-able form (floats survive ``json`` round-trips bitwise via
``repr`` shortest-round-trip printing) — the serialization layer behind
``orchestrator.Plan.to_json``/``from_json``.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Sequence

from .costmodel import CostTable, PUSpec, transition_cost
from .op import FusedOp
from .workload import Workload


@dataclasses.dataclass
class SeqSchedule:
    """Sequential schedule: one PU per op along a chain."""

    chain: list[int]               # op indices
    assignment: list[str]          # PU per chain position
    latency: float
    energy: float
    objective: str

    def pu_of(self, op_idx: int) -> str:
        return self.assignment[self.chain.index(op_idx)]


@dataclasses.dataclass
class BranchSchedule:
    branch_ops: list[int]
    assignment: list[str]
    solo_latency: float            # before contention adjustment
    adj_latency: float             # after SF adjustment
    energy: float


@dataclasses.dataclass
class PhaseSchedule:
    index: int
    parallel: bool                 # whether branches co-execute
    branches: list[BranchSchedule]
    makespan: float
    energy: float


@dataclasses.dataclass
class ParallelSchedule:
    phases: list[PhaseSchedule]
    latency: float
    energy: float
    objective: str

    @property
    def assignment(self) -> dict[int, str]:
        out: dict[int, str] = {}
        for ph in self.phases:
            for br in ph.branches:
                for o, p in zip(br.branch_ops, br.assignment):
                    out[o] = p
        return out

    @property
    def n_concurrent_phases(self) -> int:
        return sum(1 for ph in self.phases if ph.parallel and len(ph.branches) > 1)


@dataclasses.dataclass
class ConcurrentStep:
    """One step of an M-request concurrent schedule.

    ``ops[r]`` / ``pus[r]`` give request ``r``'s op index and PU for this
    step, or ``None`` when request ``r`` does not advance.  The original
    two-request solvers emit 2-tuples; the M-ary solvers emit M-tuples.
    """

    ops: tuple[int | None, ...]   # op index per request (None = idle)
    pus: tuple[str | None, ...]
    cost: float


@dataclasses.dataclass
class ConcurrentSchedule:
    steps: list[ConcurrentStep]
    latency: float
    energy: float
    objective: str
    mode: str  # "aligned" | "joint" | "joint-grid" | "rolling" | "pairwise"

    @property
    def n_requests(self) -> int:
        return len(self.steps[0].ops) if self.steps else 0

    def assignment_of(self, request: int) -> list[tuple[int, str]]:
        out = []
        for st in self.steps:
            if st.ops[request] is not None:
                out.append((st.ops[request], st.pus[request]))
        return out


@dataclasses.dataclass(slots=True)
class DagStep:
    """One step of a DAG (antichain-frontier) schedule.

    ``ops`` is the antichain of DAG node indices advanced this step —
    mutually independent ops, all of whose predecessors completed in
    earlier steps.  ``pus[j]`` is the PU running ``ops[j]``.  A singleton
    step is ordinary sequential progress; a multi-op step co-executes its
    ops under the contention model (the paper's intra-model parallelism).
    """

    ops: tuple[int, ...]           # DAG node indices (len >= 1, no None)
    pus: tuple[str, ...]           # PU per op
    cost: float


@dataclasses.dataclass
class DagSchedule:
    """Static schedule over an op DAG: a sequence of antichain steps whose
    union, in order, is a topological linear extension of the DAG."""

    steps: list[DagStep]
    latency: float
    energy: float
    objective: str
    mode: str  # "chain" | "union-grid" | "phase" | "frontier"

    @property
    def assignment(self) -> dict[int, str]:
        out: dict[int, str] = {}
        for st in self.steps:
            for o, p in zip(st.ops, st.pus):
                out[o] = p
        return out

    @property
    def order(self) -> list[int]:
        """Node completion order (a linear extension of the DAG)."""
        return [o for st in self.steps for o in st.ops]

    @property
    def n_parallel_steps(self) -> int:
        return sum(1 for st in self.steps if len(st.ops) > 1)


# ---------------------------------------------------------------------------
# Fixed-assignment evaluation (dense Workload layer)
# ---------------------------------------------------------------------------


def evaluate_sequential(
    chain: Sequence[int],
    assignment: Sequence[str],
    ops: Sequence[FusedOp],
    table: CostTable,
    pus: Mapping[str, PUSpec],
    workload: Workload | None = None,
) -> tuple[float, float]:
    """(latency, energy) of a fixed sequential assignment, including the
    boundary H2D/D2H and inter-op transition costs of the execution graph.

    Runs as one dense gather on the ``Workload`` view; pass ``workload``
    to reuse a prebuilt one (otherwise the scalar table is ingested once
    per call)."""
    wl = workload if workload is not None else Workload.build(
        chain, table, pus, ops=ops)
    return wl.evaluate(assignment)


def evaluate_sequential_reference(
    chain: Sequence[int],
    assignment: Sequence[str],
    ops: Sequence[FusedOp],
    table: CostTable,
    pus: Mapping[str, PUSpec],
) -> tuple[float, float]:
    """Scalar dict-walk evaluation (pre-Workload oracle, kept for the
    equivalence regression suite)."""
    assert len(chain) == len(assignment)
    lat = 0.0
    eng = 0.0
    first, last = chain[0], chain[-1]
    e0 = table.require(first, assignment[0])
    lat += e0.h2d
    eng += e0.h2d * pus[assignment[0]].power_memory
    for pos, (oi, p) in enumerate(zip(chain, assignment)):
        e = table.require(oi, p)
        lat += e.w
        eng += e.w * e.power
        if pos + 1 < len(chain):
            oj, pk = chain[pos + 1], assignment[pos + 1]
            tc = transition_cost(pus, table, oi, p, oj, pk)
            lat += tc
            eng += tc * pus[pk].power_memory
    eN = table.require(last, assignment[-1])
    lat += eN.d2h
    eng += eN.d2h * pus[assignment[-1]].power_memory
    return lat, eng


def single_pu_cost(
    chain: Sequence[int],
    pu: str,
    ops: Sequence[FusedOp],
    table: CostTable,
    pus: Mapping[str, PUSpec],
    workload: Workload | None = None,
) -> tuple[float, float] | None:
    """(latency, energy) of monolithic execution on one PU; None if any op
    is unsupported there (the paper's compile-failure case)."""
    wl = workload if workload is not None else Workload.build(
        chain, table, pus, ops=ops)
    return wl.single_pu(pu)


# ---------------------------------------------------------------------------
# Lossless (de)serialization of every schedule kind
# ---------------------------------------------------------------------------


AnySchedule = SeqSchedule | ParallelSchedule | ConcurrentSchedule | DagSchedule


def schedule_to_dict(s: AnySchedule) -> dict:
    """JSON-able dict of any schedule kind, tagged with ``"type"``.

    The inverse ``schedule_from_dict`` reconstructs an ``==``-equal
    schedule: every float survives a JSON round-trip bitwise and every
    tuple/list shape is restored exactly.
    """
    if isinstance(s, SeqSchedule):
        return {"type": "sequential", "chain": list(s.chain),
                "assignment": list(s.assignment), "latency": s.latency,
                "energy": s.energy, "objective": s.objective}
    if isinstance(s, ParallelSchedule):
        return {
            "type": "parallel", "latency": s.latency, "energy": s.energy,
            "objective": s.objective,
            "phases": [{
                "index": ph.index, "parallel": ph.parallel,
                "makespan": ph.makespan, "energy": ph.energy,
                "branches": [{
                    "branch_ops": list(b.branch_ops),
                    "assignment": list(b.assignment),
                    "solo_latency": b.solo_latency,
                    "adj_latency": b.adj_latency, "energy": b.energy,
                } for b in ph.branches],
            } for ph in s.phases],
        }
    if isinstance(s, ConcurrentSchedule):
        return {"type": "concurrent", "latency": s.latency,
                "energy": s.energy, "objective": s.objective, "mode": s.mode,
                "steps": [{"ops": list(st.ops), "pus": list(st.pus),
                           "cost": st.cost} for st in s.steps]}
    if isinstance(s, DagSchedule):
        return {"type": "dag", "latency": s.latency, "energy": s.energy,
                "objective": s.objective, "mode": s.mode,
                "steps": [{"ops": list(st.ops), "pus": list(st.pus),
                           "cost": st.cost} for st in s.steps]}
    raise TypeError(f"not a schedule: {type(s).__name__}")


def schedule_from_dict(d: Mapping) -> AnySchedule:
    """Rebuild the schedule serialized by :func:`schedule_to_dict`."""
    kind = d.get("type")
    if kind == "sequential":
        return SeqSchedule(chain=list(d["chain"]),
                           assignment=list(d["assignment"]),
                           latency=d["latency"], energy=d["energy"],
                           objective=d["objective"])
    if kind == "parallel":
        return ParallelSchedule(
            phases=[PhaseSchedule(
                index=ph["index"], parallel=ph["parallel"],
                makespan=ph["makespan"], energy=ph["energy"],
                branches=[BranchSchedule(
                    branch_ops=list(b["branch_ops"]),
                    assignment=list(b["assignment"]),
                    solo_latency=b["solo_latency"],
                    adj_latency=b["adj_latency"], energy=b["energy"],
                ) for b in ph["branches"]],
            ) for ph in d["phases"]],
            latency=d["latency"], energy=d["energy"],
            objective=d["objective"])
    if kind == "concurrent":
        return ConcurrentSchedule(
            steps=[ConcurrentStep(ops=tuple(st["ops"]), pus=tuple(st["pus"]),
                                  cost=st["cost"]) for st in d["steps"]],
            latency=d["latency"], energy=d["energy"],
            objective=d["objective"], mode=d["mode"])
    if kind == "dag":
        return DagSchedule(
            steps=[DagStep(ops=tuple(st["ops"]), pus=tuple(st["pus"]),
                           cost=st["cost"]) for st in d["steps"]],
            latency=d["latency"], energy=d["energy"],
            objective=d["objective"], mode=d["mode"])
    raise ValueError(f"unknown schedule type {kind!r}")
