"""Per-target health tracking and circuit breaking for degraded-mode
serving.

The planning layer trusts profiled costs; the fault runtime (PR 6)
recovers a *single* execution.  This module is the piece between them: a
per-PU :class:`HealthMonitor` that watches every real execution the
serving loop performs and decides when a target is *degrading* — before
it takes the whole serving set down with it.

Two independent detectors feed one actuator:

* **Consecutive-failure counting** — every failure attributable to a
  lane (an injected or real ``PULostError``, a watchdog timeout whose
  in-flight snapshot names the lane, a transient storm that exhausts the
  retry budget) bumps that lane's consecutive-failure counter; any
  success on the lane resets it.  Crossing
  ``HealthPolicy.failure_threshold`` opens the breaker.  A hard PU loss
  (:class:`~repro.core.errors.PULostError`) opens it immediately — there
  is no point counting a dead lane's failures.

* **EWMA latency-drift tracking** — each completed op contributes a
  measured-wall-clock / predicted-cost ratio to its lane's EWMA.  The
  first ``HealthPolicy.calibration`` observations establish the lane's
  baseline ratio (wall seconds per cost-model second is an arbitrary
  host-dependent constant — only *drift relative to the lane's own
  baseline* is meaningful, echoing the context-dependent operator-cost
  shifts measured for real NPUs).  When the EWMA exceeds ``baseline *
  rescale_threshold`` the monitor recommends a *rescale*: a
  ``RuntimeCondition.slowdown`` factor equal to the measured drift, so
  the planner re-prices the lane instead of abandoning it.  Hysteresis
  (``rescale_hysteresis``, plus a minimum relative change before a
  recommended factor is revised) keeps EWMA noise from thrashing the
  plan cache.

The actuator is the **circuit breaker** (per lane):

    closed ──(failures ≥ threshold, or PU loss)──▶ open
    open ──(cooldown elapsed on the serving clock)──▶ half_open
    half_open ──(probe dispatch succeeds)──▶ closed   (re-admit)
    half_open ──(probe dispatch fails)──▶ open        (cooldown × backoff)

``open`` lanes are folded into the session condition as unavailable
(:meth:`HealthMonitor.condition` composes with
``RuntimeCondition.lose``/``restore``), which makes
``Orchestrator.on_condition`` invalidate affected cached plans and the
serving loop warm-re-plan the entire active set on the survivors.
``half_open`` lanes re-enter the planning table; the next chunk that
actually dispatches to the lane is the probe.  The monitor never reads
the chaos script — re-admission happens only on *observed* success.

Every transition is recorded (:class:`BreakerTransition`) with its
serving-clock time and reason; ``ServeReport.breaker["transitions"]``
surfaces the list for availability accounting.
"""
from __future__ import annotations

import dataclasses

from .dynamic import RuntimeCondition

BREAKER_CLOSED = "closed"
BREAKER_OPEN = "open"
BREAKER_HALF_OPEN = "half_open"


@dataclasses.dataclass
class HealthPolicy:
    """Knobs of the per-target health state machine.

    ``cooldown`` is measured on the *serving clock* (the virtual-time
    axis arrivals live on), not wall clock — chaos scripts and probe
    scheduling then share one deterministic timeline.
    """

    failure_threshold: int = 2        # consecutive failures -> open
    cooldown: float = 0.5             # open -> half-open (serving-clock s)
    cooldown_backoff: float = 2.0     # cooldown multiplier per failed probe
    max_cooldown: float = 30.0        # cooldown growth cap
    ewma_alpha: float = 0.25          # drift EWMA smoothing factor
    calibration: int = 8              # observations forming the baseline
    rescale_threshold: float = 4.0    # EWMA/baseline ratio -> recommend
    rescale_hysteresis: float = 0.5   # drop rescale below thr * hysteresis
    rescale_min_change: float = 1.25  # relative change before re-recommending

    def __post_init__(self) -> None:
        if self.failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        if not (0.0 < self.ewma_alpha <= 1.0):
            raise ValueError("ewma_alpha must be in (0, 1]")
        if self.rescale_threshold <= 1.0:
            raise ValueError("rescale_threshold must be > 1")
        if self.cooldown < 0.0 or self.max_cooldown < self.cooldown:
            raise ValueError("need 0 <= cooldown <= max_cooldown")


@dataclasses.dataclass
class BreakerTransition:
    """One breaker state change (or drift-rescale event) on one lane."""

    time: float                       # serving-clock time
    pu: str
    frm: str
    to: str
    reason: str

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)


@dataclasses.dataclass
class TargetHealth:
    """Mutable health record of one PU lane."""

    pu: str
    state: str = BREAKER_CLOSED
    consecutive_failures: int = 0
    failures: int = 0                 # total attributed failures
    successes: int = 0                # total successfully completed ops
    opened_at: float | None = None    # serving-clock time of last open
    cooldown: float = 0.0             # current open->half_open wait
    n_obs: int = 0                    # drift observations so far
    baseline: float | None = None     # calibrated wall/predicted ratio
    ewma: float | None = None         # running wall/predicted EWMA
    rescale: float | None = None      # active recommended slowdown factor

    def drift(self) -> float | None:
        """EWMA ratio relative to the calibrated baseline (1.0 = on
        profile), or ``None`` before calibration completes."""
        if self.baseline is None or self.ewma is None or self.baseline <= 0:
            return None
        return self.ewma / self.baseline


class HealthMonitor:
    """Per-target health ledger + circuit breaker for a serving run.

    The serving loop feeds it observations (:meth:`observe` per completed
    op, :meth:`record_failure` / :meth:`record_loss` per attributed
    failure), polls :meth:`due_probes` at boundaries, reports probe
    outcomes via :meth:`probe_result`, and applies :meth:`condition` to
    the orchestrator whenever :meth:`dirty` says the health-derived view
    of the PU set changed.
    """

    def __init__(self, policy: HealthPolicy | None = None):
        self.policy = policy if policy is not None else HealthPolicy()
        self.targets: dict[str, TargetHealth] = {}
        self.transitions: list[BreakerTransition] = []
        self.opens = 0
        self.readmits = 0
        self.probes = 0
        self.rescales = 0
        self._dirty = False

    def health(self, pu: str) -> TargetHealth:
        th = self.targets.get(pu)
        if th is None:
            th = self.targets[pu] = TargetHealth(
                pu=pu, cooldown=self.policy.cooldown)
        return th

    def dirty(self) -> bool:
        """True once since the last call if the health-derived condition
        (open set or recommended rescales) changed."""
        d, self._dirty = self._dirty, False
        return d

    def _transition(self, th: TargetHealth, to: str, now: float,
                    reason: str) -> None:
        self.transitions.append(BreakerTransition(
            time=now, pu=th.pu, frm=th.state, to=to, reason=reason))
        th.state = to
        self._dirty = True

    # -- success / drift path ------------------------------------------------
    def observe(self, pu: str, predicted: float, measured: float,
                now: float) -> None:
        """Record one completed op on ``pu``: ``predicted`` cost-model
        seconds took ``measured`` wall seconds.  Success evidence (resets
        the consecutive-failure counter) plus one EWMA drift sample."""
        th = self.health(pu)
        th.successes += 1
        th.consecutive_failures = 0
        if predicted <= 0.0 or measured < 0.0:
            return
        p = self.policy
        ratio = measured / predicted
        th.ewma = ratio if th.ewma is None else (
            p.ewma_alpha * ratio + (1.0 - p.ewma_alpha) * th.ewma)
        th.n_obs += 1
        if th.n_obs == p.calibration:
            th.baseline = th.ewma
        if th.baseline is None:
            return
        drift = th.drift()
        if th.rescale is None:
            if drift is not None and drift >= p.rescale_threshold:
                th.rescale = drift
                self.rescales += 1
                self._dirty = True
                self.transitions.append(BreakerTransition(
                    time=now, pu=pu, frm=th.state, to=th.state,
                    reason=f"drift_rescale x{drift:.1f}"))
        else:
            if drift is None or drift < p.rescale_threshold * \
                    p.rescale_hysteresis:
                th.rescale = None
                self._dirty = True
                self.transitions.append(BreakerTransition(
                    time=now, pu=pu, frm=th.state, to=th.state,
                    reason="drift_recovered"))
            elif (drift / th.rescale >= p.rescale_min_change
                  or th.rescale / drift >= p.rescale_min_change):
                th.rescale = drift
                self._dirty = True

    # -- failure path --------------------------------------------------------
    def record_failure(self, pu: str, now: float,
                       reason: str = "failure") -> bool:
        """One failure attributed to ``pu``; returns True when this
        failure opened (or re-opened) the breaker."""
        th = self.health(pu)
        th.failures += 1
        th.consecutive_failures += 1
        if th.state == BREAKER_HALF_OPEN:
            self.probe_result(pu, ok=False, now=now, reason=reason)
            return True
        if th.state == BREAKER_CLOSED and \
                th.consecutive_failures >= self.policy.failure_threshold:
            self._open(th, now, reason)
            return True
        return False

    def record_loss(self, pu: str, now: float) -> None:
        """A hard PU loss: open immediately regardless of counters."""
        th = self.health(pu)
        th.failures += 1
        th.consecutive_failures += 1
        if th.state == BREAKER_HALF_OPEN:
            self.probe_result(pu, ok=False, now=now, reason="pu_lost")
        elif th.state != BREAKER_OPEN:
            self._open(th, now, "pu_lost")

    def _open(self, th: TargetHealth, now: float, reason: str) -> None:
        self.opens += 1
        th.opened_at = now
        self._transition(th, BREAKER_OPEN, now, reason)

    # -- probe scheduling ----------------------------------------------------
    def due_probes(self, now: float) -> list[str]:
        """Open lanes whose cooldown elapsed — flipped to half-open and
        returned; the caller re-admits them into the planning table so
        the next dispatching chunk becomes the probe."""
        due = []
        for th in self.targets.values():
            if th.state == BREAKER_OPEN and th.opened_at is not None \
                    and now - th.opened_at >= th.cooldown:
                self.probes += 1
                self._transition(th, BREAKER_HALF_OPEN, now, "cooldown")
                due.append(th.pu)
        return due

    def probe_result(self, pu: str, ok: bool, now: float,
                     reason: str = "") -> None:
        """Outcome of a half-open lane's probe dispatch: success closes
        the breaker (re-admission, cooldown reset); failure re-opens it
        with the cooldown grown by ``cooldown_backoff``."""
        th = self.health(pu)
        if th.state != BREAKER_HALF_OPEN:
            return
        if ok:
            self.readmits += 1
            th.consecutive_failures = 0
            th.cooldown = self.policy.cooldown
            th.opened_at = None
            self._transition(th, BREAKER_CLOSED, now, "probe_ok")
        else:
            self.opens += 1
            th.cooldown = min(th.cooldown * self.policy.cooldown_backoff,
                              self.policy.max_cooldown)
            th.opened_at = now
            self._transition(th, BREAKER_OPEN, now,
                             reason or "probe_failed")

    # -- condition synthesis -------------------------------------------------
    def quarantined(self) -> set[str]:
        """Lanes currently breaker-open (half-open lanes are back in the
        table — they are being probed)."""
        return {p for p, th in self.targets.items()
                if th.state == BREAKER_OPEN}

    def half_open(self) -> set[str]:
        return {p for p, th in self.targets.items()
                if th.state == BREAKER_HALF_OPEN}

    def condition(self, base: RuntimeCondition | None = None
                  ) -> RuntimeCondition:
        """The health-adjusted runtime condition: ``base`` (the session's
        externally-imposed condition) with breaker-open lanes folded
        unavailable and active drift rescales folded as slowdowns.
        Half-open lanes are restored so the planner can route the probe."""
        cond = base if base is not None else RuntimeCondition()
        slowdown = dict(cond.slowdown)
        for pu, th in self.targets.items():
            if th.rescale is not None and th.state == BREAKER_CLOSED:
                slowdown[pu] = th.rescale
            else:
                slowdown.pop(pu, None)
        unavailable = (frozenset(cond.unavailable) - self.half_open()) \
            | self.quarantined()
        return RuntimeCondition(slowdown=slowdown,
                                unavailable=frozenset(unavailable))

    # -- reporting -----------------------------------------------------------
    def stats(self) -> dict:
        """JSON-ready availability accounting for ``ServeReport``."""
        return {
            "opens": self.opens,
            "probes": self.probes,
            "readmits": self.readmits,
            "rescales": self.rescales,
            "quarantined": sorted(self.quarantined()),
            "half_open": sorted(self.half_open()),
            "targets": {
                pu: {"state": th.state, "failures": th.failures,
                     "successes": th.successes,
                     "consecutive_failures": th.consecutive_failures,
                     "drift": th.drift(), "rescale": th.rescale}
                for pu, th in sorted(self.targets.items())},
            "transitions": [t.to_dict() for t in self.transitions],
        }
