"""``Orchestrator`` — the session-style front door of BIDENT.

The solver library exposes one free function per regime with historically
grown signatures (``solve_sequential(chain, ops, table, pus, ...)`` vs
``solve_concurrent(workloads, cm)``); every example and benchmark had to
hand-assemble ``Workload``s, pair caches, and executors.  The orchestrator
wraps that into the register → plan → execute flow of a serving system:

    orch = Orchestrator(EdgeSoCCostModel(), pus=EDGE_PUS)
    h = orch.register(graph)              # profile + dense Workload, once
    plan = orch.plan(h)                   # routed solve, cached
    outputs = orch.execute(plan, inputs)  # multi-lane ScheduleExecutor

* ``register`` profiles the graph through the configured cost provider
  (or takes a prebuilt ``CostTable``) and memoizes the dense ``Workload``
  — the single scalar-dict ingestion pass.  Malformed inputs fail here
  with descriptive errors (empty graphs, unprofiled ops, unknown PUs).
* ``plan`` routes by shape: one chain handle → the sequential DP; one
  handle with ``Branch`` nodes (fork/join DAG) → the phase/branch
  parallel solve; one *disconnected* handle (a union of chains, which
  is no single sequence) → the DAG route; a tuple of handles → the
  M-ary concurrent search (``mode="aligned"`` opts a pair into the
  lockstep solver, ``mode="dag"`` forces the antichain-frontier front
  door :func:`~repro.core.search.solve_dag` for any single-handle
  shape).  Results come back as a uniform :class:`Plan` and are
  **bitwise identical** to the corresponding direct solver call — the
  free functions remain the stable low-level layer underneath.
* Plans are cached keyed by (workload signatures + progress, objective,
  resolved mode, runtime-condition scaling); the objective-independent
  solver state (``ConcurrentCaches`` holding ``PairCostCache``s / group
  edges) is shared across calls on the same workload tuple, so a
  latency + energy solve pair pays the 4-D pair-cost setup once and a
  repeated ``plan`` call is a dict hit.
* ``on_condition`` folds in a :class:`RuntimeCondition` (per-PU column
  scalings on the dense views).  Cached plans priced under a now-stale
  assumption about a changed PU are invalidated; handles admitted to the
  active set re-plan through their :class:`DynamicScheduler` from their
  current progress (hysteresis and plan stitching included).
* ``admit`` / ``retire`` maintain the online serving set: each call
  re-plans the concurrent schedule over every active request's
  *remaining* ops (``Workload.tail`` views), which is how requests
  arriving or completing mid-flight are absorbed.

Serving lifecycle (what :class:`~repro.core.serve.ServingEngine` drives)::

    h = orch.register(graph)     # once per model, profile + dense tables
    orch.admit(h)                #   arrival: join the concurrent set,
                                 #   re-plan the set from progress
    orch.advance(h, k)           #   execution progress: completed ops
    orch.replan_active(...)      #   plan-delta from the new frontier
    orch.retire(h)               #   departure: drop out, re-plan the rest

  Warm-start invariants of this loop:

  * Every re-plan is served by a per-(workload signatures, condition)
    :class:`~repro.core.search.IncrementalConcurrentSolver` when the
    route allows it (``algorithm="auto"``, default ``max_states``):
    persistent per-active-subset grid contexts plus the shared
    content-keyed ``ConcurrentCaches`` pool mean an admit/advance/retire
    event re-prices only subsets involving genuinely new content and
    re-sweeps only the remaining sub-box.  Warm plans are **bitwise
    identical** to a cold ``solve_concurrent`` on the same state — the
    cold solver stays the oracle (``tests/test_incremental_replan.py``);
    routes the warm layer cannot reproduce bitwise (custom contention
    laws, the pairwise fallback) fall back to the cold path.
    ``stats["replans_warm"]``/``stats["replans_cold"]`` count the split.
  * ``horizon_states`` (on ``admit``/``retire``/``replan_active``)
    bounds a re-plan to the next exact window
    (:func:`~repro.core.search.solve_concurrent_horizon`), making
    re-plan latency O(budget) instead of O(remaining grid) — the
    serving engine's bounded-admission-latency knob.
  * A condition change re-prices affected tables exactly once into the
    new condition's pool (content signatures change under
    ``under_condition``); subsequent re-plans under that condition are
    warm again.
  * ``admit``/``retire`` return ``None`` — not a ``Plan`` — when there
    is nothing left to schedule: every active request fully advanced
    (``admit``/``retire``) or the set emptied (``retire``).  The
    serving loop must treat ``None`` as "no schedule to run", never
    dereference it.
  * All session caches (``_plans``, ``_pools``, ``_cond_views``,
    ``_programs``, warm solvers) are insertion-ordered LRUs with hard
    capacity bounds; evictions are counted in ``stats`` so serving
    traffic with thousands of distinct keys degrades to re-solves, not
    unbounded memory.
* ``execute`` drives the multi-lane :class:`ScheduleExecutor` for any
  plan kind (sequential / parallel assignments, M-ary concurrent
  multiplexing) — through a compiled, segment-fused
  :class:`~repro.core.laneprogram.LaneProgram` by default (cached keyed
  by plan cache key + handles + input shapes/dtypes, mirroring the plan
  cache), with ``compile=False`` retaining the per-op interpreter as the
  bitwise-equivalence oracle.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Any, Mapping, Sequence

import numpy as np

from .contention import ContentionModel
from .costmodel import CostTable, EDGE_PUS, PUSpec
from .dynamic import DynamicScheduler, RuntimeCondition
from .errors import PULostError
from .executor import ScheduleExecutor
from .faults import ExecutionPolicy, FaultPlan
from .laneprogram import LaneProgram
from .op import FusedOp, OpGraph, chain_graph
from .targets import pu_specs_for_targets, resolve_targets
from .schedule import (ConcurrentSchedule, ConcurrentStep, DagSchedule,
                       ParallelSchedule,
                       SeqSchedule, schedule_from_dict, schedule_to_dict)
from .search import (ConcurrentCaches, DAG_ALGORITHMS,
                     IncrementalConcurrentSolver,
                     _pair_cache, solve_concurrent, solve_concurrent_aligned,
                     solve_concurrent_horizon, solve_dag, solve_parallel,
                     solve_sequential)
from .workload import Workload

PLAN_MODES = ("auto", "sequential", "parallel", "concurrent", "aligned",
              "dag")
# concurrent-search routes accepted by plan(algorithm=...); passed through
# to solve_concurrent verbatim ("astar"/"dijkstra" are pair-only spellings
# the low-level layer also accepts, but the front door keeps the M-ary set)
CONCURRENT_ALGORITHMS = ("auto", "grid", "grid_astar", "rolling", "pairwise")


@dataclasses.dataclass
class Plan:
    """Uniform result of ``Orchestrator.plan``: one schedule of any kind
    plus the routing metadata needed to execute or serialize it."""

    kind: str          # "sequential" | "parallel" | "concurrent" | "dag"
    schedule: (SeqSchedule | ParallelSchedule | ConcurrentSchedule
               | DagSchedule)
    objective: str
    handles: tuple[int, ...] = ()
    mode: str = ""            # resolved plan mode (e.g. "aligned")
    # the plan-cache key this plan was stored under (set by the
    # orchestrator; the compiled-execution program cache reuses it, so a
    # repeat execute() skips segment partitioning and compilation the
    # same way a repeat plan() skips the solve).  Not serialized:
    # restored plans fall back to a content hash.
    cache_key: tuple | None = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def latency(self) -> float:
        return self.schedule.latency

    @property
    def energy(self) -> float:
        return self.schedule.energy

    @property
    def route(self) -> list[list[tuple[int, str]]]:
        """Per-request ``[(op index, PU name), ...]`` in execution order —
        the one assignment shape shared by all three schedule kinds.  For
        parallel plans the order is phase-by-phase (phases are barriers),
        each branch's chain listed whole (branches within a phase
        co-execute, so any branch interleaving is valid).  For DAG plans
        the order is step-by-step (each step a precedence-valid advance,
        co-scheduled ops listed together)."""
        s = self.schedule
        if isinstance(s, SeqSchedule):
            return [list(zip(s.chain, s.assignment))]
        if isinstance(s, DagSchedule):
            return [[(o, p) for st in s.steps
                     for o, p in zip(st.ops, st.pus)]]
        if isinstance(s, ParallelSchedule):
            out: list[tuple[int, str]] = []
            for ph in s.phases:
                for br in ph.branches:
                    out.extend(zip(br.branch_ops, br.assignment))
            return [out]
        return [s.assignment_of(r) for r in range(s.n_requests)]

    def to_json(self) -> str:
        return json.dumps({"kind": self.kind, "objective": self.objective,
                           "handles": list(self.handles), "mode": self.mode,
                           "schedule": schedule_to_dict(self.schedule)})

    @classmethod
    def from_json(cls, s: str) -> "Plan":
        d = json.loads(s)
        return cls(kind=d["kind"], schedule=schedule_from_dict(d["schedule"]),
                   objective=d["objective"], handles=tuple(d["handles"]),
                   mode=d.get("mode", ""))


def _arg_signature(a) -> tuple:
    dt = getattr(a, "dtype", None)
    if dt is None:
        dt = np.asarray(a).dtype
    return (tuple(np.shape(a)), str(dt))


def _inputs_signature(inputs) -> tuple | None:
    """Hashable shapes/dtypes signature of ``execute`` inputs: one sorted
    ``(op, per-arg (shape, dtype))`` tuple per request mapping."""
    if inputs is None:
        return None

    def one(mapping) -> tuple:
        if mapping is None:
            return ()
        return tuple(sorted(
            (i, tuple(_arg_signature(a) for a in args))
            for i, args in mapping.items()))

    if isinstance(inputs, Mapping):
        return ("single", one(inputs))
    return ("multi", tuple(one(m) for m in inputs))


@dataclasses.dataclass
class _Registration:
    handle: int
    graph: OpGraph
    chain: list[int]
    table: CostTable
    wl: Workload
    sig: str          # Workload content signature (chain + dense arrays)
    struct_sig: str   # graph edge-structure hash (phases/branches)
    # the exact object the caller registered (an OpGraph or a bare op
    # sequence) — kept alive so the id()-keyed memo can never collide
    # with a recycled address of a freed object
    source: Any = None
    # lazily-built DAG workload (``Workload.from_graph`` — same dense
    # arrays as ``wl`` plus explicit predecessor sets).  Kept separate so
    # the preds-free ``wl``/``sig`` the chain/concurrent routes key their
    # caches by are untouched by DAG planning.
    dag_wl: Workload | None = None


class Orchestrator:
    """Session front door: register inference graphs once, plan under any
    objective/regime with caching, react to runtime conditions, and
    execute plans on the multi-lane executor.

    ``cost`` is the cost provider: an ``EdgeSoCCostModel``-like object
    (``build_table(graph)``), a profiler (``profile(graph)``), or a
    prebuilt ``CostTable`` applied to every registered graph (op indices
    must then match that table).

    ``targets`` binds PU lane names to registered execution
    :class:`~repro.core.targets.Target`\\ s (a ``{lane: Target}``
    mapping, a :class:`~repro.core.targets.TargetRegistry`, or an
    iterable of targets — one lane per target name).  When bound, the
    lanes are real backends instead of anonymous host threads: ``pus``
    defaults to the targets' synthesized specs
    (:func:`~repro.core.targets.pu_specs_for_targets`), the compiled
    execution path serves per-target payload variants
    (probe-verified — see :mod:`repro.core.laneprogram`), and a
    per-target :class:`MeasuredProfiler` can fill the cost table from
    real execution on each backend.  The interpreter path
    (``execute(compile=False)``) always runs the reference payloads.
    """

    def __init__(self, cost, pus: Mapping[str, PUSpec] | None = None,
                 contention: ContentionModel | None = None,
                 max_cached_plans: int = 256, max_cache_pools: int = 32,
                 max_cached_programs: int = 64, targets=None):
        if not (isinstance(cost, CostTable) or hasattr(cost, "build_table")
                or hasattr(cost, "profile")):
            raise TypeError(
                "cost must be a CostTable, a cost model with "
                "build_table(graph), or a profiler with profile(graph); "
                f"got {type(cost).__name__}")
        self.cost = cost
        self.targets = resolve_targets(targets)
        if pus is None:
            pus = (pu_specs_for_targets(self.targets)
                   if self.targets else EDGE_PUS)
        self.pus = dict(pus)
        if self.targets:
            unknown = sorted(set(self.targets) - set(self.pus))
            if unknown:
                raise ValueError(
                    f"target binding names lane(s) {unknown} absent from "
                    f"the PU set {sorted(self.pus)}")
        self.contention = contention or ContentionModel()
        self.executor = ScheduleExecutor(list(self.pus),
                                         targets=self.targets)
        self.condition = RuntimeCondition()
        self.stats = {"hits": 0, "misses": 0, "invalidated": 0,
                      "program_hits": 0, "program_misses": 0,
                      "recoveries": 0,
                      "replans_warm": 0, "replans_cold": 0,
                      "plan_evictions": 0, "pool_evictions": 0,
                      "cond_view_evictions": 0, "program_evictions": 0,
                      "warm_evictions": 0}
        self._max_plans = max_cached_plans
        self._max_pools = max_cache_pools
        self._max_programs = max_cached_programs
        self._programs: dict[tuple, LaneProgram] = {}  # insertion-ordered LRU
        self._regs: dict[int, _Registration] = {}
        self._by_graph: dict[int, int] = {}          # id(graph) -> handle
        self._plans: dict[tuple, Plan] = {}          # insertion-ordered LRU
        self._pools: dict[tuple, ConcurrentCaches] = {}
        self._cond_views: dict[tuple[int, tuple], Workload] = {}
        self._warm: dict[tuple, IncrementalConcurrentSolver] = {}
        self._active: dict[int, int] = {}            # handle -> ops done
        self._dyn: dict[tuple[int, str], DynamicScheduler] = {}

    def _evict_lru(self, cache: dict, cap: int, stat: str,
                   close: bool = False) -> None:
        """Drop oldest entries of an insertion-ordered LRU dict past
        ``cap``, counting them under ``stats[stat]``."""
        while len(cache) > cap:
            victim = cache.pop(next(iter(cache)))
            if close:
                victim.close()
            self.stats[stat] += 1

    def cache_stats(self) -> dict:
        """Bounded-cache pressure snapshot: the session's LRU eviction
        counters plus the live pools' ``ConcurrentCaches`` trim counters
        and current cache sizes.  ``ServeReport.cache`` surfaces the
        over-a-run delta of the counters so cache-pressure-induced
        serving slowdowns are visible in serving output, not just in
        ``orchestrator.stats``.  (Trim counters cover the *live* pools;
        a pool evicted whole takes its counts with it — the eviction
        itself shows up in ``pool_evictions``.)"""
        counters = {k: self.stats[k] for k in (
            "plan_evictions", "pool_evictions", "cond_view_evictions",
            "program_evictions", "warm_evictions", "invalidated")}
        trims = {"pair_trims": 0, "group_table_trims": 0,
                 "group_scope_trims": 0}
        for pool in self._pools.values():
            for k in trims:
                trims[k] += pool.stats[k]
        return {**counters, **trims,
                "sizes": {"plans": len(self._plans),
                          "pools": len(self._pools),
                          "cond_views": len(self._cond_views),
                          "warm_solvers": len(self._warm),
                          "programs": len(self._programs)}}

    # -- register -----------------------------------------------------------
    def register(self, graph: OpGraph | Sequence[FusedOp],
                 table: CostTable | None = None) -> int:
        """Profile ``graph`` (unless ``table`` is given) and build its
        dense ``Workload`` once; returns a handle for ``plan``/``admit``.

        Re-registering the same graph (or op-sequence) object without an
        explicit ``table`` returns the existing provider-profiled handle
        without re-profiling; explicitly-tabled registrations always get
        a fresh handle and never shadow the provider-profiled one.  A
        bare sequence of ``FusedOp``s is wrapped into a chain graph.
        """
        source = graph             # the object the caller handed us,
        memo_key = id(source)      # pre-wrapping
        explicit_table = table is not None
        if not explicit_table and memo_key in self._by_graph:
            return self._by_graph[memo_key]
        if not isinstance(graph, OpGraph):
            graph = chain_graph(list(graph))
        if not len(graph.ops):
            raise ValueError("register: the graph has no ops")
        if table is None:
            if isinstance(self.cost, CostTable):
                table = self.cost
            elif hasattr(self.cost, "build_table"):
                table = self.cost.build_table(graph)
            else:
                table = self.cost.profile(graph)
        chain = graph.topo_order()
        wl = Workload.build(chain, table, self.pus, ops=graph.ops)
        h = len(self._regs)
        struct_sig = hashlib.blake2b(repr(sorted(graph.edges)).encode(),
                                     digest_size=8).hexdigest()
        self._regs[h] = _Registration(handle=h, graph=graph, chain=chain,
                                      table=table, wl=wl,
                                      sig=wl.signature(),
                                      struct_sig=struct_sig, source=source)
        if not explicit_table:
            self._by_graph[memo_key] = h
        return h

    def workload(self, h: int) -> Workload:
        """The memoized dense Workload of a registered handle (nominal
        profile; conditions are applied per-plan, not destructively)."""
        return self._reg(h).wl

    def _reg(self, h: int) -> _Registration:
        try:
            return self._regs[h]
        except KeyError:
            raise KeyError(
                f"unknown handle {h!r}; register(graph) first "
                f"(valid handles: {sorted(self._regs)})") from None

    # -- runtime condition ---------------------------------------------------
    def _cond_key(self, cond: RuntimeCondition | None = None) -> tuple:
        return (cond if cond is not None else self.condition).key(self.pus)

    def _wl(self, reg: _Registration) -> Workload:
        """Registration workload under the active condition (memoized
        derived view; the nominal workload itself when no condition)."""
        if self.condition.nominal:
            return reg.wl
        key = (reg.handle, self._cond_key())
        wl = self._cond_views.get(key)
        if wl is None:
            wl = reg.wl.under_condition(self.condition.slowdown,
                                        self.condition.unavailable)
            self._cond_views[key] = wl
            self._evict_lru(self._cond_views, self._max_pools,
                            "cond_view_evictions")
        else:
            self._cond_views[key] = self._cond_views.pop(key)  # LRU refresh
        return wl

    def _dag_wl(self, reg: _Registration) -> Workload:
        """Registration DAG workload (``Workload.from_graph``, built
        lazily) under the active condition.  ``under_condition`` carries
        the predecessor sets, so the derived view keeps its DAG shape;
        views share the ``_cond_views`` LRU under a dag-tagged key."""
        if reg.dag_wl is None:
            reg.dag_wl = Workload.from_graph(reg.graph, reg.table, self.pus)
        if self.condition.nominal:
            return reg.dag_wl
        key = ((reg.handle, "dag"), self._cond_key())
        wl = self._cond_views.get(key)
        if wl is None:
            wl = reg.dag_wl.under_condition(self.condition.slowdown,
                                            self.condition.unavailable)
            self._cond_views[key] = wl
            self._evict_lru(self._cond_views, self._max_pools,
                            "cond_view_evictions")
        else:
            self._cond_views[key] = self._cond_views.pop(key)  # LRU refresh
        return wl

    def on_condition(self, cond: RuntimeCondition
                     ) -> dict[tuple[int, str], Plan]:
        """Fold a runtime condition into the session.

        Cached plans and solver pools are invalidated *per changed PU*:
        any entry priced under an assumption about a changed PU that
        disagrees with the new condition is dropped, because it no longer
        describes the hardware (keys fully encode the condition, so this
        is staleness hygiene, not hit-correctness — a condition change
        deliberately costs a cold solve for the affected plans; entries
        that already agree with the new factors on every changed PU
        survive).  Active chain handles re-plan through their
        ``DynamicScheduler`` trackers from current progress — hysteresis
        and prefix/tail stitching apply — and the re-stitched sequential
        plans are returned keyed by ``(handle, objective)``, one entry
        per tracker (a latency-objective tracker is created for active
        chain handles that have none).

        PU names the session doesn't know are rejected loudly — a typo'd
        ``slowdown`` key would otherwise silently leave the real PU
        unthrottled in every re-plan.
        """
        unknown = sorted(p for p in set(cond.slowdown) | set(cond.unavailable)
                         if p not in self.pus)
        if unknown:
            raise ValueError(
                f"on_condition: unknown PU name(s) {unknown}; this "
                f"session's PUs are {sorted(self.pus)}")
        old, new = self._cond_key(), self._cond_key(cond)
        changed = {p for (p, f0), (_, f1) in zip(old, new) if f0 != f1}
        if changed:
            new_f = dict(new)
            for cache in (self._plans, self._pools, self._cond_views,
                          self._warm):
                for key in list(cache):
                    entry_cond = key[-1]
                    if any(p in changed and f != new_f[p]
                           for p, f in entry_cond):
                        del cache[key]
                        if cache is self._plans:
                            self.stats["invalidated"] += 1
        self.condition = cond
        out: dict[tuple[int, str], Plan] = {}
        for h, progress in self._active.items():
            reg = self._regs[h]
            if not reg.graph.is_chain():
                continue
            if not any(dh == h for dh, _ in self._dyn):
                self.dynamic(h)        # default latency-objective tracker
            for (dh, objective), dyn in list(self._dyn.items()):
                if dh != h:
                    continue
                sched = dyn.on_condition(progress, cond)
                out[(h, objective)] = Plan(kind="sequential", schedule=sched,
                                           objective=objective, handles=(h,),
                                           mode="sequential")
        return out

    def dynamic(self, h: int, objective: str = "latency",
                replan_threshold: float = 0.05) -> DynamicScheduler:
        """The handle's ``DynamicScheduler`` (created lazily, sharing the
        memoized workload); ``on_condition`` re-plans through it."""
        reg = self._reg(h)
        if not reg.graph.is_chain():
            raise ValueError(
                f"handle {h}: dynamic re-planning needs a chain graph "
                "(the DAG regimes re-plan via plan() under a condition)")
        key = (h, objective)
        dyn = self._dyn.get(key)
        if dyn is None:
            dyn = DynamicScheduler(reg.chain, reg.graph.ops, reg.table,
                                   self.pus, objective,
                                   replan_threshold=replan_threshold,
                                   workload=reg.wl)
            self._dyn[key] = dyn
        return dyn

    # -- plan ---------------------------------------------------------------
    def plan(self, handles: int | Sequence[int], objective: str = "latency",
             mode: str = "auto", algorithm: str = "auto",
             max_states: int | None = None) -> Plan:
        """Solve (or serve from cache) a schedule for one or more handles.

        ``mode="auto"`` routes a single chain handle to the sequential
        DP, a single fork/join handle (``Branch`` nodes present) to the
        phase/branch parallel solve, a single *disconnected* handle (a
        union of chains — degree-wise a "chain" but not one schedulable
        as a single sequence) to the DAG route, and multiple handles to
        the M-ary concurrent search; ``"aligned"`` forces the lockstep
        pair solver for exactly two handles; ``"dag"`` forces the
        antichain-frontier front door
        (:func:`~repro.core.search.solve_dag`) for any single-handle
        graph shape.  Results are bitwise identical to the corresponding
        direct solver call on the same workloads.

        ``algorithm`` and ``max_states`` are route knobs passed through
        verbatim: for concurrent plans the
        :func:`~repro.core.search.solve_concurrent` set (exact
        vectorized ``"grid"`` sweep, retained ``"grid_astar"`` heap
        oracle, ``"rolling"`` horizon merge, ``"pairwise"`` fallback),
        for DAG plans the :func:`~repro.core.search.solve_dag` set
        (``"chain"`` / ``"union-grid"`` / ``"phase"`` oracles and the
        ``"frontier"`` generalization); ``max_states`` bounds the
        exact-solve grid / discovered order ideals.  Both are part of
        the plan-cache key, so a forced route can never be served
        another route's cached schedule; they are rejected for modes
        without such knobs rather than silently ignored.
        """
        hs = (handles,) if isinstance(handles, int) else tuple(handles)
        if not hs:
            raise ValueError("plan: no handles given")
        regs = [self._reg(h) for h in hs]
        if mode not in PLAN_MODES:
            raise ValueError(f"unknown mode {mode!r}; one of {PLAN_MODES}")
        if max_states is not None and max_states < 1:
            raise ValueError(f"max_states must be >= 1, got {max_states}")
        if mode == "auto":
            if len(hs) > 1:
                mode = "concurrent"
            elif not regs[0].graph.is_chain():
                mode = "parallel"
            elif len(regs[0].graph.components()) > 1:
                # degree-wise a "chain" but disconnected: a union of
                # chains has no single sequence to DP over — route it to
                # the DAG front door (union-grid co-scheduling)
                mode = "dag"
            else:
                mode = "sequential"
        allowed = (DAG_ALGORITHMS if mode == "dag"
                   else CONCURRENT_ALGORITHMS)
        if algorithm not in allowed:
            raise ValueError(f"unknown algorithm {algorithm!r}; one of "
                             f"{allowed} for mode={mode!r}")
        if mode in ("sequential", "parallel", "dag") and len(hs) != 1:
            raise ValueError(
                f"mode={mode!r} plans one handle, got {len(hs)}")
        if mode == "aligned" and len(hs) != 2:
            raise ValueError(
                f"mode='aligned' is the lockstep pair solver, got "
                f"{len(hs)} handle(s)")
        if algorithm != "auto" or max_states is not None:
            if mode not in ("concurrent", "dag"):
                raise ValueError(
                    "algorithm=/max_states= are knobs of the M-ary "
                    "concurrent search and the DAG route; this plan "
                    f"resolved to mode={mode!r}")
            if mode == "concurrent" and len(hs) == 1:
                raise ValueError(
                    "algorithm=/max_states= route the M >= 2 concurrent "
                    "search; a single-request concurrent plan is a solo "
                    "best-PU walk with nothing to route")
        return self._plan_cached(
            [(reg, 0) for reg in regs], hs, objective, mode,
            algorithm, max_states)

    def _plan_cached(self, regs_progress: list[tuple[_Registration, int]],
                     hs: tuple[int, ...], objective: str, mode: str,
                     algorithm: str = "auto",
                     max_states: int | None = None,
                     horizon_states: int | None = None) -> Plan:
        # the sequential/concurrent solvers consume only the chain + dense
        # cost views (covered by the workload signature); the parallel and
        # DAG solves additionally consume the graph's edge structure
        # (phases/branches — predecessor sets), so their keys must
        # include the structure hash
        if mode in ("parallel", "dag"):
            wl_key = tuple((reg.sig, reg.struct_sig, prog)
                           for reg, prog in regs_progress)
        else:
            wl_key = tuple((reg.sig, prog) for reg, prog in regs_progress)
        # algorithm/max_states/horizon_states are in the key: a
        # forced-pairwise plan must never be served a cached grid
        # schedule, nor a full plan a cached horizon window (and vice
        # versa).  The condition stays the LAST element — on_condition
        # invalidates by key[-1].
        key = (wl_key, objective, mode, algorithm, max_states,
               horizon_states, self._cond_key())
        plan = self._plans.get(key)
        if plan is not None:
            self.stats["hits"] += 1
            self._plans[key] = self._plans.pop(key)   # LRU refresh
            if plan.handles != hs:
                # equal signatures make the *schedule* shareable, but the
                # handles must be the caller's — execute() resolves graphs
                # (and their op payloads) through them
                plan = dataclasses.replace(plan, handles=hs)
            return plan
        self.stats["misses"] += 1
        plan = self._solve(regs_progress, hs, objective, mode,
                           algorithm, max_states, horizon_states)
        plan.cache_key = key
        self._plans[key] = plan
        self._evict_lru(self._plans, self._max_plans, "plan_evictions")
        return plan

    def _pool(self) -> ConcurrentCaches:
        """Objective-independent solver state (pair-cost matrices, group
        edge tables) shared across every concurrent solve under the same
        condition.  One pool per condition — NOT per workload tuple:
        ``ConcurrentCaches`` keys everything by content signature, so
        overlapping handle sets, re-admitted models and tail re-plans
        all hit the same tables.  (A pool must never span conditions:
        condition-scaled workloads get new signatures, so a per-condition
        pool is re-priced exactly once per change.)"""
        key = (self._cond_key(),)    # condition last: on_condition reads it
        pool = self._pools.get(key)
        if pool is None:
            pool = ConcurrentCaches()
            self._pools[key] = pool
            self._evict_lru(self._pools, self._max_pools, "pool_evictions")
        else:
            self._pools[key] = self._pools.pop(key)   # LRU refresh
        return pool

    def _warm_solver(self, wls: list[Workload]
                     ) -> IncrementalConcurrentSolver:
        """Memoized warm re-planner for a (full-workload signatures,
        condition) tuple, sharing the per-condition cache pool with the
        cold path — cold solves warm the pool for later warm solves and
        vice versa."""
        key = (tuple(wl.signature() for wl in wls), self._cond_key())
        inc = self._warm.get(key)
        if inc is None:
            inc = IncrementalConcurrentSolver(wls, self.contention,
                                              caches=self._pool())
            self._warm[key] = inc
            self._evict_lru(self._warm, self._max_pools, "warm_evictions")
        else:
            self._warm[key] = self._warm.pop(key)     # LRU refresh
        return inc

    def _solve(self, regs_progress: list[tuple[_Registration, int]],
               hs: tuple[int, ...], objective: str, mode: str,
               algorithm: str = "auto",
               max_states: int | None = None,
               horizon_states: int | None = None) -> Plan:
        nominal = self.condition.nominal
        wls_full = [self._wl(reg) for reg, _ in regs_progress]
        wls = [wl if prog == 0 else wl.tail(prog)
               for wl, (_, prog) in zip(wls_full, regs_progress)]
        if mode == "sequential":
            reg, wl = regs_progress[0][0], wls[0]
            sched = solve_sequential(
                wl.chain, reg.graph.ops, reg.table if nominal else None,
                self.pus, objective, workload=wl)
            return Plan("sequential", sched, objective, hs, mode)
        if mode == "parallel":
            reg, wl = regs_progress[0][0], wls[0]
            sched = solve_parallel(
                reg.graph, reg.table if nominal else None, self.pus,
                self.contention, objective, workload=wl)
            return Plan("parallel", sched, objective, hs, mode)
        if mode == "dag":
            # DAG plans always cover the whole graph (progress tails drop
            # predecessor sets; recovery re-plans from 0 and skips the
            # completed frontier at execution time, like parallel plans)
            reg = regs_progress[0][0]
            sched = solve_dag(
                reg.graph, reg.table if nominal else None, self.pus,
                self.contention, objective, algorithm=algorithm,
                workload=self._dag_wl(reg), caches=self._pool(),
                max_states=max_states)
            return Plan("dag", sched, objective, hs, mode)
        pool = self._pool()
        if mode == "aligned":
            w0, w1 = wls
            cache = _pair_cache(pool, self.contention, wls, 0, 1)
            sched = solve_concurrent_aligned(
                w0.chain, w0.table, w1.chain, w1.table, self.pus,
                self.contention, objective, dense0=w0.dense,
                dense1=w1.dense, cache=cache)
            return Plan("concurrent", sched, objective, hs, mode)
        if algorithm == "auto" and max_states is None:
            # warm fast path: persistent per-tuple incremental solver
            # (bitwise-identical to the cold routes below; returns None
            # on routes it cannot reproduce bitwise)
            inc = self._warm_solver(wls_full)
            sched = inc.solve([prog for _, prog in regs_progress],
                              objective, horizon_states=horizon_states)
            if sched is not None:
                self.stats["replans_warm"] += 1
                return Plan("concurrent", sched, objective, hs, mode)
        self.stats["replans_cold"] += 1
        if horizon_states is not None:
            sched = solve_concurrent_horizon(
                wls, self.contention, objective, caches=pool,
                horizon_states=horizon_states)
            return Plan("concurrent", sched, objective, hs, mode)
        kw = {} if max_states is None else {"max_states": max_states}
        sched = solve_concurrent(wls, self.contention, objective,
                                 algorithm=algorithm, caches=pool, **kw)
        return Plan("concurrent", sched, objective, hs, mode)

    # -- online admission (the serving scenario) ----------------------------
    def admit(self, h: int, objective: str = "latency",
              horizon_states: int | None = None) -> Plan | None:
        """Admit a registered request into the active concurrent set and
        re-plan the set from every member's current progress — the
        request-arriving-mid-flight case.

        **``None`` contract**: returns ``None`` — never a ``Plan`` —
        exactly when no active request (including the admitted one) has
        remaining ops, i.e. everything is already fully advanced.  With
        at least one unfinished active request the return value is
        always a ``Plan``; callers in a serving loop must branch on
        ``None`` rather than assume a schedule exists.

        ``horizon_states`` bounds the re-plan to the next exact window
        (see :meth:`replan_active`)."""
        self._reg(h)
        self._active.setdefault(h, 0)
        return self._replan_active(objective, horizon_states)

    def retire(self, h: int, objective: str = "latency",
               horizon_states: int | None = None) -> Plan | None:
        """Remove a request from the active set (completed or cancelled)
        and re-plan the remainder.

        **``None`` contract**: returns ``None`` — never a ``Plan`` —
        exactly when there is nothing left to schedule: the active set
        emptied, or every remaining member is fully advanced.
        Otherwise always a ``Plan``.  Unknown handles raise ``KeyError``
        (retiring is a bookkeeping claim about a specific admitted
        request)."""
        if h not in self._active:
            raise KeyError(f"handle {h} is not in the active set "
                           f"({sorted(self._active)})")
        del self._active[h]
        if not self._active:
            return None
        return self._replan_active(objective, horizon_states)

    def advance(self, h: int, n_ops: int = 1) -> int:
        """Record execution progress (completed op count) for an active
        request; the next re-plan covers only the remaining tail."""
        if h not in self._active:
            raise KeyError(f"handle {h} is not in the active set")
        if n_ops < 0:
            raise ValueError(f"advance: n_ops must be >= 0, got {n_ops}")
        reg = self._regs[h]
        self._active[h] = min(self._active[h] + n_ops, reg.wl.n)
        return self._active[h]

    def replan_active(self, objective: str = "latency",
                      horizon_states: int | None = None) -> Plan | None:
        """Re-plan the active concurrent set from every member's current
        progress without changing membership — the advance-driven
        re-plan of the serving loop.  Served warm by the incremental
        solver whenever possible (``stats["replans_warm"]``).

        With ``horizon_states`` the plan covers only the next exact
        window of ``<= horizon_states`` grid states
        (:func:`~repro.core.search.solve_concurrent_horizon`,
        ``schedule.mode == "horizon"``): re-plan latency becomes
        O(budget) regardless of remaining work, and the caller re-plans
        again at the window frontier.  Returns ``None`` exactly when no
        active request has remaining ops."""
        return self._replan_active(objective, horizon_states)

    def _replan_active(self, objective: str,
                       horizon_states: int | None = None) -> Plan | None:
        items = [(h, p) for h, p in sorted(self._active.items())
                 if p < self._regs[h].wl.n]
        if not items:
            return None
        regs_progress = [(self._regs[h], p) for h, p in items]
        return self._plan_cached(regs_progress, tuple(h for h, _ in items),
                                 objective, "concurrent",
                                 horizon_states=horizon_states)

    # -- execute ------------------------------------------------------------
    def execute(self, plan: Plan, inputs=None, *, compile: bool = True,
                policy: ExecutionPolicy | None = None,
                faults: FaultPlan | None = None,
                recover: bool = True) -> Any:
        """Run a plan on the multi-lane executor.

        Sequential/parallel plans take one ``{op: (args...)}`` mapping
        and return that graph's results dict; concurrent plans take a
        sequence of such mappings (one per request, in handle order) and
        return a list of results dicts.  Partial plans (admission tails)
        cannot be executed — re-plan from progress 0 first.

        By default execution goes through the **compiled lane program**
        (``program_for``): per-op closure dispatch and event churn
        collapse into segment-fused callables (jitted where bitwise-safe)
        with handoff events only at cross-lane cuts, and the program is
        cached keyed by (plan cache key, handles, input shapes/dtypes) so
        a repeat ``execute`` skips partitioning and compilation like a
        repeat ``plan`` skips the solve.  Op payloads must be pure on
        this path (compile verification replays them on probe and
        perturbed inputs); ``compile=False`` runs the per-op interpreter
        instead — the bitwise-equivalence oracle, and the right path for
        stateful or side-effecting payloads.

        Execution runs under the fault runtime of
        :mod:`repro.core.faults`: ``policy`` tunes the watchdog/retry
        knobs (the watchdog budget scales with the plan's cost-model
        latency) and ``faults`` injects a scripted
        :class:`~repro.core.faults.FaultPlan`.  With ``recover=True``
        (the default) a permanent mid-run PU loss is handled here: the
        loss is folded into the session condition
        (:meth:`on_condition` — invalidating stale cached plans), the
        *remaining* ops are re-planned onto the surviving PUs, and
        execution resumes from the frontier of completed results —
        recovered outputs are bitwise identical to the fault-free run
        (completed results are reused; the remaining pure payloads
        compute the same values on any lane).  ``recover=False``
        propagates the :class:`~repro.core.errors.PULostError` (frontier
        attached as ``err.partial``) to the caller.
        """
        try:
            return self._execute_once(plan, inputs, compile, policy, faults)
        except PULostError as err:
            if not recover:
                raise
            return self._recover(plan, inputs, err, policy, faults)

    def _execute_once(self, plan: Plan, inputs, compile: bool,
                      policy: ExecutionPolicy | None,
                      faults: FaultPlan | None) -> Any:
        if not compile:
            regs = self._execute_regs(plan, validate=True)
            graphs = [reg.graph for reg in regs]
            if plan.kind == "dag":
                return self.executor.run_dag(
                    graphs[0], plan.schedule, inputs,
                    policy=policy, faults=faults, estimate=plan.latency)
            if plan.kind in ("sequential", "parallel"):
                return self.executor.run_scheduled(
                    graphs[0], plan.schedule, inputs,
                    policy=policy, faults=faults, estimate=plan.latency)
            return self.executor.run_concurrent(
                graphs, plan.schedule, inputs,
                policy=policy, faults=faults, estimate=plan.latency)
        return self.program_for(plan, inputs).run(
            inputs, policy=policy, faults=faults, estimate=plan.latency)

    # -- mid-run recovery ---------------------------------------------------
    @staticmethod
    def _chain_progress(chain: Sequence[int],
                        done: Mapping[int, Any]) -> int:
        """Completed-prefix length of a chain under a frontier (results
        record in chain order, so the frontier is always a prefix)."""
        k = 0
        while k < len(chain) and chain[k] in done:
            k += 1
        return k

    def _recover(self, plan: Plan, inputs, err: PULostError,
                 policy: ExecutionPolicy | None,
                 faults: FaultPlan | None) -> Any:
        """Re-plan-and-resume after a permanent mid-run PU loss.

        Folds each lost PU into the session :class:`RuntimeCondition`
        (``on_condition`` invalidates cached plans priced with it and
        re-stitches active trackers), re-plans the ops still missing
        from the frontier onto the surviving PUs, and resumes on the
        interpreter path seeded with the completed results.  Loops if
        another PU dies during the resume; raises
        :class:`~repro.core.errors.InfeasibleScheduleError` when no
        surviving PU can run a remaining op, and re-raises the loss when
        it carries no usable PU identity.
        """
        m = len(plan.handles)
        partials: list[dict[int, Any]] = [{} for _ in range(m)]
        lost_seen: set[str] = set()
        while True:
            if err.pu is None or err.pu in lost_seen:
                raise err   # no identity to exclude / no progress possible
            lost_seen.add(err.pu)
            for d, p in zip(partials, err.partial or []):
                d.update(p)
            self.on_condition(self.condition.lose(err.pu))
            self.stats["recoveries"] += 1
            try:
                return self._resume(plan, inputs, partials, policy, faults)
            except PULostError as e2:
                err = e2

    def _resume(self, plan: Plan, inputs,
                partials: list[dict[int, Any]],
                policy: ExecutionPolicy | None,
                faults: FaultPlan | None) -> Any:
        """Re-plan the non-frontier ops under the current (degraded)
        condition and run them on the interpreter path, seeded with the
        frontier results."""
        regs = self._execute_regs(plan, validate=True)
        graphs = [reg.graph for reg in regs]
        objective = plan.objective

        if plan.kind == "parallel":
            # branch/phase structure is condition-independent: re-plan the
            # whole DAG under the degraded condition; the frontier seed
            # skips every already-completed op at execution time
            sub = self._plan_cached([(regs[0], 0)], plan.handles, objective,
                                    "parallel")
            return self.executor.run_scheduled(
                graphs[0], sub.schedule, inputs, policy=policy,
                faults=faults, completed=partials[0],
                estimate=sub.latency)

        if plan.kind == "dag":
            # same shape as parallel: precedence structure survives the
            # condition change, so re-plan the whole DAG onto the
            # surviving PUs and let the lane queues skip the frontier
            sub = self._plan_cached([(regs[0], 0)], plan.handles, objective,
                                    "dag")
            return self.executor.run_dag(
                graphs[0], sub.schedule, inputs, policy=policy,
                faults=faults, completed=partials[0],
                estimate=sub.latency)

        if plan.kind == "sequential":
            done = partials[0]
            prog = self._chain_progress(regs[0].chain, done)
            if prog == len(regs[0].chain):
                return dict(done)          # the loss hit after the last op
            sub = self._plan_cached([(regs[0], prog)], plan.handles,
                                    objective, "sequential")
            amap = dict(zip(sub.schedule.chain, sub.schedule.assignment))
            return self.executor.run_scheduled(
                graphs[0], amap, inputs, policy=policy, faults=faults,
                completed=done, estimate=sub.latency)

        # concurrent: re-plan only the requests with remaining ops, then
        # widen the sub-schedule back to all M request slots
        items = [(r, reg, self._chain_progress(reg.chain, partials[r]))
                 for r, reg in enumerate(regs)]
        remaining = [(r, reg, prog) for r, reg, prog in items
                     if prog < len(reg.chain)]
        if not remaining:
            return [dict(d) for d in partials]
        sub = self._plan_cached(
            [(reg, prog) for _, reg, prog in remaining],
            tuple(plan.handles[r] for r, _, _ in remaining),
            objective, "concurrent")
        slot = {k: r for k, (r, _, _) in enumerate(remaining)}

        def widen(vals: tuple) -> tuple:
            out: list = [None] * len(regs)
            for k, v in enumerate(vals):
                out[slot[k]] = v
            return tuple(out)

        ssched = sub.schedule
        full = ConcurrentSchedule(
            steps=[ConcurrentStep(ops=widen(st.ops), pus=widen(st.pus),
                                  cost=st.cost) for st in ssched.steps],
            latency=ssched.latency, energy=ssched.energy,
            objective=ssched.objective, mode=ssched.mode)
        return self.executor.run_concurrent(
            graphs, full, inputs, policy=policy, faults=faults,
            completed=partials, estimate=full.latency)

    def program_for(self, plan: Plan, inputs=None) -> LaneProgram:
        """The compiled :class:`LaneProgram` for a plan (cached).

        The cache key is (plan cache key — or a content hash for plans
        restored from JSON —, the plan's handles, and the shapes/dtypes
        of ``inputs``): equal-signature plans re-bound to different
        handles compile separately (their op payloads differ), and a
        shape change recompiles rather than silently retracing inside a
        shared program.
        """
        key = (self._plan_token(plan), plan.handles,
               _inputs_signature(inputs))
        prog = self._programs.get(key)
        if prog is not None:
            if prog.payloads_current():
                self.stats["program_hits"] += 1
                self._programs[key] = self._programs.pop(key)  # LRU refresh
                return prog
            # an op.fn was rebound after compilation: the baked fused
            # callables are stale — drop and recompile, never serve them
            self._programs.pop(key).close()
        self.stats["program_misses"] += 1
        # plan/handle validation runs on the miss path only: a cached
        # program was already validated at compile time, and the hit path
        # is the warm fast path the overhead gate measures
        regs = self._execute_regs(plan, validate=True)
        graphs = [reg.graph for reg in regs]
        if plan.kind == "dag":
            prog = self.executor.compile_dag(graphs[0], plan.schedule)
        elif plan.kind in ("sequential", "parallel"):
            prog = self.executor.compile_scheduled(graphs[0], plan.schedule)
        else:
            prog = self.executor.compile_concurrent(graphs, plan.schedule)
        self._programs[key] = prog
        self._evict_lru(self._programs, self._max_programs,
                        "program_evictions", close=True)
        return prog

    def _execute_regs(self, plan: Plan,
                      validate: bool = False) -> list[_Registration]:
        if not plan.handles:
            raise ValueError("plan carries no handles; was it built by "
                             "this orchestrator (or restored from JSON "
                             "with handles intact)?")
        regs = [self._reg(h) for h in plan.handles]
        if not validate:
            return regs
        # a stale/re-registered plan must fail here with the handle named,
        # not deep inside lane-queue construction
        routes = plan.route
        if len(routes) != len(regs):
            raise ValueError(
                f"plan routes {len(routes)} request(s) but carries "
                f"{len(regs)} handle(s) {plan.handles} — the plan does not "
                "match this orchestrator's registrations")
        for reg, route in zip(regs, routes):
            n = len(reg.graph.ops)
            bad = [i for i, _ in route if not 0 <= i < n]
            if bad:
                raise ValueError(
                    f"plan does not match handle {reg.handle}: it routes "
                    f"op {bad[0]} but the graph registered under that "
                    f"handle has {n} op(s) — the plan is stale (was the "
                    "workload re-registered, or the plan built against a "
                    "different orchestrator?)")
            unknown = sorted({p for _, p in route if p not in self.pus})
            if unknown:
                raise ValueError(
                    f"plan for handle {reg.handle} routes ops to unknown "
                    f"PU(s) {unknown}; this session's PUs are "
                    f"{sorted(self.pus)}")
        return regs

    def _plan_token(self, plan: Plan):
        if plan.cache_key is None:
            # JSON-restored / hand-built plan: memoize the content hash
            # on the plan so repeat executes stay O(1) like
            # orchestrator-built plans
            plan.cache_key = ("content", hashlib.blake2b(
                plan.to_json().encode(), digest_size=16).hexdigest())
        return plan.cache_key
