"""Execution targets: named backends behind the PU lanes.

Before this layer, a PU lane was an anonymous host thread priced by an
analytic cost model — the profile → plan → execute → measure loop never
closed on anything that actually executes differently per PU.  A
:class:`Target` closes it: a *data* declaration of how a lane executes —

* which JAX device the payloads are placed on (``device``),
* whether the fused segment is ``jax.jit``-ed or runs eagerly (``jit``),
* which entry of an op's variant table is served (``dialect``; ``"ref"``
  is the op's own ``fn``, the oracle payload), and
* how the planner should price its dispatch and cross-lane handoffs
  (``dispatch_s``, ``handoff_s``, ``is_accelerator``).

Adding a backend is registering one more ``Target`` value — no executor
or planner code changes (the MATCH-style pluggable-target shape, arXiv
2409.18566).  :class:`TargetRegistry` holds them by name;
``backends.default_registry()`` provides the builtin set (`numpy-eager`,
`xla-cpu`, `pallas-interpret`, plus one auto-discovered target per real
``jax.devices()`` entry) and ``Orchestrator(targets=...)`` binds lane
names to registered targets.

Verification contract (mirrors the PR 5 jit-probe): a non-``ref``
dialect variant is served by the compiled path only after a cold-run
probe against the reference composition — **bitwise**-gated where the
probe passes exactly, else tolerance-gated per output dtype
(:func:`variant_tolerance`), else rejected back to the reference
payload.  The per-op interpreter never reads variant tables: it stays
the single-variant oracle.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Iterable, Mapping

import numpy as np

from .costmodel import PUSpec

# Per-dtype (atol, rtol) used when a variant's probe is not bitwise equal
# to the reference composition.  Buckets follow tests/test_kernels.py: the
# Pallas kernels reorder float accumulation blockwise, so f32 variants
# land within ~1e-4 of the jnp oracle and bf16 within ~5e-2.  Non-float
# outputs get (0, 0): integer/bool variants must be bitwise.
VARIANT_TOL: dict[str, tuple[float, float]] = {
    "float64": (1e-9, 1e-9),
    "float32": (3e-4, 3e-4),
    "float16": (2e-2, 2e-2),
    "bfloat16": (5e-2, 5e-2),
}


def variant_tolerance(dtype: Any) -> tuple[float, float]:
    """(atol, rtol) bucket for comparing a variant output of ``dtype``
    against the reference payload's output."""
    try:
        name = np.dtype(dtype).name
    except TypeError:
        name = str(dtype)
    return VARIANT_TOL.get(name, (0.0, 0.0))


@dataclasses.dataclass(frozen=True, eq=False)
class Target:
    """One named execution backend, declared as data.

    ``dialect`` selects the op payload: ``op.payload_for(dialect)``
    returns ``op.variants[dialect]`` when present, else the reference
    ``op.fn``.  ``jit=False`` targets (eager/NumPy backends) are never
    ``jax.jit``-ed by the compiled path or the profiler.  ``device``
    pins segment inputs via ``jax.device_put`` before execution.

    The pricing fields feed :meth:`pu_spec`: ``handoff_s`` becomes the
    cost-table H2D/D2H column (charged by ``transition_cost`` on lane
    switches when ``is_accelerator``), so the planner only routes an op
    off its neighbours' lane when the measured win clears a real sync
    margin.  Targets compare by identity (a registry entry is the unit
    of binding), not by field value.
    """

    name: str
    kind: str = "host"             # device-class label ("host", "cpu", "tpu")
    dialect: str = "ref"           # variant-table key; "ref" = op.fn oracle
    jit: bool = True               # jit fused segments / profile jitted
    device: Any = None             # a jax.Device, or None = wherever-is
    interpret: bool | None = None  # pallas interpret-mode knob (data only)
    is_accelerator: bool = False   # gate handoff pricing + boundary H2D/D2H
    dispatch_s: float = 2e-5       # per-op dispatch charged in the table
    handoff_s: float = 2.5e-4      # priced cross-lane sync (h2d = d2h)
    power_compute: float = 17.0    # W while compute-bound (energy objective)
    power_memory: float = 12.0     # W while memory/transfer-bound
    atol: float | None = None      # override variant_tolerance() per target
    rtol: float | None = None
    meta: dict = dataclasses.field(default_factory=dict)

    def tolerance(self, dtype: Any) -> tuple[float, float]:
        """The (atol, rtol) this target's variants are gated at."""
        at, rt = variant_tolerance(dtype)
        return (self.atol if self.atol is not None else at,
                self.rtol if self.rtol is not None else rt)

    def pu_spec(self) -> PUSpec:
        """Synthesize the planner-side PUSpec for this target.

        The analytic compute fields are neutral placeholders (flat
        ``kind_eff``, generous peaks): a target-backed workload is meant
        to be priced by *measured* per-target cells
        (``MeasuredProfiler(targets=...)``), and the spec's job is the
        transition algebra — ``is_accelerator`` gating, ``power_*`` for
        the energy objective, ``dispatch_s`` as the analytic fallback.
        """
        return PUSpec(
            name=self.name, is_accelerator=self.is_accelerator,
            dispatch_s=self.dispatch_s, mem_bw=50e9,
            peak_gemm={1: 1e12, 2: 1e12, 4: 1e12, 8: 1e12},
            sat_flops={1: 0.0, 2: 0.0, 4: 0.0, 8: 0.0},
            kind_eff={"other": 1.0}, kind_bw_eff={},
            h2d_base=self.handoff_s, h2d_bw=float("inf"),
            power_compute=self.power_compute,
            power_memory=self.power_memory)

    def __repr__(self) -> str:  # keep registry dumps readable
        dev = getattr(self.device, "id", None)
        return (f"Target({self.name!r}, kind={self.kind!r}, "
                f"dialect={self.dialect!r}, jit={self.jit}, "
                f"device={dev if dev is not None else None})")


class TargetRegistry:
    """Named :class:`Target` set; adding a backend is one ``register``."""

    def __init__(self, targets: Iterable[Target] = ()):
        self._targets: dict[str, Target] = {}
        for t in targets:
            self.register(t)

    def register(self, target: Target, *, replace: bool = False) -> Target:
        if not isinstance(target, Target):
            raise TypeError(f"expected a Target, got {type(target).__name__}")
        if target.name in self._targets and not replace:
            raise ValueError(
                f"target {target.name!r} already registered "
                f"(pass replace=True to rebind)")
        self._targets[target.name] = target
        return target

    def get(self, name: str) -> Target:
        try:
            return self._targets[name]
        except KeyError:
            raise KeyError(
                f"unknown target {name!r}; registered: {self.names()}"
            ) from None

    def names(self) -> list[str]:
        return list(self._targets)

    def items(self):
        return self._targets.items()

    def __contains__(self, name: str) -> bool:
        return name in self._targets

    def __iter__(self):
        return iter(self._targets.values())

    def __len__(self) -> int:
        return len(self._targets)

    def __repr__(self) -> str:
        return f"TargetRegistry({self.names()})"


def resolve_targets(spec) -> dict[str, Target] | None:
    """Normalize a target binding to ``{lane name: Target}``.

    Accepts ``None``, a :class:`TargetRegistry` (one lane per registered
    target, named after it), a ``{lane: Target}`` mapping (lane names may
    differ from target names — two lanes can share one target), or an
    iterable of targets.
    """
    if spec is None:
        return None
    if isinstance(spec, TargetRegistry):
        return {t.name: t for t in spec}
    if isinstance(spec, Mapping):
        binding = dict(spec)
    else:
        binding = {t.name: t for t in spec}
    if not binding:
        raise ValueError("empty target binding: need at least one lane")
    for lane, t in binding.items():
        if not isinstance(t, Target):
            raise TypeError(
                f"lane {lane!r}: expected a Target, got {type(t).__name__}")
    return binding


def pu_specs_for_targets(targets: Mapping[str, Target]) -> dict[str, PUSpec]:
    """Planner PU axis for a lane→target binding (``Target.pu_spec`` per
    lane, keyed by *lane* name so cost-table columns line up)."""
    return {lane: t.pu_spec() for lane, t in targets.items()}
