"""``Workload`` — the dense-layer contract every solver consumes.

A ``Workload`` binds one inference request's op chain to everything the
schedulers need, in vectorized form:

* the ``(N, K)`` :class:`~repro.core.costmodel.DenseCostTable` (cost,
  power, dispatch, support mask) along the chain,
* the contention *signatures* (``dense.sig``) that let the concurrent
  solvers memoize per-signature pair/group cost matrices,
* the boundary H2D row (``dense.h2d[0]``) and D2H row (``dense.d2h[-1]``)
  that price entering/leaving the chain,
* the per-PU specs (``power_memory`` for transition-energy scaling,
  ``is_accelerator`` for H2D/D2H gating).

**The dense-layer contract.**  The scalar dict ``CostTable`` remains the
*ingestion* format: profilers and analytic cost models populate it cell
by cell, and the scalar ``*_reference`` solvers keep using it as the
equivalence oracle.  Everything on a solver or evaluator hot path —
``sequential_dp``, ``solve_parallel``'s branch re-walk, the concurrent
pair/group searches, ``evaluate_sequential``/``single_pu_cost``, and the
``DynamicScheduler`` — consumes ``Workload`` views instead.  A
``Workload`` is built **once** per (chain, table) via :meth:`build` —
the only place the scalar dict is iterated — and then sliced
(:meth:`tail`), re-indexed (:meth:`select`), or rescaled
(:meth:`under_condition`) as O(N*K) array operations that never touch
the dict again.

Derived views share the source arrays where possible (``tail`` and
``select`` return NumPy views / fancy-indexed copies of rows; they do
not re-ingest), so building per-branch or per-tail workloads inside
``solve_parallel`` / ``DynamicScheduler`` is allocation-cheap.

**DAG invariants.**  A ``Workload`` may additionally carry ``preds`` —
per-position predecessor sets over an op *DAG* — in which case the
following invariants hold and are what every DAG route relies on:

1. ``chain`` is a **topological order** of the DAG: every predecessor
   position in ``preds[i]`` is ``< i``.  A chain-shaped workload is the
   special case ``preds[i] == (i-1,)`` (``preds=None`` means exactly
   that), so every chain solver remains a valid DAG solver oracle.
2. Scheduler state is an **order ideal** (downward-closed set) of DAG
   positions; the *frontier* is the antichain of ready positions (all
   predecessors inside the ideal).  Any prefix of ``chain`` is an
   ideal, so prefix-progress resume/recovery stays well-defined on
   DAGs.
3. Cost semantics are the *concurrent* formulation: no inter-op
   transition costs; singleton advances are priced from the dense solo
   arrays, co-scheduled antichain steps via the contention model's
   group law.  Execution-side synchronization derives from the same
   ``preds`` sets (cross-lane events only at true dependency edges).
4. ``preds`` participates in :meth:`signature` **only when non-linear**,
   so chain workload signatures (and every existing plan-cache key)
   are unchanged.
5. Row-reordering views (``tail``, ``select``) drop ``preds`` — their
   rows no longer index the same DAG positions; row-preserving views
   (``under_condition``, ``spliced``) carry it through unchanged.
"""
from __future__ import annotations

import hashlib
from typing import Iterable, Mapping, Sequence

import numpy as np

from .costmodel import CostTable, DenseCostTable, PUSpec


def _as_pu_specs(pus: Mapping[str, PUSpec]) -> dict[str, PUSpec]:
    """Normalize a PU-axis mapping: values may be ``PUSpec``s or execution
    :class:`~repro.core.targets.Target`\\ s (anything with ``pu_spec()``),
    so target-backed lanes plug into every solver unchanged."""
    out: dict[str, PUSpec] = {}
    for name, spec in dict(pus).items():
        if not isinstance(spec, PUSpec) and hasattr(spec, "pu_spec"):
            spec = spec.pu_spec()
        out[name] = spec
    return out


class Workload:
    """One request: an op chain bound to its dense cost views."""

    def __init__(self, chain: Sequence[int], dense: DenseCostTable,
                 pus: Mapping[str, PUSpec], ops: Sequence | None = None,
                 table: CostTable | None = None,
                 preds: Sequence[Sequence[int]] | None = None):
        self.chain = list(chain)
        self.dense = dense
        self.pus = pus = _as_pu_specs(pus)
        self.ops = ops                  # optional FusedOp list (names in errors)
        # Optional DAG structure: preds[i] = sorted tuple of predecessor
        # *positions* (indices into ``chain``), each < i (topological
        # order).  None means the linear chain preds[i] == (i-1,).
        self.preds = (None if preds is None
                      else tuple(tuple(sorted(int(q) for q in ps))
                                 for ps in preds))
        if self.preds is not None:
            if len(self.preds) != len(self.chain):
                raise ValueError(
                    f"preds length {len(self.preds)} != chain length "
                    f"{len(self.chain)}")
            for i, ps in enumerate(self.preds):
                if any(not 0 <= q < i for q in ps):
                    raise ValueError(
                        f"preds[{i}]={ps} is not topologically ordered "
                        "(every predecessor position must be < its node)")
        # The scalar source table is kept ONLY as the oracle handle for the
        # ``*_reference`` fallbacks (custom contention models); no Workload
        # method iterates it.
        self.table = table
        self.pu_names = dense.pus
        self._col = {p: j for j, p in enumerate(self.pu_names)}
        # (K,) transition-energy scale: transitions consume time on the
        # interconnect/host, charged at the destination PU's memory-bound
        # power in energy mode (same rule as graph.build_sequential_graph).
        self.power_memory = np.array(
            [pus[p].power_memory for p in self.pu_names])
        self._signature: str | None = None
        self._succs: tuple[tuple[int, ...], ...] | None = None

    # -- construction -------------------------------------------------------
    @classmethod
    def build(cls, chain: Sequence[int], table: CostTable,
              pus: Mapping[str, PUSpec], ops: Sequence | None = None,
              preds: Sequence[Sequence[int]] | None = None
              ) -> "Workload":
        """Ingest a scalar ``CostTable`` into a dense Workload (the single
        sanctioned dict pass).

        Malformed inputs raise descriptive ``ValueError``s here, at the
        front door, instead of surfacing as bare ``KeyError``/``IndexError``
        deep inside the dense views: empty chains, chain ops with no cost
        entry on any PU (unprofiled), and cost-table PU names the
        ``PUSpec`` mapping doesn't know.
        """
        chain = list(chain)
        pus = _as_pu_specs(pus)
        if not chain:
            raise ValueError(
                "Workload.build: empty op chain — nothing to schedule")
        if table is None:
            raise ValueError(
                "Workload.build: no CostTable (table=None); profile the "
                "graph first, or pass a prebuilt workload to the solver")
        unknown = [p for p in table.pus if p not in pus]
        if unknown:
            raise ValueError(
                f"Workload.build: cost table uses unknown PU name(s) "
                f"{unknown}; the PUSpec mapping only defines "
                f"{sorted(pus)}")
        missing = [oi for oi in dict.fromkeys(chain)
                   if not table.supported_pus(oi)]
        if missing:
            def _nm(oi: int) -> str:
                if ops is not None and 0 <= oi < len(ops):
                    return f"op {oi} ({ops[oi].name})"
                return f"op {oi}"
            shown = ", ".join(_nm(oi) for oi in missing[:5])
            more = f" (+{len(missing) - 5} more)" if len(missing) > 5 else ""
            raise ValueError(
                f"Workload.build: {len(missing)} chain op(s) missing from "
                f"the cost table on every PU: {shown}{more} — were they "
                "profiled?")
        dense = DenseCostTable.from_chain(chain, table, pus)
        return cls(chain, dense, pus, ops=ops, table=table, preds=preds)

    @classmethod
    def from_graph(cls, graph, table: CostTable,
                   pus: Mapping[str, PUSpec]) -> "Workload":
        """Build a DAG workload from an :class:`~repro.core.op.OpGraph`:
        rows follow ``graph.topo_order()`` and ``preds`` holds the graph's
        predecessor sets mapped to topological positions."""
        order = graph.topo_order()
        pos_of = {oi: i for i, oi in enumerate(order)}
        preds = [tuple(sorted(pos_of[q] for q in graph.pred[oi]))
                 for oi in order]
        return cls.build(order, table, pus, ops=graph.ops, preds=preds)

    def signature(self) -> str:
        """Content hash of the dense views (chain, PU set, all cost
        arrays).  Two workloads with equal signatures are interchangeable
        for every dense solver — the orchestrator keys its plan cache on
        this, so an identically-profiled graph reuses cached *schedules*
        (the orchestrator re-binds the plan's handles to the caller's,
        since op payloads may differ behind equal cost tables)."""
        if self._signature is None:
            h = hashlib.blake2b(digest_size=16)
            d = self.dense
            h.update(repr((tuple(self.chain), tuple(d.pus))).encode())
            for a in (d.mask, d.w, d.power, d.h2d, d.d2h, d.dispatch, d.acc):
                h.update(np.ascontiguousarray(a).tobytes())
            # DAG structure changes the schedule space, so it must change
            # the signature — but ONLY when non-linear, keeping every
            # existing chain-workload signature (and plan-cache key) stable.
            if not self.is_linear:
                h.update(b"dag")
                h.update(repr(self.preds).encode())
            self._signature = h.hexdigest()
        return self._signature

    # -- basic queries -------------------------------------------------------
    @property
    def n(self) -> int:
        return self.dense.n

    @property
    def k(self) -> int:
        return self.dense.k

    def col(self, pu: str) -> int:
        return self._col[pu]

    def cols(self, assignment: Sequence[str]) -> np.ndarray:
        """(len(assignment),) column index per assigned PU name."""
        return np.fromiter((self._col[p] for p in assignment),
                           dtype=np.int64, count=len(assignment))

    def op_name(self, pos: int) -> str:
        oi = self.chain[pos]
        if self.ops is not None and 0 <= oi < len(self.ops):
            return f"op {oi} ({self.ops[oi].name})"
        return f"op {oi}"

    # -- DAG structure -------------------------------------------------------
    @property
    def is_linear(self) -> bool:
        """True when the dependency structure is the plain chain
        ``0 -> 1 -> ... -> n-1`` (including ``preds=None``)."""
        if self.preds is None:
            return True
        return all(ps == (() if i == 0 else (i - 1,))
                   for i, ps in enumerate(self.preds))

    def pred_positions(self, pos: int) -> tuple[int, ...]:
        """Predecessor positions of ``pos`` (chain semantics if no DAG)."""
        if self.preds is None:
            return () if pos == 0 else (pos - 1,)
        return self.preds[pos]

    @property
    def succs(self) -> tuple[tuple[int, ...], ...]:
        """Successor positions per position (derived from ``preds``)."""
        if self._succs is None:
            out: list[list[int]] = [[] for _ in range(self.n)]
            for i in range(self.n):
                for q in self.pred_positions(i):
                    out[q].append(i)
            self._succs = tuple(tuple(s) for s in out)
        return self._succs

    # -- derived views -------------------------------------------------------
    def _derive(self, dense: DenseCostTable,
                preds: tuple[tuple[int, ...], ...] | None = None
                ) -> "Workload":
        wl = Workload.__new__(Workload)
        wl.chain = list(dense.chain)
        wl.dense = dense
        wl.pus = self.pus
        wl.ops = self.ops
        # a derived view's rows no longer correspond to the source dict
        # (sliced / re-indexed / condition-scaled), so it carries NO
        # oracle handle — consumers needing the scalar fallback must be
        # given a Workload built directly from a table
        wl.table = None
        wl.pu_names = dense.pus
        wl._col = self._col
        wl.power_memory = self.power_memory
        wl._signature = None
        # row-preserving views pass the DAG structure through explicitly;
        # row-reordering views (tail/select) leave it behind
        wl.preds = preds
        wl._succs = None
        return wl

    def tail(self, pos: int) -> "Workload":
        """Workload over ``chain[pos:]`` — row *views*, no copies."""
        d = self.dense
        sub = DenseCostTable(d.pus, d.chain[pos:], d.mask[pos:], d.w[pos:],
                             d.power[pos:], d.h2d[pos:], d.d2h[pos:], d.acc,
                             dispatch=d.dispatch[pos:])
        return self._derive(sub)

    def select(self, sub_chain: Sequence[int]) -> "Workload":
        """Workload over an arbitrary op subset (e.g. one parallel branch).

        Rows are fancy-indexed from this workload's dense arrays — the
        scalar table is not consulted.  Each op index in ``sub_chain``
        must appear in ``self.chain``.
        """
        pos_of: dict[int, int] = {}
        for i, oi in enumerate(self.chain):
            pos_of.setdefault(oi, i)
        rows = np.fromiter((pos_of[oi] for oi in sub_chain), dtype=np.int64,
                           count=len(sub_chain))
        d = self.dense
        sub = DenseCostTable(d.pus, list(sub_chain), d.mask[rows], d.w[rows],
                             d.power[rows], d.h2d[rows], d.d2h[rows], d.acc,
                             dispatch=d.dispatch[rows])
        return self._derive(sub)

    def under_condition(self, slowdown: Mapping[str, float] | None = None,
                        unavailable: Iterable[str] = ()) -> "Workload":
        """Workload under a runtime condition: per-PU *column* scalings.

        ``slowdown[pu] = f`` multiplies the kernel share of every op on
        that PU (dispatch, H2D/D2H, and power are monitoring-invariant);
        ``unavailable`` PUs are masked out entirely (the paper's
        compile-failure semantics applied at runtime).  O(N*K) array work
        — the dict-table rebuild of the old ``dynamic.adjusted_table`` is
        retired from this path.
        """
        d = self.dense
        w = d.w.copy()
        mask = d.mask.copy()
        for pu, f in (slowdown or {}).items():
            j = self._col.get(pu)
            if j is None:
                continue
            col = mask[:, j]
            w[col, j] = d.dispatch[col, j] + (d.w[col, j]
                                              - d.dispatch[col, j]) * float(f)
        for pu in unavailable:
            j = self._col.get(pu)
            if j is None:
                continue
            mask[:, j] = False
            w[:, j] = np.inf
        sub = DenseCostTable(d.pus, d.chain, mask, w, d.power, d.h2d, d.d2h,
                             d.acc, dispatch=d.dispatch)
        return self._derive(sub, preds=self.preds)

    def spliced(self, other: "Workload", pos: int) -> "Workload":
        """Rows ``[:pos]`` from this workload, rows ``[pos:]`` from
        ``other`` (same chain/PUs).  Used by the dynamic scheduler to
        price a stitched plan: the already-executed prefix at the nominal
        profile, the re-planned tail under the current condition."""
        d0, d1 = self.dense, other.dense
        sub = DenseCostTable(
            d0.pus, d0.chain,
            np.concatenate([d0.mask[:pos], d1.mask[pos:]]),
            np.concatenate([d0.w[:pos], d1.w[pos:]]),
            np.concatenate([d0.power[:pos], d1.power[pos:]]),
            np.concatenate([d0.h2d[:pos], d1.h2d[pos:]]),
            np.concatenate([d0.d2h[:pos], d1.d2h[pos:]]),
            d0.acc,
            dispatch=np.concatenate([d0.dispatch[:pos], d1.dispatch[pos:]]))
        return self._derive(sub, preds=self.preds)

    # -- evaluation ----------------------------------------------------------
    def evaluate(self, assignment: Sequence[str],
                 allow_infeasible: bool = False) -> tuple[float, float]:
        """(latency, energy) of a fixed assignment, including boundary
        H2D/D2H and inter-op transition costs — the dense equivalent of
        the scalar ``evaluate_sequential`` walk.

        Unsupported (op, PU) cells raise ``KeyError`` (matching the
        scalar ``CostTable.require``) unless ``allow_infeasible``, which
        returns ``(inf, inf)`` instead.
        """
        d = self.dense
        n = d.n
        if len(assignment) != n:
            raise ValueError(
                f"assignment length {len(assignment)} != chain length {n}")
        c = self.cols(assignment)
        rows = np.arange(n)
        sup = d.mask[rows, c]
        if not sup.all():
            if allow_infeasible:
                return float("inf"), float("inf")
            bad = int(np.argmin(sup))
            raise KeyError(
                f"{self.op_name(bad)} unsupported on {assignment[bad]}")
        w = d.w[rows, c]
        pw = d.power[rows, c]
        h2d = d.h2d[rows, c]
        d2h = d.d2h[rows, c]
        accv = d.acc[c]
        pmv = self.power_memory[c]
        if n > 1:
            same = c[1:] == c[:-1]
            tc = np.where(same, 0.0,
                          np.where(accv[1:], h2d[1:], 0.0)
                          + np.where(accv[:-1], d2h[:-1], 0.0))
            tc_lat = float(np.sum(tc))
            tc_eng = float(np.sum(tc * pmv[1:]))
        else:
            tc_lat = tc_eng = 0.0
        lat = float(h2d[0]) + float(np.sum(w)) + tc_lat + float(d2h[-1])
        eng = (float(h2d[0]) * float(pmv[0]) + float(np.sum(w * pw))
               + tc_eng + float(d2h[-1]) * float(pmv[-1]))
        return lat, eng

    def single_pu(self, pu: str) -> tuple[float, float] | None:
        """(latency, energy) of monolithic execution on ``pu``; ``None``
        if any op is unsupported there (the compile-failure case)."""
        j = self._col[pu]
        d = self.dense
        if not d.mask[:, j].all():
            return None
        w = d.w[:, j]
        pm = float(self.power_memory[j])
        lat = float(d.h2d[0, j]) + float(np.sum(w)) + float(d.d2h[-1, j])
        eng = (float(d.h2d[0, j]) * pm + float(np.sum(w * d.power[:, j]))
               + float(d.d2h[-1, j]) * pm)
        return lat, eng

    def best_solo(self, objective: str = "latency"
                  ) -> tuple[str, float, dict[str, float | None]]:
        """(best PU, value, per-PU dict) of monolithic execution."""
        idx = 0 if objective == "latency" else 1
        vals: dict[str, float | None] = {}
        for pu in self.pu_names:
            c = self.single_pu(pu)
            vals[pu] = None if c is None else c[idx]
        feas = {p: v for p, v in vals.items() if v is not None}
        if not feas:
            raise ValueError(
                f"no single PU supports every op of the chain "
                f"(len={self.n})")
        b = min(feas, key=feas.get)
        return b, feas[b], vals

    def require_feasible(self) -> None:
        """Raise if any chain position is unsupported on every PU."""
        ok = self.dense.mask.any(axis=1)
        if not ok.all():
            bad = int(np.argmin(ok))
            raise ValueError(f"{self.op_name(bad)} unsupported on all PUs")
