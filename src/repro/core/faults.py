"""Fault model & degraded-mode semantics of the execution runtime.

Edge SoCs are shared, thermally-limited, contended machines: PUs stall,
throttle, and drop out *mid-inference*, not just between requests.  The
scheduling side already reacts to condition changes between executions
(``Orchestrator.on_condition`` restitches plans); this module is the
runtime half — the fault model both executor paths (the per-op
interpreter oracle and the compiled ``LaneProgram``) enforce, plus the
scriptable injection machinery that tests and benchmarks drive it with.

**Fault taxonomy** (``FaultSpec.kind``) and what the runtime guarantees
for each:

* ``"transient"`` — a payload raises
  :class:`~repro.fault.manager.RecoverableError` (the same signal the
  train-loop fault manager retries through — one vocabulary for both
  runtimes; the injected form is :class:`TransientFault`).
  **Recoverable.**  The failing unit (one op on the interpreter path,
  one fused segment on the compiled path) retries with exponential
  backoff up to ``ExecutionPolicy.max_retries`` times; retry is safe
  because payloads are documented pure on the compiled path, and raising
  ``RecoverableError`` is a payload's explicit opt-in to re-execution on
  the interpreter path.  A fault that persists through every attempt
  raises :class:`~repro.core.errors.FaultRetryExceededError` — typed,
  never silent.  A jitted segment that fails with a *non*-transient
  error additionally falls back to its composed-eager form once
  (mirroring the compile-time probe fallback) before giving up.

* ``"straggler"`` — the lane sleeps ``delay`` seconds before the op
  (thermal throttling, a co-resident process).  **Recoverable** as long
  as the watchdog budget absorbs the slowdown: execution completes with
  identical outputs, just later.  A straggler that pushes past the
  deadline degenerates into the stall case below.

* ``"stall"`` — the lane hangs at the injection point for ``delay``
  seconds (``float("inf")`` = forever).  **Recoverable** when ``delay``
  fits the budget.  Otherwise the watchdog converts the hang into a
  typed :class:`~repro.core.errors.ExecutionTimeoutError`: every
  cross-lane wait is deadline-bounded, the stalled lane itself sleeps
  abort-aware and raises at the deadline, and worker pools shut down
  cleanly — **no execution path can block forever**.

* ``"pu_lost"`` — the lane dies permanently from the injection point on
  (every later dispatch on it raises
  :class:`~repro.core.errors.PULostError`).  **Recoverable by
  re-planning**: the executor attaches the execution frontier (completed
  per-request results) to the error; ``Orchestrator.execute`` folds the
  loss into the session condition (``RuntimeCondition.lose``,
  invalidating stale cached plans via ``on_condition``), re-plans the
  *remaining* ops on the surviving PUs, and resumes from the frontier.
  **Bitwise-recovery guarantee:** recovered outputs are bitwise
  identical to the fault-free run — completed results are reused, and
  the remaining pure payloads compute the same values regardless of
  which host-thread lane runs them.  When no surviving PU can run some
  remaining op, recovery raises
  :class:`~repro.core.errors.InfeasibleScheduleError` with op context.

**Watchdog semantics.**  :class:`ExecutionPolicy` turns the plan's
cost-model estimate into a wall-clock budget
(``max(min_timeout, timeout_factor * estimate)``, or the explicit
``timeout``); :class:`RunContext` threads that deadline through every
event wait, worker join, and injected sleep of a run.  The first failure
on any lane sets the run's abort flag and releases every event, so
sibling lanes parked on a dead producer unwind immediately instead of
deadlocking (they raise the internal ``_Aborted`` control signal and
exit silently; only the original error surfaces).  ``watchdog=False``
restores the pre-fault-runtime semantics (unbounded waits, no injection
hooks) — retained as the overhead baseline ``benchmarks/bench_fault.py``
measures against.

**Retry limits.**  ``max_retries`` bounds re-execution per unit (default
2 retries → 3 attempts); backoff is ``backoff * 2**(attempt-1)`` seconds
and abort-aware, so a peer's failure interrupts a backoff sleep.

Injection is *seeded and scriptable*: a :class:`FaultPlan` is an ordered
list of :class:`FaultSpec` match rules ((lane, request, op) points, each
with a bounded fire count), plus ``FaultPlan.sample`` for seeded random
single-fault scenarios.  Both executor paths call ``FaultPlan.fire`` at
every dispatch point — per op on the interpreter, per fused segment
(covering each of its items) on the compiled path — so a fault can be
placed at any (lane, op/segment) point of either path.

**Serving-scope injection.**  A single execution is one fault surface;
a *serving run* (``ServingEngine(execution="real")``) is many chunked
executions sharing one persistent ``FaultPlan``, which extends the
semantics three ways:

* **Time-indexed arming** — a :class:`ChaosTrace` scripts faults on the
  serving run's *virtual clock*: each :class:`ChaosEvent` carries a
  ``time`` and is folded into the live plan (:meth:`FaultPlan.add`) only
  once the serving loop's clock reaches it.  The executor never sees the
  trace, only the armed specs — the serving loop cannot peek ahead at
  the script, which keeps chaos tests honest.
* **Request-indexed targeting** — a ``ChaosEvent.rid`` names a *serving
  request id* (stable across the run), not an execution slot.  Execution
  slots are positional and shift as requests admit/retire, so the
  serving loop re-translates rid → current slot immediately before each
  chunked execution (an event whose rid is not in flight arms against a
  sentinel slot that matches nothing until it is).
* **Lane revival** — ``kind="pu_restored"`` events model a PU coming
  back (driver reset, thermal recovery): :meth:`FaultPlan.revive` drops
  the lane from ``lost``.  Revival is *ground truth only* — the serving
  loop does not learn of it from the plan; the health layer's half-open
  circuit-breaker probe (:mod:`repro.core.health`) must re-discover the
  lane by dispatching to it and observing success.

Fired counts stay global across the chunks of a serving run (same
statefulness as across retry/resume of a single run), so a bounded storm
is bounded over the whole run, not per chunk.
"""
from __future__ import annotations

import dataclasses
import json
import random
import threading
import time
from typing import Callable, Iterable, Sequence

from repro.fault.manager import RecoverableError

from .errors import (ExecutionTimeoutError, FaultRetryExceededError,
                     PULostError)

FAULT_KINDS = ("transient", "stall", "straggler", "pu_lost")

# extra wall-clock the run joiner grants lane workers past the deadline
# before declaring a lane truly hung (covers watchdog raise + unwind time)
_JOIN_GRACE = 2.0


class TransientFault(RecoverableError):
    """Injected transient payload failure — the runtime's retryable
    fault, sharing the train-loop fault manager's ``RecoverableError``
    vocabulary so one ``except`` clause covers both runtimes."""


class _Aborted(BaseException):
    """Internal control signal: a peer lane already failed; unwind this
    lane silently.  Derives from ``BaseException`` so payload-level
    ``except Exception`` blocks (including the retry machinery) can
    never swallow it."""


@dataclasses.dataclass
class ExecutionPolicy:
    """Watchdog + retry knobs of one execution run.

    ``budget`` derives the run's wall-clock deadline: the explicit
    ``timeout`` when set, else ``timeout_factor`` times the plan's
    cost-model estimate, floored at ``min_timeout`` (cost-model units
    are idealized device-seconds; the floor absorbs host-thread
    scheduling noise that dwarfs ms-scale estimates).  ``watchdog=False``
    disables deadlines and fault hooks entirely — the pre-fault-runtime
    execution semantics, kept as the measured overhead baseline.
    """

    timeout: float | None = None      # explicit per-run budget (seconds)
    timeout_factor: float = 200.0     # x plan cost-model estimate
    min_timeout: float = 10.0         # budget floor (seconds)
    max_retries: int = 2              # transient retries per op/segment
    backoff: float = 0.002            # base backoff (doubles per attempt)
    watchdog: bool = True             # False -> unbounded waits, no hooks

    def budget(self, estimate: float | None = None) -> float | None:
        """Wall-clock budget for a run whose cost-model estimate is
        ``estimate`` (``None`` = no estimate); ``None`` = unbounded."""
        if not self.watchdog:
            return None
        if self.timeout is not None:
            return float(self.timeout)
        if estimate is not None and estimate > 0.0:
            return max(self.min_timeout, self.timeout_factor * estimate)
        return self.min_timeout


DEFAULT_POLICY = ExecutionPolicy()


class RunContext:
    """Shared per-run state: deadline, abort flag, error collection.

    One ``RunContext`` spans one executor run across all its lanes.  All
    blocking operations of the run go through it (``wait`` for handoff
    events, ``stall``/``backoff_sleep`` for injected or retry sleeps) so
    every one of them is deadline-bounded and abort-aware.
    """

    __slots__ = ("policy", "faults", "budget", "t0", "deadline", "abort",
                 "errors", "current", "release", "retries", "_lock")

    def __init__(self, policy: ExecutionPolicy | None = None,
                 faults: "FaultPlan | None" = None,
                 estimate: float | None = None):
        self.policy = policy if policy is not None else DEFAULT_POLICY
        self.faults = faults if (self.policy.watchdog or faults is None) \
            else None
        if faults is not None and not self.policy.watchdog:
            # injection needs the watchdog machinery (abort-aware sleeps,
            # bounded waits) to uphold the no-hang guarantee
            raise ValueError(
                "FaultPlan injection requires ExecutionPolicy.watchdog=True "
                "(watchdog=False is the bare pre-fault baseline)")
        self.budget = self.policy.budget(estimate)
        self.t0 = time.monotonic()
        self.deadline = None if self.budget is None else self.t0 + self.budget
        self.abort = threading.Event()
        self.errors: list[BaseException] = []
        self.current: dict[str, str] = {}   # lane -> in-flight description
        self.release: Callable[[], None] | None = None
        self.retries = 0
        self._lock = threading.Lock()

    # -- timing --------------------------------------------------------------
    def elapsed(self) -> float:
        return time.monotonic() - self.t0

    def remaining(self) -> float | None:
        return None if self.deadline is None \
            else self.deadline - time.monotonic()

    def _timeout(self, what: str) -> ExecutionTimeoutError:
        inflight = dict(self.current)
        busy = "; ".join(f"{lane}: {d}" for lane, d in
                         sorted(inflight.items())) or "none"
        return ExecutionTimeoutError(
            f"{what} did not complete within the watchdog budget "
            f"({self.elapsed():.2f}s elapsed vs {self.budget:.2f}s budget; "
            f"in-flight: {busy})", inflight=inflight)

    # -- blocking primitives -------------------------------------------------
    def check_abort(self) -> None:
        if self.abort.is_set():
            raise _Aborted()

    def wait(self, ev: threading.Event, what: str) -> None:
        """Deadline-bounded ``ev.wait()``: raises
        :class:`ExecutionTimeoutError` (naming ``what`` plus elapsed vs
        budget) at the deadline, and ``_Aborted`` when a peer lane has
        already failed (failures release every event, so the wake-up is
        immediate)."""
        if self.deadline is None:
            ev.wait()
        elif not ev.wait(max(self.deadline - time.monotonic(), 0.0)):
            self.check_abort()
            raise self._timeout(what)
        self.check_abort()

    def stall(self, duration: float, what: str) -> None:
        """Abort-aware sleep for injected stalls/stragglers.  Sleeps at
        most to the deadline; a stall whose requested duration was
        truncated by the deadline raises the typed timeout (this is how
        an injected infinite hang resolves on the lane that hangs)."""
        rem = self.remaining()
        t = duration if rem is None else min(duration, max(rem, 0.0))
        if t == float("inf"):
            self.abort.wait()               # only abort can end it
            raise _Aborted()
        if self.abort.wait(t):
            raise _Aborted()
        if rem is not None and duration > t:
            raise self._timeout(what)

    def backoff_sleep(self, attempt: int) -> None:
        d = self.policy.backoff * (2.0 ** (attempt - 1))
        rem = self.remaining()
        if rem is not None:
            d = min(d, max(rem, 0.0))
        if self.abort.wait(d):
            raise _Aborted()

    # -- failure propagation -------------------------------------------------
    def fail(self, e: BaseException) -> None:
        """Record a lane failure, flip the abort flag, and release every
        event of the run so no sibling lane stays parked on a dead
        producer (the first recorded error is the one re-raised)."""
        with self._lock:
            self.errors.append(e)
        self.abort.set()
        if self.release is not None:
            self.release()

    def first_error(self) -> BaseException:
        """The error to surface: a ``PULostError`` wins over secondary
        errors (it carries the recovery semantics), else the first
        recorded failure."""
        for e in self.errors:
            if isinstance(e, PULostError):
                return e
        return self.errors[0]


def run_with_retries(run: RunContext | None, attempt: Callable[[], object],
                     what: str, lane: str | None = None,
                     request: int | None = None, op: int | None = None):
    """Drive ``attempt`` through the bounded-retry policy: transient
    (``RecoverableError``) failures retry with exponential backoff up to
    ``max_retries`` times, then raise
    :class:`FaultRetryExceededError` ``from`` the final transient error
    (carrying the ``lane``/``request``/``op`` point when the caller
    supplied one).  Non-transient exceptions propagate immediately.
    ``run=None`` (the fault-free serial fast path) retries under the
    default policy with a plain sleep."""
    policy = run.policy if run is not None else DEFAULT_POLICY
    attempts = 0
    while True:
        try:
            return attempt()
        except RecoverableError as e:
            attempts += 1
            if run is not None:
                run.retries += 1
            if attempts > policy.max_retries:
                raise FaultRetryExceededError(
                    f"{what} still failing after {policy.max_retries} "
                    f"retried attempt(s): {e}",
                    lane=lane, request=request, op=op) from e
            if run is not None:
                run.backoff_sleep(attempts)
            else:
                time.sleep(policy.backoff * (2.0 ** (attempts - 1)))


# ---------------------------------------------------------------------------
# scriptable fault injection
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FaultSpec:
    """One injection rule: fire ``kind`` at every dispatch point matching
    the non-``None`` fields, at most ``count`` times (``count <= 0`` =
    unlimited).  ``delay`` is the stall duration / straggler slowdown in
    wall-clock seconds (``float("inf")`` hangs a stall forever — the
    watchdog, not the fault, ends it)."""

    kind: str
    lane: str | None = None
    request: int | None = None
    op: int | None = None
    count: int = 1
    delay: float = 0.05

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; one of {FAULT_KINDS}")
        if self.delay < 0:
            raise ValueError(f"delay must be >= 0, got {self.delay}")

    def matches(self, lane: str, request: int, op: int) -> bool:
        return ((self.lane is None or self.lane == lane)
                and (self.request is None or self.request == request)
                and (self.op is None or self.op == op))


class FaultPlan:
    """A seeded, scriptable set of faults to inject into one or more
    executor runs.

    Both executor paths call :meth:`fire` at every dispatch point — per
    op on the interpreter, per fused segment (iterating its (request,
    op) items) on the compiled ``LaneProgram`` — so specs can target any
    (lane, op/segment) point of either path.  The plan is stateful:
    fired counts persist across runs (a one-shot transient consumed
    during the first attempt does not re-fire during the retry or the
    post-recovery resume), and a ``pu_lost`` lane stays dead for every
    later dispatch until :meth:`reset`.  Thread-safe: lanes fire
    concurrently.
    """

    def __init__(self, specs: Iterable[FaultSpec] = (), seed: int = 0):
        self.specs = list(specs)
        self.seed = seed
        self.lost: set[str] = set()
        self.fired: list[tuple[str, str, int, int]] = []  # (kind, lane, r, op)
        self._remaining = [s.count for s in self.specs]
        self._lock = threading.Lock()

    # -- construction helpers ------------------------------------------------
    @classmethod
    def single(cls, kind: str, **kw) -> "FaultPlan":
        """One-spec plan: ``FaultPlan.single("pu_lost", request=0, op=3)``."""
        return cls([FaultSpec(kind=kind, **kw)])

    @classmethod
    def sample(cls, points: Sequence[tuple[int, int]], n: int = 1,
               kinds: Sequence[str] = FAULT_KINDS, seed: int = 0,
               delay: float = 0.05) -> "FaultPlan":
        """Seeded random single-fault scenario generator: draw ``n``
        (request, op) points (with their kinds) from ``points`` — the
        same seed always produces the same plan."""
        rng = random.Random(seed)
        specs = [FaultSpec(kind=rng.choice(list(kinds)), request=r, op=op,
                           delay=delay)
                 for r, op in (rng.choice(list(points)) for _ in range(n))]
        return cls(specs, seed=seed)

    def reset(self) -> None:
        """Restore every spec's fire budget and revive lost lanes."""
        with self._lock:
            self._remaining = [s.count for s in self.specs]
            self.lost.clear()
            self.fired.clear()

    def add(self, spec: FaultSpec) -> None:
        """Arm ``spec`` into a live plan with a fresh fire budget — how a
        :class:`ChaosTrace` event becomes active once the serving clock
        reaches its time.  Thread-safe against concurrent :meth:`fire`."""
        with self._lock:
            self.specs.append(spec)
            self._remaining.append(spec.count)

    def revive(self, lane: str) -> bool:
        """Bring a lost lane back (``"pu_restored"`` chaos semantics):
        later dispatches on ``lane`` no longer raise
        :class:`~repro.core.errors.PULostError` from permanence.  Armed
        ``pu_lost`` specs are untouched — a second loss can still fire.
        Returns whether the lane was actually lost."""
        with self._lock:
            was = lane in self.lost
            self.lost.discard(lane)
            return was

    # -- the runtime hook ----------------------------------------------------
    def fire(self, lane: str, request: int, op: int, run: RunContext) -> None:
        """Called by the executor before dispatching ``op`` of
        ``request`` on ``lane``; raises/sleeps per the first matching
        armed spec.  A lane already lost raises immediately (permanence)."""
        if lane in self.lost:
            raise PULostError(
                f"PU {lane!r} is lost (permanent fault injected earlier); "
                f"cannot dispatch op {op} of request {request}",
                pu=lane, request=request, op=op)
        spec = None
        with self._lock:
            for k, s in enumerate(self.specs):
                if self._remaining[k] != 0 and s.matches(lane, request, op):
                    if self._remaining[k] > 0:
                        self._remaining[k] -= 1
                    spec = s
                    self.fired.append((s.kind, lane, request, op))
                    break
        if spec is None:
            return
        point = f"op {op} of request {request} on lane {lane!r}"
        if spec.kind == "pu_lost":
            self.lost.add(lane)
            raise PULostError(
                f"PU {lane!r} lost permanently at {point} (injected)",
                pu=lane, request=request, op=op)
        if spec.kind == "transient":
            raise TransientFault(f"injected transient fault at {point}")
        # stall / straggler: abort-aware bounded sleep; an over-budget
        # stall resolves as a typed timeout on this very lane
        run.stall(spec.delay, f"injected {spec.kind} ({spec.delay}s) at "
                              f"{point}")


# ---------------------------------------------------------------------------
# serving-scope chaos scripting
# ---------------------------------------------------------------------------

# ChaosEvent kinds = FAULT_KINDS plus lane revival (serving-scope only)
CHAOS_KINDS = FAULT_KINDS + ("pu_restored",)


@dataclasses.dataclass
class ChaosEvent:
    """One scripted serving-run fault: at virtual time ``time``, arm a
    fault (or revive a lane).

    ``kind`` is a :data:`FAULT_KINDS` member — armed as a
    :class:`FaultSpec` with the event's (lane, op, count, delay) match
    fields — or ``"pu_restored"``, which calls :meth:`FaultPlan.revive`
    instead.  ``rid`` targets a *serving request id* (translated to an
    execution slot per chunk by the serving loop); ``lane``/``op`` match
    as in :class:`FaultSpec`; ``count`` bounds total fires across the
    rest of the run.
    """

    time: float
    kind: str
    lane: str | None = None
    rid: int | None = None
    op: int | None = None
    count: int = 1
    delay: float = 0.05

    def __post_init__(self) -> None:
        if self.kind not in CHAOS_KINDS:
            raise ValueError(
                f"unknown chaos kind {self.kind!r}; one of {CHAOS_KINDS}")
        if not (self.time >= 0.0):
            raise ValueError(
                f"chaos events live on the serving clock; time must be "
                f">= 0, got {self.time!r}")
        if self.kind in ("pu_lost", "pu_restored") and self.lane is None:
            raise ValueError(f"{self.kind} events must name a lane")

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "ChaosEvent":
        return cls(**d)

    def spec(self) -> FaultSpec:
        """The :class:`FaultSpec` this event arms (``request`` is left
        ``None``; the serving loop re-binds rid-targeted specs to the
        live execution slot before each chunk)."""
        if self.kind == "pu_restored":
            raise ValueError("pu_restored events arm no FaultSpec")
        return FaultSpec(kind=self.kind, lane=self.lane, op=self.op,
                         count=self.count, delay=self.delay)


@dataclasses.dataclass
class ChaosTrace:
    """A time-ordered script of :class:`ChaosEvent` for one serving run.

    The JSON round-trip (:meth:`to_json` / :meth:`from_json`) makes a
    failing chaos run a replayable artifact — ship the trace, not the
    seed.  ``kind`` is a free-form scenario label carried through to
    reports (``"transient_storm"``, ``"pu_lost_return"``, ...).
    """

    events: list[ChaosEvent] = dataclasses.field(default_factory=list)
    kind: str = "custom"
    seed: int = 0

    def __post_init__(self) -> None:
        self.events = sorted(self.events, key=lambda e: e.time)

    def __len__(self) -> int:
        return len(self.events)

    def to_json(self) -> str:
        return json.dumps({"kind": self.kind, "seed": self.seed,
                           "events": [e.to_dict() for e in self.events]})

    @classmethod
    def from_json(cls, s: str) -> "ChaosTrace":
        d = json.loads(s)
        return cls(events=[ChaosEvent.from_dict(e) for e in d["events"]],
                   kind=d.get("kind", "custom"), seed=d.get("seed", 0))
