"""Fused-operator abstraction and operator DAG.

BIDENT operates on *fused operators*: groups of primitive ops fused by the
backend compiler (paper §3, "we use the term operator to refer to a group of
primitive operations fused by the backend compiler").  ``FusedOp`` carries
everything the cost model needs (kind, operand shapes, flop/byte counts) plus
an optional callable so the executor can actually run it.

``OpGraph`` is the fused-operator DAG.  It supports the paper's phase/branch
partitioning (§3.2.2): a topological traversal partitions the DAG into
*phases* bounded by fork (out-degree > 1) and join (in-degree > 1) points;
within a phase, *branches* are the mutually independent chains.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Iterable, Sequence

import numpy as np

# Operator kinds.  These cover the paper's seven representative operators
# (Fig. 2) plus the kinds needed by the model zoo.
OP_KINDS = (
    "matmul",        # dense GEMM / GEMV
    "conv2d",        # standard convolution
    "dwconv",        # depthwise convolution
    "add",           # elementwise add / residual
    "mul",           # elementwise multiply / gating
    "rdft",          # real FFT (Hyena long conv)
    "cumsum",        # sequential scan (Mamba selective scan recurrence)
    "gather",        # indexed gather (KAN spline eval, MoE dispatch)
    "scatter",       # indexed scatter (MoE combine)
    "norm",          # layer/rms/batch norm
    "act",           # nonlinearity (SiLU/GELU/ReLU/spike)
    "softmax",       # softmax / attention probs
    "attention",     # fused attention block
    "scan",          # recurrent scan (SSM/xLSTM state update)
    "embed",         # embedding lookup
    "transfer",      # explicit data movement (rare; usually edge cost)
    "other",
)


@dataclasses.dataclass
class FusedOp:
    """One fused operator in an inference/training graph."""

    name: str
    kind: str
    # Shapes of the major input operands and the output (element counts are
    # what the cost model consumes).
    in_shapes: tuple[tuple[int, ...], ...] = ()
    out_shape: tuple[int, ...] = ()
    dtype_bytes: int = 2  # FP16 default, INT8 -> 1
    flops: float = 0.0    # algorithmic FLOPs
    bytes_moved: float = 0.0  # bytes read + written (roofline memory term)
    # Optional execution payload: fn(*inputs) -> output.  Used by the
    # executor to really run the schedule; None for analytic-only graphs.
    # ``fn`` is always the *reference* variant: the per-op interpreter
    # executes it exclusively (the single-variant bitwise oracle).
    fn: Callable[..., Any] | None = None
    # Per-target payload variants: ``{dialect: callable}`` with the same
    # call signature as ``fn``.  The compiled path serves
    # ``payload_for(target.dialect)`` on a lane bound to a target, after
    # probe-verifying it against the reference composition.  Rebinding
    # any entry after compilation invalidates cached lane programs (the
    # same staleness rule as rebinding ``fn``).
    variants: dict[str, Callable[..., Any]] = dataclasses.field(
        default_factory=dict)
    # Free-form metadata (e.g. which paper model / layer this came from).
    meta: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self) -> None:
        if self.kind not in OP_KINDS:
            raise ValueError(f"unknown op kind {self.kind!r}")
        if not self.bytes_moved:
            n_in = sum(int(np.prod(s)) for s in self.in_shapes)
            n_out = int(np.prod(self.out_shape)) if self.out_shape else 0
            self.bytes_moved = float((n_in + n_out) * self.dtype_bytes)
        if not self.flops:
            self.flops = default_flops(self.kind, self.in_shapes, self.out_shape)

    def payload_for(self, dialect: str | None) -> Callable[..., Any] | None:
        """The payload serving ``dialect``: the variant-table entry when
        one is bound, else the reference ``fn`` (``"ref"``/``None`` always
        resolve to ``fn`` — the oracle is not overridable)."""
        if dialect is None or dialect == "ref":
            return self.fn
        return self.variants.get(dialect, self.fn)

    def payload_token(self) -> tuple:
        """Identity snapshot of ``fn`` + the variant table, compared with
        ``is`` per entry by ``LaneProgram.payloads_current`` so rebinding
        *any* payload after compilation is detected."""
        return (self.fn,
                tuple((k, self.variants[k]) for k in sorted(self.variants)))

    @property
    def out_bytes(self) -> float:
        return float(int(np.prod(self.out_shape)) * self.dtype_bytes) if self.out_shape else 0.0

    @property
    def in_bytes(self) -> float:
        return float(sum(int(np.prod(s)) for s in self.in_shapes) * self.dtype_bytes)


def default_flops(kind: str, in_shapes: Sequence[tuple[int, ...]], out_shape: tuple[int, ...]) -> float:
    """Default algorithmic FLOP count for an op kind."""
    n_out = float(np.prod(out_shape)) if out_shape else 0.0
    if kind == "matmul" and len(in_shapes) >= 2:
        # [.., M, K] x [K, N] -> 2*M*K*N (batch included via out size)
        k = in_shapes[0][-1]
        return 2.0 * n_out * k
    if kind in ("conv2d", "dwconv") and len(in_shapes) >= 2:
        # weight shape (Cout, Cin, kh, kw) or (C, 1, kh, kw) for dw
        w = in_shapes[1]
        per_out = 2.0 * float(np.prod(w[1:]))
        return n_out * per_out
    if kind == "attention" and len(in_shapes) >= 2:
        # q [B,H,Lq,D], k [B,H,Lk,D] -> 4*B*H*Lq*Lk*D
        q, k = in_shapes[0], in_shapes[1]
        return 4.0 * float(np.prod(q)) * k[-2]
    if kind == "rdft":
        n = float(np.prod(in_shapes[0])) if in_shapes else n_out
        return 5.0 * n * max(math.log2(max(n, 2.0)), 1.0)
    if kind in ("cumsum", "scan"):
        return 3.0 * n_out
    if kind in ("add", "mul", "act", "gather", "scatter", "embed", "transfer"):
        return n_out
    if kind in ("norm", "softmax"):
        return 8.0 * n_out
    return n_out


class OpGraph:
    """Fused-operator DAG with phase/branch partitioning (paper §3.2.2)."""

    def __init__(self, ops: Sequence[FusedOp], edges: Iterable[tuple[int, int]] | None = None):
        self.ops: list[FusedOp] = list(ops)
        n = len(self.ops)
        if edges is None:  # pure sequential chain
            edges = [(i, i + 1) for i in range(n - 1)]
        self.succ: list[list[int]] = [[] for _ in range(n)]
        self.pred: list[list[int]] = [[] for _ in range(n)]
        self.n_edges = 0
        for a, b in edges:
            if not (0 <= a < n and 0 <= b < n):
                raise ValueError(f"edge ({a},{b}) out of range")
            self.succ[a].append(b)
            self.pred[b].append(a)
            self.n_edges += 1
        # structure is fixed after construction, so the derived views
        # below are computed once (the acyclicity check already pays for
        # the first topological sort)
        self._topo: list[int] | None = None
        self._is_chain: bool | None = None
        self._check_acyclic()

    # -- basic structure ----------------------------------------------------
    def __len__(self) -> int:
        return len(self.ops)

    @property
    def edges(self) -> list[tuple[int, int]]:
        return [(a, b) for a in range(len(self.ops)) for b in self.succ[a]]

    def is_chain(self) -> bool:
        if self._is_chain is None:
            self._is_chain = (all(len(s) <= 1 for s in self.succ)
                              and all(len(p) <= 1 for p in self.pred))
        return self._is_chain

    def components(self) -> list[list[int]]:
        """Weakly-connected components, each as a topologically-ordered op
        list (in global topo-order positions)."""
        n = len(self.ops)
        parent = list(range(n))

        def find(x: int) -> int:
            while parent[x] != x:
                parent[x] = parent[parent[x]]
                x = parent[x]
            return x

        for a, b in self.edges:
            ra, rb = find(a), find(b)
            if ra != rb:
                parent[ra] = rb
        buckets: dict[int, list[int]] = {}
        for u in self.topo_order():
            buckets.setdefault(find(u), []).append(u)
        return list(buckets.values())

    def topo_order(self) -> list[int]:
        if self._topo is None:
            n = len(self.ops)
            indeg = [len(p) for p in self.pred]
            stack = [i for i in range(n) if indeg[i] == 0]
            order: list[int] = []
            while stack:
                u = stack.pop()
                order.append(u)
                for v in self.succ[u]:
                    indeg[v] -= 1
                    if indeg[v] == 0:
                        stack.append(v)
            if len(order) != n:
                raise ValueError("graph has a cycle")
            self._topo = order
        return list(self._topo)    # defensive copy: callers may mutate

    def _check_acyclic(self) -> None:
        self.topo_order()

    # -- phase / branch partitioning (paper §3.2.2) -------------------------
    def phases(self) -> list["Phase"]:
        """Partition into phases bounded by fork/join points.

        A *level-synchronous* partition: we walk the DAG in topological order
        and cut a phase boundary at every join (in-degree > 1) and after
        every fork (out-degree > 1).  Inside a phase, branches are the
        maximal independent chains discovered by DFS from the phase's roots.
        Phase boundaries are synchronization barriers.
        """
        n = len(self.ops)
        order = self.topo_order()
        # Longest-path level of each op; ops at disjoint chains between a
        # fork and the matching join share levels.
        level = [0] * n
        for u in order:
            for v in self.succ[u]:
                level[v] = max(level[v], level[u] + 1)

        # Group ops into chains: follow single-in/single-out links.
        visited = [False] * n
        chains: list[list[int]] = []
        for u in order:
            if visited[u]:
                continue
            chain = [u]
            visited[u] = True
            cur = u
            while (
                len(self.succ[cur]) == 1
                and len(self.pred[self.succ[cur][0]]) == 1
            ):
                cur = self.succ[cur][0]
                if visited[cur]:
                    break
                visited[cur] = True
                chain.append(cur)
            chains.append(chain)

        # A chain's phase key: (level of first op).  Chains whose head ops
        # have no dependency between them and overlapping level ranges can
        # co-execute.  We bucket chains by the level of their head; this is
        # the paper's fork/join bounded partition for series-parallel graphs
        # (all graphs our builders emit are series-parallel).
        chain_key = [min(level[i] for i in ch) for ch in chains]
        buckets: dict[int, list[list[int]]] = {}
        for ch, key in zip(chains, chain_key):
            buckets.setdefault(key, []).append(ch)
        phases = [
            Phase(index=pi, branches=[Branch(ops=ch) for ch in buckets[k]])
            for pi, k in enumerate(sorted(buckets))
        ]
        return phases


@dataclasses.dataclass
class Branch:
    """A sequential chain of op indices inside a phase."""

    ops: list[int]


@dataclasses.dataclass
class Phase:
    """A set of mutually independent branches; bounded by barriers."""

    index: int
    branches: list[Branch]

    @property
    def concurrent(self) -> bool:
        return len(self.branches) > 1


def chain_graph(ops: Sequence[FusedOp]) -> OpGraph:
    return OpGraph(ops, edges=None)
