"""Dynamic scheduling + tile-level mapping (the paper's §6 future work)."""
import jax
import pytest

from repro.core import (EDGE_PUS, AnalyticProfiler, FusedOp, OpGraph,
                        solve_sequential)
from repro.core.costmodel import GPU, make_conv2d, make_cumsum, make_matmul
from repro.core.dynamic import (DynamicScheduler, RuntimeCondition,
                                adjusted_table, ridge_intensity, tile_split)


def _chain(n=8):
    ops = []
    for i in range(n):
        ops.append(make_matmul(512, name=f"mm{i}") if i % 2 == 0
                   else make_cumsum(4096, 128))
    g = OpGraph(ops)
    table = AnalyticProfiler().profile(g)
    return g, table


def test_adjusted_table_scales_and_drops():
    g, table = _chain(4)
    cond = RuntimeCondition(slowdown={"GPU": 2.0}, unavailable=frozenset({"NPU"}))
    adj = adjusted_table(table, cond)
    assert adj.require(0, "GPU").kernel == pytest.approx(
        2.0 * table.require(0, "GPU").kernel)
    assert adj.require(0, "CPU").kernel == pytest.approx(
        table.require(0, "CPU").kernel)
    assert not adj.supported(0, "NPU")


def test_remap_on_throttling_beats_static():
    """When the GPU throttles 4x mid-run, the dynamic scheduler reroutes
    the tail and realises a lower latency than sticking to the static
    plan."""
    g, table = _chain(10)
    chain = g.topo_order()
    throttle = {5: RuntimeCondition(slowdown={"GPU": 4.0})}

    dyn = DynamicScheduler(chain, g.ops, table, EDGE_PUS)
    realised_dynamic = dyn.simulate(throttle)
    assert dyn.events, "expected a remap event"

    static = DynamicScheduler(chain, g.ops, table, EDGE_PUS,
                              replan_threshold=1e9)   # never re-plan
    realised_static = static.simulate(throttle)
    assert not static.events
    assert realised_dynamic < realised_static * 0.95


def test_remap_on_pu_loss():
    """A PU going unavailable forces rerouting (runtime analog of the
    paper's compile-failure semantics)."""
    g, table = _chain(6)
    chain = g.topo_order()
    dyn = DynamicScheduler(chain, g.ops, table, EDGE_PUS)
    dyn.simulate({2: RuntimeCondition(unavailable=frozenset({"GPU"}))})
    assert any(e.reason == "unavailable PU" for e in dyn.events)
    assert all(p != "GPU" for p in dyn.plan.assignment[2:])


def test_hysteresis_suppresses_noise():
    """A 1% drift must not trigger re-planning (threshold 5%)."""
    g, table = _chain(6)
    chain = g.topo_order()
    dyn = DynamicScheduler(chain, g.ops, table, EDGE_PUS)
    dyn.simulate({3: RuntimeCondition(slowdown={"GPU": 1.01})})
    assert not dyn.events


# ---------------------------------------------------------------------------
# tile-level mapping
# ---------------------------------------------------------------------------


def test_tile_split_favours_compute_bound_op():
    """A compute-bound GEMM paired with a memory-bound elementwise op:
    the GEMM gets most tiles (the paper's roofline allocation rule)."""
    gemm = make_matmul(2048)                    # far above the ridge
    elt = FusedOp(name="add", kind="add", in_shapes=((1 << 22,),),
                  out_shape=(1 << 22,))        # memory-bound
    ka, kb, mk = tile_split(gemm, elt, GPU, n_tiles=6)
    assert ka >= 4 and ka + kb == 6
    assert mk < float("inf")


def test_tile_split_balanced_for_equal_ops():
    a, b = make_matmul(1024), make_matmul(1024)
    ka, kb, _ = tile_split(a, b, GPU, n_tiles=6)
    assert ka == kb == 3


def test_ridge_point_orders_pus():
    """NPU (dense MAC arrays) has a higher ridge than CPU: it needs more
    arithmetic intensity to leave the memory-bound regime."""
    from repro.core.costmodel import CPU, NPU
    assert ridge_intensity(NPU, 1) > ridge_intensity(CPU, 1)


def test_tile_split_makespan_beats_serial():
    """Co-executing with the optimal split beats running both ops on all
    tiles back-to-back when the memory-bound op is long enough to hide
    behind the compute-bound one (a short memory-bound op is better run
    serially — giving up tiles costs the GEMM more; tile_split still
    returns the best achievable co-schedule)."""
    gemm = make_matmul(2048)
    elt = FusedOp(name="mul", kind="mul", in_shapes=((1 << 27,),),
                  out_shape=(1 << 27,))
    ka, kb, mk = tile_split(gemm, elt, GPU, n_tiles=6)

    def t_full(op):
        eff = GPU.kind_eff.get(op.kind, GPU.kind_eff["other"])
        peak = GPU.peak_gemm.get(op.dtype_bytes, GPU.peak_gemm[2]) * eff
        return max(op.flops / peak, op.bytes_moved / GPU.mem_bw)

    serial = t_full(gemm) + t_full(elt)
    assert mk < serial


# ---------------------------------------------------------------------------
# Workload-layer dynamic scheduling (dense conditions, real replan numbers)
# ---------------------------------------------------------------------------


def test_replanned_schedule_carries_real_numbers():
    """After a remap the stitched plan must expose finite re-evaluated
    latency/energy (prefix at the nominal profile, tail under the active
    condition) — not NaN placeholders."""
    import math

    g, table = _chain(10)
    chain = g.topo_order()
    dyn = DynamicScheduler(chain, g.ops, table, EDGE_PUS)
    cond = RuntimeCondition(slowdown={"GPU": 4.0})
    plan = dyn.on_condition(5, cond)
    assert dyn.events, "expected a remap event"
    assert math.isfinite(plan.latency) and plan.latency > 0
    assert math.isfinite(plan.energy) and plan.energy > 0
    # the numbers must equal the spliced-workload evaluation of the plan
    adj = dyn.workload.under_condition(cond.slowdown, cond.unavailable)
    want = dyn.workload.spliced(adj, 5).evaluate(plan.assignment)
    assert (plan.latency, plan.energy) == want


def test_on_condition_uses_dense_views_not_dict_rebuilds():
    """The dynamic hot path must not construct scalar CostTables."""
    from unittest import mock

    from repro.core.costmodel import CostTable as CT

    g, table = _chain(8)
    chain = g.topo_order()
    dyn = DynamicScheduler(chain, g.ops, table, EDGE_PUS)
    with mock.patch.object(CT, "__init__",
                           side_effect=AssertionError(
                               "scalar CostTable built on the dynamic "
                               "hot path")):
        dyn.on_condition(3, RuntimeCondition(slowdown={"GPU": 3.0}))
        dyn.simulate({4: RuntimeCondition(slowdown={"CPU": 1.5})})


def test_total_pu_loss_raises_descriptive_error():
    """An op losing ALL PUs under a condition must raise a descriptive
    infeasibility error, not a bare IndexError."""
    from repro.core.dynamic import InfeasibleScheduleError

    g, table = _chain(6)
    chain = g.topo_order()
    dyn = DynamicScheduler(chain, g.ops, table, EDGE_PUS)
    doom = RuntimeCondition(unavailable=frozenset({"CPU", "GPU", "NPU"}))
    with pytest.raises(InfeasibleScheduleError, match="infeasible"):
        dyn.simulate({3: doom})


def test_simulate_guard_raises_on_unsupported_assignment():
    """If the active plan somehow assigns an op to a PU the condition has
    removed, simulate reports it descriptively."""
    from repro.core.dynamic import InfeasibleScheduleError

    g, table = _chain(6)
    chain = g.topo_order()
    dyn = DynamicScheduler(chain, g.ops, table, EDGE_PUS)
    # corrupt the plan: an op forced onto a PU we then take away;
    # no condition event fires at that position, so no replan happens
    dyn.plan.assignment[4] = "NPU"
    dyn.workload = dyn.workload.under_condition({}, {"NPU"})
    with pytest.raises(InfeasibleScheduleError, match="cannot run on NPU"):
        dyn.simulate({})


def test_dynamic_scheduler_accepts_prebuilt_workload():
    from repro.core import Workload

    g, table = _chain(6)
    chain = g.topo_order()
    wl = Workload.build(chain, table, EDGE_PUS, ops=g.ops)
    dyn = DynamicScheduler(chain, g.ops, table, EDGE_PUS, workload=wl)
    assert dyn.workload is wl
    ref = DynamicScheduler(chain, g.ops, table, EDGE_PUS)
    assert dyn.plan.assignment == ref.plan.assignment
