"""The dense ``Workload`` layer must reproduce the scalar oracles exactly.

``Workload.evaluate`` / ``single_pu`` vs the scalar dict walks;
``select``/``tail`` row views vs re-ingestion; ``under_condition`` column
scalings vs the scalar ``adjusted_table`` rebuild."""
import numpy as np
import pytest

from repro.core import (CostEntry, CostTable, EDGE_PUS, Workload,
                        evaluate_sequential, evaluate_sequential_reference,
                        single_pu_cost)
from repro.core.dynamic import RuntimeCondition, adjusted_table
from repro.core.op import FusedOp

PUS = ("CPU", "GPU", "NPU")


def random_table(rng, n_ops, drop_frac=0.25):
    table = CostTable(list(PUS))
    ops = []
    for i in range(n_ops):
        ops.append(FusedOp(name=f"o{i}", kind="other", out_shape=(4,)))
        sup = [p for p in PUS if rng.random() > drop_frac]
        if not sup:
            sup = [PUS[int(rng.integers(len(PUS)))]]
        for pu in sup:
            table.set(i, pu, CostEntry(
                kernel=float(rng.uniform(1e-6, 1e-3)),
                dispatch=float(rng.uniform(0, 1e-5)),
                h2d=float(rng.uniform(0, 1e-4)),
                d2h=float(rng.uniform(0, 1e-4)),
                power=float(rng.uniform(5.0, 30.0))))
    return ops, table


def random_assignment(rng, table, chain):
    return [table.supported_pus(oi)[int(rng.integers(
        len(table.supported_pus(oi))))] for oi in chain]


@pytest.mark.parametrize("seed", range(10))
def test_evaluate_matches_scalar_reference(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(1, 25))
    ops, table = random_table(rng, n)
    chain = list(range(n))
    wl = Workload.build(chain, table, EDGE_PUS, ops=ops)
    for _ in range(5):
        assign = random_assignment(rng, table, chain)
        lat_d, eng_d = wl.evaluate(assign)
        lat_r, eng_r = evaluate_sequential_reference(
            chain, assign, ops, table, EDGE_PUS)
        assert lat_d == pytest.approx(lat_r, rel=1e-12)
        assert eng_d == pytest.approx(eng_r, rel=1e-12)
        # the public wrapper goes through the same dense path
        lat_w, eng_w = evaluate_sequential(chain, assign, ops, table,
                                           EDGE_PUS, workload=wl)
        assert (lat_w, eng_w) == (lat_d, eng_d)


def test_evaluate_rejects_or_flags_infeasible():
    rng = np.random.default_rng(3)
    ops, table = random_table(rng, 4, drop_frac=0.0)
    # drop op 2 from GPU
    t2 = CostTable(list(PUS))
    for (oi, pu), e in table.items():
        if not (oi == 2 and pu == "GPU"):
            t2.set(oi, pu, e)
    wl = Workload.build([0, 1, 2, 3], t2, EDGE_PUS, ops=ops)
    with pytest.raises(KeyError, match="unsupported on GPU"):
        wl.evaluate(["CPU", "CPU", "GPU", "CPU"])
    assert wl.evaluate(["CPU", "CPU", "GPU", "CPU"],
                       allow_infeasible=True) == (float("inf"), float("inf"))


@pytest.mark.parametrize("seed", range(6))
def test_single_pu_matches_scalar(seed):
    rng = np.random.default_rng(100 + seed)
    n = int(rng.integers(1, 20))
    ops, table = random_table(rng, n, drop_frac=0.3)
    chain = list(range(n))
    wl = Workload.build(chain, table, EDGE_PUS, ops=ops)
    for pu in PUS:
        got = single_pu_cost(chain, pu, ops, table, EDGE_PUS, workload=wl)
        if any(not table.supported(oi, pu) for oi in chain):
            assert got is None
            continue
        want = evaluate_sequential_reference(chain, [pu] * n, ops, table,
                                             EDGE_PUS)
        assert got == pytest.approx(want, rel=1e-12)


def test_select_and_tail_are_views_of_the_same_costs():
    rng = np.random.default_rng(7)
    ops, table = random_table(rng, 12, drop_frac=0.0)
    chain = list(range(12))
    wl = Workload.build(chain, table, EDGE_PUS, ops=ops)
    sub_chain = [3, 5, 8, 11]
    sub = wl.select(sub_chain)
    fresh = Workload.build(sub_chain, table, EDGE_PUS, ops=ops)
    np.testing.assert_array_equal(sub.dense.w, fresh.dense.w)
    np.testing.assert_array_equal(sub.dense.mask, fresh.dense.mask)
    np.testing.assert_array_equal(sub.dense.dispatch, fresh.dense.dispatch)
    assign = random_assignment(rng, table, sub_chain)
    assert sub.evaluate(assign) == fresh.evaluate(assign)
    t = wl.tail(4)
    fresh_t = Workload.build(chain[4:], table, EDGE_PUS, ops=ops)
    np.testing.assert_array_equal(t.dense.w, fresh_t.dense.w)
    assert t.chain == chain[4:]


@pytest.mark.parametrize("seed", range(6))
def test_under_condition_matches_adjusted_table(seed):
    """Column scalings on the dense view == the scalar adjusted_table
    rebuild, cell for cell (kernel share scaled, dispatch untouched,
    unavailable PUs dropped)."""
    rng = np.random.default_rng(200 + seed)
    n = int(rng.integers(2, 15))
    ops, table = random_table(rng, n)
    chain = list(range(n))
    wl = Workload.build(chain, table, EDGE_PUS, ops=ops)
    cond = RuntimeCondition(slowdown={"GPU": 2.5, "CPU": 1.3},
                            unavailable=frozenset({"NPU"}))
    adj_wl = wl.under_condition(cond.slowdown, cond.unavailable)
    adj_t = adjusted_table(table, cond)
    for pos, oi in enumerate(chain):
        for j, pu in enumerate(wl.pu_names):
            e = adj_t.get(oi, pu)
            if e is None:
                assert not adj_wl.dense.mask[pos, j]
                assert adj_wl.dense.w[pos, j] == float("inf")
            else:
                assert adj_wl.dense.mask[pos, j]
                assert adj_wl.dense.w[pos, j] == pytest.approx(e.w, rel=1e-15)


def test_spliced_mixes_prefix_and_tail():
    rng = np.random.default_rng(5)
    ops, table = random_table(rng, 8, drop_frac=0.0)
    chain = list(range(8))
    wl = Workload.build(chain, table, EDGE_PUS, ops=ops)
    adj = wl.under_condition({"GPU": 4.0}, ())
    sp = wl.spliced(adj, 4)
    np.testing.assert_array_equal(sp.dense.w[:4], wl.dense.w[:4])
    np.testing.assert_array_equal(sp.dense.w[4:], adj.dense.w[4:])


def test_best_solo_matches_best_single():
    from benchmarks.common import best_single
    rng = np.random.default_rng(11)
    ops, table = random_table(rng, 10, drop_frac=0.0)
    chain = list(range(10))
    wl = Workload.build(chain, table, EDGE_PUS, ops=ops)
    b, v, vals = wl.best_solo()
    b2, v2, vals2 = best_single(chain, ops, table, workload=wl)
    assert (b, v) == (b2, v2)
    assert vals == vals2
