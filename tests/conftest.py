"""Test path setup: make ``repro`` (src/) and ``benchmarks`` importable
regardless of how pytest is invoked.

NOTE: no XLA_FLAGS here on purpose — smoke tests and benches must see the
single real CPU device; only launch/dryrun.py forces 512 host devices
(and does so before any other import, in its own process).
"""
import os
import sys

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
for p in (_ROOT, os.path.join(_ROOT, "src")):
    if p not in sys.path:
        sys.path.insert(0, p)
