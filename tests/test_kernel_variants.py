"""Payload-level ref-vs-Pallas equivalence: the variant tables served to
heterogeneous targets, parametrized over dtype with tolerance buckets
matching ``targets.VARIANT_TOL`` (blockwise accumulation reorders sums,
so bf16 needs a much wider bucket than f32)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.payloads import (attention_payloads, bind_variants,
                                    eltwise_payloads, moe_payloads,
                                    sort_payloads, ssd_payloads)

jax.config.update("jax_enable_x64", False)

# per-dtype tolerance buckets (match tests/test_kernels.py)
TOL = {jnp.float32: (3e-5, 3e-5), jnp.bfloat16: (3e-2, 3e-2)}
MOE_TOL = {jnp.float32: (2e-4, 2e-4), jnp.bfloat16: (5e-2, 5e-2)}
DTYPES = [jnp.float32, jnp.bfloat16]


def rand(key, shape, dtype):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def _close(got, want, tol):
    atol, rtol = tol
    np.testing.assert_allclose(
        np.asarray(got, np.float64), np.asarray(want, np.float64),
        atol=atol, rtol=rtol)


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
@pytest.mark.parametrize("causal", [True, False], ids=["causal", "full"])
def test_attention_payload_pallas_matches_ref(dtype, causal):
    B, Tq, Tk, Hq, Hk, D = 1, 96, 96, 4, 2, 32     # GQA: 2 query groups
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(0), 3)
    k, v = rand(kk, (B, Tk, Hk, D), dtype), rand(kv, (B, Tk, Hk, D), dtype)
    q = rand(kq, (B, Tq, Hq, D), dtype)
    table = attention_payloads(k, v, causal=causal, block_q=32, block_k=32,
                               interpret=True)
    _close(table["pallas"](q), table["ref"](q), TOL[dtype])


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_attention_payload_decode_q_offset(dtype):
    """Single-query decode against a longer KV cache: the q_offset edge
    case (query row 299 of a 300-token causal context)."""
    B, Tk, Hq, Hk, D = 1, 300, 4, 2, 32
    kq, kk, kv = jax.random.split(jax.random.PRNGKey(1), 3)
    k, v = rand(kk, (B, Tk, Hk, D), dtype), rand(kv, (B, Tk, Hk, D), dtype)
    q = rand(kq, (B, 1, Hq, D), dtype)
    table = attention_payloads(k, v, causal=True, q_offset=Tk - 1,
                               block_q=32, block_k=32, interpret=True)
    _close(table["pallas"](q), table["ref"](q), TOL[dtype])


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
@pytest.mark.parametrize("with_s0", [False, True], ids=["zero-s0", "s0"])
def test_ssd_payload_pallas_matches_ref(dtype, with_s0):
    B, T, H, N, P = 1, 64, 2, 8, 16
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    c, b = rand(ks[0], (B, T, H, N), dtype), rand(ks[1], (B, T, H, N), dtype)
    x = rand(ks[2], (B, T, H, P), dtype)
    log_a = (-0.05 * jnp.abs(jax.random.normal(ks[3], (B, T, H)))
             ).astype(dtype)
    s0 = rand(ks[4], (B, H, N, P), dtype) if with_s0 else None
    table = ssd_payloads(c, b, log_a, initial_state=s0, chunk=32,
                         interpret=True)
    _close(table["pallas"](x), table["ref"](x), TOL[dtype])


@pytest.mark.parametrize("dtype", DTYPES, ids=["f32", "bf16"])
def test_moe_payload_pallas_matches_ref(dtype):
    T, d, E, F, top_k = 32, 16, 4, 16, 2
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = rand(ks[0], (T, d), dtype)
    w_gate = rand(ks[1], (d, E), dtype)
    w_up = rand(ks[2], (E, d, 2 * F), dtype) * 0.1
    w_down = rand(ks[3], (E, F, d), dtype) * 0.1
    capacity = 32        # ample: routing identical across dialects
    table = moe_payloads(w_gate, w_up, w_down, capacity=capacity,
                         top_k=top_k, block_m=16, block_f=16, interpret=True)
    _close(table["pallas"](x), table["ref"](x), MOE_TOL[dtype])


def test_eltwise_payload_numpy_matches_ref():
    x = rand(jax.random.PRNGKey(4), (8, 8), jnp.float32)
    table = eltwise_payloads(scale=1.25)
    got = table["numpy"](x)
    assert isinstance(got, np.ndarray)
    _close(got, table["ref"](x), TOL[jnp.float32])


def test_sort_payload_numpy_matches_ref_bitwise():
    """Sorting is exact: the host variant must agree bitwise, and both
    dialects must preserve the activation's shape."""
    x = rand(jax.random.PRNGKey(5), (4, 16), jnp.float32)
    table = sort_payloads()
    r, n = table["ref"](x), table["numpy"](x)
    assert r.shape == n.shape == x.shape
    assert np.asarray(r).tobytes() == np.asarray(n).tobytes()


def test_bind_variants_installs_table():
    from repro.core.op import FusedOp
    op = FusedOp("gate", "act", ((4, 4),), (4, 4), fn=None)
    x = rand(jax.random.PRNGKey(6), (4, 4), jnp.float32)
    table = eltwise_payloads(scale=2.0)
    bind_variants(op, table, example_inputs=(x,))
    assert op.fn is table["ref"]
    assert op.variants == {"numpy": table["numpy"]}
    assert op.meta["example_inputs"] == (x,)
    assert op.payload_for("numpy") is table["numpy"]
    assert op.payload_for("ref") is table["ref"]
    assert op.payload_for("pallas") is table["ref"]     # unknown -> ref
    with pytest.raises(ValueError, match="ref"):
        bind_variants(op, {"numpy": table["numpy"]})
