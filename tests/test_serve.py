"""Streaming serving engine: traces, the serving loop, SLO and
infeasibility shedding, and handle-alias pooling.

The companion warm-start correctness suite is
``test_incremental_replan.py``; here we test the traffic layer built on
top of it: reproducible arrival traces, a full run-to-drain over the
orchestrator's admission API, the report's accounting, and that the
loop degrades by shedding (never by crashing) under deadlines and
conditions that strand a model.
"""
import numpy as np
import pytest

from repro.core import (Arrival, ArrivalTrace, CostEntry, CostTable,
                        EDGE_PUS, FusedOp, Orchestrator, RuntimeCondition,
                        ServingEngine, chain_graph)

PUS = ("CPU", "GPU", "NPU")


def make_engine(rng, lengths=(4, 5, 3), npu_only_idx=None, **engine_kw):
    """Chain models of the given lengths over one shared cost table
    (``CostTable`` keys by op index, so all models price through it);
    ``npu_only_idx`` strands one index on the NPU for the
    condition-shedding test."""
    table = CostTable(list(PUS))
    for i in range(max(lengths)):
        sup = ("NPU",) if i == npu_only_idx else PUS
        for pu in sup:
            table.set(i, pu, CostEntry(
                kernel=float(rng.uniform(1e-5, 1e-3)),
                dispatch=float(rng.uniform(0, 1e-5)),
                h2d=float(rng.uniform(0, 1e-4)),
                d2h=float(rng.uniform(0, 1e-4)),
                power=float(rng.uniform(5.0, 30.0))))
    models = {
        f"model{k}": chain_graph([FusedOp(name=f"m{k}o{i}", kind="other",
                                          out_shape=(4,))
                                  for i in range(n)])
        for k, n in enumerate(lengths)}
    orch = Orchestrator(table)
    return orch, ServingEngine(orch, models, **engine_kw)


# -- arrival traces ---------------------------------------------------------

def test_poisson_trace_is_reproducible_and_sorted():
    a = ArrivalTrace.poisson(["x", "y"], rate=5.0, n=20, seed=3)
    b = ArrivalTrace.poisson(["x", "y"], rate=5.0, n=20, seed=3)
    assert a.arrivals == b.arrivals
    assert len(a) == 20 and a.kind == "poisson"
    ts = [v.time for v in a.arrivals]
    assert ts == sorted(ts) and all(t > 0 for t in ts)
    assert {v.model for v in a.arrivals} <= {"x", "y"}


def test_arrival_trace_json_round_trip():
    a = ArrivalTrace.bursty(["x", "y"], rate=7.0, n=15, seed=5, slo=0.25)
    b = ArrivalTrace.from_json(a.to_json())
    assert b.kind == a.kind
    assert b.arrivals == a.arrivals          # floats round-trip via repr
    # and the artifact is plain JSON, re-serializable stably
    assert ArrivalTrace.from_json(b.to_json()).arrivals == a.arrivals


def test_chaos_trace_json_round_trip():
    from repro.core import ChaosEvent, ChaosTrace
    t = ChaosTrace([
        ChaosEvent(time=0.2, kind="pu_lost", lane="GPU"),
        ChaosEvent(time=0.05, kind="transient", rid=3, count=2),
        ChaosEvent(time=0.4, kind="pu_restored", lane="GPU"),
        ChaosEvent(time=0.1, kind="stall", lane="CPU", delay=0.4),
    ], kind="mixed", seed=9)
    assert [e.time for e in t.events] == sorted(e.time for e in t.events)
    u = ChaosTrace.from_json(t.to_json())
    assert u.kind == t.kind and u.seed == t.seed
    assert u.events == t.events


def test_chaos_event_validation():
    from repro.core import ChaosEvent
    with pytest.raises(ValueError):
        ChaosEvent(time=0.0, kind="meteor")
    with pytest.raises(ValueError):
        ChaosEvent(time=0.0, kind="pu_lost")          # needs a lane
    with pytest.raises(ValueError):
        ChaosEvent(time=-1.0, kind="transient", rid=0)


def test_chaos_trace_requires_real_execution():
    rng = np.random.default_rng(0)
    from repro.core import ChaosEvent, ChaosTrace
    _, eng = make_engine(rng)
    trace = ArrivalTrace.poisson(["model0"], rate=10.0, n=2, seed=0)
    chaos = ChaosTrace([ChaosEvent(time=0.0, kind="transient", rid=0)])
    with pytest.raises(ValueError, match="execution='real'"):
        eng.serve(trace, chaos=chaos)


def test_bursty_trace_adds_companions():
    base = ArrivalTrace.poisson(["x"], rate=5.0, n=10, seed=0)
    burst = ArrivalTrace.bursty(["x"], rate=5.0, n=10, burst_every=5,
                                burst_size=3, seed=0)
    assert len(burst) == len(base) + 2 * 2   # 2 bursts x 2 companions
    ts = [v.time for v in burst.arrivals]
    assert ts == sorted(ts)
    assert burst.kind == "bursty"


def test_trace_validation():
    with pytest.raises(ValueError):
        ArrivalTrace.poisson(["x"], rate=0.0, n=3)
    with pytest.raises(ValueError):
        ArrivalTrace.poisson(["x"], rate=1.0, n=-1)


def test_custom_trace_sorts_on_init():
    tr = ArrivalTrace([Arrival(1, "x", 2.0), Arrival(0, "x", 1.0)])
    assert [a.rid for a in tr.arrivals] == [0, 1]


# -- serving loop -----------------------------------------------------------

def test_serve_completes_all_without_deadlines():
    rng = np.random.default_rng(0)
    orch, eng = make_engine(rng, max_concurrent=3)
    trace = ArrivalTrace.poisson(list(eng._graphs), rate=50.0, n=15, seed=1)
    rep = eng.serve(trace)
    assert rep.n_requests == 15
    assert rep.completed == 15 and rep.shed == 0
    assert rep.throughput > 0 and rep.makespan > 0
    assert rep.latency_p99 >= rep.latency_p50 > 0
    assert rep.plan_events > 0 and rep.plan_ms_p99 >= rep.plan_ms_p50 >= 0
    # every serving-loop re-plan took the incremental path
    assert rep.replans_warm > 0 and rep.replans_cold == 0
    for r in rep.requests:
        assert r.ops_done == r.ops_total and r.finished_at is not None
        assert r.latency >= 0


def test_serve_queues_beyond_capacity():
    rng = np.random.default_rng(1)
    orch, eng = make_engine(rng, max_concurrent=1)
    # everything arrives at once: strictly serialized service
    tr = ArrivalTrace([Arrival(i, f"model{i % 3}", 0.0) for i in range(6)])
    rep = eng.serve(tr)
    assert rep.completed == 6
    assert rep.occupancy_mean <= 1.0 + 1e-9
    # handle aliasing stays bounded by peak concurrency per model
    assert len(orch._regs) <= 3 * eng.max_concurrent


def test_serve_sheds_on_impossible_slo():
    rng = np.random.default_rng(2)
    orch, eng = make_engine(rng, max_concurrent=2)
    tr = ArrivalTrace([Arrival(i, "model0", float(i), slo=1e-12)
                       for i in range(4)])
    rep = eng.serve(tr)
    assert rep.completed == 0 and rep.shed == 4
    assert all(r.shed_reason == "slo" for r in rep.requests)


def test_serve_sheds_infeasible_model_under_condition():
    rng = np.random.default_rng(3)
    # index 4 exists only in model1's chain and is NPU-only
    orch, eng = make_engine(rng, lengths=(4, 5, 3), npu_only_idx=4,
                            max_concurrent=3)
    orch.on_condition(RuntimeCondition(unavailable={"NPU"}))
    tr = ArrivalTrace([Arrival(0, "model0", 0.0), Arrival(1, "model1", 0.0),
                       Arrival(2, "model2", 0.0)])
    rep = eng.serve(tr)
    shed = {r.model: r for r in rep.requests if r.shed}
    assert set(shed) == {"model1"}
    assert shed["model1"].shed_reason == "infeasible"
    assert rep.completed == 2 and rep.shed == 1


def test_serve_engine_validation():
    rng = np.random.default_rng(4)
    with pytest.raises(ValueError):
        make_engine(rng, max_concurrent=0)
    orch = Orchestrator(CostTable(list(PUS)))
    with pytest.raises(ValueError):
        ServingEngine(orch, {})


def test_report_dict_round_trips_without_requests():
    rng = np.random.default_rng(5)
    orch, eng = make_engine(rng)
    rep = eng.serve(ArrivalTrace.poisson(list(eng._graphs), rate=20.0, n=5,
                                         seed=2))
    d = rep.to_dict()
    assert "requests" not in d
    assert d["n_requests"] == 5
    assert d["completed"] + d["shed"] == 5


def test_handle_free_list_reuses_handles():
    rng = np.random.default_rng(6)
    orch, eng = make_engine(rng, max_concurrent=2)
    n_before = None
    for _ in range(3):      # repeated drains must not grow registrations
        eng.serve(ArrivalTrace.poisson(list(eng._graphs), rate=30.0, n=6,
                                       seed=7))
        if n_before is None:
            n_before = len(orch._regs)
    assert len(orch._regs) == n_before
