"""Warm-start incremental re-planning: every warm plan must be bitwise
identical to a cold solve of the same remaining state.

The orchestrator's serving path (``admit``/``advance``/``retire``/
``replan_active``) is served by the pooled
:class:`IncrementalConcurrentSolver`; the cold ``solve_concurrent`` /
``solve_concurrent_horizon`` routes are the oracle.  A property-style
trace test replays random admission/advance/retire/condition event
sequences and cross-checks every plan the orchestrator hands out —
including active-set transitions M=3 -> 2 -> 1, windowed re-plans, and
condition fold-in — plus the documented ``None`` contract, infeasibility
error parity, and the bounded-LRU eviction counters.
"""
import numpy as np
import pytest

from repro.core import (ConcurrentCaches, CostEntry, CostTable, EDGE_PUS,
                        FusedOp, InfeasibleScheduleError, Orchestrator,
                        RuntimeCondition, Workload, chain_graph,
                        solve_concurrent, solve_concurrent_horizon)

PUS = ("CPU", "GPU", "NPU")


def random_model(rng, n_ops, drop_frac=0.2):
    """A chain graph plus its explicit cost table (every op supported on
    at least one PU, so traces stay feasible under slowdown-only
    conditions)."""
    table = CostTable(list(PUS))
    ops = []
    for i in range(n_ops):
        ops.append(FusedOp(name=f"o{i}", kind="other", out_shape=(4,)))
        sup = [p for p in PUS if rng.random() > drop_frac]
        if not sup:
            sup = [PUS[int(rng.integers(len(PUS)))]]
        for pu in sup:
            table.set(i, pu, CostEntry(
                kernel=float(rng.uniform(1e-6, 1e-3)),
                dispatch=float(rng.uniform(0, 1e-5)),
                h2d=float(rng.uniform(0, 1e-4)),
                d2h=float(rng.uniform(0, 1e-4)),
                power=float(rng.uniform(5.0, 30.0))))
    return chain_graph(ops), table


def make_orch(rng, n_models=3, n_ops_lo=4, n_ops_hi=8):
    models = [random_model(rng, int(rng.integers(n_ops_lo, n_ops_hi)))
              for _ in range(n_models)]
    orch = Orchestrator(models[0][1])
    handles = [orch.register(g, table=t) for g, t in models]
    return orch, handles, models


def cold_reference(orch, objective, horizon_states=None):
    """Independent cold solve of the orchestrator's exact active state:
    condition-scaled workloads, tails from progress, sorted handle
    order, fresh caches."""
    items = [(h, p) for h, p in sorted(orch._active.items())
             if p < orch.workload(h).n]
    if not items:
        return None
    wls = []
    for h, p in items:
        wl = orch.workload(h)
        if not orch.condition.nominal:
            wl = wl.under_condition(orch.condition.slowdown,
                                    orch.condition.unavailable)
        wls.append(wl if p == 0 else wl.tail(p))
    if horizon_states is not None:
        return solve_concurrent_horizon(wls, orch.contention, objective,
                                        caches=ConcurrentCaches(),
                                        horizon_states=horizon_states)
    return solve_concurrent(wls, orch.contention, objective,
                            caches=ConcurrentCaches())


def assert_bitwise(plan, cold):
    if plan is None or cold is None:
        assert plan is None and cold is None
        return
    s = plan.schedule
    assert s.latency == cold.latency
    assert s.energy == cold.energy
    assert s.steps == cold.steps


def replay_trace(seed, horizon_states=None, n_events=15):
    """Random admission/advance/retire/condition trace; every plan the
    orchestrator returns is cross-checked bitwise against a cold solve."""
    rng = np.random.default_rng(seed)
    orch, handles, _ = make_orch(rng)
    objective = "latency" if seed % 2 == 0 else "energy"
    pool = list(handles)
    checked = 0
    for _ in range(n_events):
        ev = rng.random()
        if ev < 0.35 and pool:                       # admit
            h = pool.pop(int(rng.integers(len(pool))))
            plan = orch.admit(h, objective, horizon_states=horizon_states)
        elif ev < 0.70 and orch._active:             # advance + re-plan
            h = sorted(orch._active)[int(rng.integers(len(orch._active)))]
            orch.advance(h, int(rng.integers(1, 3)))
            plan = orch.replan_active(objective,
                                      horizon_states=horizon_states)
        elif ev < 0.85 and orch._active:             # retire one member
            h = sorted(orch._active)[int(rng.integers(len(orch._active)))]
            orch.retire(h, objective, horizon_states=horizon_states)
            pool.append(h)
            plan = orch.replan_active(objective,
                                      horizon_states=horizon_states)
        else:                                        # condition fold-in
            pu = PUS[int(rng.integers(len(PUS)))]
            factor = float(rng.uniform(1.0, 2.0))
            orch.on_condition(RuntimeCondition(slowdown={pu: factor}))
            plan = orch.replan_active(objective,
                                      horizon_states=horizon_states)
        cold = cold_reference(orch, objective, horizon_states)
        assert_bitwise(plan, cold)
        if plan is not None:
            checked += 1
    return orch, checked


@pytest.mark.parametrize("seed", range(4))
def test_trace_full_replans_bitwise_equal_cold(seed):
    orch, checked = replay_trace(seed)
    assert checked > 0
    assert orch.stats["replans_warm"] > 0
    # small default-coexec grids: the incremental solver must never
    # delegate back to the cold route
    assert orch.stats["replans_cold"] == 0


@pytest.mark.parametrize("seed", range(2))
def test_trace_windowed_replans_bitwise_equal_cold(seed):
    orch, checked = replay_trace(seed, horizon_states=64)
    assert checked > 0
    assert orch.stats["replans_warm"] > 0
    assert orch.stats["replans_cold"] == 0


def test_shrinking_active_set_stays_bitwise():
    """M=3 -> 2 -> 1 retirement ladder, re-planning after each step."""
    rng = np.random.default_rng(7)
    orch, handles, _ = make_orch(rng)
    for h in handles:
        plan = orch.admit(h)
        assert_bitwise(plan, cold_reference(orch, "latency"))
    for h in handles:
        orch.advance(h, 1)
    for h in handles:
        orch.retire(h)
        plan = orch.replan_active()
        assert_bitwise(plan, cold_reference(orch, "latency"))


def test_admit_retire_none_contract():
    rng = np.random.default_rng(11)
    orch, (h0, h1, _), _ = make_orch(rng)
    # fully-advanced single member: admit and replan_active return None
    orch.admit(h0)
    orch.advance(h0, orch.workload(h0).n)
    assert orch.replan_active() is None
    assert orch.admit(h1) is not None       # an unfinished member again
    orch.advance(h1, orch.workload(h1).n)
    assert orch.admit(h0) is None           # everything fully advanced
    assert orch.retire(h0) is None          # survivor is fully advanced
    assert orch.retire(h1) is None          # active set empties
    # unknown handle raises (bookkeeping claim about a specific request)
    with pytest.raises(KeyError):
        orch.retire(12345)


def test_retire_to_empty_returns_none():
    rng = np.random.default_rng(13)
    orch, (h0, _, _), _ = make_orch(rng)
    assert orch.admit(h0) is not None
    assert orch.retire(h0) is None


def test_infeasible_error_message_matches_cold():
    """A condition that strands an op must raise the same
    InfeasibleScheduleError from the warm path as from the cold solve.

    The stranded model is a diamond DAG (not a chain) so that
    ``on_condition``'s eager per-chain DynamicScheduler re-plan does not
    intercept first — the error under test is the concurrent route's."""
    from repro.core import OpGraph

    table = CostTable(list(PUS))
    ops = []
    for i in range(4):
        ops.append(FusedOp(name=f"o{i}", kind="other", out_shape=(4,)))
        for pu in (PUS if i != 2 else ("NPU",)):     # op 2: NPU-only
            table.set(i, pu, CostEntry(kernel=1e-4, dispatch=0.0,
                                       h2d=0.0, d2h=0.0, power=10.0))
    g = OpGraph(ops, edges=[(0, 1), (0, 2), (1, 3), (2, 3)])
    rng = np.random.default_rng(17)
    g2, t2 = random_model(rng, 5, drop_frac=0.0)     # fully supported
    g3, t3 = random_model(rng, 4, drop_frac=0.0)
    orch = Orchestrator(table)
    for graph, t in ((g, table), (g2, t2), (g3, t3)):
        orch.admit(orch.register(graph, table=t))
    orch.on_condition(orch.condition.lose("NPU"))
    with pytest.raises(InfeasibleScheduleError) as warm_err:
        orch.replan_active()
    with pytest.raises(InfeasibleScheduleError) as cold_err:
        cold_reference(orch, "latency")
    assert str(warm_err.value) == str(cold_err.value)
    assert "o2" in str(warm_err.value)


def test_plan_cache_eviction_counters():
    rng = np.random.default_rng(19)
    models = [random_model(rng, 4) for _ in range(4)]
    orch = Orchestrator(models[0][1], max_cached_plans=2)
    hs = [orch.register(g, table=t) for g, t in models]
    for h in hs:
        orch.plan([h])
    assert orch.stats["plan_evictions"] >= 2
    assert len(orch._plans) <= 2


def test_pool_warm_and_cond_view_eviction_counters():
    rng = np.random.default_rng(23)
    orch, (h0, h1, _), _ = make_orch(rng)
    orch._max_pools = 1
    # condition views: one per (handle, condition), capped at _max_pools
    orch.on_condition(RuntimeCondition(slowdown={"CPU": 1.5}))
    orch.plan([h0])
    orch.plan([h1])
    assert orch.stats["cond_view_evictions"] >= 1
    assert len(orch._cond_views) <= 1
    # warm solvers: distinct active signature-tuples under cap 1
    assert orch.admit(h0) is not None
    assert orch.retire(h0) is None
    assert orch.admit(h1) is not None
    assert orch.stats["warm_evictions"] >= 1
    assert len(orch._warm) <= 1
    # solver pools are keyed by condition alone and condition changes
    # invalidate disagreeing entries, so in practice one entry is live;
    # the LRU bound still guards the cache — exercise it directly
    orch._pools[("synthetic-a",)] = ConcurrentCaches()
    orch._pools[("synthetic-b",)] = ConcurrentCaches()
    orch._evict_lru(orch._pools, orch._max_pools, "pool_evictions")
    assert orch.stats["pool_evictions"] >= 1
    assert len(orch._pools) <= 1


def test_windowed_plan_mode_and_progress():
    """A horizon plan is a strict prefix: mode 'horizon' and every
    unfinished request advances at least one op."""
    rng = np.random.default_rng(29)
    orch, handles, _ = make_orch(rng)
    for h in handles:
        orch.admit(h)
    plan = orch.replan_active(horizon_states=8)
    assert plan.schedule.mode == "horizon"
    m = len(plan.handles)
    for r in range(m):
        assert any(st.ops[r] is not None for st in plan.schedule.steps)


def test_bounded_caches_still_bitwise():
    """Aggressively tiny cache budgets only cost rebuilds, never change
    plans."""
    rng = np.random.default_rng(31)
    orch, handles, _ = make_orch(rng)
    for h in handles:
        orch.admit(h)
    pool = orch._pool()
    pool.max_table_bytes = 1          # evict everything but the newest
    pool.max_group_scopes = 1
    for h in handles:
        orch.advance(h, 1)
        plan = orch.replan_active()
        assert_bitwise(plan, cold_reference(orch, "latency"))
