"""Degraded-mode serving: chaos-scripted faults through the real
execution loop.

The serving-loop counterpart of ``test_fault_injection.py``: scripted
:class:`ChaosTrace` events (transient storms, stalls, PU loss and
return) drive the per-target :class:`HealthMonitor` breakers while
requests stream through ``ServingEngine(execution="real")``.  The
invariants under every scenario:

* **never a hang** — every test body runs under a hard SIGALRM timeout;
* **never a silent wrong answer** — every completed request's outputs
  are bitwise-equal to a fault-free solo run, or the request is shed
  with a typed reason (:data:`SHED_REASONS`);
* **no leaked handles** — after a full run the orchestrator's active
  set is empty and the engine's free pools hold each alias exactly
  once.
"""
from __future__ import annotations

import contextlib
import signal

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.core import (ArrivalTrace, ChaosEvent, ChaosTrace,
                        EdgeSoCCostModel, ExecutionPolicy, FusedOp,
                        HealthPolicy, Orchestrator, SHED_REASONS,
                        ServingEngine, chain_graph)

pytestmark = pytest.mark.chaos


# ---------------------------------------------------------------------------
# hard timeout (mirrors test_fault_injection.py: SIGALRM, no pytest-timeout)
# ---------------------------------------------------------------------------


class HardTimeout(Exception):
    pass


@contextlib.contextmanager
def hard_timeout(seconds: float = 120.0):
    def handler(signum, frame):
        raise HardTimeout(f"test exceeded the {seconds}s hard timeout — "
                          "a serving path blocked")
    old = signal.signal(signal.SIGALRM, handler)
    signal.setitimer(signal.ITIMER_REAL, seconds)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0.0)
        signal.signal(signal.SIGALRM, old)


@pytest.fixture(autouse=True)
def _no_hang():
    with hard_timeout(120.0):
        yield


# ---------------------------------------------------------------------------
# fixtures: jax chain models behind a fresh engine per test
# ---------------------------------------------------------------------------

DIM = 8


def _payload(salt: int):
    w = jnp.asarray(np.random.default_rng(salt).standard_normal(
        (DIM, DIM)).astype(np.float32))

    def fn(x, w=w):
        return jnp.tanh(x @ w)
    return fn


def _jax_chain(n: int, salt: int):
    ops = [FusedOp(name=f"op{salt}_{k}", kind="matmul", flops=1e6,
                   bytes_moved=1e4, fn=_payload(salt * 97 + k))
           for k in range(n)]
    g = chain_graph(ops)
    x = jnp.asarray(np.random.default_rng(salt).standard_normal(
        (1, DIM)).astype(np.float32))
    return g, {0: (x,)}


def fresh_engine(**kw):
    """A fresh two-model real-execution engine (chaos runs mutate the
    session condition, so nothing is shared between tests)."""
    gA, inA = _jax_chain(5, salt=1)
    gB, inB = _jax_chain(4, salt=2)
    orch = Orchestrator(EdgeSoCCostModel())
    kw.setdefault("exec_policy", ExecutionPolicy(timeout=20.0))
    kw.setdefault("health_policy", HealthPolicy(cooldown=0.005))
    kw.setdefault("max_concurrent", 2)
    eng = ServingEngine(orch, {"A": gA, "B": gB}, execution="real",
                        inputs={"A": inA, "B": inB}, **kw)
    return orch, eng


def _trace(n=10, rate=50.0, seed=0):
    return ArrivalTrace.poisson(["A", "B"], rate=rate, n=n, seed=seed)


def assert_no_silent_wrong_answer(rep):
    """The headline invariant: completed => bitwise, else typed shed."""
    assert rep.bitwise_failures == 0
    for rec in rep.requests:
        if rec.shed:
            assert rec.shed_reason in SHED_REASONS
        elif rec.finished_at is not None:
            assert rec.bitwise_ok is True
    assert rep.completed + rep.shed == rep.n_requests


def assert_handle_ledger_clean(orch, eng, rep):
    """Satellite: shed and faulted requests retire their handles — no
    stale active entries, no duplicated or leaked aliases."""
    assert orch._active == {}
    for rec in rep.requests:
        assert rec.handle is None
    for model, free in eng._free.items():
        assert len(free) == len(set(free)), f"duplicate alias in {model}"
    all_free = [h for free in eng._free.values() for h in free]
    assert len(all_free) == len(set(all_free))


# ---------------------------------------------------------------------------
# scenarios
# ---------------------------------------------------------------------------


def test_fault_free_real_serving_is_bitwise():
    orch, eng = fresh_engine()
    rep = eng.serve(_trace(n=8))
    assert rep.completed == 8 and rep.shed == 0
    assert rep.bitwise_checked == 8
    assert rep.exec_wall_s > 0.0
    assert_no_silent_wrong_answer(rep)
    assert_handle_ledger_clean(orch, eng, rep)


def test_compiled_real_serving_is_bitwise():
    orch, eng = fresh_engine(compile_exec=True)
    rep = eng.serve(_trace(n=6))
    assert rep.completed == 6 and rep.shed == 0
    assert_no_silent_wrong_answer(rep)
    assert_handle_ledger_clean(orch, eng, rep)


def test_transient_storm_retries_and_completes():
    orch, eng = fresh_engine()
    trace = _trace(n=8, seed=1)
    chaos = ChaosTrace([
        ChaosEvent(time=0.0, kind="transient", count=3),
    ], kind="transient_storm", seed=1)
    rep = eng.serve(trace, chaos=chaos)
    # the per-op retry loop absorbs transients invisibly; whether any
    # escalate to a window retry is timing-dependent — correctness isn't
    assert rep.completed == 8 and rep.shed == 0
    assert_no_silent_wrong_answer(rep)
    assert_handle_ledger_clean(orch, eng, rep)


def test_rid_targeted_transient_hits_that_request():
    orch, eng = fresh_engine()
    trace = _trace(n=6, seed=2)
    chaos = ChaosTrace([
        ChaosEvent(time=trace.arrivals[2].time, kind="transient",
                   rid=trace.arrivals[2].rid, count=1),
    ], kind="rid_transient", seed=2)
    rep = eng.serve(trace, chaos=chaos)
    assert rep.completed == 6
    assert_no_silent_wrong_answer(rep)
    assert_handle_ledger_clean(orch, eng, rep)


def test_pu_loss_opens_breaker_and_recovers_fleet_wide():
    orch, eng = fresh_engine()
    trace = _trace(n=12, seed=3)
    chaos = ChaosTrace([
        ChaosEvent(time=trace.arrivals[4].time, kind="pu_lost", lane="CPU"),
    ], kind="pu_lost", seed=3)
    rep = eng.serve(trace, chaos=chaos)
    assert rep.recoveries >= 1
    assert rep.breaker["opens"] >= 1
    assert any(t["to"] == "open" and t["reason"] == "pu_lost"
               for t in rep.breaker["transitions"])
    # recovery latency was measured for each fault -> re-plan cycle
    assert rep.recovery_ms_p50 > 0.0
    # requests in flight at the loss completed despite it
    assert rep.recovered >= 1
    assert_no_silent_wrong_answer(rep)
    assert_handle_ledger_clean(orch, eng, rep)


def test_pu_return_readmits_via_observed_probe():
    orch, eng = fresh_engine()
    trace = _trace(n=14, seed=4)
    chaos = ChaosTrace([
        ChaosEvent(time=trace.arrivals[3].time, kind="pu_lost", lane="CPU"),
        ChaosEvent(time=trace.arrivals[8].time, kind="pu_restored",
                   lane="CPU"),
    ], kind="pu_lost_return", seed=4)
    rep = eng.serve(trace, chaos=chaos)
    assert rep.breaker["opens"] >= 1
    assert rep.breaker["readmits"] >= 1, \
        "the returned PU was never probe-re-admitted"
    tos = [t["to"] for t in rep.breaker["transitions"]
           if t["pu"] == "CPU"]
    assert "half_open" in tos and "closed" in tos
    # the final probe_ok can only come after the lane really returned
    assert rep.breaker["targets"]["CPU"]["state"] == "closed"
    assert_no_silent_wrong_answer(rep)
    assert_handle_ledger_clean(orch, eng, rep)


def test_stall_never_hangs_and_sheds_typed():
    # watchdog budget far below the injected stall: the window times out
    # repeatedly; the loop must either recover around the lane or shed
    # typed — never hang (the autouse alarm enforces it)
    orch, eng = fresh_engine(
        exec_policy=ExecutionPolicy(timeout=0.2, min_timeout=0.2,
                                    max_retries=0),
        max_window_retries=1)
    trace = _trace(n=6, seed=5)
    chaos = ChaosTrace([
        ChaosEvent(time=0.0, kind="stall", lane="CPU", delay=30.0,
                   count=-1),
    ], kind="stall", seed=5)
    rep = eng.serve(trace, chaos=chaos)
    assert_no_silent_wrong_answer(rep)
    assert_handle_ledger_clean(orch, eng, rep)
    # the stall left a trace: retries, a breaker event, or typed sheds
    assert rep.retried >= 1 or rep.breaker["opens"] >= 1 or rep.shed >= 1
    for rec in rep.requests:
        if rec.shed:
            assert rec.shed_reason in ("timeout", "fault", "slo",
                                       "infeasible")


def test_straggler_drift_is_observed():
    orch, eng = fresh_engine(
        health_policy=HealthPolicy(cooldown=0.005, calibration=4,
                                   rescale_threshold=3.0))
    trace = _trace(n=10, seed=6)
    chaos = ChaosTrace([
        ChaosEvent(time=0.0, kind="straggler", lane="CPU", delay=0.01,
                   count=-1),
    ], kind="straggler", seed=6)
    rep = eng.serve(trace, chaos=chaos)
    assert_no_silent_wrong_answer(rep)
    assert_handle_ledger_clean(orch, eng, rep)
    # drift samples were collected on the straggling lane (a rescale
    # recommendation additionally requires the EWMA to cross the
    # threshold after calibration, which injected jitter may or may not
    # reach — observation is the hard guarantee)
    cpu = rep.breaker["targets"].get("CPU")
    assert cpu is not None and cpu["successes"] > 0


def test_chaos_trace_round_trip_replays_equivalently():
    trace = _trace(n=10, seed=7)
    chaos = ChaosTrace([
        ChaosEvent(time=trace.arrivals[3].time, kind="pu_lost", lane="CPU"),
        ChaosEvent(time=trace.arrivals[7].time, kind="pu_restored",
                   lane="CPU"),
        ChaosEvent(time=0.0, kind="transient", count=2),
    ], kind="mixed", seed=7)
    replay = ChaosTrace.from_json(chaos.to_json())
    assert replay.events == chaos.events

    def run(c):
        orch, eng = fresh_engine()
        rep = eng.serve(ArrivalTrace.from_json(trace.to_json()), chaos=c)
        assert_no_silent_wrong_answer(rep)
        assert_handle_ledger_clean(orch, eng, rep)
        return rep

    a, b = run(chaos), run(replay)
    # virtual-clock accounting is deterministic across the replay
    assert (a.completed, a.shed, a.recoveries) == \
        (b.completed, b.shed, b.recoveries)
    assert [t["to"] for t in a.breaker["transitions"]] == \
        [t["to"] for t in b.breaker["transitions"]]


@pytest.mark.parametrize("seed", range(6))
def test_handle_ledger_clean_after_chaos_sweep(seed):
    """Property sweep: random arrivals + mid-run loss/return chaos never
    leak or duplicate a handle alias, whatever the retry/shed path."""
    orch, eng = fresh_engine()
    trace = _trace(n=10, rate=80.0, seed=100 + seed)
    t_lost = trace.arrivals[seed % 8].time
    chaos = ChaosTrace([
        ChaosEvent(time=t_lost, kind="pu_lost", lane="CPU"),
        ChaosEvent(time=t_lost, kind="transient", count=2),
        ChaosEvent(time=trace.arrivals[-2].time, kind="pu_restored",
                   lane="CPU"),
    ], kind="sweep", seed=seed)
    rep = eng.serve(trace, chaos=chaos)
    assert_no_silent_wrong_answer(rep)
    assert_handle_ledger_clean(orch, eng, rep)
    # serving again on the same engine works (pools are intact)
    rep2 = eng.serve(_trace(n=4, seed=200 + seed))
    assert_no_silent_wrong_answer(rep2)
    assert_handle_ledger_clean(orch, eng, rep2)


def test_report_availability_accounting_fields():
    orch, eng = fresh_engine()
    trace = _trace(n=8, seed=8)
    chaos = ChaosTrace([
        ChaosEvent(time=trace.arrivals[2].time, kind="pu_lost", lane="CPU"),
    ], kind="accounting", seed=8)
    rep = eng.serve(trace, chaos=chaos)
    d = rep.to_dict()
    for key in ("recovered", "retried", "recoveries", "recovery_ms_p50",
                "recovery_ms_p99", "shed_reasons", "bitwise_checked",
                "bitwise_failures", "exec_wall_s", "breaker", "cache"):
        assert key in d
    assert "requests" not in d
    assert d["cache"]["sizes"], "cache accounting missing"
    assert d["breaker"]["transitions"], "breaker transition log missing"
