"""DAG workloads: antichain-frontier scheduling, oracle equivalence,
execution, and failure-context tests.

The DAG front door (``solve_dag``) must be *bitwise* identical to the
retained oracles on the shapes they own — the chain DP on linear DAGs,
the anti-diagonal grid sweep on disjoint unions of chains, and
``solve_parallel`` on fork/join (branch-shaped) DAGs — and the
``"frontier"`` generalization must reduce bitwise to the grid sweep on
unions (the ideal lattice *is* the progress grid there).  Executed DAG
plans must be bitwise-equal to the single-lane reference run on both
executor paths, including under fault injection.

Property-style tests use seeded randomized sweeps (the offline container
has no `hypothesis` package; invariants are the same).
"""
from __future__ import annotations

import contextlib
import json
import signal

import numpy as np
import pytest

from repro.core import (ContentionModel, DagSchedule, DagStep,
                        EdgeSoCCostModel, FaultPlan, FusedOp,
                        InfeasibleScheduleError, OpGraph, Orchestrator,
                        ScheduleExecutor, Workload, chain_graph,
                        results_bitwise_equal, schedule_from_dict,
                        schedule_to_dict, solve_concurrent, solve_dag,
                        solve_parallel, solve_sequential)
from repro.core.costmodel import EDGE_PUS
from repro.core.faults import FaultSpec
from repro.core.paperzoo import vla_pipeline

KINDS = ["matmul", "conv2d", "add", "rdft", "cumsum", "gather", "norm",
         "act", "softmax"]


class HardTimeout(Exception):
    pass


@contextlib.contextmanager
def hard_timeout(seconds: float = 60.0):
    def handler(signum, frame):
        raise HardTimeout(f"test exceeded the {seconds}s hard timeout")
    old = signal.signal(signal.SIGALRM, handler)
    signal.setitimer(signal.ITIMER_REAL, seconds)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0.0)
        signal.signal(signal.SIGALRM, old)


@pytest.fixture(autouse=True)
def _no_hang():
    with hard_timeout(60.0):
        yield


# ---------------------------------------------------------------------------
# random graph builders
# ---------------------------------------------------------------------------


def _random_ops(rng: np.random.Generator, n: int, unsupported_frac=0.0):
    ops = []
    for i in range(n):
        kind = KINDS[rng.integers(len(KINDS))]
        if kind in ("matmul", "conv2d"):
            sz = int(rng.integers(32, 384))
            op = FusedOp(name=f"op{i}", kind="matmul",
                         in_shapes=((1, sz, sz), (sz, sz)),
                         out_shape=(1, sz, sz))
        else:
            numel = int(rng.integers(1_000, 1_000_000))
            op = FusedOp(name=f"op{i}", kind=kind, in_shapes=((numel,),),
                         out_shape=(numel,))
        if rng.random() < unsupported_frac:
            op.meta["unsupported_on"] = ("NPU",)
        ops.append(op)
    return ops


def random_linear_dag(rng: np.random.Generator, n: int) -> OpGraph:
    """A single chain, but built with explicit DAG edges."""
    return OpGraph(_random_ops(rng, n, unsupported_frac=0.15),
                   edges=[(i, i + 1) for i in range(n - 1)])


def random_union_of_chains(rng: np.random.Generator) -> OpGraph:
    """2-3 disjoint chains in one graph (interleaved op numbering)."""
    m = int(rng.integers(2, 4))
    lens = [int(rng.integers(1, 4)) for _ in range(m)]
    n = sum(lens)
    ops = _random_ops(rng, n)
    perm = rng.permutation(n).tolist()
    edges, k = [], 0
    for ln in lens:
        ids = perm[k:k + ln]
        edges += list(zip(ids, ids[1:]))
        k += ln
    return OpGraph(ops, edges=edges)


def random_branch_dag(rng: np.random.Generator) -> OpGraph:
    """Random series-parallel fork/join DAG (the shape solve_parallel
    owns): alternating chain segments and 2-3-way forked segments."""
    ops: list[FusedOp] = []
    edges: list[tuple[int, int]] = []

    def grow(after: int | None, ln: int) -> int:
        prev = after
        for _ in range(ln):
            idx = len(ops)
            ops.append(_random_ops(rng, 1)[0])
            ops[-1].name = f"op{idx}"
            if prev is not None:
                edges.append((prev, idx))
            prev = idx
        return prev

    tail = grow(None, int(rng.integers(1, 3)))
    for _ in range(int(rng.integers(1, 3))):
        ends = [grow(tail, int(rng.integers(1, 3)))
                for _ in range(int(rng.integers(2, 4)))]
        join = len(ops)
        ops.append(_random_ops(rng, 1)[0])
        ops[-1].name = f"op{join}"
        edges += [(e, join) for e in ends]
        tail = grow(join, int(rng.integers(1, 3)))
    return OpGraph(ops, edges=edges)


def _attach_payloads(graph: OpGraph, seed: int = 0) -> dict:
    """Pure (8, 8)-latent payloads + external inputs for the sources."""
    rng = np.random.default_rng(seed)
    for op in graph.ops:
        w = rng.standard_normal((8, 8)).astype(np.float32)

        def fn(*args, _w=w):
            x = sum(np.asarray(a, dtype=np.float32) for a in args)
            return np.tanh(x @ _w)

        op.fn = fn
    return {i: (rng.standard_normal((8, 8)).astype(np.float32),)
            for i in range(len(graph.ops)) if not graph.pred[i]}


def diamond_graph(payloads: bool = False):
    ops = [FusedOp(name=f"d{i}", kind="matmul",
                   in_shapes=((1, 128, 128), (128, 128)),
                   out_shape=(1, 128, 128)) for i in range(6)]
    g = OpGraph(ops, edges=[(0, 1), (0, 2), (1, 3), (2, 4), (3, 5), (4, 5)])
    return (g, _attach_payloads(g)) if payloads else g


# ---------------------------------------------------------------------------
# oracle equivalence (bitwise, not approx)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_linear_dag_bitwise_equals_chain_dp(seed, objective):
    rng = np.random.default_rng(seed)
    g = random_linear_dag(rng, n=int(rng.integers(2, 12)))
    table = EdgeSoCCostModel().build_table(g)
    dag = solve_dag(g, table, EDGE_PUS, objective=objective)
    seq = solve_sequential(g.topo_order(), g.ops, table, EDGE_PUS, objective)
    assert dag.mode == "chain"
    assert dag.latency == seq.latency and dag.energy == seq.energy
    assert dag.order == list(seq.chain)
    assert [dag.assignment[o] for o in seq.chain] == list(seq.assignment)
    # step costs decompose the chain DP's objective exactly
    assert sum(st.cost for st in dag.steps) == pytest.approx(
        seq.latency, rel=1e-12)


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_union_of_chains_bitwise_equals_grid_sweep(seed, objective):
    rng = np.random.default_rng(100 + seed)
    g = random_union_of_chains(rng)
    table = EdgeSoCCostModel().build_table(g)
    cm = ContentionModel()
    dag = solve_dag(g, table, EDGE_PUS, cm, objective=objective)
    wl = Workload.from_graph(g, table, EDGE_PUS)
    comp_wls = [wl.select(c) for c in g.components()]
    grid = solve_concurrent(comp_wls, cm, objective, algorithm="grid")
    assert dag.mode == "union-grid"
    assert dag.latency == grid.latency and dag.energy == grid.energy
    # step-by-step: same co-scheduled (op, pu) sets, None padding dropped
    assert [sorted(zip(st.ops, st.pus)) for st in dag.steps] == [
        sorted((o, p) for o, p in zip(st.ops, st.pus) if o is not None)
        for st in grid.steps]


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_branch_dag_bitwise_equals_solve_parallel(seed, objective):
    rng = np.random.default_rng(200 + seed)
    g = random_branch_dag(rng)
    table = EdgeSoCCostModel().build_table(g)
    cm = ContentionModel()
    dag = solve_dag(g, table, EDGE_PUS, cm, objective=objective)
    par = solve_parallel(g, table, EDGE_PUS, cm, objective)
    assert dag.mode == "phase"
    assert dag.latency == par.latency and dag.energy == par.energy
    want = {o: p for ph in par.phases for b in ph.branches
            for o, p in zip(b.branch_ops, b.assignment)}
    assert dag.assignment == want


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_frontier_reduces_bitwise_to_grid_on_unions(seed, objective):
    """On a union of chains the order-ideal lattice *is* the progress
    grid, so the frontier DP must reproduce the sweep bitwise —
    including the step-level co-schedules, not just the totals."""
    rng = np.random.default_rng(300 + seed)
    g = random_union_of_chains(rng)
    table = EdgeSoCCostModel().build_table(g)
    grid = solve_dag(g, table, EDGE_PUS, objective=objective,
                     algorithm="union-grid")
    fr = solve_dag(g, table, EDGE_PUS, objective=objective,
                   algorithm="frontier")
    assert fr.mode == "frontier"
    # the DP g-values minimize over identical candidate sets on the same
    # lattice, so the *objective* value is bitwise equal; on argmin ties
    # the two solvers may reconstruct different (equally optimal) step
    # sequences, so the secondary metric is only tie-equal
    assert getattr(fr, objective) == getattr(grid, objective)
    if objective == "latency":
        assert fr.energy == grid.energy
        assert ([sorted(zip(st.ops, st.pus)) for st in fr.steps]
                == [sorted(zip(st.ops, st.pus)) for st in grid.steps])
    else:
        assert fr.latency == pytest.approx(grid.latency, rel=1e-9)


@pytest.mark.parametrize("seed", range(6))
def test_frontier_never_worse_than_serialized(seed):
    """The frontier optimum can never lose to full serialization (the
    singleton-only walk is one of its feasible policies)."""
    rng = np.random.default_rng(400 + seed)
    g = random_branch_dag(rng)
    table = EdgeSoCCostModel().build_table(g)
    fr = solve_dag(g, table, EDGE_PUS, algorithm="frontier")
    wl = Workload.from_graph(g, table, EDGE_PUS)
    w = np.where(np.isfinite(wl.dense.w), wl.dense.w, np.inf)
    serialized = float(np.min(w, axis=1).sum())
    assert fr.latency <= serialized + 1e-12
    # precedence validity of every step sequence
    done: set[int] = set()
    for st in fr.steps:
        for o in st.ops:
            assert set(g.pred[o]) <= done, f"op {o} scheduled before preds"
        done |= set(st.ops)
    assert done == set(range(len(g.ops)))


def test_forced_route_validation():
    g = diamond_graph()
    table = EdgeSoCCostModel().build_table(g)
    with pytest.raises(ValueError, match="single linear chain"):
        solve_dag(g, table, EDGE_PUS, algorithm="chain")
    with pytest.raises(ValueError, match="union of"):
        solve_dag(g, table, EDGE_PUS, algorithm="union-grid")
    with pytest.raises(ValueError):
        solve_dag(g, table, EDGE_PUS, algorithm="bogus")


def test_vla_pipeline_frontier_beats_sequential():
    """The paper's VLA scenario: co-executing the vision and language
    towers must beat the best serialized single-sequence route."""
    g = vla_pipeline()
    table = EdgeSoCCostModel().build_table(g)
    fr = solve_dag(g, table, EDGE_PUS, algorithm="frontier")
    seq = solve_sequential(g.topo_order(), g.ops, table, EDGE_PUS, "latency")
    assert fr.n_parallel_steps > 0
    assert fr.latency < seq.latency


# ---------------------------------------------------------------------------
# schedule round-trip
# ---------------------------------------------------------------------------


def test_dag_schedule_json_roundtrip():
    g = diamond_graph()
    table = EdgeSoCCostModel().build_table(g)
    for alg in ("phase", "frontier"):
        sched = solve_dag(g, table, EDGE_PUS, algorithm=alg)
        d = json.loads(json.dumps(schedule_to_dict(sched)))
        back = schedule_from_dict(d)
        assert isinstance(back, DagSchedule)
        assert back == sched


# ---------------------------------------------------------------------------
# execution: both paths bitwise-equal the single-lane reference
# ---------------------------------------------------------------------------


def _exec_case(seed_graph):
    graph, inputs = seed_graph
    table = EdgeSoCCostModel().build_table(graph)
    ex = ScheduleExecutor(list(EDGE_PUS))
    ref = ex.run_monolithic(graph, inputs)
    return graph, inputs, table, ex, ref


@pytest.mark.parametrize("shape,alg", [
    ("chain", "auto"), ("union", "auto"),
    ("diamond", "phase"), ("diamond", "frontier"),
    ("vla", "frontier"),
])
def test_executed_dag_plan_bitwise_equals_monolithic(shape, alg):
    rng = np.random.default_rng(hash(shape) % 2**32)
    if shape == "chain":
        g = random_linear_dag(rng, 5)
    elif shape == "union":
        g = random_union_of_chains(rng)
    elif shape == "vla":
        g = vla_pipeline()
    else:
        g = diamond_graph()
    inputs = _attach_payloads(g, seed=7)
    graph, inputs, table, ex, ref = _exec_case((g, inputs))
    sched = solve_dag(graph, table, EDGE_PUS, algorithm=alg)
    out_i = ex.run_dag(graph, sched, inputs)           # interpreter
    assert results_bitwise_equal(out_i, ref)
    prog = ex.compile_dag(graph, sched)                # compiled program
    out_c = prog.run(inputs)
    assert results_bitwise_equal(out_c, ref)


def test_dag_fault_injection_sweep():
    """One fault of every recoverable kind at every op of a DAG plan, on
    the interpreter path: outputs stay bitwise-equal to the fault-free
    run (transients retry, stalls/stragglers only delay)."""
    g, inputs = diamond_graph(payloads=True)
    table = EdgeSoCCostModel().build_table(g)
    ex = ScheduleExecutor(list(EDGE_PUS))
    ref = ex.run_monolithic(g, inputs)
    sched = solve_dag(g, table, EDGE_PUS, algorithm="frontier")
    for kind in ("transient", "stall", "straggler"):
        for op in range(len(g.ops)):
            faults = FaultPlan([FaultSpec(kind, op=op, delay=0.01)])
            out = ex.run_dag(g, sched, inputs, faults=faults,
                             estimate=sched.latency)
            assert results_bitwise_equal(out, ref), (kind, op)


def test_dag_plan_pu_lost_recovery():
    """Permanent PU loss mid-DAG-run: the orchestrator folds the loss
    into the condition, re-plans the DAG onto the survivors, and resumes
    from the completed frontier — outputs bitwise-equal fault-free."""
    g, inputs = diamond_graph(payloads=True)
    orch = Orchestrator(EdgeSoCCostModel(), pus=EDGE_PUS)
    h = orch.register(g)
    plan = orch.plan(h, mode="dag", algorithm="frontier")
    ref = orch.executor.run_monolithic(g, inputs)
    victim = sorted(set(plan.schedule.assignment.values()))[0]
    faults = FaultPlan([FaultSpec("pu_lost", lane=victim)])
    out = orch.execute(plan, inputs, compile=False, faults=faults)
    assert results_bitwise_equal(out, ref)
    assert orch.stats["recoveries"] == 1
    assert victim in orch.condition.unavailable


# ---------------------------------------------------------------------------
# failure context (satellite: InfeasibleScheduleError carries DAG info)
# ---------------------------------------------------------------------------


def test_solver_infeasible_names_node_and_predecessors():
    """A runtime condition that kills the one PU supporting a node makes
    the DAG unschedulable: the error names the node and its predecessor
    context, not a meaningless chain position."""
    ops = [FusedOp(name=f"n{i}", kind="matmul",
                   in_shapes=((1, 64, 64), (64, 64)), out_shape=(1, 64, 64))
           for i in range(4)]
    ops[3].name = "join_op"
    ops[3].meta["unsupported_on"] = ("CPU", "GPU")    # NPU-only
    g = OpGraph(ops, edges=[(0, 1), (0, 2), (1, 3), (2, 3)])
    table = EdgeSoCCostModel().build_table(g)
    wl = Workload.from_graph(g, table, EDGE_PUS).under_condition(
        {}, unavailable=("NPU",))
    with pytest.raises(InfeasibleScheduleError) as ei:
        solve_dag(g, table, EDGE_PUS, algorithm="frontier", workload=wl)
    msg = str(ei.value)
    assert "join_op" in msg
    assert "predecessors" in msg
    assert "n1" in msg and "n2" in msg


def test_executor_rejects_order_violating_dag_schedule():
    g, inputs = diamond_graph(payloads=True)
    ex = ScheduleExecutor(list(EDGE_PUS))
    # join (op 5) listed before its predecessors ran
    bad = DagSchedule(
        steps=[DagStep(ops=(0,), pus=("CPU",), cost=1.0),
               DagStep(ops=(5,), pus=("CPU",), cost=1.0),
               DagStep(ops=(1, 2), pus=("CPU", "GPU"), cost=1.0),
               DagStep(ops=(3, 4), pus=("CPU", "GPU"), cost=1.0)],
        latency=4.0, energy=0.0, objective="latency", mode="frontier")
    with pytest.raises(InfeasibleScheduleError) as ei:
        ex.run_dag(g, bad, inputs)
    msg = str(ei.value)
    assert "d5" in msg                      # node name
    assert "unmet predecessor" in msg
    assert "d3" in msg and "d4" in msg      # which predecessors are unmet


# ---------------------------------------------------------------------------
# orchestrator integration
# ---------------------------------------------------------------------------


def test_orchestrator_auto_routes_disconnected_graphs_to_dag():
    rng = np.random.default_rng(5)
    g = random_union_of_chains(rng)
    orch = Orchestrator(EdgeSoCCostModel(), pus=EDGE_PUS)
    plan = orch.plan(orch.register(g))
    assert plan.kind == "dag"
    assert plan.schedule.mode == "union-grid"
    table = orch._reg(plan.handles[0]).table
    direct = solve_dag(g, table, EDGE_PUS, orch.contention)
    assert plan.latency == direct.latency
    assert plan.energy == direct.energy


def test_orchestrator_dag_mode_bitwise_and_cached():
    g = diamond_graph()
    orch = Orchestrator(EdgeSoCCostModel(), pus=EDGE_PUS)
    h = orch.register(g)
    auto = orch.plan(h)                     # connected fork/join: parallel
    assert auto.kind == "parallel"
    dag = orch.plan(h, mode="dag")          # forced: phase oracle, bitwise
    assert dag.kind == "dag" and dag.schedule.mode == "phase"
    assert dag.latency == auto.latency and dag.energy == auto.energy
    misses = orch.stats["misses"]
    hits = orch.stats["hits"]
    again = orch.plan(h, mode="dag")
    assert again is dag
    assert orch.stats["hits"] == hits + 1
    assert orch.stats["misses"] == misses
    # a different algorithm is a different cache key, not a stale hit
    fr = orch.plan(h, mode="dag", algorithm="frontier")
    assert fr.schedule.mode == "frontier"
    assert orch.stats["misses"] == misses + 1


def test_orchestrator_dag_plan_json_roundtrip_and_execute():
    g, inputs = diamond_graph(payloads=True)
    orch = Orchestrator(EdgeSoCCostModel(), pus=EDGE_PUS)
    h = orch.register(g)
    plan = orch.plan(h, mode="dag", algorithm="frontier")
    restored = type(plan).from_json(plan.to_json())
    assert restored.kind == "dag"
    assert restored.schedule == plan.schedule
    ref = orch.executor.run_monolithic(g, inputs)
    assert results_bitwise_equal(orch.execute(restored, inputs), ref)


def test_orchestrator_dag_condition_replans_around_lost_pu():
    from repro.core import RuntimeCondition
    g = diamond_graph()
    orch = Orchestrator(EdgeSoCCostModel(), pus=EDGE_PUS)
    h = orch.register(g)
    nominal = orch.plan(h, mode="dag", algorithm="frontier")
    orch.on_condition(RuntimeCondition(unavailable=("GPU",)))
    degraded = orch.plan(h, mode="dag", algorithm="frontier")
    assert "GPU" not in set(degraded.schedule.assignment.values())
    assert degraded.latency >= nominal.latency
