"""Equivalence regression: the vectorized/dense solvers must reproduce the
scalar reference implementations.

On randomized continuous cost tables (tie-free with probability 1) the
fast paths must agree **exactly** — same bitwise cost, same assignment,
same tie-break policy (first minimum in PU declaration order) — with:

* ``dijkstra`` over the explicit graph,
* ``sequential_dp_reference`` (scalar Eq. 1 recurrence),
* ``sequential_dp`` (vectorized dense recurrence),
* ``solve_concurrent_joint`` (dense-table A*) vs
  ``solve_concurrent_joint_reference`` (dict-state Dijkstra),
* ``solve_concurrent_aligned`` vs its scalar reference.

Structured tables with *exact* cost ties (repeated identical ops) may
legitimately return different optimal paths, so there the objective value
is compared instead of the step sequence.
"""
import numpy as np
import pytest

from repro.core import (ContentionModel, CostEntry, CostTable, DenseCostTable,
                        EDGE_PUS, dijkstra, sequential_dp,
                        sequential_dp_reference, solve_concurrent_aligned,
                        solve_concurrent_aligned_reference,
                        solve_concurrent_joint,
                        solve_concurrent_joint_reference)
from repro.core.graph import build_sequential_graph
from repro.core.op import FusedOp
from repro.core.search import _cost_to_go, _solo_edges
from repro.core.contention import PairCostCache

PUS = ("CPU", "GPU", "NPU")


def random_table(rng: np.random.Generator, n_ops: int,
                 drop_frac: float = 0.25) -> tuple[list, CostTable]:
    """Random continuous cost table; some (op, PU) cells unsupported."""
    table = CostTable(list(PUS))
    ops = []
    for i in range(n_ops):
        ops.append(FusedOp(name=f"o{i}", kind="other", out_shape=(4,)))
        sup = [p for p in PUS if rng.random() > drop_frac]
        if not sup:
            sup = [PUS[int(rng.integers(len(PUS)))]]
        for pu in sup:
            table.set(i, pu, CostEntry(
                kernel=float(rng.uniform(1e-6, 1e-3)),
                dispatch=float(rng.uniform(0, 1e-5)),
                h2d=float(rng.uniform(0, 1e-4)),
                d2h=float(rng.uniform(0, 1e-4)),
                power=float(rng.uniform(5.0, 30.0))))
    return ops, table


@pytest.mark.parametrize("seed", range(20))
@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_sequential_dp_exact_equivalence(seed, objective):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(2, 40))
    ops, table = random_table(rng, n)
    chain = list(range(n))
    c_vec, a_vec = sequential_dp(chain, ops, table, EDGE_PUS, objective)
    c_ref, a_ref = sequential_dp_reference(chain, ops, table, EDGE_PUS,
                                           objective)
    assert c_vec == c_ref           # bitwise, not approx
    assert a_vec == a_ref           # identical tie-break policy
    g = build_sequential_graph(chain, ops, table, EDGE_PUS, objective)
    c_dij, _ = dijkstra(g)
    assert c_vec == pytest.approx(c_dij, rel=1e-12)


@pytest.mark.parametrize("seed", range(12))
@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_joint_astar_exact_equivalence(seed, objective):
    rng = np.random.default_rng(1000 + seed)
    ops0, t0 = random_table(rng, int(rng.integers(2, 12)))
    ops1, t1 = random_table(rng, int(rng.integers(2, 12)))
    c0, c1 = list(range(len(ops0))), list(range(len(ops1)))
    cm = ContentionModel()
    fast = solve_concurrent_joint(c0, t0, c1, t1, EDGE_PUS, cm, objective)
    ref = solve_concurrent_joint_reference(c0, t0, c1, t1, EDGE_PUS, cm,
                                           objective)
    # The objective key is bitwise-exact and the per-request op -> PU
    # assignment identical.  The non-objective metric is bookkeeping of
    # the tie-broken path: energy mode has *structural* exact ties (a
    # same-PU pair step costs exactly the two solo steps' energy sum by
    # the cost laws), so equally-optimal schedules can differ in pairing
    # structure — and therefore in latency — while assigning every op to
    # the same PU.
    if objective == "latency":
        assert fast.latency == ref.latency      # bitwise
        assert fast.energy == pytest.approx(ref.energy, rel=1e-12)
    else:
        # the reported energy re-accumulates the same per-op terms along
        # the tie-broken path, so equally-optimal pairings can differ in
        # summation order by an ulp
        assert fast.energy == pytest.approx(ref.energy, rel=1e-14)
        assert fast.latency == pytest.approx(ref.latency, rel=1e-12)
    for r in (0, 1):
        assert fast.assignment_of(r) == ref.assignment_of(r)


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_aligned_exact_equivalence(seed, objective):
    rng = np.random.default_rng(2000 + seed)
    ops0, t0 = random_table(rng, int(rng.integers(2, 15)))
    ops1, t1 = random_table(rng, int(rng.integers(2, 15)))
    c0, c1 = list(range(len(ops0))), list(range(len(ops1)))
    cm = ContentionModel()
    fast = solve_concurrent_aligned(c0, t0, c1, t1, EDGE_PUS, cm, objective)
    ref = solve_concurrent_aligned_reference(c0, t0, c1, t1, EDGE_PUS, cm,
                                             objective)
    assert fast.latency == ref.latency
    assert fast.energy == ref.energy
    assert ([(s.ops, s.pus, s.cost) for s in fast.steps]
            == [(s.ops, s.pus, s.cost) for s in ref.steps])


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_sequential_dp_large_k_branch(seed, objective):
    """K >= 8 exercises the NumPy per-position recurrence (the edge SoC's
    K=3 and autoshard's K=6 only hit the tight-loop path); it must stay
    bit-identical to the scalar reference too."""
    import dataclasses

    from repro.core import CPU
    pus = {f"P{i}": dataclasses.replace(CPU, name=f"P{i}",
                                        is_accelerator=bool(i % 2))
           for i in range(9)}
    names = list(pus)
    rng = np.random.default_rng(3000 + seed)
    table = CostTable(names)
    ops = []
    n = int(rng.integers(2, 20))
    for i in range(n):
        ops.append(FusedOp(name=f"o{i}", kind="other", out_shape=(4,)))
        sup = [p for p in names if rng.random() > 0.2] or [names[0]]
        for pu in sup:
            table.set(i, pu, CostEntry(
                kernel=float(rng.uniform(1e-6, 1e-3)),
                dispatch=float(rng.uniform(0, 1e-5)),
                h2d=float(rng.uniform(0, 1e-4)),
                d2h=float(rng.uniform(0, 1e-4)),
                power=float(rng.uniform(5.0, 30.0))))
    chain = list(range(n))
    c_vec, a_vec = sequential_dp(chain, ops, table, pus, objective)
    c_ref, a_ref = sequential_dp_reference(chain, ops, table, pus, objective)
    assert c_vec == c_ref
    assert a_vec == a_ref


def test_explicit_astar_with_custom_contention_rejected():
    """Forcing algorithm='astar' with overridden co-execution laws must
    raise rather than silently pricing the schedule with the default
    laws."""

    class Custom(ContentionModel):
        def co_exec(self, t_a, pu_a, t_b, pu_b):
            return t_a, t_b

    rng = np.random.default_rng(1)
    ops0, t0 = random_table(rng, 3, drop_frac=0.0)
    with pytest.raises(ValueError, match="astar.*co-execution|co-execution"):
        solve_concurrent_joint([0, 1, 2], t0, [0, 1, 2], t0, EDGE_PUS,
                               Custom(), algorithm="astar")


def test_partial_pu_support_routes_identically():
    """A chain mixing fully-supported ops with NPU/GPU-unsupported ops:
    the dense mask must route around missing cells exactly like the
    sparse table."""
    rng = np.random.default_rng(7)
    table = CostTable(list(PUS))
    ops = []
    for i in range(10):
        ops.append(FusedOp(name=f"o{i}", kind="other", out_shape=(4,)))
        sup = PUS if i % 3 else ("CPU",)       # every 3rd op CPU-only
        for pu in sup:
            table.set(i, pu, CostEntry(
                kernel=float(rng.uniform(1e-5, 1e-3)), dispatch=1e-6,
                h2d=float(rng.uniform(0, 1e-4)),
                d2h=float(rng.uniform(0, 1e-4)), power=10.0))
    chain = list(range(10))
    for objective in ("latency", "energy"):
        c_vec, a_vec = sequential_dp(chain, ops, table, EDGE_PUS, objective)
        c_ref, a_ref = sequential_dp_reference(chain, ops, table, EDGE_PUS,
                                               objective)
        assert (c_vec, a_vec) == (c_ref, a_ref)
        assert all(a_vec[i] == "CPU" for i in range(0, 10, 3))
    cm = ContentionModel()
    fast = solve_concurrent_joint(chain, table, chain, table, EDGE_PUS, cm)
    ref = solve_concurrent_joint_reference(chain, table, chain, table,
                                           EDGE_PUS, cm)
    assert fast.latency == pytest.approx(ref.latency, rel=1e-12)
    for s in fast.steps:       # CPU-only ops never leave the CPU
        for r in (0, 1):
            if s.ops[r] is not None and s.ops[r] % 3 == 0:
                assert s.pus[r] == "CPU"


def test_op_unsupported_everywhere_raises():
    table = CostTable(list(PUS))
    ops = [FusedOp(name="a", kind="other", out_shape=(4,)),
           FusedOp(name="b", kind="other", out_shape=(4,))]
    table.set(0, "CPU", CostEntry(1e-4, 1e-6, 0.0, 0.0, 10.0))
    # op 1 has no entries at all
    with pytest.raises(ValueError, match="unsupported on all PUs"):
        sequential_dp([0, 1], ops, table, EDGE_PUS)
    with pytest.raises(ValueError, match="joint search failed"):
        solve_concurrent_joint([0, 1], table, [0], table, EDGE_PUS)


def test_structured_ties_agree_on_objective_value():
    """Repeated identical ops create exact cost ties; tie-broken paths may
    differ between A* and the reference Dijkstra, but the objective value
    must agree to FP noise."""
    table = CostTable(list(PUS))
    ops = []
    for i in range(12):
        ops.append(FusedOp(name=f"o{i}", kind="other", out_shape=(4,)))
        for pu, kern in (("CPU", 2e-4), ("GPU", 1e-4), ("NPU", 3e-4)):
            table.set(i, pu, CostEntry(kern, 1e-6, 5e-5, 5e-5, 12.0))
    chain = list(range(12))
    cm = ContentionModel()
    for objective in ("latency", "energy"):
        fast = solve_concurrent_joint(chain, table, chain, table, EDGE_PUS,
                                      cm, objective)
        ref = solve_concurrent_joint_reference(chain, table, chain, table,
                                               EDGE_PUS, cm, objective)
        key = "latency" if objective == "latency" else "energy"
        assert getattr(fast, key) == pytest.approx(getattr(ref, key),
                                                   rel=1e-11)


def test_cost_to_go_heuristic_admissible_and_tight():
    """The A* heuristic must lower-bound the true optimum at the start
    state (admissibility) and match it to FP noise (tightness); the
    suffix-sum bound must lower-bound the DP cost-to-go."""
    rng = np.random.default_rng(99)
    ops0, t0 = random_table(rng, 9)
    ops1, t1 = random_table(rng, 7)
    c0, c1 = list(range(9)), list(range(7))
    cm = ContentionModel()
    for objective in ("latency", "energy"):
        d0 = DenseCostTable.from_chain(c0, t0, EDGE_PUS)
        d1 = DenseCostTable.from_chain(c1, t1, EDGE_PUS)
        cache = PairCostCache(cm, d0, d1)
        pk, _, _, _ = cache.edge_tables(objective)
        sk0 = _solo_edges(d0, objective)[0]
        sk1 = _solo_edges(d1, objective)[0]
        ctg = _cost_to_go(pk, sk0, sk1, d0.sig.tolist(), d1.sig)
        ref = solve_concurrent_joint_reference(c0, t0, c1, t1, EDGE_PUS, cm,
                                               objective)
        opt = ref.latency if objective == "latency" else ref.energy
        assert ctg[0, 0] <= opt * (1 + 1e-12)
        assert ctg[0, 0] == pytest.approx(opt, rel=1e-12)
        # the loose suffix-sum bound never exceeds the exact cost-to-go
        from repro.core.search import _suffix_heuristic
        scale = cm.min_factor()
        h0 = _suffix_heuristic(d0, objective, scale)
        h1 = _suffix_heuristic(d1, objective, scale)
        if objective == "energy":
            assert h0[0] + h1[0] <= ctg[0, 0] * (1 + 1e-12)
        else:
            assert max(h0[0], h1[0]) <= ctg[0, 0] * (1 + 1e-12)


def test_custom_contention_model_falls_back_to_reference():
    """A ContentionModel subclass overriding the co-execution laws must be
    honoured (the dense pair matrices encode the default laws only)."""

    class Harsh(ContentionModel):
        def co_exec(self, t_a, pu_a, t_b, pu_b):
            return 10.0 * t_a, 10.0 * t_b

        def pair_step_cost(self, t_a, pu_a, t_b, pu_b):
            return 10.0 * max(t_a, t_b)

    rng = np.random.default_rng(5)
    ops0, t0 = random_table(rng, 5, drop_frac=0.0)
    ops1, t1 = random_table(rng, 5, drop_frac=0.0)
    c0 = c1 = list(range(5))
    harsh = Harsh()
    got = solve_concurrent_joint(c0, t0, c1, t1, EDGE_PUS, harsh)
    want = solve_concurrent_joint_reference(c0, t0, c1, t1, EDGE_PUS, harsh)
    assert got.latency == want.latency
    assert ([(s.ops, s.pus) for s in got.steps]
            == [(s.ops, s.pus) for s in want.steps])


def test_solve_concurrent_m2_is_the_pair_fast_path():
    """The M-ary entry point with M = 2 must be bitwise identical to the
    retained pair solver — the pair A* IS the M = 2 case."""
    from repro.core import Workload, solve_concurrent
    rng = np.random.default_rng(4242)
    ops0, t0 = random_table(rng, 9)
    ops1, t1 = random_table(rng, 6)
    c0, c1 = list(range(9)), list(range(6))
    cm = ContentionModel()
    wl0 = Workload.build(c0, t0, EDGE_PUS, ops=ops0)
    wl1 = Workload.build(c1, t1, EDGE_PUS, ops=ops1)
    for objective in ("latency", "energy"):
        mary = solve_concurrent([wl0, wl1], cm, objective)
        pair = solve_concurrent_joint(c0, t0, c1, t1, EDGE_PUS, cm, objective,
                                      dense0=wl0.dense, dense1=wl1.dense)
        assert mary.latency == pair.latency
        assert mary.energy == pair.energy
        assert ([(s.ops, s.pus, s.cost) for s in mary.steps]
                == [(s.ops, s.pus, s.cost) for s in pair.steps])


def test_shared_pair_cache_matches_fresh_caches():
    """One PairCostCache threaded through both objectives (the fig8
    micro-opt) must reproduce per-objective fresh-cache solves bitwise."""
    rng = np.random.default_rng(515)
    ops0, t0 = random_table(rng, 10)
    ops1, t1 = random_table(rng, 8)
    c0, c1 = list(range(10)), list(range(8))
    cm = ContentionModel()
    d0 = DenseCostTable.from_chain(c0, t0, EDGE_PUS)
    d1 = DenseCostTable.from_chain(c1, t1, EDGE_PUS)
    shared = PairCostCache(cm, d0, d1)
    for objective in ("latency", "energy"):
        got = solve_concurrent_joint(c0, t0, c1, t1, EDGE_PUS, cm, objective,
                                     cache=shared)
        want = solve_concurrent_joint(c0, t0, c1, t1, EDGE_PUS, cm, objective,
                                      dense0=d0, dense1=d1)
        assert got.latency == want.latency
        assert got.energy == want.energy
        assert ([(s.ops, s.pus, s.cost) for s in got.steps]
                == [(s.ops, s.pus, s.cost) for s in want.steps])
        ga = solve_concurrent_aligned(c0, t0, c1, t1, EDGE_PUS, cm, objective,
                                      cache=shared)
        wa = solve_concurrent_aligned(c0, t0, c1, t1, EDGE_PUS, cm, objective,
                                      dense0=d0, dense1=d1)
        assert (ga.latency, ga.energy) == (wa.latency, wa.energy)


def test_dense_evaluate_matches_scalar_reference_walk():
    """The dense Workload evaluator behind evaluate_sequential must agree
    with the retained scalar dict walk."""
    from repro.core import (Workload, evaluate_sequential,
                            evaluate_sequential_reference)
    rng = np.random.default_rng(616)
    ops, table = random_table(rng, 20)
    chain = list(range(20))
    wl = Workload.build(chain, table, EDGE_PUS, ops=ops)
    for _ in range(8):
        assign = [table.supported_pus(oi)[
            int(rng.integers(len(table.supported_pus(oi))))] for oi in chain]
        got = evaluate_sequential(chain, assign, ops, table, EDGE_PUS,
                                  workload=wl)
        want = evaluate_sequential_reference(chain, assign, ops, table,
                                             EDGE_PUS)
        assert got[0] == pytest.approx(want[0], rel=1e-12)
        assert got[1] == pytest.approx(want[1], rel=1e-12)
