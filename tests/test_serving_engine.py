"""Engine.generate decode-step compilation reuse.

Regression for the re-jitting bug: ``generate`` used to build
``jax.jit(lambda ...)`` *inside* the method, so every call owned a fresh
jit cache and re-traced + re-compiled the decode step.  The step is now
cached on the engine; the traced-call counter (incremented only when jax
actually traces) proves two same-shape ``generate`` calls share one
compilation.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.serving.engine import Engine
from repro.sharding import Policy


@pytest.fixture(scope="module")
def engine():
    cfg = get_config("llama3.2-1b").reduced()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return Engine(cfg=cfg, params=params, policy=Policy())


def _prompts(engine, batch=2, seq=8, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(0, engine.cfg.vocab, (batch, seq),
                                    dtype=np.int32))


def test_two_generates_reuse_one_decode_compilation(engine):
    toks = _prompts(engine)
    out1 = engine.generate(toks, max_new=3)
    assert sum(engine.decode_trace_counts.values()) == 1
    out2 = engine.generate(toks, max_new=3)
    # same shapes -> still exactly one trace, and greedy decode is
    # deterministic, so the outputs must agree
    assert sum(engine.decode_trace_counts.values()) == 1
    assert len(engine.decode_trace_counts) == 1
    np.testing.assert_array_equal(np.asarray(out1), np.asarray(out2))
    assert out1.shape == (2, 3)


def test_new_shapes_trace_once_each(engine):
    engine.generate(_prompts(engine), max_new=3)
    base = sum(engine.decode_trace_counts.values())
    # a different max_len changes the cache shapes -> exactly one new
    # trace, reused by the repeat call
    engine.generate(_prompts(engine), max_new=3, max_len=24)
    assert sum(engine.decode_trace_counts.values()) == base + 1
    engine.generate(_prompts(engine), max_new=3, max_len=24)
    assert sum(engine.decode_trace_counts.values()) == base + 1
