"""Fault-tolerant execution runtime: injection, watchdogs, retry, recovery.

The headline suite is the exhaustive single-fault sweep: one fault of
every kind at *every* (request, op) point of a two-model workload, on
both executor paths — each case must end in either clean recovery
(outputs bitwise-equal to the fault-free run) or a typed error, never a
hang.  Every test body runs under a hard SIGALRM timeout so a regression
to unbounded waits fails the suite instead of wedging it.
"""
from __future__ import annotations

import contextlib
import signal
import threading
import time

import numpy as np
import pytest

jax = pytest.importorskip("jax")
import jax.numpy as jnp

from repro.core import (EdgeSoCCostModel, ExecutionPolicy,
                        ExecutionTimeoutError, FaultPlan, FaultRetryExceededError,
                        FusedOp, InfeasibleScheduleError, Orchestrator,
                        PULostError, RuntimeCondition, TransientFault,
                        chain_graph, results_bitwise_equal)
from repro.core.errors import ExecutionError
from repro.core.faults import DEFAULT_POLICY, FaultSpec, RunContext
from repro.fault.manager import RecoverableError

pytestmark = pytest.mark.fault


# ---------------------------------------------------------------------------
# hard timeout: pytest-timeout is not in the container, so use SIGALRM
# (main-thread lock/event waits are signal-interruptible on Linux CPython)
# ---------------------------------------------------------------------------


class HardTimeout(Exception):
    pass


@contextlib.contextmanager
def hard_timeout(seconds: float = 60.0):
    def handler(signum, frame):
        raise HardTimeout(f"test exceeded the {seconds}s hard timeout — "
                          "an execution path blocked")
    old = signal.signal(signal.SIGALRM, handler)
    signal.setitimer(signal.ITIMER_REAL, seconds)
    try:
        yield
    finally:
        signal.setitimer(signal.ITIMER_REAL, 0.0)
        signal.signal(signal.SIGALRM, old)


@pytest.fixture(autouse=True)
def _no_hang():
    with hard_timeout(60.0):
        yield


# ---------------------------------------------------------------------------
# fixtures: a small two-model jax workload
# ---------------------------------------------------------------------------

DIM = 8


def _payload(salt: int):
    w = jnp.asarray(np.random.default_rng(salt).standard_normal(
        (DIM, DIM)).astype(np.float32))

    def fn(x, w=w):
        return jnp.tanh(x @ w)
    return fn


def _jax_chain(n: int, salt: int):
    ops = [FusedOp(name=f"op{salt}_{k}", kind="matmul", flops=1e6,
                   bytes_moved=1e4, fn=_payload(salt * 97 + k))
           for k in range(n)]
    g = chain_graph(ops)
    x = jnp.asarray(np.random.default_rng(salt).standard_normal(
        (1, DIM)).astype(np.float32))
    return g, {0: (x,)}


N_OPS = (5, 4)


@pytest.fixture(scope="module")
def duo():
    """Two registered chains + fault-free reference outputs, per path."""
    g0, in0 = _jax_chain(N_OPS[0], salt=1)
    g1, in1 = _jax_chain(N_OPS[1], salt=2)
    orch = Orchestrator(EdgeSoCCostModel())
    h0, h1 = orch.register(g0), orch.register(g1)
    plan = orch.plan((h0, h1))
    inputs = [in0, in1]
    ref_interp = orch.execute(plan, inputs, compile=False)
    ref_compiled = orch.execute(plan, inputs)          # warm the program
    assert all(results_bitwise_equal(a, b)
               for a, b in zip(ref_interp, ref_compiled))
    return {"orch": orch, "plan": plan, "inputs": inputs,
            "graphs": (g0, g1), "raw_inputs": (in0, in1),
            "ref": ref_interp}


def _fresh_duo():
    """Fresh orchestrator for destructive (pu_lost) cases — the session
    condition mutates on recovery."""
    g0, in0 = _jax_chain(N_OPS[0], salt=1)
    g1, in1 = _jax_chain(N_OPS[1], salt=2)
    orch = Orchestrator(EdgeSoCCostModel())
    plan = orch.plan((orch.register(g0), orch.register(g1)))
    return orch, plan, [in0, in1]


TIGHT = ExecutionPolicy(timeout=20.0)
ALL_POINTS = [(r, op) for r in range(2) for op in range(N_OPS[r])]


# ---------------------------------------------------------------------------
# exhaustive single-fault sweep (the satellite test)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("compiled", [False, True],
                         ids=["interp", "compiled"])
@pytest.mark.parametrize("point", ALL_POINTS,
                         ids=[f"r{r}op{op}" for r, op in ALL_POINTS])
@pytest.mark.parametrize("kind", ["transient", "straggler", "stall"])
def test_single_fault_sweep_recoverable(duo, compiled, point, kind):
    """A single recoverable fault at every (request, op) point on both
    paths: execution completes with outputs bitwise-equal to fault-free."""
    r, op = point
    delay = 0.02 if kind != "transient" else 0.0
    faults = FaultPlan.single(kind, request=r, op=op, delay=delay)
    out = duo["orch"].execute(duo["plan"], duo["inputs"],
                              compile=compiled, policy=TIGHT, faults=faults)
    assert [k for k, *_ in faults.fired] == [kind]
    assert all(results_bitwise_equal(a, b) for a, b in zip(out, duo["ref"]))


@pytest.mark.parametrize("compiled", [False, True],
                         ids=["interp", "compiled"])
@pytest.mark.parametrize("point", ALL_POINTS,
                         ids=[f"r{r}op{op}" for r, op in ALL_POINTS])
def test_single_fault_sweep_pu_lost(compiled, point):
    """A permanent PU loss at every (request, op) point on both paths:
    recovery re-plans on the survivors and the recovered outputs are
    bitwise-equal to the fault-free run."""
    r, op = point
    orch, plan, inputs = _fresh_duo()
    ref = orch.execute(plan, inputs, compile=False)
    faults = FaultPlan.single("pu_lost", request=r, op=op)
    try:
        out = orch.execute(plan, inputs, compile=compiled,
                           policy=TIGHT, faults=faults)
    except (InfeasibleScheduleError, ExecutionError) as e:
        pytest.skip(f"typed degraded-mode error (acceptable): {e}")
    assert faults.lost, "the fault plan never fired"
    assert orch.stats["recoveries"] >= 1
    assert all(results_bitwise_equal(a, b) for a, b in zip(out, ref))


@pytest.mark.parametrize("compiled", [False, True],
                         ids=["interp", "compiled"])
def test_double_pu_loss_still_recovers_or_types(compiled):
    """Losing a second PU during the recovery resume either recovers on
    the last survivor or raises a typed planning error — never hangs."""
    orch, plan, inputs = _fresh_duo()
    ref = orch.execute(plan, inputs, compile=False)
    pus = list(orch.pus)
    faults = FaultPlan([FaultSpec("pu_lost", lane=pus[0]),
                        FaultSpec("pu_lost", lane=pus[1])])
    try:
        out = orch.execute(plan, inputs, compile=compiled,
                           policy=TIGHT, faults=faults)
    except (InfeasibleScheduleError, ExecutionError):
        return
    assert all(results_bitwise_equal(a, b) for a, b in zip(out, ref))


# ---------------------------------------------------------------------------
# watchdog: hangs become typed timeouts, peers are released
# ---------------------------------------------------------------------------


def _hang_case():
    """A cross-lane workload where one payload hangs forever."""
    g0, in0 = _jax_chain(4, salt=5)
    g1, in1 = _jax_chain(4, salt=6)
    orch = Orchestrator(EdgeSoCCostModel())
    plan = orch.plan((orch.register(g0), orch.register(g1)))
    return orch, plan, [in0, in1]


@pytest.mark.parametrize("compiled", [False, True],
                         ids=["interp", "compiled"])
def test_infinite_stall_raises_timeout_not_hang(compiled):
    orch, plan, inputs = _hang_case()
    faults = FaultPlan.single("stall", request=0, op=1,
                              delay=float("inf"))
    t0 = time.monotonic()
    with pytest.raises(ExecutionTimeoutError) as ei:
        orch.execute(plan, inputs, compile=compiled,
                     policy=ExecutionPolicy(timeout=0.3), faults=faults)
    assert time.monotonic() - t0 < 10.0
    msg = str(ei.value)
    assert "watchdog budget" in msg and "elapsed" in msg


def test_interp_peer_released_on_lane_failure():
    """A payload exception on one lane must release peers parked on its
    events (the executor.py:150 satellite): the original error surfaces
    promptly on a plan that multiplexes both requests across lanes."""
    orch, plan, inputs = _hang_case()
    faults = FaultPlan([FaultSpec("transient", request=0, op=1, count=-1)])
    t0 = time.monotonic()
    with pytest.raises(FaultRetryExceededError):
        orch.execute(plan, inputs, compile=False, recover=False,
                     policy=ExecutionPolicy(timeout=30.0), faults=faults)
    assert time.monotonic() - t0 < 10.0


def test_watchdog_budget_scales_with_estimate():
    p = ExecutionPolicy(timeout_factor=100.0, min_timeout=2.0)
    assert p.budget(None) == 2.0
    assert p.budget(0.5) == 50.0
    assert p.budget(1e-9) == 2.0           # floor absorbs tiny estimates
    assert ExecutionPolicy(timeout=7.0).budget(123.0) == 7.0
    assert ExecutionPolicy(watchdog=False).budget(123.0) is None


def test_watchdog_off_is_plain_unbounded_path():
    """watchdog=False keeps the pre-fault semantics (and rejects fault
    plans, which need the watchdog machinery to stay hang-free)."""
    orch, plan, inputs = _hang_case()
    out = orch.execute(plan, inputs,
                       policy=ExecutionPolicy(watchdog=False))
    ref = orch.execute(plan, inputs, compile=False)
    assert all(results_bitwise_equal(a, b) for a, b in zip(out, ref))
    with pytest.raises(ValueError, match="watchdog"):
        RunContext(ExecutionPolicy(watchdog=False), FaultPlan.single("stall"))


# ---------------------------------------------------------------------------
# bounded retry
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("compiled", [False, True],
                         ids=["interp", "compiled"])
def test_persistent_transient_exhausts_retries(compiled):
    orch, plan, inputs = _hang_case()
    faults = FaultPlan([FaultSpec("transient", request=0, op=2, count=-1)])
    with pytest.raises(FaultRetryExceededError) as ei:
        orch.execute(plan, inputs, compile=compiled,
                     policy=TIGHT, faults=faults)
    assert isinstance(ei.value.__cause__, TransientFault)
    assert isinstance(ei.value.__cause__, RecoverableError)
    # default policy: 2 retries = 3 attempts at the failing point
    assert sum(1 for k, *_ in faults.fired if k == "transient") == 3


def test_transient_retry_count_respects_policy():
    orch, plan, inputs = _hang_case()
    faults = FaultPlan([FaultSpec("transient", request=1, op=0, count=-1)])
    with pytest.raises(FaultRetryExceededError):
        orch.execute(plan, inputs, compile=False,
                     policy=ExecutionPolicy(timeout=20.0, max_retries=5,
                                            backoff=1e-4),
                     faults=faults)
    assert len(faults.fired) == 6


def test_transient_under_retry_budget_recovers_bitwise():
    orch, plan, inputs = _hang_case()
    ref = orch.execute(plan, inputs, compile=False)
    faults = FaultPlan([FaultSpec("transient", request=0, op=0, count=2)])
    out = orch.execute(plan, inputs, compile=False, policy=TIGHT,
                       faults=faults)
    assert all(results_bitwise_equal(a, b) for a, b in zip(out, ref))


# ---------------------------------------------------------------------------
# fault-plan mechanics
# ---------------------------------------------------------------------------


def test_fault_plan_sample_is_seed_deterministic():
    points = [(r, op) for r in range(2) for op in range(5)]
    a = FaultPlan.sample(points, n=4, seed=13)
    b = FaultPlan.sample(points, n=4, seed=13)
    c = FaultPlan.sample(points, n=4, seed=14)
    sig = lambda fp: [(s.kind, s.request, s.op) for s in fp.specs]
    assert sig(a) == sig(b)
    assert sig(a) != sig(c)


def test_fault_plan_reset_and_validation():
    fp = FaultPlan.single("transient", request=0, op=0)
    run = RunContext(TIGHT)
    with pytest.raises(TransientFault):
        fp.fire("CPU", 0, 0, run)
    fp.fire("CPU", 0, 0, run)       # budget spent: no re-fire
    assert len(fp.fired) == 1
    fp.reset()
    with pytest.raises(TransientFault):
        fp.fire("CPU", 0, 0, run)
    with pytest.raises(ValueError, match="unknown fault kind"):
        FaultSpec("meteor")
    with pytest.raises(ValueError, match="delay"):
        FaultSpec("stall", delay=-1.0)


def test_lost_lane_stays_dead_until_reset():
    fp = FaultPlan.single("pu_lost", lane="NPU")
    run = RunContext(TIGHT)
    with pytest.raises(PULostError):
        fp.fire("NPU", 0, 0, run)
    with pytest.raises(PULostError):
        fp.fire("NPU", 1, 3, run)   # permanence: every later dispatch
    fp.fire("GPU", 0, 0, run)       # other lanes unaffected
    fp.reset()
    assert fp.lost == set() and fp.fired == []
    with pytest.raises(PULostError):
        fp.fire("NPU", 1, 3, run)   # revived: spec budget restored, so the
    assert fp.lost == {"NPU"}       # first dispatch re-fires the loss


def test_runtime_condition_lose():
    c = RuntimeCondition(slowdown={"GPU": 2.0})
    c2 = c.lose("NPU").lose("GPU")
    assert c2.unavailable == {"NPU", "GPU"}
    assert c2.slowdown == {"GPU": 2.0}
    assert c.unavailable == frozenset()        # original untouched


# ---------------------------------------------------------------------------
# orchestrator-level semantics
# ---------------------------------------------------------------------------


def test_stale_plan_names_the_handle():
    """A plan executed against an orchestrator whose handle maps to a
    different (smaller) graph fails naming the handle — not deep in
    lane-queue construction."""
    g_big, in_big = _jax_chain(9, salt=7)
    orch_a = Orchestrator(EdgeSoCCostModel())
    plan = orch_a.plan(orch_a.register(g_big))

    g_small, in_small = _jax_chain(3, salt=8)
    orch_b = Orchestrator(EdgeSoCCostModel())
    orch_b.register(g_small)
    for compiled in (False, True):
        with pytest.raises(ValueError, match=r"handle 0.*stale"):
            orch_b.execute(plan, in_big, compile=compiled)


def test_recovery_not_requested_propagates_frontier():
    orch, plan, inputs = _fresh_duo()
    faults = FaultPlan.single("pu_lost", request=0, op=2)
    with pytest.raises(PULostError) as ei:
        orch.execute(plan, inputs, compile=False, policy=TIGHT,
                     faults=faults, recover=False)
    err = ei.value
    assert err.pu in orch.pus
    assert err.partial is not None and len(err.partial) == 2
    # the frontier holds only completed, bitwise-valid results
    ref = orch.execute(plan, inputs, compile=False)
    for done, full in zip(err.partial, ref):
        assert set(done) <= set(full)
        assert all(np.asarray(done[k]).tobytes()
                   == np.asarray(full[k]).tobytes() for k in done)
    assert orch.stats["recoveries"] == 0


def test_recovery_invalidates_condition_and_counts():
    orch, plan, inputs = _fresh_duo()
    ref = orch.execute(plan, inputs, compile=False)
    faults = FaultPlan.single("pu_lost", request=0, op=1)
    out = orch.execute(plan, inputs, compile=False, policy=TIGHT,
                       faults=faults)
    assert all(results_bitwise_equal(a, b) for a, b in zip(out, ref))
    assert orch.stats["recoveries"] == 1
    lost = next(iter(faults.lost))
    assert lost in orch.condition.unavailable
    # post-recovery plans avoid the dead PU entirely
    plan2 = orch.plan(plan.handles)
    assert all(p != lost for route in plan2.route for _, p in route)


def test_sequential_plan_pu_loss_recovers_bitwise():
    g, inp = _jax_chain(7, salt=9)
    orch = Orchestrator(EdgeSoCCostModel())
    plan = orch.plan(orch.register(g))
    assert plan.kind == "sequential"
    ref = orch.execute(plan, inp, compile=False)
    for compiled in (False, True):
        orch2 = Orchestrator(EdgeSoCCostModel())
        plan2 = orch2.plan(orch2.register(g))
        faults = FaultPlan.single("pu_lost", request=0, op=3)
        out = orch2.execute(plan2, inp, compile=compiled, policy=TIGHT,
                            faults=faults)
        assert results_bitwise_equal(out, ref)
        assert orch2.stats["recoveries"] == 1
