"""Hypothesis property tests on the search engine's invariants.

(The seeded randomized versions live in test_core_search.py; these drive
the same invariants through hypothesis' shrinking search.)
"""
import itertools

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (CostEntry, CostTable, EDGE_PUS, dijkstra,
                        sequential_dp, solve_concurrent_joint,
                        solve_sequential)
from repro.core.graph import build_sequential_graph
from repro.core.op import FusedOp, OpGraph
from repro.core.schedule import evaluate_sequential

PUS = ("CPU", "GPU", "NPU")


def _random_table(draw, n_ops: int):
    """A random cost table; some (op, PU) entries dropped (unsupported)."""
    table = CostTable(list(PUS))
    ops = []
    for i in range(n_ops):
        ops.append(FusedOp(name=f"o{i}", kind="other", out_shape=(4,)))
        sup = draw(st.lists(st.sampled_from(PUS), min_size=1, max_size=3,
                            unique=True))
        for pu in sup:
            table.set(i, pu, CostEntry(
                kernel=draw(st.floats(1e-6, 1e-3)),
                dispatch=draw(st.floats(0, 1e-5)),
                h2d=draw(st.floats(0, 1e-4)),
                d2h=draw(st.floats(0, 1e-4)),
                power=draw(st.floats(5.0, 30.0))))
    return ops, table


def _brute_force(chain, ops, table, objective):
    best = None
    sup = [table.supported_pus(o) for o in chain]
    for assign in itertools.product(*sup):
        lat, eng = evaluate_sequential(chain, list(assign), ops, table,
                                       EDGE_PUS)
        v = lat if objective == "latency" else eng
        if best is None or v < best:
            best = v
    return best


@settings(max_examples=40, deadline=None)
@given(st.data())
def test_search_is_optimal_vs_bruteforce(data):
    n = data.draw(st.integers(2, 6))
    ops, table = _random_table(data.draw, n)
    chain = list(range(n))
    for objective in ("latency", "energy"):
        s = solve_sequential(chain, ops, table, EDGE_PUS, objective)
        bf = _brute_force(chain, ops, table, objective)
        got = s.latency if objective == "latency" else s.energy
        assert got <= bf * (1 + 1e-9) + 1e-15


@settings(max_examples=30, deadline=None)
@given(st.data())
def test_dijkstra_equals_dp(data):
    n = data.draw(st.integers(2, 8))
    ops, table = _random_table(data.draw, n)
    chain = list(range(n))
    g = build_sequential_graph(chain, ops, table, EDGE_PUS, "latency")
    cost_d, _ = dijkstra(g)
    cost_dp, _ = sequential_dp(chain, ops, table, EDGE_PUS, "latency")
    assert cost_d == pytest.approx(cost_dp, rel=1e-9)


@settings(max_examples=20, deadline=None)
@given(st.data())
def test_joint_no_worse_than_serial(data):
    na = data.draw(st.integers(1, 4))
    nb = data.draw(st.integers(1, 4))
    ops_a, table_a = _random_table(data.draw, na)
    ops_b, table_b = _random_table(data.draw, nb)
    ca, cb = list(range(na)), list(range(nb))
    sa = solve_sequential(ca, ops_a, table_a, EDGE_PUS)
    sb = solve_sequential(cb, ops_b, table_b, EDGE_PUS)
    joint = solve_concurrent_joint(ca, table_a, cb, table_b, EDGE_PUS)
    # joint can always fall back to pure serial interleaving of per-op
    # minima; node costs exclude h2d/d2h boundaries, so compare against
    # the sum of per-op best node weights (the serial upper bound the
    # joint search relaxes from)
    serial_nodes = (
        sum(min(table_a.require(o, p).w for p in table_a.supported_pus(o))
            for o in ca)
        + sum(min(table_b.require(o, p).w for p in table_b.supported_pus(o))
              for o in cb))
    assert joint.latency <= serial_nodes * (1 + 1e-9) + 1e-15


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(2, 30),
    max_segments=st.integers(1, 10),
)
def test_segment_table_conserves_cost(n, max_segments):
    """Coarsening must conserve the total single-PU cost exactly."""
    from benchmarks.common import segment_table
    import numpy as np
    rng = np.random.default_rng(n * 131 + max_segments)
    table = CostTable(list(PUS))
    ops = []
    for i in range(n):
        ops.append(FusedOp(name=f"o{i}", kind="other", out_shape=(4,)))
        for pu in PUS:
            table.set(i, pu, CostEntry(
                kernel=float(rng.uniform(1e-6, 1e-3)), dispatch=0.0,
                h2d=0.0, d2h=0.0, power=float(rng.uniform(5, 30))))
    g = OpGraph(ops, edges=None)
    chain, stable = segment_table(g, table, max_segments)
    assert len(chain) <= max(max_segments, 1) + 1
    for pu in PUS:
        total_full = sum(table.require(i, pu).w for i in range(n))
        total_seg = sum(stable.require(s, pu).w for s in chain)
        assert total_seg == pytest.approx(total_full, rel=1e-9)
