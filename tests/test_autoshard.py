"""TPU autoshard mode: invariants of the sharding-strategy search."""
import jax
import jax.numpy as jnp
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.configs import ALL_ARCHS, get_config
from repro.core.autoshard import (STRATEGIES, AutoshardResult,
                                  ShardingCostModel, autoshard,
                                  emit_overrides)
from repro.core.modelgraph import model_op_graph
from repro.core.op import FusedOp, OpGraph


def _graph(arch="llama3.2-1b", kind="decode", batch=128, seq=4096):
    return model_op_graph(get_config(arch), kind=kind, batch=batch, seq=seq)


@pytest.mark.parametrize("arch", ALL_ARCHS)
def test_never_worse_than_best_single(arch):
    g = _graph(arch)
    r = autoshard(g, d_data=4, d_model=4)
    assert r.speedup >= 1.0 - 1e-9
    # every assigned strategy must actually be in the table
    for pos, oi in enumerate(r.schedule.chain):
        assert r.table.supported(oi, r.schedule.assignment[pos])


def test_direct_reshard_at_least_as_good():
    for arch in ("llama3.2-1b", "granite-moe-1b-a400m", "xlstm-125m"):
        g = _graph(arch, kind="train", batch=256, seq=4096)
        base = autoshard(g, d_data=16, d_model=16)
        direct = autoshard(g, d_data=16, d_model=16, direct_reshard=True)
        assert direct.schedule.latency <= base.schedule.latency + 1e-12


def test_soft_feasibility_degrades_to_rep():
    """A non-divisible dim degrades the strategy to replicated cost, it
    does not drop the table entry (matches XLA divisibility behaviour)."""
    m = ShardingCostModel(d_data=16, d_model=16)
    op = FusedOp(name="odd", kind="matmul",
                 in_shapes=((7, 33), (33, 13)), out_shape=(7, 13))
    e_tp = m.entry(op, "TP")
    e_rep = m.entry(op, "REP")
    assert e_tp is not None and e_tp.kernel == e_rep.kernel


def test_hard_unsupported_omitted():
    m = ShardingCostModel(d_data=4, d_model=4)
    op = FusedOp(name="x", kind="matmul", in_shapes=((64, 64), (64, 64)),
                 out_shape=(64, 64), meta={"unsupported_on": ("TP",)})
    assert m.entry(op, "TP") is None
    assert m.entry(op, "DP") is not None


def test_weight_vs_activation_asymmetry():
    """Decode-shape GEMMs (weight-dominated) must prefer TP over DP;
    train-shape GEMMs (activation-dominated) the reverse — the TPU analog
    of the paper's Observation 2."""
    m = ShardingCostModel(d_data=16, d_model=16)
    decode_mm = FusedOp(name="d", kind="matmul",
                        in_shapes=((128, 8192), (8192, 8192)),
                        out_shape=(128, 8192))
    train_mm = FusedOp(name="t", kind="matmul",
                       in_shapes=((1048576, 1024), (1024, 1024)),
                       out_shape=(1048576, 1024))
    assert m.entry(decode_mm, "TP").kernel < m.entry(decode_mm, "DP").kernel
    assert m.entry(train_mm, "DP").kernel <= m.entry(train_mm, "TP").kernel * 1.001


@settings(max_examples=25, deadline=None)
@given(
    dd=st.sampled_from([2, 4, 8, 16]),
    dm=st.sampled_from([2, 4, 8, 16]),
    m_dim=st.sampled_from([64, 256, 1024]),
    k_dim=st.sampled_from([128, 512]),
)
def test_cost_monotone_in_mesh(dd, dm, m_dim, k_dim):
    """More chips never increase an op's kernel time under DP_TP."""
    op = FusedOp(name="mm", kind="matmul",
                 in_shapes=((m_dim, k_dim), (k_dim, k_dim)),
                 out_shape=(m_dim, k_dim))
    small = ShardingCostModel(d_data=dd, d_model=dm).entry(op, "DP_TP")
    big = ShardingCostModel(d_data=2 * dd, d_model=2 * dm).entry(op, "DP_TP")
    # with feasibility: divisible dims only
    if m_dim % (2 * dd) == 0 and k_dim % (2 * dm) == 0:
        assert big.kernel <= small.kernel + 1e-12


def test_emit_overrides_lowers():
    """Overrides emitted from a schedule must produce a compilable jit."""
    from repro.models import model as M
    from repro.sharding import Policy
    from repro.launch.mesh import make_host_mesh
    cfg = get_config("llama3.2-1b").reduced()
    ov = emit_overrides({"attn_q": "DP_TP", "mlp_h": "TP", "logits": "DP"})
    mesh = make_host_mesh()
    policy = Policy(mesh=mesh, fsdp=True, overrides=ov)
    params = jax.eval_shape(lambda: M.param_shapes(cfg))
    batch = {"tokens": jax.ShapeDtypeStruct((4, 32), jnp.int32),
             "labels": jax.ShapeDtypeStruct((4, 32), jnp.int32)}
    with mesh:
        compiled = jax.jit(
            lambda p, b: M.loss_fn(cfg, p, b, policy)[0]).lower(
                params, batch).compile()
    assert compiled is not None


def test_emit_overrides_unknown_strategy():
    with pytest.raises(KeyError):
        emit_overrides({"site": "NOT_A_STRATEGY"})


def test_dense_train_near_unity_moe_gains():
    """Paper-shaped result: uniform dense op mixes gain ~nothing; MoE /
    enc-dec / recurrent mixes gain more (heterogeneity is the source)."""
    dense = autoshard(_graph("mistral-large-123b", "train", 256, 4096),
                      d_data=16, d_model=16)
    moe = autoshard(_graph("granite-moe-1b-a400m", "train", 256, 4096),
                    d_data=16, d_model=16)
    encdec = autoshard(_graph("seamless-m4t-medium", "train", 256, 4096),
                       d_data=16, d_model=16)
    assert dense.speedup <= 1.05
    assert moe.speedup >= 1.1
    assert encdec.speedup >= 1.5
