"""HealthMonitor unit suite: breaker state machine, EWMA drift
rescaling, condition synthesis, and probe-backoff accounting.

Pure state-machine tests — no execution, no jax — so they run in the
tier-1 sweep unmarked.  The serving-loop integration (breakers driven
by real injected faults) lives in ``test_chaos_serving.py``.
"""
import pytest

from repro.core import (BreakerTransition, HealthMonitor, HealthPolicy,
                        RuntimeCondition)
from repro.core.health import BREAKER_CLOSED, BREAKER_HALF_OPEN, BREAKER_OPEN


# -- policy validation ------------------------------------------------------

@pytest.mark.parametrize("kw", [
    {"failure_threshold": 0},
    {"ewma_alpha": 0.0},
    {"ewma_alpha": 1.5},
    {"rescale_threshold": 1.0},
    {"cooldown": -0.1},
    {"cooldown": 5.0, "max_cooldown": 1.0},
])
def test_policy_validation(kw):
    with pytest.raises(ValueError):
        HealthPolicy(**kw)


# -- breaker state machine --------------------------------------------------

def test_consecutive_failures_open_the_breaker():
    mon = HealthMonitor(HealthPolicy(failure_threshold=3))
    assert not mon.record_failure("GPU", now=0.0)
    assert not mon.record_failure("GPU", now=0.1)
    assert mon.health("GPU").state == BREAKER_CLOSED
    assert mon.record_failure("GPU", now=0.2)       # third opens
    assert mon.health("GPU").state == BREAKER_OPEN
    assert mon.quarantined() == {"GPU"}
    assert mon.opens == 1 and mon.dirty()


def test_success_resets_the_failure_counter():
    mon = HealthMonitor(HealthPolicy(failure_threshold=2))
    mon.record_failure("GPU", now=0.0)
    mon.observe("GPU", predicted=1.0, measured=1.0, now=0.1)
    assert mon.health("GPU").consecutive_failures == 0
    assert not mon.record_failure("GPU", now=0.2)   # counting restarts
    assert mon.health("GPU").state == BREAKER_CLOSED


def test_loss_opens_immediately():
    mon = HealthMonitor(HealthPolicy(failure_threshold=5))
    mon.record_loss("NPU", now=1.0)
    assert mon.health("NPU").state == BREAKER_OPEN
    assert mon.transitions[-1].reason == "pu_lost"


def test_cooldown_half_open_probe_cycle():
    mon = HealthMonitor(HealthPolicy(failure_threshold=1, cooldown=0.5))
    mon.record_failure("GPU", now=0.0)
    assert mon.due_probes(now=0.4) == []            # cooldown not elapsed
    assert mon.due_probes(now=0.5) == ["GPU"]
    assert mon.health("GPU").state == BREAKER_HALF_OPEN
    assert mon.due_probes(now=0.6) == []            # already half-open
    mon.probe_result("GPU", ok=True, now=0.7)
    th = mon.health("GPU")
    assert th.state == BREAKER_CLOSED
    assert th.cooldown == 0.5 and th.opened_at is None
    assert mon.readmits == 1
    states = [(t.frm, t.to) for t in mon.transitions]
    assert states == [("closed", "open"), ("open", "half_open"),
                      ("half_open", "closed")]


def test_failed_probe_reopens_with_backoff():
    pol = HealthPolicy(failure_threshold=1, cooldown=0.5,
                       cooldown_backoff=2.0, max_cooldown=1.6)
    mon = HealthMonitor(pol)
    mon.record_failure("GPU", now=0.0)
    for k, expect in enumerate([1.0, 1.6, 1.6]):    # growth then cap
        t_half = mon.health("GPU").opened_at + mon.health("GPU").cooldown
        assert mon.due_probes(now=t_half) == ["GPU"]
        mon.probe_result("GPU", ok=False, now=t_half)
        assert mon.health("GPU").state == BREAKER_OPEN
        assert mon.health("GPU").cooldown == pytest.approx(expect)


def test_failure_during_half_open_counts_as_failed_probe():
    mon = HealthMonitor(HealthPolicy(failure_threshold=1, cooldown=0.1))
    mon.record_failure("GPU", now=0.0)
    mon.due_probes(now=0.2)
    assert mon.record_failure("GPU", now=0.25)      # probe dispatch failed
    assert mon.health("GPU").state == BREAKER_OPEN
    assert mon.health("GPU").cooldown > 0.1


def test_probe_result_ignored_unless_half_open():
    mon = HealthMonitor()
    mon.probe_result("GPU", ok=True, now=0.0)       # no-op on closed
    assert mon.health("GPU").state == BREAKER_CLOSED
    assert mon.readmits == 0 and not mon.transitions


# -- EWMA drift / rescale ---------------------------------------------------

def _calibrate(mon, pu="GPU", ratio=2.0, n=8, t0=0.0):
    for k in range(n):
        mon.observe(pu, predicted=1.0, measured=ratio, now=t0 + k * 0.01)


def test_drift_rescale_recommended_past_threshold():
    pol = HealthPolicy(calibration=8, rescale_threshold=4.0, ewma_alpha=0.5)
    mon = HealthMonitor(pol)
    _calibrate(mon, ratio=2.0)                      # baseline ~= 2.0
    mon.dirty()                                     # clear any noise
    assert mon.health("GPU").baseline == pytest.approx(2.0)
    for k in range(20):                             # 10x slower than profile
        mon.observe("GPU", predicted=1.0, measured=20.0, now=1.0 + k * 0.01)
    th = mon.health("GPU")
    assert th.rescale is not None and th.rescale >= 4.0
    assert mon.rescales == 1 and mon.dirty()
    assert any("drift_rescale" in t.reason for t in mon.transitions)


def test_drift_rescale_hysteresis_and_recovery():
    pol = HealthPolicy(calibration=4, rescale_threshold=4.0,
                       rescale_hysteresis=0.5, ewma_alpha=0.5)
    mon = HealthMonitor(pol)
    _calibrate(mon, ratio=1.0, n=4)
    for k in range(20):
        mon.observe("GPU", predicted=1.0, measured=10.0, now=1.0 + k * 0.01)
    assert mon.health("GPU").rescale is not None
    mon.dirty()
    # drifting back but above thr*hysteresis keeps the rescale active
    # (no thrash); dropping below it clears the recommendation
    for k in range(200):
        mon.observe("GPU", predicted=1.0, measured=1.0, now=2.0 + k * 0.01)
        if mon.health("GPU").rescale is None:
            break
    assert mon.health("GPU").rescale is None
    assert any(t.reason == "drift_recovered" for t in mon.transitions)


def test_drift_needs_calibration_first():
    mon = HealthMonitor(HealthPolicy(calibration=8))
    for k in range(7):
        mon.observe("GPU", predicted=1.0, measured=100.0, now=k * 0.01)
    th = mon.health("GPU")
    assert th.baseline is None and th.drift() is None
    assert th.rescale is None                       # never before baseline


# -- condition synthesis ----------------------------------------------------

def test_condition_folds_quarantine_and_rescale():
    mon = HealthMonitor(HealthPolicy(failure_threshold=1, calibration=2,
                                     rescale_threshold=2.0, ewma_alpha=1.0))
    mon.record_failure("NPU", now=0.0)              # NPU quarantined
    _calibrate(mon, pu="GPU", ratio=1.0, n=2)
    mon.observe("GPU", predicted=1.0, measured=5.0, now=0.1)  # 5x drift
    base = RuntimeCondition(slowdown={"CPU": 1.5})
    cond = mon.condition(base)
    assert cond.unavailable == frozenset({"NPU"})
    assert cond.slowdown["CPU"] == 1.5              # base preserved
    assert cond.slowdown["GPU"] == pytest.approx(5.0)


def test_condition_restores_half_open_for_probing():
    mon = HealthMonitor(HealthPolicy(failure_threshold=1, cooldown=0.1))
    mon.record_failure("GPU", now=0.0)
    base = RuntimeCondition(unavailable=frozenset({"GPU"}))
    assert "GPU" in mon.condition(base).unavailable
    mon.due_probes(now=0.2)                         # -> half-open
    # the probe needs the lane plannable even if the *base* condition
    # still lists it: health owns the lane while its breaker is live
    assert "GPU" not in mon.condition(base).unavailable
    mon.probe_result("GPU", ok=True, now=0.3)
    assert "GPU" not in mon.condition().unavailable


def test_rescale_suppressed_while_not_closed():
    mon = HealthMonitor(HealthPolicy(failure_threshold=1, calibration=2,
                                     rescale_threshold=2.0, ewma_alpha=1.0))
    _calibrate(mon, pu="GPU", ratio=1.0, n=2)
    mon.observe("GPU", predicted=1.0, measured=9.0, now=0.1)
    mon.record_failure("GPU", now=0.2)              # opens
    cond = mon.condition()
    assert "GPU" in cond.unavailable
    assert "GPU" not in cond.slowdown               # unavailable, not slow


# -- accounting -------------------------------------------------------------

def test_stats_shape_and_transition_log():
    mon = HealthMonitor(HealthPolicy(failure_threshold=1, cooldown=0.1))
    mon.record_failure("GPU", now=0.0)
    mon.due_probes(now=0.2)
    mon.probe_result("GPU", ok=True, now=0.3)
    s = mon.stats()
    assert s["opens"] == 1 and s["probes"] == 1 and s["readmits"] == 1
    assert s["quarantined"] == [] and s["half_open"] == []
    assert s["targets"]["GPU"]["state"] == "closed"
    assert [t["to"] for t in s["transitions"]] == \
        ["open", "half_open", "closed"]
    assert all(isinstance(t, dict) for t in s["transitions"])


def test_dirty_is_read_and_clear():
    mon = HealthMonitor(HealthPolicy(failure_threshold=1))
    assert not mon.dirty()
    mon.record_failure("GPU", now=0.0)
    assert mon.dirty() and not mon.dirty()
