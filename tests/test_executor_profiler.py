"""Executor equivalence + jaxpr fused-op extraction tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (EdgeSoCCostModel, FusedOp, OpGraph, ScheduleExecutor,
                        chain_graph, solve_parallel, solve_sequential,
                        trace_fused_ops)
from repro.core.costmodel import EDGE_PUS


def _payload_chain(rng, n=6):
    """A chain of real computations: each op consumes the previous output."""
    ops = []
    for i in range(n):
        if i % 3 == 0:
            w = jnp.asarray(rng.standard_normal((16, 16)), jnp.float32)
            fn = (lambda w: lambda x=None: jnp.ones((4, 16)) @ w)(w) if i == 0 \
                else (lambda w: lambda x: x @ w)(w)
            op = FusedOp(name=f"mm{i}", kind="matmul",
                         in_shapes=((4, 16), (16, 16)), out_shape=(4, 16), fn=fn)
        elif i % 3 == 1:
            op = FusedOp(name=f"act{i}", kind="act", in_shapes=((4, 16),),
                         out_shape=(4, 16), fn=lambda x: jax.nn.silu(x))
        else:
            op = FusedOp(name=f"norm{i}", kind="norm", in_shapes=((4, 16),),
                         out_shape=(4, 16),
                         fn=lambda x: x / (jnp.linalg.norm(x) + 1.0))
        ops.append(op)
    return chain_graph(ops)


def test_executor_sequential_schedule_matches_monolithic():
    rng = np.random.default_rng(0)
    g = _payload_chain(rng)
    table = EdgeSoCCostModel().build_table(g)
    sched = solve_sequential(list(range(len(g))), g.ops, table, EDGE_PUS)
    ex = ScheduleExecutor(list(EDGE_PUS))
    mono = ex.run_monolithic(g)
    orch = ex.run_scheduled(g, {i: p for i, p in enumerate(sched.assignment)})
    assert ex.outputs_close(mono, orch)


def test_executor_parallel_branches():
    """Fork/join graph with real payloads; parallel schedule == monolithic."""
    w1 = jnp.arange(16.0).reshape(4, 4) / 10.0
    ops = [
        FusedOp("src", "matmul", ((4, 4), (4, 4)), (4, 4),
                fn=lambda: jnp.eye(4) @ w1),
        FusedOp("a1", "act", ((4, 4),), (4, 4), fn=jnp.tanh),
        FusedOp("a2", "act", ((4, 4),), (4, 4), fn=jnp.sin),
        FusedOp("join", "add", ((4, 4), (4, 4)), (4, 4),
                fn=lambda x, y: x + y),
    ]
    g = OpGraph(ops, edges=[(0, 1), (0, 2), (1, 3), (2, 3)])
    table = EdgeSoCCostModel().build_table(g)
    par = solve_parallel(g, table, EDGE_PUS)
    ex = ScheduleExecutor(list(EDGE_PUS))
    mono = ex.run_monolithic(g)
    orch = ex.run_scheduled(g, par.assignment)
    assert ex.outputs_close(mono, orch)
    np.testing.assert_allclose(np.asarray(orch[3]),
                               np.tanh(np.asarray(w1)) + np.sin(np.asarray(w1)),
                               rtol=1e-6)


def test_trace_fused_ops_mlp():
    """A 3-matmul MLP must extract 3 fused matmul ops (norm/act fused in)."""
    def mlp(x, w1, w2, w3):
        h = jax.nn.silu(x @ w1)
        h = h * jax.nn.sigmoid(h @ w2)
        return h @ w3

    x = jnp.ones((2, 8))
    w = [jnp.ones((8, 8))] * 3
    g = trace_fused_ops(mlp, x, *w)
    kinds = [o.kind for o in g.ops]
    assert kinds.count("matmul") == 3
    assert g.is_chain()
    # fused elementwise FLOPs must have been attributed
    assert any(o.flops > 2 * 2 * 8 * 8 for o in g.ops if o.kind == "matmul")


def test_trace_fused_ops_scan():
    def f(x):
        def step(c, xi):
            c = 0.5 * c + xi
            return c, c
        _, ys = jax.lax.scan(step, jnp.zeros(x.shape[1:]), x)
        return ys.sum()

    g = trace_fused_ops(f, jnp.ones((16, 4)))
    assert any(o.kind == "scan" for o in g.ops)


# ---------------------------------------------------------------------------
# error propagation: a failing op must raise the original exception from
# the lane workers without deadlocking the other lanes
# ---------------------------------------------------------------------------


class _PayloadError(Exception):
    pass


def _boom(*_a):
    raise _PayloadError("op payload failed")


def _failing_chain(n=5, fail_at=2):
    ops = []
    for i in range(n):
        fn = _boom if i == fail_at else (lambda a=None: jnp.ones((4, 4))
                                         if a is None else jnp.tanh(a))
        ops.append(FusedOp(f"op{i}", "act", ((4, 4),), (4, 4), fn=fn))
    return chain_graph(ops)


def test_run_scheduled_propagates_original_exception_no_deadlock():
    g = _failing_chain()
    ex = ScheduleExecutor(list(EDGE_PUS))
    # spread ops across all three lanes so downstream lanes really are
    # blocked on the failing op's event when it dies
    assignment = {0: "CPU", 1: "GPU", 2: "NPU", 3: "CPU", 4: "GPU"}
    with pytest.raises(_PayloadError, match="op payload failed"):
        ex.run_scheduled(g, assignment)


def test_run_concurrent_propagates_original_exception_no_deadlock():
    from repro.core import EdgeSoCCostModel, Orchestrator

    good = chain_graph([
        FusedOp(f"g{i}", "act", ((4, 4),), (4, 4),
                fn=(lambda a=None: jnp.ones((4, 4)) if a is None
                    else jnp.sin(a)))
        for i in range(4)])
    bad = _failing_chain(4, fail_at=1)
    orch = Orchestrator(EdgeSoCCostModel())
    plan = orch.plan([orch.register(good), orch.register(bad)])
    with pytest.raises(_PayloadError, match="op payload failed"):
        orch.executor.run_concurrent([good, bad], plan.schedule)


# ---------------------------------------------------------------------------
# MeasuredProfiler: measurement failures are collected, not swallowed
# ---------------------------------------------------------------------------


def _measurable_graph(fail_op=1):
    def ok(x):
        return jnp.tanh(x)

    def broken(x):
        raise _PayloadError("unmeasurable payload")

    x = jnp.ones((8, 8))
    ops = []
    for i in range(3):
        fn = broken if i == fail_op else ok
        ops.append(FusedOp(f"m{i}", "act", ((8, 8),), (8, 8), fn=fn,
                           meta={"example_inputs": (x,)}))
    return chain_graph(ops)


def test_measured_profiler_records_failures_and_falls_back(caplog):
    from repro.core import MeasuredProfiler

    g = _measurable_graph(fail_op=1)
    prof = MeasuredProfiler(warmup=0, iters=1)
    with caplog.at_level("WARNING", logger="repro.core.profiler"):
        table = prof.profile(g)
    failures = table.meta["profile_failures"]
    assert set(failures) == {1}
    assert "_PayloadError" in failures[1]
    assert "unmeasurable payload" in failures[1]
    assert any("measurement failed" in r.message for r in caplog.records)
    # the failed op fell back to the pure analytic estimate (scale 1.0)
    analytic = prof.model.build_table(g)
    for pu in table.pus:
        assert table.require(1, pu).kernel == analytic.require(1, pu).kernel
    # measured ops still got a real (scaled) CPU anchor
    assert table.require(0, "CPU").kernel > 0


def test_measured_profiler_strict_raises_with_op_context():
    from repro.core import MeasuredProfiler

    g = _measurable_graph(fail_op=2)
    prof = MeasuredProfiler(warmup=0, iters=1)
    with pytest.raises(RuntimeError, match=r"op 2 \('m2'"):
        prof.profile(g, strict=True)
    # the knob is also a constructor default
    with pytest.raises(RuntimeError, match="measuring op 2"):
        MeasuredProfiler(warmup=0, iters=1, strict=True).profile(g)


def test_measured_profiler_clean_run_has_no_failures():
    from repro.core import MeasuredProfiler

    g = _measurable_graph(fail_op=-1)          # no failing op
    table = MeasuredProfiler(warmup=0, iters=1).profile(g)
    assert table.meta["profile_failures"] == {}
