"""Target registry + backend binding: registry semantics, per-PU variant
selection/verification on the compiled path, per-target measured
profiling, fenced timing, and stale-variant program invalidation."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (FusedOp, Orchestrator, ScheduleExecutor, chain_graph,
                        results_bitwise_equal)
from repro.core.backends import (default_registry, device_target,
                                 discover_devices, numpy_eager,
                                 pallas_interpret, xla_cpu)
from repro.core.laneprogram import JIT, PYTHON
from repro.core.profiler import (Measurement, MeasuredProfiler,
                                 measure_callable, measure_callable_stats)
from repro.core.schedule import ConcurrentSchedule, ConcurrentStep
from repro.core.targets import (Target, TargetRegistry, pu_specs_for_targets,
                                resolve_targets, variant_tolerance)
from repro.core.workload import Workload


def _x(dim=8):
    return jnp.linspace(0.0, 1.0, dim * dim,
                        dtype=jnp.float32).reshape(dim, dim)


def _variant_chain(n=4, dim=8, variants=None):
    """Chain of tanh payloads; ``variants`` maps op index -> extra
    payload table entries installed as ``op.variants``."""
    ops = []
    for i in range(n):
        c = jnp.float32(1.0 + 0.01 * i)
        fn = (lambda c: lambda v: jnp.tanh(v * c))(c)
        op = FusedOp(f"o{i}", "act", ((dim, dim),), (dim, dim), fn=fn)
        op.meta["example_inputs"] = (_x(dim),)
        if variants and i in variants:
            op.variants = dict(variants[i])
        ops.append(op)
    return chain_graph(ops)


def _three_targets():
    return {
        "host": numpy_eager(name="host"),
        "fast": xla_cpu(name="fast"),
        "alt": Target(name="alt", dialect="alt", jit=False,
                      dispatch_s=1e-6, handoff_s=0.0),
    }


# ---------------------------------------------------------------------------
# registry + resolution
# ---------------------------------------------------------------------------


def test_registry_register_get_names():
    reg = TargetRegistry([numpy_eager(), xla_cpu()])
    assert reg.names() == ["numpy-eager", "xla-cpu"]
    assert "xla-cpu" in reg and len(reg) == 2
    assert reg.get("numpy-eager").dialect == "numpy"
    with pytest.raises(KeyError, match="registered"):
        reg.get("nope")
    with pytest.raises(ValueError, match="already registered"):
        reg.register(xla_cpu())
    faster = reg.register(xla_cpu(dispatch_s=1e-6), replace=True)
    assert reg.get("xla-cpu") is faster
    with pytest.raises(TypeError):
        reg.register("xla-cpu")


def test_default_registry_contains_builtins_and_devices():
    reg = default_registry()
    for name in ("numpy-eager", "xla-cpu", "pallas-interpret"):
        assert name in reg
    devs = discover_devices()    # must never raise
    for t in devs:
        assert t.name in reg
    assert len(default_registry(devices=False)) == 3


def test_resolve_targets_forms():
    assert resolve_targets(None) is None
    reg = TargetRegistry([numpy_eager(), xla_cpu()])
    by_reg = resolve_targets(reg)
    assert set(by_reg) == {"numpy-eager", "xla-cpu"}
    t = xla_cpu()
    assert resolve_targets({"A": t, "B": t}) == {"A": t, "B": t}
    assert set(resolve_targets([numpy_eager(), xla_cpu()])) \
        == {"numpy-eager", "xla-cpu"}
    with pytest.raises(ValueError, match="empty"):
        resolve_targets({})
    with pytest.raises(TypeError, match="expected a Target"):
        resolve_targets({"A": "xla-cpu"})


def test_target_pu_spec_and_tolerance():
    t = xla_cpu(handoff_s=3e-3, power_compute=9.0)
    spec = t.pu_spec()
    assert spec.name == "xla-cpu" and spec.is_accelerator
    assert spec.h2d_base == 3e-3 and spec.power_compute == 9.0
    assert spec.kind_eff.get("other") == 1.0
    # declared atol/rtol override the per-dtype variant buckets
    assert t.tolerance(np.float32) == (t.atol, t.rtol)
    assert numpy_eager().tolerance(np.float32) \
        == variant_tolerance(np.float32)
    assert variant_tolerance(np.int32) == (0.0, 0.0)
    specs = pu_specs_for_targets({"L0": t})
    assert specs["L0"].name == "xla-cpu"   # keyed by lane, named by target


def test_workload_accepts_target_values_as_pus():
    g = _variant_chain(3)
    binding = _three_targets()
    table = MeasuredProfiler(warmup=0, iters=1, targets=binding).profile(g)
    wl = Workload.build(list(range(3)), table, binding, ops=g.ops)
    assert all(hasattr(p, "is_accelerator") for p in wl.pus.values())


# ---------------------------------------------------------------------------
# orchestrator / executor binding
# ---------------------------------------------------------------------------


def test_orchestrator_derives_lanes_from_targets():
    binding = _three_targets()
    g = _variant_chain(3)
    table = MeasuredProfiler(warmup=1, iters=2, targets=binding).profile(g)
    orch = Orchestrator(table, targets=binding)
    assert set(orch.pus) == set(binding)
    plan = orch.plan(orch.register(g))
    lanes = {lane for _, lane in plan.route[0]}
    assert lanes <= set(binding)


def test_unknown_target_lane_rejected():
    with pytest.raises(ValueError, match="nope"):
        ScheduleExecutor({"A": numpy_eager().pu_spec()},
                         targets={"nope": numpy_eager()})


# ---------------------------------------------------------------------------
# variant selection + probe verification on the compiled path
# ---------------------------------------------------------------------------


def _compiled_on(binding, graph, lane):
    ex = ScheduleExecutor(pu_specs_for_targets(binding), targets=binding)
    prog = ex.compile_scheduled(graph, {i: lane
                                        for i in range(len(graph))})
    return ex, prog


def test_variant_bitwise_accept_and_serve():
    binding = _three_targets()
    # the alt variant is a different callable computing the same value
    variants = {1: {"alt": lambda v: jnp.tanh(v * jnp.float32(1.01))}}
    g = _variant_chain(3, variants=variants)
    ex, prog = _compiled_on(binding, g, "alt")
    got = prog.run({0: (_x(),)})
    st = prog.stats
    assert st["n_variant"] == 1
    assert set(st["variant_verified"].values()) == {"bitwise"}
    mono = ex.run_monolithic(g, {0: (_x(),)})
    assert results_bitwise_equal(mono, got)


def test_variant_tolerance_accept():
    binding = _three_targets()
    eps = jnp.float32(1e-6)      # inside the f32 bucket (3e-4)
    variants = {1: {"alt": lambda v: jnp.tanh(v * jnp.float32(1.01)) + eps}}
    g = _variant_chain(3, variants=variants)
    ex, prog = _compiled_on(binding, g, "alt")
    prog.run({0: (_x(),)})               # cold run: probe, serves reference
    got = prog.run({0: (_x(),)})         # warm run: serves accepted variant
    assert set(prog.stats["variant_verified"].values()) == {"tolerance"}
    mono = ex.run_monolithic(g, {0: (_x(),)})
    assert not results_bitwise_equal(mono, got)
    assert ex.outputs_close(mono, got, atol=3e-4, rtol=3e-4)


def test_variant_rejected_falls_back_to_reference():
    binding = _three_targets()
    variants = {1: {"alt": lambda v: jnp.tanh(v) + jnp.float32(1.0)}}
    g = _variant_chain(3, variants=variants)
    ex, prog = _compiled_on(binding, g, "alt")
    got = prog.run({0: (_x(),)})
    assert set(prog.stats["variant_verified"].values()) == {"rejected"}
    assert prog.stats["n_variant"] == 0
    assert results_bitwise_equal(ex.run_monolithic(g, {0: (_x(),)}), got)


def test_variant_error_falls_back_to_reference():
    binding = _three_targets()

    def boom(v):
        raise RuntimeError("kernel exploded")

    g = _variant_chain(3, variants={1: {"alt": boom}})
    ex, prog = _compiled_on(binding, g, "alt")
    got = prog.run({0: (_x(),)})
    (verdict,) = set(prog.stats["variant_verified"].values())
    assert verdict.startswith("error")
    assert results_bitwise_equal(ex.run_monolithic(g, {0: (_x(),)}), got)


def test_ref_dialect_never_reads_variant_tables():
    binding = _three_targets()
    poison = {i: {"fast": lambda v: v * 0.0, "ref": lambda v: v * 0.0}
              for i in range(3)}
    g = _variant_chain(3, variants=poison)
    ex, prog = _compiled_on(binding, g, "fast")   # dialect "ref"
    got = prog.run({0: (_x(),)})
    assert prog.stats["n_variant"] == 0
    assert results_bitwise_equal(ex.run_monolithic(g, {0: (_x(),)}), got)


def test_interpreter_path_stays_single_variant_oracle():
    binding = _three_targets()
    variants = {0: {"alt": lambda v: v * jnp.float32(100.0)}}
    g = _variant_chain(2, variants=variants)
    ex = ScheduleExecutor(pu_specs_for_targets(binding), targets=binding)
    got = ex.run_scheduled(g, {0: "alt", 1: "alt"}, {0: (_x(),)})
    assert results_bitwise_equal(ex.run_monolithic(g, {0: (_x(),)}), got)


def test_target_jit_policy_and_tolerance_gated_jit():
    binding = _three_targets()
    g = _variant_chain(4)
    # jit=False target: composed-Python, never jitted
    _, prog = _compiled_on(binding, g, "host")
    prog.run({0: (_x(),)})
    assert [s.mode for s in prog.segments] == [PYTHON]
    # jit=True target with declared tolerance: jit admitted and recorded
    _, prog = _compiled_on(binding, g, "fast")
    prog.run({0: (_x(),)})
    (seg,) = prog.segments
    assert seg.mode == JIT
    assert prog.stats["jit_verified"][seg.index] in ("bitwise", "tolerance")


def test_targetless_segments_remain_strictly_bitwise():
    """The PR 5 analytic path must not inherit tolerance-gated jit."""
    from repro.core.laneprogram import Segment
    seg = Segment(index=0, lane="CPU")
    seg.fns = [lambda e, v: v + jnp.float32(1e-7)]
    seg.argspecs = [[("f", 0)]]
    seg.flat_refs = [(0, 0)]
    assert seg.target is None and seg.jit_verified is None


# ---------------------------------------------------------------------------
# stale-variant invalidation (PR 5 op.fn rule extended to variant tables)
# ---------------------------------------------------------------------------


def test_variant_rebind_invalidates_scheduled_program():
    binding = _three_targets()
    variants = {1: {"alt": lambda v: jnp.tanh(v * jnp.float32(1.01))}}
    g = _variant_chain(3, variants=variants)
    ex, prog = _compiled_on(binding, g, "alt")
    prog.run({0: (_x(),)})
    assert prog.payloads_current()
    g.ops[1].variants["alt"] = lambda v: jnp.tanh(v * jnp.float32(1.02))
    assert not prog.payloads_current()
    # adding a brand-new dialect entry also invalidates
    g2 = _variant_chain(3, variants=variants)
    _, prog2 = _compiled_on(binding, g2, "alt")
    prog2.run({0: (_x(),)})
    g2.ops[0].variants["numpy"] = lambda v: np.tanh(v)
    assert not prog2.payloads_current()


def test_variant_rebind_invalidates_concurrent_program():
    binding = _three_targets()
    variants = {0: {"alt": lambda v: jnp.tanh(v * jnp.float32(1.0))}}
    g0 = _variant_chain(2, variants=variants)
    g1 = _variant_chain(2)
    ex = ScheduleExecutor(pu_specs_for_targets(binding), targets=binding)
    sched = ConcurrentSchedule(
        steps=[ConcurrentStep(ops=(0, 0), pus=("alt", "fast"), cost=1.0),
               ConcurrentStep(ops=(1, 1), pus=("alt", "fast"), cost=1.0)],
        latency=2.0, energy=2.0, objective="latency", mode="aligned")
    prog = ex.compile_concurrent([g0, g1], sched)
    prog.run([{0: (_x(),)}, {0: (_x(),)}])
    assert prog.payloads_current()
    g0.ops[0].variants["alt"] = lambda v: jnp.tanh(v)
    assert not prog.payloads_current()


def test_orchestrator_recompiles_after_variant_rebind():
    binding = _three_targets()
    variants = {1: {"alt": lambda v: jnp.tanh(v * jnp.float32(1.01))}}
    g = _variant_chain(3, variants=variants)
    table = MeasuredProfiler(warmup=1, iters=2, targets=binding).profile(g)
    orch = Orchestrator(table, targets=binding)
    plan = orch.plan(orch.register(g))
    inputs = {0: (_x(),)}
    orch.execute(plan, inputs)
    assert orch.stats["program_misses"] == 1
    orch.execute(plan, inputs)
    assert orch.stats["program_hits"] == 1
    g.ops[1].variants["alt"] = lambda v: jnp.tanh(v * jnp.float32(1.02))
    orch.execute(plan, inputs)           # stale: must recompile, not serve
    assert orch.stats["program_misses"] == 2


# ---------------------------------------------------------------------------
# fenced timing (satellite: async-skew regression)
# ---------------------------------------------------------------------------


def test_measure_callable_fences_async_dispatch():
    """A jitted payload must be timed to completion, not to dispatch:
    unfenced timing of a chained 512x512 matmul reports ~dispatch cost
    (tens of us); fenced timing cannot."""
    a = jnp.ones((512, 512), jnp.float32) * 0.01

    def payload(x):
        for _ in range(8):
            x = x @ x + x
        return x

    m = measure_callable_stats(payload, (a,), warmup=1, iters=3, jit=True)
    assert m.median >= 1e-4          # dispatch alone is ~1e-5
    assert m.best <= m.median <= max(m.times)
    assert len(m.times) == 3
    assert float(m) == m.median and m.spread >= 0.0
    assert measure_callable(payload, (a,), warmup=1, iters=2) > 0.0


def test_measurement_reports_median_and_best():
    m = Measurement(median=2.0, best=1.0, times=(1.0, 2.0, 3.0))
    assert m.spread == 2.0 and float(m) == 2.0


def test_measure_callable_forces_warmup_before_timing():
    """warmup=0 still compiles before the timed loop: compilation time
    must never land in the measured median."""
    calls = []

    def payload(x):
        calls.append(1)      # traced once per compilation
        return x * 2.0

    measure_callable_stats(payload, (jnp.ones((4,)),), warmup=0, iters=2)
    assert len(calls) == 1   # compiled during (forced) warmup, then cached


# ---------------------------------------------------------------------------
# per-target measured profiling
# ---------------------------------------------------------------------------


@pytest.mark.backend
def test_profiler_measures_every_op_on_every_target():
    binding = _three_targets()
    g = _variant_chain(3)
    table = MeasuredProfiler(warmup=1, iters=2, targets=binding).profile(g)
    assert list(table.pus) == list(binding)
    for i in range(3):
        for lane, tgt in binding.items():
            e = table.get(i, lane)
            assert e is not None and e.kernel > 0
            assert e.dispatch == tgt.dispatch_s
            assert e.h2d == tgt.handoff_s
    meta = table.meta
    assert set(meta["measurements"]) == {(i, lane) for i in range(3)
                                         for lane in binding}
    assert meta["profile_failures"] == {}
    assert meta["targets"] == {lane: t.name for lane, t in binding.items()}
    m = meta["measurements"][(0, "host")]
    assert m["best"] <= m["median"] and m["spread"] >= 0.0


@pytest.mark.backend
def test_profiler_omits_cell_on_target_failure():
    binding = _three_targets()
    g = _variant_chain(3)

    def only_eager(v):
        if isinstance(jnp.asarray(v), jax.core.Tracer):
            raise RuntimeError("no tracing here")
        return np.tanh(np.asarray(v))

    g.ops[1].fn = only_eager     # fails under jit targets only
    table = MeasuredProfiler(warmup=1, iters=1, targets=binding).profile(g)
    assert table.get(1, "fast") is None          # jit target: cell omitted
    assert table.get(1, "host") is not None      # eager target: fine
    failures = table.meta["profile_failures"]
    assert (1, "fast") in failures
    with pytest.raises(RuntimeError, match="o1.*fast"):
        MeasuredProfiler(warmup=1, iters=1, targets=binding,
                         strict=True).profile(g)


@pytest.mark.backend
def test_profiler_respects_unsupported_on_and_anchors_payload_less_ops():
    binding = _three_targets()
    g = _variant_chain(3)
    g.ops[0].meta["unsupported_on"] = ("host",)
    del g.ops[2].meta["example_inputs"]          # no example: analytic
    table = MeasuredProfiler(warmup=1, iters=1, targets=binding).profile(g)
    assert table.get(0, "host") is None
    assert table.get(0, "fast") is not None
    fallback = set(table.meta["analytic_fallback"])
    assert fallback == {(2, lane) for lane in binding}
    for lane in binding:
        assert table.get(2, lane) is not None


@pytest.mark.backend
def test_per_target_cells_differ_between_eager_and_jit():
    """The whole point: one op, different measured numbers per backend."""
    binding = _three_targets()
    g = _variant_chain(2)
    table = MeasuredProfiler(warmup=1, iters=3, targets=binding).profile(g)
    kernels = {lane: table.get(0, lane).kernel for lane in binding}
    assert len({round(v, 9) for v in kernels.values()}) > 1
