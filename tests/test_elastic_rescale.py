"""Elastic rescale end-to-end: train on mesh A, checkpoint, restore on a
*different* mesh shape, and continue with an identical loss trajectory.

This is the DESIGN.md §6 contract: checkpoints are stored logically
unsharded, so a restarted job may come back with a different device
count/topology (lost pod) and resume exactly.  Runs in a subprocess so
the 8 virtual host devices don't leak into the rest of the suite.
"""
import json
import os
import subprocess
import sys

import numpy as np

_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json, sys
import jax, jax.numpy as jnp
from repro.checkpoint import ckpt
from repro.configs import get_config
from repro.data.pipeline import DataConfig, SyntheticTokenSource
from repro.models import model as M
from repro.optim import adamw
from repro.sharding import Policy
from repro.train import trainer as T
from jax.sharding import Mesh

mode, ckpt_dir = sys.argv[1], sys.argv[2]

cfg = dataclasses.replace(
    get_config("llama3.2-1b"), name="elastic", n_layers=2, d_model=64,
    n_heads=4, n_kv_heads=2, d_head=16, d_ff=128, vocab=128,
    dtype="float32", remat=False, q_chunk=32, kv_chunk=32)
src = SyntheticTokenSource(DataConfig(global_batch=8, seq_len=16,
                                      vocab=cfg.vocab),
                           process_index=0, process_count=1)
tc = T.TrainConfig(opt=adamw.AdamWConfig(lr=1e-3, warmup_steps=2,
                                         total_steps=10))

def make_mesh(shape):
    return jax.make_mesh(shape, ("data", "model"))

def run_steps(params, opt, policy, mesh, start, n):
    step = T.jit_train_step(cfg, tc, policy,
                            jax.eval_shape(lambda: params),
                            jax.eval_shape(lambda: src(0)))
    losses = []
    for i in range(start, start + n):
        b = jax.tree.map(jnp.asarray, src(i))
        with mesh:
            params, opt, met = step(params, opt, b)
        losses.append(float(met["loss"]))
    return params, opt, losses

if mode == "full":
    # uninterrupted 6 steps on mesh (4, 2)
    mesh = make_mesh((4, 2))
    policy = Policy(mesh=mesh, fsdp=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init_state(tc.opt, params)
    _, _, losses = run_steps(params, opt, policy, mesh, 0, 6)
    print(json.dumps(losses))
elif mode == "phase1":
    # 3 steps on mesh (4, 2), then checkpoint
    mesh = make_mesh((4, 2))
    policy = Policy(mesh=mesh, fsdp=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init_state(tc.opt, params)
    params, opt, losses = run_steps(params, opt, policy, mesh, 0, 3)
    ckpt.save(ckpt_dir, 3, {"params": params, "opt": opt},
              extra={"data": src.checkpoint_state(3)})
    print(json.dumps(losses))
else:
    # restore on a DIFFERENT mesh (2, 4) and continue 3 steps
    mesh = make_mesh((2, 4))
    policy = Policy(mesh=mesh, fsdp=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    opt = adamw.init_state(tc.opt, params)
    state, extra = ckpt.restore(ckpt_dir, {"params": params, "opt": opt})
    start = SyntheticTokenSource.resume_step(extra["data"])
    _, _, losses = run_steps(state["params"], state["opt"], policy, mesh,
                             start, 3)
    print(json.dumps(losses))
"""


def _run(mode: str, ckpt_dir: str) -> list[float]:
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.path.join(root, "src")
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT, mode, ckpt_dir],
        capture_output=True, text=True, env=env, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    return json.loads(out.stdout.strip().splitlines()[-1])


def test_elastic_rescale_exact_resume(tmp_path):
    ckpt_dir = str(tmp_path / "ck")
    full = _run("full", ckpt_dir)
    first = _run("phase1", ckpt_dir)
    resumed = _run("phase2", ckpt_dir)
    np.testing.assert_allclose(first, full[:3], rtol=1e-5)
    # resumed on the (2,4) mesh must continue the (4,2) trajectory
    np.testing.assert_allclose(resumed, full[3:], rtol=1e-4, atol=1e-5)
