"""Checkpoint atomicity/roundtrip/elastic-reshard, fault manager, and
data-pipeline determinism tests."""
import glob
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.data.pipeline import DataConfig, SyntheticTokenSource
from repro.fault.manager import (FaultConfig, HeartbeatTracker,
                                 RecoverableError, StragglerDetector,
                                 run_with_recovery)


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (8, 16)),
            "b": {"w": jnp.arange(12, dtype=jnp.int32).reshape(3, 4),
                  "s": jnp.float32(3.5)}}


# ---------------------------------------------------------------------------
# checkpoint
# ---------------------------------------------------------------------------


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t, extra={"data": {"step": 7, "seed": 0}})
    restored, extra = ckpt.restore(str(tmp_path), jax.eval_shape(lambda: t))
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert extra["data"]["step"] == 7


def test_latest_and_gc(tmp_path):
    t = _tree()
    for s in (10, 20, 30, 40, 50):
        ckpt.save(str(tmp_path), s, t, keep_last=2)
    assert ckpt.latest_step(str(tmp_path)) == 50
    kept = sorted(glob.glob(os.path.join(str(tmp_path), "step_*")))
    assert len(kept) == 2


def test_atomic_no_partial(tmp_path):
    """A .tmp directory left by a crash is never picked up as latest."""
    t = _tree()
    ckpt.save(str(tmp_path), 10, t)
    os.makedirs(os.path.join(str(tmp_path), "step_00000099.tmp"))
    assert ckpt.latest_step(str(tmp_path)) == 10


def test_elastic_reshard_restore(tmp_path):
    """Save under one mesh, restore under a different device layout: the
    checkpoint is stored logically unsharded, so restore just re-shards."""
    from repro.launch.mesh import make_host_mesh
    from jax.sharding import NamedSharding, PartitionSpec as P
    t = _tree()
    mesh = make_host_mesh()
    sharded = jax.device_put(t, NamedSharding(mesh, P()))
    ckpt.save(str(tmp_path), 3, sharded)
    # restore into a differently-specified target (fresh mesh)
    mesh2 = make_host_mesh(model_axis=1)
    target = jax.eval_shape(lambda: t)
    restored, _ = ckpt.restore(str(tmp_path), target)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# ---------------------------------------------------------------------------
# fault manager
# ---------------------------------------------------------------------------


def test_recovery_restarts_from_checkpoint():
    state = {"ckpt": 0, "fails": 0}
    executed = []

    def step(i):
        if i == 5 and state["fails"] < 2:
            state["fails"] += 1
            raise RecoverableError("injected")
        executed.append(i)

    def save(i):
        state["ckpt"] = i

    stats = run_with_recovery(
        step, start_step=0, total_steps=10,
        cfg=FaultConfig(checkpoint_every=2, max_restarts=5),
        save_fn=save, restore_fn=lambda: state["ckpt"])
    assert stats.restarts == 2
    assert executed[-1] == 9
    # steps from the restored checkpoint re-execute (exactly-resumable)
    assert executed.count(4) == 3


def test_recovery_gives_up_after_max_restarts():
    def step(i):
        raise RecoverableError("always")
    with pytest.raises(RecoverableError):
        run_with_recovery(step, start_step=0, total_steps=3,
                          cfg=FaultConfig(max_restarts=2, checkpoint_every=1),
                          save_fn=lambda i: None, restore_fn=lambda: 0)


def test_heartbeat_failure_detection():
    clock = {"t": 0.0}
    hb = HeartbeatTracker(FaultConfig(failure_timeout=10.0), n_hosts=3,
                          clock=lambda: clock["t"])
    clock["t"] = 15.0
    hb.beat(0)
    hb.beat(1)
    clock["t"] = 20.0        # host 2 silent since t=0 -> dead (>10s)
    assert hb.dead_hosts() == [2]
    hb.beat(2)
    assert hb.dead_hosts() == []


def test_straggler_detection():
    det = StragglerDetector(FaultConfig(straggler_factor=1.5,
                                        straggler_window=8), n_hosts=4)
    for _ in range(8):
        for h in range(3):
            det.record(h, 1.0)
        det.record(3, 2.0)       # host 3 is 2x the median
    assert det.stragglers() == [3]


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic():
    cfg = DataConfig(global_batch=8, seq_len=16, vocab=100, seed=3)
    s1 = SyntheticTokenSource(cfg, process_index=0, process_count=1)
    s2 = SyntheticTokenSource(cfg, process_index=0, process_count=1)
    for i in (0, 5, 11):
        a, b = s1(i), s2(i)
        np.testing.assert_array_equal(a["tokens"], b["tokens"])
        np.testing.assert_array_equal(a["labels"], b["labels"])


def test_data_per_host_sharding_partitions_batch():
    cfg = DataConfig(global_batch=8, seq_len=16, vocab=100, seed=3)
    shards = [SyntheticTokenSource(cfg, process_index=p, process_count=4)(2)
              for p in range(4)]
    assert all(s["tokens"].shape == (2, 16) for s in shards)
    # different hosts see different data
    assert not np.array_equal(shards[0]["tokens"], shards[1]["tokens"])


def test_data_resume_cursor():
    cfg = DataConfig(global_batch=4, seq_len=8, vocab=64)
    src = SyntheticTokenSource(cfg, process_index=0, process_count=1)
    state = src.checkpoint_state(17)
    assert SyntheticTokenSource.resume_step(state) == 17
    np.testing.assert_array_equal(src(17)["tokens"], src(17)["tokens"])
