"""Integration: Pallas kernels dispatched from the model's inference
paths (cfg.use_kernels) match the jnp reference path; gradient
compression with error feedback preserves training."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.optim import adamw
from repro.optim.compress import (CompressionConfig, compress,
                                  compression_ratio, init_residual)
from repro.sharding import Policy
from repro.train import trainer as T


# ---------------------------------------------------------------------------
# kernel dispatch equivalence (interpret mode on CPU)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("arch", ["llama3.2-1b", "zamba2-2.7b", "xlstm-125m"])
def test_prefill_kernels_match_reference(arch):
    cfg = get_config(arch).reduced()
    cfg_k = dataclasses.replace(cfg, use_kernels=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    B, T = 2, 64
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, T),
                                          0, cfg.vocab)}
    logits_ref, cache_ref = M.prefill(cfg, params, batch, max_len=T + 8)
    logits_k, cache_k = M.prefill(cfg_k, params, batch, max_len=T + 8)
    np.testing.assert_allclose(np.asarray(logits_k), np.asarray(logits_ref),
                               atol=2e-4, rtol=2e-4)
    for a, b in zip(jax.tree.leaves(cache_ref), jax.tree.leaves(cache_k)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=2e-4, rtol=2e-4)


def test_kernel_prefill_then_reference_decode(arch="zamba2-2.7b"):
    """A cache produced by the kernel path must be consumable by decode."""
    cfg = dataclasses.replace(get_config(arch).reduced(), use_kernels=True)
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                          0, cfg.vocab)}
    logits, cache = M.prefill(cfg, params, batch, max_len=24)
    tok = jnp.argmax(logits[:, -1], -1)[:, None].astype(jnp.int32)
    logits2, cache2 = M.decode_step(cfg, params, cache, {"tokens": tok})
    assert logits2.shape == (2, 1, cfg.vocab)
    assert not bool(jnp.isnan(logits2).any())


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------


def _tiny_cfg():
    return dataclasses.replace(
        get_config("llama3.2-1b"), name="tiny", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_head=32, d_ff=512, vocab=512,
        dtype="float32", remat=False, q_chunk=32, kv_chunk=32)


def test_compress_identity_at_full_k():
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (128, 64))}
    grads = {"w": jax.random.normal(jax.random.PRNGKey(1), (128, 64))}
    res = init_residual(params)
    sent, new_res = compress(CompressionConfig(k_frac=1.0), grads, res)
    np.testing.assert_allclose(sent["w"], grads["w"], rtol=1e-6)
    assert float(jnp.abs(new_res["w"]).max()) == 0.0


def test_compress_error_feedback_conserves_mass():
    """sent + residual' == grad + residual (nothing is lost, only delayed)."""
    g = {"w": jax.random.normal(jax.random.PRNGKey(2), (256, 32))}
    e = {"w": jax.random.normal(jax.random.PRNGKey(3), (256, 32)) * 0.1}
    sent, e2 = compress(CompressionConfig(k_frac=0.1), g, e)
    np.testing.assert_allclose(np.asarray(sent["w"] + e2["w"]),
                               np.asarray(g["w"] + e["w"]), atol=1e-6)
    # sparsity: ~10% entries synchronized
    frac = float((sent["w"] != 0).mean())
    assert 0.05 <= frac <= 0.2


def test_compress_small_leaves_pass_through():
    g = {"bias": jnp.ones((16,))}
    sent, res = compress(CompressionConfig(k_frac=0.01, min_size=4096),
                         g, init_residual(g))
    np.testing.assert_allclose(sent["bias"], g["bias"])


def test_compressed_training_converges():
    """Loss decreases under 10% top-k compression with error feedback."""
    from repro.data.pipeline import DataConfig, SyntheticTokenSource
    cfg = _tiny_cfg()
    src = SyntheticTokenSource(
        DataConfig(global_batch=32, seq_len=32, vocab=cfg.vocab),
        process_index=0, process_count=1)
    tc = T.TrainConfig(
        opt=adamw.AdamWConfig(lr=2e-3, warmup_steps=10, total_steps=200),
        compress=CompressionConfig(k_frac=0.1))
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    state = {"opt": adamw.init_state(tc.opt, params),
             "residual": init_residual(params)}
    step = jax.jit(T.make_train_step(cfg, tc, Policy()))
    losses = []
    for i in range(80):
        b = jax.tree.map(jnp.asarray, src(i))
        params, state, met = step(params, state, b)
        losses.append(float(met["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses[::20]


def test_compression_ratio_accounting():
    params = {"big": jnp.zeros((1024, 1024)), "small": jnp.zeros((64,))}
    r = compression_ratio(CompressionConfig(k_frac=0.1), params)
    assert 0.09 < r < 0.11
