"""M-request concurrent scheduling: equivalence, optimality, invariances,
and real M-model lane execution.

* M = 2 through ``solve_concurrent`` must be **bitwise identical** to the
  retained pair solvers (it dispatches to them).
* The M-dimensional grid A* must match an independent brute force over
  all interleavings x PU choices under the group co-execution laws, and
  the M = 2 grid must match the pair optimum.
* The group laws must reduce to the pair laws for M = 2, bit for bit.
* Permuting request order must never change the optimum.
* An M = 3 ``ConcurrentSchedule`` executed across the shared PU lanes
  must produce outputs identical to isolated per-model execution.
"""
import itertools
from functools import lru_cache

import numpy as np
import pytest

from repro.core import (ContentionModel, CostEntry, CostTable,
                        DenseCostTable, EDGE_PUS, FusedOp, OpGraph,
                        ScheduleExecutor, Workload, solve_concurrent,
                        solve_concurrent_joint)

PUS = ("CPU", "GPU", "NPU")


def random_workload(rng, n_ops, drop_frac=0.25):
    table = CostTable(list(PUS))
    ops = []
    for i in range(n_ops):
        ops.append(FusedOp(name=f"o{i}", kind="other", out_shape=(4,)))
        sup = [p for p in PUS if rng.random() > drop_frac]
        if not sup:
            sup = [PUS[int(rng.integers(len(PUS)))]]
        for pu in sup:
            table.set(i, pu, CostEntry(
                kernel=float(rng.uniform(1e-6, 1e-3)),
                dispatch=float(rng.uniform(0, 1e-5)),
                h2d=float(rng.uniform(0, 1e-4)),
                d2h=float(rng.uniform(0, 1e-4)),
                power=float(rng.uniform(5.0, 30.0))))
    return Workload.build(list(range(n_ops)), table, EDGE_PUS, ops=ops)


def objective_key(sched, objective):
    return sched.latency if objective == "latency" else sched.energy


# ---------------------------------------------------------------------------
# group laws
# ---------------------------------------------------------------------------


def test_group_laws_reduce_to_pair_laws():
    cm = ContentionModel()
    rng = np.random.default_rng(0)
    for _ in range(300):
        ta, tb = rng.uniform(1e-6, 1e-3, 2)
        pa, pb = (PUS[int(i)] for i in rng.integers(0, 3, 2))
        pwa, pwb = rng.uniform(5, 30, 2)
        assert (cm.group_step_cost([ta, tb], [pa, pb])
                == cm.pair_step_cost(ta, pa, tb, pb))
        cca, ccb = cm.co_exec(ta, pa, tb, pb)
        want = ta * pwa + tb * pwb if pa == pb else cca * pwa + ccb * pwb
        assert cm.group_energy([ta, tb], [pwa, pwb], [pa, pb]) == want


def test_group_step_cost_single_op_is_solo():
    cm = ContentionModel()
    assert cm.group_step_cost([3e-4], ["NPU"]) == 3e-4
    assert cm.group_energy([3e-4], [9.0], ["NPU"]) == 3e-4 * 9.0


# ---------------------------------------------------------------------------
# M = 2: bitwise equivalence with the retained pair solvers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(8))
@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_m2_bitwise_identical_to_pair_solver(seed, objective):
    rng = np.random.default_rng(1000 + seed)
    wl0 = random_workload(rng, int(rng.integers(2, 12)))
    wl1 = random_workload(rng, int(rng.integers(2, 12)))
    cm = ContentionModel()
    mary = solve_concurrent([wl0, wl1], cm, objective)
    pair = solve_concurrent_joint(wl0.chain, wl0.table, wl1.chain, wl1.table,
                                  EDGE_PUS, cm, objective,
                                  dense0=wl0.dense, dense1=wl1.dense)
    assert mary.latency == pair.latency          # bitwise
    assert mary.energy == pair.energy            # bitwise
    assert ([(s.ops, s.pus, s.cost) for s in mary.steps]
            == [(s.ops, s.pus, s.cost) for s in pair.steps])
    assert mary.mode == pair.mode


@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_m2_grid_matches_pair_optimum(objective):
    """Forcing the M-dim grid on a pair must reach the pair A* optimum
    (tie-broken paths may differ; the objective value must agree)."""
    rng = np.random.default_rng(77)
    wl0 = random_workload(rng, 7)
    wl1 = random_workload(rng, 9)
    cm = ContentionModel()
    grid = solve_concurrent([wl0, wl1], cm, objective, algorithm="grid")
    pair = solve_concurrent([wl0, wl1], cm, objective)
    assert grid.mode == "joint-grid"
    assert objective_key(grid, objective) == pytest.approx(
        objective_key(pair, objective), rel=1e-11)


# ---------------------------------------------------------------------------
# M >= 3: optimality, invariances, fallback
# ---------------------------------------------------------------------------


def brute_force_group(wls, cm, objective):
    """Exhaustive enumeration over advance-subsets x PU choices."""
    m = len(wls)
    ns = [wl.n for wl in wls]
    sups = [[list(np.flatnonzero(wl.dense.mask[i])) for i in range(wl.n)]
            for wl in wls]
    ws = [wl.dense.w for wl in wls]
    pws = [wl.dense.power for wl in wls]
    names = [wl.pu_names for wl in wls]

    @lru_cache(maxsize=None)
    def best(pos):
        if all(pos[r] == ns[r] for r in range(m)):
            return 0.0
        avail = [r for r in range(m) if pos[r] < ns[r]]
        cands = []
        for sz in range(1, len(avail) + 1):
            for reqs in itertools.combinations(avail, sz):
                npos = tuple(p + (1 if r in reqs else 0)
                             for r, p in enumerate(pos))
                rest = best(npos)
                for combo in itertools.product(
                        *[sups[r][pos[r]] for r in reqs]):
                    ts = [float(ws[r][pos[r], j])
                          for r, j in zip(reqs, combo)]
                    ps_ = [float(pws[r][pos[r], j])
                           for r, j in zip(reqs, combo)]
                    pn = [names[r][j] for r, j in zip(reqs, combo)]
                    step = cm.group_step_cost(ts, pn)
                    e = cm.group_energy(ts, ps_, pn)
                    cands.append((step if objective == "latency" else e)
                                 + rest)
        return min(cands)

    return best(tuple([0] * m))


@pytest.mark.parametrize("seed", range(6))
@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_m3_grid_optimal_vs_bruteforce(seed, objective):
    rng = np.random.default_rng(2000 + seed)
    wls = [random_workload(rng, int(rng.integers(1, 4))) for _ in range(3)]
    cm = ContentionModel()
    sched = solve_concurrent(wls, cm, objective, algorithm="grid")
    bf = brute_force_group(wls, cm, objective)
    assert objective_key(sched, objective) == pytest.approx(bf, rel=1e-11)


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_permuting_requests_preserves_optimum(seed, objective):
    """The joint optimum is symmetric in the requests: permuting the
    workload order never changes the objective value, and each request
    keeps an equally-optimal schedule."""
    rng = np.random.default_rng(3000 + seed)
    wls = [random_workload(rng, int(rng.integers(2, 5))) for _ in range(3)]
    cm = ContentionModel()
    base = solve_concurrent(wls, cm, objective, algorithm="grid")
    for perm in itertools.permutations(range(3)):
        got = solve_concurrent([wls[r] for r in perm], cm, objective,
                               algorithm="grid")
        assert objective_key(got, objective) == pytest.approx(
            objective_key(base, objective), rel=1e-11)


def test_schedule_covers_every_op_once():
    rng = np.random.default_rng(9)
    wls = [random_workload(rng, n) for n in (3, 5, 2)]
    sched = solve_concurrent(wls, ContentionModel())
    assert sched.n_requests == 3
    for r, wl in enumerate(wls):
        assert [o for o, _ in sched.assignment_of(r)] == wl.chain


@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_pairwise_fallback_upper_bounds_grid(objective):
    rng = np.random.default_rng(42)
    wls = [random_workload(rng, int(rng.integers(3, 6))) for _ in range(4)]
    cm = ContentionModel()
    grid = solve_concurrent(wls, cm, objective, algorithm="grid",
                            max_states=10**6)
    pw = solve_concurrent(wls, cm, objective, algorithm="pairwise")
    assert pw.mode == "pairwise"
    assert objective_key(grid, objective) <= (
        objective_key(pw, objective) * (1 + 1e-9))
    # the fallback is a real schedule: every op covered exactly once
    for r, wl in enumerate(wls):
        assert [o for o, _ in pw.assignment_of(r)] == wl.chain


def test_auto_routes_large_grids_to_rolling():
    """Grids beyond max_states now roll a bounded-window exact sweep
    instead of serializing pairs; the exact grid still lower-bounds it."""
    rng = np.random.default_rng(8)
    wls = [random_workload(rng, 9) for _ in range(3)]
    sched = solve_concurrent(wls, ContentionModel(), max_states=100)
    assert sched.mode == "rolling"
    for r, wl in enumerate(wls):        # a real schedule: every op covered
        assert [o for o, _ in sched.assignment_of(r)] == wl.chain
    sched2 = solve_concurrent(wls, ContentionModel(), max_states=10**6)
    assert sched2.mode == "joint-grid"
    assert sched2.latency <= sched.latency * (1 + 1e-9)


def test_custom_contention_routes_to_pairwise_and_honours_laws():
    class Harsh(ContentionModel):
        def co_exec(self, t_a, pu_a, t_b, pu_b):
            return 10.0 * t_a, 10.0 * t_b

        def pair_step_cost(self, t_a, pu_a, t_b, pu_b):
            return 10.0 * max(t_a, t_b)

    rng = np.random.default_rng(4)
    wls = [random_workload(rng, 4, drop_frac=0.0) for _ in range(3)]
    harsh = Harsh()
    sched = solve_concurrent(wls, harsh)
    assert sched.mode == "pairwise"   # grid would misprice custom laws
    with pytest.raises(ValueError, match="group co-execution"):
        solve_concurrent(wls, harsh, algorithm="grid")


def test_grid_raises_beyond_max_states():
    rng = np.random.default_rng(6)
    wls = [random_workload(rng, 10) for _ in range(3)]
    with pytest.raises(ValueError, match="max_states"):
        solve_concurrent(wls, ContentionModel(), algorithm="grid",
                         max_states=50)


def test_m1_solo_walk():
    rng = np.random.default_rng(13)
    wl = random_workload(rng, 6)
    sched = solve_concurrent([wl])
    assert sched.n_requests == 1
    assert [o for o, _ in sched.assignment_of(0)] == wl.chain
    best = float(np.sum(np.min(np.where(wl.dense.mask, wl.dense.w, np.inf),
                               axis=1)))
    assert sched.latency == pytest.approx(best, rel=1e-12)


def test_unsupported_op_raises_with_context():
    """An all-PU-masked op in an M=3 workload must raise
    ``InfeasibleScheduleError`` naming the request index, the op, and
    its chain position — on every concurrent route — instead of the old
    bare 'joint search failed to reach target state'."""
    from repro.core import InfeasibleScheduleError

    table = CostTable(list(PUS))
    ops = [FusedOp(name="a", kind="other", out_shape=(4,)),
           FusedOp(name="b", kind="other", out_shape=(4,))]
    table.set(0, "CPU", CostEntry(1e-4, 1e-6, 0.0, 0.0, 10.0))
    wl_bad = Workload(chain=[0, 1],
                      dense=DenseCostTable.from_chain([0, 1], table,
                                                      EDGE_PUS),
                      pus=EDGE_PUS, ops=ops, table=table)
    rng = np.random.default_rng(1)
    wl_ok = random_workload(rng, 3, drop_frac=0.0)
    for algo in ("grid", "grid_astar", "rolling", "pairwise"):
        with pytest.raises(InfeasibleScheduleError,
                           match=r"request 1: op 1 \(b\) at chain position 1"):
            solve_concurrent([wl_ok, wl_bad, wl_ok], algorithm=algo)


# ---------------------------------------------------------------------------
# M = 3 real execution across the shared PU lanes
# ---------------------------------------------------------------------------


def _payload_model(rng, tag, n, kind):
    ops = []
    for i in range(n):
        if kind == "matmul":
            w = rng.standard_normal((24, 24)) / 5.0
            ops.append(FusedOp(
                name=f"{tag}{i}", kind="matmul",
                in_shapes=((4, 24), (24, 24)), out_shape=(4, 24),
                fn=(lambda wi: lambda x: np.tanh(x @ wi))(w)))
        else:
            ops.append(FusedOp(
                name=f"{tag}{i}", kind="cumsum",
                in_shapes=((4, 24),), out_shape=(4, 24),
                fn=lambda x: np.cumsum(x, axis=1) / x.shape[1]))
    return OpGraph(ops)


def test_m3_executor_matches_isolated():
    """An M=3 concurrent schedule really executed across the shared PU
    lanes yields bitwise-identical outputs to isolated execution."""
    from repro.core import EdgeSoCCostModel
    rng = np.random.default_rng(0)
    graphs = [_payload_model(rng, "a", 5, "matmul"),
              _payload_model(rng, "b", 7, "cumsum"),
              _payload_model(rng, "c", 4, "matmul")]
    inputs = [{0: (rng.standard_normal((4, 24)),)} for _ in graphs]
    model = EdgeSoCCostModel()
    wls = [Workload.build(list(range(len(g))), model.build_table(g),
                          EDGE_PUS, ops=g.ops) for g in graphs]
    sched = solve_concurrent(wls, ContentionModel())
    assert sched.mode == "joint-grid"
    ex = ScheduleExecutor(list(EDGE_PUS))
    conc = ex.run_concurrent(graphs, sched, inputs)
    for g, x, got in zip(graphs, inputs, conc):
        mono = ex.run_monolithic(g, x)
        assert ScheduleExecutor.outputs_close(mono, got)  # bitwise


def test_run_concurrent_rejects_mismatched_schedule():
    rng = np.random.default_rng(2)
    graphs = [_payload_model(rng, "a", 3, "matmul"),
              _payload_model(rng, "b", 3, "cumsum")]
    from repro.core import EdgeSoCCostModel
    model = EdgeSoCCostModel()
    wls = [Workload.build(list(range(len(g))), model.build_table(g),
                          EDGE_PUS, ops=g.ops) for g in graphs]
    sched = solve_concurrent(wls, ContentionModel())
    ex = ScheduleExecutor(list(EDGE_PUS))
    with pytest.raises(ValueError, match="requests"):
        ex.run_concurrent(graphs[:1], sched)


def test_custom_contention_rejects_derived_views():
    """Derived dense views carry no oracle table; custom-law solves must
    reject them loudly instead of silently pricing nominal costs."""
    class Harsh(ContentionModel):
        def co_exec(self, t_a, pu_a, t_b, pu_b):
            return 10.0 * t_a, 10.0 * t_b

    rng = np.random.default_rng(21)
    wl = random_workload(rng, 4, drop_frac=0.0)
    adj = wl.under_condition({"GPU": 1000.0}, ())
    with pytest.raises(ValueError, match="oracle CostTable"):
        solve_concurrent([adj, wl], Harsh())
    with pytest.raises(ValueError, match="oracle CostTable"):
        solve_concurrent([adj, wl, wl], Harsh())


@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_shared_caches_match_fresh_solves(objective):
    """A ConcurrentCaches pool threaded through both objectives must
    reproduce fresh solves bitwise on both routes."""
    from repro.core import ConcurrentCaches

    cm = ContentionModel()
    rng = np.random.default_rng(33)
    wls = [random_workload(rng, int(rng.integers(2, 5))) for _ in range(3)]
    for algo in ("grid", "grid_astar", "rolling", "pairwise"):
        caches = ConcurrentCaches()
        first = solve_concurrent(wls, cm, "latency", algorithm=algo)
        warm = solve_concurrent(wls, cm, "latency", algorithm=algo,
                                caches=caches)
        reused = solve_concurrent(wls, cm, objective, algorithm=algo,
                                  caches=caches)
        fresh = solve_concurrent(wls, cm, objective, algorithm=algo)
        assert (warm.latency, warm.energy) == (first.latency, first.energy)
        assert (reused.latency, reused.energy) == (fresh.latency,
                                                   fresh.energy)
        assert ([(s.ops, s.pus, s.cost) for s in reused.steps]
                == [(s.ops, s.pus, s.cost) for s in fresh.steps])


def test_run_concurrent_rejects_misordered_schedule():
    """A coverage-complete but dependency-misordered schedule must raise,
    not deadlock the lane workers."""
    from repro.core import ConcurrentSchedule, ConcurrentStep, EdgeSoCCostModel
    rng = np.random.default_rng(3)
    g = _payload_model(rng, "a", 2, "matmul")
    model = EdgeSoCCostModel()
    wl = Workload.build([0, 1], model.build_table(g), EDGE_PUS, ops=g.ops)
    good = solve_concurrent([wl], ContentionModel())
    bad = ConcurrentSchedule(steps=list(reversed(good.steps)),
                             latency=good.latency, energy=good.energy,
                             objective=good.objective, mode=good.mode)
    ex = ScheduleExecutor(list(EDGE_PUS))
    with pytest.raises(ValueError, match="before its predecessor"):
        ex.run_concurrent([g], bad)


def test_solve_sequential_oracle_algorithms_need_a_table():
    from repro.core import solve_sequential
    rng = np.random.default_rng(15)
    wl = random_workload(rng, 4, drop_frac=0.0)
    derived = wl.under_condition({"CPU": 2.0}, ())
    for algo in ("dijkstra", "dp_reference"):
        with pytest.raises(ValueError, match="oracle table"):
            solve_sequential(derived.chain, None, None, EDGE_PUS,
                             algorithm=algo, workload=derived)
    # the dense DP needs no oracle
    s = solve_sequential(derived.chain, None, None, EDGE_PUS,
                         algorithm="dp", workload=derived)
    assert len(s.assignment) == 4
