"""The ``Orchestrator`` front door must be a zero-cost veneer: plans
bitwise-identical to the direct solver calls, cache hits bitwise-identical
to cold solves (including after condition-driven invalidation), lossless
JSON round-trips for every schedule kind, and descriptive front-door
errors instead of deep KeyError/IndexError."""
import time

import numpy as np
import pytest

from repro.core import (ContentionModel, CostEntry, CostTable, EDGE_PUS,
                        EdgeSoCCostModel, FusedOp, OpGraph, Orchestrator,
                        Plan, RuntimeCondition, ScheduleExecutor, Workload,
                        solve_concurrent, solve_concurrent_aligned,
                        solve_parallel, solve_sequential)
from repro.core.costmodel import make_cumsum, make_matmul
from repro.core.dynamic import DynamicScheduler


def _chain_graph(n=10, seed=0):
    ops = [make_matmul(256, name=f"mm{i}") if (i + seed) % 2 == 0
           else make_cumsum(2048, 64) for i in range(n)]
    return OpGraph(ops)


def _branch_graph():
    ops = [make_matmul(256, name="proj"), make_matmul(256, name="gemm"),
           make_cumsum(2048, 64), FusedOp(name="join", kind="add",
                                          in_shapes=((1, 64, 2048),),
                                          out_shape=(1, 64, 2048))]
    return OpGraph(ops, edges=[(0, 1), (0, 2), (1, 3), (2, 3)])


@pytest.fixture(scope="module")
def model():
    return EdgeSoCCostModel()


# ---------------------------------------------------------------------------
# bitwise equivalence with the direct solver calls
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_plan_sequential_equals_direct_solve(model, objective):
    g = _chain_graph()
    orch = Orchestrator(model)
    h = orch.register(g)
    plan = orch.plan(h, objective=objective)
    table = model.build_table(g)
    direct = solve_sequential(g.topo_order(), g.ops, table, EDGE_PUS,
                              objective)
    assert plan.kind == "sequential"
    assert plan.schedule == direct          # dataclass ==: bitwise floats


@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_plan_parallel_equals_direct_solve(model, objective):
    g = _branch_graph()
    orch = Orchestrator(model)
    h = orch.register(g)
    plan = orch.plan(h, objective=objective)   # auto-detected from Branch
    table = model.build_table(g)
    direct = solve_parallel(g, table, EDGE_PUS, orch.contention, objective)
    assert plan.kind == "parallel"
    assert plan.schedule == direct


@pytest.mark.parametrize("objective", ["latency", "energy"])
@pytest.mark.parametrize("m", [2, 3])
def test_plan_concurrent_equals_direct_solve(model, objective, m):
    graphs = [_chain_graph(8, seed=r) for r in range(m)]
    orch = Orchestrator(model)
    hs = [orch.register(g) for g in graphs]
    plan = orch.plan(hs, objective=objective)
    wls = [Workload.build(g.topo_order(), model.build_table(g), EDGE_PUS,
                          ops=g.ops) for g in graphs]
    direct = solve_concurrent(wls, orch.contention, objective)
    assert plan.kind == "concurrent"
    assert plan.schedule == direct


def test_plan_aligned_equals_direct_solve(model):
    g = _chain_graph()
    orch = Orchestrator(model)
    h = orch.register(g)
    plan = orch.plan((h, h), mode="aligned")
    table = model.build_table(g)
    chain = g.topo_order()
    direct = solve_concurrent_aligned(chain, table, chain, table, EDGE_PUS,
                                      orch.contention)
    assert plan.schedule == direct
    assert plan.schedule.mode == "aligned"


@pytest.mark.parametrize("algorithm", ["grid", "grid_astar", "rolling",
                                       "pairwise"])
def test_plan_algorithm_knob_equals_direct_solve(model, algorithm):
    """The front-door algorithm/max_states knobs must reach
    solve_concurrent verbatim — plans bitwise-identical to direct calls."""
    graphs = [_chain_graph(6, seed=r) for r in range(3)]
    orch = Orchestrator(model)
    hs = [orch.register(g) for g in graphs]
    plan = orch.plan(hs, algorithm=algorithm, max_states=10**6)
    wls = [Workload.build(g.topo_order(), model.build_table(g), EDGE_PUS,
                          ops=g.ops) for g in graphs]
    direct = solve_concurrent(wls, orch.contention, algorithm=algorithm,
                              max_states=10**6)
    assert plan.schedule == direct
    assert plan.schedule.mode == direct.mode


def test_plan_caches_grid_and_pairwise_separately(model):
    """A forced-pairwise plan must never be served a cached grid plan
    (and vice versa): algorithm/max_states are part of the cache key."""
    graphs = [_chain_graph(6, seed=r) for r in range(3)]
    orch = Orchestrator(model)
    hs = [orch.register(g) for g in graphs]
    grid = orch.plan(hs, algorithm="grid")
    pw = orch.plan(hs, algorithm="pairwise")
    assert orch.stats["misses"] == 2 and orch.stats["hits"] == 0
    assert grid.schedule.mode == "joint-grid"
    assert pw.schedule.mode == "pairwise"
    # repeats of either are cache hits serving the matching schedule
    assert orch.plan(hs, algorithm="grid").schedule is grid.schedule
    assert orch.plan(hs, algorithm="pairwise").schedule is pw.schedule
    assert orch.stats["hits"] == 2
    # a different max_states is a different plan too (routing boundary)
    small = orch.plan(hs, max_states=10)
    assert small.schedule.mode == "rolling"
    assert orch.stats["misses"] == 3
    # default-knob plans are yet another entry, served independently
    auto = orch.plan(hs)
    assert auto.schedule.mode == "joint-grid"
    assert orch.stats["misses"] == 4


def test_plan_rejects_concurrent_knobs_on_other_modes(model):
    g = _chain_graph()
    orch = Orchestrator(model)
    h = orch.register(g)
    with pytest.raises(ValueError, match="concurrent"):
        orch.plan(h, algorithm="grid")               # sequential route
    with pytest.raises(ValueError, match="concurrent"):
        orch.plan(h, max_states=100)
    with pytest.raises(ValueError, match="concurrent"):
        orch.plan((h, h), mode="aligned", algorithm="pairwise")
    with pytest.raises(ValueError, match="unknown algorithm"):
        orch.plan((h, h), algorithm="quantum")
    with pytest.raises(ValueError, match="max_states"):
        orch.plan((h, h), max_states=0)
    # a single-request "concurrent" plan is a solo walk: the knobs have
    # nothing to route and must be rejected, not silently ignored
    with pytest.raises(ValueError, match="solo"):
        orch.plan(h, mode="concurrent", algorithm="grid_astar")
    with pytest.raises(ValueError, match="solo"):
        orch.plan(h, mode="concurrent", max_states=50)
    # ... and the M=2 pair fast path is not state-bounded: an explicit
    # max_states surfaces the solver's descriptive rejection
    with pytest.raises(ValueError, match="pair A\\*"):
        orch.plan((h, orch.register(_chain_graph(6, seed=1))),
                  max_states=10**6)


# ---------------------------------------------------------------------------
# plan caching
# ---------------------------------------------------------------------------


def test_cache_hit_is_bitwise_equal_and_counted(model):
    g = _chain_graph()
    orch = Orchestrator(model)
    h = orch.register(g)
    cold = orch.plan(h)
    assert orch.stats["hits"] == 0 and orch.stats["misses"] == 1
    assert all(orch.stats[k] == 0 for k in orch.stats
               if k not in ("misses",))
    hit = orch.plan(h)
    assert hit is cold                       # served from cache
    assert orch.stats["hits"] == 1
    # a fresh session's cold solve is bitwise-equal to the cached plan
    orch2 = Orchestrator(model)
    cold2 = orch2.plan(orch2.register(g))
    assert cold2.to_json() == hit.to_json()


def test_cache_key_distinguishes_objective_and_mode(model):
    g = _chain_graph()
    orch = Orchestrator(model)
    h = orch.register(g)
    p_lat = orch.plan(h)
    p_eng = orch.plan(h, objective="energy")
    assert p_lat is not p_eng
    assert orch.stats["misses"] == 2
    # same handle pair, aligned vs joint: separate entries
    a = orch.plan((h, h), mode="aligned")
    j = orch.plan((h, h))
    assert a is not j and orch.stats["misses"] == 4


def test_shared_signature_shares_cache_across_handles(model):
    g = _chain_graph()
    orch = Orchestrator(model)
    h1 = orch.register(g)
    # a distinct graph object with identical ops profiles identically
    g2 = OpGraph(list(g.ops))
    h2 = orch.register(g2)
    assert h1 != h2
    p1 = orch.plan(h1)
    p2 = orch.plan(h2)
    # the schedule is shared (keyed by workload signature)...
    assert p2.schedule is p1.schedule
    assert orch.stats["hits"] == 1
    # ...but the handles are re-bound to the caller's, so execute()
    # resolves the right graph
    assert p1.handles == (h1,) and p2.handles == (h2,)


def test_cache_hit_rebinds_handles_so_execute_runs_right_graph(model):
    # two graphs with identical profiled costs (same shapes/kinds) but
    # different payload weights: a cached plan served for the second
    # handle must still execute the SECOND graph's functions
    g1, inputs = _payload_chain(4, seed=0)
    g2 = OpGraph([FusedOp(name=op.name, kind=op.kind,
                          in_shapes=op.in_shapes, out_shape=op.out_shape,
                          fn=(lambda f: lambda a: -f(-a))(op.fn))
                  for op in g1.ops])
    orch = Orchestrator(model)
    h1, h2 = orch.register(g1), orch.register(g2)
    orch.plan(h1)
    p2 = orch.plan(h2)
    assert orch.stats["hits"] == 1 and p2.handles == (h2,)
    got = orch.execute(p2, inputs)
    mono = orch.executor.run_monolithic(g2, inputs)
    assert ScheduleExecutor.outputs_close(mono, got)


def test_parallel_plans_not_shared_across_graph_structures(model):
    # a diamond DAG and a pure chain over the SAME ops have equal
    # workload signatures (chain + dense costs), but different phase
    # structure — the parallel-mode cache must not share their plans
    ops = [make_matmul(256, name="a"), make_matmul(256, name="b"),
           make_cumsum(2048, 64), make_matmul(256, name="d")]
    diamond = OpGraph(list(ops), edges=[(0, 2), (0, 1), (1, 3), (2, 3)])
    chain = OpGraph(list(ops))
    assert diamond.topo_order() == chain.topo_order()  # aliasing precondition
    orch = Orchestrator(model)
    hd, hc = orch.register(diamond), orch.register(chain)
    assert orch.workload(hd).signature() == orch.workload(hc).signature()
    pd = orch.plan(hd, mode="parallel")
    pc = orch.plan(hc, mode="parallel")
    assert orch.stats["hits"] == 0           # no structural aliasing
    table = model.build_table(chain)
    assert pc.schedule == solve_parallel(chain, table, EDGE_PUS,
                                         orch.contention)
    assert pd.schedule == solve_parallel(diamond, table, EDGE_PUS,
                                         orch.contention)


def test_condition_invalidates_per_pu_and_resolve_is_bitwise(model):
    g = _chain_graph()
    orch = Orchestrator(model)
    h = orch.register(g)
    nominal = orch.plan(h)
    orch.on_condition(RuntimeCondition(slowdown={"GPU": 4.0}))
    assert orch.stats["invalidated"] == 1    # nominal plan priced GPU@1.0
    throttled = orch.plan(h)
    # the throttled chain re-routes off the GPU somewhere
    assert throttled.schedule.assignment != nominal.schedule.assignment
    # throttled solve equals a direct solve on the adjusted workload
    table = model.build_table(g)
    wl = Workload.build(g.topo_order(), table, EDGE_PUS, ops=g.ops)
    direct = solve_sequential(g.topo_order(), g.ops, None, EDGE_PUS,
                              workload=wl.under_condition({"GPU": 4.0}))
    assert throttled.schedule == direct
    # back to nominal: the throttled entry is invalidated, and the cold
    # re-solve reproduces the original plan bitwise
    orch.on_condition(RuntimeCondition())
    renominal = orch.plan(h)
    assert renominal.to_json() == nominal.to_json()


def test_condition_unavailable_pu_reroutes(model):
    g = _chain_graph()
    orch = Orchestrator(model)
    h = orch.register(g)
    orch.on_condition(RuntimeCondition(unavailable=frozenset({"GPU"})))
    plan = orch.plan(h)
    assert "GPU" not in set(plan.schedule.assignment)


# ---------------------------------------------------------------------------
# Plan JSON round-trips (all three schedule kinds)
# ---------------------------------------------------------------------------


def test_plan_json_roundtrip_all_kinds(model):
    orch = Orchestrator(model)
    hc = orch.register(_chain_graph())
    hb = orch.register(_branch_graph())
    h2 = orch.register(_chain_graph(8, seed=1))
    h3 = orch.register(_chain_graph(6, seed=2))
    plans = [orch.plan(hc), orch.plan(hb), orch.plan((hc, h2)),
             orch.plan((hc, h2, h3)), orch.plan((hc, hc), mode="aligned"),
             orch.plan(hb, objective="energy")]
    for plan in plans:
        restored = Plan.from_json(plan.to_json())
        assert restored.schedule == plan.schedule
        assert (restored.kind, restored.objective, restored.handles,
                restored.mode) == (plan.kind, plan.objective, plan.handles,
                                   plan.mode)
        # and the round-trip is a fixed point
        assert restored.to_json() == plan.to_json()
        assert restored.route == plan.route


# ---------------------------------------------------------------------------
# descriptive front-door errors
# ---------------------------------------------------------------------------


def test_register_memoizes_provider_profiled_only(model):
    g = _chain_graph()
    orch = Orchestrator(model)
    h0 = orch.register(g)
    assert orch.register(g) == h0            # provider-profiled: memoized
    t = model.build_table(g)
    h1 = orch.register(g, table=t)           # explicit table: fresh handle
    assert h1 != h0
    assert orch.register(g) == h0            # memo not shadowed by h1
    ops = list(_chain_graph(6, seed=3).ops)  # bare op sequences memoize too
    hs = orch.register(ops)
    assert orch.register(ops) == hs


def test_register_sequence_id_reuse_cannot_alias(model):
    # temporaries freed after register() must not let a recycled id()
    # hit the memo: the orchestrator pins every registered source object
    orch = Orchestrator(model)
    h1 = orch.register([make_matmul(64, name="m1"), make_matmul(64, name="m2")])
    h2 = orch.register([make_cumsum(512, 8), make_cumsum(512, 8)])
    assert h1 != h2
    assert orch.workload(h1).signature() != orch.workload(h2).signature()


def test_on_condition_rejects_unknown_pu(model):
    orch = Orchestrator(model)
    orch.register(_chain_graph())
    with pytest.raises(ValueError, match=r"unknown PU name\(s\) \['gpu'\]"):
        orch.on_condition(RuntimeCondition(slowdown={"gpu": 4.0}))
    with pytest.raises(ValueError, match="unknown PU"):
        orch.on_condition(RuntimeCondition(unavailable=frozenset({"TPU"})))


def test_parallel_route_respects_execution_order(model):
    # op indices deliberately NOT a topological order: 2 is the root,
    # 0 is the join — route must follow phases, not index order
    ops = [FusedOp(name="join", kind="add", in_shapes=((1, 64, 2048),),
                   out_shape=(1, 64, 2048)),
           make_matmul(256, name="b1"), make_matmul(256, name="root"),
           make_cumsum(2048, 64)]
    g = OpGraph(ops, edges=[(2, 1), (2, 3), (1, 0), (3, 0)])
    orch = Orchestrator(model)
    plan = orch.plan(orch.register(g))
    assert plan.kind == "parallel"
    order = [op for op, _ in plan.route[0]]
    assert sorted(order) == [0, 1, 2, 3]
    seen = set()
    for oi in order:
        assert all(p in seen for p in g.pred[oi]), \
            f"op {oi} routed before its predecessor(s)"
        seen.add(oi)


def test_register_empty_graph_raises():
    orch = Orchestrator(EdgeSoCCostModel())
    with pytest.raises(ValueError, match="no ops"):
        orch.register(OpGraph([]))


def test_workload_build_empty_chain_raises():
    table = CostTable(["CPU"])
    with pytest.raises(ValueError, match="empty op chain"):
        Workload.build([], table, EDGE_PUS)


def test_workload_build_missing_op_raises():
    ops = [make_matmul(64, name="a"), make_matmul(64, name="b")]
    table = CostTable(["CPU", "GPU", "NPU"])
    table.set(0, "CPU", CostEntry(1e-4, 0, 0, 0, 10.0))
    with pytest.raises(ValueError, match=r"op 1 \(b\).*profiled"):
        Workload.build([0, 1], table, EDGE_PUS, ops=ops)


def test_workload_build_unknown_pu_raises():
    table = CostTable(["CPU", "TPU"])
    table.set(0, "CPU", CostEntry(1e-4, 0, 0, 0, 10.0))
    table.set(0, "TPU", CostEntry(1e-4, 0, 0, 0, 10.0))
    with pytest.raises(ValueError, match=r"unknown PU name\(s\) \['TPU'\]"):
        Workload.build([0], table, EDGE_PUS)


def test_plan_bad_handle_and_mode(model):
    orch = Orchestrator(model)
    h = orch.register(_chain_graph())
    with pytest.raises(KeyError, match="unknown handle 99"):
        orch.plan(99)
    with pytest.raises(ValueError, match="unknown mode"):
        orch.plan(h, mode="quantum")
    with pytest.raises(ValueError, match="aligned"):
        orch.plan(h, mode="aligned")
    with pytest.raises(ValueError, match="one handle"):
        orch.plan((h, h), mode="sequential")
    with pytest.raises(TypeError, match="cost must be"):
        Orchestrator(object())


# ---------------------------------------------------------------------------
# online admission (requests arriving mid-flight)
# ---------------------------------------------------------------------------


def test_admit_advance_retire(model):
    ga, gb = _chain_graph(10), _chain_graph(8, seed=1)
    orch = Orchestrator(model)
    ha, hb = orch.register(ga), orch.register(gb)
    p1 = orch.admit(ha)
    assert p1.kind == "concurrent" and p1.handles == (ha,)
    assert len(p1.route[0]) == 10
    # request A progresses 4 ops, then B arrives: the re-plan covers A's
    # remaining 6 ops and all of B
    assert orch.advance(ha, 4) == 4
    with pytest.raises(ValueError, match="n_ops must be >= 0"):
        orch.advance(ha, -1)
    p2 = orch.admit(hb)
    assert p2.handles == (ha, hb)
    assert len(p2.route[0]) == 6 and len(p2.route[1]) == 8
    assert [op for op, _ in p2.route[0]] == ga.topo_order()[4:]
    # the tail re-plan equals a direct solve on the tail workloads
    wa = Workload.build(ga.topo_order(), model.build_table(ga), EDGE_PUS,
                        ops=ga.ops)
    wb = Workload.build(gb.topo_order(), model.build_table(gb), EDGE_PUS,
                        ops=gb.ops)
    direct = solve_concurrent([wa.tail(4), wb], orch.contention)
    assert p2.schedule == direct
    # A retires: only B remains
    p3 = orch.retire(ha)
    assert p3.handles == (hb,)
    assert orch.retire(hb) is None
    with pytest.raises(KeyError, match="not in the active set"):
        orch.retire(hb)
    with pytest.raises(KeyError, match="not in the active set"):
        orch.advance(ha)


def test_admit_fully_complete_request_drops_out(model):
    ga, gb = _chain_graph(6), _chain_graph(6, seed=1)
    orch = Orchestrator(model)
    ha, hb = orch.register(ga), orch.register(gb)
    orch.admit(ha)
    plan = orch.admit(hb)
    assert plan.handles == (ha, hb)
    orch.advance(ha, 6)       # A finished executing
    plan = orch.admit(hb)     # idempotent admit, replans
    assert plan.handles == (hb,)
    orch.advance(hb, 6)       # B finished too: nothing left to schedule
    assert orch.admit(hb) is None
    assert orch.retire(ha) is None      # B still active but fully advanced


def test_on_condition_restitches_active_chain(model):
    g = _chain_graph(12)
    orch = Orchestrator(model)
    h = orch.register(g)
    orch.admit(h)
    orch.advance(h, 6)
    out = orch.on_condition(RuntimeCondition(slowdown={"GPU": 4.0}))
    assert set(out) == {(h, "latency")}
    stitched = out[(h, "latency")]
    # the stitched plan matches a standalone DynamicScheduler fed the
    # same condition at the same progress point
    dyn = DynamicScheduler(g.topo_order(), g.ops, model.build_table(g),
                           EDGE_PUS)
    dyn.on_condition(6, RuntimeCondition(slowdown={"GPU": 4.0}))
    assert stitched.schedule == dyn.plan
    assert np.isfinite(stitched.latency) and np.isfinite(stitched.energy)


def test_on_condition_returns_every_objective_tracker(model):
    g = _chain_graph(12)
    orch = Orchestrator(model)
    h = orch.register(g)
    orch.admit(h)
    orch.dynamic(h)               # latency tracker
    orch.dynamic(h, "energy")     # and an energy tracker alongside it
    out = orch.on_condition(RuntimeCondition(slowdown={"GPU": 3.0}))
    assert set(out) == {(h, "latency"), (h, "energy")}
    assert out[(h, "latency")].objective == "latency"
    assert out[(h, "energy")].objective == "energy"


# ---------------------------------------------------------------------------
# execute: plans drive the multi-lane executor
# ---------------------------------------------------------------------------


def _payload_chain(n=5, seed=0):
    rng = np.random.default_rng(seed)
    ws = [rng.standard_normal((32, 32)) / 6.0 for _ in range(n)]
    ops = [FusedOp(name=f"mm{i}", kind="matmul",
                   in_shapes=((1, 32, 32), (32, 32)), out_shape=(1, 32, 32),
                   fn=(lambda w: lambda a: np.maximum(a @ w, 0.0))(ws[i]))
           for i in range(n)]
    return OpGraph(ops), {0: (rng.standard_normal((1, 32, 32)),)}


def test_execute_sequential_matches_monolithic(model):
    g, inputs = _payload_chain()
    orch = Orchestrator(model)
    h = orch.register(g)
    plan = orch.plan(h)
    got = orch.execute(plan, inputs)
    mono = orch.executor.run_monolithic(g, inputs)
    assert ScheduleExecutor.outputs_close(mono, got)


def test_execute_concurrent_matches_isolated(model):
    g0, in0 = _payload_chain(5, seed=0)
    g1, in1 = _payload_chain(4, seed=1)
    orch = Orchestrator(model)
    h0, h1 = orch.register(g0), orch.register(g1)
    plan = orch.plan((h0, h1))
    results = orch.execute(plan, [in0, in1])
    for g, x, got in zip((g0, g1), (in0, in1), results):
        mono = orch.executor.run_monolithic(g, x)
        assert ScheduleExecutor.outputs_close(mono, got)


def test_execute_partial_plan_raises(model):
    g, _ = _payload_chain()
    orch = Orchestrator(model)
    h = orch.register(g)
    orch.admit(h)
    orch.advance(h, 2)
    partial = orch.admit(h)
    with pytest.raises(ValueError,
                       match="does not cover|before its predecessor"):
        orch.execute(partial, [{0: ()}])


# ---------------------------------------------------------------------------
# the plan-cache win on the bench_sched fig8 zoo pair
# ---------------------------------------------------------------------------


def test_cache_hit_10x_faster_on_fig8_zoo_pair(model):
    from repro.core.paperzoo import zoo
    z = zoo()
    ga, gb = z["ViT-B/16 FP16"], z["ResNet-50 FP16"]
    orch = Orchestrator(model)
    ha, hb = orch.register(ga), orch.register(gb)
    t0 = time.perf_counter()
    cold = orch.plan((ha, hb))
    cold_s = time.perf_counter() - t0
    hit_s = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        hit = orch.plan((ha, hb))
        hit_s = min(hit_s, time.perf_counter() - t0)
    assert hit is cold
    assert cold_s >= 10 * hit_s, (cold_s, hit_s)
