"""Pallas kernel sweeps: interpret-mode kernel == ref.py oracle.

Shapes/dtypes sweep per kernel + hypothesis property tests on the
invariants (GQA group equivalence, scan associativity via chunk-size
independence, MoE capacity monotonicity).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.kernels import ops, ref

jax.config.update("jax_enable_x64", False)


def rand(key, shape, dtype):
    x = jax.random.normal(key, shape, jnp.float32)
    return x.astype(dtype)


TOL = {jnp.float32: dict(atol=3e-5, rtol=3e-5),
       jnp.bfloat16: dict(atol=3e-2, rtol=3e-2)}


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,Tq,Tk,Hq,Hk,D,causal,off,bq,bk",
    [
        (2, 256, 256, 4, 2, 64, True, 0, 128, 128),
        (1, 128, 384, 8, 8, 64, True, 0, 64, 128),
        (2, 200, 200, 4, 1, 32, True, 0, 64, 64),     # padded seqs
        (1, 64, 512, 4, 2, 128, False, 0, 64, 128),
        (1, 1, 300, 4, 2, 64, True, 299, 64, 64),     # decode-style
        (1, 96, 96, 2, 2, 16, True, 0, 32, 32),
    ])
def test_flash_attention_sweep(B, Tq, Tk, Hq, Hk, D, causal, off, bq, bk,
                               dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = rand(ks[0], (B, Tq, Hq, D), dtype)
    k = rand(ks[1], (B, Tk, Hk, D), dtype)
    v = rand(ks[2], (B, Tk, Hk, D), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, q_offset=off,
                              block_q=bq, block_k=bk, interpret=True)
    expected = ref.attention_ref(q, k, v, causal=causal, q_offset=off)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32), **TOL[dtype])


def test_flash_attention_block_size_independent():
    """The online softmax must not depend on the tiling."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = rand(ks[0], (1, 160, 4, 32), jnp.float32)
    k = rand(ks[1], (1, 160, 2, 32), jnp.float32)
    v = rand(ks[2], (1, 160, 2, 32), jnp.float32)
    outs = [ops.flash_attention(q, k, v, block_q=bq, block_k=bk,
                                interpret=True)
            for bq, bk in [(32, 32), (64, 32), (32, 64), (160, 160)]]
    for o in outs[1:]:
        np.testing.assert_allclose(outs[0], o, atol=2e-6, rtol=2e-6)


@settings(max_examples=20, deadline=None)
@given(
    B=st.integers(1, 2),
    tq_blocks=st.integers(1, 3),
    hk=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2, 4]),
    d=st.sampled_from([16, 32]),
    causal=st.booleans(),
)
def test_flash_attention_property(B, tq_blocks, hk, g, d, causal):
    Tq = 32 * tq_blocks + 7    # deliberately non-multiple
    ks = jax.random.split(jax.random.PRNGKey(B * 1000 + Tq), 3)
    q = rand(ks[0], (B, Tq, hk * g, d), jnp.float32)
    k = rand(ks[1], (B, Tq, hk, d), jnp.float32)
    v = rand(ks[2], (B, Tq, hk, d), jnp.float32)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=32, block_k=32,
                              interpret=True)
    expected = ref.attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, expected, atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------------------
# ssd scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,T,H,N,P,chunk,with_s0",
    [
        (2, 128, 2, 16, 32, 32, False),
        (1, 100, 3, 8, 16, 32, False),     # padded T
        (2, 64, 2, 16, 16, 16, True),
        (1, 256, 1, 32, 64, 64, False),
        (1, 17, 2, 8, 8, 32, True),        # T < chunk
    ])
def test_ssd_scan_sweep(B, T, H, N, P, chunk, with_s0, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    c = rand(ks[0], (B, T, H, N), dtype)
    b = rand(ks[1], (B, T, H, N), dtype)
    v = rand(ks[2], (B, T, H, P), dtype)
    la = -jax.nn.softplus(jax.random.normal(ks[3], (B, T, H), jnp.float32))
    s0 = (jax.random.normal(ks[4], (B, H, N, P), jnp.float32)
          if with_s0 else None)
    y, S = ops.ssd_scan(c, b, v, la, initial_state=s0, chunk=chunk,
                        interpret=True)
    yr, Sr = ref.ssd_scan_ref(c, b, v, la, initial_state=s0)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               **TOL[dtype])
    np.testing.assert_allclose(S, Sr, atol=5e-4, rtol=5e-4)


@settings(max_examples=15, deadline=None)
@given(
    T=st.integers(4, 80),
    chunk=st.sampled_from([8, 16, 32]),
    H=st.integers(1, 3),
    N=st.sampled_from([8, 16]),
)
def test_ssd_scan_chunk_independence(T, chunk, H, N):
    """Chunked recomposition must equal the sequential recurrence for any
    chunk size (the associativity invariant of the SSD algebra)."""
    ks = jax.random.split(jax.random.PRNGKey(T * 97 + chunk), 4)
    c = rand(ks[0], (1, T, H, N), jnp.float32)
    b = rand(ks[1], (1, T, H, N), jnp.float32)
    v = rand(ks[2], (1, T, H, N), jnp.float32)
    la = -jax.nn.softplus(jax.random.normal(ks[3], (1, T, H), jnp.float32))
    y, S = ops.ssd_scan(c, b, v, la, chunk=chunk, interpret=True)
    yr, Sr = ref.ssd_scan_ref(c, b, v, la)
    np.testing.assert_allclose(y, yr, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(S, Sr, atol=1e-4, rtol=1e-4)


def test_ssd_scan_state_chaining():
    """scan(T) == scan(T/2) chained through the carried state."""
    T = 64
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    c = rand(ks[0], (1, T, 2, 8), jnp.float32)
    b = rand(ks[1], (1, T, 2, 8), jnp.float32)
    v = rand(ks[2], (1, T, 2, 8), jnp.float32)
    la = -jax.nn.softplus(jax.random.normal(ks[3], (1, T, 2), jnp.float32))
    y_full, S_full = ops.ssd_scan(c, b, v, la, chunk=16, interpret=True)
    h = T // 2
    y1, S1 = ops.ssd_scan(c[:, :h], b[:, :h], v[:, :h], la[:, :h],
                          chunk=16, interpret=True)
    y2, S2 = ops.ssd_scan(c[:, h:], b[:, h:], v[:, h:], la[:, h:],
                          initial_state=S1, chunk=16, interpret=True)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full,
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(S2, S_full, atol=1e-4, rtol=1e-4)


# ---------------------------------------------------------------------------
# moe dispatch/combine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "T,d,E,K,F,cap,bm,bf",
    [
        (64, 32, 4, 2, 16, 32, 16, 16),
        (128, 64, 8, 2, 32, 24, 32, 32),    # drops happen
        (100, 32, 4, 4, 16, 128, 64, 16),   # no drops
        (32, 16, 2, 1, 8, 16, 8, 8),
    ])
def test_moe_sweep(T, d, E, K, F, cap, bm, bf, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 4)
    x = rand(ks[0], (T, d), dtype)
    logits = jax.random.normal(ks[1], (T, E), jnp.float32)
    gv, gi = jax.lax.top_k(jax.nn.softmax(logits), K)
    gv = (gv / gv.sum(-1, keepdims=True)).astype(dtype)
    w_up = rand(ks[2], (E, d, 2 * F), dtype) * 0.1
    w_down = rand(ks[3], (E, F, d), dtype) * 0.1
    out = ops.moe_dispatch_combine(x, gi, gv, w_up, w_down, capacity=cap,
                                   block_m=bm, block_f=bf, interpret=True)
    expected = ref.moe_dispatch_combine_ref(x, gi, gv, w_up, w_down,
                                            capacity=cap)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expected, np.float32),
                               atol=5e-2 if dtype == jnp.bfloat16 else 2e-4,
                               rtol=5e-2 if dtype == jnp.bfloat16 else 2e-4)


@settings(max_examples=15, deadline=None)
@given(
    T=st.integers(8, 64),
    E=st.sampled_from([2, 4, 8]),
    K=st.integers(1, 3),
    cap_frac=st.floats(0.2, 2.0),
)
def test_moe_property(T, E, K, cap_frac):
    K = min(K, E)
    cap = max(int(cap_frac * T * K / E), 1)
    d, F = 16, 8
    ks = jax.random.split(jax.random.PRNGKey(T * 31 + E), 4)
    x = rand(ks[0], (T, d), jnp.float32)
    logits = jax.random.normal(ks[1], (T, E), jnp.float32)
    gv, gi = jax.lax.top_k(jax.nn.softmax(logits), K)
    w_up = rand(ks[2], (E, d, 2 * F), jnp.float32) * 0.1
    w_down = rand(ks[3], (E, F, d), jnp.float32) * 0.1
    out = ops.moe_dispatch_combine(x, gi, gv, w_up, w_down, capacity=cap,
                                   block_m=16, block_f=8, interpret=True)
    expected = ref.moe_dispatch_combine_ref(x, gi, gv, w_up, w_down,
                                            capacity=cap)
    np.testing.assert_allclose(out, expected, atol=2e-4, rtol=2e-4)


def test_moe_dispatch_capacity_invariants():
    """Queue positions are dense per expert and respect arrival order."""
    T, K, E, cap = 40, 2, 4, 8
    gi = jax.random.randint(jax.random.PRNGKey(7), (T, K), 0, E)
    token_of, keep, pos = ops.dispatch_indices(gi, cap, E)
    token_of = np.asarray(token_of)
    # every non-pad slot holds a valid token id, strictly increasing per
    # expert queue (first-come order)
    for e in range(E):
        ids = [t for t in token_of[e] if t >= 0]
        assert ids == sorted(ids)
    # kept count per expert <= capacity
    kept_per_e = np.zeros(E, int)
    gi_n, keep_n = np.asarray(gi), np.asarray(keep)
    for t in range(T):
        for k in range(K):
            if keep_n[t, k]:
                kept_per_e[gi_n[t, k]] += 1
    assert (kept_per_e <= cap).all()
