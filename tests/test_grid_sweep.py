"""Vectorized M-model frontier sweep + rolling-horizon merge.

* The batched group co-execution laws
  (``ContentionModel.group_step_cost_batch`` / ``group_energy_batch``)
  and the per-(subset, signature-tuple) ``GroupCostCache`` tables must
  match the scalar laws **element-for-element** (bitwise — same
  accumulation order, same first-minimum PU-combo tie-break).
* The anti-diagonal sweep (``algorithm="grid"``) must be equivalent to
  the retained heap A* (``algorithm="grid_astar"``) on shared M=3/M=4
  instances: bitwise objective value and identical per-request op→PU
  routes under the latency objective; under the energy objective the
  group laws create *structural* FP-tie plateaus (a same-PU group step
  costs exactly the solo steps' energy sum), where the heap A* is exact
  only to its 2-quanta priority quantization while the sweep returns the
  exact FP minimum — there the sweep must never be worse and must agree
  to FP noise with identical per-request assignments.
* The rolling-horizon merge upper-bounds the exact grid optimum, covers
  every op exactly once, collapses to the grid solve bitwise when a
  single window suffices, and beats the back-to-back pairwise merge on
  a constructed 4-model case with disjoint PU affinities.
"""
import itertools

import numpy as np
import pytest

from repro.core import (ContentionModel, CostEntry, CostTable,
                        DenseCostTable, EDGE_PUS, FusedOp, GroupCostCache,
                        InfeasibleScheduleError, Workload, solve_concurrent)

PUS = ("CPU", "GPU", "NPU")


def random_workload(rng, n_ops, drop_frac=0.25):
    table = CostTable(list(PUS))
    ops = []
    for i in range(n_ops):
        ops.append(FusedOp(name=f"o{i}", kind="other", out_shape=(4,)))
        sup = [p for p in PUS if rng.random() > drop_frac]
        if not sup:
            sup = [PUS[int(rng.integers(len(PUS)))]]
        for pu in sup:
            table.set(i, pu, CostEntry(
                kernel=float(rng.uniform(1e-6, 1e-3)),
                dispatch=float(rng.uniform(0, 1e-5)),
                h2d=float(rng.uniform(0, 1e-4)),
                d2h=float(rng.uniform(0, 1e-4)),
                power=float(rng.uniform(5.0, 30.0))))
    return Workload.build(list(range(n_ops)), table, EDGE_PUS, ops=ops)


def single_pu_workload(pu, n_ops, kernel, power=10.0):
    """A chain supported on exactly one PU (strict affinity)."""
    table = CostTable(list(PUS))
    ops = []
    for i in range(n_ops):
        ops.append(FusedOp(name=f"{pu}{i}", kind="other", out_shape=(4,)))
        table.set(i, pu, CostEntry(kernel=kernel, dispatch=0.0, h2d=0.0,
                                   d2h=0.0, power=power))
    return Workload.build(list(range(n_ops)), table, EDGE_PUS, ops=ops)


def objective_key(sched, objective):
    return sched.latency if objective == "latency" else sched.energy


# ---------------------------------------------------------------------------
# batched group laws == scalar group laws, element for element
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("g", [2, 3, 4])
def test_batched_group_laws_match_scalar_elementwise(g):
    cm = ContentionModel()
    rng = np.random.default_rng(100 + g)
    for _ in range(20):
        pus_ = [PUS[int(i)] for i in rng.integers(0, 3, g)]
        ts = rng.uniform(1e-6, 1e-3, (50, g))
        pws = rng.uniform(5.0, 30.0, (50, g))
        got_c = cm.group_step_cost_batch(ts, pus_)
        got_e = cm.group_energy_batch(ts, pws, pus_)
        for b in range(ts.shape[0]):
            want_c = cm.group_step_cost(list(ts[b]), pus_)
            want_e = cm.group_energy(list(ts[b]), list(pws[b]), pus_)
            assert got_c[b] == want_c          # bitwise
            assert got_e[b] == want_e          # bitwise


def _scalar_group_edges(cm, denses):
    """Independent scalar re-derivation of the per-signature-tuple group
    edges (the heap A*'s per-state enumeration): first-minimum over
    supported PU combos in lexicographic order, both objectives."""
    rows = [d.sig_row for d in denses]
    out = {}
    for sig_key in itertools.product(*[range(len(r)) for r in rows]):
        sups = []
        for d, r, s in zip(denses, rows, sig_key):
            sups.append(list(np.flatnonzero(d.mask[r[s]])))
        inf = float("inf")
        best_l = best_e = (inf, inf, inf, None)
        for combo in itertools.product(*sups):
            ts = [float(d.w[r[s], j])
                  for d, r, s, j in zip(denses, rows, sig_key, combo)]
            pws = [float(d.power[r[s], j])
                   for d, r, s, j in zip(denses, rows, sig_key, combo)]
            pnames = [d.pus[j] for d, j in zip(denses, combo)]
            step = cm.group_step_cost(ts, pnames)
            e = cm.group_energy(ts, pws, pnames)
            if step < best_l[0]:
                best_l = (step, step, e, combo)
            if e < best_e[0]:
                best_e = (e, step, e, combo)
        out[sig_key] = (best_l, best_e)
    return out


@pytest.mark.parametrize("g", [2, 3])
def test_group_cost_cache_matches_scalar_enumeration(g):
    cm = ContentionModel()
    rng = np.random.default_rng(200 + g)
    wls = [random_workload(rng, int(rng.integers(3, 7))) for _ in range(g)]
    denses = [wl.dense for wl in wls]
    cache = GroupCostCache(cm, denses)
    want = _scalar_group_edges(cm, denses)
    for oi, objective in enumerate(("latency", "energy")):
        pk, ps, pe, pa = cache.edge_tables(objective)
        for sig_key, bests in want.items():
            wk, wstep, weng, wcombo = bests[oi]
            assert pk[sig_key] == wk           # bitwise
            assert ps[sig_key] == wstep
            assert pe[sig_key] == weng
            ci = int(pa[sig_key])
            combo = []
            for d in reversed(denses):
                ci, j = divmod(ci, d.k)
                combo.append(j)
            combo.reverse()
            assert tuple(combo) == wcombo      # same first-minimum combo


def test_group_cost_cache_rejects_singletons():
    rng = np.random.default_rng(3)
    wl = random_workload(rng, 3)
    with pytest.raises(ValueError, match=">= 2"):
        GroupCostCache(ContentionModel(), [wl.dense])


# ---------------------------------------------------------------------------
# vectorized sweep vs retained heap A*
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", range(10))
@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_sweep_equivalent_to_heap_astar(seed, objective):
    rng = np.random.default_rng(seed)
    m = 3 if seed % 2 == 0 else 4
    hi = 8 if m == 3 else 6
    wls = [random_workload(rng, int(rng.integers(2, hi))) for _ in range(m)]
    cm = ContentionModel()
    sweep = solve_concurrent(wls, cm, objective, algorithm="grid")
    astar = solve_concurrent(wls, cm, objective, algorithm="grid_astar")
    assert sweep.mode == astar.mode == "joint-grid"
    ks, ka = objective_key(sweep, objective), objective_key(astar, objective)
    # the sweep is the exact FP optimum; the heap A* is exact up to its
    # 2-quanta priority quantization — never better than the sweep
    assert ks <= ka * (1 + 1e-12)
    if objective == "latency":
        assert sweep.latency == astar.latency          # bitwise
        assert sweep.energy == pytest.approx(astar.energy, rel=1e-12)
    else:
        # energy mode has structural exact ties (a same-PU group step
        # costs exactly the solo steps' energy sum), so equally-optimal
        # grouping structures can differ by accumulated FP rounding
        assert sweep.energy == pytest.approx(astar.energy, rel=1e-11)
        assert sweep.latency == pytest.approx(astar.latency, rel=1e-11)
    for r in range(m):
        assert sweep.assignment_of(r) == astar.assignment_of(r)


@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_sweep_latency_route_bitwise_on_tie_free_instance(objective):
    """On a tie-free instance the two algorithms must return the same
    schedule step for step (not just the same objective value)."""
    rng = np.random.default_rng(77)
    wls = [random_workload(rng, n, drop_frac=0.0) for n in (4, 3, 5)]
    cm = ContentionModel()
    sweep = solve_concurrent(wls, cm, objective, algorithm="grid")
    astar = solve_concurrent(wls, cm, objective, algorithm="grid_astar")
    if objective == "latency":
        assert ([(s.ops, s.pus, s.cost) for s in sweep.steps]
                == [(s.ops, s.pus, s.cost) for s in astar.steps])
        assert (sweep.latency, sweep.energy) == (astar.latency, astar.energy)
    for r in range(3):
        assert sweep.assignment_of(r) == astar.assignment_of(r)


def test_sweep_handles_m2_and_m4_shapes():
    rng = np.random.default_rng(11)
    cm = ContentionModel()
    for m in (2, 4):
        wls = [random_workload(rng, int(rng.integers(1, 5)))
               for _ in range(m)]
        sched = solve_concurrent(wls, cm, algorithm="grid")
        assert sched.n_requests == m
        for r, wl in enumerate(wls):
            assert [o for o, _ in sched.assignment_of(r)] == wl.chain


# ---------------------------------------------------------------------------
# rolling-horizon merge
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_rolling_single_window_is_bitwise_the_grid_solve(objective):
    """When one window covers all remaining ops, rolling IS the exact
    grid sweep — bitwise."""
    rng = np.random.default_rng(21)
    wls = [random_workload(rng, int(rng.integers(2, 5))) for _ in range(3)]
    cm = ContentionModel()
    grid = solve_concurrent(wls, cm, objective, algorithm="grid")
    roll = solve_concurrent(wls, cm, objective, algorithm="rolling")
    assert roll.mode == "rolling"
    assert (roll.latency, roll.energy) == (grid.latency, grid.energy)
    assert ([(s.ops, s.pus, s.cost) for s in roll.steps]
            == [(s.ops, s.pus, s.cost) for s in grid.steps])


@pytest.mark.parametrize("seed", range(5))
@pytest.mark.parametrize("objective", ["latency", "energy"])
def test_rolling_multiwindow_upper_bounds_grid_and_covers(seed, objective):
    rng = np.random.default_rng(400 + seed)
    wls = [random_workload(rng, int(rng.integers(5, 10))) for _ in range(3)]
    cm = ContentionModel()
    grid = solve_concurrent(wls, cm, objective, algorithm="grid",
                            max_states=10**6)
    roll = solve_concurrent(wls, cm, objective, algorithm="rolling",
                            window_states=60)   # forces several windows
    assert roll.mode == "rolling"
    assert objective_key(grid, objective) <= (
        objective_key(roll, objective) * (1 + 1e-9))
    for r, wl in enumerate(wls):
        assert [o for o, _ in roll.assignment_of(r)] == wl.chain


def test_rolling_beats_pairwise_on_disjoint_affinity_quad():
    """Four requests with strict PU affinities (CPU, CPU, GPU, NPU): the
    pairwise merge pairs the two long CPU-bound requests (descending
    totals) and must serialize them on the CPU queue, then run the
    GPU/NPU pair in a separate back-to-back stage; the rolling horizon
    co-schedules all four, overlapping the GPU and NPU chains with the
    serialized CPU queue.  Exact grid <= rolling < pairwise, strictly."""
    cm = ContentionModel()
    wls = [single_pu_workload("CPU", 8, 1.0e-3),
           single_pu_workload("CPU", 8, 0.99e-3),
           single_pu_workload("GPU", 8, 0.9e-3),
           single_pu_workload("NPU", 8, 0.8e-3)]
    grid = solve_concurrent(wls, cm, algorithm="grid", max_states=10**6)
    roll = solve_concurrent(wls, cm, algorithm="rolling", window_states=100)
    pw = solve_concurrent(wls, cm, algorithm="pairwise")
    assert grid.latency <= roll.latency * (1 + 1e-9)
    assert roll.latency < pw.latency * 0.95     # clearly, not marginally
    for r, wl in enumerate(wls):
        assert [o for o, _ in roll.assignment_of(r)] == wl.chain


def test_rolling_schedule_executes_bitwise_vs_isolated():
    """A multi-window rolling schedule run across the shared PU lanes
    must produce outputs identical to isolated per-model execution."""
    from repro.core import EdgeSoCCostModel, OpGraph, ScheduleExecutor

    rng = np.random.default_rng(0)
    graphs, inputs = [], []
    for r in range(3):
        ops = []
        for i in range(6):
            w = rng.standard_normal((16, 16)) / 4.0
            ops.append(FusedOp(
                name=f"m{r}.{i}", kind="matmul",
                in_shapes=((4, 16), (16, 16)), out_shape=(4, 16),
                fn=(lambda wi: lambda x: np.tanh(x @ wi))(w)))
        graphs.append(OpGraph(ops))
        inputs.append({0: (rng.standard_normal((4, 16)),)})
    model = EdgeSoCCostModel()
    wls = [Workload.build(list(range(len(g))), model.build_table(g),
                          EDGE_PUS, ops=g.ops) for g in graphs]
    sched = solve_concurrent(wls, ContentionModel(), algorithm="rolling",
                             window_states=30)    # forces several windows
    assert sched.mode == "rolling"
    ex = ScheduleExecutor(list(EDGE_PUS))
    conc = ex.run_concurrent(graphs, sched, inputs)
    for g, x, got in zip(graphs, inputs, conc):
        mono = ex.run_monolithic(g, x)
        assert ScheduleExecutor.outputs_close(mono, got)   # bitwise


def test_forced_algorithm_on_single_request_raises():
    """M=1 has no concurrent search to route: forcing any algorithm must
    raise instead of silently returning the unconstrained solo walk, and
    unknown algorithm names must never pass the M=1/M=2 early-outs."""
    rng = np.random.default_rng(17)
    wl = random_workload(rng, 4, drop_frac=0.0)
    for algo in ("grid", "grid_astar", "rolling", "pairwise"):
        with pytest.raises(ValueError, match="solo best-PU walk"):
            solve_concurrent([wl], algorithm=algo)
    with pytest.raises(ValueError, match="bogus"):
        solve_concurrent([wl], algorithm="bogus")
    with pytest.raises(ValueError, match="bogus"):
        solve_concurrent([wl, wl], algorithm="bogus")
    with pytest.raises(ValueError, match="solo best-PU walk"):
        solve_concurrent([wl], max_states=100)


def test_max_states_on_pair_fast_path_raises():
    """M=2 auto dispatches to the pair A*, which max_states cannot
    bound — passing it must raise, not be silently dropped; the forced
    state-bounded routes still honour it."""
    rng = np.random.default_rng(18)
    wl0, wl1 = (random_workload(rng, 5, drop_frac=0.0) for _ in range(2))
    cm = ContentionModel()
    with pytest.raises(ValueError, match="pair A\\* fast path"):
        solve_concurrent([wl0, wl1], cm, max_states=10**6)
    sched = solve_concurrent([wl0, wl1], cm, algorithm="grid",
                             max_states=10**6)
    assert sched.mode == "joint-grid"
    with pytest.raises(ValueError, match="max_states"):
        solve_concurrent([wl0, wl1], cm, algorithm="grid", max_states=5)


def test_forced_rolling_never_silently_downgrades_to_pairwise():
    """Near-unique per-op signatures (a measured-profile shape) make the
    rolling route's shared group tables enormous: auto falls back to the
    pairwise merge, but a *forced* algorithm='rolling' must raise rather
    than silently return a pairwise schedule."""
    rng = np.random.default_rng(31)
    # ~170 unique signatures each -> 171^3 > 4M table cap (and > the
    # default exact-solve state ceiling, so auto reaches the same gate)
    wls = [random_workload(rng, 170, drop_frac=0.0) for _ in range(3)]
    cm = ContentionModel()
    with pytest.raises(ValueError, match="table cap"):
        solve_concurrent(wls, cm, algorithm="rolling")
    sched = solve_concurrent(wls, cm)          # auto: documented fallback
    assert sched.mode == "pairwise"


def test_rolling_rejects_custom_group_laws():
    class Harsh(ContentionModel):
        def co_exec(self, t_a, pu_a, t_b, pu_b):
            return 10.0 * t_a, 10.0 * t_b

    rng = np.random.default_rng(5)
    wls = [random_workload(rng, 3, drop_frac=0.0) for _ in range(3)]
    with pytest.raises(ValueError, match="group co-execution"):
        solve_concurrent(wls, Harsh(), algorithm="rolling")


def test_custom_batch_law_override_routes_away_from_sweep():
    """Overriding only the batched law must disqualify the grid sweep
    (it would silently disagree with the scalar laws otherwise)."""
    class Odd(ContentionModel):
        def group_step_cost_batch(self, ts, pus_):
            return super().group_step_cost_batch(ts, pus_) * 2.0

    rng = np.random.default_rng(6)
    wls = [random_workload(rng, 3, drop_frac=0.0) for _ in range(3)]
    sched = solve_concurrent(wls, Odd())
    assert sched.mode == "pairwise"


# ---------------------------------------------------------------------------
# infeasibility reporting (regression: bare 'joint search failed...')
# ---------------------------------------------------------------------------


def test_all_pu_masked_op_names_request_op_and_position():
    """M=3 workload whose middle request has an op masked on every PU:
    every concurrent route raises InfeasibleScheduleError naming the
    request index, op id/name, and chain position."""
    table = CostTable(list(PUS))
    ops = []
    for i in range(4):
        ops.append(FusedOp(name=f"x{i}", kind="other", out_shape=(4,)))
        if i != 2:                      # op 2 unsupported everywhere
            for pu in PUS:
                table.set(i, pu, CostEntry(1e-4, 1e-6, 0.0, 0.0, 10.0))
    wl_bad = Workload(chain=[0, 1, 2, 3],
                      dense=DenseCostTable.from_chain([0, 1, 2, 3], table,
                                                      EDGE_PUS),
                      pus=EDGE_PUS, ops=ops, table=table)
    rng = np.random.default_rng(9)
    wl_ok = random_workload(rng, 3, drop_frac=0.0)
    for algo in ("grid", "grid_astar", "rolling", "pairwise", "auto"):
        with pytest.raises(InfeasibleScheduleError) as ei:
            solve_concurrent([wl_ok, wl_bad, wl_ok], algorithm=algo)
        msg = str(ei.value)
        assert "request 1" in msg
        assert "op 2 (x2)" in msg
        assert "chain position 2" in msg
